#!/usr/bin/env python3
"""End-to-end smoke test for the serving stack (ADR-007).

Expects a running `simetra serve` on HOST:PORT (argv[1], argv[2]) with
--dim matching DIM below. Talks the JSON-lines TCP protocol directly
(no client library) and validates:

  - ping answers pong;
  - the `search` op answers hits and never a trace;
  - the `explain` op answers the same hits (bit-exact scores via repr)
    plus a non-empty trace of known event kinds;
  - the `metrics` op returns a Prometheus text page that parses line by
    line and carries the ADR-007 families (bound-slack keyed by index
    and bound, per-stage spans) next to the request-latency histogram.
"""
import json
import re
import socket
import sys
import time

HOST, PORT = sys.argv[1], int(sys.argv[2])
DIM = 16
TRACE_KINDS = {"visit", "prune", "eval", "scan", "budget_stop", "filter_gate"}
METRIC_LINE = re.compile(
    r"^[A-Za-z_:][A-Za-z0-9_:]*(\{[^{}]*\})? -?[0-9][0-9.eE+-]*$"
)


def connect(retries=100):
    for _ in range(retries):
        try:
            return socket.create_connection((HOST, PORT), timeout=10)
        except OSError:
            time.sleep(0.2)
    sys.exit(f"server never came up on {HOST}:{PORT}")


def main():
    sock = connect()
    f = sock.makefile("rwb")

    def rpc(obj):
        f.write((json.dumps(obj) + "\n").encode())
        f.flush()
        line = f.readline()
        if not line:
            sys.exit(f"connection closed on op {obj.get('op')!r}")
        reply = json.loads(line)
        if reply.get("status") == "error":
            sys.exit(f"op {obj.get('op')!r} failed: {reply}")
        return reply

    assert rpc({"op": "ping"})["status"] == "pong"

    vec = [1.0 if i == 0 else 1e-3 * i for i in range(DIM)]
    plan = {"v": 1, "vector": vec, "mode": "knn", "k": 5}

    search = rpc({"op": "search", **plan})
    assert search["status"] == "search", search
    assert len(search["hits"]) == 5, search
    assert "trace" not in search, "search replies must never carry a trace"

    explain = rpc({"op": "explain", **plan})
    assert explain["status"] == "explain", explain
    hits = [(h["id"], repr(h["score"])) for h in search["hits"]]
    ehits = [(h["id"], repr(h["score"])) for h in explain["hits"]]
    assert hits == ehits, f"explain hits diverge from search: {hits} vs {ehits}"
    trace = explain["trace"]
    assert trace, "explain returned an empty trace"
    kinds = {e["kind"] for e in trace}
    assert kinds <= TRACE_KINDS, f"unknown trace kinds: {kinds - TRACE_KINDS}"

    text = rpc({"op": "metrics"})["text"]
    for line in text.splitlines():
        if not line or line.startswith("#"):
            continue
        assert METRIC_LINE.match(line), f"malformed metric line: {line!r}"
    for needle in [
        "# TYPE simetra_queries_total counter",
        "# TYPE simetra_request_latency_us histogram",
        "# TYPE simetra_bound_slack histogram",
        'simetra_bound_slack_count{index="',
        "# TYPE simetra_stage_duration_ns histogram",
        'stage="parse"',
        'stage="traversal"',
    ]:
        assert needle in text, f"metrics page is missing {needle!r}"

    # The stats op exposes the same latency histogram the Prometheus page
    # renders (one snapshot path; counts may drift between the two reads).
    stats = rpc({"op": "stats"})
    assert stats["queries"] >= 2, stats
    assert sum(stats["latency_us_buckets"]) >= 2, stats
    assert re.search(r"simetra_request_latency_us_count \d+", text), text

    print("serve smoke test OK "
          f"({len(trace)} trace events, {len(text.splitlines())} metric lines)")


if __name__ == "__main__":
    main()
