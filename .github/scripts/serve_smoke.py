#!/usr/bin/env python3
"""End-to-end smoke test for the serving stack (ADR-007).

Expects a running `simetra serve` on HOST:PORT (argv[1], argv[2]) with
--dim matching DIM below. Talks the JSON-lines TCP protocol directly
(no client library) and validates:

  - ping answers pong;
  - the `search` op answers hits and never a trace;
  - the `explain` op answers the same hits (bit-exact scores via repr)
    plus a non-empty trace of known event kinds;
  - the `metrics` op returns a Prometheus text page that parses line by
    line and carries the ADR-007 families (bound-slack keyed by index
    and bound, per-stage spans) next to the request-latency histogram
    and the ADR-008 wire counters/gauges;
  - a pipelined burst (many frames in one write) answers every frame,
    in order (ADR-008);
  - malformed frames — broken JSON, a truncated line, invalid UTF-8,
    an unknown op — each earn an error reply and the connection keeps
    serving afterwards.
"""
import json
import re
import socket
import sys
import time

HOST, PORT = sys.argv[1], int(sys.argv[2])
DIM = 16
TRACE_KINDS = {"visit", "prune", "eval", "scan", "budget_stop", "filter_gate"}
METRIC_LINE = re.compile(
    r"^[A-Za-z_:][A-Za-z0-9_:]*(\{[^{}]*\})? -?[0-9][0-9.eE+-]*$"
)


def connect(retries=100):
    for _ in range(retries):
        try:
            return socket.create_connection((HOST, PORT), timeout=10)
        except OSError:
            time.sleep(0.2)
    sys.exit(f"server never came up on {HOST}:{PORT}")


def main():
    sock = connect()
    f = sock.makefile("rwb")

    def rpc(obj):
        f.write((json.dumps(obj) + "\n").encode())
        f.flush()
        line = f.readline()
        if not line:
            sys.exit(f"connection closed on op {obj.get('op')!r}")
        reply = json.loads(line)
        if reply.get("status") == "error":
            sys.exit(f"op {obj.get('op')!r} failed: {reply}")
        return reply

    assert rpc({"op": "ping"})["status"] == "pong"

    vec = [1.0 if i == 0 else 1e-3 * i for i in range(DIM)]
    plan = {"v": 1, "vector": vec, "mode": "knn", "k": 5}

    search = rpc({"op": "search", **plan})
    assert search["status"] == "search", search
    assert len(search["hits"]) == 5, search
    assert "trace" not in search, "search replies must never carry a trace"

    explain = rpc({"op": "explain", **plan})
    assert explain["status"] == "explain", explain
    hits = [(h["id"], repr(h["score"])) for h in search["hits"]]
    ehits = [(h["id"], repr(h["score"])) for h in explain["hits"]]
    assert hits == ehits, f"explain hits diverge from search: {hits} vs {ehits}"
    trace = explain["trace"]
    assert trace, "explain returned an empty trace"
    kinds = {e["kind"] for e in trace}
    assert kinds <= TRACE_KINDS, f"unknown trace kinds: {kinds - TRACE_KINDS}"

    text = rpc({"op": "metrics"})["text"]
    for line in text.splitlines():
        if not line or line.startswith("#"):
            continue
        assert METRIC_LINE.match(line), f"malformed metric line: {line!r}"
    for needle in [
        "# TYPE simetra_queries_total counter",
        "# TYPE simetra_request_latency_us histogram",
        "# TYPE simetra_bound_slack histogram",
        'simetra_bound_slack_count{index="',
        "# TYPE simetra_stage_duration_ns histogram",
        'stage="parse"',
        'stage="traversal"',
        "# TYPE simetra_bytes_in_total counter",
        "# TYPE simetra_bytes_out_total counter",
        "# TYPE simetra_conns_live gauge",
    ]:
        assert needle in text, f"metrics page is missing {needle!r}"

    # The stats op exposes the same latency histogram the Prometheus page
    # renders (one snapshot path; counts may drift between the two reads).
    stats = rpc({"op": "stats"})
    assert stats["queries"] >= 2, stats
    assert sum(stats["latency_us_buckets"]) >= 2, stats
    assert re.search(r"simetra_request_latency_us_count \d+", text), text

    # Pipelined burst (ADR-008): many frames in one write, replies must
    # come back in order. Distinct k values make reordering detectable.
    burst_n = 32
    burst = b"".join(
        (json.dumps({"op": "knn", "vector": vec, "k": 1 + (i % 7)}) + "\n").encode()
        for i in range(burst_n)
    )
    f.write(burst)
    f.flush()
    for i in range(burst_n):
        line = f.readline()
        if not line:
            sys.exit(f"connection closed mid-burst at reply {i}")
        reply = json.loads(line)
        assert reply.get("status") == "ok", (i, reply)
        assert len(reply["hits"]) == 1 + (i % 7), (i, reply)

    # Malformed frames each earn an error line on the SAME connection,
    # which must keep serving (the legacy server dropped it on bad UTF-8).
    for frame, code in [
        (b"{not json}\n", "bad_request"),
        (b'{"op":"knn","vector":[1,2\n', "bad_request"),
        (b'{"op":"ping","x":"\xff"}\n', "bad_request"),
        (b'{"op":"explode"}\n', "unknown_op"),
    ]:
        f.write(frame)
        f.flush()
        line = f.readline()
        if not line:
            sys.exit(f"connection closed on malformed frame {frame!r}")
        reply = json.loads(line)
        assert reply.get("status") == "error", (frame, reply)
        assert reply.get("code") == code, (frame, reply)
    assert rpc({"op": "ping"})["status"] == "pong"

    print("serve smoke test OK "
          f"({len(trace)} trace events, {len(text.splitlines())} metric lines, "
          f"{burst_n} pipelined replies)")


if __name__ == "__main__":
    main()
