//! Bound-accelerated spherical k-means: the paper-conclusion use case
//! ("acceleration of data mining algorithms") — Elkan-style pruning with
//! Eqs. 10/13, ablated against plain Lloyd's.
//!
//!     cargo run --release --example clustering

use simetra::cluster::{spherical_kmeans, KMeansConfig};
use simetra::data::{vmf_mixture, VmfSpec};

fn main() {
    for (n, dim, k, kappa) in [
        (20_000usize, 32usize, 25usize, 120.0f64),
        (20_000, 64, 50, 300.0),
        (50_000, 32, 25, 120.0),
    ] {
        println!("\n== n={n} d={dim} k={k} kappa={kappa} ==");
        let (pts, _) = vmf_mixture(&VmfSpec { n, dim, clusters: k, kappa, seed: 5 });
        let base = KMeansConfig { k, max_iters: 30, seed: 17, ..Default::default() };

        let t0 = std::time::Instant::now();
        let plain =
            spherical_kmeans(&pts, &KMeansConfig { use_bounds: false, ..base.clone() });
        let t_plain = t0.elapsed();

        let t0 = std::time::Instant::now();
        let fast = spherical_kmeans(&pts, &KMeansConfig { use_bounds: true, ..base });
        let t_fast = t0.elapsed();

        assert_eq!(plain.assignment, fast.assignment, "pruning changed the result!");
        println!(
            "plain Lloyd:   {:>12} sim evals, {t_plain:?} ({} iters, objective {:.4})",
            plain.sim_evals, plain.iterations, plain.objective
        );
        println!(
            "Eq.10/13:      {:>12} sim evals, {t_fast:?} ({} center-prunes, {} point-skips)",
            fast.sim_evals, fast.pruned_centers, fast.skipped_points
        );
        println!(
            "savings:       {:.1}x fewer similarity evaluations, {:.1}x wall clock \
             — identical clustering",
            plain.sim_evals as f64 / fast.sim_evals as f64,
            t_plain.as_secs_f64() / t_fast.as_secs_f64()
        );
    }
}
