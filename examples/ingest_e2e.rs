//! End-to-end generational-ingest driver: start a *mutable* coordinator
//! behind the TCP server, then drive the full lifecycle over the wire —
//! insert -> query -> delete -> compact -> query — validating every
//! answer against a client-side shadow of the corpus (exact linear scan,
//! bit-identical similarities).
//!
//!     cargo run --release --example ingest_e2e

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use simetra::coordinator::{server, BatchConfig, Coordinator, CoordinatorConfig};
use simetra::ingest::IngestConfig;
use simetra::storage::{dot_slice, normalize_row};
use simetra::util::Rng;

const DIM: usize = 32;
const N: usize = 4_000;
const K: usize = 10;

fn oracle_knn(shadow: &BTreeMap<u64, Vec<f32>>, q: &[f32], k: usize) -> Vec<(u64, f64)> {
    let mut hits: Vec<(u64, f64)> =
        shadow.iter().map(|(&id, row)| (id, dot_slice(q, row))).collect();
    hits.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    hits.truncate(k);
    hits
}

/// Fire `K`-NN probes for a sample of shadow rows and require the wire
/// answer to match the oracle exactly (Rust float formatting round-trips
/// f64 bit-for-bit, so even the scores must be identical).
fn verify(
    client: &mut server::Client,
    shadow: &BTreeMap<u64, Vec<f32>>,
    label: &str,
) -> anyhow::Result<()> {
    let ids: Vec<u64> = shadow.keys().copied().collect();
    for probe in ids.iter().step_by(ids.len().max(1) / 20 + 1) {
        let q = shadow[probe].clone();
        let want = oracle_knn(shadow, &q, K);
        let got = client.knn(q, K)?;
        anyhow::ensure!(got.len() == want.len(), "{label}: hit count mismatch");
        for (g, (wid, wscore)) in got.iter().zip(&want) {
            anyhow::ensure!(
                g.id == *wid && g.score == *wscore,
                "{label}: probe {probe}: got ({}, {}), want ({wid}, {wscore})",
                g.id,
                g.score
            );
        }
    }
    println!("  verified: wire answers == linear-scan oracle ({label})");
    Ok(())
}

fn print_stats(client: &mut server::Client, label: &str) -> anyhow::Result<()> {
    let s = client.stats()?;
    println!(
        "  stats [{label}]: live={} generations={} memtable={} tombstones={} \
         sealed_bytes={} seals={} compactions={}",
        s.corpus_size,
        s.generations,
        s.memtable_items,
        s.tombstones,
        s.sealed_bytes,
        s.seals,
        s.compactions
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    println!("ingest e2e: mutable corpus over TCP, n={N} dim={DIM} k={K}");
    let coord = Coordinator::new_mutable(
        CoordinatorConfig {
            batch: BatchConfig {
                max_batch: 32,
                max_wait: Duration::from_micros(200),
                queue_depth: 1024,
            },
            ..CoordinatorConfig::default()
        },
        IngestConfig { seal_threshold: 512, ..IngestConfig::new(DIM) },
    )?;
    let server_handle = server::serve(coord, "127.0.0.1:0")?;
    let mut client = server::Client::connect(server_handle.addr())?;
    let mut rng = Rng::seed_from_u64(7);
    let mut shadow: BTreeMap<u64, Vec<f32>> = BTreeMap::new();

    // Phase 1: insert.
    let t0 = Instant::now();
    for _ in 0..N {
        let raw: Vec<f32> = (0..DIM).map(|_| rng.normal() as f32).collect();
        let id = client.insert(raw.clone())?;
        let mut row = raw;
        normalize_row(&mut row);
        shadow.insert(id, row);
    }
    println!(
        "inserted {N} vectors in {:?} ({:.0} inserts/s)",
        t0.elapsed(),
        N as f64 / t0.elapsed().as_secs_f64()
    );
    print_stats(&mut client, "after insert")?;

    // Phase 2: query while the corpus is spread over memtable + sealed
    // generations.
    verify(&mut client, &shadow, "after insert")?;

    // Phase 3: delete 10%.
    let victims: Vec<u64> = shadow.keys().copied().step_by(10).collect();
    for id in &victims {
        anyhow::ensure!(client.delete(*id)?, "id {id} was live");
        shadow.remove(id);
    }
    anyhow::ensure!(!client.delete(victims[0])?, "double delete must be a no-op");
    println!("deleted {} vectors (tombstoned)", victims.len());
    print_stats(&mut client, "after delete")?;
    verify(&mut client, &shadow, "tombstones pending")?;

    // Phase 4: compact — tombstones drop out of the physical layout.
    client.flush()?;
    client.compact()?;
    let stats = client.stats()?;
    anyhow::ensure!(stats.generations == 1, "compaction left {} generations", stats.generations);
    anyhow::ensure!(stats.tombstones == 0, "compaction left tombstones");
    anyhow::ensure!(stats.corpus_size == shadow.len() as u64, "live count drifted");
    print_stats(&mut client, "after compact")?;

    // Phase 5: query again — ids stable, deleted rows gone, still exact.
    verify(&mut client, &shadow, "after compact")?;

    println!("ok: insert -> query -> delete -> compact -> query, exact at every step");
    Ok(())
}
