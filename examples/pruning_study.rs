//! Pruning-power study (experiment X1 in DESIGN.md): the application the
//! paper defers to future work — how much exact-similarity work each metric
//! index saves with each triangle-inequality bound, across workload shapes.
//!
//! Prints one table per workload: rows = index structures, columns = bound
//! kinds, cells = % of the corpus exactly evaluated per kNN query (lower is
//! better; linear scan = 100).
//!
//!     cargo run --release --example pruning_study

use simetra::bounds::BoundKind;
use simetra::data::{uniform_sphere, vmf_mixture, VmfSpec};
use simetra::index::{
    BallTree, CoverTree, Gnat, Laesa, MTree, QueryStats, SimilarityIndex, VpTree,
};
use simetra::metrics::DenseVec;

const QUERIES: usize = 50;
const K: usize = 10;

fn eval_pct(idx: &dyn SimilarityIndex<DenseVec>, pts: &[DenseVec], n: usize) -> f64 {
    let mut stats = QueryStats::default();
    for qi in 0..QUERIES {
        let q = &pts[(qi * pts.len() / QUERIES) % pts.len()];
        idx.knn(q, K, &mut stats);
    }
    100.0 * stats.sim_evals as f64 / (QUERIES * n) as f64
}

fn study(name: &str, pts: Vec<DenseVec>) {
    let n = pts.len();
    let bounds = [
        BoundKind::Mult,
        BoundKind::ArccosFast,
        BoundKind::Euclidean,
        BoundKind::MultLb1,
        BoundKind::MultLb2,
        BoundKind::EuclLb,
    ];
    println!("\n== {name} (n={n}, {QUERIES} queries, k={K}) ==");
    print!("{:<12}", "index");
    for b in &bounds {
        print!("{:>13}", b.name());
    }
    println!("   (% of corpus exactly scored; linear = 100%)");
    let builders: Vec<(&str, Box<dyn Fn(BoundKind) -> Box<dyn SimilarityIndex<DenseVec>>>)> = vec![
        ("vp-tree", Box::new({
            let pts = pts.clone();
            move |b| Box::new(VpTree::build(pts.clone(), b, 7)) as _
        })),
        ("ball-tree", Box::new({
            let pts = pts.clone();
            move |b| Box::new(BallTree::build(pts.clone(), b, 16)) as _
        })),
        ("m-tree", Box::new({
            let pts = pts.clone();
            move |b| Box::new(MTree::build(pts.clone(), b, 12)) as _
        })),
        ("cover-tree", Box::new({
            let pts = pts.clone();
            move |b| Box::new(CoverTree::build(pts.clone(), b)) as _
        })),
        ("laesa", Box::new({
            let pts = pts.clone();
            move |b| Box::new(Laesa::build(pts.clone(), b, 32)) as _
        })),
        ("gnat", Box::new({
            let pts = pts.clone();
            move |b| Box::new(Gnat::build(pts.clone(), b, 8)) as _
        })),
    ];
    for (iname, build) in &builders {
        print!("{iname:<12}");
        for b in &bounds {
            let idx = build(*b);
            print!("{:>12.1}%", eval_pct(idx.as_ref(), &pts, n));
        }
        println!();
    }
}

fn main() {
    // Clustered embeddings: the favorable regime.
    let (clustered, _) = vmf_mixture(&VmfSpec {
        n: 20_000,
        dim: 32,
        clusters: 50,
        kappa: 100.0,
        seed: 11,
    });
    study("clustered vMF (kappa=100, d=32)", clustered);

    // Milder clustering.
    let (mild, _) = vmf_mixture(&VmfSpec {
        n: 20_000,
        dim: 32,
        clusters: 50,
        kappa: 30.0,
        seed: 12,
    });
    study("mild clusters (kappa=30, d=32)", mild);

    // Uniform sphere: the adversarial regime (concentration of measure —
    // expect little pruning at higher d, per the paper's §2 discussion).
    study("uniform sphere d=8", uniform_sphere(20_000, 8, 13));
    study("uniform sphere d=32", uniform_sphere(20_000, 32, 14));

    println!(
        "\nReading: tighter bounds (left) always prune at least as well as their\n\
         relaxations (right) — the operational content of the paper's Fig. 3 order.\n\
         Low-d / clustered data prunes hardest; uniform high-d approaches 100%\n\
         (distance concentration, paper section 2)."
    );
}
