//! Quickstart: build a similarity index with the paper's triangle
//! inequality and run exact kNN + range queries.
//!
//!     cargo run --release --example quickstart

use simetra::bounds::BoundKind;
use simetra::data::{vmf_mixture_store, VmfSpec};
use simetra::index::{LinearScan, QueryStats, SimilarityIndex, VpTree};

fn main() {
    // 1. A clustered embedding-like corpus (100k x 64, von Mises-Fisher),
    //    generated straight into one contiguous CorpusStore allocation.
    //    kappa=250 gives within-cluster sims ~0.87 — the regime where
    //    metric pruning pays off (high-dim uniform data concentrates and
    //    defeats any exact index; see paper section 2 and DESIGN.md).
    let spec = VmfSpec { n: 100_000, dim: 64, clusters: 256, kappa: 250.0, seed: 42 };
    println!("generating corpus: n={} dim={} ...", spec.n, spec.dim);
    let (store, _) = vmf_mixture_store(&spec);

    // 2. Build a VP-tree that prunes with the paper's recommended bound
    //    (Eq. 10/13, "Mult"). The index holds a zero-copy view of the
    //    store — no vectors are cloned, and leaf scans run through the
    //    blocked batch kernels.
    let t0 = std::time::Instant::now();
    let index = VpTree::build(store.view(), BoundKind::Mult, 7);
    println!("built vp-tree over {} vectors in {:?}", index.len(), t0.elapsed());

    // 3. Exact 10-NN for one query.
    let q = store.vec(123);
    let mut stats = QueryStats::default();
    let t0 = std::time::Instant::now();
    let hits = index.knn(&q, 10, &mut stats);
    let dt = t0.elapsed();
    println!("\n10-NN in {dt:?} — {} exact similarity evaluations \
              ({:.1}% of the corpus, {} subtrees pruned)",
        stats.sim_evals,
        100.0 * stats.sim_evals as f64 / store.len() as f64,
        stats.pruned);
    for (rank, (id, sim)) in hits.iter().enumerate() {
        println!("  #{rank:<2} id={id:<7} sim={sim:.6}");
    }

    // 4. Range query: everything with sim >= 0.9.
    let mut stats = QueryStats::default();
    let matches = index.range(&q, 0.9, &mut stats);
    println!("\nrange(sim >= 0.9): {} matches with {} evaluations",
        matches.len(), stats.sim_evals);

    // 5. Sanity: identical results to the exhaustive scan (which shares the
    //    same store — still zero copies of the corpus anywhere).
    let linear = LinearScan::build(store.view());
    let mut lin_stats = QueryStats::default();
    let lin_hits = linear.knn(&q, 10, &mut lin_stats);
    assert_eq!(
        hits.iter().map(|&(_, s)| (s * 1e12) as i64).collect::<Vec<_>>(),
        lin_hits.iter().map(|&(_, s)| (s * 1e12) as i64).collect::<Vec<_>>(),
    );
    println!("\nexactness check vs linear scan: OK \
              ({:.1}x fewer similarity evaluations)",
        lin_stats.sim_evals as f64 / stats.sim_evals.max(1) as f64);
}
