//! End-to-end serving driver (EXPERIMENTS.md §E2E): start the full stack —
//! corpus, sharded indexes, dynamic batcher, PJRT engine, TCP server — fire
//! a closed-loop multi-client workload at it, and report latency/throughput
//! per execution mode.
//!
//!     make artifacts && cargo run --release --example serve_e2e

use std::sync::Arc;
use std::time::Instant;

use simetra::bounds::BoundKind;
use simetra::coordinator::{
    server, BatchConfig, Coordinator, CoordinatorConfig, ExecMode, IndexKind, Request, Response,
};
use simetra::data::{vmf_mixture_store, VmfSpec};
use simetra::metrics::DenseVec;
use simetra::storage::CorpusStore;
use simetra::sync::{AtomicU64, Ordering};

const N: usize = 50_000;
const DIM: usize = 128;
const CLIENTS: usize = 8;
const QUERIES_PER_CLIENT: usize = 250;
const K: usize = 10;

fn run_mode(
    store: &CorpusStore,
    queries: &[DenseVec],
    mode: ExecMode,
    artifacts: Option<std::path::PathBuf>,
) -> anyhow::Result<()> {
    // An Arc bump, not a corpus copy: every mode serves the same buffer.
    let coord = Coordinator::new(
        store.clone(),
        CoordinatorConfig {
            n_shards: 4,
            index: IndexKind::Vp,
            bound: BoundKind::Mult,
            mode,
            batch: BatchConfig {
                max_batch: 32,
                max_wait: std::time::Duration::from_micros(500),
                queue_depth: 2048,
            },
            artifact_dir: artifacts,
            hybrid_pivots: 32,
            kernel: None,
        },
    )?;
    let server_handle = server::serve(coord.clone(), "127.0.0.1:0")?;
    let addr = server_handle.addr();

    let done = Arc::new(AtomicU64::new(0));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..CLIENTS {
        let queries: Vec<Vec<f32>> = (0..QUERIES_PER_CLIENT)
            .map(|i| queries[(c * QUERIES_PER_CLIENT + i) % queries.len()].as_slice().to_vec())
            .collect();
        let done = done.clone();
        handles.push(std::thread::spawn(move || -> anyhow::Result<Vec<u64>> {
            let mut client = server::Client::connect(addr)?;
            let mut lat_us = Vec::with_capacity(queries.len());
            for v in queries {
                let q0 = Instant::now();
                let resp = client.request(&Request::Knn { vector: v, k: K })?;
                lat_us.push(q0.elapsed().as_micros() as u64);
                match resp {
                    Response::Ok { hits, .. } => assert_eq!(hits.len(), K),
                    other => anyhow::bail!("bad response: {other:?}"),
                }
                done.fetch_add(1, Ordering::Relaxed);
            }
            Ok(lat_us)
        }));
    }
    let mut latencies: Vec<u64> = Vec::new();
    for h in handles {
        latencies.extend(h.join().expect("client thread")?);
    }
    let wall = t0.elapsed();
    latencies.sort_unstable();
    let total = latencies.len();
    let pct = |p: f64| latencies[((total as f64 * p) as usize).min(total - 1)];
    let stats = coord.stats();
    println!(
        "  mode={mode:?}: {total} queries in {wall:.2?} -> {:.0} qps | \
         p50={}us p95={}us p99={}us max={}us",
        total as f64 / wall.as_secs_f64(),
        pct(0.50),
        pct(0.95),
        pct(0.99),
        latencies[total - 1],
    );
    println!(
        "         batches={} (avg {:.1} q/batch) engine_calls={} sim_evals={} ({:.2}% of brute force)",
        stats.batches,
        stats.queries as f64 / stats.batches.max(1) as f64,
        stats.engine_calls,
        stats.sim_evals,
        100.0 * stats.sim_evals as f64 / (stats.queries as f64 * N as f64),
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    println!(
        "E2E serving benchmark: n={N} dim={DIM}, {CLIENTS} closed-loop clients x \
         {QUERIES_PER_CLIENT} queries, k={K}"
    );
    println!("generating corpus ...");
    let (store, _) = vmf_mixture_store(&VmfSpec {
        n: N,
        dim: DIM,
        // kappa=800 at d=128 => within-cluster sims ~0.92: the clustered
        // regime where exact cosine pruning engages (see pruning_study).
        clusters: 128,
        kappa: 800.0,
        seed: 42,
    });
    // Queries: corpus members spread across the id range — the "find items
    // most similar to this item" workload (every query has dense cluster
    // neighborhoods, so index pruning has something to work with).
    let queries: Vec<DenseVec> = (0..CLIENTS * QUERIES_PER_CLIENT)
        .map(|i| store.vec((i * 23) % N))
        .collect();

    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let have_artifacts = artifacts.join("manifest.json").exists();

    println!("\n== scalar index path (VP-tree, Mult bound) ==");
    run_mode(&store, &queries, ExecMode::Index, None)?;

    if have_artifacts {
        println!("\n== batched PJRT engine path (exhaustive artifact scoring) ==");
        if let Err(e) = run_mode(&store, &queries, ExecMode::Engine, Some(artifacts.clone())) {
            println!("  (engine mode unavailable: {e})");
        }
        println!("\n== hybrid path (PJRT pivot_filter + exact re-score) ==");
        if let Err(e) = run_mode(&store, &queries, ExecMode::Hybrid, Some(artifacts)) {
            println!("  (hybrid mode unavailable: {e})");
        }
    } else {
        println!("\n(artifacts/ missing — run `make artifacts` for the engine/hybrid modes)");
    }
    Ok(())
}
