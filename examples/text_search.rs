//! Sparse text search: tf-idf corpus + LAESA pivot filtering — the paper's
//! motivating workload (cosine over sparse text vectors, §2).
//!
//!     cargo run --release --example text_search

use simetra::bounds::BoundKind;
use simetra::data::{zipf_corpus, ZipfSpec};
use simetra::index::{Laesa, LinearScan, QueryStats, SimilarityIndex};

fn main() {
    // Synthetic tf-idf corpus: 20k docs, 50k-term vocabulary, Zipf terms
    // with topic structure.
    let spec = ZipfSpec {
        n_docs: 20_000,
        vocab: 50_000,
        exponent: 1.07,
        doc_len: 150,
        seed: 9,
        topics: 40,
    };
    println!("generating {} tf-idf docs (vocab {}) ...", spec.n_docs, spec.vocab);
    let docs = zipf_corpus(&spec);
    let avg_nnz: f64 =
        docs.iter().map(|d| d.nnz() as f64).sum::<f64>() / docs.len() as f64;
    println!("average non-zeros per doc: {avg_nnz:.1}");

    // LAESA with 48 pivots: the merge-join dot product of §2 is the exact
    // scorer; the paper's bounds prune candidates per pivot.
    let t0 = std::time::Instant::now();
    let index = Laesa::build(docs.clone(), BoundKind::Mult, 48);
    println!("built LAESA ({} pivots) in {:?}", index.n_pivots(), t0.elapsed());

    let linear = LinearScan::build(docs.clone());
    let mut total_idx = QueryStats::default();
    let mut total_lin = QueryStats::default();
    let queries = [5usize, 1234, 7777, 19_999];
    for &qi in &queries {
        let q = &docs[qi];
        let mut stats = QueryStats::default();
        let t0 = std::time::Instant::now();
        let hits = index.knn(q, 10, &mut stats);
        let dt = t0.elapsed();

        let mut lin_stats = QueryStats::default();
        let lin_hits = linear.knn(q, 10, &mut lin_stats);
        for ((_, a), (_, b)) in hits.iter().zip(&lin_hits) {
            assert!((a - b).abs() < 1e-12, "exactness violated");
        }
        println!(
            "\nquery doc {qi}: 10-NN in {dt:?}, {}/{} docs scored ({} pruned)",
            stats.sim_evals,
            docs.len(),
            stats.pruned
        );
        for (rank, (id, sim)) in hits.iter().take(5).enumerate() {
            println!("  #{rank} doc={id:<6} sim={sim:.4}");
        }
        total_idx.merge(&stats);
        total_lin.merge(&lin_stats);
    }
    println!(
        "\ntotal: {} vs {} exact scores ({:.2}x)",
        total_idx.sim_evals,
        total_lin.sim_evals,
        total_lin.sim_evals as f64 / total_idx.sim_evals as f64
    );
    println!(
        "\nNote: sparse text lives in the near-orthogonal regime (neighbor sims\n\
         ~0.1-0.3), where Eq. 13 through any far pivot is vacuous — exact\n\
         cosine indexes cannot prune here (paper section 2's concentration\n\
         discussion; this is why approximate methods dominate text retrieval).\n\
         What the sparse substrate buys is the merge-join scorer itself:\n\
         each exact evaluation touches ~{:.0} nonzeros instead of {} dims.",
        2.0 * docs.iter().map(|d| d.nnz() as f64).sum::<f64>() / docs.len() as f64,
        spec.vocab
    );

    // Where the bounds DO pay off for text: near-duplicate detection.
    // Append perturbed copies of some docs and range-query at high tau.
    println!("\n== near-duplicate detection (range tau=0.85) ==");
    let mut with_dups = docs.clone();
    for src in (0..200).map(|i| i * 97) {
        // A duplicate: same doc with a few entries dropped (truncation).
        let orig: Vec<(u32, f32)> = docs[src].iter().collect();
        let cut = orig.len() - orig.len() / 10;
        with_dups.push(simetra::sparse::SparseVec::new(
            orig.into_iter().take(cut).collect(),
            docs[src].dim(),
        ));
    }
    let dup_index = Laesa::build(with_dups.clone(), BoundKind::Mult, 48);
    let mut stats = QueryStats::default();
    let mut found = 0;
    for src in (0..200).map(|i| i * 97) {
        let hits = dup_index.range(&with_dups[src], 0.85, &mut stats);
        found += hits.iter().filter(|&&(id, _)| id as usize != src).count();
    }
    println!(
        "found {found}/200 near-duplicates with {} exact scores\n\
         ({:.1}% of brute force)",
        stats.sim_evals,
        100.0 * stats.sim_evals as f64 / (200.0 * with_dups.len() as f64)
    );
    println!(
        "\nEven here pruning is marginal: a pivot can only certify ub < tau for\n\
         a candidate if one leg through it is strongly similar, and spread-out\n\
         pivots on near-orthogonal data never are. Exact results + the sparse\n\
         scorer are the value on text; the pruning wins live in the clustered\n\
         embedding regime (see examples/pruning_study.rs)."
    );
}
