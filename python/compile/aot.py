"""AOT lowering: JAX graphs -> HLO *text* artifacts for the rust runtime.

HLO text (NOT `lowered.compile().serialize()` / proto bytes) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which the xla crate's bundled xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser re-assigns ids and round-trips
cleanly. See /opt/xla-example/load_hlo/.

Each entry point is lowered at a fixed set of padded shapes (the "variants"
the coordinator's batcher fills); `artifacts/manifest.json` records every
variant's entry name, file, input/output shapes and dtypes so the rust side
never hard-codes a shape.

Usage: cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# (Q, N, D, K) variants of the score_topk artifact. N is the corpus-shard
# tile the scheduler re-ranks at once; Q the padded query batch.
SCORE_VARIANTS = [
    (8, 1024, 128, 16),
    (8, 8192, 128, 16),
    (16, 8192, 128, 16),
    (32, 4096, 128, 16),
    (32, 8192, 128, 32),
    (64, 8192, 128, 32),
]
# (Q, P, N) variants of the pivot_filter artifact.
PIVOT_VARIANTS = [
    (8, 16, 1024),
    (32, 32, 4096),
]
# (Q, N, D) variants of the full score_matrix artifact (figures + re-rank).
MATRIX_VARIANTS = [
    (8, 1024, 128),
]


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_score_topk(q, n, d, k):
    fn = functools.partial(model.score_topk, k=k)
    lowered = jax.jit(fn).lower(
        _spec((q, d)), _spec((n, d)), _spec((), jnp.int32))
    return lowered, {
        "entry": "score_topk",
        "inputs": [
            {"name": "queries", "shape": [q, d], "dtype": "f32"},
            {"name": "corpus", "shape": [n, d], "dtype": "f32"},
            {"name": "valid_n", "shape": [], "dtype": "i32"},
        ],
        "outputs": [
            {"name": "values", "shape": [q, k], "dtype": "f32"},
            {"name": "indices", "shape": [q, k], "dtype": "i32"},
        ],
        "params": {"q": q, "n": n, "d": d, "k": k},
    }


def lower_score_matrix(q, n, d):
    lowered = jax.jit(lambda a, b: (model.score_matrix(a, b),)).lower(
        _spec((q, d)), _spec((n, d)))
    return lowered, {
        "entry": "score_matrix",
        "inputs": [
            {"name": "queries", "shape": [q, d], "dtype": "f32"},
            {"name": "corpus", "shape": [n, d], "dtype": "f32"},
        ],
        "outputs": [{"name": "scores", "shape": [q, n], "dtype": "f32"}],
        "params": {"q": q, "n": n, "d": d},
    }


def lower_pivot_filter(q, p, n):
    lowered = jax.jit(model.pivot_filter).lower(
        _spec((q, p)), _spec((p, n)))
    return lowered, {
        "entry": "pivot_filter",
        "inputs": [
            {"name": "sim_qp", "shape": [q, p], "dtype": "f32"},
            {"name": "sim_pc", "shape": [p, n], "dtype": "f32"},
        ],
        "outputs": [
            {"name": "lb", "shape": [q, n], "dtype": "f32"},
            {"name": "ub", "shape": [q, n], "dtype": "f32"},
        ],
        "params": {"q": q, "p": p, "n": n},
    }


def build_all(out_dir):
    os.makedirs(out_dir, exist_ok=True)
    entries = []

    jobs = []
    for q, n, d, k in SCORE_VARIANTS:
        jobs.append((f"score_topk_q{q}_n{n}_d{d}_k{k}",
                     lower_score_topk(q, n, d, k)))
    for q, p, n in PIVOT_VARIANTS:
        jobs.append((f"pivot_filter_q{q}_p{p}_n{n}",
                     lower_pivot_filter(q, p, n)))
    for q, n, d in MATRIX_VARIANTS:
        jobs.append((f"score_matrix_q{q}_n{n}_d{d}",
                     lower_score_matrix(q, n, d)))

    for name, (lowered, meta) in jobs:
        path = f"{name}.hlo.txt"
        text = to_hlo_text(lowered)
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(text)
        meta["file"] = path
        meta["name"] = name
        entries.append(meta)
        print(f"  {path}: {len(text)} chars")

    manifest = {"version": 1, "pad_score": model.PAD_SCORE,
                "artifacts": entries}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(entries)} artifacts + manifest to {out_dir}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    build_all(args.out)


if __name__ == "__main__":
    main()
