"""L1 Pallas kernel: vectorized evaluation of the paper's Mult bounds.

Given arrays of known similarities s1 = sim(x, z) and s2 = sim(z, y), emit
the certified interval on sim(x, y) from the paper's recommended pair:

    lower = s1*s2 - sqrt((1 - s1^2)(1 - s2^2))     (Eq. 10)
    upper = s1*s2 + sqrt((1 - s1^2)(1 - s2^2))     (Eq. 13)

This is the pruning hot-spot of LAESA-style pivot filtering: for Q queries,
P pivots and N corpus points, (Q*P*N) bound evaluations decide which
candidates need an exact similarity. The kernel is purely element-wise (VPU
work, no MXU), so the tiling goal is simply streaming 8-aligned VMEM blocks.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Elements per grid step; multiple of the 8x128 VPU tile. Large so the
# interpret-mode artifact executes few while-loop iterations (4 arrays x
# 128K x 4B = 2 MiB VMEM per step on a real TPU — comfortably resident).
BLOCK = 131072


def _bounds_kernel(s1_ref, s2_ref, lb_ref, ub_ref):
    s1 = s1_ref[...]
    s2 = s2_ref[...]
    prod = s1 * s2
    # max(., 0) guards |s| slightly above 1 from accumulated roundoff; the
    # paper notes (section 4.2) the radical is itself cancellation-safe
    # because it vanishes exactly where 1 - s^2 cancels.
    rad = jnp.sqrt(jnp.maximum((1.0 - s1 * s1) * (1.0 - s2 * s2), 0.0))
    lb_ref[...] = prod - rad
    ub_ref[...] = prod + rad


@functools.partial(jax.jit, static_argnames=("block",))
def mult_bounds_kernel(s1, s2, block=BLOCK):
    """(lower, upper) bound arrays for flat f32 similarity arrays.

    s1, s2: 1-D arrays of equal length, a multiple of `block` (the L2 graph
    pads; padding values are ignored by the caller's mask).
    """
    (n,) = s1.shape
    assert s2.shape == (n,), (s1.shape, s2.shape)
    assert n % block == 0, (n, block)
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    out = jax.ShapeDtypeStruct((n,), s1.dtype)
    return pl.pallas_call(
        _bounds_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[out, out],
        interpret=True,
    )(s1, s2)
