"""L1 Pallas kernel: tiled cosine-similarity matrix with fused normalization.

The serving hot-spot is scoring a batch of queries against a corpus shard:
an (M, D) x (N, D) -> (M, N) contraction followed by a rank-1 scaling by the
inverse norms. On a real TPU this is an MXU problem; we tile the output in
(BM, BN) blocks held in VMEM, iterate the contraction dimension in BK steps
(k is the innermost grid axis so each output block is revisited
sequentially), and fuse the normalization into the final k step so the raw
corpus never needs a separate normalization pass over HBM.

Lowered with interpret=True: the CPU PJRT client cannot execute Mosaic
custom-calls; real-TPU performance is estimated analytically (DESIGN.md
section "Perf").
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. On a real TPU a (128, 128) output tile per MXU pass is
# canonical; here the same kernel must also execute tolerably under
# interpret=True on CPU, where every grid step becomes one iteration of an
# XLA while-loop — so we use large tiles (few steps) that still fit VMEM:
# q-tile 128x512 (256 KiB) + c-tile 2048x512 (4 MiB) + out 128x2048 (1 MiB)
# = ~5.3 MiB live, ~11 MiB double-buffered, inside a TensorCore's ~16 MiB.
# See DESIGN.md "Perf" for the grid-step-count analysis.
BM, BN, BK = 128, 2048, 512


def _cosine_kernel(q_ref, c_ref, qinv_ref, cinv_ref, o_ref, *, nk):
    """One (BM, BN) output tile; accumulates over the k grid axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (BM, BK) x (BN, BK) -> (BM, BN), contracting the trailing dims. The
    # corpus block is kept row-major (N, D) so both operand tiles stream
    # from HBM with unit stride.
    o_ref[...] += jax.lax.dot_general(
        q_ref[...], c_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        # Fused normalization: scores = (q . c) / (|q| |c|).
        o_ref[...] *= qinv_ref[...][:, None] * cinv_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def cosine_scores_kernel(queries, corpus, q_inv_norms, c_inv_norms,
                         bm=BM, bn=BN, bk=BK):
    """Cosine-similarity matrix via the Pallas kernel.

    queries: (M, D) raw (un-normalized) vectors; corpus: (N, D);
    q_inv_norms: (M,) 1/|q| (0 for zero rows); c_inv_norms: (N,).
    M, N, D must be multiples of the block sizes (the L2 graph in
    model.py pads and masks); returns (M, N) f32 scores.
    """
    m, d = queries.shape
    n, d2 = corpus.shape
    assert d == d2, (d, d2)
    assert m % bm == 0 and n % bn == 0 and d % bk == 0, (m, n, d, bm, bn, bk)
    nk = d // bk
    grid = (m // bm, n // bn, nk)
    return pl.pallas_call(
        functools.partial(_cosine_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bm,), lambda i, j, k: (i,)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(queries, corpus, q_inv_norms, c_inv_norms)
