"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here; the pytest
suite asserts `assert_allclose(kernel(...), ref(...))` over a hypothesis
sweep of shapes. These functions are also the L2 building blocks the AOT
graphs are validated against.

Bound equations follow the paper's Table 1 numbering (Schubert, SISAP 2021):
  Eq. 7  Euclidean      Eq. 8  Eucl-LB      Eq. 9  Arccos
  Eq.10  Mult           Eq.11  Mult-LB1     Eq.12  Mult-LB2
  Eq.13  Mult upper bound
"""

import jax.numpy as jnp


def normalize(x, eps=0.0):
    """L2-normalize rows; zero rows stay zero (guarded reciprocal)."""
    norms = jnp.linalg.norm(x, axis=-1, keepdims=True)
    inv = jnp.where(norms > eps, 1.0 / jnp.maximum(norms, 1e-30), 0.0)
    return x * inv


def cosine_scores(queries, corpus):
    """Full cosine-similarity matrix: (Q, D) x (N, D) -> (Q, N)."""
    q = normalize(queries)
    c = normalize(corpus)
    return q @ c.T


# --- Triangle-inequality bounds (element-wise over arrays of s1, s2) ------

def lb_euclidean(s1, s2):
    """Eq. 7: bound via the Euclidean triangle inequality on the sphere."""
    r1 = jnp.sqrt(jnp.maximum(1.0 - s1, 0.0))
    r2 = jnp.sqrt(jnp.maximum(1.0 - s2, 0.0))
    return s1 + s2 - 1.0 - 2.0 * r1 * r2


def lb_eucl_lb(s1, s2):
    """Eq. 8: cheap approximation of Eq. 7 using min(s1, s2)."""
    return s1 + s2 + 2.0 * jnp.minimum(s1, s2) - 3.0


def lb_arccos(s1, s2):
    """Eq. 9: the tight bound via arc lengths (expensive trig form)."""
    a1 = jnp.arccos(jnp.clip(s1, -1.0, 1.0))
    a2 = jnp.arccos(jnp.clip(s2, -1.0, 1.0))
    # cos is even and 2pi-periodic; the sum of two arccos is in [0, 2pi],
    # matching the paper's Eq. 9 exactly.
    return jnp.cos(a1 + a2)


def _mult_radical(s1, s2):
    return jnp.sqrt(jnp.maximum((1.0 - s1 * s1) * (1.0 - s2 * s2), 0.0))


def lb_mult(s1, s2):
    """Eq. 10: the recommended lower bound (= Eq. 9, trig-free)."""
    return s1 * s2 - _mult_radical(s1, s2)


def ub_mult(s1, s2):
    """Eq. 13: the recommended upper bound (opposite direction)."""
    return s1 * s2 + _mult_radical(s1, s2)


def lb_mult_lb1(s1, s2):
    """Eq. 11: cheap approximation of Eq. 10 using the smaller similarity."""
    return s1 * s2 + jnp.minimum(s1 * s1, s2 * s2) - 1.0


def lb_mult_lb2(s1, s2):
    """Eq. 12: min/max expansion of Eq. 10 (strictly inferior to Eq. 11)."""
    return 2.0 * s1 * s2 - jnp.abs(s1 - s2) - 1.0


def bounds_mult(s1, s2):
    """(lower, upper) pair of the recommended Eqs. 10/13."""
    prod = s1 * s2
    rad = _mult_radical(s1, s2)
    return prod - rad, prod + rad


# --- LAESA-style pivot pruning --------------------------------------------

def pivot_bounds(sim_qp, sim_pc):
    """Combine per-pivot bounds on sim(q, c).

    sim_qp: (Q, P) similarities query->pivot; sim_pc: (P, N) pivot->corpus.
    Returns (lb, ub) of shape (Q, N): lb = max over pivots of Eq. 10,
    ub = min over pivots of Eq. 13 (each pivot gives a valid bound; the
    intersection is the tightest certified interval).
    """
    s1 = sim_qp[:, :, None]  # (Q, P, 1)
    s2 = sim_pc[None, :, :]  # (1, P, N)
    lb, ub = bounds_mult(s1, s2)
    return jnp.max(lb, axis=1), jnp.min(ub, axis=1)


def topk(scores, k):
    """Reference top-k by full sort: returns (values, indices)."""
    idx = jnp.argsort(-scores, axis=-1)[..., :k]
    vals = jnp.take_along_axis(scores, idx, axis=-1)
    return vals, idx
