"""L2: JAX compute graphs for the simetra serving engine.

These are the functions `aot.py` lowers to HLO text; the rust coordinator
executes the compiled artifacts on its PJRT CPU client. Everything here is
shape-static: the coordinator picks an artifact variant (padded batch shape)
from the manifest and pads/masks on the rust side only when a request batch
underfills it — the padding *semantics* (zero vectors score PAD_SCORE, below
any real cosine) are fixed here so both sides agree.

Graphs:
  score_topk   : raw queries + raw corpus -> (top-k values, top-k indices)
  score_matrix : raw queries + raw corpus -> full (Q, N) similarity matrix
  pivot_filter : pivot similarity tables -> certified (lb, ub) per (q, c)
"""

import jax
import jax.numpy as jnp

from compile.kernels import bounds as bounds_kernel
from compile.kernels import cosine as cosine_kernel

# Scores of padding columns: strictly below the cosine range [-1, 1] so a
# padded slot can never enter a top-k result.
PAD_SCORE = -2.0


def _inv_norms(x):
    """Row-wise 1/|x| with zero rows mapping to 0 (=> zero scores)."""
    sq = jnp.sum(x * x, axis=-1)
    return jnp.where(sq > 0.0, jax.lax.rsqrt(jnp.maximum(sq, 1e-30)), 0.0)


def _pad_to(x, m, axis):
    pad = m - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pad_up(n, b):
    return (n + b - 1) // b * b


def score_matrix(queries, corpus, valid_n=None):
    """Full (Q, N) cosine matrix via the Pallas kernel, handling padding.

    valid_n: number of real corpus rows; columns >= valid_n get PAD_SCORE.
    """
    m, d = queries.shape
    n, _ = corpus.shape
    bm = min(cosine_kernel.BM, _pad_up(m, 8))
    bn = min(cosine_kernel.BN, _pad_up(n, 128))
    # bk must divide the padded d; prefer the largest MXU-friendly tile.
    dp128 = _pad_up(d, 128)
    bk = next(c for c in (512, 384, 256, 128) if c <= cosine_kernel.BK
              and (dp128 % c == 0 or c >= dp128))
    bk = min(bk, dp128)
    mp, np_, dp = _pad_up(m, bm), _pad_up(n, bn), _pad_up(dp128, bk)
    q = _pad_to(_pad_to(queries, mp, 0), dp, 1)
    c = _pad_to(_pad_to(corpus, np_, 0), dp, 1)
    scores = cosine_kernel.cosine_scores_kernel(
        q, c, _inv_norms(q), _inv_norms(c), bm=bm, bn=bn, bk=bk)
    scores = scores[:m, :n]
    if valid_n is not None:
        col = jax.lax.broadcasted_iota(jnp.int32, (m, n), 1)
        scores = jnp.where(col < valid_n, scores, PAD_SCORE)
    return scores


def score_topk(queries, corpus, valid_n, k):
    """Top-k corpus entries per query: ((Q, k) values, (Q, k) i32 indices).

    Implemented with a full descending sort rather than `jax.lax.top_k`:
    top_k lowers to the HLO `topk(..., largest=true)` instruction, which the
    runtime's XLA (xla_extension 0.5.1 text parser) predates. `sort` with a
    custom comparator round-trips fine and XLA fuses the slice.
    """
    scores = score_matrix(queries, corpus, valid_n=valid_n)
    idx = jnp.argsort(-scores, axis=-1)[:, :k].astype(jnp.int32)
    vals = jnp.take_along_axis(scores, idx, axis=-1)
    return vals, idx


def pivot_filter(sim_qp, sim_pc):
    """LAESA pivot filtering: certified similarity intervals per (q, c).

    sim_qp: (Q, P) exact sims query->pivot; sim_pc: (P, N) precomputed
    pivot->corpus table. Per pivot, Eqs. 10/13 certify an interval on
    sim(q, c); intersecting over pivots gives (max lb, min ub) — the rust
    scheduler prunes candidates whose ub < tau (range) or < heap floor (kNN).
    """
    q, p = sim_qp.shape
    p2, n = sim_pc.shape
    assert p == p2, (p, p2)
    s1 = jnp.broadcast_to(sim_qp[:, :, None], (q, p, n)).reshape(-1)
    s2 = jnp.broadcast_to(sim_pc[None, :, :], (q, p, n)).reshape(-1)
    total = q * p * n
    # Interpret-mode grid steps carry the full output through an XLA
    # while-loop (one dynamic-update-slice copy per step), so the CPU
    # artifact wants exactly one step whenever the array fits comfortably
    # in host memory. A real-TPU build would instead fix
    # block = bounds_kernel.BLOCK (VMEM-sized) and let the grid stream.
    if total <= (1 << 23):
        block = _pad_up(total, 128)
    else:
        block = 1 << 23
    padded = _pad_up(total, block)
    s1 = _pad_to(s1, padded, 0)
    s2 = _pad_to(s2, padded, 0)
    lb, ub = bounds_kernel.mult_bounds_kernel(s1, s2, block=block)
    lb = lb[:total].reshape(q, p, n)
    ub = ub[:total].reshape(q, p, n)
    return jnp.max(lb, axis=1), jnp.min(ub, axis=1)
