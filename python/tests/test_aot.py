"""AOT smoke tests: artifacts lower to parseable HLO text + sane manifest."""

import json

import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_lower_score_topk_text(tmp_path):
    lowered, meta = aot.lower_score_topk(8, 256, 128, 4)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "HloModule" in text
    assert meta["outputs"][0]["shape"] == [8, 4]
    # 64-bit-id regression guard: text form must not carry explicit ids that
    # overflow the 0.5.1 parser (ids are reassigned by the parser; presence
    # of ENTRY suffices, this is a shape check).
    assert meta["params"] == {"q": 8, "n": 256, "d": 128, "k": 4}


def test_lower_pivot_filter_text():
    lowered, meta = aot.lower_pivot_filter(4, 8, 512)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert meta["outputs"][0]["shape"] == [4, 512]


def test_build_all_manifest(tmp_path):
    # Shrink the variant lists for the smoke build.
    old = aot.SCORE_VARIANTS, aot.PIVOT_VARIANTS, aot.MATRIX_VARIANTS
    try:
        aot.SCORE_VARIANTS = [(8, 256, 128, 4)]
        aot.PIVOT_VARIANTS = [(4, 8, 512)]
        aot.MATRIX_VARIANTS = [(8, 256, 128)]
        aot.build_all(str(tmp_path))
    finally:
        aot.SCORE_VARIANTS, aot.PIVOT_VARIANTS, aot.MATRIX_VARIANTS = old
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["pad_score"] == model.PAD_SCORE
    assert len(manifest["artifacts"]) == 3
    for entry in manifest["artifacts"]:
        text = (tmp_path / entry["file"]).read_text()
        assert "ENTRY" in text


def test_jit_executes_like_model():
    """The exact jitted callables we lower produce oracle-correct numbers."""
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((8, 128)), dtype=jnp.float32)
    c = jnp.asarray(rng.standard_normal((256, 128)), dtype=jnp.float32)
    vals, idx = model.score_topk(q, c, jnp.int32(256), 4)
    scores = np.asarray(model.score_matrix(q, c))
    best = np.sort(scores, axis=1)[:, ::-1][:, :4]
    np.testing.assert_allclose(vals, best, atol=1e-5)
