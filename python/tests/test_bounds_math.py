"""Mathematical properties of the paper's bounds (hypothesis, f64).

These are the paper's core claims, checked as executable properties:
  * validity  — every lower bound <= sim(x,y) <= upper bound, for real
    unit-vector triples (not just grid values);
  * tightness — the Mult bound (Eq. 10) equals the Arccos bound (Eq. 9)
    to f64 roundoff (paper section 4.2 / Fig. 5);
  * partial order (Fig. 3):
      Eucl-LB <= Euclidean <= Arccos = Mult
      Eucl-LB <= Mult-LB2 <= Mult-LB1 <= Mult
"""

import jax
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

jax.config.update("jax_enable_x64", True)

ALL_LOWER = [
    ref.lb_euclidean, ref.lb_eucl_lb, ref.lb_arccos,
    ref.lb_mult, ref.lb_mult_lb1, ref.lb_mult_lb2,
]


def _unit(v):
    return v / np.linalg.norm(v)


def _triple(seed, dim):
    rng = np.random.default_rng(seed)
    x, y, z = (_unit(rng.standard_normal(dim)) for _ in range(3))
    return x, y, z


@settings(max_examples=200, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), dim=st.integers(2, 64))
def test_all_lower_bounds_valid_on_unit_vectors(seed, dim):
    x, y, z = _triple(seed, dim)
    sxy, sxz, szy = x @ y, x @ z, z @ y
    for lb in ALL_LOWER:
        b = float(lb(np.float64(sxz), np.float64(szy)))
        assert b <= sxy + 1e-9, (lb.__name__, b, sxy)


@settings(max_examples=200, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), dim=st.integers(2, 64))
def test_upper_bound_valid_on_unit_vectors(seed, dim):
    x, y, z = _triple(seed, dim)
    sxy, sxz, szy = x @ y, x @ z, z @ y
    ub = float(ref.ub_mult(np.float64(sxz), np.float64(szy)))
    assert ub >= sxy - 1e-9


@settings(max_examples=300, deadline=None)
@given(s1=st.floats(-1, 1), s2=st.floats(-1, 1))
def test_partial_order_fig3(s1, s2):
    s1, s2 = np.float64(s1), np.float64(s2)
    eucl = float(ref.lb_euclidean(s1, s2))
    eucl_lb = float(ref.lb_eucl_lb(s1, s2))
    arcc = float(ref.lb_arccos(s1, s2))
    mult = float(ref.lb_mult(s1, s2))
    lb1 = float(ref.lb_mult_lb1(s1, s2))
    lb2 = float(ref.lb_mult_lb2(s1, s2))
    eps = 1e-12
    assert eucl_lb <= eucl + eps
    assert eucl <= arcc + eps
    assert eucl_lb <= lb2 + eps
    assert lb2 <= lb1 + eps
    assert lb1 <= mult + eps


@settings(max_examples=300, deadline=None)
@given(s1=st.floats(-1, 1), s2=st.floats(-1, 1))
def test_mult_equals_arccos_fig5(s1, s2):
    """Fig. 5: |Mult - Arccos| at the limit of f64 precision (~1e-16)."""
    mult = float(ref.lb_mult(np.float64(s1), np.float64(s2)))
    arcc = float(ref.lb_arccos(np.float64(s1), np.float64(s2)))
    assert abs(mult - arcc) < 5e-15


def test_paper_anchor_values():
    """Spot values the paper calls out explicitly."""
    # Inputs 0.5/0.5 (60 deg + 60 deg): the gap between the bounds peaks at
    # 0.5 (Fig. 1c): Euclidean gives -1, Arccos/Mult gives cos(120 deg) = -0.5.
    np.testing.assert_allclose(float(ref.lb_mult(0.5, 0.5)), -0.5, atol=1e-12)
    np.testing.assert_allclose(
        float(ref.lb_euclidean(0.5, 0.5)), -1.0, atol=1e-12)
    # Worst case of the Euclidean bound: opposite-opposite gives -7 while
    # the true similarity is +1 (Fig. 1 discussion).
    np.testing.assert_allclose(
        float(ref.lb_euclidean(-1.0, -1.0)), -7.0, atol=1e-12)
    np.testing.assert_allclose(float(ref.lb_mult(-1.0, -1.0)), 1.0, atol=1e-12)
    # Chained identical points: knowing sim=1 to z pins sim(x,y) exactly.
    np.testing.assert_allclose(float(ref.lb_mult(1.0, 0.3)), 0.3, atol=1e-12)
    np.testing.assert_allclose(float(ref.ub_mult(1.0, 0.3)), 0.3, atol=1e-12)


def test_grid_average_statistic_section41():
    """Paper section 4.1: avg Euclid ~ 0.2447, avg Arccos ~ 0.3121 (+27.5%).

    Reverse-engineered protocol that reproduces the printed values: uniform
    grid over the non-negative domain [0, 1]^2, averaging each bound over
    the cells where the (tight) Arccos bound is non-negative. At a 401-point
    grid this gives 0.2454 / 0.3126, ratio +27.4% — matching the paper to
    grid resolution.
    """
    g = np.linspace(0.0, 1.0, 401)
    s1, s2 = np.meshgrid(g, g)
    eucl = np.asarray(ref.lb_euclidean(s1, s2))
    mult = np.asarray(ref.lb_mult(s1, s2))
    mask = mult >= 0
    avg_e, avg_m = eucl[mask].mean(), mult[mask].mean()
    assert abs(avg_e - 0.2447) < 2e-3, avg_e
    assert abs(avg_m - 0.3121) < 2e-3, avg_m
    ratio = (avg_m - avg_e) / avg_e
    assert abs(ratio - 0.275) < 0.01, ratio
