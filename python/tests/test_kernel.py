"""Pallas kernels vs pure-jnp oracle — the CORE correctness signal.

hypothesis sweeps shapes (and the bound kernels' value domain); every case
asserts allclose against `kernels.ref`.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bounds as bounds_kernel
from compile.kernels import cosine as cosine_kernel
from compile.kernels import ref


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


# --- cosine kernel ---------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    mb=st.integers(1, 3), nb=st.integers(1, 3), kb=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_cosine_kernel_matches_ref(mb, nb, kb, seed):
    """Block-multiple shapes: kernel == normalized matmul reference."""
    bm, bn, bk = 8, 128, 128
    m, n, d = mb * bm, nb * bn, kb * bk
    rng = np.random.default_rng(seed)
    q, c = _rand(rng, m, d), _rand(rng, n, d)
    qi = 1.0 / jnp.linalg.norm(q, axis=1)
    ci = 1.0 / jnp.linalg.norm(c, axis=1)
    got = cosine_kernel.cosine_scores_kernel(q, c, qi, ci, bm=bm, bn=bn, bk=bk)
    want = ref.cosine_scores(q, c)
    np.testing.assert_allclose(got, want, atol=2e-5)


def test_cosine_kernel_multi_k_accumulation():
    """d > bk exercises the k-axis accumulate-then-epilogue path."""
    rng = np.random.default_rng(0)
    q, c = _rand(rng, 8, 512), _rand(rng, 128, 512)
    qi = 1.0 / jnp.linalg.norm(q, axis=1)
    ci = 1.0 / jnp.linalg.norm(c, axis=1)
    got = cosine_kernel.cosine_scores_kernel(q, c, qi, ci, bm=8, bn=128, bk=128)
    np.testing.assert_allclose(got, ref.cosine_scores(q, c), atol=2e-5)


def test_cosine_kernel_zero_row_guard():
    """Zero inv-norm rows must produce zero scores, not NaN."""
    rng = np.random.default_rng(1)
    q = np.asarray(rng.standard_normal((8, 128)), dtype=np.float32)
    q[3] = 0.0
    c = _rand(rng, 128, 128)
    qn = np.linalg.norm(q, axis=1)
    qi = jnp.asarray(np.where(qn > 0, 1.0 / np.where(qn > 0, qn, 1), 0.0),
                     dtype=jnp.float32)
    ci = 1.0 / jnp.linalg.norm(c, axis=1)
    got = cosine_kernel.cosine_scores_kernel(
        jnp.asarray(q), c, qi, ci, bm=8, bn=128, bk=128)
    assert not np.any(np.isnan(got))
    np.testing.assert_allclose(got[3], np.zeros(128), atol=1e-7)


def test_cosine_kernel_rejects_unaligned():
    rng = np.random.default_rng(2)
    q, c = _rand(rng, 7, 128), _rand(rng, 128, 128)
    with pytest.raises(AssertionError):
        cosine_kernel.cosine_scores_kernel(
            q, c, jnp.ones(7), jnp.ones(128), bm=8, bn=128, bk=128)


def test_cosine_kernel_self_similarity_is_one():
    rng = np.random.default_rng(3)
    x = _rand(rng, 128, 128)
    xi = 1.0 / jnp.linalg.norm(x, axis=1)
    got = cosine_kernel.cosine_scores_kernel(x, x, xi, xi, bm=128, bn=128, bk=128)
    np.testing.assert_allclose(np.diag(got), np.ones(128), atol=2e-6)


# --- bounds kernel ---------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    blocks=st.integers(1, 4), seed=st.integers(0, 2**31 - 1),
    block=st.sampled_from([8, 128, 1024]),
)
def test_bounds_kernel_matches_ref(blocks, seed, block):
    rng = np.random.default_rng(seed)
    n = blocks * block
    s1 = jnp.asarray(rng.uniform(-1, 1, n), dtype=jnp.float32)
    s2 = jnp.asarray(rng.uniform(-1, 1, n), dtype=jnp.float32)
    lb, ub = bounds_kernel.mult_bounds_kernel(s1, s2, block=block)
    wlb, wub = ref.bounds_mult(s1, s2)
    np.testing.assert_allclose(lb, wlb, atol=1e-6)
    np.testing.assert_allclose(ub, wub, atol=1e-6)


def test_bounds_kernel_edge_values():
    """|s| = 1 exactly: radical must be exactly 0, no NaN from roundoff."""
    s1 = jnp.asarray([1.0, -1.0, 1.0, -1.0, 0.0, 1.0, 0.5, 0.5], jnp.float32)
    s2 = jnp.asarray([1.0, -1.0, -1.0, 1.0, 0.0, 0.0, 0.5, -0.5], jnp.float32)
    lb, ub = bounds_kernel.mult_bounds_kernel(s1, s2, block=8)
    assert not np.any(np.isnan(lb)) and not np.any(np.isnan(ub))
    # sim(x,z)=sim(z,y)=1 => x == y on the sphere => sim(x,y) == 1 exactly.
    np.testing.assert_allclose(lb[0], 1.0, atol=1e-7)
    np.testing.assert_allclose(ub[0], 1.0, atol=1e-7)
    # opposite-opposite => identical: lb = ub = 1.
    np.testing.assert_allclose(lb[1], 1.0, atol=1e-7)
    # one similarity 0 => interval [-sqrt(1-s^2).., ..] symmetric around 0*s.
    np.testing.assert_allclose(lb[5], 0.0, atol=1e-7)
    np.testing.assert_allclose(ub[5], 0.0, atol=1e-7)


def test_bounds_kernel_rejects_mismatched_block():
    s = jnp.zeros(12, jnp.float32)
    with pytest.raises(AssertionError):
        bounds_kernel.mult_bounds_kernel(s, s, block=8)
