"""L2 graph tests: score_matrix / score_topk / pivot_filter vs references."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 40), n=st.integers(1, 300), d=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_score_matrix_arbitrary_shapes(m, n, d, seed):
    """Padding/masking must make any (m, n, d) agree with the oracle."""
    rng = np.random.default_rng(seed)
    q, c = _rand(rng, m, d), _rand(rng, n, d)
    got = model.score_matrix(q, c)
    np.testing.assert_allclose(got, ref.cosine_scores(q, c), atol=3e-5)


def test_score_matrix_valid_n_masks_tail():
    rng = np.random.default_rng(7)
    q, c = _rand(rng, 4, 64), _rand(rng, 100, 64)
    got = model.score_matrix(q, c, valid_n=60)
    want = np.asarray(ref.cosine_scores(q, c))
    np.testing.assert_allclose(got[:, :60], want[:, :60], atol=3e-5)
    assert np.all(np.asarray(got[:, 60:]) == model.PAD_SCORE)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.integers(1, 16),
       valid=st.integers(17, 100))
def test_score_topk_matches_sort(seed, k, valid):
    rng = np.random.default_rng(seed)
    q, c = _rand(rng, 5, 32), _rand(rng, 100, 32)
    vals, idx = model.score_topk(q, c, jnp.int32(valid), k)
    scores = np.asarray(model.score_matrix(q, c, valid_n=valid))
    wvals, _ = ref.topk(scores, k)
    # Values must match the sorted reference exactly (indices may differ
    # under ties, so compare values and verify each index scores its value).
    np.testing.assert_allclose(vals, wvals, atol=1e-6)
    for r in range(5):
        np.testing.assert_allclose(
            scores[r, np.asarray(idx[r])], np.asarray(vals[r]), atol=1e-6)
        assert np.all(np.asarray(idx[r]) < valid)


@settings(max_examples=10, deadline=None)
@given(q=st.integers(1, 8), p=st.integers(1, 12), n=st.integers(1, 200),
       seed=st.integers(0, 2**31 - 1))
def test_pivot_filter_matches_ref(q, p, n, seed):
    rng = np.random.default_rng(seed)
    sim_qp = jnp.asarray(rng.uniform(-1, 1, (q, p)), dtype=jnp.float32)
    sim_pc = jnp.asarray(rng.uniform(-1, 1, (p, n)), dtype=jnp.float32)
    lb, ub = model.pivot_filter(sim_qp, sim_pc)
    wlb, wub = ref.pivot_bounds(sim_qp, sim_pc)
    np.testing.assert_allclose(lb, wlb, atol=1e-6)
    np.testing.assert_allclose(ub, wub, atol=1e-6)


def test_pivot_filter_intervals_contain_truth():
    """End-to-end: intervals from real pivot sims contain the true sims."""
    rng = np.random.default_rng(11)
    d, p, n, qn = 16, 8, 50, 4
    corpus = rng.standard_normal((n, d))
    corpus /= np.linalg.norm(corpus, axis=1, keepdims=True)
    pivots = rng.standard_normal((p, d))
    pivots /= np.linalg.norm(pivots, axis=1, keepdims=True)
    queries = rng.standard_normal((qn, d))
    queries /= np.linalg.norm(queries, axis=1, keepdims=True)
    sim_qp = jnp.asarray(queries @ pivots.T, dtype=jnp.float32)
    sim_pc = jnp.asarray(pivots @ corpus.T, dtype=jnp.float32)
    lb, ub = model.pivot_filter(sim_qp, sim_pc)
    truth = queries @ corpus.T
    assert np.all(np.asarray(lb) <= truth + 1e-5)
    assert np.all(np.asarray(ub) >= truth - 1e-5)
