//! Batch-scoring bench, two layers:
//!
//! 1. Native (always runs): the `CorpusStore` blocked kernels
//!    (`scan_topk` / `scan_range`) vs the per-item `DenseVec::dot` loop on
//!    the same corpus — the cache-layout + query-reuse win the storage
//!    refactor exists for, measured on a serving-sized 100k x 128 corpus.
//! 2. PJRT (skipped with a note when artifacts/ or the `pjrt` feature is
//!    missing): batched artifact scoring vs the native scalar loop, plus
//!    the pivot_filter artifact.
//!
//!     cargo bench --bench batch_scoring
//!     # PJRT sections additionally need the `xla` dependency added to
//!     # rust/Cargo.toml (see its [features] comment) + artifacts:
//!     make artifacts && cargo bench --bench batch_scoring --features pjrt

use simetra::data::{uniform_sphere, uniform_sphere_store};
use simetra::index::KnnHeap;
use simetra::metrics::{DenseVec, SimVector};
use simetra::runtime::Engine;
use simetra::storage::CorpusStore;
use simetra::util::bench::{bench, black_box, report, BenchConfig};

fn native_blocked_vs_per_item(cfg: &BenchConfig) {
    println!("== native: blocked CorpusStore kernels vs per-item DenseVec::dot ==");
    let quick = std::env::var("SIMETRA_BENCH_QUICK").as_deref() == Ok("1");
    let sizes: &[(usize, usize)] =
        if quick { &[(10_000, 128)] } else { &[(10_000, 128), (100_000, 128)] };
    for &(n, d) in sizes {
        let k = 10usize;
        let store: CorpusStore = uniform_sphere_store(n, d, 31);
        // The per-item baseline pays the layout it measures: one heap
        // allocation per vector, pointer-chased on every scan.
        let rows: Vec<DenseVec> = (0..n).map(|i| store.vec(i)).collect();
        let queries = uniform_sphere(16, d, 32);
        let view = store.view();

        let ops = n as u64; // similarity evaluations per scan
        let mut qi = 0usize;
        let per_item = bench(cfg, &format!("per-item dot n{n} d{d}"), ops, || {
            qi = (qi + 1) % queries.len();
            let q = &queries[qi];
            let mut heap = KnnHeap::new(k);
            for (i, c) in rows.iter().enumerate() {
                heap.offer(i as u32, q.sim(c));
            }
            black_box(heap.into_sorted())
        });
        report(&per_item);

        let mut qj = 0usize;
        let blocked = bench(cfg, &format!("scan_topk blocked n{n} d{d}"), ops, || {
            qj = (qj + 1) % queries.len();
            let mut heap = KnnHeap::new(k);
            view.scan_topk(queries[qj].as_slice(), &mut heap);
            black_box(heap.into_sorted())
        });
        report(&blocked);

        let mut qr = 0usize;
        let blocked_range = bench(cfg, &format!("scan_range blocked n{n} d{d}"), ops, || {
            qr = (qr + 1) % queries.len();
            let mut out = Vec::new();
            view.scan_range(queries[qr].as_slice(), 0.3, &mut out);
            black_box(out)
        });
        report(&blocked_range);

        println!(
            "    -> blocked scan_topk is {:.2}x faster than the per-item loop\n",
            per_item.mean_ns / blocked.mean_ns
        );
    }
}

fn pjrt_sections(cfg: &BenchConfig) {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("skipping PJRT sections: artifacts/ missing (run `make artifacts`)");
        return;
    }
    let engine = match Engine::load(&dir) {
        Ok(e) => e,
        Err(e) => {
            println!("skipping PJRT sections: {e}");
            return;
        }
    };
    println!("== pjrt artifacts (platform: {}) ==\n", engine.platform());

    for (q, n, d, k) in
        [(8usize, 1024usize, 128usize, 16usize), (32, 4096, 128, 16), (64, 8192, 128, 32)]
    {
        let store = uniform_sphere_store(n, d, 31);
        let corpus: Vec<DenseVec> = (0..n).map(|i| store.vec(i)).collect();
        let queries = uniform_sphere(q, d, 32);
        let qflat: Vec<f32> = queries.iter().flat_map(|v| v.as_slice().to_vec()).collect();

        let ops = (q * n) as u64; // similarity evaluations per call
        let m = bench(cfg, &format!("pjrt score_topk q{q} n{n} k{k}"), ops, || {
            // Zero-copy: the engine reads the store's buffer directly.
            black_box(engine.score_topk(&qflat, q, store.flat(), n, d, k).unwrap())
        });
        report(&m);

        // Native scalar equivalent: full scoring + heap.
        let m2 = bench(cfg, &format!("native scalar q{q} n{n} k{k}"), ops, || {
            let mut out = Vec::with_capacity(q);
            for qv in &queries {
                let mut heap = KnnHeap::new(k);
                for (i, cv) in corpus.iter().enumerate() {
                    heap.offer(i as u32, qv.sim(cv));
                }
                out.push(heap.into_sorted());
            }
            black_box(out)
        });
        report(&m2);
        println!(
            "    -> engine/native ratio: {:.2}x per similarity\n",
            m.mean_ns / m2.mean_ns
        );
    }

    // pivot_filter artifact.
    for (q, p, n) in [(8usize, 16usize, 1024usize), (32, 32, 4096)] {
        let corpus = uniform_sphere(n, 64, 33);
        let pivots = uniform_sphere(p, 64, 34);
        let queries = uniform_sphere(q, 64, 35);
        let sim_qp: Vec<f32> = queries
            .iter()
            .flat_map(|qv| pivots.iter().map(|pv| qv.sim(pv) as f32).collect::<Vec<_>>())
            .collect();
        let sim_pc: Vec<f32> = pivots
            .iter()
            .flat_map(|pv| corpus.iter().map(|cv| pv.sim(cv) as f32).collect::<Vec<_>>())
            .collect();
        let ops = (q * p * n) as u64; // bound evaluations per call
        let m = bench(cfg, &format!("pjrt pivot_filter q{q} p{p} n{n}"), ops, || {
            black_box(engine.pivot_filter(&sim_qp, q, &sim_pc, p, n).unwrap())
        });
        report(&m);

        // Native equivalent per bound evaluation.
        let m2 = bench(cfg, &format!("native bounds q{q} p{p} n{n}"), ops, || {
            let mut acc = 0.0f32;
            for qi in 0..q {
                for ci in 0..n {
                    let mut lo = -1.0f32;
                    let mut hi = 1.0f32;
                    for pi in 0..p {
                        let s1 = sim_qp[qi * p + pi];
                        let s2 = sim_pc[pi * n + ci];
                        let prod = s1 * s2;
                        let rad =
                            (((1.0 - s1 * s1) * (1.0 - s2 * s2)).max(0.0)).sqrt();
                        lo = lo.max(prod - rad);
                        hi = hi.min(prod + rad);
                    }
                    acc += hi - lo;
                }
            }
            black_box(acc)
        });
        report(&m2);
        println!();
    }
}

fn main() {
    let cfg = BenchConfig::from_env();
    native_blocked_vs_per_item(&cfg);
    pjrt_sections(&cfg);
}
