//! Runtime bench: batched PJRT artifact scoring vs the native scalar loop —
//! the L1/L2 hot path measured from the L3 side, plus the pivot_filter
//! artifact. Skips (with a note) when artifacts/ is missing.
//!
//!     make artifacts && cargo bench --bench batch_scoring

use simetra::data::uniform_sphere;
use simetra::index::KnnHeap;
use simetra::metrics::SimVector;
use simetra::runtime::Engine;
use simetra::util::bench::{bench, black_box, report, BenchConfig};

fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("skipping: artifacts/ missing (run `make artifacts`)");
        return;
    }
    let cfg = BenchConfig::from_env();
    let engine = Engine::load(&dir).expect("engine load");
    println!("platform: {}\n", engine.platform());

    for (q, n, d, k) in [(8usize, 1024usize, 128usize, 16usize), (32, 4096, 128, 16), (64, 8192, 128, 32)] {
        let corpus = uniform_sphere(n, d, 31);
        let queries = uniform_sphere(q, d, 32);
        let qflat: Vec<f32> = queries.iter().flat_map(|v| v.as_slice().to_vec()).collect();
        let cflat: Vec<f32> = corpus.iter().flat_map(|v| v.as_slice().to_vec()).collect();

        let ops = (q * n) as u64; // similarity evaluations per call
        let m = bench(&cfg, &format!("pjrt score_topk q{q} n{n} k{k}"), ops, || {
            black_box(engine.score_topk(&qflat, q, &cflat, n, d, k).unwrap())
        });
        report(&m);

        // Native scalar equivalent: full scoring + heap.
        let m2 = bench(&cfg, &format!("native scalar q{q} n{n} k{k}"), ops, || {
            let mut out = Vec::with_capacity(q);
            for qv in &queries {
                let mut heap = KnnHeap::new(k);
                for (i, cv) in corpus.iter().enumerate() {
                    heap.offer(i as u32, qv.sim(cv));
                }
                out.push(heap.into_sorted());
            }
            black_box(out)
        });
        report(&m2);
        println!(
            "    -> engine/native ratio: {:.2}x per similarity\n",
            m.mean_ns / m2.mean_ns
        );
    }

    // pivot_filter artifact.
    for (q, p, n) in [(8usize, 16usize, 1024usize), (32, 32, 4096)] {
        let corpus = uniform_sphere(n, 64, 33);
        let pivots = uniform_sphere(p, 64, 34);
        let queries = uniform_sphere(q, 64, 35);
        let sim_qp: Vec<f32> = queries
            .iter()
            .flat_map(|qv| pivots.iter().map(|pv| qv.sim(pv) as f32).collect::<Vec<_>>())
            .collect();
        let sim_pc: Vec<f32> = pivots
            .iter()
            .flat_map(|pv| corpus.iter().map(|cv| pv.sim(cv) as f32).collect::<Vec<_>>())
            .collect();
        let ops = (q * p * n) as u64; // bound evaluations per call
        let m = bench(&cfg, &format!("pjrt pivot_filter q{q} p{p} n{n}"), ops, || {
            black_box(engine.pivot_filter(&sim_qp, q, &sim_pc, p, n).unwrap())
        });
        report(&m);

        // Native equivalent per bound evaluation.
        let m2 = bench(&cfg, &format!("native bounds q{q} p{p} n{n}"), ops, || {
            let mut acc = 0.0f32;
            for qi in 0..q {
                for ci in 0..n {
                    let mut lo = -1.0f32;
                    let mut hi = 1.0f32;
                    for pi in 0..p {
                        let s1 = sim_qp[qi * p + pi];
                        let s2 = sim_pc[pi * n + ci];
                        let prod = s1 * s2;
                        let rad =
                            (((1.0 - s1 * s1) * (1.0 - s2 * s2)).max(0.0)).sqrt();
                        lo = lo.max(prod - rad);
                        hi = hi.min(prod + rad);
                    }
                    acc += hi - lo;
                }
            }
            black_box(acc)
        });
        report(&m2);
        println!();
    }
}
