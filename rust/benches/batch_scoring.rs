//! Batch-scoring bench, two layers:
//!
//! 1. Native (always runs): the real serving path — a whole query batch
//!    through `search_batch_into` (ADR-006 multi-query traversal, the
//!    (query-block × row-block) `sim_block_multi` kernels) vs the same
//!    batch as per-query descents through `search_into`, on a
//!    serving-sized 100k x 128 corpus. This measures what the coordinator
//!    actually runs, not a hand-rolled scoring loop.
//! 2. PJRT (skipped with a note when artifacts/ or the `pjrt` feature is
//!    missing): batched artifact scoring vs the native scalar loop, plus
//!    the pivot_filter artifact.
//!
//!     cargo bench --bench batch_scoring
//!     # PJRT sections additionally need the `xla` dependency added to
//!     # rust/Cargo.toml (see its [features] comment) + artifacts:
//!     make artifacts && cargo bench --bench batch_scoring --features pjrt

use simetra::data::{uniform_sphere, uniform_sphere_store};
use simetra::index::{KnnHeap, LinearScan, SimilarityIndex};
use simetra::metrics::{DenseVec, SimVector};
use simetra::query::{QueryContext, SearchRequest, SearchResponse};
use simetra::runtime::Engine;
use simetra::storage::CorpusStore;
use simetra::util::bench::{bench, black_box, report, BenchConfig};

fn native_multi_vs_per_query(cfg: &BenchConfig) {
    println!("== native: search_batch_into multi-traversal vs per-query descent ==");
    let quick = std::env::var("SIMETRA_BENCH_QUICK").as_deref() == Ok("1");
    let sizes: &[(usize, usize)] =
        if quick { &[(10_000, 128)] } else { &[(10_000, 128), (100_000, 128)] };
    for &(n, d) in sizes {
        let k = 10usize;
        let q = 16usize;
        let store: CorpusStore = uniform_sphere_store(n, d, 31);
        let index = LinearScan::build(store.view());
        let queries = uniform_sphere(q, d, 32);
        let reqs: Vec<SearchRequest> = (0..q).map(|_| SearchRequest::knn(k).build()).collect();
        let ops = (q * n) as u64; // similarity evaluations per batch

        let mut ctx = QueryContext::new();
        let mut resps: Vec<SearchResponse> = Vec::new();
        let multi = bench(cfg, &format!("search_batch_into q{q} n{n} d{d}"), ops, || {
            index.search_batch_into(&queries, &reqs, &mut ctx, &mut resps);
            black_box(resps.len())
        });
        report(&multi);

        let mut ctx2 = QueryContext::new();
        let mut resp = SearchResponse::default();
        let per_query = bench(cfg, &format!("search_into x{q} n{n} d{d}"), ops, || {
            for (qv, req) in queries.iter().zip(&reqs) {
                ctx2.begin_query();
                index.search_into(qv, req, &mut ctx2, &mut resp);
                black_box(resp.hits.len());
            }
        });
        report(&per_query);

        let mut qr = 0usize;
        let mut rctx = QueryContext::new();
        let mut rout: Vec<(u32, f64)> = Vec::new();
        let blocked_range = bench(cfg, &format!("range_into blocked n{n} d{d}"), n as u64, || {
            qr = (qr + 1) % queries.len();
            rctx.begin_query();
            index.range_into(&queries[qr], 0.3, &mut rctx, &mut rout);
            black_box(rout.len())
        });
        report(&blocked_range);

        println!(
            "    -> multi-traversal batch is {:.2}x vs per-query descent\n",
            per_query.mean_ns / multi.mean_ns
        );
    }
}

fn pjrt_sections(cfg: &BenchConfig) {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("skipping PJRT sections: artifacts/ missing (run `make artifacts`)");
        return;
    }
    let engine = match Engine::load(&dir) {
        Ok(e) => e,
        Err(e) => {
            println!("skipping PJRT sections: {e}");
            return;
        }
    };
    println!("== pjrt artifacts (platform: {}) ==\n", engine.platform());

    for (q, n, d, k) in
        [(8usize, 1024usize, 128usize, 16usize), (32, 4096, 128, 16), (64, 8192, 128, 32)]
    {
        let store = uniform_sphere_store(n, d, 31);
        let corpus: Vec<DenseVec> = (0..n).map(|i| store.vec(i)).collect();
        let queries = uniform_sphere(q, d, 32);
        let qflat: Vec<f32> = queries.iter().flat_map(|v| v.as_slice().to_vec()).collect();

        let ops = (q * n) as u64; // similarity evaluations per call
        let m = bench(cfg, &format!("pjrt score_topk q{q} n{n} k{k}"), ops, || {
            // Zero-copy: the engine reads the store's buffer directly.
            black_box(engine.score_topk(&qflat, q, store.flat(), n, d, k).unwrap())
        });
        report(&m);

        // Native scalar equivalent: full scoring + heap.
        let m2 = bench(cfg, &format!("native scalar q{q} n{n} k{k}"), ops, || {
            let mut out = Vec::with_capacity(q);
            for qv in &queries {
                let mut heap = KnnHeap::new(k);
                for (i, cv) in corpus.iter().enumerate() {
                    heap.offer(i as u32, qv.sim(cv));
                }
                out.push(heap.into_sorted());
            }
            black_box(out)
        });
        report(&m2);
        println!(
            "    -> engine/native ratio: {:.2}x per similarity\n",
            m.mean_ns / m2.mean_ns
        );
    }

    // pivot_filter artifact.
    for (q, p, n) in [(8usize, 16usize, 1024usize), (32, 32, 4096)] {
        let corpus = uniform_sphere(n, 64, 33);
        let pivots = uniform_sphere(p, 64, 34);
        let queries = uniform_sphere(q, 64, 35);
        let sim_qp: Vec<f32> = queries
            .iter()
            .flat_map(|qv| pivots.iter().map(|pv| qv.sim(pv) as f32).collect::<Vec<_>>())
            .collect();
        let sim_pc: Vec<f32> = pivots
            .iter()
            .flat_map(|pv| corpus.iter().map(|cv| pv.sim(cv) as f32).collect::<Vec<_>>())
            .collect();
        let ops = (q * p * n) as u64; // bound evaluations per call
        let m = bench(cfg, &format!("pjrt pivot_filter q{q} p{p} n{n}"), ops, || {
            black_box(engine.pivot_filter(&sim_qp, q, &sim_pc, p, n).unwrap())
        });
        report(&m);

        // Native equivalent per bound evaluation.
        let m2 = bench(cfg, &format!("native bounds q{q} p{p} n{n}"), ops, || {
            let mut acc = 0.0f32;
            for qi in 0..q {
                for ci in 0..n {
                    let mut lo = -1.0f32;
                    let mut hi = 1.0f32;
                    for pi in 0..p {
                        let s1 = sim_qp[qi * p + pi];
                        let s2 = sim_pc[pi * n + ci];
                        let prod = s1 * s2;
                        let rad =
                            (((1.0 - s1 * s1) * (1.0 - s2 * s2)).max(0.0)).sqrt();
                        lo = lo.max(prod - rad);
                        hi = hi.min(prod + rad);
                    }
                    acc += hi - lo;
                }
            }
            black_box(acc)
        });
        report(&m2);
        println!();
    }
}

fn main() {
    let cfg = BenchConfig::from_env();
    native_multi_vs_per_query(&cfg);
    pjrt_sections(&cfg);
}
