//! Experiment X1: pruning power AND wall-clock of the similarity-native
//! indexes across bounds — the index integration the paper motivates.
//! Complements examples/pruning_study.rs (which sweeps more workloads) with
//! timed end-to-end query benchmarks on a fixed serving-like corpus.
//!
//! Two sections:
//!   1. index structures under the default Mult bound (Eq. 10/13);
//!   2. the bound-family race: every `BoundKind` (including the Ptolemaic
//!      pair bounds of ADR-009 and the Auto selector) over the same
//!      prebuilt LAESA / m-tree / vp-tree, via per-request overrides, so
//!      the structure is held fixed while only the bound varies.
//!
//! Emits `BENCH_bounds.json` with per-leg `mean_ns` and `pruned_fraction`
//! so bound-tightness claims are tracked as a perf trajectory.
//!
//!     cargo bench --bench index_pruning
//!     SIMETRA_BENCH_QUICK=1 cargo bench --bench index_pruning  # small

use simetra::bounds::BoundKind;
use simetra::data::{vmf_mixture, VmfSpec};
use simetra::index::{
    BallTree, CoverTree, Gnat, Laesa, LinearScan, MTree, QueryStats, SimilarityIndex, VpTree,
};
use simetra::metrics::DenseVec;
use simetra::query::{QueryContext, SearchRequest, SearchResponse};
use simetra::util::bench::{bench, black_box, report, write_bench_json, BenchConfig};
use simetra::util::Json;

const DIM: usize = 32;
const K: usize = 10;

fn bench_index(
    cfg: &BenchConfig,
    rows: &mut Vec<Json>,
    name: &str,
    idx: &dyn SimilarityIndex<DenseVec>,
    queries: &[DenseVec],
    n: usize,
) {
    // Wall clock per kNN query.
    let mut qi = 0usize;
    let m = bench(cfg, &format!("{name} knn"), 1, || {
        let mut stats = QueryStats::default();
        qi = (qi + 1) % queries.len();
        black_box(idx.knn(&queries[qi], K, &mut stats))
    });
    // Pruning power, measured separately (not timed).
    let mut stats = QueryStats::default();
    for q in queries {
        idx.knn(q, K, &mut stats);
    }
    let scored = stats.sim_evals as f64 / (queries.len() * n) as f64;
    report(&m);
    println!(
        "    -> {:.1}% of corpus exactly scored, {} subtrees pruned",
        100.0 * scored,
        stats.pruned
    );
    let mut row = match m.to_json() {
        Json::Obj(fields) => fields,
        _ => unreachable!("to_json returns an object"),
    };
    row.push(("leg".into(), Json::Str("structure".into())));
    row.push(("pruned_fraction".into(), Json::Num(1.0 - scored)));
    row.push(("n".into(), Json::Num(n as f64)));
    row.push(("d".into(), Json::Num(DIM as f64)));
    row.push(("k".into(), Json::Num(K as f64)));
    rows.push(Json::Obj(row));
}

/// Race every bound family over one prebuilt index via per-request
/// overrides: same tree/table, only the certified interval math varies.
fn race_bounds(
    cfg: &BenchConfig,
    rows: &mut Vec<Json>,
    leg: &str,
    idx: &dyn SimilarityIndex<DenseVec>,
    queries: &[DenseVec],
    n: usize,
) {
    println!("\n== bound race on {leg} (fixed structure, request overrides) ==");
    for bound in BoundKind::ALL {
        let req = SearchRequest::knn(K).bound(bound).build();
        let mut ctx = QueryContext::new();
        let mut resp = SearchResponse::default();
        let mut qi = 0usize;
        let m = bench(cfg, &format!("{leg}/{}", bound.name()), 1, || {
            qi = (qi + 1) % queries.len();
            ctx.begin_query();
            idx.search_into(&queries[qi], &req, &mut ctx, &mut resp);
            black_box(resp.hits.len())
        });
        // Pruning power, measured separately (not timed).
        let mut evals = 0u64;
        let mut pruned = 0u64;
        for q in queries {
            ctx.begin_query();
            idx.search_into(q, &req, &mut ctx, &mut resp);
            evals += resp.stats.sim_evals;
            pruned += resp.stats.pruned;
        }
        let scored = evals as f64 / (queries.len() * n) as f64;
        report(&m);
        println!(
            "    -> {:.1}% of corpus exactly scored, {pruned} candidates/subtrees pruned",
            100.0 * scored
        );
        let mut row = match m.to_json() {
            Json::Obj(fields) => fields,
            _ => unreachable!("to_json returns an object"),
        };
        row.push(("leg".into(), Json::Str(leg.into())));
        row.push(("bound".into(), Json::Str(bound.name().into())));
        row.push(("pruned_fraction".into(), Json::Num(1.0 - scored)));
        row.push(("n".into(), Json::Num(n as f64)));
        row.push(("d".into(), Json::Num(DIM as f64)));
        row.push(("k".into(), Json::Num(K as f64)));
        rows.push(Json::Obj(row));
    }
}

fn main() {
    let cfg = BenchConfig::from_env();
    let quick = std::env::var("SIMETRA_BENCH_QUICK").as_deref() == Ok("1");
    let n: usize = if quick { 4_000 } else { 30_000 };
    let query_rot: usize = if quick { 16 } else { 64 };
    println!("corpus: vMF n={n} d={DIM} clusters=50 kappa=80; k={K}\n");
    let (pts, _) = vmf_mixture(&VmfSpec {
        n,
        dim: DIM,
        clusters: 50,
        kappa: 80.0,
        seed: 21,
    });
    let (qs, _) = vmf_mixture(&VmfSpec {
        n: query_rot,
        dim: DIM,
        clusters: 50,
        kappa: 40.0,
        seed: 22,
    });

    let mut rows: Vec<Json> = Vec::new();

    println!("== baseline ==");
    let lin = LinearScan::build(pts.clone());
    bench_index(&cfg, &mut rows, "linear", &lin, &qs, n);

    println!("\n== index structures (Mult bound, Eq. 10/13) ==");
    let vp = VpTree::build(pts.clone(), BoundKind::Mult, 7);
    bench_index(&cfg, &mut rows, "vp-tree", &vp, &qs, n);
    let ball = BallTree::build(pts.clone(), BoundKind::Mult, 16);
    bench_index(&cfg, &mut rows, "ball-tree", &ball, &qs, n);
    let mtree = MTree::build(pts.clone(), BoundKind::Mult, 12);
    bench_index(&cfg, &mut rows, "m-tree", &mtree, &qs, n);
    let cover = CoverTree::build(pts.clone(), BoundKind::Mult);
    bench_index(&cfg, &mut rows, "cover-tree", &cover, &qs, n);
    let laesa = Laesa::build(pts.clone(), BoundKind::Mult, 32);
    bench_index(&cfg, &mut rows, "laesa-32", &laesa, &qs, n);
    let gnat = Gnat::build(pts.clone(), BoundKind::Mult, 8);
    bench_index(&cfg, &mut rows, "gnat", &gnat, &qs, n);

    // The race legs: the pivot table is where the Ptolemaic pair bound has
    // both references exact (ADR-009), the m-tree is where the parent
    // route supplies the second reference for free, and the vp-tree is the
    // two-sim degradation control (Ptolemaic == Mult there by design).
    race_bounds(&cfg, &mut rows, "laesa-32", &laesa, &qs, n);
    race_bounds(&cfg, &mut rows, "m-tree", &mtree, &qs, n);
    race_bounds(&cfg, &mut rows, "vp-tree", &vp, &qs, n);

    let path = std::path::Path::new("BENCH_bounds.json");
    write_bench_json(path, "index_pruning", rows).expect("write BENCH_bounds.json");
    println!("\nwrote {}", path.display());
}
