//! Experiment X1: pruning power AND wall-clock of the similarity-native
//! indexes across bounds — the index integration the paper motivates.
//! Complements examples/pruning_study.rs (which sweeps more workloads) with
//! timed end-to-end query benchmarks on a fixed serving-like corpus.
//!
//!     cargo bench --bench index_pruning

use simetra::bounds::BoundKind;
use simetra::data::{vmf_mixture, VmfSpec};
use simetra::index::{
    BallTree, CoverTree, Gnat, Laesa, LinearScan, MTree, QueryStats, SimilarityIndex, VpTree,
};
use simetra::metrics::DenseVec;
use simetra::util::bench::{bench, black_box, report, BenchConfig};

const N: usize = 30_000;
const DIM: usize = 32;
const K: usize = 10;
const QUERY_ROT: usize = 64;

fn bench_index(
    cfg: &BenchConfig,
    name: &str,
    idx: &dyn SimilarityIndex<DenseVec>,
    queries: &[DenseVec],
) {
    // Wall clock per kNN query.
    let mut qi = 0usize;
    let m = bench(cfg, &format!("{name} knn"), 1, || {
        let mut stats = QueryStats::default();
        qi = (qi + 1) % queries.len();
        black_box(idx.knn(&queries[qi], K, &mut stats))
    });
    // Pruning power, measured separately (not timed).
    let mut stats = QueryStats::default();
    for q in queries {
        idx.knn(q, K, &mut stats);
    }
    let pct = 100.0 * stats.sim_evals as f64 / (queries.len() * N) as f64;
    report(&m);
    println!("    -> {pct:.1}% of corpus exactly scored, {} subtrees pruned", stats.pruned);
}

fn main() {
    let cfg = BenchConfig::from_env();
    println!("corpus: vMF n={N} d={DIM} clusters=50 kappa=80; k={K}\n");
    let (pts, _) = vmf_mixture(&VmfSpec {
        n: N,
        dim: DIM,
        clusters: 50,
        kappa: 80.0,
        seed: 21,
    });
    let (qs, _) = vmf_mixture(&VmfSpec {
        n: QUERY_ROT,
        dim: DIM,
        clusters: 50,
        kappa: 40.0,
        seed: 22,
    });

    println!("== baseline ==");
    let lin = LinearScan::build(pts.clone());
    bench_index(&cfg, "linear", &lin, &qs);

    println!("\n== index structures (Mult bound, Eq. 10/13) ==");
    let vp = VpTree::build(pts.clone(), BoundKind::Mult, 7);
    bench_index(&cfg, "vp-tree", &vp, &qs);
    let ball = BallTree::build(pts.clone(), BoundKind::Mult, 16);
    bench_index(&cfg, "ball-tree", &ball, &qs);
    let mtree = MTree::build(pts.clone(), BoundKind::Mult, 12);
    bench_index(&cfg, "m-tree", &mtree, &qs);
    let cover = CoverTree::build(pts.clone(), BoundKind::Mult);
    bench_index(&cfg, "cover-tree", &cover, &qs);
    let laesa = Laesa::build(pts.clone(), BoundKind::Mult, 32);
    bench_index(&cfg, "laesa-32", &laesa, &qs);
    let gnat = Gnat::build(pts.clone(), BoundKind::Mult, 8);
    bench_index(&cfg, "gnat", &gnat, &qs);

    println!("\n== bound ablation on the vp-tree (same tree shape) ==");
    for bound in [
        BoundKind::Mult,
        BoundKind::ArccosFast,
        BoundKind::Arccos,
        BoundKind::Euclidean,
        BoundKind::MultLb1,
        BoundKind::MultLb2,
        BoundKind::EuclLb,
    ] {
        let idx = VpTree::build(pts.clone(), bound, 7);
        bench_index(&cfg, &format!("vp-tree/{}", bound.name()), &idx, &qs);
    }
}
