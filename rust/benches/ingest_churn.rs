//! Churn bench: kNN latency/QPS under ~10% concurrent write traffic vs
//! the identical corpus served statically (one sealed generation, no
//! writers), plus an exactness check (recall must be 1.0) at quiesce.
//!
//!     cargo bench --bench ingest_churn
//!     SIMETRA_BENCH_QUICK=1 cargo bench --bench ingest_churn   # small sizes
//!
//! Reported through `util::bench::Measurement` like every other bench.

use std::sync::Arc;
use std::time::Duration;

use simetra::coordinator::IndexKind;
use simetra::data::{uniform_sphere, uniform_sphere_store};
use simetra::ingest::{IngestConfig, IngestCorpus};
use simetra::metrics::DenseVec;
use simetra::storage::dot_slice;
use simetra::sync::{AtomicBool, Ordering};
use simetra::util::bench::{bench, black_box, report, BenchConfig};
use simetra::util::Rng;

const K: usize = 10;

fn ingest_cfg(d: usize) -> IngestConfig {
    IngestConfig {
        index: IndexKind::Vp,
        seal_threshold: 1024,
        max_generations: 6,
        maintenance_interval: Duration::from_micros(500),
        ..IngestConfig::new(d)
    }
}

/// Fraction of the true top-k (by brute force over the corpus's own
/// snapshot) that the ingest query path returns. Exactness means 1.0.
fn recall_at_quiesce(corpus: &IngestCorpus, queries: &[DenseVec]) -> f64 {
    let snap = corpus.snapshot();
    let mut found = 0usize;
    let mut wanted = 0usize;
    for q in queries {
        let mut truth: Vec<(u64, f64)> = Vec::new();
        snap.for_each_live_row(|id, row| truth.push((id, dot_slice(q.as_slice(), row))));
        truth.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        truth.truncate(K);
        let (got, _) = corpus.knn(q, K);
        wanted += truth.len();
        found += truth.iter().filter(|t| got.contains(t)).count();
    }
    found as f64 / wanted.max(1) as f64
}

fn main() {
    let cfg = BenchConfig::from_env();
    let quick = std::env::var("SIMETRA_BENCH_QUICK").as_deref() == Ok("1");
    let (n, d) = if quick { (5_000, 32) } else { (50_000, 64) };
    println!("== ingest churn: n={n} d={d} k={K} ==");

    let store = uniform_sphere_store(n, d, 71);
    let queries = uniform_sphere(64, d, 72);

    // Baseline: the same corpus as one sealed generation, no write traffic.
    let static_corpus = IngestCorpus::with_initial(ingest_cfg(d), Some(store.clone())).unwrap();
    let mut qi = 0usize;
    let m_static = bench(&cfg, &format!("static knn n{n}"), 1, || {
        qi = (qi + 1) % queries.len();
        black_box(static_corpus.knn(&queries[qi], K))
    });
    report(&m_static);

    // Churn: a writer thread interleaves inserts and deletes (~10% write
    // traffic by op count at serving rates) while the bench measures the
    // very same query loop.
    let churn = Arc::new(IngestCorpus::with_initial(ingest_cfg(d), Some(store)).unwrap());
    let stop = Arc::new(AtomicBool::new(false));
    let writer = {
        let churn = churn.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut rng = Rng::seed_from_u64(99);
            let mut live: Vec<u64> = (0..n as u64).collect();
            let mut writes = 0u64;
            while !stop.load(Ordering::Relaxed) {
                for _ in 0..8 {
                    let raw: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
                    live.push(churn.insert(raw).unwrap());
                }
                for _ in 0..2 {
                    if live.len() > 1 {
                        let id = live.swap_remove(rng.below(live.len()));
                        churn.delete(id);
                    }
                }
                writes += 10;
                std::thread::sleep(Duration::from_micros(200));
            }
            writes
        })
    };
    let mut qj = 0usize;
    let m_churn = bench(&cfg, &format!("churn knn n{n} (10% writes)"), 1, || {
        qj = (qj + 1) % queries.len();
        black_box(churn.knn(&queries[qj], K))
    });
    report(&m_churn);
    stop.store(true, Ordering::Relaxed);
    let writes = writer.join().unwrap();

    // Quiesce and check exactness survived.
    churn.flush();
    churn.compact();
    let recall = recall_at_quiesce(&churn, &queries[..16.min(queries.len())]);
    let st = churn.stats();
    println!(
        "    -> churn/static latency: {:.2}x | {writes} writes applied | \
         recall@{K} at quiesce = {recall:.3} | final: live={} generations={} seals={}",
        m_churn.mean_ns / m_static.mean_ns,
        st.live,
        st.generations,
        st.seals
    );
    assert!((recall - 1.0).abs() < f64::EPSILON, "recall degraded: {recall}");
}
