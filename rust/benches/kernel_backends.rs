//! Kernel-backend throughput: scalar vs simd vs i8-quantized scans
//! (ADR-003) across d in {64, 256, 768} and n in {10k, 100k}, emitting
//! `BENCH_kernels.json` so the repo accumulates a perf trajectory.
//!
//!     cargo bench --bench kernel_backends
//!     SIMETRA_BENCH_QUICK=1 cargo bench --bench kernel_backends  # small
//!
//! Each measurement is a full top-k scan; `mean_ns` is per corpus row, so
//! `mops` is millions of similarity evaluations per second and
//! `vectors_per_s` the row-scan rate. The i8 backend's per-row cost
//! includes its pre-filter plus the exact re-rank of survivors.

use simetra::data::{uniform_sphere, uniform_sphere_store};
use simetra::index::KnnHeap;
use simetra::storage::KernelKind;
use simetra::util::bench::{bench, black_box, report, write_bench_json, BenchConfig};
use simetra::util::Json;

fn main() {
    let cfg = BenchConfig::from_env();
    let quick = std::env::var("SIMETRA_BENCH_QUICK").as_deref() == Ok("1");
    let sizes: &[usize] = if quick { &[10_000] } else { &[10_000, 100_000] };
    let dims: &[usize] = if quick { &[64, 768] } else { &[64, 256, 768] };
    let kinds = [KernelKind::Scalar, KernelKind::Simd, KernelKind::QuantizedI8];
    let k = 10usize;

    let mut rows: Vec<Json> = Vec::new();
    for &n in sizes {
        for &d in dims {
            let store = uniform_sphere_store(n, d, 0xbe9f + d as u64);
            let queries = uniform_sphere(16, d, 0x5eed + d as u64);
            let mut scalar_ns = f64::NAN;
            for kind in kinds {
                // with_kernel builds the i8 sidecar eagerly, so the
                // one-time O(n*d) quantization pass stays out of the
                // measurement below.
                let s = store.clone().with_kernel(kind);
                let view = s.view();
                let mut qi = 0usize;
                let name = format!("scan_topk {} n{n} d{d}", kind.name());
                let m = bench(&cfg, &name, n as u64, || {
                    qi = (qi + 1) % queries.len();
                    let mut heap = KnnHeap::new(k);
                    view.scan_topk(queries[qi].as_slice(), &mut heap);
                    black_box(heap.into_sorted())
                });
                report(&m);
                if kind == KernelKind::Scalar {
                    scalar_ns = m.mean_ns;
                }
                let speedup = scalar_ns / m.mean_ns;
                println!("    -> {:.2}x vs scalar\n", speedup);
                let mut row = match m.to_json() {
                    Json::Obj(fields) => fields,
                    _ => unreachable!("to_json returns an object"),
                };
                row.push(("backend".into(), Json::Str(kind.name().into())));
                row.push(("n".into(), Json::Num(n as f64)));
                row.push(("d".into(), Json::Num(d as f64)));
                row.push(("vectors_per_s".into(), Json::Num(1e9 / m.mean_ns)));
                row.push(("speedup_vs_scalar".into(), Json::Num(speedup)));
                rows.push(Json::Obj(row));
            }
        }
    }

    let path = std::path::Path::new("BENCH_kernels.json");
    write_bench_json(path, "kernel_backends", rows).expect("write BENCH_kernels.json");
    println!("wrote {}", path.display());
}
