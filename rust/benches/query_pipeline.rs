//! Query-pipeline throughput through the unified execution layer
//! (ADR-004): batched kNN through a reused `QueryContext` vs the
//! allocate-per-call compatibility path, swept over batch size × index
//! kind. Emits `BENCH_query.json` so the scratch-arena win is tracked as a
//! perf trajectory, not a one-off claim.
//!
//!     cargo bench --bench query_pipeline
//!     SIMETRA_BENCH_QUICK=1 cargo bench --bench query_pipeline  # small
//!
//! Each measurement executes one whole batch; `mean_ns` is per *query*
//! (ops = batch size), so rows are comparable across batch sizes and
//! `mops` is millions of queries per second.

use simetra::bounds::BoundKind;
use simetra::coordinator::IndexKind;
use simetra::data::{uniform_sphere, uniform_sphere_store};
use simetra::index::{QueryStats, SimilarityIndex};
use simetra::query::{QueryContext, SearchRequest, SearchResponse};
use simetra::storage::KernelKind;
use simetra::util::bench::{bench, black_box, report, write_bench_json, BenchConfig};
use simetra::util::Json;

fn main() {
    let cfg = BenchConfig::from_env();
    let quick = std::env::var("SIMETRA_BENCH_QUICK").as_deref() == Ok("1");
    let n: usize = if quick { 4_000 } else { 20_000 };
    let d = 32usize;
    let k = 10usize;
    let batches: &[usize] = if quick { &[1, 32] } else { &[1, 8, 64, 256] };
    let kinds: &[IndexKind] = if quick {
        &[IndexKind::Vp, IndexKind::Linear]
    } else {
        &[IndexKind::Vp, IndexKind::Ball, IndexKind::Gnat, IndexKind::Laesa, IndexKind::Linear]
    };

    let store = uniform_sphere_store(n, d, 0x9a17);
    let queries = uniform_sphere(256, d, 0x7a11);

    let mut rows: Vec<Json> = Vec::new();
    for &kind in kinds {
        let index = kind.build(store.view(), BoundKind::Mult);
        for &batch in batches {
            let qs = &queries[..batch];

            // Reused-context batched path.
            let mut ctx = QueryContext::new();
            let name = format!("knn_batch {} b{batch}", kind.name());
            let m_ctx = bench(&cfg, &name, batch as u64, || {
                black_box(index.knn_batch(qs, k, &mut ctx))
            });
            report(&m_ctx);

            // Allocate-per-call compatibility path (the pre-ADR-004 shape).
            let name = format!("knn_fresh {} b{batch}", kind.name());
            let m_fresh = bench(&cfg, &name, batch as u64, || {
                let mut hits = Vec::with_capacity(batch);
                for q in qs {
                    let mut st = QueryStats::default();
                    hits.push(index.knn(q, k, &mut st));
                }
                black_box(hits)
            });
            report(&m_fresh);
            let speedup = m_fresh.mean_ns / m_ctx.mean_ns;
            println!("    -> context reuse is {speedup:.2}x vs fresh\n");

            for (m, path) in [(&m_ctx, "context"), (&m_fresh, "fresh")] {
                let mut row = match m.to_json() {
                    Json::Obj(fields) => fields,
                    _ => unreachable!("to_json returns an object"),
                };
                row.push(("index".into(), Json::Str(kind.name().into())));
                row.push(("path".into(), Json::Str(path.into())));
                row.push(("batch".into(), Json::Num(batch as f64)));
                row.push(("n".into(), Json::Num(n as f64)));
                row.push(("d".into(), Json::Num(d as f64)));
                row.push(("k".into(), Json::Num(k as f64)));
                rows.push(Json::Obj(row));
            }
        }
    }

    // --- ADR-006 multi-query traversal: kernel × batch-size sweep ---------
    //
    // The shared-frontier path (`search_batch_into`) vs the same plans as
    // independent per-query descents, per kernel backend. `mean_ns` stays
    // per query; the emitted rows also carry summed `nodes_visited` so the
    // "one descent instead of q" claim is tracked as data, not prose.
    let mkernels: &[KernelKind] = if quick {
        &[KernelKind::Simd]
    } else {
        &[KernelKind::Scalar, KernelKind::Simd, KernelKind::QuantizedI8]
    };
    let mkinds: &[IndexKind] = if quick {
        &[IndexKind::Vp]
    } else {
        &[IndexKind::Vp, IndexKind::Ball]
    };
    let mbatches: &[usize] = if quick { &[1, 16] } else { &[1, 4, 16, 64] };
    for &kernel in mkernels {
        let kstore = uniform_sphere_store(n, d, 0x9a17).with_kernel(kernel);
        for &kind in mkinds {
            let index = kind.build(kstore.view(), BoundKind::Mult);
            for &batch in mbatches {
                let qs = &queries[..batch];
                let reqs: Vec<SearchRequest> =
                    (0..batch).map(|_| SearchRequest::knn(k).build()).collect();

                let mut ctx = QueryContext::new();
                let mut resps: Vec<SearchResponse> = Vec::new();
                let name = format!("knn_multi {} {} b{batch}", kind.name(), kernel.name());
                let m_multi = bench(&cfg, &name, batch as u64, || {
                    index.search_batch_into(qs, &reqs, &mut ctx, &mut resps);
                    black_box(resps.len())
                });
                report(&m_multi);
                index.search_batch_into(qs, &reqs, &mut ctx, &mut resps);
                let multi_nodes: u64 = resps.iter().map(|r| r.stats.nodes_visited).sum();

                let mut ctx2 = QueryContext::new();
                let mut resp = SearchResponse::default();
                let name = format!("knn_per_query {} {} b{batch}", kind.name(), kernel.name());
                let m_seq = bench(&cfg, &name, batch as u64, || {
                    for (q, req) in qs.iter().zip(&reqs) {
                        ctx2.begin_query();
                        index.search_into(q, req, &mut ctx2, &mut resp);
                        black_box(resp.hits.len());
                    }
                });
                report(&m_seq);
                let mut seq_nodes = 0u64;
                for (q, req) in qs.iter().zip(&reqs) {
                    ctx2.begin_query();
                    index.search_into(q, req, &mut ctx2, &mut resp);
                    seq_nodes += resp.stats.nodes_visited;
                }
                println!(
                    "    -> multi is {:.2}x vs per-query ({multi_nodes} vs {seq_nodes} nodes)\n",
                    m_seq.mean_ns / m_multi.mean_ns
                );

                for (m, path, nodes) in [
                    (&m_multi, "multi", multi_nodes),
                    (&m_seq, "per_query", seq_nodes),
                ] {
                    let mut row = match m.to_json() {
                        Json::Obj(fields) => fields,
                        _ => unreachable!("to_json returns an object"),
                    };
                    row.push(("index".into(), Json::Str(kind.name().into())));
                    row.push(("kernel".into(), Json::Str(kernel.name().into())));
                    row.push(("path".into(), Json::Str(path.into())));
                    row.push(("batch".into(), Json::Num(batch as f64)));
                    row.push(("nodes_visited".into(), Json::Num(nodes as f64)));
                    row.push(("n".into(), Json::Num(n as f64)));
                    row.push(("d".into(), Json::Num(d as f64)));
                    row.push(("k".into(), Json::Num(k as f64)));
                    rows.push(Json::Obj(row));
                }
            }
        }
    }

    // --- filtered legs (ADR-005): allow-lists at three selectivities ------
    //
    // The filter is applied before exact evaluation inside the kernel
    // scans, so lower selectivity should mean proportionally fewer exact
    // evals — this leg tracks that as a perf trajectory.
    let fkinds: &[IndexKind] = if quick {
        &[IndexKind::Vp, IndexKind::Linear]
    } else {
        &[IndexKind::Vp, IndexKind::Gnat, IndexKind::Linear]
    };
    let fbatch = if quick { 16usize } else { 64 };
    for &kind in fkinds {
        let index = kind.build(store.view(), BoundKind::Mult);
        for &selectivity in &[0.1f64, 0.5, 0.9] {
            // keep `selectivity * 10` of every 10 ids: exact 10% / 50% /
            // 90% admission (a step_by(1/sel) stride would round 0.9 to
            // a stride of 1, i.e. 100% selectivity).
            let keep = (selectivity * 10.0).round() as u64;
            let allow: Vec<u64> = (0..n as u64).filter(|id| id % 10 < keep).collect();
            let req = SearchRequest::knn(k).allow(allow.clone()).build();
            let mut ctx = QueryContext::new();
            let mut resp = SearchResponse::default();
            let name = format!("knn_filtered {} sel{selectivity} b{fbatch}", kind.name());
            let m = bench(&cfg, &name, fbatch as u64, || {
                for q in &queries[..fbatch] {
                    ctx.begin_query();
                    index.search_into(q, &req, &mut ctx, &mut resp);
                    black_box(resp.hits.len());
                }
            });
            report(&m);
            let mut row = match m.to_json() {
                Json::Obj(fields) => fields,
                _ => unreachable!("to_json returns an object"),
            };
            row.push(("index".into(), Json::Str(kind.name().into())));
            row.push(("path".into(), Json::Str("filtered".into())));
            row.push(("selectivity".into(), Json::Num(selectivity)));
            row.push(("batch".into(), Json::Num(fbatch as f64)));
            row.push(("n".into(), Json::Num(n as f64)));
            row.push(("d".into(), Json::Num(d as f64)));
            row.push(("k".into(), Json::Num(k as f64)));
            rows.push(Json::Obj(row));
        }

        // --- budgeted legs: sim-eval budgets at 10% / 50% of the corpus --
        for &frac in &[0.1f64, 0.5] {
            let budget = (n as f64 * frac) as u64;
            let req = SearchRequest::knn(k).budget(budget).build();
            let mut ctx = QueryContext::new();
            let mut resp = SearchResponse::default();
            let name = format!("knn_budgeted {} budget{frac} b{fbatch}", kind.name());
            let m = bench(&cfg, &name, fbatch as u64, || {
                for q in &queries[..fbatch] {
                    ctx.begin_query();
                    index.search_into(q, &req, &mut ctx, &mut resp);
                    black_box((resp.hits.len(), resp.truncated));
                }
            });
            report(&m);
            let mut row = match m.to_json() {
                Json::Obj(fields) => fields,
                _ => unreachable!("to_json returns an object"),
            };
            row.push(("index".into(), Json::Str(kind.name().into())));
            row.push(("path".into(), Json::Str("budgeted".into())));
            row.push(("budget".into(), Json::Num(budget as f64)));
            row.push(("batch".into(), Json::Num(fbatch as f64)));
            row.push(("n".into(), Json::Num(n as f64)));
            row.push(("d".into(), Json::Num(d as f64)));
            row.push(("k".into(), Json::Num(k as f64)));
            rows.push(Json::Obj(row));
        }
    }

    // --- observability legs (ADR-007): tracing on vs off ------------------
    //
    // The EXPLAIN trace writes into pre-sized context scratch, so its cost
    // should be a small constant per event, and the tracing-OFF path must
    // stay indistinguishable from the pre-ADR-007 baseline (one predicted
    // branch per hook). Per-query `search_into` on both legs so the only
    // difference is the `trace` flag.
    let okinds: &[IndexKind] = if quick {
        &[IndexKind::Vp]
    } else {
        &[IndexKind::Vp, IndexKind::Gnat, IndexKind::Linear]
    };
    let obatch = if quick { 16usize } else { 64 };
    for &kind in okinds {
        let index = kind.build(store.view(), BoundKind::Mult);
        let mut legs = Vec::new();
        for (path, traced) in [("untraced", false), ("traced", true)] {
            let req = if traced {
                SearchRequest::knn(k).trace().build()
            } else {
                SearchRequest::knn(k).build()
            };
            let mut ctx = QueryContext::new();
            let mut resp = SearchResponse::default();
            let name = format!("knn_{path} {} b{obatch}", kind.name());
            let m = bench(&cfg, &name, obatch as u64, || {
                for q in &queries[..obatch] {
                    ctx.begin_query();
                    index.search_into(q, &req, &mut ctx, &mut resp);
                    black_box((resp.hits.len(), resp.trace.len()));
                }
            });
            report(&m);
            legs.push(m.mean_ns);
            let mut row = match m.to_json() {
                Json::Obj(fields) => fields,
                _ => unreachable!("to_json returns an object"),
            };
            row.push(("index".into(), Json::Str(kind.name().into())));
            row.push(("path".into(), Json::Str(path.into())));
            row.push(("batch".into(), Json::Num(obatch as f64)));
            row.push(("n".into(), Json::Num(n as f64)));
            row.push(("d".into(), Json::Num(d as f64)));
            row.push(("k".into(), Json::Num(k as f64)));
            rows.push(Json::Obj(row));
        }
        println!("    -> tracing overhead is {:.2}x\n", legs[1] / legs[0]);
    }

    let path = std::path::Path::new("BENCH_query.json");
    write_bench_json(path, "query_pipeline", rows).expect("write BENCH_query.json");
    println!("wrote {}", path.display());
}
