//! Query-pipeline throughput through the unified execution layer
//! (ADR-004): batched kNN through a reused `QueryContext` vs the
//! allocate-per-call compatibility path, swept over batch size × index
//! kind. Emits `BENCH_query.json` so the scratch-arena win is tracked as a
//! perf trajectory, not a one-off claim.
//!
//!     cargo bench --bench query_pipeline
//!     SIMETRA_BENCH_QUICK=1 cargo bench --bench query_pipeline  # small
//!
//! Each measurement executes one whole batch; `mean_ns` is per *query*
//! (ops = batch size), so rows are comparable across batch sizes and
//! `mops` is millions of queries per second.

use simetra::bounds::BoundKind;
use simetra::coordinator::IndexKind;
use simetra::data::{uniform_sphere, uniform_sphere_store};
use simetra::index::{QueryStats, SimilarityIndex};
use simetra::query::{QueryContext, SearchRequest, SearchResponse};
use simetra::util::bench::{bench, black_box, report, write_bench_json, BenchConfig};
use simetra::util::Json;

fn main() {
    let cfg = BenchConfig::from_env();
    let quick = std::env::var("SIMETRA_BENCH_QUICK").as_deref() == Ok("1");
    let n: usize = if quick { 4_000 } else { 20_000 };
    let d = 32usize;
    let k = 10usize;
    let batches: &[usize] = if quick { &[1, 32] } else { &[1, 8, 64, 256] };
    let kinds: &[IndexKind] = if quick {
        &[IndexKind::Vp, IndexKind::Linear]
    } else {
        &[IndexKind::Vp, IndexKind::Ball, IndexKind::Gnat, IndexKind::Laesa, IndexKind::Linear]
    };

    let store = uniform_sphere_store(n, d, 0x9a17);
    let queries = uniform_sphere(256, d, 0x7a11);

    let mut rows: Vec<Json> = Vec::new();
    for &kind in kinds {
        let index = kind.build(store.view(), BoundKind::Mult);
        for &batch in batches {
            let qs = &queries[..batch];

            // Reused-context batched path.
            let mut ctx = QueryContext::new();
            let name = format!("knn_batch {} b{batch}", kind.name());
            let m_ctx = bench(&cfg, &name, batch as u64, || {
                black_box(index.knn_batch(qs, k, &mut ctx))
            });
            report(&m_ctx);

            // Allocate-per-call compatibility path (the pre-ADR-004 shape).
            let name = format!("knn_fresh {} b{batch}", kind.name());
            let m_fresh = bench(&cfg, &name, batch as u64, || {
                let mut hits = Vec::with_capacity(batch);
                for q in qs {
                    let mut st = QueryStats::default();
                    hits.push(index.knn(q, k, &mut st));
                }
                black_box(hits)
            });
            report(&m_fresh);
            let speedup = m_fresh.mean_ns / m_ctx.mean_ns;
            println!("    -> context reuse is {speedup:.2}x vs fresh\n");

            for (m, path) in [(&m_ctx, "context"), (&m_fresh, "fresh")] {
                let mut row = match m.to_json() {
                    Json::Obj(fields) => fields,
                    _ => unreachable!("to_json returns an object"),
                };
                row.push(("index".into(), Json::Str(kind.name().into())));
                row.push(("path".into(), Json::Str(path.into())));
                row.push(("batch".into(), Json::Num(batch as f64)));
                row.push(("n".into(), Json::Num(n as f64)));
                row.push(("d".into(), Json::Num(d as f64)));
                row.push(("k".into(), Json::Num(k as f64)));
                rows.push(Json::Obj(row));
            }
        }
    }

    // --- filtered legs (ADR-005): allow-lists at three selectivities ------
    //
    // The filter is applied before exact evaluation inside the kernel
    // scans, so lower selectivity should mean proportionally fewer exact
    // evals — this leg tracks that as a perf trajectory.
    let fkinds: &[IndexKind] = if quick {
        &[IndexKind::Vp, IndexKind::Linear]
    } else {
        &[IndexKind::Vp, IndexKind::Gnat, IndexKind::Linear]
    };
    let fbatch = if quick { 16usize } else { 64 };
    for &kind in fkinds {
        let index = kind.build(store.view(), BoundKind::Mult);
        for &selectivity in &[0.1f64, 0.5, 0.9] {
            // keep `selectivity * 10` of every 10 ids: exact 10% / 50% /
            // 90% admission (a step_by(1/sel) stride would round 0.9 to
            // a stride of 1, i.e. 100% selectivity).
            let keep = (selectivity * 10.0).round() as u64;
            let allow: Vec<u64> = (0..n as u64).filter(|id| id % 10 < keep).collect();
            let req = SearchRequest::knn(k).allow(allow.clone()).build();
            let mut ctx = QueryContext::new();
            let mut resp = SearchResponse::default();
            let name = format!("knn_filtered {} sel{selectivity} b{fbatch}", kind.name());
            let m = bench(&cfg, &name, fbatch as u64, || {
                for q in &queries[..fbatch] {
                    ctx.begin_query();
                    index.search_into(q, &req, &mut ctx, &mut resp);
                    black_box(resp.hits.len());
                }
            });
            report(&m);
            let mut row = match m.to_json() {
                Json::Obj(fields) => fields,
                _ => unreachable!("to_json returns an object"),
            };
            row.push(("index".into(), Json::Str(kind.name().into())));
            row.push(("path".into(), Json::Str("filtered".into())));
            row.push(("selectivity".into(), Json::Num(selectivity)));
            row.push(("batch".into(), Json::Num(fbatch as f64)));
            row.push(("n".into(), Json::Num(n as f64)));
            row.push(("d".into(), Json::Num(d as f64)));
            row.push(("k".into(), Json::Num(k as f64)));
            rows.push(Json::Obj(row));
        }

        // --- budgeted legs: sim-eval budgets at 10% / 50% of the corpus --
        for &frac in &[0.1f64, 0.5] {
            let budget = (n as f64 * frac) as u64;
            let req = SearchRequest::knn(k).budget(budget).build();
            let mut ctx = QueryContext::new();
            let mut resp = SearchResponse::default();
            let name = format!("knn_budgeted {} budget{frac} b{fbatch}", kind.name());
            let m = bench(&cfg, &name, fbatch as u64, || {
                for q in &queries[..fbatch] {
                    ctx.begin_query();
                    index.search_into(q, &req, &mut ctx, &mut resp);
                    black_box((resp.hits.len(), resp.truncated));
                }
            });
            report(&m);
            let mut row = match m.to_json() {
                Json::Obj(fields) => fields,
                _ => unreachable!("to_json returns an object"),
            };
            row.push(("index".into(), Json::Str(kind.name().into())));
            row.push(("path".into(), Json::Str("budgeted".into())));
            row.push(("budget".into(), Json::Num(budget as f64)));
            row.push(("batch".into(), Json::Num(fbatch as f64)));
            row.push(("n".into(), Json::Num(n as f64)));
            row.push(("d".into(), Json::Num(d as f64)));
            row.push(("k".into(), Json::Num(k as f64)));
            rows.push(Json::Obj(row));
        }
    }

    let path = std::path::Path::new("BENCH_query.json");
    write_bench_json(path, "query_pipeline", rows).expect("write BENCH_query.json");
    println!("wrote {}", path.display());
}
