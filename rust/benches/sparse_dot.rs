//! Substrate bench: the sparse merge-join dot product (paper §2) vs the
//! dense dot, across sparsity levels — the scalar scoring hot path.
//!
//!     cargo bench --bench sparse_dot

use simetra::data::{zipf_corpus, ZipfSpec};
use simetra::metrics::DenseVec;
use simetra::util::bench::{bench, black_box, report, BenchConfig};
use simetra::util::Rng;

fn main() {
    let cfg = BenchConfig::from_env();

    // Dense dot at serving dimensionalities.
    for d in [64usize, 128, 768] {
        let mut rng = Rng::seed_from_u64(d as u64);
        let a = DenseVec::new((0..d).map(|_| rng.normal() as f32).collect());
        let b = DenseVec::new((0..d).map(|_| rng.normal() as f32).collect());
        let m = bench(&cfg, &format!("dense dot d={d}"), 1, || black_box(a.dot(&b)));
        report(&m);
    }

    // Sparse merge dot on text-like vectors.
    let docs = zipf_corpus(&ZipfSpec {
        n_docs: 2_000,
        vocab: 50_000,
        doc_len: 150,
        ..Default::default()
    });
    let avg_nnz: f64 = docs.iter().map(|d| d.nnz() as f64).sum::<f64>() / docs.len() as f64;
    println!("\nsparse corpus: vocab=50k, avg nnz={avg_nnz:.0}");
    let m = bench(&cfg, "sparse merge dot (text)", 1, || {
        let mut acc = 0.0;
        // 64 random-ish pairs per call to defeat branch-predictor lock-in.
        for i in 0..64 {
            let a = &docs[(i * 31) % docs.len()];
            let b = &docs[(i * 97 + 5) % docs.len()];
            acc += black_box(a).dot(black_box(b));
        }
        acc / 64.0
    });
    println!("(per call = 64 pairs)");
    report(&m);

    // Merge dot cost scales with nnz, not vocab: same vectors, denser.
    for doc_len in [50usize, 400] {
        let docs = zipf_corpus(&ZipfSpec {
            n_docs: 200,
            vocab: 50_000,
            doc_len,
            ..Default::default()
        });
        let avg: f64 = docs.iter().map(|d| d.nnz() as f64).sum::<f64>() / docs.len() as f64;
        let m = bench(&cfg, &format!("sparse dot nnz~{avg:.0}"), 1, || {
            let mut acc = 0.0;
            for i in 0..16 {
                acc += docs[i].dot(black_box(&docs[i + 16]));
            }
            acc
        });
        report(&m);
    }

    // Sparse vs densified: the §2 claim that sparse scoring beats dense at
    // text sparsity levels.
    let sd = &docs[0];
    let dd = DenseVec::from_normalized(sd.to_dense());
    let se = &docs[1];
    let de = DenseVec::from_normalized(se.to_dense());
    let ms = bench(&cfg, "one pair sparse", 1, || black_box(sd.dot(se)));
    let md = bench(&cfg, "one pair densified(50k)", 1, || black_box(dd.dot(&de)));
    report(&ms);
    report(&md);
    println!(
        "\nsparse advantage at vocab=50k: {:.0}x (paper section 2's merge argument)",
        md.mean_ns / ms.mean_ns
    );
}
