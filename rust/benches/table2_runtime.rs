//! Table 2 reproduction: runtime of each bound equation over a pre-generated
//! array of 2M random similarity pairs, JMH-style (warmup + measurement
//! iterations), plus the baseline add to calibrate memory-access cost.
//!
//! Expected *shape* (the paper's testbed was Java/JMH on an i7-8650U; ours
//! is rust on this container): Mult ~ Euclidean ~ the cheap bounds, all
//! within ~2x of the add baseline; Arccos (libm trig) an order of magnitude
//! slower; Arccos-fast (polynomial, the JaFaMa substitute) in between.
//!
//!     cargo bench --bench table2_runtime

use simetra::bounds::lower::*;
use simetra::bounds::upper::ub_mult;
use simetra::util::bench::{bench, black_box, report, BenchConfig};
use simetra::util::Rng;

const PAIRS: usize = 2_000_000;

fn main() {
    let cfg = BenchConfig::from_env();
    let mut rng = Rng::seed_from_u64(42);
    let s1: Vec<f64> = (0..PAIRS).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let s2: Vec<f64> = (0..PAIRS).map(|_| rng.uniform(-1.0, 1.0)).collect();

    println!("Table 2: per-evaluation cost over {PAIRS} pre-generated pairs");
    println!("(paper: Mult 9.7ns ~ Euclid 10.4ns << Arccos 610ns; JaFaMa 59ns)\n");

    macro_rules! row {
        ($name:expr, $eq:expr, $f:expr) => {{
            let f = $f;
            let m = bench(&cfg, concat!($name, " (", $eq, ")"), PAIRS as u64, || {
                let mut acc = 0.0f64;
                for i in 0..PAIRS {
                    acc += f(black_box(s1[i]), black_box(s2[i]));
                }
                acc
            });
            report(&m);
            m
        }};
    }

    let base = {
        let m = bench(&cfg, "baseline (sum)", PAIRS as u64, || {
            let mut acc = 0.0f64;
            for i in 0..PAIRS {
                acc += black_box(s1[i]) + black_box(s2[i]);
            }
            acc
        });
        report(&m);
        m
    };

    let eucl = row!("Euclidean", "7", lb_euclidean);
    let eucl_lb = row!("Eucl-LB", "8", lb_eucl_lb);
    let arccos = row!("Arccos", "9", lb_arccos);
    let arccos_fast = row!("Arccos-fast", "9*", lb_arccos_fast);
    let mult = row!("Mult", "10", lb_mult);
    let mult_var = row!("Mult-variant", "fn.2", lb_mult_variant);
    let mult_lb1 = row!("Mult-LB1", "11", lb_mult_lb1);
    let mult_lb2 = row!("Mult-LB2", "12", lb_mult_lb2);
    let upper = row!("Mult-upper", "13", ub_mult);

    println!("\n== shape checks vs the paper ==");
    let ratio = arccos.mean_ns / mult.mean_ns;
    println!("Arccos / Mult speed ratio: {ratio:.1}x (paper: ~63x)");
    let fast_ratio = arccos.mean_ns / arccos_fast.mean_ns;
    println!("Arccos / Arccos-fast:      {fast_ratio:.1}x (paper JaFaMa: ~10x)");
    println!(
        "Mult overhead over baseline: {:.1} ns (paper: ~1.6 ns)",
        mult.mean_ns - base.mean_ns
    );
    let mut ok = true;
    if arccos.mean_ns < 2.0 * mult.mean_ns {
        println!("!! UNEXPECTED: Arccos not clearly slower than Mult");
        ok = false;
    }
    if arccos_fast.mean_ns > arccos.mean_ns {
        println!("!! UNEXPECTED: fast arccos slower than libm arccos");
        ok = false;
    }
    for (name, m) in [
        ("Euclidean", &eucl),
        ("Eucl-LB", &eucl_lb),
        ("Mult-variant", &mult_var),
        ("Mult-LB1", &mult_lb1),
        ("Mult-LB2", &mult_lb2),
        ("Mult-upper", &upper),
    ] {
        if m.mean_ns > 6.0 * mult.mean_ns.max(base.mean_ns) {
            println!("!! UNEXPECTED: {name} an outlier at {:.1} ns", m.mean_ns);
            ok = false;
        }
    }
    println!("{}", if ok { "shape OK: matches Table 2" } else { "shape DIVERGES from Table 2" });
}
