//! Wire-path throughput (ADR-008): the streaming pull-parser and
//! tree-free serializer vs the legacy `Json`-tree codec, plus pipelined
//! end-to-end QPS through the worker-pool front door vs the legacy
//! thread-per-connection server.
//!
//!     cargo bench --bench wire_path
//!     SIMETRA_BENCH_QUICK=1 cargo bench --bench wire_path   # small
//!
//! Emits `BENCH_wire.json`. Parse/serialize rows are ns per request
//! line; end-to-end rows are ns per request at a given pipelining depth
//! (`inflight` lines written before the first reply is read), so `mops`
//! is millions of requests per second.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use simetra::coordinator::protocol::{
    parse_wire_streaming, write_response, Hit, Request, Response, WireScratch,
};
use simetra::coordinator::server::{serve, serve_legacy};
use simetra::coordinator::{Coordinator, CoordinatorConfig};
use simetra::data::uniform_sphere;
use simetra::util::bench::{bench, black_box, report, write_bench_json, BenchConfig, Measurement};
use simetra::util::Json;

fn push_row(
    rows: &mut Vec<Json>,
    m: &Measurement,
    stage: &str,
    path: &str,
    inflight: Option<usize>,
) {
    let mut row = match m.to_json() {
        Json::Obj(fields) => fields,
        _ => unreachable!("to_json returns an object"),
    };
    row.push(("stage".into(), Json::Str(stage.into())));
    row.push(("path".into(), Json::Str(path.into())));
    if let Some(w) = inflight {
        row.push(("inflight".into(), Json::Num(w as f64)));
    }
    rows.push(Json::Obj(row));
}

/// Request-line parse: streaming pull-parser into connection scratch vs
/// the legacy parse through a `Json` tree.
fn parse_section(cfg: &BenchConfig, rows: &mut Vec<Json>) {
    println!("== parse: streaming pull-parser vs legacy tree ==");
    let qv = uniform_sphere(1, 64, 0x81f)[0].as_slice().to_vec();
    let knn = Request::Knn { vector: qv.clone(), k: 10 }.to_json().to_string();
    let comps: Vec<String> = qv.iter().map(|v| format!("{v}")).collect();
    let search = format!(
        r#"{{"op":"search","v":1,"vector":[{}],"mode":"knn","k":10,"allow":[7],"trace":true}}"#,
        comps.join(",")
    );
    let mut scratch = WireScratch::new();
    for (label, line) in [("knn d64", &knn), ("search d64 optioned", &search)] {
        let m = bench(cfg, &format!("parse_streaming {label}"), 1, || {
            black_box(parse_wire_streaming(line.as_bytes(), &mut scratch).unwrap())
        });
        report(&m);
        push_row(rows, &m, "parse", "streaming", None);

        let m2 = bench(cfg, &format!("parse_legacy {label}"), 1, || {
            black_box(Request::parse(line).unwrap())
        });
        report(&m2);
        push_row(rows, &m2, "parse", "legacy", None);
        println!("    -> streaming parse is {:.2}x vs tree\n", m2.mean_ns / m.mean_ns);
    }
}

/// Response serialization: tree-free writer into a reused buffer vs
/// building a `Json` tree and rendering it to a fresh `String`.
fn serialize_section(cfg: &BenchConfig, rows: &mut Vec<Json>) {
    println!("== serialize: tree-free writer vs legacy tree ==");
    let hits: Vec<Hit> =
        (0..10).map(|i| Hit { id: i as u64 * 31, score: 1.0 - i as f64 * 0.05 }).collect();
    let resp = Response::Ok { hits, sim_evals: 4321 };
    let mut out = String::new();
    let m = bench(cfg, "serialize_streaming k10", 1, || {
        out.clear();
        write_response(&resp, &mut out);
        black_box(out.len())
    });
    report(&m);
    push_row(rows, &m, "serialize", "streaming", None);

    let m2 = bench(cfg, "serialize_legacy k10", 1, || {
        black_box(resp.to_json().to_string().len())
    });
    report(&m2);
    push_row(rows, &m2, "serialize", "legacy", None);
    println!("    -> streaming serialize is {:.2}x vs tree\n", m2.mean_ns / m.mean_ns);
}

/// End-to-end over TCP: a pipelined client writes `w` kNN request lines,
/// then reads `w` reply lines, against the worker-pool server and the
/// legacy thread-per-connection server.
fn e2e_section(cfg: &BenchConfig, rows: &mut Vec<Json>) {
    println!("== end-to-end: pipelined QPS, pool vs thread-per-connection ==");
    let quick = std::env::var("SIMETRA_BENCH_QUICK").as_deref() == Ok("1");
    let n: usize = if quick { 2_000 } else { 10_000 };
    let d = 32usize;
    let inflights: &[usize] = if quick { &[1, 8] } else { &[1, 8, 64] };

    let pts = uniform_sphere(n, d, 0x83e);
    let coord = Coordinator::new(pts.clone(), CoordinatorConfig::default()).unwrap();
    let mut pool = serve(coord.clone(), "127.0.0.1:0").unwrap();
    let mut legacy = serve_legacy(coord, "127.0.0.1:0").unwrap();

    // 64 distinct pre-rendered request lines, cycled into bursts.
    let lines: Vec<String> = (0..64usize)
        .map(|i| {
            let vector = pts[(i * 131) % n].as_slice().to_vec();
            let mut line = Request::Knn { vector, k: 10 }.to_json().to_string();
            line.push('\n');
            line
        })
        .collect();

    for &w in inflights {
        let burst: String = lines.iter().cycle().take(w).cloned().collect();
        for (path, addr) in [("pool", pool.addr()), ("legacy", legacy.addr())] {
            let stream = TcpStream::connect(addr).unwrap();
            stream.set_nodelay(true).unwrap();
            let mut writer = stream.try_clone().unwrap();
            let mut reader = BufReader::new(stream);
            let mut reply = String::new();
            let m = bench(cfg, &format!("e2e_{path} w{w}"), w as u64, || {
                writer.write_all(burst.as_bytes()).unwrap();
                let mut bytes = 0usize;
                for _ in 0..w {
                    reply.clear();
                    reader.read_line(&mut reply).unwrap();
                    bytes += reply.len();
                }
                black_box(bytes)
            });
            report(&m);
            println!("    -> {:.0} req/s", 1e9 / m.mean_ns);
            push_row(rows, &m, "e2e", path, Some(w));
        }
        println!();
    }
    pool.stop();
    legacy.stop();
}

fn main() {
    let cfg = BenchConfig::from_env();
    let mut rows: Vec<Json> = Vec::new();
    parse_section(&cfg, &mut rows);
    serialize_section(&cfg, &mut rows);
    e2e_section(&cfg, &mut rows);
    let path = std::path::Path::new("BENCH_wire.json");
    write_bench_json(path, "wire_path", rows).expect("write BENCH_wire.json");
    println!("wrote {}", path.display());
}
