//! `simetra-lint`: run the repo-invariant lint pass (ADR-010) over a
//! source tree and exit non-zero on any violation.
//!
//! Usage: `simetra-lint [SRC_DIR]` — defaults to this crate's `src/`.
//! The same checks run as a unit test (`lint::tests`), so `cargo test`
//! and the CI `lint` job enforce identical invariants.

use std::path::PathBuf;
use std::process::ExitCode;

use simetra::lint;

fn main() -> ExitCode {
    let root = std::env::args()
        .nth(1)
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src"));
    let violations = match lint::check_tree(&root) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("simetra-lint: cannot walk {}: {e}", root.display());
            return ExitCode::FAILURE;
        }
    };
    if violations.is_empty() {
        println!("simetra-lint: {} clean", root.display());
        return ExitCode::SUCCESS;
    }
    for v in &violations {
        println!("{v}");
    }
    eprintln!("simetra-lint: {} violation(s)", violations.len());
    ExitCode::FAILURE
}
