//! Interval arithmetic over similarities — the routing-node primitive.
//!
//! Tree indexes don't know one similarity `s2 = sim(z, y)` for a subtree,
//! they know a *range*: every point `y` under routing object `z` has
//! `sim(z, y)` in `[lo, hi]`. Pruning then needs
//!
//! ```text
//! ub*(s1, [lo,hi]) >= max_{s2 in [lo,hi]} ub(s1, s2)   (can anything match?)
//! lb*(s1, [lo,hi]) <= min_{s2 in [lo,hi]} lb(s1, s2)   (must everything match?)
//! ```
//!
//! For the tight Mult pair these extrema have closed positions in angle
//! space: `ub = cos(|t1 - t2|)` peaks where `t2 = t1` (i.e. `s2 = s1`) and
//! `lb = cos(t1 + t2)` bottoms where `t1 + t2 = pi` (i.e. `s2 = -s1`). For
//! the relaxed bounds the kinks of `min`/`|.|` and the vertex of Eq. 11's
//! quadratic piece add `s2 in {s1, -s1, -s1/2}`. Evaluating a bound at the
//! interval endpoints plus whichever of these probe points fall inside the
//! interval therefore covers every extremum of every kind — keeping the
//! whole routing computation trig-free.

use super::BoundKind;

/// A closed interval of similarities, `[-1, 1]`-clamped.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimInterval {
    pub lo: f64,
    pub hi: f64,
}

impl SimInterval {
    #[inline]
    pub fn new(lo: f64, hi: f64) -> Self {
        SimInterval { lo: lo.clamp(-1.0, 1.0), hi: hi.clamp(-1.0, 1.0) }
    }

    /// The degenerate interval holding a single known similarity.
    #[inline]
    pub fn point(s: f64) -> Self {
        Self::new(s, s)
    }

    /// The vacuous interval (whole similarity range).
    #[inline]
    pub fn full() -> Self {
        SimInterval { lo: -1.0, hi: 1.0 }
    }

    #[inline]
    pub fn contains(&self, s: f64) -> bool {
        self.lo <= s && s <= self.hi
    }

    /// Grow to cover `s`.
    #[inline]
    pub fn extend(&mut self, s: f64) {
        let s = s.clamp(-1.0, 1.0);
        if s < self.lo {
            self.lo = s;
        }
        if s > self.hi {
            self.hi = s;
        }
    }

    /// Intersection with another certified interval (both must hold).
    #[inline]
    pub fn intersect(&self, other: &SimInterval) -> SimInterval {
        SimInterval { lo: self.lo.max(other.lo), hi: self.hi.min(other.hi) }
    }

    /// True iff no similarity satisfies both intervals.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.lo > self.hi
    }
}

impl BoundKind {
    /// Upper bound on `sim(x, y)` over all `y` with `sim(z, y)` in `range`,
    /// given `s1 = sim(x, z)`.
    #[inline]
    pub fn upper_over(self, s1: f64, range: SimInterval) -> f64 {
        // Peak of the tight ub is at s2 = s1; if that's inside the range the
        // answer is ub(s1, s1) (= 1 for the tight kind, >= 1 for relaxed
        // ones, all valid). Otherwise the max lies at the nearest endpoint
        // for the tight kind; relaxed kinds are evaluated at all probes too
        // (a max over a superset of probe values stays an upper bound).
        let mut best = self.upper(s1, range.lo).max(self.upper(s1, range.hi));
        // Interior extrema / kinks: the tight ub peaks at s2 = s1; the
        // relaxed kinds add |s2| = |s1| kinks and quadratic vertices at
        // +/- s1/2 (e.g. Eq. 11's mirrored piece s1*s2 + 1 - s2^2).
        for probe in [s1, -s1, 0.5 * s1, -0.5 * s1] {
            if range.contains(probe) {
                best = best.max(self.upper(s1, probe));
            }
        }
        best
    }

    /// Lower bound on `sim(x, y)` over all `y` with `sim(z, y)` in `range`.
    #[inline]
    pub fn lower_over(self, s1: f64, range: SimInterval) -> f64 {
        let mut worst = self.lower(s1, range.lo).min(self.lower(s1, range.hi));
        // Interior extrema / kinks of the various bound formulas.
        for probe in [-s1, s1, -0.5 * s1, 0.5 * s1] {
            if range.contains(probe) {
                worst = worst.min(self.lower(s1, probe));
            }
        }
        worst
    }

    /// Certified interval on `sim(x, y)` for a whole subtree.
    #[inline]
    pub fn interval_over(self, s1: f64, range: SimInterval) -> SimInterval {
        SimInterval::new(self.lower_over(s1, range), self.upper_over(s1, range))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Brute-force extrema by dense sampling, to validate the probe logic.
    fn sampled_extrema(kind: BoundKind, s1: f64, range: SimInterval) -> (f64, f64) {
        let mut min_lb = f64::INFINITY;
        let mut max_ub = f64::NEG_INFINITY;
        let steps = 2000;
        for i in 0..=steps {
            let s2 = range.lo + (range.hi - range.lo) * i as f64 / steps as f64;
            min_lb = min_lb.min(kind.lower(s1, s2));
            max_ub = max_ub.max(kind.upper(s1, s2));
        }
        (min_lb, max_ub)
    }

    #[test]
    fn interval_over_dominates_sampled_extrema() {
        let ranges = [
            SimInterval::new(-1.0, 1.0),
            SimInterval::new(0.2, 0.9),
            SimInterval::new(-0.8, -0.1),
            SimInterval::new(-0.3, 0.6),
            SimInterval::new(0.95, 1.0),
        ];
        for kind in BoundKind::ALL {
            for &range in &ranges {
                for i in 0..=20 {
                    let s1 = -1.0 + i as f64 / 10.0;
                    let (min_lb, max_ub) = sampled_extrema(kind, s1, range);
                    let lo = kind.lower_over(s1, range);
                    let hi = kind.upper_over(s1, range);
                    assert!(
                        lo <= min_lb + 1e-9,
                        "{}: lower_over {lo} > sampled {min_lb} (s1={s1}, {range:?})",
                        kind.name()
                    );
                    assert!(
                        hi >= max_ub - 1e-9,
                        "{}: upper_over {hi} < sampled {max_ub} (s1={s1}, {range:?})",
                        kind.name()
                    );
                }
            }
        }
    }

    #[test]
    fn tight_interval_over_is_tight() {
        // For the Mult kind the probe construction should not just dominate
        // but *match* the sampled extrema (it is exact on the sphere).
        let range = SimInterval::new(-0.4, 0.7);
        for i in 0..=20 {
            let s1 = -1.0 + i as f64 / 10.0;
            let (min_lb, max_ub) = sampled_extrema(BoundKind::Mult, s1, range);
            assert!((BoundKind::Mult.lower_over(s1, range) - min_lb).abs() < 1e-6);
            assert!((BoundKind::Mult.upper_over(s1, range) - max_ub).abs() < 1e-6);
        }
    }

    #[test]
    fn point_interval_reduces_to_plain_bounds() {
        for kind in BoundKind::ALL {
            let iv = kind.interval_over(0.3, SimInterval::point(0.5));
            assert!((iv.lo - kind.lower(0.3, 0.5).max(-1.0)).abs() < 1e-12);
            assert!((iv.hi - kind.upper(0.3, 0.5).min(1.0)).abs() < 1e-12);
        }
    }

    #[test]
    fn containment_yields_trivial_upper() {
        // s1 inside the subtree range: some y may equal x, so ub must be 1.
        let ub = BoundKind::Mult.upper_over(0.4, SimInterval::new(0.0, 0.8));
        assert!((ub - 1.0).abs() < 1e-12);
    }

    #[test]
    fn opposite_reachable_yields_trivial_lower() {
        // -s1 inside the range: some y may be antipodal, so lb must be -1.
        let lb = BoundKind::Mult.lower_over(0.4, SimInterval::new(-0.8, 0.0));
        assert!((lb + 1.0).abs() < 1e-12);
    }

    #[test]
    fn intersect_and_empty() {
        let a = SimInterval::new(0.1, 0.5);
        let b = SimInterval::new(0.4, 0.9);
        let c = a.intersect(&b);
        assert!((c.lo - 0.4).abs() < 1e-15 && (c.hi - 0.5).abs() < 1e-15);
        assert!(!c.is_empty());
        assert!(a.intersect(&SimInterval::new(0.6, 0.9)).is_empty());
    }

    #[test]
    fn extend_covers() {
        let mut iv = SimInterval::point(0.0);
        iv.extend(0.5);
        iv.extend(-0.25);
        assert!(iv.contains(0.49) && iv.contains(-0.2) && !iv.contains(0.51));
    }
}
