//! Lower bounds on `sim(x, y)` given `s1 = sim(x, z)`, `s2 = sim(z, y)`.
//!
//! Equation numbers follow the paper. All functions take similarities in
//! `[-1, 1]`; values slightly outside (from accumulated floating-point
//! roundoff in dot products) are tolerated — the radicands are clamped at 0
//! so no NaN can escape.

/// Eq. 7: lower bound through the Euclidean triangle inequality applied to
/// `d = sqrt(2 - 2 sim)` on the unit sphere.
#[inline(always)]
pub fn lb_euclidean(s1: f64, s2: f64) -> f64 {
    s1 + s2 - 1.0 - 2.0 * ((1.0 - s1).max(0.0) * (1.0 - s2).max(0.0)).sqrt()
}

/// Eq. 8: cheap relaxation of Eq. 7 — the radical is over-approximated with
/// the smaller similarity, trading tightness for a sqrt-free form.
#[inline(always)]
pub fn lb_eucl_lb(s1: f64, s2: f64) -> f64 {
    s1 + s2 + 2.0 * s1.min(s2) - 3.0
}

/// Eq. 9: the tight bound via arc lengths, in its direct trig form
/// `cos(arccos(s1) + arccos(s2))`. Mathematically equal to [`lb_mult`];
/// 60–100 cycles per trig call make it the slow reference (paper Table 2).
#[inline(always)]
pub fn lb_arccos(s1: f64, s2: f64) -> f64 {
    (s1.clamp(-1.0, 1.0).acos() + s2.clamp(-1.0, 1.0).acos()).cos()
}

/// Polynomial arccos in the spirit of the paper's JaFaMa measurement:
/// a fast-math drop-in for `acos` (Abramowitz & Stegun 4.4.45 minimax form,
/// max abs error ~6.7e-5 rad).
#[inline(always)]
pub fn fast_arccos(x: f64) -> f64 {
    let neg = x < 0.0;
    let x = x.abs().min(1.0);
    // acos(x) ~= sqrt(1-x) * (a0 + a1 x + a2 x^2 + a3 x^3)
    let poly = 1.570_796_3 + x * (-0.212_114_4 + x * (0.074_261_0 - x * 0.018_729_3));
    let r = (1.0 - x).sqrt() * poly;
    if neg {
        std::f64::consts::PI - r
    } else {
        r
    }
}

/// Eq. 9 evaluated with [`fast_arccos`] — Table 2's "Arccos (JaFaMa)" row.
///
/// NOTE: the polynomial error (~1.3e-4 rad) makes this an *approximation* of
/// the tight bound; to stay a valid lower bound for pruning we subtract the
/// worst-case error (cos is 1-Lipschitz, so a similarity margin equal to the
/// summed angle error is always sufficient, on both monotone branches).
#[inline(always)]
pub fn lb_arccos_fast(s1: f64, s2: f64) -> f64 {
    const ERR: f64 = 2.6e-4; // 2 * max poly error (1.27e-4 rad each)
    (fast_arccos(s1.clamp(-1.0, 1.0)) + fast_arccos(s2.clamp(-1.0, 1.0))).cos() - ERR
}

/// Eq. 10, "Mult": the recommended tight lower bound,
/// `s1*s2 - sqrt((1 - s1^2)(1 - s2^2))` — equal to Eq. 9 up to f64 roundoff
/// (paper Fig. 5) at roughly the cost of the Euclidean form.
#[inline(always)]
pub fn lb_mult(s1: f64, s2: f64) -> f64 {
    s1 * s2 - (((1.0 - s1 * s1) * (1.0 - s2 * s2)).max(0.0)).sqrt()
}

/// Footnote-2 variant of Eq. 10: radical expanded via
/// `(1 - x^2) = (1 + x)(1 - x)` — numerically equivalent, measured
/// separately in Table 2 ("Mult-variant").
#[inline(always)]
pub fn lb_mult_variant(s1: f64, s2: f64) -> f64 {
    s1 * s2
        - (((1.0 + s1) * (1.0 - s1) * (1.0 + s2) * (1.0 - s2)).max(0.0)).sqrt()
}

/// Eq. 11, "Mult-LB1": sqrt-free relaxation of Eq. 10 using the smaller
/// squared similarity. The best of the cheap bounds (paper Fig. 2f).
#[inline(always)]
pub fn lb_mult_lb1(s1: f64, s2: f64) -> f64 {
    s1 * s2 + (s1 * s1).min(s2 * s2) - 1.0
}

/// Eq. 12, "Mult-LB2": min/max expansion of Eq. 10; strictly inferior to
/// Eq. 11 (paper section 3).
#[inline(always)]
pub fn lb_mult_lb2(s1: f64, s2: f64) -> f64 {
    2.0 * s1 * s2 - (s1 - s2).abs() - 1.0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid() -> Vec<f64> {
        (0..=80).map(|i| -1.0 + i as f64 / 40.0).collect()
    }

    #[test]
    fn mult_equals_arccos_to_roundoff() {
        // Paper Fig. 5: |Mult - Arccos| at the limit of f64 precision.
        for &s1 in &grid() {
            for &s2 in &grid() {
                let diff = (lb_mult(s1, s2) - lb_arccos(s1, s2)).abs();
                assert!(diff < 5e-15, "diff {diff} at ({s1}, {s2})");
            }
        }
    }

    #[test]
    fn mult_variant_equals_mult() {
        for &s1 in &grid() {
            for &s2 in &grid() {
                let diff = (lb_mult(s1, s2) - lb_mult_variant(s1, s2)).abs();
                assert!(diff < 1e-14);
            }
        }
    }

    #[test]
    fn fast_arccos_error_within_budget() {
        // The A&S 4.4.45 minimax form is good to ~1.27e-4 rad.
        for i in 0..=100_000 {
            let x = -1.0 + 2.0 * i as f64 / 100_000.0;
            let err = (fast_arccos(x) - x.acos()).abs();
            assert!(err < 1.3e-4, "err {err} at {x}");
        }
    }

    #[test]
    fn fast_arccos_bound_is_conservative() {
        // lb_arccos_fast must never exceed the true tight bound.
        for &s1 in &grid() {
            for &s2 in &grid() {
                assert!(
                    lb_arccos_fast(s1, s2) <= lb_arccos(s1, s2) + 1e-12,
                    "at ({s1}, {s2})"
                );
            }
        }
    }

    #[test]
    fn paper_anchor_values() {
        // Fig. 1 discussion: inputs (0.5, 0.5) -> Euclid -1, tight -0.5;
        // opposite-opposite -> Euclid -7, tight +1.
        assert!((lb_euclidean(0.5, 0.5) - (-1.0)).abs() < 1e-12);
        assert!((lb_mult(0.5, 0.5) - (-0.5)).abs() < 1e-12);
        assert!((lb_euclidean(-1.0, -1.0) - (-7.0)).abs() < 1e-12);
        assert!((lb_mult(-1.0, -1.0) - 1.0).abs() < 1e-12);
        // sim(x,z) = 1 pins x = z on the sphere: bound collapses to s2.
        assert!((lb_mult(1.0, 0.3) - 0.3).abs() < 1e-12);
    }

    #[test]
    fn bounds_tolerate_slightly_out_of_range_inputs() {
        for f in [lb_euclidean, lb_eucl_lb, lb_arccos, lb_arccos_fast, lb_mult,
                  lb_mult_variant, lb_mult_lb1, lb_mult_lb2] {
            let v = f(1.0 + 1e-9, -1.0 - 1e-9);
            assert!(v.is_finite(), "non-finite bound for out-of-range input");
        }
    }
}
