//! Triangle inequalities for cosine similarity (Schubert, SISAP 2021).
//!
//! Given the known similarities `s1 = sim(x, z)` and `s2 = sim(z, y)` to a
//! common reference point `z`, these bounds certify an interval on the
//! unknown `sim(x, y)` without computing it. The recommended tight pair
//! (paper Eqs. 10/13, "Mult") is
//!
//! ```text
//! sim(x,y) >= s1*s2 - sqrt((1 - s1^2)(1 - s2^2))
//! sim(x,y) <= s1*s2 + sqrt((1 - s1^2)(1 - s2^2))
//! ```
//!
//! which is exactly `cos(theta1 +/- theta2)` — tight on the sphere — at the
//! cost of one square root. The module also implements every alternative the
//! paper evaluates (Table 1) plus the matching upper-bound forms, so the
//! index layer can be instantiated with any of them and the benchmark
//! harness can regenerate the paper's comparisons.

pub mod interval;
pub mod lower;
pub mod order;
pub mod upper;

pub use interval::SimInterval;
pub use lower::{
    fast_arccos, lb_arccos, lb_arccos_fast, lb_eucl_lb, lb_euclidean, lb_mult,
    lb_mult_lb1, lb_mult_lb2, lb_mult_variant,
};
pub use upper::{ub_arccos, ub_eucl_ub, ub_euclidean, ub_mult, ub_mult_ub1};

/// Which triangle-inequality pair an index uses for pruning.
///
/// Every variant is *valid* (never prunes a true result); they differ in
/// tightness (pruning power) and per-evaluation cost — the trade-off the
/// paper's evaluation section measures.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BoundKind {
    /// Paper Eq. 7 (lower) and its mirrored upper form: bounds via the
    /// Euclidean metric on the unit sphere.
    Euclidean,
    /// Paper Eq. 8: cheapest, loosest (lower); upper mirrors Eq. 7's
    /// structure with the `min`-approximation.
    EuclLb,
    /// Paper Eq. 9: tight bound through `arccos`/`cos` (expensive trig).
    Arccos,
    /// Paper Eq. 9 evaluated with polynomial `fast_arccos` — the JaFaMa
    /// substitute of Table 2.
    ArccosFast,
    /// Paper Eqs. 10/13: the recommended tight, trig-free pair.
    Mult,
    /// Paper Eq. 11 (lower) + matching relaxation of Eq. 13 (upper).
    MultLb1,
    /// Paper Eq. 12 (lower) + Eq. 13 relaxed the same way (upper).
    MultLb2,
}

impl BoundKind {
    /// All kinds, in the paper's Table 1 order (fast-arccos appended).
    pub const ALL: [BoundKind; 7] = [
        BoundKind::Euclidean,
        BoundKind::EuclLb,
        BoundKind::Arccos,
        BoundKind::ArccosFast,
        BoundKind::Mult,
        BoundKind::MultLb1,
        BoundKind::MultLb2,
    ];

    /// Parse a bound name: the lowercase wire tokens ([`BoundKind::token`]),
    /// the Table-1 display names ([`BoundKind::name`], case-insensitive),
    /// and the CLI short aliases all round-trip.
    pub fn parse(s: &str) -> Option<BoundKind> {
        Some(match s.to_lowercase().as_str() {
            "euclidean" | "eucl" => BoundKind::Euclidean,
            "eucl-lb" | "eucllb" => BoundKind::EuclLb,
            "arccos" => BoundKind::Arccos,
            "arccos-fast" | "fast" => BoundKind::ArccosFast,
            "mult" => BoundKind::Mult,
            "mult-lb1" | "lb1" => BoundKind::MultLb1,
            "mult-lb2" | "lb2" => BoundKind::MultLb2,
            _ => return None,
        })
    }

    /// Stable lowercase wire token (round-trips through
    /// [`BoundKind::parse`]).
    pub fn token(self) -> &'static str {
        match self {
            BoundKind::Euclidean => "euclidean",
            BoundKind::EuclLb => "eucl-lb",
            BoundKind::Arccos => "arccos",
            BoundKind::ArccosFast => "arccos-fast",
            BoundKind::Mult => "mult",
            BoundKind::MultLb1 => "mult-lb1",
            BoundKind::MultLb2 => "mult-lb2",
        }
    }

    /// Stable display name matching the paper's Table 1.
    pub fn name(self) -> &'static str {
        match self {
            BoundKind::Euclidean => "Euclidean",
            BoundKind::EuclLb => "Eucl-LB",
            BoundKind::Arccos => "Arccos",
            BoundKind::ArccosFast => "Arccos-fast",
            BoundKind::Mult => "Mult",
            BoundKind::MultLb1 => "Mult-LB1",
            BoundKind::MultLb2 => "Mult-LB2",
        }
    }

    /// Paper equation number of the lower bound ("9*" for the fast-math
    /// variant of Eq. 9).
    pub fn equation(self) -> &'static str {
        match self {
            BoundKind::Euclidean => "7",
            BoundKind::EuclLb => "8",
            BoundKind::Arccos => "9",
            BoundKind::ArccosFast => "9*",
            BoundKind::Mult => "10",
            BoundKind::MultLb1 => "11",
            BoundKind::MultLb2 => "12",
        }
    }

    /// Lower bound on `sim(x, y)` from `s1 = sim(x, z)`, `s2 = sim(z, y)`.
    #[inline]
    pub fn lower(self, s1: f64, s2: f64) -> f64 {
        match self {
            BoundKind::Euclidean => lb_euclidean(s1, s2),
            BoundKind::EuclLb => lb_eucl_lb(s1, s2),
            BoundKind::Arccos => lb_arccos(s1, s2),
            BoundKind::ArccosFast => lb_arccos_fast(s1, s2),
            BoundKind::Mult => lb_mult(s1, s2),
            BoundKind::MultLb1 => lb_mult_lb1(s1, s2),
            BoundKind::MultLb2 => lb_mult_lb2(s1, s2),
        }
    }

    /// Upper bound on `sim(x, y)` from `s1 = sim(x, z)`, `s2 = sim(z, y)`.
    #[inline]
    pub fn upper(self, s1: f64, s2: f64) -> f64 {
        match self {
            BoundKind::Euclidean => ub_euclidean(s1, s2),
            BoundKind::EuclLb => ub_eucl_ub(s1, s2),
            BoundKind::Arccos => ub_arccos(s1, s2),
            BoundKind::ArccosFast => ub_mult(s1, s2),
            BoundKind::Mult => ub_mult(s1, s2),
            BoundKind::MultLb1 => ub_mult_ub1(s1, s2),
            BoundKind::MultLb2 => ub_mult_ub1(s1, s2),
        }
    }

    /// Certified interval on `sim(x, y)`.
    #[inline]
    pub fn interval(self, s1: f64, s2: f64) -> SimInterval {
        SimInterval::new(self.lower(s1, s2), self.upper(s1, s2))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_names_and_equations() {
        let rows: Vec<(&str, &str)> =
            BoundKind::ALL.iter().map(|b| (b.name(), b.equation())).collect();
        assert_eq!(rows[0], ("Euclidean", "7"));
        assert_eq!(rows[1], ("Eucl-LB", "8"));
        assert_eq!(rows[2], ("Arccos", "9"));
        assert_eq!(rows[4], ("Mult", "10"));
        assert_eq!(rows[5], ("Mult-LB1", "11"));
        assert_eq!(rows[6], ("Mult-LB2", "12"));
    }

    #[test]
    fn tokens_round_trip_through_parse() {
        for kind in BoundKind::ALL {
            assert_eq!(BoundKind::parse(kind.token()), Some(kind));
            assert_eq!(BoundKind::parse(kind.name()), Some(kind), "{}", kind.name());
        }
        assert_eq!(BoundKind::parse("lb1"), Some(BoundKind::MultLb1));
        assert_eq!(BoundKind::parse("bogus"), None);
    }

    #[test]
    fn lower_never_exceeds_upper() {
        for kind in BoundKind::ALL {
            for i in 0..=40 {
                for j in 0..=40 {
                    let s1 = -1.0 + i as f64 / 20.0;
                    let s2 = -1.0 + j as f64 / 20.0;
                    let iv = kind.interval(s1, s2);
                    assert!(
                        iv.lo <= iv.hi + 1e-12,
                        "{} lo={} hi={} at ({s1},{s2})",
                        kind.name(),
                        iv.lo,
                        iv.hi
                    );
                }
            }
        }
    }
}
