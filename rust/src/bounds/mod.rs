//! Triangle inequalities for cosine similarity (Schubert, SISAP 2021).
//!
//! Given the known similarities `s1 = sim(x, z)` and `s2 = sim(z, y)` to a
//! common reference point `z`, these bounds certify an interval on the
//! unknown `sim(x, y)` without computing it. The recommended tight pair
//! (paper Eqs. 10/13, "Mult") is
//!
//! ```text
//! sim(x,y) >= s1*s2 - sqrt((1 - s1^2)(1 - s2^2))
//! sim(x,y) <= s1*s2 + sqrt((1 - s1^2)(1 - s2^2))
//! ```
//!
//! which is exactly `cos(theta1 +/- theta2)` — tight on the sphere — at the
//! cost of one square root. The module also implements every alternative the
//! paper evaluates (Table 1) plus the matching upper-bound forms, so the
//! index layer can be instantiated with any of them and the benchmark
//! harness can regenerate the paper's comparisons.
//!
//! Beyond the paper's triangle family, [`ptolemy`] ports the quadrilateral
//! (Ptolemaic) inequality into similarity space the same way, [`pivot_table`]
//! combines it across build-time pivot pairs, and [`BoundKind::Auto`] picks
//! a family per (index, bound) from the live obs slack histograms (ADR-009).

pub mod interval;
pub mod lower;
pub mod order;
pub mod pivot_table;
pub mod ptolemy;
pub mod upper;

pub use interval::SimInterval;
pub use lower::{
    fast_arccos, lb_arccos, lb_arccos_fast, lb_eucl_lb, lb_euclidean, lb_mult,
    lb_mult_lb1, lb_mult_lb2, lb_mult_variant,
};
pub use pivot_table::PivotPairs;
pub use ptolemy::PairRefs;
pub use upper::{ub_arccos, ub_arccos_fast, ub_eucl_ub, ub_euclidean, ub_mult, ub_mult_ub1};

/// Which triangle-inequality pair an index uses for pruning.
///
/// Every variant is *valid* (never prunes a true result); they differ in
/// tightness (pruning power) and per-evaluation cost — the trade-off the
/// paper's evaluation section measures.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BoundKind {
    /// Paper Eq. 7 (lower) and its mirrored upper form: bounds via the
    /// Euclidean metric on the unit sphere.
    Euclidean,
    /// Paper Eq. 8: cheapest, loosest (lower); upper mirrors Eq. 7's
    /// structure with the `min`-approximation.
    EuclLb,
    /// Paper Eq. 9: tight bound through `arccos`/`cos` (expensive trig).
    Arccos,
    /// Paper Eq. 9 evaluated with polynomial `fast_arccos` — the JaFaMa
    /// substitute of Table 2.
    ArccosFast,
    /// Paper Eqs. 10/13: the recommended tight, trig-free pair.
    Mult,
    /// Paper Eq. 11 (lower) + matching relaxation of Eq. 13 (upper).
    MultLb1,
    /// Paper Eq. 12 (lower) + Eq. 13 relaxed the same way (upper).
    MultLb2,
    /// Quadrilateral (Ptolemaic) family ([`ptolemy`]): indexes holding a
    /// *pair* of reference points intersect the Ptolemy pair interval on
    /// top of the triangle bounds. The plain two-sim forms below degrade to
    /// Mult (Eqs. 10/13), so the family is never looser than Mult.
    Ptolemaic,
    /// Sqrt-free Ptolemaic relaxation; two-sim forms degrade to the
    /// sqrt-free Eq. 11 pair, matching the family's cost profile.
    PtolemaicFast,
    /// Per-(index, bound) adaptive selection trained on the obs slack
    /// histograms (ADR-009): resolved to a concrete family once per query
    /// at the search frame (fixed Mult fallback while histograms are
    /// cold), so it never reaches a traversal. Two-sim forms equal Mult.
    Auto,
}

impl BoundKind {
    /// All kinds, in the paper's Table 1 order (fast-arccos appended),
    /// followed by the quadrilateral family and the adaptive selector.
    pub const ALL: [BoundKind; 10] = [
        BoundKind::Euclidean,
        BoundKind::EuclLb,
        BoundKind::Arccos,
        BoundKind::ArccosFast,
        BoundKind::Mult,
        BoundKind::MultLb1,
        BoundKind::MultLb2,
        BoundKind::Ptolemaic,
        BoundKind::PtolemaicFast,
        BoundKind::Auto,
    ];

    /// Parse a bound name: the lowercase wire tokens ([`BoundKind::token`]),
    /// the Table-1 display names ([`BoundKind::name`], case-insensitive),
    /// and the CLI short aliases all round-trip.
    ///
    /// Allocation-free: this sits on the per-request wire path (ADR-004),
    /// so matching is `eq_ignore_ascii_case` against a static alias table
    /// instead of building a lowercased copy of the input.
    pub fn parse(s: &str) -> Option<BoundKind> {
        const ALIASES: &[(&str, BoundKind)] = &[
            ("euclidean", BoundKind::Euclidean),
            ("eucl", BoundKind::Euclidean),
            ("eucl-lb", BoundKind::EuclLb),
            ("eucllb", BoundKind::EuclLb),
            ("arccos", BoundKind::Arccos),
            ("arccos-fast", BoundKind::ArccosFast),
            ("fast", BoundKind::ArccosFast),
            ("mult", BoundKind::Mult),
            ("mult-lb1", BoundKind::MultLb1),
            ("lb1", BoundKind::MultLb1),
            ("mult-lb2", BoundKind::MultLb2),
            ("lb2", BoundKind::MultLb2),
            ("ptolemaic", BoundKind::Ptolemaic),
            ("ptol", BoundKind::Ptolemaic),
            ("ptolemaic-fast", BoundKind::PtolemaicFast),
            ("ptol-fast", BoundKind::PtolemaicFast),
            ("auto", BoundKind::Auto),
        ];
        ALIASES.iter().find(|(alias, _)| s.eq_ignore_ascii_case(alias)).map(|&(_, k)| k)
    }

    /// Stable lowercase wire token (round-trips through
    /// [`BoundKind::parse`]).
    pub fn token(self) -> &'static str {
        match self {
            BoundKind::Euclidean => "euclidean",
            BoundKind::EuclLb => "eucl-lb",
            BoundKind::Arccos => "arccos",
            BoundKind::ArccosFast => "arccos-fast",
            BoundKind::Mult => "mult",
            BoundKind::MultLb1 => "mult-lb1",
            BoundKind::MultLb2 => "mult-lb2",
            BoundKind::Ptolemaic => "ptolemaic",
            BoundKind::PtolemaicFast => "ptolemaic-fast",
            BoundKind::Auto => "auto",
        }
    }

    /// Stable display name matching the paper's Table 1.
    pub fn name(self) -> &'static str {
        match self {
            BoundKind::Euclidean => "Euclidean",
            BoundKind::EuclLb => "Eucl-LB",
            BoundKind::Arccos => "Arccos",
            BoundKind::ArccosFast => "Arccos-fast",
            BoundKind::Mult => "Mult",
            BoundKind::MultLb1 => "Mult-LB1",
            BoundKind::MultLb2 => "Mult-LB2",
            BoundKind::Ptolemaic => "Ptolemaic",
            BoundKind::PtolemaicFast => "Ptolemaic-fast",
            BoundKind::Auto => "Auto",
        }
    }

    /// Paper equation number of the lower bound ("9*" for the fast-math
    /// variant of Eq. 9; "P"/"P*" for the Ptolemaic pair, which is not in
    /// the paper's table; "—" for the selector, which is not a formula).
    pub fn equation(self) -> &'static str {
        match self {
            BoundKind::Euclidean => "7",
            BoundKind::EuclLb => "8",
            BoundKind::Arccos => "9",
            BoundKind::ArccosFast => "9*",
            BoundKind::Mult => "10",
            BoundKind::MultLb1 => "11",
            BoundKind::MultLb2 => "12",
            BoundKind::Ptolemaic => "P",
            BoundKind::PtolemaicFast => "P*",
            BoundKind::Auto => "—",
        }
    }

    /// True for the quadrilateral family: traversals that hold a second
    /// reference point (LAESA pivot partners, M-tree parent routes)
    /// additionally intersect [`ptolemy`] pair bounds for these kinds.
    #[inline]
    pub fn is_ptolemaic(self) -> bool {
        matches!(self, BoundKind::Ptolemaic | BoundKind::PtolemaicFast)
    }

    /// Lower bound on `sim(x, y)` from `s1 = sim(x, z)`, `s2 = sim(z, y)`.
    ///
    /// The Ptolemaic kinds need *two* reference points to improve on the
    /// triangle family; with a single reference they fall back to the Mult
    /// forms (exact: Eq. 10; fast: the sqrt-free Eq. 11), so they are valid
    /// everywhere a `BoundKind` is accepted. `Auto` is resolved before
    /// traversal; its own forms equal Mult as a safe identity.
    #[inline]
    pub fn lower(self, s1: f64, s2: f64) -> f64 {
        match self {
            BoundKind::Euclidean => lb_euclidean(s1, s2),
            BoundKind::EuclLb => lb_eucl_lb(s1, s2),
            BoundKind::Arccos => lb_arccos(s1, s2),
            BoundKind::ArccosFast => lb_arccos_fast(s1, s2),
            BoundKind::Mult | BoundKind::Ptolemaic | BoundKind::Auto => lb_mult(s1, s2),
            BoundKind::MultLb1 | BoundKind::PtolemaicFast => lb_mult_lb1(s1, s2),
            BoundKind::MultLb2 => lb_mult_lb2(s1, s2),
        }
    }

    /// Upper bound on `sim(x, y)` from `s1 = sim(x, z)`, `s2 = sim(z, y)`.
    /// (Single-reference fallbacks for the Ptolemaic kinds mirror
    /// [`BoundKind::lower`].)
    #[inline]
    pub fn upper(self, s1: f64, s2: f64) -> f64 {
        match self {
            BoundKind::Euclidean => ub_euclidean(s1, s2),
            BoundKind::EuclLb => ub_eucl_ub(s1, s2),
            BoundKind::Arccos => ub_arccos(s1, s2),
            BoundKind::ArccosFast => ub_arccos_fast(s1, s2),
            BoundKind::Mult | BoundKind::Ptolemaic | BoundKind::Auto => ub_mult(s1, s2),
            BoundKind::MultLb1 | BoundKind::PtolemaicFast => ub_mult_ub1(s1, s2),
            BoundKind::MultLb2 => ub_mult_ub1(s1, s2),
        }
    }

    /// Certified interval on `sim(x, y)`.
    #[inline]
    pub fn interval(self, s1: f64, s2: f64) -> SimInterval {
        SimInterval::new(self.lower(s1, s2), self.upper(s1, s2))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_names_and_equations() {
        let rows: Vec<(&str, &str)> =
            BoundKind::ALL.iter().map(|b| (b.name(), b.equation())).collect();
        assert_eq!(rows[0], ("Euclidean", "7"));
        assert_eq!(rows[1], ("Eucl-LB", "8"));
        assert_eq!(rows[2], ("Arccos", "9"));
        assert_eq!(rows[4], ("Mult", "10"));
        assert_eq!(rows[5], ("Mult-LB1", "11"));
        assert_eq!(rows[6], ("Mult-LB2", "12"));
        assert_eq!(rows[7], ("Ptolemaic", "P"));
        assert_eq!(rows[8], ("Ptolemaic-fast", "P*"));
        assert_eq!(rows[9], ("Auto", "—"));
    }

    #[test]
    fn tokens_round_trip_through_parse() {
        for kind in BoundKind::ALL {
            assert_eq!(BoundKind::parse(kind.token()), Some(kind));
            assert_eq!(BoundKind::parse(kind.name()), Some(kind), "{}", kind.name());
        }
        assert_eq!(BoundKind::parse("lb1"), Some(BoundKind::MultLb1));
        assert_eq!(BoundKind::parse("ptol"), Some(BoundKind::Ptolemaic));
        assert_eq!(BoundKind::parse("PTOL-FAST"), Some(BoundKind::PtolemaicFast));
        assert_eq!(BoundKind::parse("bogus"), None);
    }

    #[test]
    fn ptolemaic_two_sim_forms_equal_their_fallbacks() {
        // With one reference point the quadrilateral kinds must behave
        // exactly like the triangle forms they degrade to — traversals that
        // know no second reference rely on this identity.
        for i in 0..=40 {
            for j in 0..=40 {
                let s1 = -1.0 + i as f64 / 20.0;
                let s2 = -1.0 + j as f64 / 20.0;
                assert_eq!(BoundKind::Ptolemaic.lower(s1, s2), BoundKind::Mult.lower(s1, s2));
                assert_eq!(BoundKind::Ptolemaic.upper(s1, s2), BoundKind::Mult.upper(s1, s2));
                assert_eq!(BoundKind::Auto.lower(s1, s2), BoundKind::Mult.lower(s1, s2));
                assert_eq!(BoundKind::Auto.upper(s1, s2), BoundKind::Mult.upper(s1, s2));
                assert_eq!(
                    BoundKind::PtolemaicFast.lower(s1, s2),
                    BoundKind::MultLb1.lower(s1, s2)
                );
                assert_eq!(
                    BoundKind::PtolemaicFast.upper(s1, s2),
                    BoundKind::MultLb1.upper(s1, s2)
                );
            }
        }
    }

    #[test]
    fn lower_never_exceeds_upper() {
        for kind in BoundKind::ALL {
            for i in 0..=40 {
                for j in 0..=40 {
                    let s1 = -1.0 + i as f64 / 20.0;
                    let s2 = -1.0 + j as f64 / 20.0;
                    let iv = kind.interval(s1, s2);
                    assert!(
                        iv.lo <= iv.hi + 1e-12,
                        "{} lo={} hi={} at ({s1},{s2})",
                        kind.name(),
                        iv.lo,
                        iv.hi
                    );
                }
            }
        }
    }
}
