//! The partial order between lower bounds (paper Fig. 3), as executable
//! checks: `Eucl-LB <= Euclidean <= Arccos = Mult` and
//! `Eucl-LB <= Mult-LB2 <= Mult-LB1 <= Mult = Arccos`.
//!
//! `verify_order` is used by the `figures --fig 3` harness to emit the
//! empirical verification table, and by the proptest suite.

use super::lower::*;

/// One directed edge `a <= b` of the Fig. 3 Hasse diagram.
#[derive(Debug, Clone, Copy)]
pub struct OrderEdge {
    pub weaker: &'static str,
    pub stronger: &'static str,
    weaker_fn: fn(f64, f64) -> f64,
    stronger_fn: fn(f64, f64) -> f64,
}

/// All claimed dominance relations from Fig. 3.
pub const EDGES: [OrderEdge; 5] = [
    OrderEdge { weaker: "Eucl-LB", stronger: "Euclidean",
                weaker_fn: lb_eucl_lb, stronger_fn: lb_euclidean },
    OrderEdge { weaker: "Euclidean", stronger: "Mult",
                weaker_fn: lb_euclidean, stronger_fn: lb_mult },
    OrderEdge { weaker: "Eucl-LB", stronger: "Mult-LB2",
                weaker_fn: lb_eucl_lb, stronger_fn: lb_mult_lb2 },
    OrderEdge { weaker: "Mult-LB2", stronger: "Mult-LB1",
                weaker_fn: lb_mult_lb2, stronger_fn: lb_mult_lb1 },
    OrderEdge { weaker: "Mult-LB1", stronger: "Mult",
                weaker_fn: lb_mult_lb1, stronger_fn: lb_mult },
];

impl OrderEdge {
    /// Check the relation at one input pair; returns the violation amount
    /// (positive = violated), for the empirical Fig. 3 table.
    #[inline]
    pub fn violation(&self, s1: f64, s2: f64) -> f64 {
        (self.weaker_fn)(s1, s2) - (self.stronger_fn)(s1, s2)
    }
}

/// Verify every Fig. 3 edge on an `n x n` grid over `[-1, 1]^2`; returns
/// `(edge name, max violation)` per edge. All max violations must be
/// <= ~1e-15 for the figure's claim to hold.
pub fn verify_order(n: usize) -> Vec<(String, f64)> {
    EDGES
        .iter()
        .map(|edge| {
            let mut worst = f64::NEG_INFINITY;
            for i in 0..n {
                for j in 0..n {
                    let s1 = -1.0 + 2.0 * i as f64 / (n - 1) as f64;
                    let s2 = -1.0 + 2.0 * j as f64 / (n - 1) as f64;
                    worst = worst.max(edge.violation(s1, s2));
                }
            }
            (format!("{} <= {}", edge.weaker, edge.stronger), worst)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_order_holds_on_grid() {
        for (name, violation) in verify_order(201) {
            assert!(violation <= 1e-12, "{name} violated by {violation}");
        }
    }

    #[test]
    fn order_is_strict_somewhere() {
        // The edges are genuine (not equalities): each has a point where the
        // stronger bound is strictly better.
        for edge in EDGES {
            let mut found = false;
            for i in 0..50 {
                for j in 0..50 {
                    let s1 = -0.98 + 2.0 * i as f64 / 50.0;
                    let s2 = -0.98 + 2.0 * j as f64 / 50.0;
                    if edge.violation(s1, s2) < -1e-3 {
                        found = true;
                    }
                }
            }
            assert!(found, "{} <= {} never strict", edge.weaker, edge.stronger);
        }
    }
}
