//! Multi-pivot combination bounds: Ptolemaic refinement over a pivot table.
//!
//! A pivot table (LAESA) already certifies `sim(q, c)` by intersecting the
//! per-pivot triangle intervals. With [`super::ptolemy`] every *pair* of
//! pivots certifies a second, quadrilateral interval from the same stored
//! similarities — no extra exact evaluations, just arithmetic. Evaluating
//! all `m^2` pairs per candidate would break LAESA's O(m) filter cost, so
//! each pivot is assigned one build-time *partner*: the pivot it is least
//! similar to. That maximizes the pair chord `1 - sim(u, v)` — the
//! denominator of every Ptolemaic form — which is where the quadrilateral
//! bound is tightest (and the inequality degenerates as partners coincide).
//! The combination bound is then the intersection of the per-pivot triangle
//! intervals and the `m` partner-pair intervals: still O(m) per candidate,
//! and never looser than the triangle-only intersection by construction.
//!
//! The survey taxonomy (Chen et al., "Indexing Metric Spaces") calls this a
//! hybrid pivot-combination scheme; Hetland's Ptolemaic LAESA uses the full
//! pair matrix. The partner scheme keeps the candidate phase linear in the
//! number of pivots, which is what the batched traversal relies on.

use super::ptolemy::PairRefs;
use super::SimInterval;

/// Build-time pivot pairing for Ptolemaic refinement.
///
/// `partner[p]` is the index (into the same pivot list) of the pivot least
/// similar to pivot `p`; `pair_sim[p]` caches `sim(pivot[p],
/// pivot[partner[p]])`. With fewer than two pivots the table is empty and
/// refinement is a no-op.
#[derive(Debug, Clone, Default)]
pub struct PivotPairs {
    partner: Vec<u32>,
    pair_sim: Vec<f64>,
}

impl PivotPairs {
    /// Pair each of `m` pivots with its least-similar peer. `sim(a, b)`
    /// reports the similarity between pivots `a` and `b` (only called for
    /// `a != b`, `O(m^2)` total — build-time only).
    pub fn build(m: usize, mut sim: impl FnMut(usize, usize) -> f64) -> Self {
        if m < 2 {
            return PivotPairs::default();
        }
        let mut partner = Vec::with_capacity(m);
        let mut pair_sim = Vec::with_capacity(m);
        for p in 0..m {
            let mut best = usize::MAX;
            let mut best_sim = f64::INFINITY;
            for q in 0..m {
                if q == p {
                    continue;
                }
                let s = sim(p, q);
                // Deterministic tie-break on index keeps builds reproducible
                // across corpora that store the same vectors.
                if s < best_sim || (s == best_sim && q < best) {
                    best = q;
                    best_sim = s;
                }
            }
            partner.push(best as u32);
            pair_sim.push(best_sim);
        }
        PivotPairs { partner, pair_sim }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.partner.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.partner.is_empty()
    }

    /// The partner pivot index for pivot `p`.
    #[inline]
    pub fn partner(&self, p: usize) -> usize {
        self.partner[p] as usize
    }

    /// Cached `sim(pivot[p], pivot[partner(p)])`.
    #[inline]
    pub fn pair_sim(&self, p: usize) -> f64 {
        self.pair_sim[p]
    }

    /// Intersect the `m` partner-pair Ptolemaic intervals into `iv`.
    ///
    /// `q_piv[p]` holds `sim(q, pivot[p])` (already computed once per
    /// query); `cand(p)` reads the candidate's stored `sim(c, pivot[p])`
    /// from the table. `fast` selects the sqrt-free variant. Returns as
    /// soon as the intersection is empty — the candidate is certified out.
    #[inline]
    pub fn refine(
        &self,
        mut iv: SimInterval,
        fast: bool,
        q_piv: &[f64],
        cand: impl Fn(usize) -> f64,
    ) -> SimInterval {
        for p in 0..self.partner.len() {
            let o = self.partner[p] as usize;
            let refs = PairRefs::new(q_piv[p], q_piv[o], self.pair_sim[p]);
            let (s_yu, s_yv) = (cand(p), cand(o));
            let pair = if fast {
                refs.interval_fast(s_yu, s_yv)
            } else {
                refs.interval(s_yu, s_yv)
            };
            iv = iv.intersect(&pair);
            if iv.is_empty() {
                break;
            }
        }
        iv
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::BoundKind;
    use crate::data::uniform_sphere;

    #[test]
    fn partners_are_least_similar_and_never_self() {
        let pts = uniform_sphere(8, 6, 77);
        let pairs = PivotPairs::build(8, |a, b| pts[a].dot(&pts[b]));
        assert_eq!(pairs.len(), 8);
        for p in 0..8 {
            let o = pairs.partner(p);
            assert_ne!(o, p);
            for q in 0..8 {
                if q != p {
                    assert!(pts[p].dot(&pts[q]) >= pairs.pair_sim(p) - 1e-12);
                }
            }
            assert!((pts[p].dot(&pts[o]) - pairs.pair_sim(p)).abs() < 1e-12);
        }
    }

    #[test]
    fn under_two_pivots_is_inert() {
        let pairs = PivotPairs::build(1, |_, _| unreachable!());
        assert!(pairs.is_empty());
        let iv = pairs.refine(SimInterval::new(-0.5, 0.5), false, &[0.1], |_| 0.2);
        assert_eq!((iv.lo, iv.hi), (-0.5, 0.5));
    }

    /// The combined interval stays valid and is never looser than the
    /// Mult-only intersection it refines (S4 tightness obligation: the
    /// Ptolemaic family is the triangle intersection *plus* constraints).
    #[test]
    fn refined_interval_contains_truth_and_tightens_mult() {
        let m = 6;
        let pts = uniform_sphere(200 + m, 8, 78);
        let (pivots, items) = pts.split_at(m);
        let pairs = PivotPairs::build(m, |a, b| pivots[a].dot(&pivots[b]));
        let q = &items[0];
        let q_piv: Vec<f64> = (0..m).map(|p| q.dot(&pivots[p])).collect();
        for c in items.iter().skip(1) {
            let truth = q.dot(c);
            let mut mult = SimInterval::full();
            for p in 0..m {
                mult = mult.intersect(&BoundKind::Mult.interval(q_piv[p], c.dot(&pivots[p])));
            }
            for fast in [false, true] {
                let iv = pairs.refine(mult, fast, &q_piv, |p| c.dot(&pivots[p]));
                // f32-normalized corpus vectors leave ~1e-6 of chord slack
                // (the f64 derivation itself is pinned in bounds::ptolemy).
                assert!(
                    iv.lo <= truth + 1e-6 && truth <= iv.hi + 1e-6,
                    "fast={fast}: sim={truth} outside {iv:?}"
                );
                assert!(iv.lo >= mult.lo - 1e-12 && iv.hi <= mult.hi + 1e-12);
            }
        }
    }
}
