//! Ptolemaic (quadrilateral) bounds for cosine similarity.
//!
//! The paper ports the *triangle* inequality into similarity space through
//! the chord distance `d(a, b) = sqrt(2 - 2 sim(a, b))`, which is the
//! Euclidean distance between unit vectors. The same embedding buys more:
//! Euclidean spaces are *Ptolemaic*, i.e. for any four points
//!
//! ```text
//! d(x,y) * d(u,v) <= d(x,u) * d(y,v) + d(x,v) * d(y,u)
//! ```
//!
//! (products of opposite sides of the quadrilateral `x u y v`; Hetland,
//! "Ptolemaic Indexing"). Solving for `d(x,y)` with *two* reference points
//! `u, v` certifies an interval on `sim(x, y)` that is often strictly
//! tighter than intersecting the two per-pivot triangle (Mult) intervals —
//! extra pruning for free wherever two pivot similarities are already known
//! (LAESA's pivot table, an M-tree child route + its parent route).
//!
//! Substituting chords and writing `A^2 = (1 - s_xu)(1 - s_yv)`,
//! `B^2 = (1 - s_xv)(1 - s_yu)`, `C = 1 - s_uv` gives the sin-form pair
//! (mirroring the paper's Mult derivation, one shared square root):
//!
//! ```text
//! sim(x,y) >= 1 - (A^2 + B^2 + 2*sqrt(A^2*B^2)) / C      (= 1 - (A+B)^2/C)
//! sim(x,y) <= 1 - (A^2 + B^2 - 2*sqrt(A^2*B^2)) / C      (= 1 - (A-B)^2/C)
//! ```
//!
//! The lower bound is the direct Ptolemy inequality; the upper bound is the
//! permuted form `d(x,y) d(u,v) >= |d(x,u) d(y,v) - d(x,v) d(y,u)|`
//! (Ptolemy applied to the other two side pairings). Both are valid for
//! any four points of a Ptolemaic space, hence for any four unit vectors.
//!
//! The *fast* variant drops the remaining square root using
//! `(A + B)^2 <= 2 (A^2 + B^2)` and
//! `(A - B)^2 >= (A^2 - B^2)^2 / (2 (A^2 + B^2))`, trading tightness for a
//! fully polynomial evaluation — the same cost/tightness trade Table 1
//! makes for the triangle family.
//!
//! Degenerate pivots (`s_uv -> 1`, chord `C -> 0`) certify nothing: every
//! form returns the trivial interval instead of dividing by zero.

use super::SimInterval;

/// Below this pivot-pair chord (`1 - s_uv`) the quadrilateral collapses
/// and the bounds certify nothing; callers get the trivial interval.
const MIN_PAIR_CHORD: f64 = 1e-9;

/// Known similarities of query `x` and the pivot pair `(u, v)`.
///
/// These are the quantities available *before* a candidate is scored:
/// LAESA computes the query row against all pivots once per query, and the
/// pivot-pair similarity is a build-time constant.
#[derive(Debug, Clone, Copy)]
pub struct PairRefs {
    /// `sim(x, u)` — query to first pivot.
    pub s_xu: f64,
    /// `sim(x, v)` — query to second pivot.
    pub s_xv: f64,
    /// `sim(u, v)` — pivot to pivot (build-time constant).
    pub s_uv: f64,
}

impl PairRefs {
    #[inline]
    pub fn new(s_xu: f64, s_xv: f64, s_uv: f64) -> Self {
        PairRefs { s_xu, s_xv, s_uv }
    }

    /// `1 - s_uv`, the squared pivot-pair chord over 2.
    #[inline]
    fn c(&self) -> f64 {
        (1.0 - self.s_uv).max(0.0)
    }

    /// Squared cross terms `A^2 = (1-s_xu)(1-s_yv)`, `B^2 = (1-s_xv)(1-s_yu)`
    /// for a candidate `y` with known pivot similarities.
    #[inline]
    fn cross_sq(&self, s_yu: f64, s_yv: f64) -> (f64, f64) {
        let a2 = (1.0 - self.s_xu).max(0.0) * (1.0 - s_yv).max(0.0);
        let b2 = (1.0 - self.s_xv).max(0.0) * (1.0 - s_yu).max(0.0);
        (a2, b2)
    }

    /// Certified Ptolemaic interval on `sim(x, y)` given the candidate's
    /// similarities `s_yu = sim(y, u)`, `s_yv = sim(y, v)`. One square root.
    #[inline]
    pub fn interval(&self, s_yu: f64, s_yv: f64) -> SimInterval {
        let c = self.c();
        if c < MIN_PAIR_CHORD {
            return SimInterval::full();
        }
        let (a2, b2) = self.cross_sq(s_yu, s_yv);
        let r2 = 2.0 * (a2 * b2).sqrt();
        let sum = a2 + b2;
        SimInterval::new(1.0 - (sum + r2) / c, 1.0 - (sum - r2) / c)
    }

    /// Sqrt-free relaxation of [`PairRefs::interval`]: the lower bound uses
    /// `(A+B)^2 <= 2(A^2+B^2)`, the upper `(A-B)^2 >= (A^2-B^2)^2 /
    /// (2(A^2+B^2))`. Strictly contains the exact interval.
    #[inline]
    pub fn interval_fast(&self, s_yu: f64, s_yv: f64) -> SimInterval {
        let c = self.c();
        if c < MIN_PAIR_CHORD {
            return SimInterval::full();
        }
        let (a2, b2) = self.cross_sq(s_yu, s_yv);
        let sum = a2 + b2;
        let lo = 1.0 - 2.0 * sum / c;
        let hi = if sum > 0.0 {
            let diff = a2 - b2;
            1.0 - diff * diff / (2.0 * sum * c)
        } else {
            1.0 // x = u = v (or antipodal pivots hit by both): nothing known.
        };
        SimInterval::new(lo, hi)
    }

    /// Upper bound over a whole subtree: every `y` below the routing pair
    /// has `sim(y, u)` in `cover_u` and `sim(y, v)` in `cover_v`; the bound
    /// must dominate the per-point upper for every such `y`.
    ///
    /// `A^2` and `B^2` are monotone (decreasing) images of `s_yv` / `s_yu`,
    /// so they range over boxes; `max_y ub = 1 - min (A-B)^2 / C`, and the
    /// minimum of `(A-B)^2` over an axis box is 0 when the `A`- and
    /// `B`-ranges overlap (tested on the squared endpoints — sqrt is
    /// monotone) or the squared gap between the nearest endpoints otherwise.
    #[inline]
    pub fn upper_over(&self, cover_u: SimInterval, cover_v: SimInterval) -> f64 {
        let c = self.c();
        if c < MIN_PAIR_CHORD {
            return 1.0;
        }
        let (a2_lo, a2_hi, b2_lo, b2_hi) = self.cross_sq_boxes(cover_u, cover_v);
        if a2_lo <= b2_hi && b2_lo <= a2_hi {
            return 1.0; // A = B reachable: the quadrilateral can degenerate.
        }
        // Disjoint ranges: nearest endpoints carry the minimum gap.
        let (near_hi, near_lo) = if a2_lo > b2_hi { (a2_lo, b2_hi) } else { (b2_lo, a2_hi) };
        let gap_sq = near_hi + near_lo - 2.0 * (near_hi * near_lo).sqrt();
        (1.0 - gap_sq / c).min(1.0)
    }

    /// Lower bound over a whole subtree (see [`PairRefs::upper_over`]):
    /// `min_y lb = 1 - max (A+B)^2 / C`, maximized at both box tops.
    #[inline]
    pub fn lower_over(&self, cover_u: SimInterval, cover_v: SimInterval) -> f64 {
        let c = self.c();
        if c < MIN_PAIR_CHORD {
            return -1.0;
        }
        let (_, a2_hi, _, b2_hi) = self.cross_sq_boxes(cover_u, cover_v);
        let peak = a2_hi + b2_hi + 2.0 * (a2_hi * b2_hi).sqrt();
        (1.0 - peak / c).max(-1.0)
    }

    /// Sqrt-free subtree upper bound: minimum of the fast per-point upper
    /// over the box, via `min (A^2-B^2)^2` and `max (A^2+B^2)`.
    #[inline]
    pub fn upper_over_fast(&self, cover_u: SimInterval, cover_v: SimInterval) -> f64 {
        let c = self.c();
        if c < MIN_PAIR_CHORD {
            return 1.0;
        }
        let (a2_lo, a2_hi, b2_lo, b2_hi) = self.cross_sq_boxes(cover_u, cover_v);
        let sum_hi = a2_hi + b2_hi;
        if sum_hi <= 0.0 || (a2_lo <= b2_hi && b2_lo <= a2_hi) {
            return 1.0;
        }
        let min_diff = if a2_lo > b2_hi { a2_lo - b2_hi } else { b2_lo - a2_hi };
        (1.0 - min_diff * min_diff / (2.0 * sum_hi * c)).min(1.0)
    }

    /// Squared cross-term ranges over a subtree box: `1 - s` is decreasing,
    /// so the `hi` cover endpoint maps to the `lo` squared cross term.
    #[inline]
    fn cross_sq_boxes(
        &self,
        cover_u: SimInterval,
        cover_v: SimInterval,
    ) -> (f64, f64, f64, f64) {
        let xu = (1.0 - self.s_xu).max(0.0);
        let xv = (1.0 - self.s_xv).max(0.0);
        let a2_lo = xu * (1.0 - cover_v.hi).max(0.0);
        let a2_hi = xu * (1.0 - cover_v.lo).max(0.0);
        let b2_lo = xv * (1.0 - cover_u.hi).max(0.0);
        let b2_hi = xv * (1.0 - cover_u.lo).max(0.0);
        (a2_lo, a2_hi, b2_lo, b2_hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic xorshift sampler. The quadruples are drawn as *f64*
    /// unit vectors (not f32 `DenseVec`s): on low dimensions every
    /// quadruple is near-concyclic, Ptolemy approaches equality, and f32
    /// normalization error amplified by a small pivot chord would swamp a
    /// tight tolerance — the property under test is the derivation, not
    /// the storage precision.
    struct Rng(u64);
    impl Rng {
        fn next_f64(&mut self) -> f64 {
            self.0 ^= self.0 << 13;
            self.0 ^= self.0 >> 7;
            self.0 ^= self.0 << 17;
            (self.0 >> 11) as f64 / (1u64 << 53) as f64
        }

        /// Standard normal via Box-Muller.
        fn next_gauss(&mut self) -> f64 {
            let u1 = self.next_f64().max(1e-12);
            let u2 = self.next_f64();
            (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
        }

        fn unit(&mut self, dim: usize) -> Vec<f64> {
            let mut v: Vec<f64> = (0..dim).map(|_| self.next_gauss()).collect();
            let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-300);
            v.iter_mut().for_each(|x| *x /= norm);
            v
        }
    }

    fn dot(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| x * y).sum::<f64>().clamp(-1.0, 1.0)
    }

    fn quad_sims(dim: usize, seed: u64, n: usize) -> Vec<[f64; 6]> {
        // Draw unit-sphere quadruples (x, y, u, v) and record all six sims.
        let mut rng = Rng(seed);
        (0..n)
            .map(|_| {
                let (x, y, u, v) =
                    (rng.unit(dim), rng.unit(dim), rng.unit(dim), rng.unit(dim));
                [
                    dot(&x, &y),
                    dot(&x, &u),
                    dot(&x, &v),
                    dot(&y, &u),
                    dot(&y, &v),
                    dot(&u, &v),
                ]
            })
            .collect()
    }

    /// S4 property sweep: `lower <= sim(x,y) <= upper` for both variants on
    /// >= 10^4 random unit-sphere quadruples, across dimensions where the
    /// quadrilateral is near-degenerate (d=2: concyclic, Ptolemy equality)
    /// and generic (d=16).
    #[test]
    fn random_quadruples_respect_interval() {
        let mut cases = 0usize;
        for (dim, seed) in [(2, 41u64), (3, 42), (8, 43), (16, 44)] {
            for [sxy, sxu, sxv, syu, syv, suv] in quad_sims(dim, seed, 3000) {
                let refs = PairRefs::new(sxu, sxv, suv);
                let iv = refs.interval(syu, syv);
                assert!(
                    iv.lo <= sxy + 1e-7 && sxy <= iv.hi + 1e-7,
                    "exact: sim={sxy} outside [{}, {}] (d={dim})",
                    iv.lo,
                    iv.hi
                );
                let ivf = refs.interval_fast(syu, syv);
                assert!(
                    ivf.lo <= sxy + 1e-7 && sxy <= ivf.hi + 1e-7,
                    "fast: sim={sxy} outside [{}, {}] (d={dim})",
                    ivf.lo,
                    ivf.hi
                );
                // The fast interval is a relaxation of the exact one.
                assert!(ivf.lo <= iv.lo + 1e-9 && ivf.hi >= iv.hi - 1e-9);
                cases += 1;
            }
        }
        assert!(cases >= 10_000);
    }

    #[test]
    fn degenerate_pivot_pair_is_trivial() {
        let refs = PairRefs::new(0.3, 0.3, 1.0);
        let iv = refs.interval(0.5, 0.5);
        assert_eq!((iv.lo, iv.hi), (-1.0, 1.0));
        let ivf = refs.interval_fast(0.5, 0.5);
        assert_eq!((ivf.lo, ivf.hi), (-1.0, 1.0));
        assert_eq!(refs.upper_over(SimInterval::full(), SimInterval::full()), 1.0);
        assert_eq!(refs.lower_over(SimInterval::full(), SimInterval::full()), -1.0);
    }

    #[test]
    fn coincident_query_and_pivot_pins_value() {
        // x = u: A^2 = 0, so the interval collapses onto sim(y, v)-driven
        // bounds; with y = v too it must pin sim(x,y) = s_uv ... = s_xv.
        let refs = PairRefs::new(1.0, 0.2, 0.2);
        let iv = refs.interval(0.2, 1.0);
        assert!(iv.lo <= 0.2 + 1e-12 && 0.2 <= iv.hi + 1e-12);
        assert!(iv.hi - iv.lo < 1e-9, "exact quadrilateral must pin: {iv:?}");
    }

    /// Over-box forms dominate the per-point forms for every (s_yu, s_yv)
    /// inside the covers — the subtree-pruning soundness obligation.
    #[test]
    fn over_box_dominates_pointwise() {
        let mut rng = Rng(0x9e3779b97f4a7c15);
        for _ in 0..2000 {
            let r = |rng: &mut Rng| 2.0 * rng.next_f64() - 1.0;
            let refs = PairRefs::new(r(&mut rng), r(&mut rng), r(&mut rng) * 0.999);
            let (a, b) = (r(&mut rng), r(&mut rng));
            let cover_u = SimInterval::new(a.min(b), a.max(b));
            let (a, b) = (r(&mut rng), r(&mut rng));
            let cover_v = SimInterval::new(a.min(b), a.max(b));
            let ub = refs.upper_over(cover_u, cover_v);
            let ubf = refs.upper_over_fast(cover_u, cover_v);
            let lb = refs.lower_over(cover_u, cover_v);
            for i in 0..=8 {
                for j in 0..=8 {
                    let syu = cover_u.lo + (cover_u.hi - cover_u.lo) * i as f64 / 8.0;
                    let syv = cover_v.lo + (cover_v.hi - cover_v.lo) * j as f64 / 8.0;
                    let iv = refs.interval(syu, syv);
                    assert!(ub >= iv.hi - 1e-9, "ub_over {ub} < point {}", iv.hi);
                    assert!(lb <= iv.lo + 1e-9, "lb_over {lb} > point {}", iv.lo);
                    let ivf = refs.interval_fast(syu, syv);
                    assert!(ubf >= ivf.hi - 1e-9, "fast ub_over {ubf} < point {}", ivf.hi);
                }
            }
        }
    }

    #[test]
    fn point_covers_reduce_to_pointwise() {
        let refs = PairRefs::new(0.4, -0.2, 0.1);
        let (syu, syv) = (0.3, -0.5);
        let iv = refs.interval(syu, syv);
        let ub = refs.upper_over(SimInterval::point(syu), SimInterval::point(syv));
        let lb = refs.lower_over(SimInterval::point(syu), SimInterval::point(syv));
        assert!((ub - iv.hi).abs() < 1e-12 && (lb - iv.lo).abs() < 1e-12);
    }
}
