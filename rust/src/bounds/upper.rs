//! Upper bounds on `sim(x, y)` — the paper's "opposite direction" (§3.1).
//!
//! For similarity search these are the *pruning* direction: a range query
//! `sim(q, y) >= tau` can discard `y` (or a whole subtree) whenever an upper
//! bound falls below `tau`, and a kNN search whenever it falls below the
//! current k-th best similarity.

/// Eq. 13: the recommended tight upper bound,
/// `s1*s2 + sqrt((1 - s1^2)(1 - s2^2))` = `cos(arccos s1 - arccos s2)`.
#[inline(always)]
pub fn ub_mult(s1: f64, s2: f64) -> f64 {
    s1 * s2 + (((1.0 - s1 * s1) * (1.0 - s2 * s2)).max(0.0)).sqrt()
}

/// Trig form of Eq. 13 (the §3.1 derivation before simplification).
#[inline(always)]
pub fn ub_arccos(s1: f64, s2: f64) -> f64 {
    (s1.clamp(-1.0, 1.0).acos() - s2.clamp(-1.0, 1.0).acos()).cos()
}

/// Eq. 13 evaluated with [`crate::bounds::fast_arccos`] — the upper-side
/// counterpart of [`crate::bounds::lb_arccos_fast`], so the ArccosFast kind
/// is fast-math in *both* pruning directions instead of silently borrowing
/// the exact [`ub_mult`].
///
/// Validity mirrors the lower form: the polynomial errs by at most
/// ~1.27e-4 rad per call and `cos` is 1-Lipschitz, so adding the summed
/// worst-case angle error keeps this an over-estimate of
/// `cos(arccos s1 - arccos s2)` on both monotone branches.
#[inline(always)]
pub fn ub_arccos_fast(s1: f64, s2: f64) -> f64 {
    use crate::bounds::lower::fast_arccos;
    const ERR: f64 = 2.6e-4; // 2 * max poly error (1.27e-4 rad each)
    (fast_arccos(s1.clamp(-1.0, 1.0)) - fast_arccos(s2.clamp(-1.0, 1.0))).cos() + ERR
}

/// Upper bound via the Euclidean metric on the sphere: from
/// `d(x,y) >= |d(x,z) - d(z,y)|` with `d = sqrt(2 - 2 sim)`,
/// `sim(x,y) <= s1 + s2 - 1 + 2 sqrt((1-s1)(1-s2))` — the mirror of Eq. 7.
#[inline(always)]
pub fn ub_euclidean(s1: f64, s2: f64) -> f64 {
    s1 + s2 - 1.0 + 2.0 * ((1.0 - s1).max(0.0) * (1.0 - s2).max(0.0)).sqrt()
}

/// Sqrt-free relaxation of [`ub_euclidean`] mirroring Eq. 8's construction:
/// `sqrt((1-s1)(1-s2)) <= 1 - min(s1, s2)` (both factors in `[0, 2]`).
#[inline(always)]
pub fn ub_eucl_ub(s1: f64, s2: f64) -> f64 {
    s1 + s2 - 1.0 + 2.0 * (1.0 - s1.min(s2))
}

/// Sqrt-free relaxation of Eq. 13 mirroring Eq. 11's construction:
/// the radical is over-approximated by `1 - min(s1^2, s2^2)`.
#[inline(always)]
pub fn ub_mult_ub1(s1: f64, s2: f64) -> f64 {
    s1 * s2 + 1.0 - (s1 * s1).min(s2 * s2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::lower::lb_mult;

    fn grid() -> Vec<f64> {
        (0..=80).map(|i| -1.0 + i as f64 / 40.0).collect()
    }

    #[test]
    fn ub_mult_equals_trig_form() {
        for &s1 in &grid() {
            for &s2 in &grid() {
                assert!((ub_mult(s1, s2) - ub_arccos(s1, s2)).abs() < 5e-15);
            }
        }
    }

    #[test]
    fn relaxations_dominate_tight_upper() {
        for &s1 in &grid() {
            for &s2 in &grid() {
                let tight = ub_mult(s1, s2);
                assert!(ub_euclidean(s1, s2) >= tight - 1e-12);
                assert!(ub_eucl_ub(s1, s2) >= ub_euclidean(s1, s2) - 1e-12);
                assert!(ub_mult_ub1(s1, s2) >= tight - 1e-12);
            }
        }
    }

    #[test]
    fn fast_arccos_upper_is_conservative_and_close() {
        // ub_arccos_fast must dominate the true tight upper bound (it is a
        // pruning upper bound) while staying within the documented error
        // budget of it — fast-math, not a different bound.
        for &s1 in &grid() {
            for &s2 in &grid() {
                let tight = ub_mult(s1, s2);
                let fast = ub_arccos_fast(s1, s2);
                assert!(fast >= tight - 1e-12, "fast {fast} < tight {tight} at ({s1}, {s2})");
                assert!(fast <= tight + 6e-4, "fast {fast} too loose at ({s1}, {s2})");
            }
        }
    }

    #[test]
    fn symmetric_error_band_around_product() {
        // §3.1: |sim(x,y) - s1*s2| <= radical, i.e. ub - lb = 2 * radical
        // and both are symmetric around the product.
        for &s1 in &grid() {
            for &s2 in &grid() {
                let mid = 0.5 * (ub_mult(s1, s2) + lb_mult(s1, s2));
                assert!((mid - s1 * s2).abs() < 1e-14);
            }
        }
    }

    #[test]
    fn identical_reference_pins_value() {
        // s1 = 1 => x = z => sim(x,y) = s2 exactly, from both sides.
        assert!((ub_mult(1.0, -0.4) - (-0.4)).abs() < 1e-12);
        assert!((lb_mult(1.0, -0.4) - (-0.4)).abs() < 1e-12);
    }
}
