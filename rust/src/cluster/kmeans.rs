//! Bound-accelerated spherical k-means (see module docs in `mod.rs`).

use crate::bounds::ub_mult;
use crate::metrics::{DenseVec, SimVector};
use crate::util::Rng;

/// Configuration for [`spherical_kmeans`].
#[derive(Debug, Clone)]
pub struct KMeansConfig {
    pub k: usize,
    pub max_iters: usize,
    /// Stop when fewer than this fraction of points change assignment.
    pub tol_moved: f64,
    pub seed: u64,
    /// Enable the Eq. 10/13 prunings (off = plain Lloyd, for ablation).
    pub use_bounds: bool,
}

impl Default for KMeansConfig {
    fn default() -> Self {
        KMeansConfig { k: 8, max_iters: 50, tol_moved: 0.001, seed: 42, use_bounds: true }
    }
}

/// Clustering output + instrumentation.
#[derive(Debug, Clone)]
pub struct KMeansResult {
    pub assignment: Vec<u32>,
    pub centroids: Vec<DenseVec>,
    /// Mean similarity of points to their centroid (objective; maximize).
    pub objective: f64,
    pub iterations: usize,
    /// Exact similarity evaluations spent in assignment steps.
    pub sim_evals: u64,
    /// Candidate centroids skipped by Eq. 13 (center-center pruning).
    pub pruned_centers: u64,
    /// Points whose assignment was certified unchanged by drift chaining.
    pub skipped_points: u64,
}

fn mean_direction(points: &[DenseVec], members: &[u32], d: usize) -> Option<DenseVec> {
    if members.is_empty() {
        return None;
    }
    let mut acc = vec![0.0f64; d];
    for &i in members {
        for (a, &v) in acc.iter_mut().zip(points[i as usize].as_slice()) {
            *a += v as f64;
        }
    }
    let v: Vec<f32> = acc.iter().map(|&a| a as f32).collect();
    let out = DenseVec::new(v);
    // Degenerate (sum ~ 0): signal caller to reseed.
    if out.as_slice().iter().all(|&x| x == 0.0) {
        None
    } else {
        Some(out)
    }
}

/// Spherical k-means with Eq. 10/13 acceleration.
///
/// Assignments are identical to plain Lloyd's at every iteration (the
/// prunings are exact), so `use_bounds` changes only `sim_evals`, never the
/// result — a property the tests assert.
pub fn spherical_kmeans(points: &[DenseVec], config: &KMeansConfig) -> KMeansResult {
    let n = points.len();
    let k = config.k.min(n).max(1);
    let d = points.first().map(|p| p.len()).unwrap_or(0);
    let mut rng = Rng::seed_from_u64(config.seed);

    // k-means++-style seeding in similarity space: first centroid random,
    // each next one sampled proportional to (1 - max sim to chosen).
    let mut centroids: Vec<DenseVec> = Vec::with_capacity(k);
    centroids.push(points[rng.below(n)].clone());
    let mut best_sim: Vec<f64> = points.iter().map(|p| p.sim(&centroids[0])).collect();
    let mut sim_evals = n as u64;
    while centroids.len() < k {
        let weights: Vec<f64> = best_sim.iter().map(|&s| (1.0 - s).max(1e-12)).collect();
        let total: f64 = weights.iter().sum();
        let mut pick = rng.f64() * total;
        let mut chosen = n - 1;
        for (i, w) in weights.iter().enumerate() {
            pick -= w;
            if pick <= 0.0 {
                chosen = i;
                break;
            }
        }
        let c = points[chosen].clone();
        for (i, p) in points.iter().enumerate() {
            let s = p.sim(&c);
            if s > best_sim[i] {
                best_sim[i] = s;
            }
        }
        sim_evals += n as u64;
        centroids.push(c);
    }

    let mut assignment: Vec<u32> = vec![0; n];
    // Certified interval on sim(x, c_assigned) carried between iterations.
    let mut lb_assigned: Vec<f64> = vec![-1.0; n];
    let mut ub_others: Vec<f64> = vec![1.0; n]; // upper bound on best rival sim
    let mut pruned_centers = 0u64;
    let mut skipped_points = 0u64;
    let mut iterations = 0;

    for iter in 0..config.max_iters {
        iterations = iter + 1;
        // Centroid-centroid similarity table (k^2, cheap next to n*k).
        let cc: Vec<f64> = (0..k * k)
            .map(|ij| centroids[ij / k].sim(&centroids[ij % k]))
            .collect();

        let mut moved = 0usize;
        for i in 0..n {
            // Drift chaining: if the certified lower bound on the assigned
            // centroid still beats the certified upper bound on every
            // rival, the assignment provably cannot change.
            if config.use_bounds && iter > 0 && lb_assigned[i] >= ub_others[i] {
                skipped_points += 1;
                continue;
            }
            let p = &points[i];
            let mut best = assignment[i] as usize;
            let mut s_best = p.sim(&centroids[best]);
            sim_evals += 1;
            let mut second = -1.0f64;
            for j in 0..k {
                if j == best {
                    continue;
                }
                if config.use_bounds {
                    // Eq. 13 with z = current best centroid.
                    let cap = ub_mult(s_best, cc[best * k + j]);
                    if cap <= s_best {
                        pruned_centers += 1;
                        second = second.max(cap);
                        continue;
                    }
                }
                let s = p.sim(&centroids[j]);
                sim_evals += 1;
                if s > s_best {
                    second = second.max(s_best);
                    s_best = s;
                    best = j;
                } else {
                    second = second.max(s);
                }
            }
            if best != assignment[i] as usize {
                moved += 1;
                assignment[i] = best as u32;
            }
            lb_assigned[i] = s_best;
            ub_others[i] = second;
        }

        // Update step.
        let mut members: Vec<Vec<u32>> = vec![Vec::new(); k];
        for (i, &a) in assignment.iter().enumerate() {
            members[a as usize].push(i as u32);
        }
        let mut drift: Vec<f64> = Vec::with_capacity(k); // sim(c_old, c_new)
        for j in 0..k {
            match mean_direction(points, &members[j], d) {
                Some(new_c) => {
                    drift.push(centroids[j].sim(&new_c));
                    centroids[j] = new_c;
                }
                None => {
                    // Empty/degenerate cluster: reseed on a random point.
                    centroids[j] = points[rng.below(n)].clone();
                    drift.push(-1.0); // no certificate survives a reseed
                }
            }
        }
        // Re-chain the carried bounds through the drift with the interval
        // primitives (the raw Eq. 10/13 forms are not monotone in the
        // carried argument, so certified-interval propagation is the only
        // valid way to chain a *bound* rather than an exact similarity):
        //   sim(x, c_new) >= lower_over(drift_a, [lb, 1])
        //   rival sims    <= upper_over(min rival drift, [-1, ub])
        if config.use_bounds {
            use crate::bounds::{BoundKind, SimInterval};
            // Smallest drift among all centroids (conservative scalar for
            // the rival side keeps the pass O(n + k)).
            for i in 0..n {
                let a = assignment[i] as usize;
                lb_assigned[i] = BoundKind::Mult
                    .lower_over(drift[a], SimInterval::new(lb_assigned[i], 1.0));
                let mut worst = 1.0f64;
                for (j, &dj) in drift.iter().enumerate() {
                    if j != a {
                        worst = worst.min(dj);
                    }
                }
                ub_others[i] = BoundKind::Mult
                    .upper_over(worst, SimInterval::new(-1.0, ub_others[i]));
            }
        }

        if (moved as f64) < config.tol_moved * n as f64 && iter > 0 {
            break;
        }
    }

    let mut objective = 0.0;
    for (i, &a) in assignment.iter().enumerate() {
        objective += points[i].sim(&centroids[a as usize]);
    }
    objective /= n.max(1) as f64;

    KMeansResult {
        assignment,
        centroids,
        objective,
        iterations,
        sim_evals,
        pruned_centers,
        skipped_points,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{vmf_mixture, VmfSpec};

    fn clustered(n: usize, k: usize) -> (Vec<DenseVec>, Vec<u32>) {
        vmf_mixture(&VmfSpec { n, dim: 16, clusters: k, kappa: 120.0, seed: 31 })
    }

    #[test]
    fn bounded_and_plain_agree() {
        let (pts, _) = clustered(2000, 8);
        let base = KMeansConfig { k: 8, seed: 7, ..Default::default() };
        let plain = spherical_kmeans(&pts, &KMeansConfig { use_bounds: false, ..base.clone() });
        let fast = spherical_kmeans(&pts, &KMeansConfig { use_bounds: true, ..base });
        // The prunings are exact: identical assignments and objective.
        assert_eq!(plain.assignment, fast.assignment);
        assert!((plain.objective - fast.objective).abs() < 1e-12);
        // And the bounds must actually save work on clustered data.
        assert!(
            fast.sim_evals < plain.sim_evals,
            "no savings: {} vs {}",
            fast.sim_evals,
            plain.sim_evals
        );
        assert!(fast.pruned_centers > 0);
    }

    #[test]
    fn recovers_planted_clusters() {
        let (pts, labels) = clustered(1500, 5);
        let res = spherical_kmeans(&pts, &KMeansConfig { k: 5, ..Default::default() });
        assert!(res.objective > 0.85, "objective {}", res.objective);
        // Clustering accuracy via majority-label purity.
        let mut purity = 0usize;
        for c in 0..5u32 {
            let mut counts = [0usize; 5];
            for i in 0..pts.len() {
                if res.assignment[i] == c {
                    counts[labels[i] as usize] += 1;
                }
            }
            purity += counts.iter().max().unwrap();
        }
        assert!(purity as f64 / pts.len() as f64 > 0.9, "purity {purity}");
    }

    #[test]
    fn objective_nondecreasing_over_restarts_of_same_seed() {
        let (pts, _) = clustered(800, 4);
        let a = spherical_kmeans(&pts, &KMeansConfig { k: 4, seed: 3, ..Default::default() });
        let b = spherical_kmeans(&pts, &KMeansConfig { k: 4, seed: 3, ..Default::default() });
        assert_eq!(a.assignment, b.assignment); // deterministic
        assert!((a.objective - b.objective).abs() < 1e-12);
    }

    #[test]
    fn handles_k_greater_than_n_and_tiny_inputs() {
        let pts = vec![
            DenseVec::new(vec![1.0, 0.0]),
            DenseVec::new(vec![0.0, 1.0]),
        ];
        let res = spherical_kmeans(&pts, &KMeansConfig { k: 8, ..Default::default() });
        assert_eq!(res.assignment.len(), 2);
        assert!(res.objective > 0.99); // each point gets its own centroid
    }

    #[test]
    fn duplicate_points_are_fine() {
        let pts = vec![DenseVec::new(vec![0.6, 0.8]); 50];
        let res = spherical_kmeans(&pts, &KMeansConfig { k: 3, ..Default::default() });
        assert!((res.objective - 1.0).abs() < 1e-6);
    }
}
