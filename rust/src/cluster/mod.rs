//! Spherical k-means accelerated by the paper's triangle inequality —
//! the "acceleration of data mining algorithms" the paper's conclusion
//! anticipates, in the style of Elkan (2003) but natively in the
//! similarity domain.
//!
//! Lloyd's algorithm on the unit sphere assigns each point to its most
//! *similar* centroid. The expensive part is the assignment step:
//! `n * k` similarity evaluations per iteration. Two bound-based prunings
//! cut this down, both direct applications of Eqs. 10/13 with a centroid
//! as the reference point `z`:
//!
//! 1. **Center-center pruning** (Elkan's lemma, cosine form): knowing
//!    `s_a = sim(x, c_a)` for the current best centroid and the
//!    centroid-centroid similarity `sim(c_a, c_j)`,
//!    `sim(x, c_j) <= ub_mult(s_a, sim(c_a, c_j))` — if that is at most
//!    `s_a`, centroid `c_j` cannot win and is skipped with no evaluation.
//! 2. **Drift chaining**: after centroids move, last iteration's exact
//!    `sim(x, c_old)` becomes the certified interval
//!    `[lb_mult, ub_mult](sim(x, c_old), sim(c_old, c_new))` on
//!    `sim(x, c_new)` — points whose interval proves their assignment
//!    unchanged skip the assignment search entirely.

pub mod kmeans;

pub use kmeans::{spherical_kmeans, KMeansConfig, KMeansResult};
