//! Dynamic batching: collect queries until `max_batch` or `max_wait`,
//! whichever first — the standard serving trade-off between batching
//! efficiency (the PJRT artifact amortizes over the padded batch) and
//! tail latency. Thread-based (this offline build has no async runtime):
//! one collector thread owns the queue; per-request replies travel over
//! rendezvous channels.

use std::sync::mpsc;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatchConfig {
    /// Flush when this many queries are waiting.
    pub max_batch: usize,
    /// Flush when the oldest waiting query has waited this long.
    pub max_wait: Duration,
    /// Bounded queue depth — submitters block when full (backpressure).
    pub queue_depth: usize,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig { max_batch: 32, max_wait: Duration::from_millis(2), queue_depth: 1024 }
    }
}

/// A queued unit of work with its reply channel.
pub struct Job<Q, R> {
    pub query: Q,
    pub reply: mpsc::SyncSender<R>,
    pub enqueued: Instant,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchError {
    /// The batch loop has shut down.
    Closed,
    /// The batch loop dropped the reply (worker panic / overload shed).
    Dropped,
}

impl std::fmt::Display for BatchError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BatchError::Closed => write!(f, "batcher closed"),
            BatchError::Dropped => write!(f, "reply dropped"),
        }
    }
}

impl std::error::Error for BatchError {}

/// Submit side: shareable across threads.
pub struct BatchSubmitter<Q, R> {
    tx: Mutex<mpsc::SyncSender<Job<Q, R>>>,
}

impl<Q: Send + 'static, R: Send + 'static> BatchSubmitter<Q, R> {
    /// Submit one query and block for its result. Applies backpressure when
    /// the queue is full; errors only if the batch loop is gone.
    pub fn submit(&self, query: Q) -> Result<R, BatchError> {
        let (reply, rx) = mpsc::sync_channel(1);
        {
            let tx = self.tx.lock().map_err(|_| BatchError::Closed)?;
            tx.send(Job { query, reply, enqueued: Instant::now() })
                .map_err(|_| BatchError::Closed)?;
        }
        rx.recv().map_err(|_| BatchError::Dropped)
    }
}

/// Spawn the batch loop: `handler` receives full batches on the collector
/// thread. Returns the submitter; the loop ends when the submitter drops.
/// The handler is `FnMut` — it runs on the one collector thread, so it can
/// own mutable per-worker state (the coordinator parks a reusable
/// `query::QueryContext` there, ADR-004).
pub fn spawn_batcher<Q, R, F>(config: BatchConfig, mut handler: F) -> BatchSubmitter<Q, R>
where
    Q: Send + 'static,
    R: Send + 'static,
    F: FnMut(Vec<Job<Q, R>>) + Send + 'static,
{
    let (tx, rx) = mpsc::sync_channel::<Job<Q, R>>(config.queue_depth.max(1));
    std::thread::Builder::new()
        .name("simetra-batcher".into())
        .spawn(move || {
            let mut pending: Vec<Job<Q, R>> = Vec::with_capacity(config.max_batch);
            loop {
                // Wait for the first job of the batch (or shutdown).
                let first = match rx.recv() {
                    Ok(job) => job,
                    Err(_) => break,
                };
                pending.push(first);
                // Drain whatever is already queued (no waiting): under
                // sustained load the backlog fills batches immediately.
                while pending.len() < config.max_batch {
                    match rx.try_recv() {
                        Ok(job) => pending.push(job),
                        Err(_) => break,
                    }
                }
                // Then wait up to max_wait (measured from now — if the
                // previous batch took long, the clock must not have already
                // expired or batching degrades to size 1 under load).
                let deadline = Instant::now() + config.max_wait;
                while pending.len() < config.max_batch {
                    let remaining = deadline.saturating_duration_since(Instant::now());
                    if remaining.is_zero() {
                        break;
                    }
                    match rx.recv_timeout(remaining) {
                        Ok(job) => pending.push(job),
                        Err(mpsc::RecvTimeoutError::Timeout) => break,
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                }
                handler(std::mem::take(&mut pending));
            }
            if !pending.is_empty() {
                handler(pending);
            }
        })
        .expect("spawn batcher thread");
    BatchSubmitter { tx: Mutex::new(tx) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sync::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn batches_fill_to_max_batch() {
        let sizes = Arc::new(Mutex::new(Vec::new()));
        let s2 = sizes.clone();
        let sub: Arc<BatchSubmitter<u32, u32>> = Arc::new(spawn_batcher(
            BatchConfig { max_batch: 4, max_wait: Duration::from_millis(100), queue_depth: 64 },
            move |jobs| {
                s2.lock().unwrap().push(jobs.len());
                for j in jobs {
                    let q = j.query;
                    let _ = j.reply.send(q * 2);
                }
            },
        ));
        let mut handles = Vec::new();
        for i in 0..8u32 {
            let sub = sub.clone();
            handles.push(std::thread::spawn(move || sub.submit(i).unwrap()));
        }
        for (i, h) in handles.into_iter().enumerate() {
            assert_eq!(h.join().unwrap(), i as u32 * 2);
        }
        let sizes = sizes.lock().unwrap();
        assert_eq!(sizes.iter().sum::<usize>(), 8);
        assert!(sizes.iter().all(|&s| s <= 4));
    }

    #[test]
    fn flushes_on_timeout() {
        let sub: BatchSubmitter<u32, u32> = spawn_batcher(
            BatchConfig { max_batch: 100, max_wait: Duration::from_millis(5), queue_depth: 16 },
            |jobs| {
                for j in jobs {
                    let q = j.query;
                    let _ = j.reply.send(q + 1);
                }
            },
        );
        // A single query must not wait for a full batch.
        let start = Instant::now();
        assert_eq!(sub.submit(41).unwrap(), 42);
        assert!(start.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn no_job_is_lost_under_load() {
        let count = Arc::new(AtomicUsize::new(0));
        let c2 = count.clone();
        let sub: Arc<BatchSubmitter<u32, u32>> = Arc::new(spawn_batcher(
            BatchConfig { max_batch: 7, max_wait: Duration::from_millis(1), queue_depth: 8 },
            move |jobs| {
                c2.fetch_add(jobs.len(), Ordering::SeqCst);
                for j in jobs {
                    let q = j.query;
                    let _ = j.reply.send(q);
                }
            },
        ));
        let mut handles = Vec::new();
        for i in 0..200u32 {
            let sub = sub.clone();
            handles.push(std::thread::spawn(move || sub.submit(i).unwrap()));
        }
        for (i, h) in handles.into_iter().enumerate() {
            assert_eq!(h.join().unwrap(), i as u32);
        }
        assert_eq!(count.load(Ordering::SeqCst), 200);
    }
}
