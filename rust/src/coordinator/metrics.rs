//! Lock-free serving metrics: counters + a fixed-bucket latency histogram.

use std::sync::atomic::{AtomicU64, Ordering};

use super::protocol::StatsSnapshot;

/// Exponential histogram buckets in microseconds: 1us .. ~17s.
const BUCKETS: usize = 48;

/// Serving metrics, cheap enough for the per-request hot path.
#[derive(Debug, Default)]
pub struct Metrics {
    pub queries: AtomicU64,
    pub batches: AtomicU64,
    pub errors: AtomicU64,
    pub sim_evals: AtomicU64,
    pub engine_calls: AtomicU64,
    pub pruned: AtomicU64,
    latency: LatencyHistogram,
}

#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; BUCKETS],
    max_us: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            max_us: AtomicU64::new(0),
        }
    }
}

impl LatencyHistogram {
    #[inline]
    fn bucket_of(us: u64) -> usize {
        // One bucket per octave: bucket i holds [2^(i-1), 2^i).
        ((64 - (us + 1).leading_zeros()) as usize).min(BUCKETS - 1)
    }

    pub fn record(&self, us: u64) {
        self.buckets[Self::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// Approximate percentile (upper edge of the containing bucket).
    pub fn percentile(&self, p: f64) -> u64 {
        let counts: Vec<u64> =
            self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * p).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                // Upper edge of bucket i.
                return 1u64 << i.min(63);
            }
        }
        self.max_us.load(Ordering::Relaxed)
    }
}

impl Metrics {
    pub fn record_latency_us(&self, us: u64) {
        self.latency.record(us);
    }

    pub fn snapshot(&self, corpus_size: u64, shards: u64) -> StatsSnapshot {
        StatsSnapshot {
            queries: self.queries.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            corpus_size,
            shards,
            sim_evals: self.sim_evals.load(Ordering::Relaxed),
            engine_calls: self.engine_calls.load(Ordering::Relaxed),
            pruned: self.pruned.load(Ordering::Relaxed),
            latency_us_p50: self.latency.percentile(0.50),
            latency_us_p99: self.latency.percentile(0.99),
            latency_us_max: self.latency.max_us.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles_are_monotone() {
        let h = LatencyHistogram::default();
        for us in [1u64, 5, 10, 50, 100, 500, 1000, 5000, 10_000] {
            for _ in 0..10 {
                h.record(us);
            }
        }
        let p50 = h.percentile(0.5);
        let p99 = h.percentile(0.99);
        assert!(p50 <= p99, "p50={p50} p99={p99}");
        assert!(p50 >= 10, "p50={p50}");
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::default();
        assert_eq!(h.percentile(0.5), 0);
    }

    #[test]
    fn snapshot_reflects_counters() {
        let m = Metrics::default();
        m.queries.fetch_add(3, Ordering::Relaxed);
        m.record_latency_us(120);
        let s = m.snapshot(100, 2);
        assert_eq!(s.queries, 3);
        assert_eq!(s.corpus_size, 100);
        assert_eq!(s.shards, 2);
        assert!(s.latency_us_max >= 120);
    }
}
