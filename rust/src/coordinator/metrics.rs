//! Lock-free serving metrics: counters + a fixed-bucket latency histogram,
//! plus the ingest gauges (generations, memtable, tombstones, sealed
//! bytes) when the coordinator serves a mutable corpus.

use crate::ingest::IngestStats;
use crate::storage::KernelBackend;
use crate::sync::{AtomicU64, Ordering};

use super::protocol::StatsSnapshot;

/// Exponential histogram buckets in microseconds: sub-1us .. ~17s.
const BUCKETS: usize = 48;

/// Serving metrics, cheap enough for the per-request hot path.
#[derive(Debug, Default)]
pub struct Metrics {
    pub queries: AtomicU64,
    pub batches: AtomicU64,
    pub errors: AtomicU64,
    pub sim_evals: AtomicU64,
    pub engine_calls: AtomicU64,
    /// Candidates (subtrees / regions / pivot-table rows) discarded by a
    /// certified bound without an exact evaluation, aggregated from every
    /// worker's per-query [`crate::index::QueryStats`] (ADR-004).
    pub pruned: AtomicU64,
    /// Tree nodes / pivot tables visited, aggregated like `pruned`.
    pub nodes_visited: AtomicU64,
    /// Queries answered on a previously-used worker `QueryContext` — the
    /// scratch-arena hit rate (steady state: every query but each worker's
    /// first).
    pub ctx_reuses: AtomicU64,
    /// Wire bytes read off client sockets (request lines, ADR-008).
    pub bytes_in: AtomicU64,
    /// Wire bytes flushed back to client sockets (response lines).
    pub bytes_out: AtomicU64,
    /// Connections currently open against the worker pool (gauge).
    pub conns_live: AtomicU64,
    /// Connections parked in the pool's run queue waiting for a worker
    /// turn (gauge; live - queued connections are being served right now).
    pub conns_queued: AtomicU64,
    latency: LatencyHistogram,
}

#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; BUCKETS],
    max_us: AtomicU64,
    sum_us: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            max_us: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }
}

impl LatencyHistogram {
    /// Bucket edges `[0, 1, 2, 4, 8, ...)`: bucket 0 holds exactly 0us
    /// (sub-microsecond ops), bucket `i >= 1` holds `[2^(i-1), 2^i)`.
    #[inline]
    fn bucket_of(us: u64) -> usize {
        // Bit width of `us`: 0 -> 0, 1 -> 1, [2,4) -> 2, [4,8) -> 3, ...
        // (The old `us + 1` form shifted everything up one bucket and made
        // bucket 0 unreachable.)
        ((64 - us.leading_zeros()) as usize).min(BUCKETS - 1)
    }

    pub fn record(&self, us: u64) {
        self.buckets[Self::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Counts per bucket, loaded into a caller-provided fixed array — no
    /// heap traffic on the stats path (ADR-004 discipline extends to
    /// metrics reads, not just the query hot path).
    fn load_counts(&self) -> [u64; BUCKETS] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }

    /// Approximate percentile (upper edge of the containing bucket).
    pub fn percentile(&self, p: f64) -> u64 {
        let counts = self.load_counts();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * p).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                // Upper edge of bucket i (bucket 0 holds only 0us).
                return if i == 0 { 0 } else { 1u64 << i.min(63) };
            }
        }
        self.max_us.load(Ordering::Relaxed)
    }
}

impl Metrics {
    pub fn record_latency_us(&self, us: u64) {
        self.latency.record(us);
    }

    /// Point-in-time snapshot. `ingest` carries the mutable-corpus gauges
    /// and counters when the coordinator serves one (`None` for the
    /// build-once path: those fields report zero). `kernel` is the corpus's
    /// active backend: its name and scan/re-rank counters are reported
    /// alongside the serving metrics.
    pub fn snapshot(
        &self,
        corpus_size: u64,
        shards: u64,
        ingest: Option<&IngestStats>,
        kernel: &dyn KernelBackend,
    ) -> StatsSnapshot {
        let ing = ingest.copied().unwrap_or_default();
        let kc = kernel.counters();
        let sim_evals = self.sim_evals.load(Ordering::Relaxed);
        let pruned = self.pruned.load(Ordering::Relaxed);
        // Bound-tightness gauge: of all candidate decisions the indexes
        // made (prune by bound vs score exactly), the fraction resolved by
        // a bound. 0 on an idle server.
        let pruned_fraction = if pruned + sim_evals > 0 {
            pruned as f64 / (pruned + sim_evals) as f64
        } else {
            0.0
        };
        StatsSnapshot {
            kernel: kernel.kind().name().to_string(),
            blocked_scan_rows: kc.blocked_scan_rows(),
            quant_prefilter_rows: kc.quant_prefilter_rows(),
            quant_rerank_rows: kc.quant_rerank_rows(),
            queries: self.queries.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            corpus_size,
            shards,
            sim_evals,
            engine_calls: self.engine_calls.load(Ordering::Relaxed),
            pruned,
            nodes_visited: self.nodes_visited.load(Ordering::Relaxed),
            ctx_reuses: self.ctx_reuses.load(Ordering::Relaxed),
            pruned_fraction,
            latency_us_p50: self.latency.percentile(0.50),
            latency_us_p99: self.latency.percentile(0.99),
            latency_us_max: self.max_latency_us(),
            latency_us_sum: self.latency.sum_us.load(Ordering::Relaxed),
            latency_us_buckets: self.latency.load_counts().to_vec(),
            generations: ing.generations,
            memtable_items: ing.memtable_items,
            tombstones: ing.tombstones,
            sealed_bytes: ing.sealed_bytes,
            inserts: ing.inserts,
            deletes: ing.deletes,
            seals: ing.seals,
            compactions: ing.compactions,
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
            conns_live: self.conns_live.load(Ordering::Relaxed),
            conns_queued: self.conns_queued.load(Ordering::Relaxed),
        }
    }

    fn max_latency_us(&self) -> u64 {
        self.latency.max_us.load(Ordering::Relaxed)
    }
}

/// Render a [`StatsSnapshot`] as Prometheus text-format families — the
/// serving half of the exposition surface. The observability registry
/// (`crate::obs::ObsRegistry::render_into`) appends its families after
/// this, so the `metrics` wire op and `simetra stats --prometheus` share
/// one snapshot path with the `stats` op.
pub fn render_prometheus(s: &StatsSnapshot, out: &mut String) {
    use std::fmt::Write;
    let counters: [(&str, u64); 17] = [
        ("simetra_queries_total", s.queries),
        ("simetra_batches_total", s.batches),
        ("simetra_errors_total", s.errors),
        ("simetra_sim_evals_total", s.sim_evals),
        ("simetra_engine_calls_total", s.engine_calls),
        ("simetra_pruned_total", s.pruned),
        ("simetra_nodes_visited_total", s.nodes_visited),
        ("simetra_ctx_reuses_total", s.ctx_reuses),
        ("simetra_inserts_total", s.inserts),
        ("simetra_deletes_total", s.deletes),
        ("simetra_seals_total", s.seals),
        ("simetra_compactions_total", s.compactions),
        ("simetra_blocked_scan_rows_total", s.blocked_scan_rows),
        ("simetra_quant_prefilter_rows_total", s.quant_prefilter_rows),
        ("simetra_quant_rerank_rows_total", s.quant_rerank_rows),
        ("simetra_bytes_in_total", s.bytes_in),
        ("simetra_bytes_out_total", s.bytes_out),
    ];
    for (name, v) in counters {
        let _ = writeln!(out, "# TYPE {name} counter");
        let _ = writeln!(out, "{name} {v}");
    }
    let gauges: [(&str, u64); 8] = [
        ("simetra_corpus_size", s.corpus_size),
        ("simetra_shards", s.shards),
        ("simetra_generations", s.generations),
        ("simetra_memtable_items", s.memtable_items),
        ("simetra_tombstones", s.tombstones),
        ("simetra_sealed_bytes", s.sealed_bytes),
        ("simetra_conns_live", s.conns_live),
        ("simetra_conns_queued", s.conns_queued),
    ];
    for (name, v) in gauges {
        let _ = writeln!(out, "# TYPE {name} gauge");
        let _ = writeln!(out, "{name} {v}");
    }
    let _ = writeln!(out, "# TYPE simetra_pruned_fraction gauge");
    let _ = writeln!(out, "simetra_pruned_fraction {}", s.pruned_fraction);
    let _ = writeln!(out, "# TYPE simetra_kernel_info gauge");
    let _ = writeln!(out, "simetra_kernel_info{{kernel=\"{}\"}} 1", s.kernel);
    // Cumulative histogram over the pinned edges (bucket 0 holds exactly
    // 0us; bucket i >= 1 holds [2^(i-1), 2^i), so its inclusive upper
    // edge is 2^i - 1). Interior zero-count buckets are skipped — the
    // cumulative counts stay exact.
    let _ = writeln!(out, "# TYPE simetra_request_latency_us histogram");
    let mut cum = 0u64;
    for (i, &c) in s.latency_us_buckets.iter().enumerate() {
        cum += c;
        if c == 0 {
            continue;
        }
        let le = if i == 0 { 0 } else { (1u64 << i.min(63)) - 1 };
        let _ = writeln!(out, "simetra_request_latency_us_bucket{{le=\"{le}\"}} {cum}");
    }
    let _ = writeln!(out, "simetra_request_latency_us_bucket{{le=\"+Inf\"}} {cum}");
    let _ = writeln!(out, "simetra_request_latency_us_sum {}", s.latency_us_sum);
    let _ = writeln!(out, "simetra_request_latency_us_count {cum}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges_are_pinned() {
        // Edges [0, 1, 2, 4, 8, ...): bucket_of(0) must hit bucket 0 —
        // the old `us + 1` form returned 1 and made bucket 0 unreachable.
        assert_eq!(LatencyHistogram::bucket_of(0), 0);
        assert_eq!(LatencyHistogram::bucket_of(1), 1);
        assert_eq!(LatencyHistogram::bucket_of(2), 2);
        assert_eq!(LatencyHistogram::bucket_of(3), 2);
        assert_eq!(LatencyHistogram::bucket_of(4), 3);
        assert_eq!(LatencyHistogram::bucket_of(7), 3);
        assert_eq!(LatencyHistogram::bucket_of(8), 4);
        assert_eq!(LatencyHistogram::bucket_of(1023), 10);
        assert_eq!(LatencyHistogram::bucket_of(1024), 11);
        assert_eq!(LatencyHistogram::bucket_of(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn sub_microsecond_ops_land_in_bucket_zero() {
        let h = LatencyHistogram::default();
        h.record(0);
        assert_eq!(h.percentile(0.5), 0);
        assert_eq!(h.buckets[0].load(Ordering::Relaxed), 1);
    }

    #[test]
    fn histogram_percentiles_are_monotone() {
        let h = LatencyHistogram::default();
        for us in [1u64, 5, 10, 50, 100, 500, 1000, 5000, 10_000] {
            for _ in 0..10 {
                h.record(us);
            }
        }
        let p50 = h.percentile(0.5);
        let p99 = h.percentile(0.99);
        assert!(p50 <= p99, "p50={p50} p99={p99}");
        assert!(p50 >= 10, "p50={p50}");
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::default();
        assert_eq!(h.percentile(0.5), 0);
    }

    #[test]
    fn prometheus_rendering_is_well_formed() {
        let kernel = crate::storage::ScalarKernel::default();
        let m = Metrics::default();
        m.queries.fetch_add(2, Ordering::Relaxed);
        m.record_latency_us(0);
        m.record_latency_us(100);
        let s = m.snapshot(50, 1, None, &kernel);
        let mut out = String::new();
        render_prometheus(&s, &mut out);
        assert!(out.contains("simetra_queries_total 2"), "{out}");
        assert!(out.contains("simetra_kernel_info{kernel=\"scalar\"} 1"), "{out}");
        assert!(out.contains("simetra_request_latency_us_bucket{le=\"0\"} 1"), "{out}");
        assert!(out.contains("simetra_request_latency_us_bucket{le=\"127\"} 2"), "{out}");
        assert!(out.contains("simetra_request_latency_us_bucket{le=\"+Inf\"} 2"), "{out}");
        assert!(out.contains("simetra_request_latency_us_sum 100"), "{out}");
        assert!(out.contains("simetra_request_latency_us_count 2"), "{out}");
        // Exposition shape: every line is a # comment or `name value`.
        for line in out.lines() {
            assert!(line.starts_with('#') || line.split(' ').count() == 2, "{line}");
        }
    }

    #[test]
    fn snapshot_reflects_counters_and_ingest_gauges() {
        let kernel = crate::storage::ScalarKernel::default();
        let m = Metrics::default();
        m.queries.fetch_add(3, Ordering::Relaxed);
        m.record_latency_us(120);
        let s = m.snapshot(100, 2, None, &kernel);
        assert_eq!(s.queries, 3);
        assert_eq!(s.corpus_size, 100);
        assert_eq!(s.shards, 2);
        assert_eq!(s.kernel, "scalar");
        assert!(s.latency_us_max >= 120);
        assert_eq!(s.latency_us_buckets.len(), BUCKETS);
        assert_eq!(s.latency_us_buckets.iter().sum::<u64>(), 1);
        assert_eq!(s.generations, 0);

        let ing = IngestStats {
            live: 90,
            memtable_items: 7,
            generations: 3,
            tombstones: 2,
            sealed_bytes: 4096,
            inserts: 100,
            deletes: 10,
            seals: 4,
            compactions: 1,
        };
        let s = m.snapshot(ing.live, 1, Some(&ing), &kernel);
        assert_eq!(s.corpus_size, 90);
        assert_eq!(s.generations, 3);
        assert_eq!(s.memtable_items, 7);
        assert_eq!(s.tombstones, 2);
        assert_eq!(s.sealed_bytes, 4096);
        assert_eq!(s.seals, 4);
        assert_eq!(s.compactions, 1);
    }
}
