//! The serving coordinator: dynamic batching, shard scatter-gather, and the
//! choice between the scalar index path and the batched PJRT paths.
//!
//! Request flow:
//!
//! ```text
//! client -> BatchSubmitter -> batch loop -> per-shard execution -> merge
//!            (queue +           (max_batch /    Index | Engine |     (top-k /
//!             backpressure)      max_wait)       Hybrid)              concat)
//! ```
//!
//! Python never appears on this path: the Engine/Hybrid strategies execute
//! AOT-compiled HLO artifacts on the PJRT CPU client owned by a dedicated
//! executor thread. Threading model: batch collection on one thread, shard
//! execution fanned out over a per-coordinator thread pool, PJRT execution
//! serialized on the engine thread (single CPU device).

pub mod batcher;
pub mod metrics;
pub mod protocol;
pub mod router;
pub mod server;
pub mod shard;

pub use batcher::{BatchConfig, BatchError, BatchSubmitter};
pub use metrics::Metrics;
pub use protocol::{ConfigSnapshot, Hit, Request, Response, SearchResult, StatsSnapshot};
pub use shard::{ExecMode, IndexKind, Shard};

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::bounds::BoundKind;
use crate::error::SimetraError;
use crate::index::QueryStats;
use crate::ingest::{IngestConfig, IngestCorpus};
use crate::metrics::DenseVec;
use crate::obs::{SlowEntry, Stage, TraceEvent, TraceKind, OBS};
use crate::query::{QueryContext, SearchMode, SearchRequest};
use crate::runtime::EngineHandle;
use crate::storage::{CorpusStore, KernelBackend, KernelKind};
use crate::sync::Ordering::Relaxed;

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub n_shards: usize,
    pub index: IndexKind,
    pub bound: BoundKind,
    pub mode: ExecMode,
    pub batch: BatchConfig,
    /// Artifact directory; required for Engine/Hybrid modes.
    pub artifact_dir: Option<PathBuf>,
    /// Pivots per shard for the hybrid path (0 = default).
    pub hybrid_pivots: usize,
    /// Kernel backend for every scan under this coordinator (ADR-003).
    /// `None` keeps whatever the store carries — the `SIMETRA_KERNEL` env
    /// default for freshly built stores.
    pub kernel: Option<KernelKind>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            n_shards: 2,
            index: IndexKind::Vp,
            bound: BoundKind::Mult,
            mode: ExecMode::Index,
            batch: BatchConfig::default(),
            artifact_dir: None,
            hybrid_pivots: 0,
            kernel: None,
        }
    }
}

/// One query travelling through the batcher: the raw vector plus its
/// typed plan (ADR-005). Legacy `knn`/`range` entry points build plain
/// plans, so the uniform-batch fast paths below still recognize them.
#[derive(Debug, Clone)]
struct Query {
    vector: Vec<f32>,
    req: SearchRequest,
}

type QueryResult = Result<SearchResult, String>;

/// Per-job answer from one shard: local-id hits, the query's stats
/// window, the budget-truncation flag, and the trace event log (empty
/// unless the request asked for one).
type ShardAnswer = (Vec<(u32, f64)>, QueryStats, bool, Vec<TraceEvent>);

/// Append a shard's trace to a per-job accumulator, lifting item-scoped
/// event ids into the global id space (counter-scoped kinds — scan rows,
/// filter lengths — pass through unchanged).
fn extend_trace(acc: &mut Vec<TraceEvent>, base: u64, trace: Vec<TraceEvent>) {
    for mut ev in trace {
        if matches!(ev.kind, TraceKind::Visit | TraceKind::Prune | TraceKind::Eval) {
            ev.id += base;
        }
        acc.push(ev);
    }
}

/// Work sent to a persistent per-shard worker thread (Index mode): the
/// whole batch, answered with per-job [`ShardAnswer`]s. Long-lived workers
/// avoid per-batch thread-spawn latency on the hot path.
struct ShardJob {
    queries: Arc<Vec<Query>>,
    parsed: Arc<Vec<DenseVec>>,
    reply: std::sync::mpsc::SyncSender<(u64, Vec<ShardAnswer>)>,
}

struct ShardWorker {
    tx: std::sync::mpsc::Sender<ShardJob>,
}

/// Execute one batch on a shard through the worker's reusable context
/// (ADR-006): every *plain* plan of the batch — any mode, any `k`/`tau`
/// mix — rides the index's shared-frontier multi-query traversal in one
/// call; optioned plans run per query through [`Shard::search_ctx`].
/// Either way every query of every batch reuses the same scratch arena.
/// Aggregates each query's pruning stats into `agg` and returns per-job
/// answers in job order.
fn run_shard_batch(
    shard: &Shard,
    queries: &[Query],
    parsed: &[DenseVec],
    ctx: &mut QueryContext,
    agg: &mut QueryStats,
) -> Vec<ShardAnswer> {
    let n = queries.len();
    let plain: Vec<usize> = (0..n).filter(|&i| queries[i].req.is_plain()).collect();
    if plain.len() == n {
        // All-plain (the common shape): no re-grouping copies.
        let reqs: Vec<SearchRequest> = queries.iter().map(|q| q.req.clone()).collect();
        let mut resps = Vec::new();
        shard.search_batch_ctx(parsed, &reqs, ctx, &mut resps);
        return resps
            .into_iter()
            .map(|resp| {
                agg.merge(&resp.stats);
                (resp.hits, resp.stats, resp.truncated, resp.trace)
            })
            .collect();
    }
    let mut out: Vec<ShardAnswer> = Vec::with_capacity(n);
    out.resize_with(n, || (Vec::new(), QueryStats::default(), false, Vec::new()));
    if !plain.is_empty() {
        let pv: Vec<DenseVec> = plain.iter().map(|&i| parsed[i].clone()).collect();
        let reqs: Vec<SearchRequest> = plain.iter().map(|&i| queries[i].req.clone()).collect();
        let mut resps = Vec::new();
        shard.search_batch_ctx(&pv, &reqs, ctx, &mut resps);
        for (pos, resp) in resps.into_iter().enumerate() {
            agg.merge(&resp.stats);
            out[plain[pos]] = (resp.hits, resp.stats, resp.truncated, resp.trace);
        }
    }
    for i in 0..n {
        if queries[i].req.is_plain() {
            continue;
        }
        let (hits, stats, truncated, trace) = shard.search_ctx(&parsed[i], &queries[i].req, ctx);
        agg.merge(&stats);
        out[i] = (hits, stats, truncated, trace);
    }
    out
}

fn spawn_shard_worker(pos: usize, shard: Arc<Shard>, metrics: Arc<Metrics>) -> ShardWorker {
    let (tx, rx) = std::sync::mpsc::channel::<ShardJob>();
    std::thread::Builder::new()
        .name(format!("simetra-shard-{}", shard.base))
        .spawn(move || {
            // The worker's scratch arena: one per shard thread, reused by
            // every query of every batch (ADR-004). Serving contexts feed
            // the observability registry (bound-slack histograms keyed by
            // this shard's index kind; see `Shard::search_ctx`).
            let mut ctx = QueryContext::new();
            ctx.set_obs_enabled(true);
            for job in rx {
                let t0 = Instant::now();
                let q0 = ctx.queries();
                let mut agg = QueryStats::default();
                let out = run_shard_batch(&shard, &job.queries, &job.parsed, &mut ctx, &mut agg);
                OBS.record_stage(Stage::Traversal, t0.elapsed());
                let nq = job.queries.len() as u64;
                OBS.record_shard(pos, nq, agg.sim_evals, agg.nodes_visited, agg.pruned);
                metrics.ctx_reuses.fetch_add(ctx.reuses_since(q0), Relaxed);
                metrics.pruned.fetch_add(agg.pruned, Relaxed);
                metrics.nodes_visited.fetch_add(agg.nodes_visited, Relaxed);
                let _ = job.reply.send((shard.base, out));
            }
        })
        .expect("spawn shard worker");
    ShardWorker { tx }
}

/// The serving engine. Cheap to clone (all state behind `Arc`).
#[derive(Clone)]
pub struct Coordinator {
    submitter: Arc<BatchSubmitter<Query, QueryResult>>,
    metrics: Arc<Metrics>,
    /// Present for mutable corpora (built with [`Coordinator::new_mutable`]):
    /// queries fan out across its generations instead of static shards, and
    /// the insert/delete/flush/compact methods route here.
    ingest: Option<Arc<IngestCorpus>>,
    /// The corpus's kernel backend (shared with every shard view and
    /// ingest generation): its counters feed [`Coordinator::stats`].
    kernel: Arc<dyn KernelBackend>,
    config: Arc<ConfigSnapshot>,
    corpus_size: u64,
    corpus_dim: usize,
    n_shards: u64,
}

impl Coordinator {
    /// Build shards and spawn the batch loop.
    ///
    /// Accepts a [`CorpusStore`] directly (the zero-copy path — shards
    /// become views of the one shared buffer) or anything convertible into
    /// one, e.g. a `Vec<DenseVec>`, which is packed into a store first.
    pub fn new(corpus: impl Into<CorpusStore>, config: CoordinatorConfig) -> Result<Self> {
        let mut store: CorpusStore = corpus.into();
        if let Some(kind) = config.kernel {
            store = store.with_kernel(kind);
        }
        // Validate the *effective* backend — explicit selection or the
        // env-default the store was built with — then build a quantized
        // sidecar now (startup), not on the first query.
        store.kernel_kind().validate_dim(store.dim())?;
        store.warm_quant_sidecar();
        let kernel = store.kernel().clone();
        let corpus_size = store.len() as u64;
        let corpus_dim = store.dim();
        let hybrid_pivots =
            if config.mode == ExecMode::Hybrid { config.hybrid_pivots.max(16) } else { 0 };
        let shards = router::build_shards(
            &store,
            config.n_shards,
            config.index,
            config.bound,
            hybrid_pivots,
        );
        let n_shards = shards.len() as u64;
        let engine: Option<Arc<EngineHandle>> = match (&config.artifact_dir, config.mode) {
            (Some(dir), ExecMode::Engine | ExecMode::Hybrid) => {
                Some(Arc::new(EngineHandle::spawn(dir)?))
            }
            (Some(dir), ExecMode::Index) => EngineHandle::spawn(dir).ok().map(Arc::new),
            (None, ExecMode::Engine | ExecMode::Hybrid) => {
                anyhow::bail!("mode {:?} requires an artifact dir", config.mode)
            }
            (None, ExecMode::Index) => None,
        };
        let metrics = Arc::new(Metrics::default());
        let workers: Arc<Vec<ShardWorker>> = Arc::new(
            shards
                .iter()
                .enumerate()
                .map(|(i, s)| spawn_shard_worker(i, s.clone(), metrics.clone()))
                .collect(),
        );

        let m2 = metrics.clone();
        let mode = config.mode;
        // Context for the Engine/Hybrid paths that execute inline on the
        // collector thread (index-path fallbacks and engine-mode range
        // queries); Index mode runs on the shard workers' own contexts.
        let mut ctx = QueryContext::new();
        let submitter = batcher::spawn_batcher(
            config.batch.clone(),
            move |jobs: Vec<batcher::Job<Query, QueryResult>>| {
                m2.batches.fetch_add(1, Relaxed);
                execute_batch(&shards, &workers, engine.as_deref(), &m2, mode, &mut ctx, jobs);
            },
        );
        let snapshot = ConfigSnapshot {
            kernel: kernel.kind().name().to_string(),
            index: config.index.name().to_string(),
            bound: config.bound.name().to_string(),
            mode: config.mode.name().to_string(),
            shards: n_shards,
            mutable: false,
        };
        Ok(Coordinator {
            submitter: Arc::new(submitter),
            metrics,
            ingest: None,
            kernel,
            config: Arc::new(snapshot),
            corpus_size,
            corpus_dim,
            n_shards,
        })
    }

    /// Build a serving engine over an empty *mutable* generational corpus
    /// (see the `ingest` module / ADR-002): `insert`/`delete`/`flush`/
    /// `compact` become available, and every query runs against the
    /// atomically published snapshot — exact, and never blocked by the
    /// sealer/compactor.
    pub fn new_mutable(config: CoordinatorConfig, ingest_cfg: IngestConfig) -> Result<Self> {
        Self::new_mutable_with(None, config, ingest_cfg)
    }

    /// Like [`Coordinator::new_mutable`], seeded with an existing store as
    /// generation 0 (ids `0..initial.len()`).
    ///
    /// `config.index` and `config.bound` are the source of truth for the
    /// per-generation index, overriding the corresponding [`IngestConfig`]
    /// fields — one knob for static and mutable serving alike.
    pub fn new_mutable_with(
        initial: Option<CorpusStore>,
        config: CoordinatorConfig,
        ingest_cfg: IngestConfig,
    ) -> Result<Self> {
        if config.mode != ExecMode::Index {
            anyhow::bail!(
                "mutable corpora serve through the index path; mode {:?} is build-once",
                config.mode
            );
        }
        let ingest_cfg = IngestConfig {
            index: config.index,
            bound: config.bound,
            kernel: config.kernel.unwrap_or(ingest_cfg.kernel),
            ..ingest_cfg
        };
        let corpus_dim = ingest_cfg.dim;
        let ingest = Arc::new(IngestCorpus::with_initial(ingest_cfg, initial)?);
        let kernel = ingest.kernel().clone();
        let metrics = Arc::new(Metrics::default());
        let m2 = metrics.clone();
        let ing2 = ingest.clone();
        // The batch collector thread's scratch arena: the mutable path has
        // no shard fan-out, so one context (owned by the FnMut handler)
        // serves every query of every batch.
        let mut ctx = QueryContext::new();
        ctx.set_obs_enabled(true);
        let mut outs: Vec<Vec<(u64, f64)>> = Vec::new();
        let mut metas: Vec<(QueryStats, bool, Vec<TraceEvent>)> = Vec::new();
        let submitter = batcher::spawn_batcher(
            config.batch.clone(),
            move |jobs: Vec<batcher::Job<Query, QueryResult>>| {
                m2.batches.fetch_add(1, Relaxed);
                execute_batch_ingest(&ing2, &m2, &mut ctx, &mut outs, &mut metas, jobs);
            },
        );
        let snapshot = ConfigSnapshot {
            kernel: kernel.kind().name().to_string(),
            index: config.index.name().to_string(),
            bound: config.bound.name().to_string(),
            mode: config.mode.name().to_string(),
            shards: 1,
            mutable: true,
        };
        Ok(Coordinator {
            submitter: Arc::new(submitter),
            metrics,
            ingest: Some(ingest),
            kernel,
            config: Arc::new(snapshot),
            corpus_size: 0,
            corpus_dim,
            n_shards: 1,
        })
    }

    fn ingest_handle(&self) -> Result<&Arc<IngestCorpus>, SimetraError> {
        self.ingest.as_ref().ok_or_else(|| {
            SimetraError::BadRequest(
                "corpus is read-only (built with Coordinator::new); \
                 use Coordinator::new_mutable for ingest"
                    .into(),
            )
        })
    }

    /// Insert a vector into a mutable corpus; returns the assigned id.
    pub fn insert(&self, vector: Vec<f32>) -> Result<u64, SimetraError> {
        let ingest = self.ingest_handle()?;
        self.check_dim(&vector)?;
        ingest.insert(vector).map_err(|e| SimetraError::BadRequest(e.to_string()))
    }

    /// Tombstone an id in a mutable corpus; returns whether it was live.
    pub fn delete(&self, id: u64) -> Result<bool, SimetraError> {
        Ok(self.ingest_handle()?.delete(id))
    }

    /// Seal the memtable into a generation now.
    pub fn flush(&self) -> Result<(), SimetraError> {
        self.ingest_handle()?.flush();
        Ok(())
    }

    /// Seal, then merge all generations, dropping tombstoned rows.
    pub fn compact(&self) -> Result<(), SimetraError> {
        self.ingest_handle()?.compact();
        Ok(())
    }

    /// Live (visible) item count: the static corpus size, or the mutable
    /// corpus's current snapshot count.
    pub fn live_items(&self) -> u64 {
        match &self.ingest {
            Some(ingest) => ingest.stats().live,
            None => self.corpus_size,
        }
    }

    /// Reject wrong-dimension client vectors up front: the strict dot
    /// kernels treat a dimension mismatch deep inside a shard worker as a
    /// bug (panic), so malformed input must never get that far. Mutable
    /// corpora fix the dimension at construction, so it is enforced even
    /// while the corpus is empty.
    fn check_dim(&self, vector: &[f32]) -> Result<(), SimetraError> {
        let enforce = self.ingest.is_some() || self.corpus_size > 0;
        if enforce && vector.len() != self.corpus_dim {
            return Err(SimetraError::DimMismatch { got: vector.len(), want: self.corpus_dim });
        }
        Ok(())
    }

    /// Validate a typed plan against this serving corpus (ADR-005): mode
    /// parameters must be sane, filter lists sorted, and a kernel override
    /// resolvable against the corpus's available backends.
    fn check_request(&self, req: &SearchRequest) -> Result<(), SimetraError> {
        match req.mode {
            SearchMode::Knn { k } | SearchMode::KnnWithin { k, .. } if k == 0 => {
                return Err(SimetraError::BadRequest("k must be >= 1".into()));
            }
            _ => {}
        }
        if let Some(tau) = req.mode.tau() {
            if tau.is_nan() {
                return Err(SimetraError::BadRequest("tau must not be NaN".into()));
            }
        }
        if !req.filter.is_sorted() {
            return Err(SimetraError::BadRequest("filter ids must be sorted ascending".into()));
        }
        if let Some(kind) = req.kernel {
            kind.validate_dim(self.corpus_dim)
                .map_err(|e| SimetraError::KernelUnavailable(e.to_string()))?;
            // The i8 pre-filter needs the corpus's sidecar, which only an
            // i8-primary store builds; exact kinds are always available.
            if kind == KernelKind::QuantizedI8 && self.kernel.kind() != KernelKind::QuantizedI8 {
                return Err(SimetraError::KernelUnavailable(format!(
                    "kernel override 'i8' unavailable: corpus serves through '{}' \
                     and carries no quantized sidecar",
                    self.kernel.kind().name()
                )));
            }
        }
        Ok(())
    }

    /// Execute one typed search plan (batched behind the scenes); blocks
    /// until answered. The single search entry point — `knn` and `range`
    /// are plain-plan wrappers over it.
    pub fn search(
        &self,
        vector: Vec<f32>,
        req: SearchRequest,
    ) -> Result<SearchResult, SimetraError> {
        let started = Instant::now();
        let checked = self.check_dim(&vector).and_then(|()| self.check_request(&req));
        OBS.record_stage(Stage::Plan, started.elapsed());
        let fanned = Instant::now();
        let out = checked.and_then(|()| {
            self.submitter
                .submit(Query { vector, req: req.clone() })
                .map_err(|e| SimetraError::Io(e.to_string()))?
                .map_err(SimetraError::Io)
        });
        OBS.record_stage(Stage::ShardFanout, fanned.elapsed());
        self.finish(started, &req, &out);
        out
    }

    /// kNN query; blocks until answered. (Plain-plan wrapper over
    /// [`Coordinator::search`], byte-identical results — including the
    /// legacy `k = 0` behavior: the query executes and returns no hits,
    /// where the stricter `search` surface rejects `k = 0` outright.)
    pub fn knn(&self, vector: Vec<f32>, k: usize) -> Result<(Vec<Hit>, u64), SimetraError> {
        self.search(vector, SearchRequest::knn(k.max(1)).build()).map(|mut r| {
            r.hits.truncate(k);
            (r.hits, r.sim_evals)
        })
    }

    /// Range query (`sim >= tau`); blocks until answered. (Plain-plan
    /// wrapper over [`Coordinator::search`].)
    pub fn range(&self, vector: Vec<f32>, tau: f64) -> Result<(Vec<Hit>, u64), SimetraError> {
        self.search(vector, SearchRequest::range(tau).build()).map(|r| (r.hits, r.sim_evals))
    }

    fn finish(
        &self,
        started: Instant,
        req: &SearchRequest,
        out: &Result<SearchResult, SimetraError>,
    ) {
        self.metrics.queries.fetch_add(1, Relaxed);
        if out.is_err() {
            self.metrics.errors.fetch_add(1, Relaxed);
        }
        let us = started.elapsed().as_micros() as u64;
        self.metrics.record_latency_us(us);
        if let Ok(r) = out {
            let mode = match req.mode {
                SearchMode::Knn { .. } => "knn",
                SearchMode::Range { .. } => "range",
                SearchMode::KnnWithin { .. } => "knn_within",
            };
            OBS.note_query(SlowEntry {
                latency_us: us,
                mode,
                k: req.mode.k().unwrap_or(0) as u64,
                tau: req.mode.tau().unwrap_or(0.0),
                has_tau: req.mode.tau().is_some(),
                bound: req.bound.map_or("default", |b| b.token()),
                hits: r.hits.len() as u64,
                sim_evals: r.sim_evals,
                nodes_visited: r.nodes_visited,
                pruned: r.pruned,
                truncated: r.truncated,
            });
        }
    }

    pub fn stats(&self) -> StatsSnapshot {
        let ingest = self.ingest.as_ref().map(|i| i.stats());
        let corpus_size = match &ingest {
            Some(s) => s.live,
            None => self.corpus_size,
        };
        self.metrics.snapshot(corpus_size, self.n_shards, ingest.as_ref(), self.kernel.as_ref())
    }

    /// The serving configuration (active kernel backend, index, bound,
    /// mode) — fixed at build time, exposed through the wire `config` op.
    pub fn describe(&self) -> ConfigSnapshot {
        (*self.config).clone()
    }

    /// Prometheus text exposition: the serving counters and latency
    /// histogram from the same snapshot path as [`Coordinator::stats`],
    /// followed by the process-wide observability registry's families
    /// (bound-slack histograms, per-stage spans, per-shard /
    /// per-generation work, the slow-query ring). Serves the `metrics`
    /// wire op and `simetra stats --prometheus`.
    pub fn prometheus(&self) -> String {
        let mut out = String::with_capacity(4096);
        metrics::render_prometheus(&self.stats(), &mut out);
        OBS.render_into(&mut out);
        out
    }
}

/// Execute one batch against the mutable corpus: the whole batch runs
/// over one atomically published generation snapshot (no shard scatter —
/// the generation fan-out happens inside the snapshot), through the
/// collector thread's one reusable context and per-query hit buffers.
/// Plain plans descend each generation's tree together behind the shared
/// frontier (ADR-006); optioned plans fall back per query inside
/// `search_batch_into`.
fn execute_batch_ingest(
    ingest: &IngestCorpus,
    metrics: &Metrics,
    ctx: &mut QueryContext,
    outs: &mut Vec<Vec<(u64, f64)>>,
    metas: &mut Vec<(QueryStats, bool, Vec<TraceEvent>)>,
    jobs: Vec<batcher::Job<Query, QueryResult>>,
) {
    let q0 = ctx.queries();
    let mut parsed: Vec<DenseVec> = Vec::with_capacity(jobs.len());
    parsed.extend(jobs.iter().map(|j| DenseVec::new(j.query.vector.clone())));
    let reqs: Vec<SearchRequest> = jobs.iter().map(|j| j.query.req.clone()).collect();
    let t0 = Instant::now();
    ingest.search_batch_ctx(&parsed, &reqs, ctx, outs, metas);
    OBS.record_stage(Stage::Traversal, t0.elapsed());
    let t_merge = Instant::now();
    for (job, (out, meta)) in jobs.into_iter().zip(outs.iter().zip(metas.iter_mut())) {
        let (stats, truncated, trace) = meta;
        metrics.sim_evals.fetch_add(stats.sim_evals, Relaxed);
        metrics.pruned.fetch_add(stats.pruned, Relaxed);
        metrics.nodes_visited.fetch_add(stats.nodes_visited, Relaxed);
        let hits: Vec<Hit> = out.iter().map(|&(id, score)| Hit { id, score }).collect();
        let _ = job.reply.send(Ok(SearchResult {
            hits,
            truncated: *truncated,
            sim_evals: stats.sim_evals,
            nodes_visited: stats.nodes_visited,
            pruned: stats.pruned,
            trace: std::mem::take(trace),
        }));
    }
    OBS.record_stage(Stage::Merge, t_merge.elapsed());
    metrics.ctx_reuses.fetch_add(ctx.reuses_since(q0), Relaxed);
}

/// Execute one batch: scatter to shards, merge, reply. `ctx` is the
/// collector thread's reusable context, used by the Engine/Hybrid arms'
/// inline index-path executions (Index mode runs on the shard workers).
fn execute_batch(
    shards: &[Arc<Shard>],
    workers: &[ShardWorker],
    engine: Option<&EngineHandle>,
    metrics: &Metrics,
    mode: ExecMode,
    ctx: &mut QueryContext,
    jobs: Vec<batcher::Job<Query, QueryResult>>,
) {
    let queries: Vec<Query> = jobs.iter().map(|j| j.query.clone()).collect();
    let parsed: Arc<Vec<DenseVec>> =
        Arc::new(queries.iter().map(|q| DenseVec::new(q.vector.clone())).collect());
    let queries = Arc::new(queries);

    /// Per-job accumulator: global hits, stats, truncated, trace.
    #[derive(Default, Clone)]
    struct Acc {
        hits: Vec<(u64, f64)>,
        stats: QueryStats,
        truncated: bool,
        trace: Vec<TraceEvent>,
    }
    let mut results: Vec<Acc> = vec![Acc::default(); jobs.len()];
    let mut poisoned = false;

    match mode {
        ExecMode::Index => {
            // Scalar path: scatter the batch to the persistent shard
            // workers, gather per-shard answers.
            let (reply, rx) = std::sync::mpsc::sync_channel(workers.len());
            let mut sent = 0usize;
            for worker in workers {
                if worker
                    .tx
                    .send(ShardJob {
                        queries: queries.clone(),
                        parsed: parsed.clone(),
                        reply: reply.clone(),
                    })
                    .is_ok()
                {
                    sent += 1;
                }
            }
            drop(reply);
            let mut answered = 0usize;
            for (base, per_shard) in rx {
                answered += 1;
                for (ji, (hits, stats, truncated, trace)) in per_shard.into_iter().enumerate() {
                    for (id, s) in hits {
                        results[ji].hits.push((base + id as u64, s));
                    }
                    results[ji].stats.merge(&stats);
                    results[ji].truncated |= truncated;
                    extend_trace(&mut results[ji].trace, base, trace);
                }
            }
            if answered != sent {
                poisoned = true; // a worker died mid-batch
            }
        }
        ExecMode::Engine | ExecMode::Hybrid => {
            let engine = engine.expect("engine required (checked in new)");
            let ctx_q0 = ctx.queries();
            let mut agg = QueryStats::default();
            // Plain kNN queries take the batched engine path; everything
            // else (range, KnnWithin, any per-request option) runs the
            // index path per query on the collector's context.
            let mut knn_ids: Vec<usize> = Vec::new();
            let mut other_ids: Vec<usize> = Vec::new();
            for (i, q) in queries.iter().enumerate() {
                if q.req.is_plain() && matches!(q.req.mode, SearchMode::Knn { .. }) {
                    knn_ids.push(i);
                } else {
                    other_ids.push(i);
                }
            }
            let kmax = knn_ids.iter().filter_map(|&i| queries[i].req.mode.k()).max().unwrap_or(0);
            let knn_vecs: Vec<DenseVec> = knn_ids.iter().map(|&i| parsed[i].clone()).collect();

            for shard in shards {
                if !knn_ids.is_empty() {
                    metrics.engine_calls.fetch_add(1, Relaxed);
                    let res = match mode {
                        ExecMode::Engine => shard.knn_engine(engine, &knn_vecs, kmax).map(
                            |hits| {
                                hits.into_iter()
                                    .map(|h| (h, shard.len() as u64))
                                    .collect::<Vec<_>>()
                            },
                        ),
                        _ => shard.knn_hybrid(engine, &knn_vecs, kmax),
                    };
                    match res {
                        Ok(per_query) => {
                            for (pos, (hits, evals)) in per_query.into_iter().enumerate() {
                                let ji = knn_ids[pos];
                                for (id, s) in hits {
                                    results[ji].hits.push((shard.base + id as u64, s));
                                }
                                results[ji].stats.sim_evals += evals;
                            }
                        }
                        Err(e) => {
                            eprintln!("engine batch failed: {e}; falling back to index");
                            for &ji in &knn_ids {
                                let (hits, stats, _, trace) =
                                    shard.search_ctx(&parsed[ji], &queries[ji].req, ctx);
                                agg.merge(&stats);
                                for (id, s) in hits {
                                    results[ji].hits.push((shard.base + id as u64, s));
                                }
                                results[ji].stats.merge(&stats);
                                extend_trace(&mut results[ji].trace, shard.base, trace);
                            }
                        }
                    }
                }
                for &ji in &other_ids {
                    let req = &queries[ji].req;
                    let plain_range_tau = match req.mode {
                        SearchMode::Range { tau } if req.is_plain() => Some(tau),
                        _ => None,
                    };
                    if let (ExecMode::Hybrid, Some(tau)) = (mode, plain_range_tau) {
                        metrics.engine_calls.fetch_add(1, Relaxed);
                        match shard.range_hybrid(engine, std::slice::from_ref(&parsed[ji]), tau) {
                            Ok(mut per_query) => {
                                let (hits, evals) = per_query.remove(0);
                                for (id, s) in hits {
                                    results[ji].hits.push((shard.base + id as u64, s));
                                }
                                results[ji].stats.sim_evals += evals;
                            }
                            Err(e) => {
                                eprintln!("hybrid range failed: {e}; index fallback");
                                let (hits, stats, truncated, trace) =
                                    shard.search_ctx(&parsed[ji], req, ctx);
                                agg.merge(&stats);
                                for (id, s) in hits {
                                    results[ji].hits.push((shard.base + id as u64, s));
                                }
                                results[ji].stats.merge(&stats);
                                results[ji].truncated |= truncated;
                                extend_trace(&mut results[ji].trace, shard.base, trace);
                            }
                        }
                    } else {
                        // The engine scores plain top-k only; every other
                        // plan shape runs the index path on the
                        // collector's context.
                        let (hits, stats, truncated, trace) =
                            shard.search_ctx(&parsed[ji], req, ctx);
                        agg.merge(&stats);
                        for (id, s) in hits {
                            results[ji].hits.push((shard.base + id as u64, s));
                        }
                        results[ji].stats.merge(&stats);
                        results[ji].truncated |= truncated;
                        extend_trace(&mut results[ji].trace, shard.base, trace);
                    }
                }
            }
            metrics.pruned.fetch_add(agg.pruned, Relaxed);
            metrics.nodes_visited.fetch_add(agg.nodes_visited, Relaxed);
            metrics.ctx_reuses.fetch_add(ctx.reuses_since(ctx_q0), Relaxed);
        }
    }

    // Merge + reply.
    let t_merge = Instant::now();
    for (job, mut acc) in jobs.into_iter().zip(results) {
        if poisoned {
            metrics.errors.fetch_add(1, Relaxed);
            let _ = job.reply.send(Err("internal shard failure".into()));
            continue;
        }
        metrics.sim_evals.fetch_add(acc.stats.sim_evals, Relaxed);
        // Total order (ids unique): unstable sort, identical permutation,
        // no merge-buffer allocation on the reply path.
        acc.hits.sort_unstable_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        if let Some(k) = job.query.req.mode.k() {
            acc.hits.truncate(k);
        }
        let hits: Vec<Hit> = acc.hits.into_iter().map(|(id, score)| Hit { id, score }).collect();
        let _ = job.reply.send(Ok(SearchResult {
            hits,
            truncated: acc.truncated,
            sim_evals: acc.stats.sim_evals,
            nodes_visited: acc.stats.nodes_visited,
            pruned: acc.stats.pruned,
            trace: acc.trace,
        }));
    }
    OBS.record_stage(Stage::Merge, t_merge.elapsed());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::uniform_sphere;
    use crate::index::{LinearScan, QueryStats, SimilarityIndex};

    #[test]
    fn index_mode_matches_linear_scan() {
        let pts = uniform_sphere(500, 16, 101);
        let coord = Coordinator::new(
            pts.clone(),
            CoordinatorConfig { n_shards: 3, ..Default::default() },
        )
        .unwrap();
        let lin = LinearScan::build(pts.clone());
        for qi in [0usize, 250, 499] {
            let (hits, _) = coord.knn(pts[qi].as_slice().to_vec(), 5).unwrap();
            let mut st = QueryStats::default();
            let want = lin.knn(&pts[qi], 5, &mut st);
            assert_eq!(hits.len(), 5);
            for (h, (_, s)) in hits.iter().zip(&want) {
                assert!((h.score - s).abs() < 1e-9);
            }
            assert_eq!(hits[0].id, qi as u64);
        }
        let stats = coord.stats();
        assert_eq!(stats.queries, 3);
        assert!(stats.batches >= 1);
        // The aggregated traversal stats flow through (ADR-004): every
        // query visits at least the root node, and from the second query
        // on, each shard worker's context is a reuse.
        assert!(stats.nodes_visited > 0, "{stats:?}");
        assert!(stats.ctx_reuses > 0, "{stats:?}");
        assert!((0.0..=1.0).contains(&stats.pruned_fraction), "{stats:?}");
    }

    #[test]
    fn range_mode_returns_threshold_matches() {
        let pts = uniform_sphere(300, 8, 102);
        let coord = Coordinator::new(
            pts.clone(),
            CoordinatorConfig { n_shards: 2, ..Default::default() },
        )
        .unwrap();
        let (hits, _) = coord.range(pts[7].as_slice().to_vec(), 0.5).unwrap();
        let lin = LinearScan::build(pts.clone());
        let mut st = QueryStats::default();
        let want = lin.range(&pts[7], 0.5, &mut st);
        assert_eq!(hits.len(), want.len());
        assert_eq!(hits[0].id, 7);
    }

    #[test]
    fn store_backed_coordinator_serves_and_rejects_bad_dims() {
        let store = crate::data::uniform_sphere_store(200, 16, 104);
        let q = store.vec(9).as_slice().to_vec();
        let coord = Coordinator::new(
            store.clone(),
            CoordinatorConfig { n_shards: 3, ..Default::default() },
        )
        .unwrap();
        let (hits, _) = coord.knn(q, 4).unwrap();
        assert_eq!(hits[0].id, 9);
        // Wrong-dimension queries get a clean error, not a shard panic.
        let err = coord.knn(vec![1.0f32; 7], 3);
        assert!(err.is_err());
        assert!(err.unwrap_err().to_string().contains("dimension"));
        // The coordinator still works afterwards.
        let (hits, _) = coord.knn(store.vec(0).as_slice().to_vec(), 1).unwrap();
        assert_eq!(hits[0].id, 0);
    }

    #[test]
    fn mutable_coordinator_serves_the_ingest_lifecycle() {
        let coord = Coordinator::new_mutable(
            CoordinatorConfig::default(),
            crate::ingest::IngestConfig {
                seal_threshold: 32,
                background: false,
                ..crate::ingest::IngestConfig::new(8)
            },
        )
        .unwrap();
        let pts = uniform_sphere(100, 8, 105);
        for p in &pts {
            coord.insert(p.as_slice().to_vec()).unwrap();
        }
        let (hits, _) = coord.knn(pts[11].as_slice().to_vec(), 3).unwrap();
        assert_eq!(hits[0].id, 11);
        assert!(coord.delete(11).unwrap());
        assert!(!coord.delete(11).unwrap());
        let (hits, _) = coord.knn(pts[11].as_slice().to_vec(), 3).unwrap();
        assert_ne!(hits[0].id, 11);
        coord.flush().unwrap();
        coord.compact().unwrap();
        assert_eq!(coord.live_items(), 99);
        let stats = coord.stats();
        assert_eq!(stats.corpus_size, 99);
        assert_eq!(stats.generations, 1);
        assert_eq!(stats.tombstones, 0);
        assert_eq!(stats.inserts, 100);
        assert_eq!(stats.deletes, 1);
        // Wrong-dimension inserts and queries fail cleanly, even though
        // the mutable corpus started out empty.
        assert!(coord.insert(vec![1.0; 5]).is_err());
        assert!(coord.knn(vec![1.0; 5], 2).is_err());
        // Build-once coordinators reject mutations.
        let fixed = Coordinator::new(pts, CoordinatorConfig::default()).unwrap();
        let err = fixed.insert(vec![0.0; 8]);
        assert!(err.unwrap_err().to_string().contains("read-only"));
    }

    #[test]
    fn kernel_override_is_reported_in_stats_and_config() {
        let pts = uniform_sphere(120, 8, 106);
        let coord = Coordinator::new(
            pts.clone(),
            CoordinatorConfig {
                kernel: Some(crate::storage::KernelKind::Simd),
                ..Default::default()
            },
        )
        .unwrap();
        let (hits, _) = coord.knn(pts[3].as_slice().to_vec(), 2).unwrap();
        assert_eq!(hits[0].id, 3);
        let stats = coord.stats();
        assert_eq!(stats.kernel, "simd");
        assert!(stats.blocked_scan_rows > 0, "{stats:?}");
        let cfg = coord.describe();
        assert_eq!(cfg.kernel, "simd");
        assert_eq!(cfg.index, "vp");
        assert_eq!(cfg.mode, "index");
        assert!(!cfg.mutable);
    }

    #[test]
    fn concurrent_queries_all_answered() {
        let pts = uniform_sphere(400, 8, 103);
        let coord = Coordinator::new(
            pts.clone(),
            CoordinatorConfig { n_shards: 2, ..Default::default() },
        )
        .unwrap();
        let mut handles = Vec::new();
        for qi in 0..100usize {
            let coord = coord.clone();
            let v = pts[qi % 400].as_slice().to_vec();
            handles.push(std::thread::spawn(move || coord.knn(v, 3).unwrap()));
        }
        for (qi, h) in handles.into_iter().enumerate() {
            let (hits, _) = h.join().unwrap();
            assert_eq!(hits[0].id, (qi % 400) as u64, "query {qi}");
        }
        assert_eq!(coord.stats().queries, 100);
    }
}
