//! Wire protocol of the serving engine: newline-delimited JSON over TCP.
//!
//! Hand-rolled (de)serialization over `util::Json` (serde is unavailable in
//! this offline build); the shapes mirror what a serde-tagged enum would
//! produce: `{"op": "knn", "vector": [...], "k": 10}`.

use anyhow::{bail, Result};

use crate::util::Json;

/// A client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// k nearest neighbors by cosine similarity.
    Knn { vector: Vec<f32>, k: usize },
    /// All items with `sim >= tau`.
    Range { vector: Vec<f32>, tau: f64 },
    /// Server + query statistics.
    Stats,
    /// Health check.
    Ping,
}

impl Request {
    pub fn to_json(&self) -> Json {
        match self {
            Request::Knn { vector, k } => Json::obj(vec![
                ("op", Json::Str("knn".into())),
                ("vector", Json::arr_f32(vector.iter().copied())),
                ("k", Json::Num(*k as f64)),
            ]),
            Request::Range { vector, tau } => Json::obj(vec![
                ("op", Json::Str("range".into())),
                ("vector", Json::arr_f32(vector.iter().copied())),
                ("tau", Json::Num(*tau)),
            ]),
            Request::Stats => Json::obj(vec![("op", Json::Str("stats".into()))]),
            Request::Ping => Json::obj(vec![("op", Json::Str("ping".into()))]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Request> {
        Ok(match v.req("op")?.as_str()? {
            "knn" => Request::Knn {
                vector: v.req("vector")?.as_f32_vec()?,
                k: v.req("k")?.as_usize()?,
            },
            "range" => Request::Range {
                vector: v.req("vector")?.as_f32_vec()?,
                tau: v.req("tau")?.as_f64()?,
            },
            "stats" => Request::Stats,
            "ping" => Request::Ping,
            other => bail!("unknown op '{other}'"),
        })
    }

    pub fn parse(line: &str) -> Result<Request> {
        Self::from_json(&Json::parse(line)?)
    }
}

/// One scored hit.
#[derive(Debug, Clone, PartialEq)]
pub struct Hit {
    pub id: u64,
    pub score: f64,
}

/// A server response.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Ok {
        hits: Vec<Hit>,
        /// Exact similarity evaluations spent on this query (pruning power).
        sim_evals: u64,
    },
    Stats(StatsSnapshot),
    Pong,
    Error { message: String },
}

impl Response {
    pub fn to_json(&self) -> Json {
        match self {
            Response::Ok { hits, sim_evals } => Json::obj(vec![
                ("status", Json::Str("ok".into())),
                (
                    "hits",
                    Json::Arr(
                        hits.iter()
                            .map(|h| {
                                Json::obj(vec![
                                    ("id", Json::Num(h.id as f64)),
                                    ("score", Json::Num(h.score)),
                                ])
                            })
                            .collect(),
                    ),
                ),
                ("sim_evals", Json::Num(*sim_evals as f64)),
            ]),
            Response::Stats(s) => Json::obj(vec![
                ("status", Json::Str("stats".into())),
                ("queries", Json::Num(s.queries as f64)),
                ("batches", Json::Num(s.batches as f64)),
                ("errors", Json::Num(s.errors as f64)),
                ("corpus_size", Json::Num(s.corpus_size as f64)),
                ("shards", Json::Num(s.shards as f64)),
                ("sim_evals", Json::Num(s.sim_evals as f64)),
                ("engine_calls", Json::Num(s.engine_calls as f64)),
                ("pruned", Json::Num(s.pruned as f64)),
                ("latency_us_p50", Json::Num(s.latency_us_p50 as f64)),
                ("latency_us_p99", Json::Num(s.latency_us_p99 as f64)),
                ("latency_us_max", Json::Num(s.latency_us_max as f64)),
            ]),
            Response::Pong => Json::obj(vec![("status", Json::Str("pong".into()))]),
            Response::Error { message } => Json::obj(vec![
                ("status", Json::Str("error".into())),
                ("message", Json::Str(message.clone())),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Response> {
        Ok(match v.req("status")?.as_str()? {
            "ok" => Response::Ok {
                hits: v
                    .req("hits")?
                    .as_arr()?
                    .iter()
                    .map(|h| {
                        Ok(Hit {
                            id: h.req("id")?.as_f64()? as u64,
                            score: h.req("score")?.as_f64()?,
                        })
                    })
                    .collect::<Result<_>>()?,
                sim_evals: v.req("sim_evals")?.as_f64()? as u64,
            },
            "stats" => {
                let g = |key: &str| -> Result<u64> { Ok(v.req(key)?.as_f64()? as u64) };
                Response::Stats(StatsSnapshot {
                    queries: g("queries")?,
                    batches: g("batches")?,
                    errors: g("errors")?,
                    corpus_size: g("corpus_size")?,
                    shards: g("shards")?,
                    sim_evals: g("sim_evals")?,
                    engine_calls: g("engine_calls")?,
                    pruned: g("pruned")?,
                    latency_us_p50: g("latency_us_p50")?,
                    latency_us_p99: g("latency_us_p99")?,
                    latency_us_max: g("latency_us_max")?,
                })
            }
            "pong" => Response::Pong,
            "error" => Response::Error { message: v.req("message")?.as_str()?.to_string() },
            other => bail!("unknown status '{other}'"),
        })
    }

    pub fn parse(line: &str) -> Result<Response> {
        Self::from_json(&Json::parse(line)?)
    }
}

/// Point-in-time metrics snapshot.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StatsSnapshot {
    pub queries: u64,
    pub batches: u64,
    pub errors: u64,
    pub corpus_size: u64,
    pub shards: u64,
    pub sim_evals: u64,
    pub engine_calls: u64,
    pub pruned: u64,
    /// Latency percentiles in microseconds.
    pub latency_us_p50: u64,
    pub latency_us_p99: u64,
    pub latency_us_max: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_round_trips() {
        let reqs = vec![
            Request::Knn { vector: vec![1.0, 2.0], k: 5 },
            Request::Range { vector: vec![-0.5], tau: 0.25 },
            Request::Stats,
            Request::Ping,
        ];
        for r in reqs {
            let line = r.to_json().to_string();
            assert_eq!(Request::parse(&line).unwrap(), r);
        }
    }

    #[test]
    fn response_round_trips() {
        let resps = vec![
            Response::Ok { hits: vec![Hit { id: 3, score: 0.9 }], sim_evals: 17 },
            Response::Stats(StatsSnapshot { queries: 5, corpus_size: 100, ..Default::default() }),
            Response::Pong,
            Response::Error { message: "boom".into() },
        ];
        for r in resps {
            let line = r.to_json().to_string();
            assert_eq!(Response::parse(&line).unwrap(), r);
        }
    }

    #[test]
    fn rejects_unknown_op() {
        assert!(Request::parse(r#"{"op": "explode"}"#).is_err());
        assert!(Request::parse(r#"{"vector": []}"#).is_err());
    }
}
