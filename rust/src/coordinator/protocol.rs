//! Wire protocol of the serving engine: newline-delimited JSON over TCP.
//!
//! Hand-rolled (de)serialization over `util::Json` (serde is unavailable in
//! this offline build); the shapes mirror what a serde-tagged enum would
//! produce: `{"op": "knn", "vector": [...], "k": 10}`.
//!
//! The one search surface (ADR-005) is the versioned `search` op: an
//! envelope carrying the query mode (`knn` / `range` / `knn_within`) plus
//! the per-request options of a [`SearchRequest`] (bound/kernel override,
//! allow/deny filter, evaluation budget), answered by a `search` status
//! with hits, stats, and the truncation flag. The legacy `knn` / `range`
//! ops remain accepted — they parse into plain [`SearchRequest`]s
//! internally and are answered with the original `ok` envelope, byte for
//! byte.

use std::sync::Arc;

use anyhow::Result;

use crate::bounds::BoundKind;
use crate::error::SimetraError;
use crate::obs::{TraceEvent, TraceKind};
use crate::query::{IdFilter, SearchMode, SearchRequest};
use crate::storage::KernelKind;
use crate::util::json::MAX_EXACT_JSON_INT;
use crate::util::json_stream::{Event, PullParser, StrSpan};
use crate::util::Json;

/// A client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// k nearest neighbors by cosine similarity (legacy op; served through
    /// the `search` path as a plain plan, byte-identical reply).
    Knn { vector: Vec<f32>, k: usize },
    /// All items with `sim >= tau` (legacy op; see [`Request::Knn`]).
    Range { vector: Vec<f32>, tau: f64 },
    /// One typed search plan (ADR-005): mode + per-request options.
    Search { vector: Vec<f32>, req: SearchRequest },
    /// A `search` envelope executed with tracing forced on; the reply
    /// carries the bounded traversal event log (EXPLAIN).
    Explain { vector: Vec<f32>, req: SearchRequest },
    /// Insert a vector into a mutable corpus; the reply carries the
    /// assigned id.
    Insert { vector: Vec<f32> },
    /// Tombstone an id in a mutable corpus.
    Delete { id: u64 },
    /// Seal the memtable into a generation now.
    Flush,
    /// Seal, then merge all generations (dropping tombstoned rows).
    Compact,
    /// Server + query statistics.
    Stats,
    /// Prometheus text exposition of the observability registry (shares
    /// the `stats` snapshot path; see `crate::obs`).
    Metrics,
    /// Serving configuration (active kernel backend, index, bound, mode).
    Config,
    /// Health check.
    Ping,
}

/// Wire version of the `search` op envelope.
const SEARCH_VERSION: usize = 1;

impl Request {
    pub fn to_json(&self) -> Json {
        match self {
            Request::Knn { vector, k } => Json::obj(vec![
                ("op", Json::Str("knn".into())),
                ("vector", Json::arr_f32(vector.iter().copied())),
                ("k", Json::Num(*k as f64)),
            ]),
            Request::Range { vector, tau } => Json::obj(vec![
                ("op", Json::Str("range".into())),
                ("vector", Json::arr_f32(vector.iter().copied())),
                ("tau", Json::Num(*tau)),
            ]),
            Request::Search { vector, req } => plan_to_json("search", vector, req),
            Request::Explain { vector, req } => plan_to_json("explain", vector, req),
            Request::Insert { vector } => Json::obj(vec![
                ("op", Json::Str("insert".into())),
                ("vector", Json::arr_f32(vector.iter().copied())),
            ]),
            Request::Delete { id } => Json::obj(vec![
                ("op", Json::Str("delete".into())),
                ("id", Json::Num(*id as f64)),
            ]),
            Request::Flush => Json::obj(vec![("op", Json::Str("flush".into()))]),
            Request::Compact => Json::obj(vec![("op", Json::Str("compact".into()))]),
            Request::Stats => Json::obj(vec![("op", Json::Str("stats".into()))]),
            Request::Metrics => Json::obj(vec![("op", Json::Str("metrics".into()))]),
            Request::Config => Json::obj(vec![("op", Json::Str("config".into()))]),
            Request::Ping => Json::obj(vec![("op", Json::Str("ping".into()))]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Request, SimetraError> {
        let bad = |e: anyhow::Error| SimetraError::BadRequest(e.to_string());
        let op = v.req("op").map_err(bad)?.as_str().map_err(bad)?.to_string();
        match Self::parse_known(&op, v) {
            Ok(Some(req)) => Ok(req),
            Ok(None) => Err(SimetraError::UnknownOp(op)),
            Err(e) => Err(bad(e)),
        }
    }

    /// Parse a known op (`Ok(None)` for an unknown one; field errors are
    /// `Err`).
    fn parse_known(op: &str, v: &Json) -> Result<Option<Request>> {
        Ok(Some(match op {
            "knn" => Request::Knn {
                vector: v.req("vector")?.as_f32_vec()?,
                k: v.req("k")?.as_usize()?,
            },
            "range" => Request::Range {
                vector: v.req("vector")?.as_f32_vec()?,
                tau: v.req("tau")?.as_f64()?,
            },
            "search" => Request::Search {
                vector: v.req("vector")?.as_f32_vec()?,
                req: parse_search_plan(v)?,
            },
            "explain" => {
                // An explain IS a traced search; tracing cannot be opted
                // out of on this op.
                let mut req = parse_search_plan(v)?;
                req.trace = true;
                Request::Explain { vector: v.req("vector")?.as_f32_vec()?, req }
            }
            "insert" => Request::Insert { vector: v.req("vector")?.as_f32_vec()? },
            "delete" => Request::Delete { id: v.req("id")?.as_u64()? },
            "flush" => Request::Flush,
            "compact" => Request::Compact,
            "stats" => Request::Stats,
            "metrics" => Request::Metrics,
            "config" => Request::Config,
            "ping" => Request::Ping,
            _ => return Ok(None),
        }))
    }

    pub fn parse(line: &str) -> Result<Request, SimetraError> {
        let v = Json::parse(line).map_err(|e| SimetraError::BadRequest(e.to_string()))?;
        Self::from_json(&v)
    }
}

/// Serialize a search plan under the given op name (`search` / `explain`).
/// The `trace` field is emitted only on `search` — on `explain` tracing is
/// implied by the op itself.
fn plan_to_json(op: &str, vector: &[f32], req: &SearchRequest) -> Json {
    let mut fields: Vec<(&str, Json)> = vec![
        ("op", Json::Str(op.into())),
        ("v", Json::Num(SEARCH_VERSION as f64)),
        ("vector", Json::arr_f32(vector.iter().copied())),
    ];
    match req.mode {
        SearchMode::Knn { k } => {
            fields.push(("mode", Json::Str("knn".into())));
            fields.push(("k", Json::Num(k as f64)));
        }
        SearchMode::Range { tau } => {
            fields.push(("mode", Json::Str("range".into())));
            fields.push(("tau", Json::Num(tau)));
        }
        SearchMode::KnnWithin { k, tau } => {
            fields.push(("mode", Json::Str("knn_within".into())));
            fields.push(("k", Json::Num(k as f64)));
            fields.push(("tau", Json::Num(tau)));
        }
    }
    if let Some(bound) = req.bound {
        fields.push(("bound", Json::Str(bound.token().into())));
    }
    if let Some(kernel) = req.kernel {
        fields.push(("kernel", Json::Str(kernel.name().into())));
    }
    match &req.filter {
        IdFilter::None => {}
        IdFilter::Allow(ids) => {
            fields.push(("allow", Json::arr_f64(ids.iter().map(|&i| i as f64))));
        }
        IdFilter::Deny(ids) => {
            fields.push(("deny", Json::arr_f64(ids.iter().map(|&i| i as f64))));
        }
    }
    if let Some(budget) = req.budget {
        fields.push(("budget", Json::Num(budget as f64)));
    }
    if req.trace && op == "search" {
        fields.push(("trace", Json::Bool(true)));
    }
    Json::obj(fields)
}

/// Parse the plan fields of a `search` envelope.
fn parse_search_plan(v: &Json) -> Result<SearchRequest> {
    if let Some(ver) = v.get("v") {
        let ver = ver.as_usize()?;
        anyhow::ensure!(ver == SEARCH_VERSION, "unsupported search version {ver}");
    }
    let tau = |v: &Json| -> Result<f64> {
        let tau = v.req("tau")?.as_f64()?;
        anyhow::ensure!(tau.is_finite(), "tau must be finite, got {tau}");
        Ok(tau)
    };
    let mode = match v.req("mode")?.as_str()? {
        "knn" => SearchMode::Knn { k: v.req("k")?.as_usize()? },
        "range" => SearchMode::Range { tau: tau(v)? },
        "knn_within" => SearchMode::KnnWithin { k: v.req("k")?.as_usize()?, tau: tau(v)? },
        other => anyhow::bail!("unknown search mode '{other}'"),
    };
    let bound = match v.get("bound") {
        Some(b) => {
            let name = b.as_str()?;
            Some(
                BoundKind::parse(name)
                    .ok_or_else(|| anyhow::anyhow!("unknown bound '{name}'"))?,
            )
        }
        None => None,
    };
    let kernel = match v.get("kernel") {
        Some(k) => {
            let name = k.as_str()?;
            Some(
                KernelKind::parse(name)
                    .ok_or_else(|| anyhow::anyhow!("unknown kernel '{name}'"))?,
            )
        }
        None => None,
    };
    let sorted_ids = |field: &Json| -> Result<Vec<u64>> {
        let mut ids =
            field.as_arr()?.iter().map(|x| x.as_u64()).collect::<Result<Vec<u64>>>()?;
        ids.sort_unstable();
        ids.dedup();
        Ok(ids)
    };
    let filter = match (v.get("allow"), v.get("deny")) {
        (Some(_), Some(_)) => anyhow::bail!("allow and deny are mutually exclusive"),
        (Some(a), None) => IdFilter::Allow(Arc::new(sorted_ids(a)?)),
        (None, Some(d)) => IdFilter::Deny(Arc::new(sorted_ids(d)?)),
        (None, None) => IdFilter::None,
    };
    let budget = match v.get("budget") {
        Some(b) => Some(b.as_u64()?),
        None => None,
    };
    let trace = match v.get("trace") {
        Some(t) => t.as_bool()?,
        None => false,
    };
    Ok(SearchRequest { mode, bound, kernel, filter, budget, trace })
}

/// One scored hit.
#[derive(Debug, Clone, PartialEq)]
pub struct Hit {
    pub id: u64,
    pub score: f64,
}

/// The reply of one `search` op: hits, the truncation flag, and the
/// query's traversal stats. Also the return type of
/// `Coordinator::search`, so library and wire callers see one shape.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SearchResult {
    pub hits: Vec<Hit>,
    /// Whether an evaluation budget stopped the traversal early (hits are
    /// then exact over the evaluated subset; ADR-005).
    pub truncated: bool,
    /// Exact similarity evaluations spent on this query (pruning power).
    pub sim_evals: u64,
    /// Tree nodes / pivot tables visited.
    pub nodes_visited: u64,
    /// Candidates discarded by a certified bound without an exact
    /// evaluation.
    pub pruned: u64,
    /// Bounded traversal event log — populated only when the request asked
    /// for tracing, and serialized only on the `explain` envelope so the
    /// `search` reply stays byte-identical whether or not it was traced.
    pub trace: Vec<TraceEvent>,
}

/// A server response.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Ok {
        hits: Vec<Hit>,
        /// Exact similarity evaluations spent on this query (pruning power).
        sim_evals: u64,
    },
    /// Reply to the `search` op: hits + stats + truncation envelope.
    Search(SearchResult),
    /// Reply to the `explain` op: the search envelope plus the trace log.
    Explain(SearchResult),
    /// Reply to `insert`: the assigned global id.
    Inserted { id: u64 },
    /// Reply to `delete`: whether the id was live (deleting an unknown or
    /// already-deleted id is a no-op, not an error).
    Deleted { existed: bool },
    /// Acknowledgement of `flush` / `compact`.
    Done,
    Stats(StatsSnapshot),
    Config(ConfigSnapshot),
    /// Reply to `metrics`: Prometheus text exposition.
    Metrics { text: String },
    Pong,
    Error {
        /// Stable machine-readable code (`crate::error::SimetraError::code`;
        /// empty when talking to a pre-ADR-005 server).
        code: String,
        message: String,
    },
}

/// Hits as a JSON array (shared by the `ok` and `search` envelopes).
fn hits_to_json(hits: &[Hit]) -> Json {
    Json::Arr(
        hits.iter()
            .map(|h| {
                Json::obj(vec![("id", Json::Num(h.id as f64)), ("score", Json::Num(h.score))])
            })
            .collect(),
    )
}

fn hits_from_json(v: &Json) -> Result<Vec<Hit>> {
    v.as_arr()?
        .iter()
        .map(|h| Ok(Hit { id: h.req("id")?.as_u64()?, score: h.req("score")?.as_f64()? }))
        .collect()
}

/// Trace events as a JSON array (the `explain` envelope only).
fn trace_to_json(events: &[TraceEvent]) -> Json {
    Json::Arr(
        events
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("kind", Json::Str(e.kind.token().into())),
                    ("id", Json::Num(e.id as f64)),
                    ("bound", Json::Num(e.bound)),
                    ("sim", Json::Num(e.sim)),
                ])
            })
            .collect(),
    )
}

fn trace_from_json(v: &Json) -> Result<Vec<TraceEvent>> {
    v.as_arr()?
        .iter()
        .map(|e| {
            let kind = e.req("kind")?.as_str()?;
            let kind = TraceKind::parse(kind)
                .ok_or_else(|| anyhow::anyhow!("unknown trace kind '{kind}'"))?;
            Ok(TraceEvent {
                kind,
                id: e.req("id")?.as_u64()?,
                bound: e.req("bound")?.as_f64()?,
                sim: e.req("sim")?.as_f64()?,
            })
        })
        .collect()
}

impl Response {
    pub fn to_json(&self) -> Json {
        match self {
            Response::Ok { hits, sim_evals } => Json::obj(vec![
                ("status", Json::Str("ok".into())),
                ("hits", hits_to_json(hits)),
                ("sim_evals", Json::Num(*sim_evals as f64)),
            ]),
            // The `search` reply never serializes the trace: a traced and
            // an untraced search answer with identical bytes.
            Response::Search(r) => Json::obj(vec![
                ("status", Json::Str("search".into())),
                ("hits", hits_to_json(&r.hits)),
                ("truncated", Json::Bool(r.truncated)),
                ("sim_evals", Json::Num(r.sim_evals as f64)),
                ("nodes_visited", Json::Num(r.nodes_visited as f64)),
                ("pruned", Json::Num(r.pruned as f64)),
            ]),
            Response::Explain(r) => Json::obj(vec![
                ("status", Json::Str("explain".into())),
                ("hits", hits_to_json(&r.hits)),
                ("truncated", Json::Bool(r.truncated)),
                ("sim_evals", Json::Num(r.sim_evals as f64)),
                ("nodes_visited", Json::Num(r.nodes_visited as f64)),
                ("pruned", Json::Num(r.pruned as f64)),
                ("trace", trace_to_json(&r.trace)),
            ]),
            Response::Inserted { id } => Json::obj(vec![
                ("status", Json::Str("inserted".into())),
                ("id", Json::Num(*id as f64)),
            ]),
            Response::Deleted { existed } => Json::obj(vec![
                ("status", Json::Str("deleted".into())),
                ("existed", Json::Bool(*existed)),
            ]),
            Response::Done => Json::obj(vec![("status", Json::Str("done".into()))]),
            Response::Config(c) => Json::obj(vec![
                ("status", Json::Str("config".into())),
                ("kernel", Json::Str(c.kernel.clone())),
                ("index", Json::Str(c.index.clone())),
                ("bound", Json::Str(c.bound.clone())),
                ("mode", Json::Str(c.mode.clone())),
                ("shards", Json::Num(c.shards as f64)),
                ("mutable", Json::Bool(c.mutable)),
            ]),
            Response::Stats(s) => Json::obj(vec![
                ("status", Json::Str("stats".into())),
                ("kernel", Json::Str(s.kernel.clone())),
                ("queries", Json::Num(s.queries as f64)),
                ("batches", Json::Num(s.batches as f64)),
                ("errors", Json::Num(s.errors as f64)),
                ("corpus_size", Json::Num(s.corpus_size as f64)),
                ("shards", Json::Num(s.shards as f64)),
                ("sim_evals", Json::Num(s.sim_evals as f64)),
                ("engine_calls", Json::Num(s.engine_calls as f64)),
                ("pruned", Json::Num(s.pruned as f64)),
                ("nodes_visited", Json::Num(s.nodes_visited as f64)),
                ("ctx_reuses", Json::Num(s.ctx_reuses as f64)),
                ("pruned_fraction", Json::Num(s.pruned_fraction)),
                ("latency_us_p50", Json::Num(s.latency_us_p50 as f64)),
                ("latency_us_p99", Json::Num(s.latency_us_p99 as f64)),
                ("latency_us_max", Json::Num(s.latency_us_max as f64)),
                ("latency_us_sum", Json::Num(s.latency_us_sum as f64)),
                (
                    "latency_us_buckets",
                    Json::arr_f64(s.latency_us_buckets.iter().map(|&c| c as f64)),
                ),
                ("generations", Json::Num(s.generations as f64)),
                ("memtable_items", Json::Num(s.memtable_items as f64)),
                ("tombstones", Json::Num(s.tombstones as f64)),
                ("sealed_bytes", Json::Num(s.sealed_bytes as f64)),
                ("inserts", Json::Num(s.inserts as f64)),
                ("deletes", Json::Num(s.deletes as f64)),
                ("seals", Json::Num(s.seals as f64)),
                ("compactions", Json::Num(s.compactions as f64)),
                ("blocked_scan_rows", Json::Num(s.blocked_scan_rows as f64)),
                ("quant_prefilter_rows", Json::Num(s.quant_prefilter_rows as f64)),
                ("quant_rerank_rows", Json::Num(s.quant_rerank_rows as f64)),
                ("bytes_in", Json::Num(s.bytes_in as f64)),
                ("bytes_out", Json::Num(s.bytes_out as f64)),
                ("conns_live", Json::Num(s.conns_live as f64)),
                ("conns_queued", Json::Num(s.conns_queued as f64)),
            ]),
            Response::Metrics { text } => Json::obj(vec![
                ("status", Json::Str("metrics".into())),
                ("text", Json::Str(text.clone())),
            ]),
            Response::Pong => Json::obj(vec![("status", Json::Str("pong".into()))]),
            Response::Error { code, message } => Json::obj(vec![
                ("status", Json::Str("error".into())),
                ("code", Json::Str(code.clone())),
                ("message", Json::Str(message.clone())),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Response> {
        Ok(match v.req("status")?.as_str()? {
            "ok" => Response::Ok {
                hits: hits_from_json(v.req("hits")?)?,
                sim_evals: v.req("sim_evals")?.as_f64()? as u64,
            },
            "search" => Response::Search(SearchResult {
                hits: hits_from_json(v.req("hits")?)?,
                truncated: v.req("truncated")?.as_bool()?,
                sim_evals: v.req("sim_evals")?.as_f64()? as u64,
                nodes_visited: v.req("nodes_visited")?.as_f64()? as u64,
                pruned: v.req("pruned")?.as_f64()? as u64,
                trace: Vec::new(),
            }),
            "explain" => Response::Explain(SearchResult {
                hits: hits_from_json(v.req("hits")?)?,
                truncated: v.req("truncated")?.as_bool()?,
                sim_evals: v.req("sim_evals")?.as_f64()? as u64,
                nodes_visited: v.req("nodes_visited")?.as_f64()? as u64,
                pruned: v.req("pruned")?.as_f64()? as u64,
                trace: trace_from_json(v.req("trace")?)?,
            }),
            "inserted" => Response::Inserted { id: v.req("id")?.as_u64()? },
            "deleted" => Response::Deleted { existed: v.req("existed")?.as_bool()? },
            "done" => Response::Done,
            "config" => Response::Config(ConfigSnapshot {
                kernel: v.req("kernel")?.as_str()?.to_string(),
                index: v.req("index")?.as_str()?.to_string(),
                bound: v.req("bound")?.as_str()?.to_string(),
                mode: v.req("mode")?.as_str()?.to_string(),
                shards: v.req("shards")?.as_f64()? as u64,
                mutable: v.req("mutable")?.as_bool()?,
            }),
            "stats" => {
                let g = |key: &str| -> Result<u64> { Ok(v.req(key)?.as_f64()? as u64) };
                // Wire-path fields are absent in pre-ADR-008 server
                // output: default to zero instead of failing the parse.
                let opt = |key: &str| -> u64 {
                    v.get(key).and_then(|x| x.as_f64().ok()).unwrap_or(0.0) as u64
                };
                Response::Stats(StatsSnapshot {
                    kernel: v.req("kernel")?.as_str()?.to_string(),
                    queries: g("queries")?,
                    batches: g("batches")?,
                    errors: g("errors")?,
                    corpus_size: g("corpus_size")?,
                    shards: g("shards")?,
                    sim_evals: g("sim_evals")?,
                    engine_calls: g("engine_calls")?,
                    pruned: g("pruned")?,
                    nodes_visited: g("nodes_visited")?,
                    ctx_reuses: g("ctx_reuses")?,
                    pruned_fraction: v.req("pruned_fraction")?.as_f64()?,
                    latency_us_p50: g("latency_us_p50")?,
                    latency_us_p99: g("latency_us_p99")?,
                    latency_us_max: g("latency_us_max")?,
                    latency_us_sum: g("latency_us_sum")?,
                    latency_us_buckets: v
                        .req("latency_us_buckets")?
                        .as_arr()?
                        .iter()
                        .map(|x| Ok(x.as_f64()? as u64))
                        .collect::<Result<Vec<u64>>>()?,
                    generations: g("generations")?,
                    memtable_items: g("memtable_items")?,
                    tombstones: g("tombstones")?,
                    sealed_bytes: g("sealed_bytes")?,
                    inserts: g("inserts")?,
                    deletes: g("deletes")?,
                    seals: g("seals")?,
                    compactions: g("compactions")?,
                    blocked_scan_rows: g("blocked_scan_rows")?,
                    quant_prefilter_rows: g("quant_prefilter_rows")?,
                    quant_rerank_rows: g("quant_rerank_rows")?,
                    bytes_in: opt("bytes_in"),
                    bytes_out: opt("bytes_out"),
                    conns_live: opt("conns_live"),
                    conns_queued: opt("conns_queued"),
                })
            }
            "metrics" => Response::Metrics { text: v.req("text")?.as_str()?.to_string() },
            "pong" => Response::Pong,
            "error" => Response::Error {
                // `code` is absent in pre-ADR-005 server output.
                code: v.get("code").and_then(|c| c.as_str().ok()).unwrap_or("").to_string(),
                message: v.req("message")?.as_str()?.to_string(),
            },
            other => anyhow::bail!("unknown status '{other}'"),
        })
    }

    pub fn parse(line: &str) -> Result<Response> {
        Self::from_json(&Json::parse(line)?)
    }
}

// --- streaming wire path (ADR-008) --------------------------------------
//
// The tree-based `Request::parse` / `Response::to_json` above allocate a
// `Vec`/`String` per field per request. The functions below replace them
// on the serving hot path: `parse_wire_streaming` pull-parses the line
// straight into connection scratch, `write_response` serializes into a
// reusable output buffer. Both are conformance-locked to the tree path —
// identical accept/reject decisions and byte-identical output — and the
// tree path stays as the differential oracle (tests/integration_wire.rs).

/// Per-connection parse scratch: the reusable landing buffers the
/// streaming parser fills instead of allocating per request. Query
/// vectors land in `vector`, filter id lists in the pooled `filter_ids`
/// `Arc`, escaped strings decode into `unescape` — after the first few
/// requests warm the capacities, parsing allocates nothing.
#[derive(Debug)]
pub struct WireScratch {
    vector: Vec<f32>,
    filter_ids: Arc<Vec<u64>>,
    unescape: String,
}

impl Default for WireScratch {
    fn default() -> Self {
        Self::new()
    }
}

impl WireScratch {
    pub fn new() -> WireScratch {
        WireScratch {
            vector: Vec::new(),
            filter_ids: Arc::new(Vec::new()),
            unescape: String::new(),
        }
    }

    /// The query vector of the most recently parsed vector-carrying op.
    pub fn vector(&self) -> &[f32] {
        &self.vector
    }
}

/// A parsed request in borrowed form: the streaming twin of [`Request`].
/// Vector-carrying ops leave the query vector in the [`WireScratch`] it
/// was parsed into instead of owning a fresh `Vec<f32>` per request.
#[derive(Debug, Clone, PartialEq)]
pub enum WireOp {
    Knn { k: usize },
    Range { tau: f64 },
    Search { req: SearchRequest },
    Explain { req: SearchRequest },
    Insert,
    Delete { id: u64 },
    Flush,
    Compact,
    Stats,
    Metrics,
    Config,
    Ping,
}

impl WireOp {
    /// Rebuild the owning [`Request`] (tests and compatibility shims;
    /// clones the scratch vector, so not for the hot path).
    pub fn into_request(self, scratch: &WireScratch) -> Request {
        match self {
            WireOp::Knn { k } => Request::Knn { vector: scratch.vector.clone(), k },
            WireOp::Range { tau } => Request::Range { vector: scratch.vector.clone(), tau },
            WireOp::Search { req } => Request::Search { vector: scratch.vector.clone(), req },
            WireOp::Explain { req } => Request::Explain { vector: scratch.vector.clone(), req },
            WireOp::Insert => Request::Insert { vector: scratch.vector.clone() },
            WireOp::Delete { id } => Request::Delete { id },
            WireOp::Flush => Request::Flush,
            WireOp::Compact => Request::Compact,
            WireOp::Stats => Request::Stats,
            WireOp::Metrics => Request::Metrics,
            WireOp::Config => Request::Config,
            WireOp::Ping => Request::Ping,
        }
    }

    /// Decompose an owned [`Request`], parking its vector in `scratch`
    /// (the legacy-fallback path of [`parse_wire`]).
    pub fn from_request(req: Request, scratch: &mut WireScratch) -> WireOp {
        let mut park = |v: Vec<f32>| {
            scratch.vector.clear();
            scratch.vector.extend_from_slice(&v);
        };
        match req {
            Request::Knn { vector, k } => {
                park(vector);
                WireOp::Knn { k }
            }
            Request::Range { vector, tau } => {
                park(vector);
                WireOp::Range { tau }
            }
            Request::Search { vector, req } => {
                park(vector);
                WireOp::Search { req }
            }
            Request::Explain { vector, req } => {
                park(vector);
                WireOp::Explain { req }
            }
            Request::Insert { vector } => {
                park(vector);
                WireOp::Insert
            }
            Request::Delete { id } => WireOp::Delete { id },
            Request::Flush => WireOp::Flush,
            Request::Compact => WireOp::Compact,
            Request::Stats => WireOp::Stats,
            Request::Metrics => WireOp::Metrics,
            Request::Config => WireOp::Config,
            Request::Ping => WireOp::Ping,
        }
    }
}

fn bad_req(e: impl std::fmt::Display) -> SimetraError {
    SimetraError::BadRequest(e.to_string())
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum OpKind {
    Knn,
    Range,
    Search,
    Explain,
    Insert,
    Delete,
    Flush,
    Compact,
    Stats,
    Metrics,
    Config,
    Ping,
}

fn op_kind(name: &str) -> Option<OpKind> {
    Some(match name {
        "knn" => OpKind::Knn,
        "range" => OpKind::Range,
        "search" => OpKind::Search,
        "explain" => OpKind::Explain,
        "insert" => OpKind::Insert,
        "delete" => OpKind::Delete,
        "flush" => OpKind::Flush,
        "compact" => OpKind::Compact,
        "stats" => OpKind::Stats,
        "metrics" => OpKind::Metrics,
        "config" => OpKind::Config,
        "ping" => OpKind::Ping,
        _ => return None,
    })
}

/// A numeric field captured during the field walk. Deferred validation
/// preserves a tree-parser quirk: fields are only *type*-checked when the
/// op actually consumes them (`tau` on a `mode:"knn"` search may hold any
/// JSON value), so capture records what was there and judgement waits.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
enum NumField {
    #[default]
    Missing,
    NotNum,
    Num(f64),
}

#[derive(Debug, Clone, Copy, Default)]
enum StrField<'a> {
    #[default]
    Missing,
    NotStr,
    Str(StrSpan<'a>),
}

#[derive(Debug, Clone, Copy, Default)]
enum BoolField {
    #[default]
    Missing,
    NotBool,
    Bool(bool),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FieldId {
    Vector,
    K,
    Tau,
    Ver,
    Mode,
    Bound,
    Kernel,
    Allow,
    Deny,
    Budget,
    Trace,
    Id,
}

/// The fields each op consumes; everything else on the line is
/// syntax-validated but otherwise ignored, exactly like the tree parser.
fn consumed_fields(op: OpKind) -> &'static [(&'static str, FieldId)] {
    use FieldId::*;
    match op {
        OpKind::Knn => &[("vector", Vector), ("k", K)],
        OpKind::Range => &[("vector", Vector), ("tau", Tau)],
        OpKind::Search | OpKind::Explain => &[
            ("vector", Vector),
            ("v", Ver),
            ("mode", Mode),
            ("k", K),
            ("tau", Tau),
            ("bound", Bound),
            ("kernel", Kernel),
            ("allow", Allow),
            ("deny", Deny),
            ("budget", Budget),
            ("trace", Trace),
        ],
        OpKind::Insert => &[("vector", Vector)],
        OpKind::Delete => &[("id", Id)],
        OpKind::Flush
        | OpKind::Compact
        | OpKind::Stats
        | OpKind::Metrics
        | OpKind::Config
        | OpKind::Ping => &[],
    }
}

#[derive(Default)]
struct Fields<'a> {
    vector: bool,
    k: NumField,
    tau: NumField,
    ver: NumField,
    budget: NumField,
    id: NumField,
    mode: StrField<'a>,
    bound: StrField<'a>,
    kernel: StrField<'a>,
    trace: BoolField,
    allow_seen: bool,
    deny_seen: bool,
}

fn expect_end(p: &mut PullParser) -> Result<(), SimetraError> {
    match p.next().map_err(bad_req)? {
        Event::End => Ok(()),
        _ => Err(SimetraError::BadRequest("trailing characters".into())),
    }
}

/// Pass 1: validate the whole line (syntax, escapes, UTF-8) and resolve
/// the op. The tree parser validates the full document before looking at
/// any field, so the streaming path must too or error *codes* diverge —
/// `unknown_op` is only ever reported for a syntactically valid line.
fn scan_op(line: &[u8], unescape: &mut String) -> Result<OpKind, SimetraError> {
    let mut p = PullParser::new(line);
    match p.next().map_err(bad_req)? {
        Event::ObjBegin => {}
        first => {
            // Not an object: finish validating (syntax errors win over
            // the missing-op error, like the oracle), then reject.
            p.finish_value(first).map_err(bad_req)?;
            expect_end(&mut p)?;
            return Err(SimetraError::BadRequest("missing field 'op'".into()));
        }
    }
    let mut op: Option<StrSpan> = None;
    let mut op_not_string = false;
    let mut op_seen = false;
    loop {
        match p.next().map_err(bad_req)? {
            Event::ObjEnd => break,
            Event::Key(key) => {
                // First duplicate wins, like `Json::get`.
                let is_op = !op_seen && key.eq_decoded("op", unescape);
                let first = p.next().map_err(bad_req)?;
                if is_op {
                    op_seen = true;
                    match first {
                        Event::Str(s) => op = Some(s),
                        other => {
                            op_not_string = true;
                            p.finish_value(other).map_err(bad_req)?;
                        }
                    }
                } else {
                    p.finish_value(first).map_err(bad_req)?;
                }
            }
            _ => unreachable!("object fields always start with a Key event"),
        }
    }
    expect_end(&mut p)?;
    if op_not_string {
        return Err(SimetraError::BadRequest("expected string op".into()));
    }
    let Some(span) = op else {
        return Err(SimetraError::BadRequest("missing field 'op'".into()));
    };
    let name = span.decode(unescape).map_err(bad_req)?;
    op_kind(name).ok_or_else(|| SimetraError::UnknownOp(name.to_string()))
}

/// `Json::as_usize` for a streamed number.
fn num_to_usize(v: f64) -> Result<usize, SimetraError> {
    if v < 0.0 || v.fract() != 0.0 {
        return Err(SimetraError::BadRequest(format!("expected non-negative integer, got {v}")));
    }
    Ok(v as usize)
}

/// `Json::as_u64` for a streamed number, including the 2^53 id guard.
fn num_to_u64(v: f64) -> Result<u64, SimetraError> {
    if v < 0.0 || v.fract() != 0.0 {
        return Err(SimetraError::BadRequest(format!("expected non-negative integer, got {v}")));
    }
    if v >= MAX_EXACT_JSON_INT as f64 {
        return Err(SimetraError::BadRequest(format!(
            "integer {v} is not exactly representable in JSON (>= 2^53)"
        )));
    }
    Ok(v as u64)
}

fn req_num(f: NumField, name: &str) -> Result<f64, SimetraError> {
    match f {
        NumField::Num(n) => Ok(n),
        NumField::NotNum => Err(SimetraError::BadRequest(format!("expected number '{name}'"))),
        NumField::Missing => Err(SimetraError::BadRequest(format!("missing field '{name}'"))),
    }
}

impl NumField {
    /// Capture the next value as this field; the first occurrence wins,
    /// later duplicates are skipped (like `Json::get` on a tree).
    fn capture(self, p: &mut PullParser) -> Result<NumField, SimetraError> {
        let first = p.next().map_err(bad_req)?;
        if self != NumField::Missing {
            p.finish_value(first).map_err(bad_req)?;
            return Ok(self);
        }
        Ok(match first {
            Event::Num(n) => NumField::Num(n),
            other => {
                p.finish_value(other).map_err(bad_req)?;
                NumField::NotNum
            }
        })
    }
}

impl<'a> StrField<'a> {
    fn capture(self, p: &mut PullParser<'a>) -> Result<StrField<'a>, SimetraError> {
        let first = p.next().map_err(bad_req)?;
        if !matches!(self, StrField::Missing) {
            p.finish_value(first).map_err(bad_req)?;
            return Ok(self);
        }
        Ok(match first {
            Event::Str(s) => StrField::Str(s),
            other => {
                p.finish_value(other).map_err(bad_req)?;
                StrField::NotStr
            }
        })
    }
}

impl BoolField {
    fn capture(self, p: &mut PullParser) -> Result<BoolField, SimetraError> {
        let first = p.next().map_err(bad_req)?;
        if !matches!(self, BoolField::Missing) {
            p.finish_value(first).map_err(bad_req)?;
            return Ok(self);
        }
        Ok(match first {
            Event::Bool(b) => BoolField::Bool(b),
            other => {
                p.finish_value(other).map_err(bad_req)?;
                BoolField::NotBool
            }
        })
    }
}

/// Stream a `[f32]` query vector into the scratch buffer.
fn parse_vector(p: &mut PullParser, out: &mut Vec<f32>) -> Result<(), SimetraError> {
    out.clear();
    match p.next().map_err(bad_req)? {
        Event::ArrBegin => {}
        other => {
            p.finish_value(other).map_err(bad_req)?;
            return Err(SimetraError::BadRequest("expected array, got vector".into()));
        }
    }
    loop {
        match p.next().map_err(bad_req)? {
            Event::ArrEnd => return Ok(()),
            Event::Num(n) => out.push(n as f32),
            _ => return Err(SimetraError::BadRequest("expected number in vector".into())),
        }
    }
}

/// Stream a filter id list into the pooled buffer, sorted + deduped with
/// the same per-element checks as `Json::as_u64`.
fn parse_ids(p: &mut PullParser, out: &mut Vec<u64>) -> Result<(), SimetraError> {
    out.clear();
    match p.next().map_err(bad_req)? {
        Event::ArrBegin => {}
        other => {
            p.finish_value(other).map_err(bad_req)?;
            return Err(SimetraError::BadRequest("expected id array".into()));
        }
    }
    loop {
        match p.next().map_err(bad_req)? {
            Event::ArrEnd => break,
            Event::Num(n) => out.push(num_to_u64(n)?),
            _ => return Err(SimetraError::BadRequest("expected id number".into())),
        }
    }
    out.sort_unstable();
    out.dedup();
    Ok(())
}

/// Pass 2: re-walk the (already validated) line capturing the op's
/// consumed fields; everything else is skipped event-by-event.
fn collect_fields<'a>(
    line: &'a [u8],
    table: &[(&'static str, FieldId)],
    vector: &mut Vec<f32>,
    ids: &mut Vec<u64>,
    unescape: &mut String,
) -> Result<Fields<'a>, SimetraError> {
    let mut f = Fields::default();
    let mut p = PullParser::new(line);
    match p.next().map_err(bad_req)? {
        Event::ObjBegin => {}
        _ => return Err(SimetraError::BadRequest("expected object".into())),
    }
    loop {
        match p.next().map_err(bad_req)? {
            Event::ObjEnd => break,
            Event::Key(key) => {
                let fid = table
                    .iter()
                    .find(|(name, _)| key.eq_decoded(name, unescape))
                    .map(|&(_, id)| id);
                match fid {
                    None => p.skip_value().map_err(bad_req)?,
                    Some(FieldId::Vector) => {
                        if f.vector {
                            p.skip_value().map_err(bad_req)?;
                        } else {
                            parse_vector(&mut p, vector)?;
                            f.vector = true;
                        }
                    }
                    Some(FieldId::K) => f.k = f.k.capture(&mut p)?,
                    Some(FieldId::Tau) => f.tau = f.tau.capture(&mut p)?,
                    Some(FieldId::Ver) => f.ver = f.ver.capture(&mut p)?,
                    Some(FieldId::Budget) => f.budget = f.budget.capture(&mut p)?,
                    Some(FieldId::Id) => f.id = f.id.capture(&mut p)?,
                    Some(FieldId::Mode) => f.mode = f.mode.capture(&mut p)?,
                    Some(FieldId::Bound) => f.bound = f.bound.capture(&mut p)?,
                    Some(FieldId::Kernel) => f.kernel = f.kernel.capture(&mut p)?,
                    Some(FieldId::Trace) => f.trace = f.trace.capture(&mut p)?,
                    Some(FieldId::Allow) => {
                        if f.allow_seen {
                            p.skip_value().map_err(bad_req)?;
                        } else if f.deny_seen {
                            return Err(SimetraError::BadRequest(
                                "allow and deny are mutually exclusive".into(),
                            ));
                        } else {
                            parse_ids(&mut p, ids)?;
                            f.allow_seen = true;
                        }
                    }
                    Some(FieldId::Deny) => {
                        if f.deny_seen {
                            p.skip_value().map_err(bad_req)?;
                        } else if f.allow_seen {
                            return Err(SimetraError::BadRequest(
                                "allow and deny are mutually exclusive".into(),
                            ));
                        } else {
                            parse_ids(&mut p, ids)?;
                            f.deny_seen = true;
                        }
                    }
                }
            }
            _ => unreachable!("object fields always start with a Key event"),
        }
    }
    expect_end(&mut p)?;
    Ok(f)
}

/// Assemble a [`SearchRequest`] from captured fields, mirroring
/// `parse_search_plan` (version gate, conditional `k`/`tau` consumption,
/// finite-`tau` check, bound/kernel token parse, filter exclusivity,
/// forced tracing on `explain`).
fn assemble_plan(
    op: OpKind,
    f: &Fields,
    filter_ids: &Arc<Vec<u64>>,
    unescape: &mut String,
) -> Result<SearchRequest, SimetraError> {
    match f.ver {
        NumField::Missing => {}
        NumField::NotNum => return Err(SimetraError::BadRequest("expected number 'v'".into())),
        NumField::Num(n) => {
            let ver = num_to_usize(n)?;
            if ver != SEARCH_VERSION {
                return Err(SimetraError::BadRequest(format!("unsupported search version {ver}")));
            }
        }
    }
    let finite_tau = |tau: f64| -> Result<f64, SimetraError> {
        if tau.is_finite() {
            Ok(tau)
        } else {
            Err(SimetraError::BadRequest(format!("tau must be finite, got {tau}")))
        }
    };
    let mode = {
        let name = match &f.mode {
            StrField::Str(s) => s.decode(unescape).map_err(bad_req)?,
            StrField::NotStr => return Err(SimetraError::BadRequest("expected string mode".into())),
            StrField::Missing => {
                return Err(SimetraError::BadRequest("missing field 'mode'".into()));
            }
        };
        match name {
            "knn" => SearchMode::Knn { k: num_to_usize(req_num(f.k, "k")?)? },
            "range" => SearchMode::Range { tau: finite_tau(req_num(f.tau, "tau")?)? },
            "knn_within" => SearchMode::KnnWithin {
                k: num_to_usize(req_num(f.k, "k")?)?,
                tau: finite_tau(req_num(f.tau, "tau")?)?,
            },
            other => return Err(SimetraError::BadRequest(format!("unknown search mode '{other}'"))),
        }
    };
    let bound = match &f.bound {
        StrField::Missing => None,
        StrField::NotStr => return Err(SimetraError::BadRequest("expected string bound".into())),
        StrField::Str(s) => {
            let name = s.decode(unescape).map_err(bad_req)?;
            Some(
                BoundKind::parse(name)
                    .ok_or_else(|| SimetraError::BadRequest(format!("unknown bound '{name}'")))?,
            )
        }
    };
    let kernel = match &f.kernel {
        StrField::Missing => None,
        StrField::NotStr => return Err(SimetraError::BadRequest("expected string kernel".into())),
        StrField::Str(s) => {
            let name = s.decode(unescape).map_err(bad_req)?;
            Some(
                KernelKind::parse(name)
                    .ok_or_else(|| SimetraError::BadRequest(format!("unknown kernel '{name}'")))?,
            )
        }
    };
    let filter = match (f.allow_seen, f.deny_seen) {
        (true, true) => unreachable!("exclusivity is rejected during the field walk"),
        (true, false) => IdFilter::Allow(filter_ids.clone()),
        (false, true) => IdFilter::Deny(filter_ids.clone()),
        (false, false) => IdFilter::None,
    };
    let budget = match f.budget {
        NumField::Missing => None,
        NumField::NotNum => return Err(SimetraError::BadRequest("expected number 'budget'".into())),
        NumField::Num(n) => Some(num_to_u64(n)?),
    };
    let trace = match f.trace {
        BoolField::Missing => false,
        BoolField::NotBool => return Err(SimetraError::BadRequest("expected bool 'trace'".into())),
        BoolField::Bool(b) => b,
    };
    let trace = trace || op == OpKind::Explain;
    Ok(SearchRequest { mode, bound, kernel, filter, budget, trace })
}

/// Mutable access to the pooled filter-id buffer: reuse the `Arc`'s
/// allocation when this connection holds the only reference (steady
/// state — the previous request's plan has been executed and dropped),
/// fall back to a fresh one while a previous filter is still alive.
fn lease_ids(slot: &mut Arc<Vec<u64>>) -> &mut Vec<u64> {
    if Arc::get_mut(slot).is_none() {
        *slot = Arc::new(Vec::new());
    }
    Arc::get_mut(slot).expect("freshly created Arc has one owner")
}

/// Parse one request line with the streaming pull-parser — no `Json`
/// tree, no per-request allocation: the query vector and filter id list
/// land in `scratch`, escaped strings decode into its scratch buffer.
///
/// Accept/reject decisions and error *codes* match [`Request::parse`]
/// exactly (swept by the differential oracle in
/// `tests/integration_wire.rs`); error *messages* may differ —
/// [`parse_wire`] re-runs the tree parser on the error path so served
/// diagnostics stay byte-identical to the legacy server's.
pub fn parse_wire_streaming(
    line: &[u8],
    scratch: &mut WireScratch,
) -> Result<WireOp, SimetraError> {
    let WireScratch { vector, filter_ids, unescape } = scratch;
    let op = scan_op(line, unescape)?;
    let table = consumed_fields(op);
    if table.is_empty() {
        return Ok(match op {
            OpKind::Flush => WireOp::Flush,
            OpKind::Compact => WireOp::Compact,
            OpKind::Stats => WireOp::Stats,
            OpKind::Metrics => WireOp::Metrics,
            OpKind::Config => WireOp::Config,
            OpKind::Ping => WireOp::Ping,
            _ => unreachable!("field-carrying op with an empty field table"),
        });
    }
    let ids = lease_ids(filter_ids);
    let f = collect_fields(line, table, vector, ids, unescape)?;
    let missing_vector = || SimetraError::BadRequest("missing field 'vector'".into());
    match op {
        OpKind::Knn => {
            if !f.vector {
                return Err(missing_vector());
            }
            Ok(WireOp::Knn { k: num_to_usize(req_num(f.k, "k")?)? })
        }
        OpKind::Range => {
            if !f.vector {
                return Err(missing_vector());
            }
            // The legacy `range` op does NOT finiteness-check tau — only
            // the versioned `search` envelope does. Conformance > taste.
            Ok(WireOp::Range { tau: req_num(f.tau, "tau")? })
        }
        OpKind::Insert => {
            if !f.vector {
                return Err(missing_vector());
            }
            Ok(WireOp::Insert)
        }
        OpKind::Delete => Ok(WireOp::Delete { id: num_to_u64(req_num(f.id, "id")?)? }),
        OpKind::Search | OpKind::Explain => {
            if !f.vector {
                return Err(missing_vector());
            }
            let req = assemble_plan(op, &f, filter_ids, unescape)?;
            Ok(if op == OpKind::Search {
                WireOp::Search { req }
            } else {
                WireOp::Explain { req }
            })
        }
        _ => unreachable!("no-field ops returned above"),
    }
}

/// Parse a request line for serving: the streaming parser first, the
/// tree parser as the diagnostics fallback. The happy path allocates
/// nothing; when the streaming parse rejects, the line is re-parsed
/// through the legacy oracle so served error messages stay byte-identical
/// (and any accept/reject divergence — which the differential tests would
/// catch first — resolves to the oracle's verdict).
pub fn parse_wire(line: &[u8], scratch: &mut WireScratch) -> Result<WireOp, SimetraError> {
    match parse_wire_streaming(line, scratch) {
        Ok(op) => Ok(op),
        Err(stream_err) => match std::str::from_utf8(line) {
            Ok(text) => Request::parse(text).map(|req| WireOp::from_request(req, scratch)),
            // The tree parser never sees invalid UTF-8 (it takes `&str`);
            // keep the streaming error.
            Err(_) => Err(stream_err),
        },
    }
}

fn write_bool(out: &mut String, b: bool) {
    out.push_str(if b { "true" } else { "false" });
}

fn write_hits(hits: &[Hit], out: &mut String) {
    use crate::util::json::write_num;
    out.push('[');
    for (i, h) in hits.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str("{\"id\":");
        write_num(out, h.id as f64);
        out.push_str(",\"score\":");
        write_num(out, h.score);
        out.push('}');
    }
    out.push(']');
}

/// The shared body of the `search` / `explain` envelopes (everything
/// after the status, before the optional trace).
fn write_search_body(r: &SearchResult, out: &mut String) {
    use crate::util::json::write_num;
    out.push_str(",\"hits\":");
    write_hits(&r.hits, out);
    out.push_str(",\"truncated\":");
    write_bool(out, r.truncated);
    out.push_str(",\"sim_evals\":");
    write_num(out, r.sim_evals as f64);
    out.push_str(",\"nodes_visited\":");
    write_num(out, r.nodes_visited as f64);
    out.push_str(",\"pruned\":");
    write_num(out, r.pruned as f64);
}

fn write_stats(s: &StatsSnapshot, out: &mut String) {
    use crate::util::json::{write_escaped, write_num};
    fn field(out: &mut String, key: &str, v: f64) {
        out.push_str(",\"");
        out.push_str(key);
        out.push_str("\":");
        crate::util::json::write_num(out, v);
    }
    out.push_str("{\"status\":\"stats\",\"kernel\":");
    write_escaped(&s.kernel, out);
    field(out, "queries", s.queries as f64);
    field(out, "batches", s.batches as f64);
    field(out, "errors", s.errors as f64);
    field(out, "corpus_size", s.corpus_size as f64);
    field(out, "shards", s.shards as f64);
    field(out, "sim_evals", s.sim_evals as f64);
    field(out, "engine_calls", s.engine_calls as f64);
    field(out, "pruned", s.pruned as f64);
    field(out, "nodes_visited", s.nodes_visited as f64);
    field(out, "ctx_reuses", s.ctx_reuses as f64);
    field(out, "pruned_fraction", s.pruned_fraction);
    field(out, "latency_us_p50", s.latency_us_p50 as f64);
    field(out, "latency_us_p99", s.latency_us_p99 as f64);
    field(out, "latency_us_max", s.latency_us_max as f64);
    field(out, "latency_us_sum", s.latency_us_sum as f64);
    out.push_str(",\"latency_us_buckets\":[");
    for (i, &c) in s.latency_us_buckets.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        write_num(out, c as f64);
    }
    out.push(']');
    field(out, "generations", s.generations as f64);
    field(out, "memtable_items", s.memtable_items as f64);
    field(out, "tombstones", s.tombstones as f64);
    field(out, "sealed_bytes", s.sealed_bytes as f64);
    field(out, "inserts", s.inserts as f64);
    field(out, "deletes", s.deletes as f64);
    field(out, "seals", s.seals as f64);
    field(out, "compactions", s.compactions as f64);
    field(out, "blocked_scan_rows", s.blocked_scan_rows as f64);
    field(out, "quant_prefilter_rows", s.quant_prefilter_rows as f64);
    field(out, "quant_rerank_rows", s.quant_rerank_rows as f64);
    field(out, "bytes_in", s.bytes_in as f64);
    field(out, "bytes_out", s.bytes_out as f64);
    field(out, "conns_live", s.conns_live as f64);
    field(out, "conns_queued", s.conns_queued as f64);
    out.push('}');
}

/// Serialize a [`Response`] into `out` without building a `Json` tree —
/// byte-identical to `resp.to_json().to_string()` by construction (both
/// writers share `util::json::{write_num, write_escaped}`; the
/// differential tests sweep the corpus). The buffer is appended to, not
/// cleared: the server writes one response per drained request and
/// flushes the batch in one syscall.
pub fn write_response(resp: &Response, out: &mut String) {
    use crate::util::json::{write_escaped, write_num};
    match resp {
        Response::Ok { hits, sim_evals } => {
            out.push_str("{\"status\":\"ok\",\"hits\":");
            write_hits(hits, out);
            out.push_str(",\"sim_evals\":");
            write_num(out, *sim_evals as f64);
            out.push('}');
        }
        Response::Search(r) => {
            out.push_str("{\"status\":\"search\"");
            write_search_body(r, out);
            out.push('}');
        }
        Response::Explain(r) => {
            out.push_str("{\"status\":\"explain\"");
            write_search_body(r, out);
            out.push_str(",\"trace\":[");
            for (i, e) in r.trace.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str("{\"kind\":");
                write_escaped(e.kind.token(), out);
                out.push_str(",\"id\":");
                write_num(out, e.id as f64);
                out.push_str(",\"bound\":");
                write_num(out, e.bound);
                out.push_str(",\"sim\":");
                write_num(out, e.sim);
                out.push('}');
            }
            out.push_str("]}");
        }
        Response::Inserted { id } => {
            out.push_str("{\"status\":\"inserted\",\"id\":");
            write_num(out, *id as f64);
            out.push('}');
        }
        Response::Deleted { existed } => {
            out.push_str("{\"status\":\"deleted\",\"existed\":");
            write_bool(out, *existed);
            out.push('}');
        }
        Response::Done => out.push_str("{\"status\":\"done\"}"),
        Response::Config(c) => {
            out.push_str("{\"status\":\"config\",\"kernel\":");
            write_escaped(&c.kernel, out);
            out.push_str(",\"index\":");
            write_escaped(&c.index, out);
            out.push_str(",\"bound\":");
            write_escaped(&c.bound, out);
            out.push_str(",\"mode\":");
            write_escaped(&c.mode, out);
            out.push_str(",\"shards\":");
            write_num(out, c.shards as f64);
            out.push_str(",\"mutable\":");
            write_bool(out, c.mutable);
            out.push('}');
        }
        Response::Stats(s) => write_stats(s, out),
        Response::Metrics { text } => {
            out.push_str("{\"status\":\"metrics\",\"text\":");
            write_escaped(text, out);
            out.push('}');
        }
        Response::Pong => out.push_str("{\"status\":\"pong\"}"),
        Response::Error { code, message } => {
            out.push_str("{\"status\":\"error\",\"code\":");
            write_escaped(code, out);
            out.push_str(",\"message\":");
            write_escaped(message, out);
            out.push('}');
        }
    }
}

/// The serving configuration, fixed at build time (backends and indexes
/// are immutable once a corpus is serving; see ADR-003).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ConfigSnapshot {
    /// Active kernel backend ("scalar", "simd", "i8") for the native scan
    /// paths: index walks, range queries, and hybrid re-scoring. PJRT
    /// artifact scoring (`mode = "engine"` top-k) reads the f32 buffer
    /// directly and bypasses the backend.
    pub kernel: String,
    pub index: String,
    pub bound: String,
    pub mode: String,
    pub shards: u64,
    pub mutable: bool,
}

/// Point-in-time metrics snapshot.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StatsSnapshot {
    /// Active kernel backend ("scalar", "simd", "i8").
    pub kernel: String,
    pub queries: u64,
    pub batches: u64,
    pub errors: u64,
    pub corpus_size: u64,
    pub shards: u64,
    pub sim_evals: u64,
    pub engine_calls: u64,
    /// Candidates discarded by a certified bound without an exact
    /// evaluation, totalled across all served queries (ADR-004 aggregates
    /// every worker's per-query `QueryStats` here).
    pub pruned: u64,
    /// Tree nodes / pivot tables visited, totalled like `pruned`.
    pub nodes_visited: u64,
    /// Queries answered on a reused worker `QueryContext` (scratch-arena
    /// hit count; steady state = every query but each worker's first).
    pub ctx_reuses: u64,
    /// Bound-tightness gauge: `pruned / (pruned + sim_evals)` — the
    /// fraction of candidate decisions resolved by a bound instead of an
    /// exact evaluation. 0.0 on an idle server.
    pub pruned_fraction: f64,
    /// Latency percentiles in microseconds.
    pub latency_us_p50: u64,
    pub latency_us_p99: u64,
    pub latency_us_max: u64,
    /// Total microseconds across all recorded requests (the Prometheus
    /// histogram `_sum`).
    pub latency_us_sum: u64,
    /// Full latency histogram: per-bucket counts over the edges
    /// `[0, 1, 2, 4, 8, ...)`us (bucket 0 holds exactly 0us; bucket
    /// `i >= 1` holds `[2^(i-1), 2^i)`; the last bucket is unbounded).
    pub latency_us_buckets: Vec<u64>,
    /// Ingest gauges (zero for build-once corpora): sealed generations,
    /// staged memtable rows, unresolved tombstones, sealed vector bytes.
    pub generations: u64,
    pub memtable_items: u64,
    pub tombstones: u64,
    pub sealed_bytes: u64,
    /// Ingest lifetime counters (zero for build-once corpora).
    pub inserts: u64,
    pub deletes: u64,
    pub seals: u64,
    pub compactions: u64,
    /// Kernel counters (ADR-003): rows scored exactly by the blocked scan
    /// entry points, rows screened by the i8 pre-filter, and pre-filter
    /// survivors re-ranked through the exact kernel.
    pub blocked_scan_rows: u64,
    pub quant_prefilter_rows: u64,
    pub quant_rerank_rows: u64,
    /// Wire-path byte counters (ADR-008): request bytes read off sockets
    /// and response bytes written back, totalled across all connections.
    pub bytes_in: u64,
    pub bytes_out: u64,
    /// Front-door pool gauges (ADR-008): connections currently open, and
    /// open connections parked in the worker queue awaiting a drain turn.
    pub conns_live: u64,
    pub conns_queued: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_round_trips() {
        let reqs = vec![
            Request::Knn { vector: vec![1.0, 2.0], k: 5 },
            Request::Range { vector: vec![-0.5], tau: 0.25 },
            Request::Insert { vector: vec![0.25, -1.5, 0.0] },
            Request::Delete { id: 123_456 },
            Request::Flush,
            Request::Compact,
            Request::Stats,
            Request::Metrics,
            Request::Config,
            Request::Ping,
        ];
        for r in reqs {
            let line = r.to_json().to_string();
            assert_eq!(Request::parse(&line).unwrap(), r);
        }
    }

    #[test]
    fn search_round_trips_every_mode_and_option_combination() {
        let modes = [
            SearchMode::Knn { k: 7 },
            SearchMode::Range { tau: 0.3 },
            SearchMode::KnnWithin { k: 4, tau: 0.6 },
        ];
        let bounds = [None, Some(BoundKind::Mult), Some(BoundKind::EuclLb)];
        let kernels = [None, Some(KernelKind::Simd), Some(KernelKind::QuantizedI8)];
        let filters = [
            IdFilter::None,
            IdFilter::Allow(Arc::new(vec![1, 5, 9])),
            IdFilter::Deny(Arc::new(vec![0, 2, 4_294_967_296])),
        ];
        let budgets = [None, Some(0u64), Some(123_456)];
        for mode in modes {
            for bound in bounds {
                for kernel in kernels {
                    for filter in &filters {
                        for budget in budgets {
                            let req = SearchRequest {
                                mode,
                                bound,
                                kernel,
                                filter: filter.clone(),
                                budget,
                                trace: false,
                            };
                            let wire =
                                Request::Search { vector: vec![0.5, -0.5], req: req.clone() };
                            let line = wire.to_json().to_string();
                            let back = Request::parse(&line).unwrap();
                            assert_eq!(back, wire, "line: {line}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn traced_search_and_explain_round_trip() {
        let req = SearchRequest::knn(5).trace().build();
        let wire = Request::Search { vector: vec![0.5], req: req.clone() };
        let line = wire.to_json().to_string();
        assert!(line.contains(r#""trace":true"#), "{line}");
        assert_eq!(Request::parse(&line).unwrap(), wire);

        // `explain` implies tracing: the field is never emitted, and a
        // parse always comes back with `trace` forced on.
        let wire = Request::Explain { vector: vec![0.5], req };
        let line = wire.to_json().to_string();
        assert!(!line.contains("trace"), "{line}");
        assert_eq!(Request::parse(&line).unwrap(), wire);
    }

    #[test]
    fn search_rejects_malformed_plans() {
        let base = r#""vector": [1.0]"#;
        for (line, why) in [
            (format!(r#"{{"op": "search", {base}, "mode": "warp", "k": 3}}"#), "unknown mode"),
            (format!(r#"{{"op": "search", {base}, "mode": "knn"}}"#), "missing k"),
            (format!(r#"{{"op": "search", {base}, "mode": "range"}}"#), "missing tau"),
            (
                format!(r#"{{"op": "search", "v": 2, {base}, "mode": "knn", "k": 3}}"#),
                "unsupported version",
            ),
            (
                format!(r#"{{"op": "search", {base}, "mode": "range", "tau": 1e999}}"#),
                "non-finite tau",
            ),
            (
                format!(r#"{{"op":"search",{base},"mode":"knn","k":3,"allow":[1],"deny":[2]}}"#),
                "allow+deny",
            ),
            (
                format!(r#"{{"op": "search", {base}, "mode": "knn", "k": 3, "kernel": "gpu"}}"#),
                "unknown kernel",
            ),
            (
                format!(r#"{{"op": "search", {base}, "mode": "knn", "k": 3, "bound": "best"}}"#),
                "unknown bound",
            ),
        ] {
            let got = Request::parse(&line);
            assert!(got.is_err(), "{why}: {line} parsed as {got:?}");
            assert_eq!(got.unwrap_err().code(), "bad_request", "{why}");
        }
    }

    #[test]
    fn delete_ids_parse_as_u64_with_boundary_checks() {
        // Round-trip at the exactly-representable boundary values.
        for id in [0u64, 1, u32::MAX as u64 + 1, (1u64 << 53) - 1] {
            let r = Request::Delete { id };
            let line = r.to_json().to_string();
            assert_eq!(Request::parse(&line).unwrap(), r, "id {id}");
        }
        // From 2^53 a JSON double no longer represents ids unambiguously
        // (2^53+1 arrives as exactly 2^53): reject instead of silently
        // acting on a neighboring id (and never truncate through usize,
        // which is 32 bits on 32-bit targets).
        for line in [
            r#"{"op": "delete", "id": 9007199254740992}"#, // 2^53
            r#"{"op": "delete", "id": 9007199254740993}"#, // 2^53 + 1: rounds to 2^53
            r#"{"op": "delete", "id": 9007199254740994}"#, // 2^53 + 2
            r#"{"op": "delete", "id": 1e300}"#,
            r#"{"op": "delete", "id": -3}"#,
            r#"{"op": "delete", "id": 1.5}"#,
        ] {
            assert!(Request::parse(line).is_err(), "{line}");
        }
    }

    #[test]
    fn unknown_op_gets_the_typed_code() {
        let err = Request::parse(r#"{"op": "explode"}"#).unwrap_err();
        assert_eq!(err.code(), "unknown_op");
        assert_eq!(err.to_string(), "unknown op 'explode'");
        let err = Request::parse(r#"{"k": 3}"#).unwrap_err();
        assert_eq!(err.code(), "bad_request");
    }

    #[test]
    fn response_round_trips() {
        let resps = vec![
            Response::Ok { hits: vec![Hit { id: 3, score: 0.9 }], sim_evals: 17 },
            Response::Search(SearchResult {
                hits: vec![Hit { id: 9, score: 0.75 }, Hit { id: 2, score: 0.5 }],
                truncated: true,
                sim_evals: 321,
                nodes_visited: 17,
                pruned: 44,
                trace: Vec::new(),
            }),
            Response::Search(SearchResult::default()),
            Response::Explain(SearchResult {
                hits: vec![Hit { id: 9, score: 0.75 }],
                truncated: false,
                sim_evals: 12,
                nodes_visited: 3,
                pruned: 1,
                trace: vec![
                    TraceEvent::visit(7),
                    TraceEvent::prune(3, 0.25),
                    TraceEvent::eval(9, 0.875, 0.75),
                    TraceEvent::scan(64, 16),
                    TraceEvent::budget_stop(),
                ],
            }),
            Response::Metrics { text: "# TYPE simetra_bound_slack histogram\n".into() },
            Response::Inserted { id: 42 },
            Response::Deleted { existed: true },
            Response::Deleted { existed: false },
            Response::Done,
            Response::Stats(StatsSnapshot {
                kernel: "i8".into(),
                queries: 5,
                corpus_size: 100,
                nodes_visited: 42,
                ctx_reuses: 4,
                pruned_fraction: 0.25,
                generations: 3,
                memtable_items: 17,
                tombstones: 2,
                sealed_bytes: 8192,
                inserts: 120,
                deletes: 4,
                seals: 6,
                compactions: 1,
                blocked_scan_rows: 4096,
                quant_prefilter_rows: 2048,
                quant_rerank_rows: 77,
                ..Default::default()
            }),
            Response::Config(ConfigSnapshot {
                kernel: "simd".into(),
                index: "vp".into(),
                bound: "mult".into(),
                mode: "index".into(),
                shards: 4,
                mutable: true,
            }),
            Response::Pong,
            Response::Error { code: "bad_request".into(), message: "boom".into() },
        ];
        for r in resps {
            let line = r.to_json().to_string();
            assert_eq!(Response::parse(&line).unwrap(), r);
        }
        // Pre-ADR-005 error envelopes (no code field) still parse.
        let old = Response::parse(r#"{"status": "error", "message": "boom"}"#).unwrap();
        assert_eq!(old, Response::Error { code: String::new(), message: "boom".into() });
    }

    #[test]
    fn rejects_unknown_op_and_missing_fields() {
        assert!(Request::parse(r#"{"op": "explode"}"#).is_err());
        assert!(Request::parse(r#"{"vector": []}"#).is_err());
        assert!(Request::parse(r#"{"op": "insert"}"#).is_err());
        assert!(Request::parse(r#"{"op": "delete"}"#).is_err());
        assert!(Request::parse(r#"{"op": "delete", "id": -3}"#).is_err());
        assert!(Request::parse(r#"{"op": "insert", "vector": [NaN]}"#).is_err());
    }

    /// Run one line through the streaming parser, rebuilt as an owning
    /// [`Request`] for comparison against the tree oracle.
    fn stream_parse(line: &str) -> Result<Request, SimetraError> {
        let mut scratch = WireScratch::new();
        parse_wire_streaming(line.as_bytes(), &mut scratch).map(|op| op.into_request(&scratch))
    }

    /// Streaming and tree parse must agree: equal requests on accept,
    /// equal error *codes* on reject (messages may differ — `parse_wire`
    /// re-runs the oracle for served diagnostics).
    fn assert_parsers_agree(line: &str) {
        let tree = Request::parse(line);
        let stream = stream_parse(line);
        match (&tree, &stream) {
            (Ok(a), Ok(b)) => assert_eq!(a, b, "line: {line}"),
            (Err(a), Err(b)) => assert_eq!(a.code(), b.code(), "line: {line}\n {a}\n {b}"),
            _ => panic!("parsers diverge on {line}:\n tree {tree:?}\n stream {stream:?}"),
        }
    }

    #[test]
    fn streaming_parse_agrees_with_the_oracle_on_every_round_trip() {
        let mut lines = Vec::new();
        for r in [
            Request::Knn { vector: vec![1.0, 2.0], k: 5 },
            Request::Range { vector: vec![-0.5], tau: 0.25 },
            Request::Insert { vector: vec![0.25, -1.5, 0.0] },
            Request::Delete { id: (1u64 << 53) - 1 },
            Request::Flush,
            Request::Compact,
            Request::Stats,
            Request::Metrics,
            Request::Config,
            Request::Ping,
        ] {
            lines.push(r.to_json().to_string());
        }
        let filters = [
            IdFilter::None,
            IdFilter::Allow(Arc::new(vec![1, 5, 9])),
            IdFilter::Deny(Arc::new(vec![0, 2, 4_294_967_296])),
        ];
        let modes = [
            SearchMode::Knn { k: 7 },
            SearchMode::Range { tau: 0.3 },
            SearchMode::KnnWithin { k: 4, tau: 0.6 },
        ];
        for mode in modes {
            for bound in [None, Some(BoundKind::Mult)] {
                for kernel in [None, Some(KernelKind::QuantizedI8)] {
                    for filter in &filters {
                        for budget in [None, Some(123_456u64)] {
                            for trace in [false, true] {
                                let req = SearchRequest {
                                    mode,
                                    bound,
                                    kernel,
                                    filter: filter.clone(),
                                    budget,
                                    trace,
                                };
                                let v = vec![0.5, -0.5];
                                let s = Request::Search { vector: v.clone(), req: req.clone() };
                                lines.push(s.to_json().to_string());
                                let e = Request::Explain { vector: v, req };
                                lines.push(e.to_json().to_string());
                            }
                        }
                    }
                }
            }
        }
        for line in &lines {
            assert_parsers_agree(line);
            assert!(Request::parse(line).is_ok(), "corpus line must be valid: {line}");
        }
    }

    #[test]
    fn streaming_parse_agrees_with_the_oracle_on_edge_lines() {
        let valid = r#"{"op":"search","vector":[1.0],"mode":"knn","k":3}"#;
        let mut lines: Vec<String> = vec![
            // Field order, duplicates, ignored fields.
            r#"{"vector":[1,2],"k":3,"op":"knn"}"#.into(),
            r#"{"op":"knn","k":3,"k":99,"vector":[1]}"#.into(),
            r#"{"op":"knn","op":"range","vector":[1],"k":1,"tau":0.5}"#.into(),
            r#"{"op":"ping","k":"not a number"}"#.into(),
            r#"{"op":"knn","vector":[1],"k":2,"extra":{"deep":[null,true]}}"#.into(),
            r#"{"op":"range","vector":[1],"tau":0.5,"k":"ignored junk"}"#.into(),
            // Escaped keys and values.
            r#"{"op":"knn","vector":[1],"k":2}"#.into(),
            r#"{"op":"ping"}"#.into(),
            r#"{"op":"search","vector":[1],"mode":"knn","k":1}"#.into(),
            // Numbers in all their glory.
            r#"{"op":"knn","vector":[1],"k":1e1}"#.into(),
            r#"{"op":"knn","vector":[1],"k":3.0}"#.into(),
            r#"{"op":"knn","vector":[1],"k":3.5}"#.into(),
            r#"{"op":"knn","vector":[1],"k":-2}"#.into(),
            r#"{"op":"knn","vector":[1],"k":+5}"#.into(),
            r#"{"op":"range","vector":[1],"tau":1e999}"#.into(),
            r#"{"op":"search","vector":[1],"mode":"range","tau":1e999}"#.into(),
            r#"{"op":"search","vector":[1],"mode":"knn","k":3,"v":1}"#.into(),
            r#"{"op":"search","vector":[1],"mode":"knn","k":3,"v":2}"#.into(),
            r#"{"op":"search","vector":[1],"mode":"knn","k":3,"v":1.5}"#.into(),
            r#"{"op":"delete","id":9007199254740992}"#.into(),
            r#"{"op":"delete","id":9007199254740991}"#.into(),
            r#"{"op":"delete","id":-3}"#.into(),
            r#"{"op":"delete","id":1.5}"#.into(),
            r#"{"op":"insert","vector":[NaN]}"#.into(),
            r#"{"op":"insert","vector":[1,]}"#.into(),
            r#"{"op":"insert","vector":"not an array"}"#.into(),
            // Filters.
            r#"{"op":"search","vector":[1],"mode":"knn","k":3,"allow":[9,1,5,1]}"#.into(),
            r#"{"op":"search","vector":[1],"mode":"knn","k":3,"deny":[]}"#.into(),
            r#"{"op":"search","vector":[1],"mode":"knn","k":3,"allow":[1],"deny":[2]}"#.into(),
            r#"{"op":"search","vector":[1],"mode":"knn","k":3,"allow":[1.5]}"#.into(),
            r#"{"op":"search","vector":[1],"mode":"knn","k":3,"allow":[9007199254740992]}"#.into(),
            // Plan options.
            r#"{"op":"search","vector":[1],"mode":"knn","k":3,"bound":"best"}"#.into(),
            r#"{"op":"search","vector":[1],"mode":"knn","k":3,"kernel":"gpu"}"#.into(),
            r#"{"op":"search","vector":[1],"mode":"knn","k":3,"trace":"yes"}"#.into(),
            r#"{"op":"search","vector":[1],"mode":"knn","k":3,"budget":null}"#.into(),
            r#"{"op":"explain","vector":[1],"mode":"knn","k":3,"trace":false}"#.into(),
            r#"{"op":"search","vector":[1],"mode":"warp"}"#.into(),
            r#"{"op":"search","vector":[1],"mode":7,"k":1}"#.into(),
            // Structure errors and non-object documents.
            "[1,2]".into(),
            "42".into(),
            "{}".into(),
            r#"{"op":null}"#.into(),
            r#"{"op":["knn"]}"#.into(),
            r#"{"op":"explode"}"#.into(),
            r#"{"op":"explode",}"#.into(),
            r#"{"op" "ping"}"#.into(),
            r#"{"op":"ping"} trailing"#.into(),
            "".into(),
            "   ".into(),
            r#" { "op" : "ping" } "#.into(),
            "{\"op\":\t\"ping\"}".into(),
            // Broken strings.
            r#"{"op":"ping","x":"\q"}"#.into(),
            r#"{"op":"ping","x":"\ud800"}"#.into(),
            r#"{"op":"ping","x":"\ud800A"}"#.into(),
            r#"{"op":"ping","x":"😀"}"#.into(),
            r#"{"op":"ping","x":"unterminated"#.into(),
        ];
        // Every truncation of a valid line.
        for cut in 0..valid.len() {
            lines.push(valid[..cut].to_string());
        }
        for line in &lines {
            assert_parsers_agree(line);
        }
    }

    #[test]
    fn streaming_parse_lands_vectors_and_ids_in_scratch() {
        let mut scratch = WireScratch::new();
        let line = br#"{"op":"search","vector":[3.0,4.0],"mode":"knn","k":2,"allow":[9,1,5]}"#;
        let op = parse_wire_streaming(line, &mut scratch).unwrap();
        assert_eq!(scratch.vector(), &[3.0, 4.0]);
        match op {
            WireOp::Search { req } => match req.filter {
                IdFilter::Allow(ids) => assert_eq!(*ids, vec![1, 5, 9]),
                other => panic!("{other:?}"),
            },
            other => panic!("{other:?}"),
        }
        // The pooled id buffer is reused once the previous plan is gone.
        let first = Arc::as_ptr(&scratch.filter_ids);
        parse_wire_streaming(line, &mut scratch).unwrap();
        assert_eq!(Arc::as_ptr(&scratch.filter_ids), first, "id pool must be reused");
    }

    #[test]
    fn parse_wire_serves_legacy_diagnostics_on_errors() {
        let lines = [r#"{"op":"explode"}"#, r#"{"op":"knn","vector":[1]}"#, r#"{not json}"#, "[]"];
        for line in lines {
            let mut scratch = WireScratch::new();
            let stream = parse_wire(line.as_bytes(), &mut scratch).unwrap_err();
            let tree = Request::parse(line).unwrap_err();
            assert_eq!(stream.code(), tree.code(), "{line}");
            assert_eq!(stream.to_string(), tree.to_string(), "{line}");
        }
        // Invalid UTF-8 never reaches the tree parser; the streaming
        // error is served as-is.
        let mut scratch = WireScratch::new();
        assert_eq!(
            parse_wire(b"{\"op\":\"ping\",\"x\":\"\xff\"}", &mut scratch).unwrap_err().code(),
            "bad_request"
        );
    }

    #[test]
    fn write_response_is_byte_identical_to_the_tree_serializer() {
        let resps = vec![
            Response::Ok { hits: vec![Hit { id: 3, score: 0.9 }], sim_evals: 17 },
            Response::Ok { hits: Vec::new(), sim_evals: 0 },
            Response::Search(SearchResult {
                hits: vec![Hit { id: 9, score: 0.75 }, Hit { id: 2, score: -0.5 }],
                truncated: true,
                sim_evals: 321,
                nodes_visited: 17,
                pruned: 44,
                trace: Vec::new(),
            }),
            Response::Search(SearchResult::default()),
            Response::Explain(SearchResult {
                hits: vec![Hit { id: 9, score: 1.0 }],
                truncated: false,
                sim_evals: 12,
                nodes_visited: 3,
                pruned: 1,
                trace: vec![
                    TraceEvent::visit(7),
                    TraceEvent::prune(3, 0.25),
                    TraceEvent::eval(9, 0.875, 0.75),
                    TraceEvent::scan(64, 16),
                    TraceEvent::budget_stop(),
                ],
            }),
            Response::Metrics { text: "# TYPE x counter\nline \"quoted\"\tok\n".into() },
            Response::Inserted { id: (1 << 53) - 1 },
            Response::Deleted { existed: true },
            Response::Deleted { existed: false },
            Response::Done,
            Response::Stats(StatsSnapshot {
                kernel: "i8".into(),
                queries: 5,
                corpus_size: 100,
                nodes_visited: 42,
                ctx_reuses: 4,
                pruned_fraction: 0.247_211,
                latency_us_p50: 12,
                latency_us_p99: 99,
                latency_us_max: 123,
                latency_us_sum: 4567,
                generations: 3,
                memtable_items: 17,
                tombstones: 2,
                sealed_bytes: 8192,
                inserts: 120,
                deletes: 4,
                seals: 6,
                compactions: 1,
                blocked_scan_rows: 4096,
                quant_prefilter_rows: 2048,
                quant_rerank_rows: 77,
                bytes_in: 1024,
                bytes_out: 2048,
                conns_live: 3,
                conns_queued: 1,
                ..Default::default()
            }),
            Response::Config(ConfigSnapshot {
                kernel: "simd".into(),
                index: "vp".into(),
                bound: "mult".into(),
                mode: "index".into(),
                shards: 4,
                mutable: true,
            }),
            Response::Pong,
            Response::Error { code: "bad_request".into(), message: "boom \"q\" \n".into() },
            Response::Error { code: "unknown_op".into(), message: "unknown op 'x'".into() },
        ];
        let mut out = String::new();
        for r in &resps {
            out.clear();
            write_response(r, &mut out);
            assert_eq!(out, r.to_json().to_string(), "{r:?}");
        }
        // The buffer appends (one response per pipelined line), never
        // clears behind the caller's back.
        out.clear();
        write_response(&Response::Pong, &mut out);
        write_response(&Response::Done, &mut out);
        assert_eq!(out, "{\"status\":\"pong\"}{\"status\":\"done\"}");
    }
}
