//! Wire protocol of the serving engine: newline-delimited JSON over TCP.
//!
//! Hand-rolled (de)serialization over `util::Json` (serde is unavailable in
//! this offline build); the shapes mirror what a serde-tagged enum would
//! produce: `{"op": "knn", "vector": [...], "k": 10}`.

use anyhow::{bail, Result};

use crate::util::Json;

/// A client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// k nearest neighbors by cosine similarity.
    Knn { vector: Vec<f32>, k: usize },
    /// All items with `sim >= tau`.
    Range { vector: Vec<f32>, tau: f64 },
    /// Insert a vector into a mutable corpus; the reply carries the
    /// assigned id.
    Insert { vector: Vec<f32> },
    /// Tombstone an id in a mutable corpus.
    Delete { id: u64 },
    /// Seal the memtable into a generation now.
    Flush,
    /// Seal, then merge all generations (dropping tombstoned rows).
    Compact,
    /// Server + query statistics.
    Stats,
    /// Serving configuration (active kernel backend, index, bound, mode).
    Config,
    /// Health check.
    Ping,
}

impl Request {
    pub fn to_json(&self) -> Json {
        match self {
            Request::Knn { vector, k } => Json::obj(vec![
                ("op", Json::Str("knn".into())),
                ("vector", Json::arr_f32(vector.iter().copied())),
                ("k", Json::Num(*k as f64)),
            ]),
            Request::Range { vector, tau } => Json::obj(vec![
                ("op", Json::Str("range".into())),
                ("vector", Json::arr_f32(vector.iter().copied())),
                ("tau", Json::Num(*tau)),
            ]),
            Request::Insert { vector } => Json::obj(vec![
                ("op", Json::Str("insert".into())),
                ("vector", Json::arr_f32(vector.iter().copied())),
            ]),
            Request::Delete { id } => Json::obj(vec![
                ("op", Json::Str("delete".into())),
                ("id", Json::Num(*id as f64)),
            ]),
            Request::Flush => Json::obj(vec![("op", Json::Str("flush".into()))]),
            Request::Compact => Json::obj(vec![("op", Json::Str("compact".into()))]),
            Request::Stats => Json::obj(vec![("op", Json::Str("stats".into()))]),
            Request::Config => Json::obj(vec![("op", Json::Str("config".into()))]),
            Request::Ping => Json::obj(vec![("op", Json::Str("ping".into()))]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Request> {
        Ok(match v.req("op")?.as_str()? {
            "knn" => Request::Knn {
                vector: v.req("vector")?.as_f32_vec()?,
                k: v.req("k")?.as_usize()?,
            },
            "range" => Request::Range {
                vector: v.req("vector")?.as_f32_vec()?,
                tau: v.req("tau")?.as_f64()?,
            },
            "insert" => Request::Insert { vector: v.req("vector")?.as_f32_vec()? },
            "delete" => Request::Delete { id: v.req("id")?.as_usize()? as u64 },
            "flush" => Request::Flush,
            "compact" => Request::Compact,
            "stats" => Request::Stats,
            "config" => Request::Config,
            "ping" => Request::Ping,
            other => bail!("unknown op '{other}'"),
        })
    }

    pub fn parse(line: &str) -> Result<Request> {
        Self::from_json(&Json::parse(line)?)
    }
}

/// One scored hit.
#[derive(Debug, Clone, PartialEq)]
pub struct Hit {
    pub id: u64,
    pub score: f64,
}

/// A server response.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Ok {
        hits: Vec<Hit>,
        /// Exact similarity evaluations spent on this query (pruning power).
        sim_evals: u64,
    },
    /// Reply to `insert`: the assigned global id.
    Inserted { id: u64 },
    /// Reply to `delete`: whether the id was live (deleting an unknown or
    /// already-deleted id is a no-op, not an error).
    Deleted { existed: bool },
    /// Acknowledgement of `flush` / `compact`.
    Done,
    Stats(StatsSnapshot),
    Config(ConfigSnapshot),
    Pong,
    Error { message: String },
}

impl Response {
    pub fn to_json(&self) -> Json {
        match self {
            Response::Ok { hits, sim_evals } => Json::obj(vec![
                ("status", Json::Str("ok".into())),
                (
                    "hits",
                    Json::Arr(
                        hits.iter()
                            .map(|h| {
                                Json::obj(vec![
                                    ("id", Json::Num(h.id as f64)),
                                    ("score", Json::Num(h.score)),
                                ])
                            })
                            .collect(),
                    ),
                ),
                ("sim_evals", Json::Num(*sim_evals as f64)),
            ]),
            Response::Inserted { id } => Json::obj(vec![
                ("status", Json::Str("inserted".into())),
                ("id", Json::Num(*id as f64)),
            ]),
            Response::Deleted { existed } => Json::obj(vec![
                ("status", Json::Str("deleted".into())),
                ("existed", Json::Bool(*existed)),
            ]),
            Response::Done => Json::obj(vec![("status", Json::Str("done".into()))]),
            Response::Config(c) => Json::obj(vec![
                ("status", Json::Str("config".into())),
                ("kernel", Json::Str(c.kernel.clone())),
                ("index", Json::Str(c.index.clone())),
                ("bound", Json::Str(c.bound.clone())),
                ("mode", Json::Str(c.mode.clone())),
                ("shards", Json::Num(c.shards as f64)),
                ("mutable", Json::Bool(c.mutable)),
            ]),
            Response::Stats(s) => Json::obj(vec![
                ("status", Json::Str("stats".into())),
                ("kernel", Json::Str(s.kernel.clone())),
                ("queries", Json::Num(s.queries as f64)),
                ("batches", Json::Num(s.batches as f64)),
                ("errors", Json::Num(s.errors as f64)),
                ("corpus_size", Json::Num(s.corpus_size as f64)),
                ("shards", Json::Num(s.shards as f64)),
                ("sim_evals", Json::Num(s.sim_evals as f64)),
                ("engine_calls", Json::Num(s.engine_calls as f64)),
                ("pruned", Json::Num(s.pruned as f64)),
                ("nodes_visited", Json::Num(s.nodes_visited as f64)),
                ("ctx_reuses", Json::Num(s.ctx_reuses as f64)),
                ("pruned_fraction", Json::Num(s.pruned_fraction)),
                ("latency_us_p50", Json::Num(s.latency_us_p50 as f64)),
                ("latency_us_p99", Json::Num(s.latency_us_p99 as f64)),
                ("latency_us_max", Json::Num(s.latency_us_max as f64)),
                ("generations", Json::Num(s.generations as f64)),
                ("memtable_items", Json::Num(s.memtable_items as f64)),
                ("tombstones", Json::Num(s.tombstones as f64)),
                ("sealed_bytes", Json::Num(s.sealed_bytes as f64)),
                ("inserts", Json::Num(s.inserts as f64)),
                ("deletes", Json::Num(s.deletes as f64)),
                ("seals", Json::Num(s.seals as f64)),
                ("compactions", Json::Num(s.compactions as f64)),
                ("blocked_scan_rows", Json::Num(s.blocked_scan_rows as f64)),
                ("quant_prefilter_rows", Json::Num(s.quant_prefilter_rows as f64)),
                ("quant_rerank_rows", Json::Num(s.quant_rerank_rows as f64)),
            ]),
            Response::Pong => Json::obj(vec![("status", Json::Str("pong".into()))]),
            Response::Error { message } => Json::obj(vec![
                ("status", Json::Str("error".into())),
                ("message", Json::Str(message.clone())),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Response> {
        Ok(match v.req("status")?.as_str()? {
            "ok" => Response::Ok {
                hits: v
                    .req("hits")?
                    .as_arr()?
                    .iter()
                    .map(|h| {
                        Ok(Hit {
                            id: h.req("id")?.as_f64()? as u64,
                            score: h.req("score")?.as_f64()?,
                        })
                    })
                    .collect::<Result<_>>()?,
                sim_evals: v.req("sim_evals")?.as_f64()? as u64,
            },
            "inserted" => Response::Inserted { id: v.req("id")?.as_usize()? as u64 },
            "deleted" => Response::Deleted { existed: v.req("existed")?.as_bool()? },
            "done" => Response::Done,
            "config" => Response::Config(ConfigSnapshot {
                kernel: v.req("kernel")?.as_str()?.to_string(),
                index: v.req("index")?.as_str()?.to_string(),
                bound: v.req("bound")?.as_str()?.to_string(),
                mode: v.req("mode")?.as_str()?.to_string(),
                shards: v.req("shards")?.as_f64()? as u64,
                mutable: v.req("mutable")?.as_bool()?,
            }),
            "stats" => {
                let g = |key: &str| -> Result<u64> { Ok(v.req(key)?.as_f64()? as u64) };
                Response::Stats(StatsSnapshot {
                    kernel: v.req("kernel")?.as_str()?.to_string(),
                    queries: g("queries")?,
                    batches: g("batches")?,
                    errors: g("errors")?,
                    corpus_size: g("corpus_size")?,
                    shards: g("shards")?,
                    sim_evals: g("sim_evals")?,
                    engine_calls: g("engine_calls")?,
                    pruned: g("pruned")?,
                    nodes_visited: g("nodes_visited")?,
                    ctx_reuses: g("ctx_reuses")?,
                    pruned_fraction: v.req("pruned_fraction")?.as_f64()?,
                    latency_us_p50: g("latency_us_p50")?,
                    latency_us_p99: g("latency_us_p99")?,
                    latency_us_max: g("latency_us_max")?,
                    generations: g("generations")?,
                    memtable_items: g("memtable_items")?,
                    tombstones: g("tombstones")?,
                    sealed_bytes: g("sealed_bytes")?,
                    inserts: g("inserts")?,
                    deletes: g("deletes")?,
                    seals: g("seals")?,
                    compactions: g("compactions")?,
                    blocked_scan_rows: g("blocked_scan_rows")?,
                    quant_prefilter_rows: g("quant_prefilter_rows")?,
                    quant_rerank_rows: g("quant_rerank_rows")?,
                })
            }
            "pong" => Response::Pong,
            "error" => Response::Error { message: v.req("message")?.as_str()?.to_string() },
            other => bail!("unknown status '{other}'"),
        })
    }

    pub fn parse(line: &str) -> Result<Response> {
        Self::from_json(&Json::parse(line)?)
    }
}

/// The serving configuration, fixed at build time (backends and indexes
/// are immutable once a corpus is serving; see ADR-003).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ConfigSnapshot {
    /// Active kernel backend ("scalar", "simd", "i8") for the native scan
    /// paths: index walks, range queries, and hybrid re-scoring. PJRT
    /// artifact scoring (`mode = "engine"` top-k) reads the f32 buffer
    /// directly and bypasses the backend.
    pub kernel: String,
    pub index: String,
    pub bound: String,
    pub mode: String,
    pub shards: u64,
    pub mutable: bool,
}

/// Point-in-time metrics snapshot.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StatsSnapshot {
    /// Active kernel backend ("scalar", "simd", "i8").
    pub kernel: String,
    pub queries: u64,
    pub batches: u64,
    pub errors: u64,
    pub corpus_size: u64,
    pub shards: u64,
    pub sim_evals: u64,
    pub engine_calls: u64,
    /// Candidates discarded by a certified bound without an exact
    /// evaluation, totalled across all served queries (ADR-004 aggregates
    /// every worker's per-query `QueryStats` here).
    pub pruned: u64,
    /// Tree nodes / pivot tables visited, totalled like `pruned`.
    pub nodes_visited: u64,
    /// Queries answered on a reused worker `QueryContext` (scratch-arena
    /// hit count; steady state = every query but each worker's first).
    pub ctx_reuses: u64,
    /// Bound-tightness gauge: `pruned / (pruned + sim_evals)` — the
    /// fraction of candidate decisions resolved by a bound instead of an
    /// exact evaluation. 0.0 on an idle server.
    pub pruned_fraction: f64,
    /// Latency percentiles in microseconds.
    pub latency_us_p50: u64,
    pub latency_us_p99: u64,
    pub latency_us_max: u64,
    /// Ingest gauges (zero for build-once corpora): sealed generations,
    /// staged memtable rows, unresolved tombstones, sealed vector bytes.
    pub generations: u64,
    pub memtable_items: u64,
    pub tombstones: u64,
    pub sealed_bytes: u64,
    /// Ingest lifetime counters (zero for build-once corpora).
    pub inserts: u64,
    pub deletes: u64,
    pub seals: u64,
    pub compactions: u64,
    /// Kernel counters (ADR-003): rows scored exactly by the blocked scan
    /// entry points, rows screened by the i8 pre-filter, and pre-filter
    /// survivors re-ranked through the exact kernel.
    pub blocked_scan_rows: u64,
    pub quant_prefilter_rows: u64,
    pub quant_rerank_rows: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_round_trips() {
        let reqs = vec![
            Request::Knn { vector: vec![1.0, 2.0], k: 5 },
            Request::Range { vector: vec![-0.5], tau: 0.25 },
            Request::Insert { vector: vec![0.25, -1.5, 0.0] },
            Request::Delete { id: 123_456 },
            Request::Flush,
            Request::Compact,
            Request::Stats,
            Request::Config,
            Request::Ping,
        ];
        for r in reqs {
            let line = r.to_json().to_string();
            assert_eq!(Request::parse(&line).unwrap(), r);
        }
    }

    #[test]
    fn response_round_trips() {
        let resps = vec![
            Response::Ok { hits: vec![Hit { id: 3, score: 0.9 }], sim_evals: 17 },
            Response::Inserted { id: 42 },
            Response::Deleted { existed: true },
            Response::Deleted { existed: false },
            Response::Done,
            Response::Stats(StatsSnapshot {
                kernel: "i8".into(),
                queries: 5,
                corpus_size: 100,
                nodes_visited: 42,
                ctx_reuses: 4,
                pruned_fraction: 0.25,
                generations: 3,
                memtable_items: 17,
                tombstones: 2,
                sealed_bytes: 8192,
                inserts: 120,
                deletes: 4,
                seals: 6,
                compactions: 1,
                blocked_scan_rows: 4096,
                quant_prefilter_rows: 2048,
                quant_rerank_rows: 77,
                ..Default::default()
            }),
            Response::Config(ConfigSnapshot {
                kernel: "simd".into(),
                index: "vp".into(),
                bound: "mult".into(),
                mode: "index".into(),
                shards: 4,
                mutable: true,
            }),
            Response::Pong,
            Response::Error { message: "boom".into() },
        ];
        for r in resps {
            let line = r.to_json().to_string();
            assert_eq!(Response::parse(&line).unwrap(), r);
        }
    }

    #[test]
    fn rejects_unknown_op_and_missing_fields() {
        assert!(Request::parse(r#"{"op": "explode"}"#).is_err());
        assert!(Request::parse(r#"{"vector": []}"#).is_err());
        assert!(Request::parse(r#"{"op": "insert"}"#).is_err());
        assert!(Request::parse(r#"{"op": "delete"}"#).is_err());
        assert!(Request::parse(r#"{"op": "delete", "id": -3}"#).is_err());
        assert!(Request::parse(r#"{"op": "insert", "vector": [NaN]}"#).is_err());
    }
}
