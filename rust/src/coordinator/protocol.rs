//! Wire protocol of the serving engine: newline-delimited JSON over TCP.
//!
//! Hand-rolled (de)serialization over `util::Json` (serde is unavailable in
//! this offline build); the shapes mirror what a serde-tagged enum would
//! produce: `{"op": "knn", "vector": [...], "k": 10}`.
//!
//! The one search surface (ADR-005) is the versioned `search` op: an
//! envelope carrying the query mode (`knn` / `range` / `knn_within`) plus
//! the per-request options of a [`SearchRequest`] (bound/kernel override,
//! allow/deny filter, evaluation budget), answered by a `search` status
//! with hits, stats, and the truncation flag. The legacy `knn` / `range`
//! ops remain accepted — they parse into plain [`SearchRequest`]s
//! internally and are answered with the original `ok` envelope, byte for
//! byte.

use std::sync::Arc;

use anyhow::Result;

use crate::bounds::BoundKind;
use crate::error::SimetraError;
use crate::obs::{TraceEvent, TraceKind};
use crate::query::{IdFilter, SearchMode, SearchRequest};
use crate::storage::KernelKind;
use crate::util::Json;

/// A client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// k nearest neighbors by cosine similarity (legacy op; served through
    /// the `search` path as a plain plan, byte-identical reply).
    Knn { vector: Vec<f32>, k: usize },
    /// All items with `sim >= tau` (legacy op; see [`Request::Knn`]).
    Range { vector: Vec<f32>, tau: f64 },
    /// One typed search plan (ADR-005): mode + per-request options.
    Search { vector: Vec<f32>, req: SearchRequest },
    /// A `search` envelope executed with tracing forced on; the reply
    /// carries the bounded traversal event log (EXPLAIN).
    Explain { vector: Vec<f32>, req: SearchRequest },
    /// Insert a vector into a mutable corpus; the reply carries the
    /// assigned id.
    Insert { vector: Vec<f32> },
    /// Tombstone an id in a mutable corpus.
    Delete { id: u64 },
    /// Seal the memtable into a generation now.
    Flush,
    /// Seal, then merge all generations (dropping tombstoned rows).
    Compact,
    /// Server + query statistics.
    Stats,
    /// Prometheus text exposition of the observability registry (shares
    /// the `stats` snapshot path; see `crate::obs`).
    Metrics,
    /// Serving configuration (active kernel backend, index, bound, mode).
    Config,
    /// Health check.
    Ping,
}

/// Wire version of the `search` op envelope.
const SEARCH_VERSION: usize = 1;

impl Request {
    pub fn to_json(&self) -> Json {
        match self {
            Request::Knn { vector, k } => Json::obj(vec![
                ("op", Json::Str("knn".into())),
                ("vector", Json::arr_f32(vector.iter().copied())),
                ("k", Json::Num(*k as f64)),
            ]),
            Request::Range { vector, tau } => Json::obj(vec![
                ("op", Json::Str("range".into())),
                ("vector", Json::arr_f32(vector.iter().copied())),
                ("tau", Json::Num(*tau)),
            ]),
            Request::Search { vector, req } => plan_to_json("search", vector, req),
            Request::Explain { vector, req } => plan_to_json("explain", vector, req),
            Request::Insert { vector } => Json::obj(vec![
                ("op", Json::Str("insert".into())),
                ("vector", Json::arr_f32(vector.iter().copied())),
            ]),
            Request::Delete { id } => Json::obj(vec![
                ("op", Json::Str("delete".into())),
                ("id", Json::Num(*id as f64)),
            ]),
            Request::Flush => Json::obj(vec![("op", Json::Str("flush".into()))]),
            Request::Compact => Json::obj(vec![("op", Json::Str("compact".into()))]),
            Request::Stats => Json::obj(vec![("op", Json::Str("stats".into()))]),
            Request::Metrics => Json::obj(vec![("op", Json::Str("metrics".into()))]),
            Request::Config => Json::obj(vec![("op", Json::Str("config".into()))]),
            Request::Ping => Json::obj(vec![("op", Json::Str("ping".into()))]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Request, SimetraError> {
        let bad = |e: anyhow::Error| SimetraError::BadRequest(e.to_string());
        let op = v.req("op").map_err(bad)?.as_str().map_err(bad)?.to_string();
        match Self::parse_known(&op, v) {
            Ok(Some(req)) => Ok(req),
            Ok(None) => Err(SimetraError::UnknownOp(op)),
            Err(e) => Err(bad(e)),
        }
    }

    /// Parse a known op (`Ok(None)` for an unknown one; field errors are
    /// `Err`).
    fn parse_known(op: &str, v: &Json) -> Result<Option<Request>> {
        Ok(Some(match op {
            "knn" => Request::Knn {
                vector: v.req("vector")?.as_f32_vec()?,
                k: v.req("k")?.as_usize()?,
            },
            "range" => Request::Range {
                vector: v.req("vector")?.as_f32_vec()?,
                tau: v.req("tau")?.as_f64()?,
            },
            "search" => Request::Search {
                vector: v.req("vector")?.as_f32_vec()?,
                req: parse_search_plan(v)?,
            },
            "explain" => {
                // An explain IS a traced search; tracing cannot be opted
                // out of on this op.
                let mut req = parse_search_plan(v)?;
                req.trace = true;
                Request::Explain { vector: v.req("vector")?.as_f32_vec()?, req }
            }
            "insert" => Request::Insert { vector: v.req("vector")?.as_f32_vec()? },
            "delete" => Request::Delete { id: v.req("id")?.as_u64()? },
            "flush" => Request::Flush,
            "compact" => Request::Compact,
            "stats" => Request::Stats,
            "metrics" => Request::Metrics,
            "config" => Request::Config,
            "ping" => Request::Ping,
            _ => return Ok(None),
        }))
    }

    pub fn parse(line: &str) -> Result<Request, SimetraError> {
        let v = Json::parse(line).map_err(|e| SimetraError::BadRequest(e.to_string()))?;
        Self::from_json(&v)
    }
}

/// Serialize a search plan under the given op name (`search` / `explain`).
/// The `trace` field is emitted only on `search` — on `explain` tracing is
/// implied by the op itself.
fn plan_to_json(op: &str, vector: &[f32], req: &SearchRequest) -> Json {
    let mut fields: Vec<(&str, Json)> = vec![
        ("op", Json::Str(op.into())),
        ("v", Json::Num(SEARCH_VERSION as f64)),
        ("vector", Json::arr_f32(vector.iter().copied())),
    ];
    match req.mode {
        SearchMode::Knn { k } => {
            fields.push(("mode", Json::Str("knn".into())));
            fields.push(("k", Json::Num(k as f64)));
        }
        SearchMode::Range { tau } => {
            fields.push(("mode", Json::Str("range".into())));
            fields.push(("tau", Json::Num(tau)));
        }
        SearchMode::KnnWithin { k, tau } => {
            fields.push(("mode", Json::Str("knn_within".into())));
            fields.push(("k", Json::Num(k as f64)));
            fields.push(("tau", Json::Num(tau)));
        }
    }
    if let Some(bound) = req.bound {
        fields.push(("bound", Json::Str(bound.token().into())));
    }
    if let Some(kernel) = req.kernel {
        fields.push(("kernel", Json::Str(kernel.name().into())));
    }
    match &req.filter {
        IdFilter::None => {}
        IdFilter::Allow(ids) => {
            fields.push(("allow", Json::arr_f64(ids.iter().map(|&i| i as f64))));
        }
        IdFilter::Deny(ids) => {
            fields.push(("deny", Json::arr_f64(ids.iter().map(|&i| i as f64))));
        }
    }
    if let Some(budget) = req.budget {
        fields.push(("budget", Json::Num(budget as f64)));
    }
    if req.trace && op == "search" {
        fields.push(("trace", Json::Bool(true)));
    }
    Json::obj(fields)
}

/// Parse the plan fields of a `search` envelope.
fn parse_search_plan(v: &Json) -> Result<SearchRequest> {
    if let Some(ver) = v.get("v") {
        let ver = ver.as_usize()?;
        anyhow::ensure!(ver == SEARCH_VERSION, "unsupported search version {ver}");
    }
    let tau = |v: &Json| -> Result<f64> {
        let tau = v.req("tau")?.as_f64()?;
        anyhow::ensure!(tau.is_finite(), "tau must be finite, got {tau}");
        Ok(tau)
    };
    let mode = match v.req("mode")?.as_str()? {
        "knn" => SearchMode::Knn { k: v.req("k")?.as_usize()? },
        "range" => SearchMode::Range { tau: tau(v)? },
        "knn_within" => SearchMode::KnnWithin { k: v.req("k")?.as_usize()?, tau: tau(v)? },
        other => anyhow::bail!("unknown search mode '{other}'"),
    };
    let bound = match v.get("bound") {
        Some(b) => {
            let name = b.as_str()?;
            Some(
                BoundKind::parse(name)
                    .ok_or_else(|| anyhow::anyhow!("unknown bound '{name}'"))?,
            )
        }
        None => None,
    };
    let kernel = match v.get("kernel") {
        Some(k) => {
            let name = k.as_str()?;
            Some(
                KernelKind::parse(name)
                    .ok_or_else(|| anyhow::anyhow!("unknown kernel '{name}'"))?,
            )
        }
        None => None,
    };
    let sorted_ids = |field: &Json| -> Result<Vec<u64>> {
        let mut ids =
            field.as_arr()?.iter().map(|x| x.as_u64()).collect::<Result<Vec<u64>>>()?;
        ids.sort_unstable();
        ids.dedup();
        Ok(ids)
    };
    let filter = match (v.get("allow"), v.get("deny")) {
        (Some(_), Some(_)) => anyhow::bail!("allow and deny are mutually exclusive"),
        (Some(a), None) => IdFilter::Allow(Arc::new(sorted_ids(a)?)),
        (None, Some(d)) => IdFilter::Deny(Arc::new(sorted_ids(d)?)),
        (None, None) => IdFilter::None,
    };
    let budget = match v.get("budget") {
        Some(b) => Some(b.as_u64()?),
        None => None,
    };
    let trace = match v.get("trace") {
        Some(t) => t.as_bool()?,
        None => false,
    };
    Ok(SearchRequest { mode, bound, kernel, filter, budget, trace })
}

/// One scored hit.
#[derive(Debug, Clone, PartialEq)]
pub struct Hit {
    pub id: u64,
    pub score: f64,
}

/// The reply of one `search` op: hits, the truncation flag, and the
/// query's traversal stats. Also the return type of
/// `Coordinator::search`, so library and wire callers see one shape.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SearchResult {
    pub hits: Vec<Hit>,
    /// Whether an evaluation budget stopped the traversal early (hits are
    /// then exact over the evaluated subset; ADR-005).
    pub truncated: bool,
    /// Exact similarity evaluations spent on this query (pruning power).
    pub sim_evals: u64,
    /// Tree nodes / pivot tables visited.
    pub nodes_visited: u64,
    /// Candidates discarded by a certified bound without an exact
    /// evaluation.
    pub pruned: u64,
    /// Bounded traversal event log — populated only when the request asked
    /// for tracing, and serialized only on the `explain` envelope so the
    /// `search` reply stays byte-identical whether or not it was traced.
    pub trace: Vec<TraceEvent>,
}

/// A server response.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Ok {
        hits: Vec<Hit>,
        /// Exact similarity evaluations spent on this query (pruning power).
        sim_evals: u64,
    },
    /// Reply to the `search` op: hits + stats + truncation envelope.
    Search(SearchResult),
    /// Reply to the `explain` op: the search envelope plus the trace log.
    Explain(SearchResult),
    /// Reply to `insert`: the assigned global id.
    Inserted { id: u64 },
    /// Reply to `delete`: whether the id was live (deleting an unknown or
    /// already-deleted id is a no-op, not an error).
    Deleted { existed: bool },
    /// Acknowledgement of `flush` / `compact`.
    Done,
    Stats(StatsSnapshot),
    Config(ConfigSnapshot),
    /// Reply to `metrics`: Prometheus text exposition.
    Metrics { text: String },
    Pong,
    Error {
        /// Stable machine-readable code (`crate::error::SimetraError::code`;
        /// empty when talking to a pre-ADR-005 server).
        code: String,
        message: String,
    },
}

/// Hits as a JSON array (shared by the `ok` and `search` envelopes).
fn hits_to_json(hits: &[Hit]) -> Json {
    Json::Arr(
        hits.iter()
            .map(|h| {
                Json::obj(vec![("id", Json::Num(h.id as f64)), ("score", Json::Num(h.score))])
            })
            .collect(),
    )
}

fn hits_from_json(v: &Json) -> Result<Vec<Hit>> {
    v.as_arr()?
        .iter()
        .map(|h| Ok(Hit { id: h.req("id")?.as_u64()?, score: h.req("score")?.as_f64()? }))
        .collect()
}

/// Trace events as a JSON array (the `explain` envelope only).
fn trace_to_json(events: &[TraceEvent]) -> Json {
    Json::Arr(
        events
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("kind", Json::Str(e.kind.token().into())),
                    ("id", Json::Num(e.id as f64)),
                    ("bound", Json::Num(e.bound)),
                    ("sim", Json::Num(e.sim)),
                ])
            })
            .collect(),
    )
}

fn trace_from_json(v: &Json) -> Result<Vec<TraceEvent>> {
    v.as_arr()?
        .iter()
        .map(|e| {
            let kind = e.req("kind")?.as_str()?;
            let kind = TraceKind::parse(kind)
                .ok_or_else(|| anyhow::anyhow!("unknown trace kind '{kind}'"))?;
            Ok(TraceEvent {
                kind,
                id: e.req("id")?.as_u64()?,
                bound: e.req("bound")?.as_f64()?,
                sim: e.req("sim")?.as_f64()?,
            })
        })
        .collect()
}

impl Response {
    pub fn to_json(&self) -> Json {
        match self {
            Response::Ok { hits, sim_evals } => Json::obj(vec![
                ("status", Json::Str("ok".into())),
                ("hits", hits_to_json(hits)),
                ("sim_evals", Json::Num(*sim_evals as f64)),
            ]),
            // The `search` reply never serializes the trace: a traced and
            // an untraced search answer with identical bytes.
            Response::Search(r) => Json::obj(vec![
                ("status", Json::Str("search".into())),
                ("hits", hits_to_json(&r.hits)),
                ("truncated", Json::Bool(r.truncated)),
                ("sim_evals", Json::Num(r.sim_evals as f64)),
                ("nodes_visited", Json::Num(r.nodes_visited as f64)),
                ("pruned", Json::Num(r.pruned as f64)),
            ]),
            Response::Explain(r) => Json::obj(vec![
                ("status", Json::Str("explain".into())),
                ("hits", hits_to_json(&r.hits)),
                ("truncated", Json::Bool(r.truncated)),
                ("sim_evals", Json::Num(r.sim_evals as f64)),
                ("nodes_visited", Json::Num(r.nodes_visited as f64)),
                ("pruned", Json::Num(r.pruned as f64)),
                ("trace", trace_to_json(&r.trace)),
            ]),
            Response::Inserted { id } => Json::obj(vec![
                ("status", Json::Str("inserted".into())),
                ("id", Json::Num(*id as f64)),
            ]),
            Response::Deleted { existed } => Json::obj(vec![
                ("status", Json::Str("deleted".into())),
                ("existed", Json::Bool(*existed)),
            ]),
            Response::Done => Json::obj(vec![("status", Json::Str("done".into()))]),
            Response::Config(c) => Json::obj(vec![
                ("status", Json::Str("config".into())),
                ("kernel", Json::Str(c.kernel.clone())),
                ("index", Json::Str(c.index.clone())),
                ("bound", Json::Str(c.bound.clone())),
                ("mode", Json::Str(c.mode.clone())),
                ("shards", Json::Num(c.shards as f64)),
                ("mutable", Json::Bool(c.mutable)),
            ]),
            Response::Stats(s) => Json::obj(vec![
                ("status", Json::Str("stats".into())),
                ("kernel", Json::Str(s.kernel.clone())),
                ("queries", Json::Num(s.queries as f64)),
                ("batches", Json::Num(s.batches as f64)),
                ("errors", Json::Num(s.errors as f64)),
                ("corpus_size", Json::Num(s.corpus_size as f64)),
                ("shards", Json::Num(s.shards as f64)),
                ("sim_evals", Json::Num(s.sim_evals as f64)),
                ("engine_calls", Json::Num(s.engine_calls as f64)),
                ("pruned", Json::Num(s.pruned as f64)),
                ("nodes_visited", Json::Num(s.nodes_visited as f64)),
                ("ctx_reuses", Json::Num(s.ctx_reuses as f64)),
                ("pruned_fraction", Json::Num(s.pruned_fraction)),
                ("latency_us_p50", Json::Num(s.latency_us_p50 as f64)),
                ("latency_us_p99", Json::Num(s.latency_us_p99 as f64)),
                ("latency_us_max", Json::Num(s.latency_us_max as f64)),
                ("latency_us_sum", Json::Num(s.latency_us_sum as f64)),
                (
                    "latency_us_buckets",
                    Json::arr_f64(s.latency_us_buckets.iter().map(|&c| c as f64)),
                ),
                ("generations", Json::Num(s.generations as f64)),
                ("memtable_items", Json::Num(s.memtable_items as f64)),
                ("tombstones", Json::Num(s.tombstones as f64)),
                ("sealed_bytes", Json::Num(s.sealed_bytes as f64)),
                ("inserts", Json::Num(s.inserts as f64)),
                ("deletes", Json::Num(s.deletes as f64)),
                ("seals", Json::Num(s.seals as f64)),
                ("compactions", Json::Num(s.compactions as f64)),
                ("blocked_scan_rows", Json::Num(s.blocked_scan_rows as f64)),
                ("quant_prefilter_rows", Json::Num(s.quant_prefilter_rows as f64)),
                ("quant_rerank_rows", Json::Num(s.quant_rerank_rows as f64)),
            ]),
            Response::Metrics { text } => Json::obj(vec![
                ("status", Json::Str("metrics".into())),
                ("text", Json::Str(text.clone())),
            ]),
            Response::Pong => Json::obj(vec![("status", Json::Str("pong".into()))]),
            Response::Error { code, message } => Json::obj(vec![
                ("status", Json::Str("error".into())),
                ("code", Json::Str(code.clone())),
                ("message", Json::Str(message.clone())),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Response> {
        Ok(match v.req("status")?.as_str()? {
            "ok" => Response::Ok {
                hits: hits_from_json(v.req("hits")?)?,
                sim_evals: v.req("sim_evals")?.as_f64()? as u64,
            },
            "search" => Response::Search(SearchResult {
                hits: hits_from_json(v.req("hits")?)?,
                truncated: v.req("truncated")?.as_bool()?,
                sim_evals: v.req("sim_evals")?.as_f64()? as u64,
                nodes_visited: v.req("nodes_visited")?.as_f64()? as u64,
                pruned: v.req("pruned")?.as_f64()? as u64,
                trace: Vec::new(),
            }),
            "explain" => Response::Explain(SearchResult {
                hits: hits_from_json(v.req("hits")?)?,
                truncated: v.req("truncated")?.as_bool()?,
                sim_evals: v.req("sim_evals")?.as_f64()? as u64,
                nodes_visited: v.req("nodes_visited")?.as_f64()? as u64,
                pruned: v.req("pruned")?.as_f64()? as u64,
                trace: trace_from_json(v.req("trace")?)?,
            }),
            "inserted" => Response::Inserted { id: v.req("id")?.as_u64()? },
            "deleted" => Response::Deleted { existed: v.req("existed")?.as_bool()? },
            "done" => Response::Done,
            "config" => Response::Config(ConfigSnapshot {
                kernel: v.req("kernel")?.as_str()?.to_string(),
                index: v.req("index")?.as_str()?.to_string(),
                bound: v.req("bound")?.as_str()?.to_string(),
                mode: v.req("mode")?.as_str()?.to_string(),
                shards: v.req("shards")?.as_f64()? as u64,
                mutable: v.req("mutable")?.as_bool()?,
            }),
            "stats" => {
                let g = |key: &str| -> Result<u64> { Ok(v.req(key)?.as_f64()? as u64) };
                Response::Stats(StatsSnapshot {
                    kernel: v.req("kernel")?.as_str()?.to_string(),
                    queries: g("queries")?,
                    batches: g("batches")?,
                    errors: g("errors")?,
                    corpus_size: g("corpus_size")?,
                    shards: g("shards")?,
                    sim_evals: g("sim_evals")?,
                    engine_calls: g("engine_calls")?,
                    pruned: g("pruned")?,
                    nodes_visited: g("nodes_visited")?,
                    ctx_reuses: g("ctx_reuses")?,
                    pruned_fraction: v.req("pruned_fraction")?.as_f64()?,
                    latency_us_p50: g("latency_us_p50")?,
                    latency_us_p99: g("latency_us_p99")?,
                    latency_us_max: g("latency_us_max")?,
                    latency_us_sum: g("latency_us_sum")?,
                    latency_us_buckets: v
                        .req("latency_us_buckets")?
                        .as_arr()?
                        .iter()
                        .map(|x| Ok(x.as_f64()? as u64))
                        .collect::<Result<Vec<u64>>>()?,
                    generations: g("generations")?,
                    memtable_items: g("memtable_items")?,
                    tombstones: g("tombstones")?,
                    sealed_bytes: g("sealed_bytes")?,
                    inserts: g("inserts")?,
                    deletes: g("deletes")?,
                    seals: g("seals")?,
                    compactions: g("compactions")?,
                    blocked_scan_rows: g("blocked_scan_rows")?,
                    quant_prefilter_rows: g("quant_prefilter_rows")?,
                    quant_rerank_rows: g("quant_rerank_rows")?,
                })
            }
            "metrics" => Response::Metrics { text: v.req("text")?.as_str()?.to_string() },
            "pong" => Response::Pong,
            "error" => Response::Error {
                // `code` is absent in pre-ADR-005 server output.
                code: v.get("code").and_then(|c| c.as_str().ok()).unwrap_or("").to_string(),
                message: v.req("message")?.as_str()?.to_string(),
            },
            other => anyhow::bail!("unknown status '{other}'"),
        })
    }

    pub fn parse(line: &str) -> Result<Response> {
        Self::from_json(&Json::parse(line)?)
    }
}

/// The serving configuration, fixed at build time (backends and indexes
/// are immutable once a corpus is serving; see ADR-003).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ConfigSnapshot {
    /// Active kernel backend ("scalar", "simd", "i8") for the native scan
    /// paths: index walks, range queries, and hybrid re-scoring. PJRT
    /// artifact scoring (`mode = "engine"` top-k) reads the f32 buffer
    /// directly and bypasses the backend.
    pub kernel: String,
    pub index: String,
    pub bound: String,
    pub mode: String,
    pub shards: u64,
    pub mutable: bool,
}

/// Point-in-time metrics snapshot.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StatsSnapshot {
    /// Active kernel backend ("scalar", "simd", "i8").
    pub kernel: String,
    pub queries: u64,
    pub batches: u64,
    pub errors: u64,
    pub corpus_size: u64,
    pub shards: u64,
    pub sim_evals: u64,
    pub engine_calls: u64,
    /// Candidates discarded by a certified bound without an exact
    /// evaluation, totalled across all served queries (ADR-004 aggregates
    /// every worker's per-query `QueryStats` here).
    pub pruned: u64,
    /// Tree nodes / pivot tables visited, totalled like `pruned`.
    pub nodes_visited: u64,
    /// Queries answered on a reused worker `QueryContext` (scratch-arena
    /// hit count; steady state = every query but each worker's first).
    pub ctx_reuses: u64,
    /// Bound-tightness gauge: `pruned / (pruned + sim_evals)` — the
    /// fraction of candidate decisions resolved by a bound instead of an
    /// exact evaluation. 0.0 on an idle server.
    pub pruned_fraction: f64,
    /// Latency percentiles in microseconds.
    pub latency_us_p50: u64,
    pub latency_us_p99: u64,
    pub latency_us_max: u64,
    /// Total microseconds across all recorded requests (the Prometheus
    /// histogram `_sum`).
    pub latency_us_sum: u64,
    /// Full latency histogram: per-bucket counts over the edges
    /// `[0, 1, 2, 4, 8, ...)`us (bucket 0 holds exactly 0us; bucket
    /// `i >= 1` holds `[2^(i-1), 2^i)`; the last bucket is unbounded).
    pub latency_us_buckets: Vec<u64>,
    /// Ingest gauges (zero for build-once corpora): sealed generations,
    /// staged memtable rows, unresolved tombstones, sealed vector bytes.
    pub generations: u64,
    pub memtable_items: u64,
    pub tombstones: u64,
    pub sealed_bytes: u64,
    /// Ingest lifetime counters (zero for build-once corpora).
    pub inserts: u64,
    pub deletes: u64,
    pub seals: u64,
    pub compactions: u64,
    /// Kernel counters (ADR-003): rows scored exactly by the blocked scan
    /// entry points, rows screened by the i8 pre-filter, and pre-filter
    /// survivors re-ranked through the exact kernel.
    pub blocked_scan_rows: u64,
    pub quant_prefilter_rows: u64,
    pub quant_rerank_rows: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_round_trips() {
        let reqs = vec![
            Request::Knn { vector: vec![1.0, 2.0], k: 5 },
            Request::Range { vector: vec![-0.5], tau: 0.25 },
            Request::Insert { vector: vec![0.25, -1.5, 0.0] },
            Request::Delete { id: 123_456 },
            Request::Flush,
            Request::Compact,
            Request::Stats,
            Request::Metrics,
            Request::Config,
            Request::Ping,
        ];
        for r in reqs {
            let line = r.to_json().to_string();
            assert_eq!(Request::parse(&line).unwrap(), r);
        }
    }

    #[test]
    fn search_round_trips_every_mode_and_option_combination() {
        let modes = [
            SearchMode::Knn { k: 7 },
            SearchMode::Range { tau: 0.3 },
            SearchMode::KnnWithin { k: 4, tau: 0.6 },
        ];
        let bounds = [None, Some(BoundKind::Mult), Some(BoundKind::EuclLb)];
        let kernels = [None, Some(KernelKind::Simd), Some(KernelKind::QuantizedI8)];
        let filters = [
            IdFilter::None,
            IdFilter::Allow(Arc::new(vec![1, 5, 9])),
            IdFilter::Deny(Arc::new(vec![0, 2, 4_294_967_296])),
        ];
        let budgets = [None, Some(0u64), Some(123_456)];
        for mode in modes {
            for bound in bounds {
                for kernel in kernels {
                    for filter in &filters {
                        for budget in budgets {
                            let req = SearchRequest {
                                mode,
                                bound,
                                kernel,
                                filter: filter.clone(),
                                budget,
                                trace: false,
                            };
                            let wire =
                                Request::Search { vector: vec![0.5, -0.5], req: req.clone() };
                            let line = wire.to_json().to_string();
                            let back = Request::parse(&line).unwrap();
                            assert_eq!(back, wire, "line: {line}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn traced_search_and_explain_round_trip() {
        let req = SearchRequest::knn(5).trace().build();
        let wire = Request::Search { vector: vec![0.5], req: req.clone() };
        let line = wire.to_json().to_string();
        assert!(line.contains(r#""trace":true"#), "{line}");
        assert_eq!(Request::parse(&line).unwrap(), wire);

        // `explain` implies tracing: the field is never emitted, and a
        // parse always comes back with `trace` forced on.
        let wire = Request::Explain { vector: vec![0.5], req };
        let line = wire.to_json().to_string();
        assert!(!line.contains("trace"), "{line}");
        assert_eq!(Request::parse(&line).unwrap(), wire);
    }

    #[test]
    fn search_rejects_malformed_plans() {
        let base = r#""vector": [1.0]"#;
        for (line, why) in [
            (format!(r#"{{"op": "search", {base}, "mode": "warp", "k": 3}}"#), "unknown mode"),
            (format!(r#"{{"op": "search", {base}, "mode": "knn"}}"#), "missing k"),
            (format!(r#"{{"op": "search", {base}, "mode": "range"}}"#), "missing tau"),
            (
                format!(r#"{{"op": "search", "v": 2, {base}, "mode": "knn", "k": 3}}"#),
                "unsupported version",
            ),
            (
                format!(r#"{{"op": "search", {base}, "mode": "range", "tau": 1e999}}"#),
                "non-finite tau",
            ),
            (
                format!(
                    r#"{{"op": "search", {base}, "mode": "knn", "k": 3, "allow": [1], "deny": [2]}}"#
                ),
                "allow+deny",
            ),
            (
                format!(r#"{{"op": "search", {base}, "mode": "knn", "k": 3, "kernel": "gpu"}}"#),
                "unknown kernel",
            ),
            (
                format!(r#"{{"op": "search", {base}, "mode": "knn", "k": 3, "bound": "best"}}"#),
                "unknown bound",
            ),
        ] {
            let got = Request::parse(&line);
            assert!(got.is_err(), "{why}: {line} parsed as {got:?}");
            assert_eq!(got.unwrap_err().code(), "bad_request", "{why}");
        }
    }

    #[test]
    fn delete_ids_parse_as_u64_with_boundary_checks() {
        // Round-trip at the exactly-representable boundary values.
        for id in [0u64, 1, u32::MAX as u64 + 1, (1u64 << 53) - 1] {
            let r = Request::Delete { id };
            let line = r.to_json().to_string();
            assert_eq!(Request::parse(&line).unwrap(), r, "id {id}");
        }
        // From 2^53 a JSON double no longer represents ids unambiguously
        // (2^53+1 arrives as exactly 2^53): reject instead of silently
        // acting on a neighboring id (and never truncate through usize,
        // which is 32 bits on 32-bit targets).
        for line in [
            r#"{"op": "delete", "id": 9007199254740992}"#, // 2^53
            r#"{"op": "delete", "id": 9007199254740993}"#, // 2^53 + 1: rounds to 2^53
            r#"{"op": "delete", "id": 9007199254740994}"#, // 2^53 + 2
            r#"{"op": "delete", "id": 1e300}"#,
            r#"{"op": "delete", "id": -3}"#,
            r#"{"op": "delete", "id": 1.5}"#,
        ] {
            assert!(Request::parse(line).is_err(), "{line}");
        }
    }

    #[test]
    fn unknown_op_gets_the_typed_code() {
        let err = Request::parse(r#"{"op": "explode"}"#).unwrap_err();
        assert_eq!(err.code(), "unknown_op");
        assert_eq!(err.to_string(), "unknown op 'explode'");
        let err = Request::parse(r#"{"k": 3}"#).unwrap_err();
        assert_eq!(err.code(), "bad_request");
    }

    #[test]
    fn response_round_trips() {
        let resps = vec![
            Response::Ok { hits: vec![Hit { id: 3, score: 0.9 }], sim_evals: 17 },
            Response::Search(SearchResult {
                hits: vec![Hit { id: 9, score: 0.75 }, Hit { id: 2, score: 0.5 }],
                truncated: true,
                sim_evals: 321,
                nodes_visited: 17,
                pruned: 44,
                trace: Vec::new(),
            }),
            Response::Search(SearchResult::default()),
            Response::Explain(SearchResult {
                hits: vec![Hit { id: 9, score: 0.75 }],
                truncated: false,
                sim_evals: 12,
                nodes_visited: 3,
                pruned: 1,
                trace: vec![
                    TraceEvent::visit(7),
                    TraceEvent::prune(3, 0.25),
                    TraceEvent::eval(9, 0.875, 0.75),
                    TraceEvent::scan(64, 16),
                    TraceEvent::budget_stop(),
                ],
            }),
            Response::Metrics { text: "# TYPE simetra_bound_slack histogram\n".into() },
            Response::Inserted { id: 42 },
            Response::Deleted { existed: true },
            Response::Deleted { existed: false },
            Response::Done,
            Response::Stats(StatsSnapshot {
                kernel: "i8".into(),
                queries: 5,
                corpus_size: 100,
                nodes_visited: 42,
                ctx_reuses: 4,
                pruned_fraction: 0.25,
                generations: 3,
                memtable_items: 17,
                tombstones: 2,
                sealed_bytes: 8192,
                inserts: 120,
                deletes: 4,
                seals: 6,
                compactions: 1,
                blocked_scan_rows: 4096,
                quant_prefilter_rows: 2048,
                quant_rerank_rows: 77,
                ..Default::default()
            }),
            Response::Config(ConfigSnapshot {
                kernel: "simd".into(),
                index: "vp".into(),
                bound: "mult".into(),
                mode: "index".into(),
                shards: 4,
                mutable: true,
            }),
            Response::Pong,
            Response::Error { code: "bad_request".into(), message: "boom".into() },
        ];
        for r in resps {
            let line = r.to_json().to_string();
            assert_eq!(Response::parse(&line).unwrap(), r);
        }
        // Pre-ADR-005 error envelopes (no code field) still parse.
        let old = Response::parse(r#"{"status": "error", "message": "boom"}"#).unwrap();
        assert_eq!(old, Response::Error { code: String::new(), message: "boom".into() });
    }

    #[test]
    fn rejects_unknown_op_and_missing_fields() {
        assert!(Request::parse(r#"{"op": "explode"}"#).is_err());
        assert!(Request::parse(r#"{"vector": []}"#).is_err());
        assert!(Request::parse(r#"{"op": "insert"}"#).is_err());
        assert!(Request::parse(r#"{"op": "delete"}"#).is_err());
        assert!(Request::parse(r#"{"op": "delete", "id": -3}"#).is_err());
        assert!(Request::parse(r#"{"op": "insert", "vector": [NaN]}"#).is_err());
    }
}
