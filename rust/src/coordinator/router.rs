//! Sharding and scatter-gather merge.

use std::sync::Arc;

use crate::bounds::BoundKind;
use crate::storage::CorpusStore;

use super::shard::{IndexKind, Shard};

/// Partition the shared store into `n_shards` contiguous row-range views
/// and build one [`Shard`] per block. Contiguous blocks keep global-id math
/// trivial, preserve any locality the ingest order had, and — because every
/// shard holds a view, not a copy — the corpus stays a single allocation no
/// matter the shard count.
pub fn build_shards(
    store: &CorpusStore,
    n_shards: usize,
    kind: IndexKind,
    bound: BoundKind,
    hybrid_pivots: usize,
) -> Vec<Arc<Shard>> {
    let n = store.len();
    let n_shards = n_shards.max(1).min(n.max(1));
    let per = n.div_ceil(n_shards);
    let mut shards = Vec::with_capacity(n_shards);
    let mut start = 0usize;
    while start < n {
        let end = (start + per).min(n);
        shards.push(Arc::new(Shard::new(
            start as u64,
            store.slice(start..end),
            kind,
            bound,
            hybrid_pivots,
        )));
        start = end;
    }
    shards
}

/// Merge per-shard kNN results (local ids) into a global top-k.
pub fn merge_knn(
    per_shard: &[(u64, Vec<(u32, f64)>)],
    k: usize,
) -> Vec<(u64, f64)> {
    // Per-shard lists are already <= k; a sort of <= shards*k entries is
    // cheaper than a heap at serving sizes.
    let mut all: Vec<(u64, f64)> = Vec::new();
    for (base, hits) in per_shard {
        for &(id, s) in hits {
            all.push((base + id as u64, s));
        }
    }
    all.sort_unstable_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    all.truncate(k);
    all
}

/// Merge per-shard range results into a single sorted list.
pub fn merge_range(per_shard: &[(u64, Vec<(u32, f64)>)]) -> Vec<(u64, f64)> {
    let mut all: Vec<(u64, f64)> = Vec::new();
    for (base, hits) in per_shard {
        for &(id, s) in hits {
            all.push((base + id as u64, s));
        }
    }
    all.sort_unstable_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    all
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::uniform_sphere_store;

    #[test]
    fn shards_cover_corpus_contiguously() {
        let store = uniform_sphere_store(103, 8, 91);
        let shards = build_shards(&store, 4, IndexKind::Linear, BoundKind::Mult, 0);
        assert_eq!(shards.len(), 4);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, 103);
        let mut expect_base = 0u64;
        for s in &shards {
            assert_eq!(s.base, expect_base);
            expect_base += s.len() as u64;
        }
    }

    #[test]
    fn merge_knn_takes_global_best() {
        let a = (0u64, vec![(0u32, 0.9), (1, 0.5)]);
        let b = (100u64, vec![(0u32, 0.8), (1, 0.7)]);
        let merged = merge_knn(&[a, b], 3);
        assert_eq!(merged, vec![(0, 0.9), (100, 0.8), (101, 0.7)]);
    }

    #[test]
    fn merge_range_sorts_globally() {
        let a = (0u64, vec![(1u32, 0.6)]);
        let b = (10u64, vec![(2u32, 0.9)]);
        let merged = merge_range(&[a, b]);
        assert_eq!(merged, vec![(12, 0.9), (1, 0.6)]);
    }

    #[test]
    fn more_shards_than_items() {
        let store = uniform_sphere_store(3, 4, 92);
        let shards = build_shards(&store, 10, IndexKind::Linear, BoundKind::Mult, 0);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, 3);
    }
}
