//! TCP front end: newline-delimited JSON requests, thread-per-connection,
//! a shutdown handle, plus a typed blocking client.

use std::io::{BufRead, BufReader, Write};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::protocol::{ConfigSnapshot, Hit, Request, Response, SearchResult, StatsSnapshot};
use super::Coordinator;
use crate::error::SimetraError;
use crate::obs::{Stage, OBS};
use crate::query::SearchRequest;

/// A running TCP server: the bound address plus a shutdown handle.
///
/// [`ServeHandle::stop`] (also called on drop) closes the listener and
/// joins the accept thread, so tests and examples that bind port 0 tear
/// down cleanly instead of leaking an accept thread until process exit.
#[must_use = "dropping the handle stops the server"]
pub struct ServeHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl ServeHandle {
    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Close the listener and join the accept thread. Idempotent.
    /// Established connections keep their per-connection threads until the
    /// peer disconnects; no new connections are accepted.
    pub fn stop(&mut self) {
        if let Some(handle) = self.accept.take() {
            self.stop.store(true, Ordering::SeqCst);
            // Wake the blocking accept with a loopback connection (a
            // 0.0.0.0 / :: bind is not connectable everywhere). If the
            // wake cannot land, leave the accept thread parked instead of
            // blocking this thread on the join forever.
            let mut wake = self.addr;
            match wake.ip() {
                IpAddr::V4(ip) if ip.is_unspecified() => {
                    wake.set_ip(IpAddr::V4(Ipv4Addr::LOCALHOST));
                }
                IpAddr::V6(ip) if ip.is_unspecified() => {
                    wake.set_ip(IpAddr::V6(Ipv6Addr::LOCALHOST));
                }
                _ => {}
            }
            if TcpStream::connect_timeout(&wake, Duration::from_secs(1)).is_ok() {
                let _ = handle.join();
            }
        }
    }
}

impl Drop for ServeHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Serve a coordinator on `addr` on a background thread; returns a
/// [`ServeHandle`] carrying the bound address and the shutdown control.
pub fn serve(coordinator: Coordinator, addr: &str) -> Result<ServeHandle> {
    let listener = TcpListener::bind(addr).context("bind")?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let accept = std::thread::Builder::new()
        .name("simetra-accept".into())
        .spawn(move || {
            for stream in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                match stream {
                    Ok(socket) => {
                        let coord = coordinator.clone();
                        let _ = std::thread::Builder::new()
                            .name("simetra-conn".into())
                            .spawn(move || {
                                if let Err(e) = handle_conn(coord, socket) {
                                    let msg = e.to_string();
                                    if !msg.contains("reset") && !msg.contains("Broken pipe") {
                                        eprintln!("connection error: {msg}");
                                    }
                                }
                            });
                    }
                    Err(e) => {
                        eprintln!("accept error: {e}");
                        break;
                    }
                }
            }
            // The listener drops here, closing the socket.
        })
        .context("spawn accept thread")?;
    Ok(ServeHandle { addr: local, stop, accept: Some(accept) })
}

fn handle_conn(coord: Coordinator, socket: TcpStream) -> Result<()> {
    socket.set_nodelay(true)?;
    let mut writer = socket.try_clone()?;
    let reader = BufReader::new(socket);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let t_parse = Instant::now();
        let parsed = Request::parse(&line);
        OBS.record_stage(Stage::Parse, t_parse.elapsed());
        let response = match parsed {
            Ok(req) => dispatch(&coord, req),
            Err(e) => Response::Error {
                code: e.code().to_string(),
                message: format!("bad request: {e}"),
            },
        };
        let t_ser = Instant::now();
        let mut out = response.to_json().to_string().into_bytes();
        out.push(b'\n');
        writer.write_all(&out)?;
        OBS.record_stage(Stage::Serialize, t_ser.elapsed());
    }
    Ok(())
}

fn err_response(e: SimetraError) -> Response {
    Response::Error { code: e.code().to_string(), message: e.to_string() }
}

fn dispatch(coord: &Coordinator, req: Request) -> Response {
    match req {
        Request::Ping => Response::Pong,
        Request::Stats => Response::Stats(coord.stats()),
        Request::Config => Response::Config(coord.describe()),
        // Legacy ops stay byte-identical: served through the one search
        // path as plain plans, answered with the original `ok` envelope.
        Request::Knn { vector, k } => match coord.knn(vector, k.max(1)) {
            Ok((hits, sim_evals)) => Response::Ok { hits, sim_evals },
            Err(e) => err_response(e),
        },
        Request::Range { vector, tau } => match coord.range(vector, tau) {
            Ok((hits, sim_evals)) => Response::Ok { hits, sim_evals },
            Err(e) => err_response(e),
        },
        Request::Search { vector, req } => match coord.search(vector, req) {
            Ok(result) => Response::Search(result),
            Err(e) => err_response(e),
        },
        // Same execution path as `search` — only the reply envelope
        // differs (it carries the trace the forced `req.trace` recorded).
        Request::Explain { vector, req } => match coord.search(vector, req) {
            Ok(result) => Response::Explain(result),
            Err(e) => err_response(e),
        },
        Request::Metrics => Response::Metrics { text: coord.prometheus() },
        Request::Insert { vector } => match coord.insert(vector) {
            Ok(id) => Response::Inserted { id },
            Err(e) => err_response(e),
        },
        Request::Delete { id } => match coord.delete(id) {
            Ok(existed) => Response::Deleted { existed },
            Err(e) => err_response(e),
        },
        Request::Flush => match coord.flush() {
            Ok(()) => Response::Done,
            Err(e) => err_response(e),
        },
        Request::Compact => match coord.compact() {
            Ok(()) => Response::Done,
            Err(e) => err_response(e),
        },
    }
}

/// Reject filter ids a JSON double cannot carry unambiguously (>= 2^53)
/// *before* serialization: `Json::Num` would silently round them to a
/// neighboring id — the same corruption class the `Json::as_u64`
/// parse-side guard exists for, caught here on the way out instead (both
/// sides share `util::json::MAX_EXACT_JSON_INT`).
fn check_wire_filter(req: &SearchRequest) -> Result<()> {
    if let Some(ids) = req.filter.ids() {
        if let Some(&id) = ids.iter().find(|&&id| id >= crate::util::json::MAX_EXACT_JSON_INT) {
            anyhow::bail!("filter id {id} exceeds 2^53 and cannot be sent exactly over the wire");
        }
    }
    Ok(())
}

/// Rebuild [`SimetraError::DimMismatch`] from its stable wire message
/// ("vector dimension {got} does not match corpus dimension {want}").
fn parse_dim_mismatch(message: &str) -> Option<SimetraError> {
    let mut nums = message
        .split(|c: char| !c.is_ascii_digit())
        .filter(|s| !s.is_empty())
        .map(str::parse::<usize>);
    let got = nums.next()?.ok()?;
    let want = nums.next()?.ok()?;
    Some(SimetraError::DimMismatch { got, want })
}

/// Blocking line-protocol client for examples, tests and load generators.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr).context("connect")?;
        stream.set_nodelay(true)?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    pub fn request(&mut self, req: &Request) -> Result<Response> {
        // Guard the one op that carries raw u64 id lists before the
        // infallible JSON serialization can round them (see
        // check_wire_filter) — so every sender is covered, not just the
        // typed `search` wrappers.
        if let Request::Search { req: plan, .. } | Request::Explain { req: plan, .. } = req {
            check_wire_filter(plan)?;
        }
        let mut line = req.to_json().to_string().into_bytes();
        line.push(b'\n');
        self.writer.write_all(&line)?;
        let mut buf = String::new();
        self.reader.read_line(&mut buf)?;
        Response::parse(&buf)
    }

    /// Send raw bytes (for protocol-robustness tests).
    pub fn request_raw(&mut self, raw: &[u8]) -> Result<Response> {
        self.writer.write_all(raw)?;
        let mut buf = String::new();
        self.reader.read_line(&mut buf)?;
        Response::parse(&buf)
    }

    pub fn knn(&mut self, vector: Vec<f32>, k: usize) -> Result<Vec<Hit>> {
        match self.request(&Request::Knn { vector, k })? {
            Response::Ok { hits, .. } => Ok(hits),
            Response::Error { message, .. } => anyhow::bail!("server error: {message}"),
            other => anyhow::bail!("unexpected response: {other:?}"),
        }
    }

    /// Execute one typed search plan (ADR-005) over the wire `search` op.
    pub fn search(&mut self, vector: Vec<f32>, req: SearchRequest) -> Result<SearchResult> {
        match self.request(&Request::Search { vector, req })? {
            Response::Search(result) => Ok(result),
            Response::Error { message, .. } => anyhow::bail!("server error: {message}"),
            other => anyhow::bail!("unexpected response: {other:?}"),
        }
    }

    /// Like [`Client::search`], surfacing the server's typed error code on
    /// failure (the `Response::Error` envelope's `code` field).
    pub fn search_checked(
        &mut self,
        vector: Vec<f32>,
        req: SearchRequest,
    ) -> Result<SearchResult, SimetraError> {
        check_wire_filter(&req).map_err(|e| SimetraError::BadRequest(e.to_string()))?;
        let resp = self
            .request(&Request::Search { vector, req })
            .map_err(|e| SimetraError::Io(e.to_string()))?;
        match resp {
            Response::Search(result) => Ok(result),
            Response::Error { code, message } => Err(match code.as_str() {
                "unknown_op" => SimetraError::UnknownOp(message),
                "kernel_unavailable" => SimetraError::KernelUnavailable(message),
                "io" => SimetraError::Io(message),
                // The structured fields are not on the wire; rebuild them
                // from the (stable) message so `code()` stays faithful.
                "dim_mismatch" => parse_dim_mismatch(&message)
                    .unwrap_or(SimetraError::BadRequest(message)),
                _ => SimetraError::BadRequest(message),
            }),
            other => Err(SimetraError::Io(format!("unexpected response: {other:?}"))),
        }
    }

    /// Insert a vector into a mutable corpus; returns the assigned id.
    pub fn insert(&mut self, vector: Vec<f32>) -> Result<u64> {
        match self.request(&Request::Insert { vector })? {
            Response::Inserted { id } => Ok(id),
            Response::Error { message, .. } => anyhow::bail!("server error: {message}"),
            other => anyhow::bail!("unexpected response: {other:?}"),
        }
    }

    /// Tombstone an id; returns whether it was live.
    pub fn delete(&mut self, id: u64) -> Result<bool> {
        match self.request(&Request::Delete { id })? {
            Response::Deleted { existed } => Ok(existed),
            Response::Error { message, .. } => anyhow::bail!("server error: {message}"),
            other => anyhow::bail!("unexpected response: {other:?}"),
        }
    }

    pub fn flush(&mut self) -> Result<()> {
        match self.request(&Request::Flush)? {
            Response::Done => Ok(()),
            Response::Error { message, .. } => anyhow::bail!("server error: {message}"),
            other => anyhow::bail!("unexpected response: {other:?}"),
        }
    }

    pub fn compact(&mut self) -> Result<()> {
        match self.request(&Request::Compact)? {
            Response::Done => Ok(()),
            Response::Error { message, .. } => anyhow::bail!("server error: {message}"),
            other => anyhow::bail!("unexpected response: {other:?}"),
        }
    }

    pub fn stats(&mut self) -> Result<StatsSnapshot> {
        match self.request(&Request::Stats)? {
            Response::Stats(s) => Ok(s),
            Response::Error { message, .. } => anyhow::bail!("server error: {message}"),
            other => anyhow::bail!("unexpected response: {other:?}"),
        }
    }

    /// The server's fixed serving configuration (kernel backend, index,
    /// bound, mode).
    pub fn config(&mut self) -> Result<ConfigSnapshot> {
        match self.request(&Request::Config)? {
            Response::Config(c) => Ok(c),
            Response::Error { message, .. } => anyhow::bail!("server error: {message}"),
            other => anyhow::bail!("unexpected response: {other:?}"),
        }
    }

    /// Execute a traced search over the wire `explain` op; the result's
    /// `trace` holds the traversal event log.
    pub fn explain(&mut self, vector: Vec<f32>, req: SearchRequest) -> Result<SearchResult> {
        match self.request(&Request::Explain { vector, req })? {
            Response::Explain(result) => Ok(result),
            Response::Error { message, .. } => anyhow::bail!("server error: {message}"),
            other => anyhow::bail!("unexpected response: {other:?}"),
        }
    }

    /// Fetch the Prometheus text exposition over the wire `metrics` op.
    pub fn metrics(&mut self) -> Result<String> {
        match self.request(&Request::Metrics)? {
            Response::Metrics { text } => Ok(text),
            Response::Error { message, .. } => anyhow::bail!("server error: {message}"),
            other => anyhow::bail!("unexpected response: {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordinatorConfig;
    use crate::data::uniform_sphere;

    #[test]
    fn serve_and_query_over_tcp() {
        let pts = uniform_sphere(200, 8, 111);
        let coord = Coordinator::new(pts.clone(), CoordinatorConfig::default()).unwrap();
        let server = serve(coord, "127.0.0.1:0").unwrap();
        let mut client = Client::connect(server.addr()).unwrap();

        match client.request(&Request::Ping).unwrap() {
            Response::Pong => {}
            other => panic!("{other:?}"),
        }
        let hits = client.knn(pts[3].as_slice().to_vec(), 4).unwrap();
        assert_eq!(hits.len(), 4);
        assert_eq!(hits[0].id, 3);
        // The config op reports the build-time serving configuration.
        let cfg = client.config().unwrap();
        assert_eq!(cfg.index, "vp");
        assert_eq!(cfg.mode, "index");
        assert!(!cfg.mutable);
        assert!(!cfg.kernel.is_empty());
        match client.request(&Request::Stats).unwrap() {
            Response::Stats(s) => {
                assert_eq!(s.corpus_size, 200);
                assert!(s.queries >= 1);
            }
            other => panic!("{other:?}"),
        }
        // Malformed input yields an error response, not a dropped connection.
        match client.request_raw(b"{not json}\n").unwrap() {
            Response::Error { .. } => {}
            other => panic!("{other:?}"),
        }
        // The connection still works afterwards.
        let hits = client.knn(pts[5].as_slice().to_vec(), 2).unwrap();
        assert_eq!(hits[0].id, 5);

        // Explain returns the same hits as a plain search plus a trace.
        let req = SearchRequest::knn(4).build();
        let plain = client.search(pts[3].as_slice().to_vec(), req.clone()).unwrap();
        let traced = client.explain(pts[3].as_slice().to_vec(), req).unwrap();
        assert_eq!(plain.hits.len(), traced.hits.len());
        for (a, b) in plain.hits.iter().zip(traced.hits.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.score.to_bits(), b.score.to_bits());
        }
        assert!(plain.trace.is_empty());
        assert!(!traced.trace.is_empty());

        // Metrics serves a non-empty Prometheus text exposition.
        let text = client.metrics().unwrap();
        assert!(text.contains("# TYPE simetra_queries_total counter"));
        assert!(text.contains("simetra_request_latency_us_count"));
    }

    #[test]
    fn multiple_concurrent_clients() {
        let pts = uniform_sphere(100, 8, 112);
        let coord = Coordinator::new(pts.clone(), CoordinatorConfig::default()).unwrap();
        let server = serve(coord, "127.0.0.1:0").unwrap();
        let addr = server.addr();
        let mut handles = Vec::new();
        for c in 0..8usize {
            let pts = pts.clone();
            handles.push(std::thread::spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                for qi in 0..10 {
                    let id = (c * 10 + qi) % 100;
                    let hits = client.knn(pts[id].as_slice().to_vec(), 1).unwrap();
                    assert_eq!(hits[0].id, id as u64);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn stop_closes_listener_and_joins_accept_thread() {
        let pts = uniform_sphere(50, 8, 113);
        let coord = Coordinator::new(pts.clone(), CoordinatorConfig::default()).unwrap();
        let mut server = serve(coord, "127.0.0.1:0").unwrap();
        let addr = server.addr();
        {
            let mut client = Client::connect(addr).unwrap();
            match client.request(&Request::Ping).unwrap() {
                Response::Pong => {}
                other => panic!("{other:?}"),
            }
        }
        server.stop();
        server.stop(); // idempotent
        assert!(TcpStream::connect(addr).is_err(), "listener still accepting after stop()");
        // Mutations against a build-once coordinator fail cleanly.
        let coord2 = Coordinator::new(pts, CoordinatorConfig::default()).unwrap();
        let server2 = serve(coord2, "127.0.0.1:0").unwrap();
        let mut client = Client::connect(server2.addr()).unwrap();
        let err = client.insert(vec![0.0; 8]);
        assert!(err.is_err());
        assert!(err.unwrap_err().to_string().contains("read-only"));
    }
}
