//! TCP front end: newline-delimited JSON requests, thread-per-connection,
//! plus a typed blocking client.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};

use anyhow::{Context, Result};

use super::protocol::{Hit, Request, Response};
use super::Coordinator;

/// Serve a coordinator on `addr` on a background thread; returns the bound
/// address (useful with port 0). The listener runs until process exit.
pub fn serve(coordinator: Coordinator, addr: &str) -> Result<SocketAddr> {
    let listener = TcpListener::bind(addr).context("bind")?;
    let local = listener.local_addr()?;
    std::thread::Builder::new()
        .name("simetra-accept".into())
        .spawn(move || {
            for stream in listener.incoming() {
                match stream {
                    Ok(socket) => {
                        let coord = coordinator.clone();
                        let _ = std::thread::Builder::new()
                            .name("simetra-conn".into())
                            .spawn(move || {
                                if let Err(e) = handle_conn(coord, socket) {
                                    let msg = e.to_string();
                                    if !msg.contains("reset") && !msg.contains("Broken pipe") {
                                        eprintln!("connection error: {msg}");
                                    }
                                }
                            });
                    }
                    Err(e) => {
                        eprintln!("accept error: {e}");
                        break;
                    }
                }
            }
        })
        .context("spawn accept thread")?;
    Ok(local)
}

fn handle_conn(coord: Coordinator, socket: TcpStream) -> Result<()> {
    socket.set_nodelay(true)?;
    let mut writer = socket.try_clone()?;
    let reader = BufReader::new(socket);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let response = match Request::parse(&line) {
            Ok(req) => dispatch(&coord, req),
            Err(e) => Response::Error { message: format!("bad request: {e}") },
        };
        let mut out = response.to_json().to_string().into_bytes();
        out.push(b'\n');
        writer.write_all(&out)?;
    }
    Ok(())
}

fn dispatch(coord: &Coordinator, req: Request) -> Response {
    match req {
        Request::Ping => Response::Pong,
        Request::Stats => Response::Stats(coord.stats()),
        Request::Knn { vector, k } => match coord.knn(vector, k.max(1)) {
            Ok((hits, sim_evals)) => Response::Ok { hits, sim_evals },
            Err(e) => Response::Error { message: e.to_string() },
        },
        Request::Range { vector, tau } => match coord.range(vector, tau) {
            Ok((hits, sim_evals)) => Response::Ok { hits, sim_evals },
            Err(e) => Response::Error { message: e.to_string() },
        },
    }
}

/// Blocking line-protocol client for examples, tests and load generators.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr).context("connect")?;
        stream.set_nodelay(true)?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    pub fn request(&mut self, req: &Request) -> Result<Response> {
        let mut line = req.to_json().to_string().into_bytes();
        line.push(b'\n');
        self.writer.write_all(&line)?;
        let mut buf = String::new();
        self.reader.read_line(&mut buf)?;
        Response::parse(&buf)
    }

    /// Send raw bytes (for protocol-robustness tests).
    pub fn request_raw(&mut self, raw: &[u8]) -> Result<Response> {
        self.writer.write_all(raw)?;
        let mut buf = String::new();
        self.reader.read_line(&mut buf)?;
        Response::parse(&buf)
    }

    pub fn knn(&mut self, vector: Vec<f32>, k: usize) -> Result<Vec<Hit>> {
        match self.request(&Request::Knn { vector, k })? {
            Response::Ok { hits, .. } => Ok(hits),
            Response::Error { message } => anyhow::bail!("server error: {message}"),
            other => anyhow::bail!("unexpected response: {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordinatorConfig;
    use crate::data::uniform_sphere;

    #[test]
    fn serve_and_query_over_tcp() {
        let pts = uniform_sphere(200, 8, 111);
        let coord = Coordinator::new(pts.clone(), CoordinatorConfig::default()).unwrap();
        let addr = serve(coord, "127.0.0.1:0").unwrap();
        let mut client = Client::connect(addr).unwrap();

        match client.request(&Request::Ping).unwrap() {
            Response::Pong => {}
            other => panic!("{other:?}"),
        }
        let hits = client.knn(pts[3].as_slice().to_vec(), 4).unwrap();
        assert_eq!(hits.len(), 4);
        assert_eq!(hits[0].id, 3);
        match client.request(&Request::Stats).unwrap() {
            Response::Stats(s) => {
                assert_eq!(s.corpus_size, 200);
                assert!(s.queries >= 1);
            }
            other => panic!("{other:?}"),
        }
        // Malformed input yields an error response, not a dropped connection.
        match client.request_raw(b"{not json}\n").unwrap() {
            Response::Error { .. } => {}
            other => panic!("{other:?}"),
        }
        // The connection still works afterwards.
        let hits = client.knn(pts[5].as_slice().to_vec(), 2).unwrap();
        assert_eq!(hits[0].id, 5);
    }

    #[test]
    fn multiple_concurrent_clients() {
        let pts = uniform_sphere(100, 8, 112);
        let coord = Coordinator::new(pts.clone(), CoordinatorConfig::default()).unwrap();
        let addr = serve(coord, "127.0.0.1:0").unwrap();
        let mut handles = Vec::new();
        for c in 0..8usize {
            let pts = pts.clone();
            handles.push(std::thread::spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                for qi in 0..10 {
                    let id = (c * 10 + qi) % 100;
                    let hits = client.knn(pts[id].as_slice().to_vec(), 1).unwrap();
                    assert_eq!(hits[0].id, id as u64);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}
