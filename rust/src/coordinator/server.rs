//! TCP front end: a fixed worker pool multiplexing pipelined
//! newline-delimited JSON connections over the streaming wire path
//! (ADR-008), a shutdown handle that joins its workers, a legacy
//! thread-per-connection server kept as the conformance baseline, plus a
//! typed blocking client.

use std::io::{self, BufRead, BufReader, Write};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::metrics::Metrics;
use super::protocol::{parse_wire, write_response, WireOp, WireScratch};
use super::protocol::{ConfigSnapshot, Hit, Request, Response, SearchResult, StatsSnapshot};
use super::Coordinator;
use crate::error::SimetraError;
use crate::obs::{Stage, OBS};
use crate::query::SearchRequest;
use crate::sync::queue::RunQueue;
use crate::sync::{AtomicBool, Ordering};

/// How long one worker turn blocks on a quiet socket before parking the
/// connection back in the run queue — the pool's fairness quantum, and
/// its shutdown-latency floor for a worker mid-turn.
const TURN_READ_TIMEOUT: Duration = Duration::from_millis(20);

/// How long a parked worker waits for the ready signal before re-checking
/// the stop flag.
const POP_WAIT: Duration = Duration::from_millis(50);

/// How long [`ServeHandle::stop`] waits for workers to finish their
/// current turns before giving up on the join.
const STOP_DEADLINE: Duration = Duration::from_secs(5);

/// Tuning for the worker-pool front door (ADR-008).
#[derive(Debug, Clone, Copy, Default)]
pub struct ServeConfig {
    /// Worker threads multiplexing every connection; `0` (the default)
    /// sizes the pool from the host's available parallelism, clamped to
    /// `2..=8`.
    pub workers: usize,
}

impl ServeConfig {
    fn resolved_workers(self) -> usize {
        if self.workers > 0 {
            return self.workers;
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).clamp(2, 8)
    }
}

/// A running TCP server: the bound address plus a shutdown handle.
///
/// [`ServeHandle::stop`] (also called on drop) closes the listener, joins
/// the accept thread and the worker pool, so tests and examples that bind
/// port 0 tear down cleanly instead of leaking threads until process
/// exit.
#[must_use = "dropping the handle stops the server"]
pub struct ServeHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    pool: Option<Arc<PoolShared>>,
    workers: Vec<JoinHandle<()>>,
}

impl ServeHandle {
    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Close the listener, join the accept thread, then shut the pool
    /// down: workers are signalled and joined within [`STOP_DEADLINE`],
    /// and connections still parked in the run queue are dropped, so no
    /// `simetra-conn-*` thread outlives `stop()`. Idempotent. (The legacy
    /// server has no pool; its per-connection threads live until the peer
    /// disconnects.)
    pub fn stop(&mut self) {
        if let Some(handle) = self.accept.take() {
            self.stop.store(true, Ordering::SeqCst);
            // Wake the blocking accept with a loopback connection (a
            // 0.0.0.0 / :: bind is not connectable everywhere). If the
            // wake cannot land, leave the accept thread parked instead of
            // blocking this thread on the join forever.
            let mut wake = self.addr;
            match wake.ip() {
                IpAddr::V4(ip) if ip.is_unspecified() => {
                    wake.set_ip(IpAddr::V4(Ipv4Addr::LOCALHOST));
                }
                IpAddr::V6(ip) if ip.is_unspecified() => {
                    wake.set_ip(IpAddr::V6(Ipv6Addr::LOCALHOST));
                }
                _ => {}
            }
            if TcpStream::connect_timeout(&wake, Duration::from_secs(1)).is_ok() {
                let _ = handle.join();
            }
        }
        if let Some(pool) = self.pool.take() {
            pool.queue.stop();
            let deadline = Instant::now() + STOP_DEADLINE;
            for worker in self.workers.drain(..) {
                // Turn reads and condvar waits are bounded, so workers
                // notice the stop flag promptly; the deadline guards one
                // wedged writing to a dead-slow peer (leaked, not joined).
                while !worker.is_finished() && Instant::now() < deadline {
                    std::thread::sleep(Duration::from_millis(2));
                }
                if worker.is_finished() {
                    let _ = worker.join();
                }
            }
            // Close connections still waiting for a worker turn.
            drop(pool.queue.drain());
            pool.metrics.conns_queued.store(0, Ordering::Relaxed);
        }
    }
}

impl Drop for ServeHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Serve a coordinator on `addr` with the default pool configuration;
/// returns a [`ServeHandle`] carrying the bound address and the shutdown
/// control.
pub fn serve(coordinator: Coordinator, addr: &str) -> Result<ServeHandle> {
    serve_with(coordinator, addr, ServeConfig::default())
}

/// Serve a coordinator on `addr` through a fixed worker pool (ADR-008):
/// each worker multiplexes queued connections round-robin, draining every
/// complete pipelined request line per turn and flushing the batch of
/// responses with one write.
pub fn serve_with(
    coordinator: Coordinator,
    addr: &str,
    config: ServeConfig,
) -> Result<ServeHandle> {
    let listener = TcpListener::bind(addr).context("bind")?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let metrics = coordinator.metrics.clone();
    let pool = Arc::new(PoolShared { queue: RunQueue::new(), metrics: metrics.clone() });
    let mut workers = Vec::new();
    for i in 0..config.resolved_workers() {
        let coord = coordinator.clone();
        let pool = pool.clone();
        let worker = std::thread::Builder::new()
            .name(format!("simetra-conn-{i}"))
            .spawn(move || worker_loop(coord, &pool))
            .context("spawn pool worker")?;
        workers.push(worker);
    }
    let stop2 = stop.clone();
    let pool2 = pool.clone();
    let accept = std::thread::Builder::new()
        .name("simetra-accept".into())
        .spawn(move || {
            for stream in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                match stream {
                    Ok(socket) => match Conn::new(socket, metrics.clone()) {
                        Ok(conn) => pool2.push(conn),
                        Err(e) => eprintln!("connection setup error: {e}"),
                    },
                    Err(e) => {
                        eprintln!("accept error: {e}");
                        break;
                    }
                }
            }
            // The listener drops here, closing the socket.
        })
        .context("spawn accept thread")?;
    Ok(ServeHandle { addr: local, stop, accept: Some(accept), pool: Some(pool), workers })
}

/// Serve a coordinator thread-per-connection over the legacy `Json`-tree
/// wire path. Kept as the conformance and performance baseline for the
/// streaming pool (`benches/wire_path.rs`, the differential tests);
/// established connections keep their threads until the peer disconnects.
pub fn serve_legacy(coordinator: Coordinator, addr: &str) -> Result<ServeHandle> {
    let listener = TcpListener::bind(addr).context("bind")?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let accept = std::thread::Builder::new()
        .name("simetra-accept".into())
        .spawn(move || {
            for stream in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                match stream {
                    Ok(socket) => spawn_legacy_conn(coordinator.clone(), socket),
                    Err(e) => {
                        eprintln!("accept error: {e}");
                        break;
                    }
                }
            }
            // The listener drops here, closing the socket.
        })
        .context("spawn accept thread")?;
    Ok(ServeHandle { addr: local, stop, accept: Some(accept), pool: None, workers: Vec::new() })
}

fn spawn_legacy_conn(coord: Coordinator, socket: TcpStream) {
    let _ = std::thread::Builder::new()
        .name("simetra-legacy".into())
        .spawn(move || {
            if let Err(e) = handle_conn_legacy(coord, socket) {
                // Peer disconnects are business as usual; everything else
                // is worth a log line. Classified by `io::ErrorKind`, not
                // by error-message substrings.
                if !e.downcast_ref::<io::Error>().is_some_and(is_disconnect) {
                    eprintln!("connection error: {e}");
                }
            }
        });
}

/// Whether `e` is a routine peer disconnect (not worth logging).
fn is_disconnect(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::ConnectionReset | io::ErrorKind::BrokenPipe)
}

/// One client connection owned by the pool: buffered reader + writer
/// halves, the partial-line carryover, and the per-connection scratch
/// that keeps the steady-state wire path allocation-free (ADR-008).
struct Conn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// Bytes of the current request line; a turn that times out mid-line
    /// parks the partial prefix here and the next turn appends to it.
    line: Vec<u8>,
    scratch: WireScratch,
    out: String,
    metrics: Arc<Metrics>,
}

impl Conn {
    fn new(socket: TcpStream, metrics: Arc<Metrics>) -> io::Result<Conn> {
        socket.set_nodelay(true)?;
        // A bounded read timeout turns the blocking socket cooperative: a
        // quiet connection costs its worker one `TURN_READ_TIMEOUT` slice
        // per turn, then yields the worker back to the run queue.
        socket.set_read_timeout(Some(TURN_READ_TIMEOUT))?;
        let writer = socket.try_clone()?;
        metrics.conns_live.fetch_add(1, Ordering::Relaxed);
        Ok(Conn {
            reader: BufReader::new(socket),
            writer,
            line: Vec::new(),
            scratch: WireScratch::new(),
            out: String::new(),
            metrics,
        })
    }
}

impl Drop for Conn {
    fn drop(&mut self) {
        self.metrics.conns_live.fetch_sub(1, Ordering::Relaxed);
    }
}

/// State shared between the accept thread and the pool workers: the
/// connection run queue (a [`RunQueue`], so the model checker covers its
/// push/pop/stop protocol directly — see `tests/model_checker.rs`) plus
/// the queue-depth gauge.
struct PoolShared {
    queue: RunQueue<Conn>,
    metrics: Arc<Metrics>,
}

impl PoolShared {
    fn push(&self, conn: Conn) {
        let queued = self.queue.push(conn);
        self.metrics.conns_queued.store(queued as u64, Ordering::Relaxed);
    }

    /// The next connection due a turn; `None` once the pool is stopping.
    fn pop(&self) -> Option<Conn> {
        let (conn, queued) = self.queue.pop(POP_WAIT)?;
        self.metrics.conns_queued.store(queued as u64, Ordering::Relaxed);
        Some(conn)
    }
}

/// What to do with a connection after one worker turn.
enum Turn {
    /// Park it back in the run queue (idle, or mid-request-line).
    Keep,
    /// Drop it (EOF, disconnect, or an unrecoverable socket error).
    Close,
}

fn worker_loop(coord: Coordinator, pool: &PoolShared) {
    while let Some(mut conn) = pool.pop() {
        match serve_turn(&coord, &mut conn) {
            Turn::Keep => pool.push(conn),
            Turn::Close => drop(conn),
        }
    }
}

/// One worker turn over one connection: drain every complete request line
/// already readable (pipelining: read many, answer in order), accumulate
/// the response lines in the connection's output buffer, and flush them
/// with one write.
fn serve_turn(coord: &Coordinator, conn: &mut Conn) -> Turn {
    conn.out.clear();
    let mut close = false;
    loop {
        match conn.reader.read_until(b'\n', &mut conn.line) {
            Ok(0) => {
                // EOF: answer a final unterminated line, then close.
                if !conn.line.is_empty() {
                    conn.metrics.bytes_in.fetch_add(conn.line.len() as u64, Ordering::Relaxed);
                    process_line(coord, &conn.line, &mut conn.scratch, &mut conn.out);
                    conn.line.clear();
                }
                close = true;
                break;
            }
            Ok(_) => {
                if conn.line.last() != Some(&b'\n') {
                    // `read_until` stops short of the delimiter only at
                    // EOF; the next read reports it as `Ok(0)`.
                    continue;
                }
                conn.metrics.bytes_in.fetch_add(conn.line.len() as u64, Ordering::Relaxed);
                process_line(coord, &conn.line, &mut conn.scratch, &mut conn.out);
                conn.line.clear();
                if !conn.reader.buffer().contains(&b'\n') {
                    break;
                }
            }
            // No (more) data within this turn's slice: any partial line
            // stays parked in `conn.line` for the next turn.
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {
                break;
            }
            Err(e) => {
                if !is_disconnect(&e) {
                    eprintln!("connection error: {e}");
                }
                close = true;
                break;
            }
        }
    }
    if !conn.out.is_empty() {
        if let Err(e) = conn.writer.write_all(conn.out.as_bytes()) {
            if !is_disconnect(&e) {
                eprintln!("connection error: {e}");
            }
            return Turn::Close;
        }
        conn.metrics.bytes_out.fetch_add(conn.out.len() as u64, Ordering::Relaxed);
    }
    if close {
        Turn::Close
    } else {
        Turn::Keep
    }
}

/// Answer one raw request line, appending the response line to `out`.
fn process_line(coord: &Coordinator, raw: &[u8], scratch: &mut WireScratch, out: &mut String) {
    let mut line = raw;
    if line.last() == Some(&b'\n') {
        line = &line[..line.len() - 1];
    }
    if line.last() == Some(&b'\r') {
        line = &line[..line.len() - 1];
    }
    // Blank lines are skipped, matching the legacy loop's `trim` check; a
    // non-UTF-8 line is not blank and earns an error response below
    // (where the legacy server dropped the whole connection).
    if std::str::from_utf8(line).is_ok_and(|s| s.trim().is_empty()) {
        return;
    }
    let t_parse = Instant::now();
    let parsed = parse_wire(line, scratch);
    OBS.record_stage(Stage::Parse, t_parse.elapsed());
    let response = match parsed {
        Ok(op) => dispatch_wire(coord, op, scratch),
        Err(e) => Response::Error {
            code: e.code().to_string(),
            message: format!("bad request: {e}"),
        },
    };
    let t_ser = Instant::now();
    write_response(&response, out);
    out.push('\n');
    OBS.record_stage(Stage::Serialize, t_ser.elapsed());
}

/// Execute a streaming-parsed op. Vector-carrying ops pay exactly one
/// owned copy out of the connection scratch here — the coordinator hands
/// the query vector to shard workers by value — and that copy is the only
/// steady-state allocation between socket read and dispatch.
fn dispatch_wire(coord: &Coordinator, op: WireOp, scratch: &WireScratch) -> Response {
    dispatch(coord, op.into_request(scratch))
}

/// Per-connection loop of the legacy server: `Json`-tree parse and
/// serialize, one request per iteration, one thread per connection.
fn handle_conn_legacy(coord: Coordinator, socket: TcpStream) -> Result<()> {
    socket.set_nodelay(true)?;
    let mut writer = socket.try_clone()?;
    let reader = BufReader::new(socket);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let t_parse = Instant::now();
        let parsed = Request::parse(&line);
        OBS.record_stage(Stage::Parse, t_parse.elapsed());
        let response = match parsed {
            Ok(req) => dispatch(&coord, req),
            Err(e) => Response::Error {
                code: e.code().to_string(),
                message: format!("bad request: {e}"),
            },
        };
        let t_ser = Instant::now();
        let mut out = response.to_json().to_string().into_bytes();
        out.push(b'\n');
        writer.write_all(&out)?;
        OBS.record_stage(Stage::Serialize, t_ser.elapsed());
    }
    Ok(())
}

fn err_response(e: SimetraError) -> Response {
    Response::Error { code: e.code().to_string(), message: e.to_string() }
}

fn dispatch(coord: &Coordinator, req: Request) -> Response {
    match req {
        Request::Ping => Response::Pong,
        Request::Stats => Response::Stats(coord.stats()),
        Request::Config => Response::Config(coord.describe()),
        // Legacy ops stay byte-identical: served through the one search
        // path as plain plans, answered with the original `ok` envelope.
        Request::Knn { vector, k } => match coord.knn(vector, k.max(1)) {
            Ok((hits, sim_evals)) => Response::Ok { hits, sim_evals },
            Err(e) => err_response(e),
        },
        Request::Range { vector, tau } => match coord.range(vector, tau) {
            Ok((hits, sim_evals)) => Response::Ok { hits, sim_evals },
            Err(e) => err_response(e),
        },
        Request::Search { vector, req } => match coord.search(vector, req) {
            Ok(result) => Response::Search(result),
            Err(e) => err_response(e),
        },
        // Same execution path as `search` — only the reply envelope
        // differs (it carries the trace the forced `req.trace` recorded).
        Request::Explain { vector, req } => match coord.search(vector, req) {
            Ok(result) => Response::Explain(result),
            Err(e) => err_response(e),
        },
        Request::Metrics => Response::Metrics { text: coord.prometheus() },
        Request::Insert { vector } => match coord.insert(vector) {
            Ok(id) => Response::Inserted { id },
            Err(e) => err_response(e),
        },
        Request::Delete { id } => match coord.delete(id) {
            Ok(existed) => Response::Deleted { existed },
            Err(e) => err_response(e),
        },
        Request::Flush => match coord.flush() {
            Ok(()) => Response::Done,
            Err(e) => err_response(e),
        },
        Request::Compact => match coord.compact() {
            Ok(()) => Response::Done,
            Err(e) => err_response(e),
        },
    }
}

/// Reject filter ids a JSON double cannot carry unambiguously (>= 2^53)
/// *before* serialization: `Json::Num` would silently round them to a
/// neighboring id — the same corruption class the `Json::as_u64`
/// parse-side guard exists for, caught here on the way out instead (both
/// sides share `util::json::MAX_EXACT_JSON_INT`).
fn check_wire_filter(req: &SearchRequest) -> Result<()> {
    if let Some(ids) = req.filter.ids() {
        if let Some(&id) = ids.iter().find(|&&id| id >= crate::util::json::MAX_EXACT_JSON_INT) {
            anyhow::bail!("filter id {id} exceeds 2^53 and cannot be sent exactly over the wire");
        }
    }
    Ok(())
}

/// Rebuild [`SimetraError::DimMismatch`] from its stable wire message
/// ("vector dimension {got} does not match corpus dimension {want}").
fn parse_dim_mismatch(message: &str) -> Option<SimetraError> {
    let mut nums = message
        .split(|c: char| !c.is_ascii_digit())
        .filter(|s| !s.is_empty())
        .map(str::parse::<usize>);
    let got = nums.next()?.ok()?;
    let want = nums.next()?.ok()?;
    Some(SimetraError::DimMismatch { got, want })
}

/// Blocking line-protocol client for examples, tests and load generators.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr).context("connect")?;
        stream.set_nodelay(true)?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    pub fn request(&mut self, req: &Request) -> Result<Response> {
        // Guard the one op that carries raw u64 id lists before the
        // infallible JSON serialization can round them (see
        // check_wire_filter) — so every sender is covered, not just the
        // typed `search` wrappers.
        if let Request::Search { req: plan, .. } | Request::Explain { req: plan, .. } = req {
            check_wire_filter(plan)?;
        }
        let mut line = req.to_json().to_string().into_bytes();
        line.push(b'\n');
        self.writer.write_all(&line)?;
        let mut buf = String::new();
        self.reader.read_line(&mut buf)?;
        Response::parse(&buf)
    }

    /// Send raw bytes (for protocol-robustness tests).
    pub fn request_raw(&mut self, raw: &[u8]) -> Result<Response> {
        self.writer.write_all(raw)?;
        let mut buf = String::new();
        self.reader.read_line(&mut buf)?;
        Response::parse(&buf)
    }

    pub fn knn(&mut self, vector: Vec<f32>, k: usize) -> Result<Vec<Hit>> {
        match self.request(&Request::Knn { vector, k })? {
            Response::Ok { hits, .. } => Ok(hits),
            Response::Error { message, .. } => anyhow::bail!("server error: {message}"),
            other => anyhow::bail!("unexpected response: {other:?}"),
        }
    }

    /// Execute one typed search plan (ADR-005) over the wire `search` op.
    pub fn search(&mut self, vector: Vec<f32>, req: SearchRequest) -> Result<SearchResult> {
        match self.request(&Request::Search { vector, req })? {
            Response::Search(result) => Ok(result),
            Response::Error { message, .. } => anyhow::bail!("server error: {message}"),
            other => anyhow::bail!("unexpected response: {other:?}"),
        }
    }

    /// Like [`Client::search`], surfacing the server's typed error code on
    /// failure (the `Response::Error` envelope's `code` field).
    pub fn search_checked(
        &mut self,
        vector: Vec<f32>,
        req: SearchRequest,
    ) -> Result<SearchResult, SimetraError> {
        check_wire_filter(&req).map_err(|e| SimetraError::BadRequest(e.to_string()))?;
        let resp = self
            .request(&Request::Search { vector, req })
            .map_err(|e| SimetraError::Io(e.to_string()))?;
        match resp {
            Response::Search(result) => Ok(result),
            Response::Error { code, message } => Err(match code.as_str() {
                "unknown_op" => SimetraError::UnknownOp(message),
                "kernel_unavailable" => SimetraError::KernelUnavailable(message),
                "io" => SimetraError::Io(message),
                // The structured fields are not on the wire; rebuild them
                // from the (stable) message so `code()` stays faithful.
                "dim_mismatch" => parse_dim_mismatch(&message)
                    .unwrap_or(SimetraError::BadRequest(message)),
                _ => SimetraError::BadRequest(message),
            }),
            other => Err(SimetraError::Io(format!("unexpected response: {other:?}"))),
        }
    }

    /// Insert a vector into a mutable corpus; returns the assigned id.
    pub fn insert(&mut self, vector: Vec<f32>) -> Result<u64> {
        match self.request(&Request::Insert { vector })? {
            Response::Inserted { id } => Ok(id),
            Response::Error { message, .. } => anyhow::bail!("server error: {message}"),
            other => anyhow::bail!("unexpected response: {other:?}"),
        }
    }

    /// Tombstone an id; returns whether it was live.
    pub fn delete(&mut self, id: u64) -> Result<bool> {
        match self.request(&Request::Delete { id })? {
            Response::Deleted { existed } => Ok(existed),
            Response::Error { message, .. } => anyhow::bail!("server error: {message}"),
            other => anyhow::bail!("unexpected response: {other:?}"),
        }
    }

    pub fn flush(&mut self) -> Result<()> {
        match self.request(&Request::Flush)? {
            Response::Done => Ok(()),
            Response::Error { message, .. } => anyhow::bail!("server error: {message}"),
            other => anyhow::bail!("unexpected response: {other:?}"),
        }
    }

    pub fn compact(&mut self) -> Result<()> {
        match self.request(&Request::Compact)? {
            Response::Done => Ok(()),
            Response::Error { message, .. } => anyhow::bail!("server error: {message}"),
            other => anyhow::bail!("unexpected response: {other:?}"),
        }
    }

    pub fn stats(&mut self) -> Result<StatsSnapshot> {
        match self.request(&Request::Stats)? {
            Response::Stats(s) => Ok(s),
            Response::Error { message, .. } => anyhow::bail!("server error: {message}"),
            other => anyhow::bail!("unexpected response: {other:?}"),
        }
    }

    /// The server's fixed serving configuration (kernel backend, index,
    /// bound, mode).
    pub fn config(&mut self) -> Result<ConfigSnapshot> {
        match self.request(&Request::Config)? {
            Response::Config(c) => Ok(c),
            Response::Error { message, .. } => anyhow::bail!("server error: {message}"),
            other => anyhow::bail!("unexpected response: {other:?}"),
        }
    }

    /// Execute a traced search over the wire `explain` op; the result's
    /// `trace` holds the traversal event log.
    pub fn explain(&mut self, vector: Vec<f32>, req: SearchRequest) -> Result<SearchResult> {
        match self.request(&Request::Explain { vector, req })? {
            Response::Explain(result) => Ok(result),
            Response::Error { message, .. } => anyhow::bail!("server error: {message}"),
            other => anyhow::bail!("unexpected response: {other:?}"),
        }
    }

    /// Fetch the Prometheus text exposition over the wire `metrics` op.
    pub fn metrics(&mut self) -> Result<String> {
        match self.request(&Request::Metrics)? {
            Response::Metrics { text } => Ok(text),
            Response::Error { message, .. } => anyhow::bail!("server error: {message}"),
            other => anyhow::bail!("unexpected response: {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordinatorConfig;
    use crate::data::uniform_sphere;

    #[test]
    fn serve_and_query_over_tcp() {
        let pts = uniform_sphere(200, 8, 111);
        let coord = Coordinator::new(pts.clone(), CoordinatorConfig::default()).unwrap();
        let server = serve(coord, "127.0.0.1:0").unwrap();
        let mut client = Client::connect(server.addr()).unwrap();

        match client.request(&Request::Ping).unwrap() {
            Response::Pong => {}
            other => panic!("{other:?}"),
        }
        let hits = client.knn(pts[3].as_slice().to_vec(), 4).unwrap();
        assert_eq!(hits.len(), 4);
        assert_eq!(hits[0].id, 3);
        // The config op reports the build-time serving configuration.
        let cfg = client.config().unwrap();
        assert_eq!(cfg.index, "vp");
        assert_eq!(cfg.mode, "index");
        assert!(!cfg.mutable);
        assert!(!cfg.kernel.is_empty());
        match client.request(&Request::Stats).unwrap() {
            Response::Stats(s) => {
                assert_eq!(s.corpus_size, 200);
                assert!(s.queries >= 1);
                assert!(s.bytes_in > 0, "wire bytes not counted: {s:?}");
                assert!(s.bytes_out > 0, "wire bytes not counted: {s:?}");
                assert_eq!(s.conns_live, 1);
            }
            other => panic!("{other:?}"),
        }
        // Malformed input yields an error response, not a dropped connection.
        match client.request_raw(b"{not json}\n").unwrap() {
            Response::Error { .. } => {}
            other => panic!("{other:?}"),
        }
        // The connection still works afterwards.
        let hits = client.knn(pts[5].as_slice().to_vec(), 2).unwrap();
        assert_eq!(hits[0].id, 5);

        // Explain returns the same hits as a plain search plus a trace.
        let req = SearchRequest::knn(4).build();
        let plain = client.search(pts[3].as_slice().to_vec(), req.clone()).unwrap();
        let traced = client.explain(pts[3].as_slice().to_vec(), req).unwrap();
        assert_eq!(plain.hits.len(), traced.hits.len());
        for (a, b) in plain.hits.iter().zip(traced.hits.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.score.to_bits(), b.score.to_bits());
        }
        assert!(plain.trace.is_empty());
        assert!(!traced.trace.is_empty());

        // Metrics serves a non-empty Prometheus text exposition,
        // including the wire counters and pool gauges.
        let text = client.metrics().unwrap();
        assert!(text.contains("# TYPE simetra_queries_total counter"));
        assert!(text.contains("simetra_request_latency_us_count"));
        assert!(text.contains("# TYPE simetra_bytes_in_total counter"));
        assert!(text.contains("# TYPE simetra_bytes_out_total counter"));
        assert!(text.contains("simetra_conns_live 1"));
        assert!(text.contains("# TYPE simetra_conns_queued gauge"));
    }

    #[test]
    fn multiple_concurrent_clients() {
        let pts = uniform_sphere(100, 8, 112);
        let coord = Coordinator::new(pts.clone(), CoordinatorConfig::default()).unwrap();
        let server = serve(coord, "127.0.0.1:0").unwrap();
        let addr = server.addr();
        let mut handles = Vec::new();
        for c in 0..8usize {
            let pts = pts.clone();
            handles.push(std::thread::spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                for qi in 0..10 {
                    let id = (c * 10 + qi) % 100;
                    let hits = client.knn(pts[id].as_slice().to_vec(), 1).unwrap();
                    assert_eq!(hits[0].id, id as u64);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn more_clients_than_pool_workers() {
        let pts = uniform_sphere(100, 8, 116);
        let coord = Coordinator::new(pts.clone(), CoordinatorConfig::default()).unwrap();
        let server = serve_with(coord, "127.0.0.1:0", ServeConfig { workers: 2 }).unwrap();
        let addr = server.addr();
        let mut handles = Vec::new();
        for c in 0..8usize {
            let pts = pts.clone();
            handles.push(std::thread::spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                for qi in 0..5 {
                    let id = (c * 13 + qi) % 100;
                    let hits = client.knn(pts[id].as_slice().to_vec(), 1).unwrap();
                    assert_eq!(hits[0].id, id as u64);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn pipelined_requests_are_answered_in_order() {
        let pts = uniform_sphere(64, 8, 114);
        let coord = Coordinator::new(pts.clone(), CoordinatorConfig::default()).unwrap();
        let server = serve(coord, "127.0.0.1:0").unwrap();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        let mut burst = Vec::new();
        for id in 0..32usize {
            let req = Request::Knn { vector: pts[id].as_slice().to_vec(), k: 1 };
            burst.extend_from_slice(req.to_json().to_string().as_bytes());
            burst.push(b'\n');
        }
        stream.write_all(&burst).unwrap();
        let mut reader = BufReader::new(stream);
        for id in 0..32usize {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            match Response::parse(&line).unwrap() {
                Response::Ok { hits, .. } => assert_eq!(hits[0].id, id as u64),
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn slow_reader_gets_backpressure_not_disconnect() {
        let pts = uniform_sphere(64, 8, 115);
        let coord = Coordinator::new(pts.clone(), CoordinatorConfig::default()).unwrap();
        let server = serve_with(coord, "127.0.0.1:0", ServeConfig { workers: 2 }).unwrap();
        let addr = server.addr();
        // A slow reader: hundreds of pipelined responses back up in the
        // socket buffers until the client finally drains them.
        let mut slow = TcpStream::connect(addr).unwrap();
        let mut burst = Vec::new();
        for id in 0..256usize {
            let req = Request::Knn { vector: pts[id % 64].as_slice().to_vec(), k: 8 };
            burst.extend_from_slice(req.to_json().to_string().as_bytes());
            burst.push(b'\n');
        }
        slow.write_all(&burst).unwrap();
        // While those responses queue, other connections stay responsive.
        let mut fast = Client::connect(addr).unwrap();
        let hits = fast.knn(pts[7].as_slice().to_vec(), 1).unwrap();
        assert_eq!(hits[0].id, 7);
        std::thread::sleep(Duration::from_millis(100));
        let mut reader = BufReader::new(slow);
        for id in 0..256usize {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            match Response::parse(&line).unwrap() {
                Response::Ok { hits, .. } => assert_eq!(hits[0].id, (id % 64) as u64),
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn stop_joins_pool_workers() {
        let pts = uniform_sphere(50, 8, 117);
        let coord = Coordinator::new(pts.clone(), CoordinatorConfig::default()).unwrap();
        let mut server = serve_with(coord, "127.0.0.1:0", ServeConfig { workers: 3 }).unwrap();
        let mut client = Client::connect(server.addr()).unwrap();
        let hits = client.knn(pts[1].as_slice().to_vec(), 1).unwrap();
        assert_eq!(hits[0].id, 1);
        server.stop();
        assert!(server.workers.is_empty(), "workers not joined by stop()");
        // The open connection was dropped by the shutdown: the next
        // request observes EOF (or a reset) instead of hanging.
        assert!(client.request(&Request::Ping).is_err());
    }

    fn exchange(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, line: &str) -> String {
        stream.write_all(line.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        reply
    }

    #[test]
    fn pool_answers_byte_identically_to_the_legacy_server() {
        let pts = uniform_sphere(80, 8, 118);
        let coord = Coordinator::new(pts, CoordinatorConfig::default()).unwrap();
        let pool = serve(coord.clone(), "127.0.0.1:0").unwrap();
        let legacy = serve_legacy(coord, "127.0.0.1:0").unwrap();
        let mut a = TcpStream::connect(pool.addr()).unwrap();
        let mut b = TcpStream::connect(legacy.addr()).unwrap();
        let mut ra = BufReader::new(a.try_clone().unwrap());
        let mut rb = BufReader::new(b.try_clone().unwrap());
        let lines = [
            r#"{"op":"ping"}"#,
            r#"{"op":"knn","vector":[1,0,0,0,0,0,0,0],"k":3}"#,
            r#"{"op":"range","vector":[0,1,0,0,0,0,0,0],"tau":0.9}"#,
            r#"{"op":"search","v":1,"vector":[0,0,1,0,0,0,0,0],"mode":"knn","k":2}"#,
            r#"{"op":"explain","v":1,"vector":[0,0,1,0,0,0,0,0],"mode":"knn","k":2}"#,
            r#"{"op":"explode"}"#,
            r#"{"op":"knn","vector":"nope","k":1}"#,
            r#"{"op":"delete","id":7}"#,
        ];
        for line in lines {
            let la = exchange(&mut a, &mut ra, line);
            let lb = exchange(&mut b, &mut rb, line);
            assert_eq!(la, lb, "divergent replies for {line}");
            assert!(la.ends_with('\n'), "unterminated reply for {line}");
        }
    }
}
