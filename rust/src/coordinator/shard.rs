//! A corpus shard: a zero-copy view into the shared [`CorpusStore`], the
//! local index built over it, and the per-shard execution strategies (pure
//! index walk, batched PJRT scoring, hybrid pivot filter).
//!
//! A shard never owns vector data: its view, its index, its LAESA pivot
//! table, and the PJRT input tiles all alias the one store buffer.

use std::sync::Arc;

use anyhow::Result;

use crate::bounds::BoundKind;
use crate::index::{
    BallTree, Corpus, CoverTree, Gnat, KnnHeap, Laesa, LinearScan, MTree, QueryStats,
    SimilarityIndex, VpTree,
};
use crate::metrics::DenseVec;
use crate::query::{QueryContext, SearchRequest, SearchResponse};
use crate::runtime::EngineHandle;
use crate::storage::CorpusView;

/// Which index structure each shard builds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IndexKind {
    Linear,
    Vp,
    Ball,
    MTree,
    Cover,
    Laesa,
    Gnat,
}

impl IndexKind {
    pub fn parse(s: &str) -> Option<IndexKind> {
        Some(match s {
            "linear" => IndexKind::Linear,
            "vp" | "vp-tree" | "vptree" => IndexKind::Vp,
            "ball" | "ball-tree" => IndexKind::Ball,
            "m" | "m-tree" | "mtree" => IndexKind::MTree,
            "cover" | "cover-tree" => IndexKind::Cover,
            "laesa" => IndexKind::Laesa,
            "gnat" => IndexKind::Gnat,
            _ => return None,
        })
    }

    /// Canonical name (round-trips through [`IndexKind::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            IndexKind::Linear => "linear",
            IndexKind::Vp => "vp",
            IndexKind::Ball => "ball",
            IndexKind::MTree => "m-tree",
            IndexKind::Cover => "cover",
            IndexKind::Laesa => "laesa",
            IndexKind::Gnat => "gnat",
        }
    }

    /// Dense ordinal into the observability registry's per-index slots;
    /// pinned to [`crate::obs::INDEX_NAMES`] by a unit test below.
    pub fn ordinal(self) -> usize {
        self as usize
    }

    /// Build this index kind over a zero-copy corpus view (the view is an
    /// `Arc`-backed handle; no vector data is cloned).
    pub fn build(
        self,
        view: CorpusView,
        bound: BoundKind,
    ) -> Box<dyn SimilarityIndex<DenseVec>> {
        match self {
            IndexKind::Linear => Box::new(LinearScan::build(view)),
            IndexKind::Vp => Box::new(VpTree::build(view, bound, 0x5ee_d)),
            IndexKind::Ball => Box::new(BallTree::build(view, bound, 16)),
            IndexKind::MTree => Box::new(MTree::build(view, bound, 12)),
            IndexKind::Cover => Box::new(CoverTree::build(view, bound)),
            IndexKind::Laesa => Box::new(Laesa::build(view, bound, 24)),
            IndexKind::Gnat => Box::new(Gnat::build(view, bound, 8)),
        }
    }
}

/// Execution strategy for query batches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Per-query index walk (scalar hot path).
    Index,
    /// Batched exhaustive scoring through the PJRT artifact (top-k only;
    /// range queries fall back to the index).
    Engine,
    /// LAESA pivot filtering through the PJRT `pivot_filter` artifact,
    /// exact re-scoring of survivors in rust.
    Hybrid,
}

impl ExecMode {
    pub fn parse(s: &str) -> Option<ExecMode> {
        Some(match s {
            "index" => ExecMode::Index,
            "engine" => ExecMode::Engine,
            "hybrid" => ExecMode::Hybrid,
            _ => return None,
        })
    }

    /// Canonical name (round-trips through [`ExecMode::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            ExecMode::Index => "index",
            ExecMode::Engine => "engine",
            ExecMode::Hybrid => "hybrid",
        }
    }
}

/// One shard of the corpus with its local index. Local ids `0..len` map to
/// global ids `base..base+len`.
pub struct Shard {
    /// Global id of local item 0 (shards own contiguous id blocks).
    pub base: u64,
    /// Zero-copy window onto the shared store.
    view: CorpusView,
    index: Box<dyn SimilarityIndex<DenseVec>>,
    /// Pivot table for the hybrid path.
    laesa: Option<Laesa<CorpusView>>,
    /// Pivot->corpus similarity table, f32 row-major (p, n), for the engine.
    pivot_table_f32: Vec<f32>,
    bound: BoundKind,
    kind: IndexKind,
}

impl Shard {
    /// Build a shard over a corpus view. The serving stack
    /// (`router::build_shards`) always passes contiguous row-range views;
    /// id-list views work for the index/hybrid paths but make
    /// [`Shard::flat_corpus`] panic — keep engine-path shards contiguous.
    pub fn new(
        base: u64,
        view: CorpusView,
        kind: IndexKind,
        bound: BoundKind,
        hybrid_pivots: usize,
    ) -> Self {
        let laesa = if hybrid_pivots > 0 && !view.is_empty() {
            Some(Laesa::build(view.clone(), bound, hybrid_pivots))
        } else {
            None
        };
        let pivot_table_f32 = match &laesa {
            Some(l) => {
                let n = view.len();
                let mut t = Vec::with_capacity(l.n_pivots() * n);
                for p in 0..l.n_pivots() {
                    t.extend(l.table_row(p).iter().map(|&v| v as f32));
                }
                t
            }
            None => Vec::new(),
        };
        let index = kind.build(view.clone(), bound);
        Shard { base, view, index, laesa, pivot_table_f32, bound, kind }
    }

    pub fn len(&self) -> usize {
        self.view.len()
    }

    pub fn is_empty(&self) -> bool {
        self.view.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.view.dim()
    }

    /// The shard's view into the shared store.
    pub fn view(&self) -> &CorpusView {
        &self.view
    }

    /// Row-major normalized matrix: a borrowed slice of the shared store's
    /// buffer — no copy. (The engine path itself ships view tiles; this
    /// accessor exists for aliasing checks and direct matrix consumers.)
    ///
    /// # Panics
    /// Panics if the shard was built over a non-contiguous (id-list) view;
    /// see [`Shard::new`]. Use [`Shard::view`] +
    /// [`CorpusView::contiguous_or_gather`] when that case must work.
    pub fn flat_corpus(&self) -> &[f32] {
        self.view
            .as_contiguous()
            .expect("shard view is a non-contiguous id-list; see Shard::new docs")
    }

    /// Per-query kNN through the local index (throwaway scratch).
    pub fn knn_index(&self, q: &DenseVec, k: usize) -> (Vec<(u32, f64)>, QueryStats) {
        let mut stats = QueryStats::default();
        let hits = self.index.knn(q, k, &mut stats);
        (hits, stats)
    }

    /// Per-query range through the local index (throwaway scratch).
    pub fn range_index(&self, q: &DenseVec, tau: f64) -> (Vec<(u32, f64)>, QueryStats) {
        let mut stats = QueryStats::default();
        let hits = self.index.range(q, tau, &mut stats);
        (hits, stats)
    }

    /// Execute one typed search plan against this shard through a borrowed
    /// [`QueryContext`] — the worker hot path: the traversal reuses the
    /// context's heap, frontier, and quantized-query cache instead of
    /// allocating (ADR-004/ADR-005). Marks the query boundary itself.
    /// The request's filter ids are *global*; they are translated into
    /// this shard's local id space (its contiguous block) before the index
    /// runs. Returns local-id hits, the per-query stats window, the
    /// budget-truncation flag, and the trace event log (empty unless the
    /// request asked for one).
    pub fn search_ctx(
        &self,
        q: &DenseVec,
        req: &SearchRequest,
        ctx: &mut QueryContext,
    ) -> (Vec<(u32, f64)>, QueryStats, bool, Vec<crate::obs::TraceEvent>) {
        ctx.begin_query();
        let mut resp = SearchResponse::default();
        if req.filter.is_none() || self.base == 0 {
            // base == 0 means global ids ARE this shard's local ids
            // (entries beyond the shard's range match nothing and
            // constrain nothing), so the filter is shared as-is — no
            // per-query translation copy for the first/only shard.
            self.index.search_into(q, req, ctx, &mut resp);
        } else {
            let hi = self.base + self.len() as u64;
            let local = req.localized(req.mode, |id| {
                if (self.base..hi).contains(&id) {
                    Some(id - self.base)
                } else {
                    None
                }
            });
            self.index.search_into(q, &local, ctx, &mut resp);
        }
        if ctx.obs_enabled() {
            ctx.drain_slack(self.kind.ordinal());
        }
        (resp.hits, ctx.stats, resp.truncated, resp.trace)
    }

    /// Per-query kNN through a borrowed [`QueryContext`] (plain-plan shim
    /// over [`Shard::search_ctx`]).
    pub fn knn_ctx(
        &self,
        q: &DenseVec,
        k: usize,
        ctx: &mut QueryContext,
    ) -> (Vec<(u32, f64)>, QueryStats) {
        let (hits, stats, _, _) = self.search_ctx(q, &SearchRequest::knn(k).build(), ctx);
        (hits, stats)
    }

    /// Per-query range through a borrowed [`QueryContext`] (plain-plan
    /// shim over [`Shard::search_ctx`]).
    pub fn range_ctx(
        &self,
        q: &DenseVec,
        tau: f64,
        ctx: &mut QueryContext,
    ) -> (Vec<(u32, f64)>, QueryStats) {
        let (hits, stats, _, _) = self.search_ctx(q, &SearchRequest::range(tau).build(), ctx);
        (hits, stats)
    }

    /// A whole batch of typed plans through one shared context (ADR-006):
    /// plain plans ride the index's shared-frontier multi-query traversal;
    /// optioned plans fall back to per-query execution inside the same
    /// call. Filters are translated into shard-local id space exactly as
    /// in [`Shard::search_ctx`]. Owns the query boundary. Responses land
    /// in `resps` (resized to `queries.len()`), hits in local ids.
    pub fn search_batch_ctx(
        &self,
        queries: &[DenseVec],
        reqs: &[SearchRequest],
        ctx: &mut QueryContext,
        resps: &mut Vec<SearchResponse>,
    ) {
        if self.base == 0 || reqs.iter().all(|r| r.filter.is_none()) {
            // base == 0: global ids ARE local ids (see search_ctx).
            self.index.search_batch_into(queries, reqs, ctx, resps);
            if ctx.obs_enabled() {
                ctx.drain_slack(self.kind.ordinal());
            }
            return;
        }
        let hi = self.base + self.len() as u64;
        let local: Vec<SearchRequest> = reqs
            .iter()
            .map(|req| {
                if req.filter.is_none() {
                    req.clone()
                } else {
                    req.localized(req.mode, |id| {
                        if (self.base..hi).contains(&id) {
                            Some(id - self.base)
                        } else {
                            None
                        }
                    })
                }
            })
            .collect();
        self.index.search_batch_into(queries, &local, ctx, resps);
        if ctx.obs_enabled() {
            ctx.drain_slack(self.kind.ordinal());
        }
    }

    /// A whole kNN batch through one shared context: per-query results and
    /// stats, byte-identical to per-query [`Shard::knn_index`] calls.
    pub fn knn_batch(
        &self,
        queries: &[DenseVec],
        k: usize,
        ctx: &mut QueryContext,
    ) -> Vec<(Vec<(u32, f64)>, QueryStats)> {
        self.index.knn_batch(queries, k, ctx)
    }

    /// A whole range batch through one shared context; see
    /// [`Shard::knn_batch`].
    pub fn range_batch(
        &self,
        queries: &[DenseVec],
        tau: f64,
        ctx: &mut QueryContext,
    ) -> Vec<(Vec<(u32, f64)>, QueryStats)> {
        self.index.range_batch(queries, tau, ctx)
    }

    /// Batched kNN over the whole shard through the PJRT artifact, tiling
    /// the corpus when it exceeds the largest artifact. Tiles are sub-views
    /// of the store: the engine reads the shared buffer directly.
    pub fn knn_engine(
        &self,
        engine: &EngineHandle,
        queries: &[DenseVec],
        k: usize,
    ) -> Result<Vec<Vec<(u32, f64)>>> {
        let qn = queries.len();
        let mut qflat = Vec::with_capacity(qn * self.dim());
        for q in queries {
            qflat.extend_from_slice(q.as_slice());
        }
        let qflat = Arc::new(qflat);
        // Tile size: the largest n available for this d is discovered by
        // probing; use 8192 (the biggest emitted variant) and fall back to
        // smaller tiles automatically via variant selection.
        let tile = 8192usize;
        let mut heaps: Vec<KnnHeap> = (0..qn).map(|_| KnnHeap::new(k)).collect();
        let mut start = 0usize;
        while start < self.len() {
            let n = tile.min(self.len() - start);
            let sub = self.view.slice_rows(start, start + n);
            let out = engine.score_topk(qflat.clone(), qn, sub, k.min(n))?;
            for qi in 0..qn {
                for j in 0..out.k {
                    let idx = out.indices[qi * out.k + j];
                    let val = out.values[qi * out.k + j] as f64;
                    heaps[qi].offer((start + idx as usize) as u32, val);
                }
            }
            start += n;
        }
        Ok(heaps.into_iter().map(|h| h.into_sorted()).collect())
    }

    /// Certified (lb, ub) for every (query, corpus item) through the PJRT
    /// `pivot_filter` artifact, tiling the corpus when the shard exceeds the
    /// largest artifact's n. Returns row-major (qn, n) arrays.
    fn pivot_bounds_tiled(
        &self,
        engine: &EngineHandle,
        sim_qp: &[f32],
        qn: usize,
        p: usize,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let n = self.len();
        const TILE: usize = 4096;
        let mut lb = vec![0.0f32; qn * n];
        let mut ub = vec![0.0f32; qn * n];
        let mut start = 0usize;
        while start < n {
            let tn = TILE.min(n - start);
            // Column slice of the row-major (p, n) pivot table.
            let mut pc = Vec::with_capacity(p * tn);
            for row in 0..p {
                pc.extend_from_slice(&self.pivot_table_f32[row * n + start..row * n + start + tn]);
            }
            let out = engine.pivot_filter(sim_qp.to_vec(), qn, pc, p, tn)?;
            for qi in 0..qn {
                lb[qi * n + start..qi * n + start + tn]
                    .copy_from_slice(&out.lb[qi * tn..(qi + 1) * tn]);
                ub[qi * n + start..qi * n + start + tn]
                    .copy_from_slice(&out.ub[qi * tn..(qi + 1) * tn]);
            }
            start += tn;
        }
        Ok((lb, ub))
    }

    /// Query-pivot similarities (exact, cheap: p dots per query), row-major,
    /// through the blocked batch kernel.
    fn query_pivot_sims(&self, laesa: &Laesa<CorpusView>, queries: &[DenseVec]) -> Vec<f32> {
        let mut sim_qp = Vec::with_capacity(queries.len() * laesa.n_pivots());
        let mut buf = Vec::new();
        for q in queries {
            self.view.sims(q, laesa.pivots(), &mut buf);
            sim_qp.extend(buf.iter().map(|&v| v as f32));
        }
        sim_qp
    }

    /// Hybrid kNN: pivot similarities in rust, certified bounds through the
    /// PJRT `pivot_filter` artifact, exact re-scoring of survivors in rust.
    /// Returns per-query hits plus the number of exact evaluations spent.
    pub fn knn_hybrid(
        &self,
        engine: &EngineHandle,
        queries: &[DenseVec],
        k: usize,
    ) -> Result<Vec<(Vec<(u32, f64)>, u64)>> {
        let laesa = self
            .laesa
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("shard built without pivots"))?;
        let qn = queries.len();
        let p = laesa.n_pivots();
        let n = self.len();
        let sim_qp = self.query_pivot_sims(laesa, queries);
        let bounds = {
            let (lb, ub) = self.pivot_bounds_tiled(engine, &sim_qp, qn, p)?;
            crate::runtime::PivotBounds { lb, ub, n }
        };
        let mut out = Vec::with_capacity(qn);
        // f32 bound slack: the artifact computes in f32; widen certified
        // intervals by an epsilon so no true neighbor is lost to roundoff.
        const EPS: f64 = 1e-5;
        for qi in 0..qn {
            let lb = &bounds.lb[qi * n..(qi + 1) * n];
            let ub = &bounds.ub[qi * n..(qi + 1) * n];
            // Floor: k-th largest certified lower bound.
            let mut lbs: Vec<f64> = lb.iter().map(|&v| v as f64 - EPS).collect();
            let kth = if lbs.len() > k {
                let (_, kth, _) = lbs.select_nth_unstable_by(k - 1, |a, b| b.partial_cmp(a).unwrap());
                *kth
            } else {
                -1.0
            };
            let mut heap = KnnHeap::new(k);
            let mut evals = 0u64;
            for (i, &u) in ub.iter().enumerate() {
                if (u as f64 + EPS) >= kth {
                    let s = self.view.sim_q(&queries[qi], i as u32);
                    evals += 1;
                    heap.offer(i as u32, s);
                }
            }
            out.push((heap.into_sorted(), evals));
        }
        Ok(out)
    }

    /// Hybrid range: like `knn_hybrid` but with a fixed threshold.
    pub fn range_hybrid(
        &self,
        engine: &EngineHandle,
        queries: &[DenseVec],
        tau: f64,
    ) -> Result<Vec<(Vec<(u32, f64)>, u64)>> {
        let laesa = self
            .laesa
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("shard built without pivots"))?;
        let qn = queries.len();
        let p = laesa.n_pivots();
        let n = self.len();
        let sim_qp = self.query_pivot_sims(laesa, queries);
        let bounds = {
            let (lb, ub) = self.pivot_bounds_tiled(engine, &sim_qp, qn, p)?;
            crate::runtime::PivotBounds { lb, ub, n }
        };
        const EPS: f64 = 1e-5;
        let mut out = Vec::with_capacity(qn);
        for qi in 0..qn {
            let ub = &bounds.ub[qi * n..(qi + 1) * n];
            let mut hits = Vec::new();
            let mut evals = 0u64;
            for (i, &u) in ub.iter().enumerate() {
                if (u as f64 + EPS) >= tau {
                    let s = self.view.sim_q(&queries[qi], i as u32);
                    evals += 1;
                    if s >= tau {
                        hits.push((i as u32, s));
                    }
                }
            }
            hits.sort_unstable_by(|a: &(u32, f64), b| {
                b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0))
            });
            out.push((hits, evals));
        }
        Ok(out)
    }

    pub fn bound(&self) -> BoundKind {
        self.bound
    }

    /// The index structure this shard built (drives the per-index slot in
    /// the observability registry).
    pub fn kind(&self) -> IndexKind {
        self.kind
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::uniform_sphere;
    use crate::storage::CorpusStore;

    #[test]
    fn index_kinds_parse() {
        assert_eq!(IndexKind::parse("vp"), Some(IndexKind::Vp));
        assert_eq!(IndexKind::parse("m-tree"), Some(IndexKind::MTree));
        assert_eq!(IndexKind::parse("bogus"), None);
    }

    #[test]
    fn ordinals_pin_obs_index_names() {
        // The obs registry labels per-index slots by ordinal; every kind's
        // canonical name must sit at its own slot in INDEX_NAMES.
        let kinds = [
            IndexKind::Linear,
            IndexKind::Vp,
            IndexKind::Ball,
            IndexKind::MTree,
            IndexKind::Cover,
            IndexKind::Laesa,
            IndexKind::Gnat,
        ];
        assert_eq!(kinds.len(), crate::obs::INDEX_NAMES.len());
        for k in kinds {
            assert_eq!(crate::obs::INDEX_NAMES[k.ordinal()], k.name());
        }
    }

    #[test]
    fn shard_local_search_matches_linear() {
        let pts = uniform_sphere(300, 16, 81);
        let store = CorpusStore::from_rows(pts.clone());
        let shard = Shard::new(0, store.view(), IndexKind::Vp, BoundKind::Mult, 0);
        let lin = Shard::new(0, store.view(), IndexKind::Linear, BoundKind::Mult, 0);
        let (a, _) = shard.knn_index(&pts[5], 7);
        let (b, _) = lin.knn_index(&pts[5], 7);
        for ((_, x), (_, y)) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn shards_alias_one_store_buffer() {
        let pts = uniform_sphere(64, 8, 82);
        let store = CorpusStore::from_rows(pts);
        let a = Shard::new(0, store.slice(0..32), IndexKind::Linear, BoundKind::Mult, 4);
        let b = Shard::new(32, store.slice(32..64), IndexKind::Linear, BoundKind::Mult, 4);
        assert_eq!(a.flat_corpus().as_ptr(), store.flat().as_ptr());
        assert_eq!(b.flat_corpus().as_ptr(), store.flat()[32 * 8..].as_ptr());
    }
}
