//! Synthetic workload generators.
//!
//! The paper evaluates its bounds on value grids, not datasets; the index
//! and serving experiments need corpora. Substitution (documented in
//! DESIGN.md): we generate the workloads the paper's introduction motivates —
//! dense neural-network-embedding-like vectors (uniform sphere and von
//! Mises–Fisher cluster mixtures) and sparse text-like tf-idf vectors with
//! Zipf-distributed vocabulary.
//!
//! Dense generators come in two flavors: `Vec<DenseVec>` (owning, handy in
//! tests) and `*_store` variants that sample straight into a contiguous
//! [`crate::storage::CorpusStore`] — the native serving path, bit-identical
//! rows, no per-vector allocations.

pub mod sphere;
pub mod vmf;
pub mod zipf;

pub use sphere::{uniform_sphere, uniform_sphere_store};
pub use vmf::{vmf_mixture, vmf_mixture_store, VmfSpec};
pub use zipf::{zipf_corpus, ZipfSpec};
