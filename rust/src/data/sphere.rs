//! Uniform samples on the unit sphere S^{d-1}.

use crate::metrics::DenseVec;
use crate::util::Rng;

/// `n` i.i.d. uniform unit vectors in `d` dimensions (isotropic Gaussian,
/// normalized) — the hardest case for pruning (no cluster structure).
pub fn uniform_sphere(n: usize, d: usize, seed: u64) -> Vec<DenseVec> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..n).map(|_| sample_unit(&mut rng, d)).collect()
}

pub(crate) fn sample_unit(rng: &mut Rng, d: usize) -> DenseVec {
    loop {
        let raw: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let norm: f64 = raw.iter().map(|&v| v as f64 * v as f64).sum::<f64>().sqrt();
        if norm > 1e-12 {
            let inv = (1.0 / norm) as f32;
            return DenseVec::from_normalized(raw.iter().map(|&v| v * inv).collect());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::SimVector;

    #[test]
    fn vectors_are_unit_norm() {
        for v in uniform_sphere(50, 16, 1) {
            let n: f64 = v.as_slice().iter().map(|&x| x as f64 * x as f64).sum();
            assert!((n - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        assert_eq!(uniform_sphere(5, 8, 7), uniform_sphere(5, 8, 7));
        assert_ne!(uniform_sphere(5, 8, 7), uniform_sphere(5, 8, 8));
    }

    #[test]
    fn high_dim_similarities_concentrate_near_zero() {
        // Distance concentration (paper §2): random high-dim directions are
        // nearly orthogonal.
        let pts = uniform_sphere(200, 256, 3);
        let mut max_abs: f64 = 0.0;
        for i in 1..pts.len() {
            max_abs = max_abs.max(pts[0].sim(&pts[i]).abs());
        }
        assert!(max_abs < 0.35, "max |sim| = {max_abs}");
    }
}
