//! Uniform samples on the unit sphere S^{d-1}.

use crate::metrics::DenseVec;
use crate::storage::CorpusStore;
use crate::util::Rng;

/// `n` i.i.d. uniform unit vectors in `d` dimensions (isotropic Gaussian,
/// normalized) — the hardest case for pruning (no cluster structure).
pub fn uniform_sphere(n: usize, d: usize, seed: u64) -> Vec<DenseVec> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..n).map(|_| sample_unit(&mut rng, d)).collect()
}

/// Store-native variant of [`uniform_sphere`]: samples straight into the
/// contiguous SoA buffer (no per-vector allocations) and produces rows
/// bit-identical to the `Vec<DenseVec>` variant for the same seed.
pub fn uniform_sphere_store(n: usize, d: usize, seed: u64) -> CorpusStore {
    let mut rng = Rng::seed_from_u64(seed);
    let mut flat = vec![0.0f32; n * d];
    for row in flat.chunks_mut(d.max(1)).take(n) {
        fill_unit_row(&mut rng, row);
    }
    CorpusStore::from_flat_normalized(flat, d)
}

pub(crate) fn sample_unit(rng: &mut Rng, d: usize) -> DenseVec {
    let mut row = vec![0.0f32; d];
    fill_unit_row(rng, &mut row);
    DenseVec::from_normalized(row)
}

/// Fill `row` with a uniform unit vector (rejection on near-zero norms).
pub(crate) fn fill_unit_row(rng: &mut Rng, row: &mut [f32]) {
    if row.is_empty() {
        return;
    }
    loop {
        for v in row.iter_mut() {
            *v = rng.normal() as f32;
        }
        let norm: f64 = row.iter().map(|&v| v as f64 * v as f64).sum::<f64>().sqrt();
        if norm > 1e-12 {
            let inv = (1.0 / norm) as f32;
            for v in row.iter_mut() {
                *v *= inv;
            }
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::SimVector;

    #[test]
    fn vectors_are_unit_norm() {
        for v in uniform_sphere(50, 16, 1) {
            let n: f64 = v.as_slice().iter().map(|&x| x as f64 * x as f64).sum();
            assert!((n - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        assert_eq!(uniform_sphere(5, 8, 7), uniform_sphere(5, 8, 7));
        assert_ne!(uniform_sphere(5, 8, 7), uniform_sphere(5, 8, 8));
    }

    #[test]
    fn store_variant_matches_vec_variant_bitwise() {
        let store = uniform_sphere_store(40, 16, 5);
        let rows = uniform_sphere(40, 16, 5);
        assert_eq!(store.len(), 40);
        assert_eq!(store.dim(), 16);
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(store.row(i), r.as_slice(), "row {i}");
        }
    }

    #[test]
    fn high_dim_similarities_concentrate_near_zero() {
        // Distance concentration (paper §2): random high-dim directions are
        // nearly orthogonal.
        let pts = uniform_sphere(200, 256, 3);
        let mut max_abs: f64 = 0.0;
        for i in 1..pts.len() {
            max_abs = max_abs.max(pts[0].sim(&pts[i]).abs());
        }
        assert!(max_abs < 0.35, "max |sim| = {max_abs}");
    }
}
