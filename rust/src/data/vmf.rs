//! Von Mises–Fisher mixtures: clustered directional data, the realistic
//! regime for embedding corpora (and the regime where similarity indexes
//! actually pay off).

use crate::metrics::DenseVec;
use crate::storage::{normalize_row, CorpusStore};
use crate::util::Rng;

use super::sphere::sample_unit;

/// Parameters of a vMF mixture corpus.
#[derive(Debug, Clone)]
pub struct VmfSpec {
    pub n: usize,
    pub dim: usize,
    pub clusters: usize,
    /// Concentration; higher = tighter clusters. kappa = 0 is uniform.
    pub kappa: f64,
    pub seed: u64,
}

impl Default for VmfSpec {
    fn default() -> Self {
        VmfSpec { n: 10_000, dim: 64, clusters: 32, kappa: 40.0, seed: 42 }
    }
}

/// Sample a vMF mixture: cluster means uniform on the sphere, points vMF
/// around a uniformly chosen mean. Returns (points, cluster assignment).
pub fn vmf_mixture(spec: &VmfSpec) -> (Vec<DenseVec>, Vec<u32>) {
    let mut rng = Rng::seed_from_u64(spec.seed);
    let means: Vec<DenseVec> =
        (0..spec.clusters).map(|_| sample_unit(&mut rng, spec.dim)).collect();
    let mut points = Vec::with_capacity(spec.n);
    let mut labels = Vec::with_capacity(spec.n);
    for _ in 0..spec.n {
        let c = rng.below(spec.clusters);
        points.push(sample_vmf(&mut rng, means[c].as_slice(), spec.kappa));
        labels.push(c as u32);
    }
    (points, labels)
}

/// Store-native variant of [`vmf_mixture`]: samples straight into the
/// contiguous SoA buffer (no per-vector allocations) and produces rows
/// bit-identical to the `Vec<DenseVec>` variant for the same spec.
pub fn vmf_mixture_store(spec: &VmfSpec) -> (CorpusStore, Vec<u32>) {
    let mut rng = Rng::seed_from_u64(spec.seed);
    let means: Vec<DenseVec> =
        (0..spec.clusters).map(|_| sample_unit(&mut rng, spec.dim)).collect();
    let mut flat = vec![0.0f32; spec.n * spec.dim];
    let mut labels = Vec::with_capacity(spec.n);
    for row in flat.chunks_mut(spec.dim.max(1)).take(spec.n) {
        let c = rng.below(spec.clusters);
        sample_vmf_into(&mut rng, means[c].as_slice(), spec.kappa, row);
        labels.push(c as u32);
    }
    (CorpusStore::from_flat_normalized(flat, spec.dim), labels)
}

/// Wood (1994) rejection sampler for vMF on S^{d-1}.
pub fn sample_vmf(rng: &mut Rng, mean: &[f32], kappa: f64) -> DenseVec {
    let mut out = vec![0.0f32; mean.len()];
    sample_vmf_into(rng, mean, kappa, &mut out);
    DenseVec::from_normalized(out)
}

/// [`sample_vmf`] writing into a caller-provided row (normalized in place).
pub fn sample_vmf_into(rng: &mut Rng, mean: &[f32], kappa: f64, out: &mut [f32]) {
    let d = mean.len();
    assert_eq!(out.len(), d, "output row dimension {} != mean dimension {d}", out.len());
    if kappa < 1e-9 {
        crate::data::sphere::fill_unit_row(rng, out);
        return;
    }
    let dm1 = (d - 1) as f64;
    let b = dm1 / (2.0 * kappa + (4.0 * kappa * kappa + dm1 * dm1).sqrt());
    let x0 = (1.0 - b) / (1.0 + b);
    let c = kappa * x0 + dm1 * (1.0 - x0 * x0).ln();

    // Sample the cosine w of the angle to the mean.
    let w = loop {
        let z: f64 = sample_beta(rng, dm1 / 2.0, dm1 / 2.0);
        let w = (1.0 - (1.0 + b) * z) / (1.0 - (1.0 - b) * z);
        let u: f64 = rng.f64();
        if kappa * w + dm1 * (1.0 - x0 * w).ln() - c >= u.ln() {
            break w;
        }
    };

    // Uniform tangential direction orthogonal to the mean.
    let mut v: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let dot: f64 = v.iter().zip(mean).map(|(&a, &m)| a * m as f64).sum();
    for (vi, &m) in v.iter_mut().zip(mean) {
        *vi -= dot * m as f64;
    }
    let norm: f64 = v.iter().map(|&a| a * a).sum::<f64>().sqrt();
    let t = (1.0 - w * w).max(0.0).sqrt();
    for ((o, &m), &vi) in out.iter_mut().zip(mean).zip(&v) {
        let vi = if norm > 1e-12 { vi / norm } else { 0.0 };
        *o = (w * m as f64 + t * vi) as f32;
    }
    // Same arithmetic as `DenseVec::new`: rows stay bit-identical to the
    // owning generator path.
    normalize_row(out);
}

fn sample_beta(rng: &mut Rng, a: f64, b: f64) -> f64 {
    // Beta via two gammas (Marsaglia–Tsang); a, b >= 0.5 in our use.
    let x = sample_gamma(rng, a);
    let y = sample_gamma(rng, b);
    x / (x + y)
}

fn sample_gamma(rng: &mut Rng, shape: f64) -> f64 {
    if shape < 1.0 {
        let u: f64 = rng.f64();
        return sample_gamma(rng, shape + 1.0) * u.powf(1.0 / shape);
    }
    let d = shape - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x: f64 = rng.normal();
        let v = (1.0 + c * x).powi(3);
        if v <= 0.0 {
            continue;
        }
        let u: f64 = rng.f64();
        if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
            return d * v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::SimVector;

    #[test]
    fn points_are_unit_norm() {
        let (pts, _) = vmf_mixture(&VmfSpec { n: 100, ..Default::default() });
        for p in pts {
            let n: f64 = p.as_slice().iter().map(|&x| x as f64 * x as f64).sum();
            assert!((n - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn higher_kappa_concentrates_around_mean() {
        let mut rng = Rng::seed_from_u64(5);
        let mean = sample_unit(&mut rng, 32);
        let mut avg = |kappa: f64| {
            let mut s = 0.0;
            for _ in 0..200 {
                s += sample_vmf(&mut rng, mean.as_slice(), kappa).sim(&mean);
            }
            s / 200.0
        };
        let loose = avg(2.0);
        let tight = avg(100.0);
        assert!(tight > loose, "tight={tight} loose={loose}");
        // E[cos theta] ~ 1 - (d-1)/(2 kappa) = 1 - 31/200 ~ 0.845 at d=32.
        assert!(tight > 0.75, "tight={tight}");
    }

    #[test]
    fn store_variant_matches_vec_variant_bitwise() {
        let spec = VmfSpec { n: 60, dim: 12, clusters: 5, kappa: 30.0, seed: 13 };
        let (store, store_labels) = vmf_mixture_store(&spec);
        let (pts, labels) = vmf_mixture(&spec);
        assert_eq!(store_labels, labels);
        for (i, p) in pts.iter().enumerate() {
            assert_eq!(store.row(i), p.as_slice(), "row {i}");
        }
    }

    #[test]
    fn same_cluster_pairs_are_more_similar() {
        let spec = VmfSpec { n: 400, dim: 32, clusters: 4, kappa: 60.0, seed: 9 };
        let (pts, labels) = vmf_mixture(&spec);
        let (mut same, mut diff, mut ns, mut nd) = (0.0, 0.0, 0, 0);
        for i in 0..200 {
            for j in (i + 1)..200 {
                let s = pts[i].sim(&pts[j]);
                if labels[i] == labels[j] {
                    same += s;
                    ns += 1;
                } else {
                    diff += s;
                    nd += 1;
                }
            }
        }
        assert!(same / ns as f64 > diff / nd as f64 + 0.2);
    }
}
