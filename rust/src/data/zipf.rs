//! Sparse text-like corpora: Zipf-distributed term draws, log-tf * idf
//! weighting — the workload shape of paper §2's text-analysis motivation.

use std::collections::HashMap;

use crate::sparse::SparseVec;
use crate::util::Rng;

/// Parameters of a synthetic tf-idf corpus.
#[derive(Debug, Clone)]
pub struct ZipfSpec {
    pub n_docs: usize,
    pub vocab: usize,
    /// Zipf exponent (~1.0 for natural language).
    pub exponent: f64,
    /// Mean document length in token draws.
    pub doc_len: usize,
    pub seed: u64,
    /// Number of latent topics; each doc draws most tokens from its topic's
    /// reshuffled rank order, giving cluster structure like real corpora.
    pub topics: usize,
}

impl Default for ZipfSpec {
    fn default() -> Self {
        ZipfSpec { n_docs: 5_000, vocab: 20_000, exponent: 1.07, doc_len: 120, seed: 42, topics: 25 }
    }
}

/// Generate the corpus: returns normalized tf-idf sparse vectors.
pub fn zipf_corpus(spec: &ZipfSpec) -> Vec<SparseVec> {
    let mut rng = Rng::seed_from_u64(spec.seed);
    // Zipf CDF table for inverse-transform sampling.
    let weights: Vec<f64> =
        (1..=spec.vocab).map(|r| 1.0 / (r as f64).powf(spec.exponent)).collect();
    let total: f64 = weights.iter().sum();
    let mut cdf = Vec::with_capacity(spec.vocab);
    let mut acc = 0.0;
    for w in &weights {
        acc += w / total;
        cdf.push(acc);
    }
    // Topic structure: the head of the Zipf curve (top 64 ranks) is shared
    // global vocabulary (stopword-like); tail ranks map into a per-topic
    // vocabulary block, so documents of one topic overlap heavily in
    // content terms (like real corpora) while different topics are nearly
    // orthogonal after idf weighting.
    let head = 64usize.min(spec.vocab);
    let block_len = ((spec.vocab - head) / spec.topics.max(1)).max(1);

    // First pass: raw term frequencies per doc.
    let mut docs_tf: Vec<HashMap<u32, u32>> = Vec::with_capacity(spec.n_docs);
    let mut df: HashMap<u32, u32> = HashMap::new();
    for _ in 0..spec.n_docs {
        let topic = rng.below(spec.topics);
        let len = (spec.doc_len / 2).max(1) + rng.below(spec.doc_len + 1);
        let mut tf: HashMap<u32, u32> = HashMap::new();
        for _ in 0..len {
            let u: f64 = rng.f64();
            let rank = match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
                Ok(i) | Err(i) => i.min(spec.vocab - 1),
            };
            // Head terms stay global; tail terms land in the topic block
            // (rank order preserved inside the block, keeping Zipf shape).
            let term = if rank < head {
                rank
            } else {
                head + topic * block_len + (rank - head) % block_len
            };
            *tf.entry(term as u32).or_insert(0) += 1;
        }
        for &t in tf.keys() {
            *df.entry(t).or_insert(0) += 1;
        }
        docs_tf.push(tf);
    }

    // Second pass: log-tf * idf, normalized.
    let n = spec.n_docs as f64;
    docs_tf
        .into_iter()
        .map(|tf| {
            let pairs: Vec<(u32, f32)> = tf
                .into_iter()
                .map(|(t, f)| {
                    let idf = (n / (1.0 + df[&t] as f64)).ln().max(0.0);
                    (t, ((1.0 + f as f64).ln() * idf) as f32)
                })
                .filter(|&(_, w)| w > 0.0)
                .collect();
            SparseVec::new(pairs, spec.vocab)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_sparse_and_normalized() {
        let spec = ZipfSpec { n_docs: 100, vocab: 2_000, doc_len: 60, ..Default::default() };
        let docs = zipf_corpus(&spec);
        assert_eq!(docs.len(), 100);
        for d in &docs {
            assert!(d.nnz() > 0, "empty doc");
            assert!(d.nnz() < 400, "doc not sparse: {}", d.nnz());
            let norm: f64 = d.iter().map(|(_, v)| v as f64 * v as f64).sum();
            assert!((norm - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let spec = ZipfSpec { n_docs: 20, vocab: 500, doc_len: 30, ..Default::default() };
        assert_eq!(zipf_corpus(&spec), zipf_corpus(&spec));
    }

    #[test]
    fn similarities_are_nonnegative_and_in_range() {
        let spec = ZipfSpec { n_docs: 50, vocab: 1_000, doc_len: 40, ..Default::default() };
        let docs = zipf_corpus(&spec);
        for i in 0..docs.len() {
            for j in 0..docs.len() {
                let s = docs[i].dot(&docs[j]);
                assert!((-1e-9..=1.0 + 1e-9).contains(&s), "s = {s}");
            }
        }
    }
}
