//! Typed errors of the serving surface (ADR-005).
//!
//! The coordinator's request path and the wire protocol used to produce
//! stringly `anyhow!` errors; clients could only substring-match messages.
//! [`SimetraError`] names the failure classes instead, `Display`s to the
//! exact wire messages the stringly errors produced (so existing clients
//! and tests keep working), and carries a stable machine-readable
//! [`SimetraError::code`] that the wire `Response::Error` envelope exposes
//! as its `code` field.

use std::fmt;

/// A typed error of the coordinator/protocol public surface.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SimetraError {
    /// A query/insert vector whose dimension does not match the corpus.
    DimMismatch { got: usize, want: usize },
    /// A structurally valid request the server refuses (bad field values,
    /// mutations against a read-only corpus, malformed JSON, ...).
    BadRequest(String),
    /// An `op` the protocol does not know.
    UnknownOp(String),
    /// A per-request kernel override the serving corpus cannot honor.
    KernelUnavailable(String),
    /// Transport/queueing failure (batcher shut down, shard worker died).
    Io(String),
}

impl SimetraError {
    /// Stable machine-readable code, carried in the wire error envelope.
    /// Codes are part of the protocol contract: new variants may be added,
    /// existing codes never change meaning.
    pub fn code(&self) -> &'static str {
        match self {
            SimetraError::DimMismatch { .. } => "dim_mismatch",
            SimetraError::BadRequest(_) => "bad_request",
            SimetraError::UnknownOp(_) => "unknown_op",
            SimetraError::KernelUnavailable(_) => "kernel_unavailable",
            SimetraError::Io(_) => "io",
        }
    }

}

impl fmt::Display for SimetraError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            // Exactly the message the stringly error produced, so clients
            // substring-matching "dimension" keep working.
            SimetraError::DimMismatch { got, want } => write!(
                f,
                "vector dimension {got} does not match corpus dimension {want}"
            ),
            SimetraError::BadRequest(msg) => f.write_str(msg),
            SimetraError::UnknownOp(op) => write!(f, "unknown op '{op}'"),
            SimetraError::KernelUnavailable(msg) => f.write_str(msg),
            SimetraError::Io(msg) => f.write_str(msg),
        }
    }
}

impl std::error::Error for SimetraError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_match_the_wire_messages() {
        let e = SimetraError::DimMismatch { got: 7, want: 128 };
        assert_eq!(
            e.to_string(),
            "vector dimension 7 does not match corpus dimension 128"
        );
        assert_eq!(SimetraError::UnknownOp("explode".into()).to_string(), "unknown op 'explode'");
        assert_eq!(SimetraError::BadRequest("k must be >= 1".into()).to_string(), "k must be >= 1");
    }

    #[test]
    fn codes_are_stable() {
        for (e, code) in [
            (SimetraError::DimMismatch { got: 1, want: 2 }, "dim_mismatch"),
            (SimetraError::BadRequest("x".into()), "bad_request"),
            (SimetraError::UnknownOp("x".into()), "unknown_op"),
            (SimetraError::KernelUnavailable("x".into()), "kernel_unavailable"),
            (SimetraError::Io("x".into()), "io"),
        ] {
            assert_eq!(e.code(), code);
        }
    }
}
