//! Regeneration of every figure in the paper's evaluation section.
//!
//! Each function returns the figure's data as rows and can write it as CSV;
//! `simetra figures` is the CLI front end. Figures are value grids over the
//! input similarities `s1 = sim(x, z)`, `s2 = sim(z, y)`:
//!
//! * Fig. 1: Euclidean (a) vs Arccos (b) bound surfaces on `[-1, 1]^2` and
//!   their difference (c) — max difference 0.5 at (0.5, 0.5).
//! * Fig. 2: all six bound surfaces on the non-negative domain `[0, 1]^2`.
//! * Fig. 3: empirical verification of the bound partial order.
//! * Fig. 4: differences of the simplified bounds to the tight bound.
//! * Fig. 5: `Mult - Arccos` in f64 — numerical noise at ~1e-16.
//! * §4.1 statistic: average Euclidean vs Arccos bound over the grid.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::bounds::{order, BoundKind};

/// A sampled surface `z = f(s1, s2)` over a uniform grid.
pub struct Surface {
    pub name: String,
    pub lo: f64,
    pub hi: f64,
    pub steps: usize,
    /// Row-major `steps x steps`: `values[i * steps + j] = f(lo + i*h, lo + j*h)`.
    pub values: Vec<f64>,
}

impl Surface {
    pub fn sample(name: &str, lo: f64, hi: f64, steps: usize, f: impl Fn(f64, f64) -> f64) -> Self {
        let h = (hi - lo) / (steps - 1) as f64;
        let mut values = Vec::with_capacity(steps * steps);
        for i in 0..steps {
            for j in 0..steps {
                values.push(f(lo + i as f64 * h, lo + j as f64 * h));
            }
        }
        Surface { name: name.to_string(), lo, hi, steps, values }
    }

    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.values[i * self.steps + j]
    }

    pub fn min(&self) -> f64 {
        self.values.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Write `s1,s2,value` CSV.
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?,
        );
        writeln!(f, "s1,s2,{}", self.name)?;
        let h = (self.hi - self.lo) / (self.steps - 1) as f64;
        for i in 0..self.steps {
            for j in 0..self.steps {
                writeln!(
                    f,
                    "{:.6},{:.6},{:.17e}",
                    self.lo + i as f64 * h,
                    self.lo + j as f64 * h,
                    self.at(i, j)
                )?;
            }
        }
        Ok(())
    }
}

/// Default grid resolution (the paper plots are ~512 px wide; 401 keeps the
/// §4.1 statistic at the paper's printed precision).
pub const GRID: usize = 401;

/// Fig. 1: Euclidean and Arccos surfaces on `[-1, 1]^2` plus difference.
pub fn fig1(steps: usize) -> Vec<Surface> {
    let eucl = Surface::sample("euclidean_eq7", -1.0, 1.0, steps, |a, b| {
        BoundKind::Euclidean.lower(a, b)
    });
    let arcc =
        Surface::sample("arccos_eq9", -1.0, 1.0, steps, |a, b| BoundKind::Arccos.lower(a, b));
    // Fig. 1c: difference of the *effective* bounds — any lower bound below
    // the trivial -1 is clamped (a bound below -1 prunes nothing). This is
    // what makes the paper's "max difference 0.5 at (0.5, 0.5)" true even
    // though the raw Euclidean bound dives to -7.
    let diff = Surface {
        name: "arccos_minus_euclidean".into(),
        lo: -1.0,
        hi: 1.0,
        steps,
        values: arcc
            .values
            .iter()
            .zip(&eucl.values)
            .map(|(a, e)| a.max(-1.0) - e.max(-1.0))
            .collect(),
    };
    vec![eucl, arcc, diff]
}

/// Fig. 2: the six Table-1 bounds on the non-negative domain `[0, 1]^2`.
pub fn fig2(steps: usize) -> Vec<Surface> {
    [
        BoundKind::Euclidean,
        BoundKind::Arccos,
        BoundKind::Mult,
        BoundKind::EuclLb,
        BoundKind::MultLb2,
        BoundKind::MultLb1,
    ]
    .iter()
    .map(|&k| Surface::sample(k.name(), 0.0, 1.0, steps, move |a, b| k.lower(a, b)))
    .collect()
}

/// Fig. 3: empirical verification of the partial order; returns
/// `(relation, max violation over the grid)` — all must be <= ~1e-15.
pub fn fig3(steps: usize) -> Vec<(String, f64)> {
    order::verify_order(steps)
}

/// Fig. 4: differences of the three simplified bounds to the tight bound
/// on `[0, 1]^2`.
pub fn fig4(steps: usize) -> Vec<Surface> {
    [BoundKind::EuclLb, BoundKind::MultLb2, BoundKind::MultLb1]
        .iter()
        .map(|&k| {
            Surface::sample(
                &format!("arccos_minus_{}", k.name()),
                0.0,
                1.0,
                steps,
                move |a, b| BoundKind::Arccos.lower(a, b) - k.lower(a, b),
            )
        })
        .collect()
}

/// Fig. 5: `Mult - Arccos` (f64), expected |.| < 5e-15 everywhere.
pub fn fig5(steps: usize) -> Surface {
    Surface::sample("mult_minus_arccos", -1.0, 1.0, steps, |a, b| {
        BoundKind::Mult.lower(a, b) - BoundKind::Arccos.lower(a, b)
    })
}

/// §4.1 statistic: (avg Euclidean, avg Arccos, ratio) over the cells of the
/// `[0, 1]^2` grid where the tight bound is non-negative. Paper values:
/// 0.2447, 0.3121, +27.5%.
pub fn section41_stats(steps: usize) -> (f64, f64, f64) {
    let h = 1.0 / (steps - 1) as f64;
    let (mut se, mut sm, mut count) = (0.0, 0.0, 0usize);
    for i in 0..steps {
        for j in 0..steps {
            let (a, b) = (i as f64 * h, j as f64 * h);
            let m = BoundKind::Mult.lower(a, b);
            if m >= 0.0 {
                se += BoundKind::Euclidean.lower(a, b);
                sm += m;
                count += 1;
            }
        }
    }
    let avg_e = se / count as f64;
    let avg_m = sm / count as f64;
    (avg_e, avg_m, (avg_m - avg_e) / avg_e)
}

/// Write all figures + a summary to `out_dir`.
pub fn write_all(out_dir: &Path, steps: usize) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    for (fig, surfaces) in
        [("fig1", fig1(steps)), ("fig2", fig2(steps)), ("fig4", fig4(steps))]
    {
        for s in surfaces {
            s.write_csv(&out_dir.join(format!("{fig}_{}.csv", s.name)))?;
        }
    }
    fig5(steps).write_csv(&out_dir.join("fig5_mult_minus_arccos.csv"))?;

    let mut f = std::fs::File::create(out_dir.join("summary.txt"))?;
    writeln!(f, "== Fig. 3: partial order (max violation; <= 0 means holds) ==")?;
    for (name, v) in fig3(steps.min(301)) {
        writeln!(f, "{name}: {v:.3e}")?;
    }
    let (e, m, r) = section41_stats(steps);
    writeln!(f, "\n== Section 4.1 average-bound statistic ==")?;
    writeln!(f, "avg Euclidean bound: {e:.4}  (paper: 0.2447)")?;
    writeln!(f, "avg Arccos bound:    {m:.4}  (paper: 0.3121)")?;
    writeln!(f, "ratio:               +{:.1}% (paper: +27.5%)", r * 100.0)?;
    let f5 = fig5(steps.min(301));
    writeln!(f, "\n== Fig. 5 numerical-stability check ==")?;
    writeln!(f, "max |Mult - Arccos| = {:.3e} (expect ~1e-15)",
        f5.values.iter().fold(0.0f64, |acc, &v| acc.max(v.abs())))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_anchors() {
        let surfaces = fig1(401);
        let (eucl, arcc, diff) = (&surfaces[0], &surfaces[1], &surfaces[2]);
        // Euclidean bound goes down to -7 at (-1, -1); Arccos gives +1 there
        // (opposite-opposite implies identical).
        assert!((eucl.at(0, 0) - (-7.0)).abs() < 1e-12);
        assert!((arcc.at(0, 0) - 1.0).abs() < 1e-12);
        // Paper: max difference 0.5 at inputs (0.5, 0.5) — this is the
        // difference of the *effective* (clamped-at--1) bounds over the
        // non-negative domain; in the negative domain the gap reaches 2.
        let i = 300; // s = -1 + 300/200 = 0.5
        assert!((diff.at(i, i) - 0.5).abs() < 1e-12);
        let mid = 200; // s = 0
        let mut nonneg_max = f64::NEG_INFINITY;
        for a in mid..401 {
            for b in mid..401 {
                nonneg_max = nonneg_max.max(diff.at(a, b));
            }
        }
        assert!((nonneg_max - 0.5).abs() < 1e-9, "nonneg max = {nonneg_max}");
        assert!((diff.at(0, 0) - 2.0).abs() < 1e-12);
        // Arccos bound is never below Euclidean.
        assert!(diff.min() >= -1e-12);
    }

    #[test]
    fn fig2_bounds_max_at_one_one() {
        for s in fig2(101) {
            let v = s.at(100, 100);
            assert!((v - 1.0).abs() < 1e-9, "{}: bound at (1,1) = {v}", s.name);
        }
    }

    #[test]
    fn fig3_no_violations() {
        for (name, v) in fig3(151) {
            assert!(v <= 1e-12, "{name}: {v}");
        }
    }

    #[test]
    fn fig4_differences_nonnegative() {
        for s in fig4(101) {
            assert!(s.min() >= -1e-12, "{} dips to {}", s.name, s.min());
        }
    }

    #[test]
    fn fig5_noise_at_f64_limit() {
        let s = fig5(201);
        let max = s.values.iter().fold(0.0f64, |a, &v| a.max(v.abs()));
        assert!(max < 5e-15, "max |diff| = {max}");
    }

    #[test]
    fn section41_matches_paper() {
        let (e, m, r) = section41_stats(401);
        assert!((e - 0.2447).abs() < 2e-3, "avg eucl {e}");
        assert!((m - 0.3121).abs() < 2e-3, "avg arccos {m}");
        assert!((r - 0.275).abs() < 0.01, "ratio {r}");
    }

    #[test]
    fn csv_write_smoke() {
        let dir = std::env::temp_dir().join("simetra_fig_test");
        let _ = std::fs::remove_dir_all(&dir);
        write_all(&dir, 51).unwrap();
        assert!(dir.join("summary.txt").exists());
        assert!(dir.join("fig1_euclidean_eq7.csv").exists());
    }
}
