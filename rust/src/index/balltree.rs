//! Ball tree in the similarity domain.
//!
//! Binary covering tree (Omohundro 1989): each node owns a routing point
//! (an actual corpus item) and the exact similarity interval of the *other*
//! items in its subtree to that point — a "similarity cap" replacing the
//! covering radius. Children are formed by two-seed assignment: pick the
//! two least-similar items as seeds, assign every item to the seed it is
//! more similar to. Pruning: once `sim(q, center)` is known, the subtree
//! can only contain a match if `upper_over(sim(q, center), cover) >= tau`
//! (range) / `> floor` (kNN) — Eq. 13 applied to the similarity interval.
//!
//! Leaf buckets are scored through the corpus's batch kernels when built on
//! a zero-copy [`crate::storage::CorpusView`].

use crate::bounds::{BoundKind, SimInterval};
use crate::query::{BatchContext, Frontier, QueryContext, SearchRequest, SearchResponse};

use super::{sort_desc, Corpus, RangePlan, SimilarityIndex, TopkPlan};

struct Node {
    /// Routing point id; also a member of the subtree.
    center: u32,
    /// Similarity interval of every *other* subtree member to `center`.
    /// `None` when the node holds only its center.
    cover: Option<SimInterval>,
    children: Vec<Node>,
    /// Leaf payload (excluding center).
    bucket: Vec<u32>,
}

/// Similarity-native ball tree.
pub struct BallTree<C: Corpus> {
    corpus: C,
    root: Option<Node>,
    bound: BoundKind,
}

impl<C: Corpus> BallTree<C> {
    pub fn build(corpus: C, bound: BoundKind, leaf_size: usize) -> Self {
        let ids: Vec<u32> = (0..corpus.len() as u32).collect();
        let root = if ids.is_empty() {
            None
        } else {
            Some(Self::build_node(&corpus, ids, leaf_size.max(2)))
        };
        BallTree { corpus, root, bound }
    }

    fn cover_of(corpus: &C, center: u32, member_ids: &[u32]) -> Option<SimInterval> {
        let mut iv: Option<SimInterval> = None;
        for &id in member_ids {
            let s = corpus.sim_ij(center, id);
            match &mut iv {
                Some(iv) => iv.extend(s),
                None => iv = Some(SimInterval::point(s)),
            }
        }
        iv
    }

    /// All member ids below a node (for cover computation during build).
    fn collect_members(node: &Node, out: &mut Vec<u32>) {
        out.extend_from_slice(&node.bucket);
        for c in &node.children {
            out.push(c.center);
            Self::collect_members(c, out);
        }
    }

    fn build_node(corpus: &C, mut ids: Vec<u32>, leaf_size: usize) -> Node {
        let center = ids[0];
        ids.remove(0);

        if ids.len() <= leaf_size {
            let cover = Self::cover_of(corpus, center, &ids);
            return Node { center, cover, children: Vec::new(), bucket: ids };
        }

        // Two-seed split: seed A = least similar to center; seed B = least
        // similar to A (farthest-pair heuristic in angle space).
        let seed_a = *ids
            .iter()
            .min_by(|&&x, &&y| {
                corpus.sim_ij(center, x).partial_cmp(&corpus.sim_ij(center, y)).unwrap()
            })
            .unwrap();
        let seed_b = *ids
            .iter()
            .filter(|&&x| x != seed_a)
            .min_by(|&&x, &&y| {
                corpus.sim_ij(seed_a, x).partial_cmp(&corpus.sim_ij(seed_a, y)).unwrap()
            })
            .unwrap();

        let mut left_ids = vec![seed_a];
        let mut right_ids = vec![seed_b];
        for &id in &ids {
            if id == seed_a || id == seed_b {
                continue;
            }
            let sa = corpus.sim_ij(seed_a, id);
            let sb = corpus.sim_ij(seed_b, id);
            if sa >= sb {
                left_ids.push(id);
            } else {
                right_ids.push(id);
            }
        }

        let children = vec![
            Self::build_node(corpus, left_ids, leaf_size),
            Self::build_node(corpus, right_ids, leaf_size),
        ];
        // Cover over all members (children's centers + everything below).
        let mut members = Vec::new();
        for ch in &children {
            members.push(ch.center);
            Self::collect_members(ch, &mut members);
        }
        let cover = Self::cover_of(corpus, center, &members);
        Node { center, cover, children, bucket: Vec::new() }
    }

    /// Range search; `s` is the already-computed `sim(q, node.center)`.
    fn range_rec(
        &self,
        node: &Node,
        q: &C::Vector,
        s: f64,
        plan: &RangePlan,
        out: &mut Vec<(u32, f64)>,
        ctx: &mut QueryContext,
    ) {
        if ctx.budget_exhausted() {
            ctx.truncated = true;
            return;
        }
        ctx.stats.nodes_visited += 1;
        ctx.trace_visit(node.center as u64);
        ctx.trace_eval(node.center as u64, 1.0, s);
        if s >= plan.tau && ctx.admits(node.center) {
            out.push((node.center, s));
        }
        let Some(cover) = node.cover else { return };
        let ub = plan.bound.upper_over(s, cover);
        if ub < plan.tau {
            ctx.stats.pruned += 1;
            ctx.trace_prune(node.center as u64, ub);
            return; // nothing below can reach tau
        }
        let n =
            self.corpus.scan_ids_range_ctx(q, &node.bucket, plan.tau, out, ctx.kernel_scratch());
        ctx.stats.sim_evals += n;
        for child in &node.children {
            let sc = self.corpus.sim_q(q, child.center);
            ctx.stats.sim_evals += 1;
            self.range_rec(child, q, sc, plan, out, ctx);
        }
    }

    fn topk_into(
        &self,
        q: &C::Vector,
        plan: &TopkPlan,
        ctx: &mut QueryContext,
        out: &mut Vec<(u32, f64)>,
    ) {
        let mut results = plan.lease_heap(ctx);
        // Frontier entries carry the node and its already-computed center
        // similarity; priority is the subtree's upper bound.
        let mut frontier: Frontier<'_, Node> = ctx.lease_frontier();
        if let Some(root) = &self.root {
            let s = self.corpus.sim_q(q, root.center);
            ctx.stats.sim_evals += 1;
            ctx.trace_eval(root.center as u64, 1.0, s);
            if ctx.admits(root.center) {
                results.offer(root.center, s);
            }
            let ub = match root.cover {
                Some(cover) => plan.bound.upper_over(s, cover),
                None => -1.0,
            };
            frontier.push(ub, root, s);
        }
        while let Some((ub, node, _s)) = frontier.pop() {
            if results.len() >= plan.k && ub <= results.floor() {
                break;
            }
            if plan.dead_below_floor(ub) {
                break;
            }
            if node.cover.is_none() {
                continue;
            }
            if ctx.budget_exhausted() {
                ctx.truncated = true;
                break;
            }
            ctx.stats.nodes_visited += 1;
            ctx.trace_visit(node.center as u64);
            let evals =
                self.corpus.scan_ids_topk_ctx(q, &node.bucket, &mut results, ctx.kernel_scratch());
            ctx.stats.sim_evals += evals;
            for child in &node.children {
                let sc = self.corpus.sim_q(q, child.center);
                ctx.stats.sim_evals += 1;
                ctx.note_eval_slack(plan.bound, child.center as u64, ub, sc);
                if ctx.admits(child.center) {
                    results.offer(child.center, sc);
                }
                let child_ub = match child.cover {
                    Some(cover) => plan.bound.upper_over(sc, cover),
                    None => -1.0,
                };
                if !plan.dead_below_floor(child_ub)
                    && (results.len() < plan.k || child_ub > results.floor())
                {
                    frontier.push(child_ub, child, sc);
                } else {
                    ctx.stats.pruned += 1;
                    ctx.trace_prune(child.center as u64, child_ub);
                }
            }
        }
        out.clear();
        results.drain_into(out);
        ctx.release_heap(results);
        ctx.release_frontier(frontier);
    }

    /// Shared-frontier multi-query descent (ADR-006). Centers are
    /// evaluated and offered per live slot when their node is *pushed*
    /// (exactly once per slot, like the single-query expansion), so
    /// frontier entries need no cached center similarity — the auxiliary
    /// float carries the live-slot bitmask instead.
    fn traverse_batch(
        &self,
        queries: &[C::Vector],
        bc: &mut BatchContext,
        ctx: &mut QueryContext,
        resps: &mut [SearchResponse],
    ) {
        let Some(root) = &self.root else { return };
        self.corpus.stage_queries(queries, &mut bc.qb);
        let mut frontier: Frontier<'_, Node> = ctx.lease_frontier();
        {
            let mut mask = 0u64;
            let mut ub_max = f64::NEG_INFINITY;
            let mut m = bc.full_mask();
            while m != 0 {
                let j = m.trailing_zeros() as usize;
                m &= m - 1;
                let s = self.corpus.sim_q(&queries[j], root.center);
                super::batch_offer(bc, resps, j, root.center, s);
                let ub_j = match root.cover {
                    Some(cover) => bc.bound.upper_over(s, cover),
                    None => -1.0,
                };
                if bc.slot_alive(j, ub_j) {
                    mask |= 1 << j;
                    ub_max = ub_max.max(ub_j);
                } else {
                    bc.stats[j].pruned += 1;
                }
            }
            if mask != 0 {
                frontier.push(ub_max, root, f64::from_bits(mask));
            }
        }
        while let Some((ub, node, aux)) = frontier.pop() {
            if !bc.any_alive(ub) {
                break;
            }
            let mask = bc.refine(aux.to_bits(), ub);
            if mask == 0 {
                continue;
            }
            if node.cover.is_none() {
                continue; // center-only node: its center was offered at push
            }
            super::note_visit(bc, mask);
            super::batch_scan_ids(&self.corpus, queries, bc, mask, &node.bucket, resps);
            for child in &node.children {
                let mut child_mask = 0u64;
                let mut child_ub = f64::NEG_INFINITY;
                let mut m = mask;
                while m != 0 {
                    let j = m.trailing_zeros() as usize;
                    m &= m - 1;
                    let sc = self.corpus.sim_q(&queries[j], child.center);
                    super::batch_offer(bc, resps, j, child.center, sc);
                    let ub_j = match child.cover {
                        Some(cover) => bc.bound.upper_over(sc, cover),
                        None => -1.0,
                    };
                    if bc.slot_alive(j, ub_j) {
                        child_mask |= 1 << j;
                        child_ub = child_ub.max(ub_j);
                    } else {
                        bc.stats[j].pruned += 1;
                    }
                }
                if child_mask != 0 {
                    frontier.push(child_ub, child, f64::from_bits(child_mask));
                }
            }
        }
        ctx.release_frontier(frontier);
    }
}

impl<C: Corpus> SimilarityIndex<C::Vector> for BallTree<C> {
    fn len(&self) -> usize {
        self.corpus.len()
    }

    fn search_into(
        &self,
        q: &C::Vector,
        req: &SearchRequest,
        ctx: &mut QueryContext,
        resp: &mut SearchResponse,
    ) {
        super::search_frame(
            req,
            ctx,
            resp,
            self.bound,
            super::ORD_BALL,
            |plan, ctx, out| {
                if let Some(root) = &self.root {
                    let s = self.corpus.sim_q(q, root.center);
                    ctx.stats.sim_evals += 1;
                    self.range_rec(root, q, s, plan, out, ctx);
                }
                sort_desc(out);
            },
            |plan, ctx, out| self.topk_into(q, plan, ctx, out),
        );
    }

    fn search_batch_into(
        &self,
        queries: &[C::Vector],
        reqs: &[SearchRequest],
        ctx: &mut QueryContext,
        resps: &mut Vec<SearchResponse>,
    ) {
        super::run_batch(
            queries,
            reqs,
            ctx,
            resps,
            self.bound,
            super::ORD_BALL,
            &mut |q, req, ctx, resp| self.search_into(q, req, ctx, resp),
            &mut |qs, bc, ctx, chunk| self.traverse_batch(qs, bc, ctx, chunk),
        );
    }

    fn name(&self) -> &'static str {
        "ball-tree"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::uniform_sphere;
    use crate::index::{LinearScan, QueryStats};

    #[test]
    fn matches_linear_scan() {
        let pts = uniform_sphere(400, 8, 31);
        let tree = BallTree::build(pts.clone(), BoundKind::Mult, 8);
        let lin = LinearScan::build(pts.clone());
        let mut s1 = QueryStats::default();
        let mut s2 = QueryStats::default();
        for qi in [0usize, 37, 200, 399] {
            for tau in [0.9, 0.4, 0.0] {
                assert_eq!(
                    tree.range(&pts[qi], tau, &mut s1),
                    lin.range(&pts[qi], tau, &mut s2)
                );
            }
            let a = tree.knn(&pts[qi], 7, &mut s1);
            let b = lin.knn(&pts[qi], 7, &mut s2);
            for ((_, x), (_, y)) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn matches_linear_with_loose_bound() {
        let pts = uniform_sphere(200, 6, 33);
        let tree = BallTree::build(pts.clone(), BoundKind::MultLb1, 4);
        let lin = LinearScan::build(pts.clone());
        let mut s1 = QueryStats::default();
        let mut s2 = QueryStats::default();
        for qi in [3usize, 77, 150] {
            assert_eq!(
                tree.range(&pts[qi], 0.3, &mut s1),
                lin.range(&pts[qi], 0.3, &mut s2)
            );
        }
    }

    #[test]
    fn covers_are_valid() {
        let pts = uniform_sphere(100, 6, 32);
        let tree = BallTree::build(pts.clone(), BoundKind::Mult, 4);
        let root = tree.root.as_ref().unwrap();
        let cover = root.cover.unwrap();
        let c = &pts[root.center as usize];
        for (i, p) in pts.iter().enumerate() {
            if i as u32 != root.center {
                let s = crate::metrics::SimVector::sim(c, p);
                assert!(s >= cover.lo - 1e-9 && s <= cover.hi + 1e-9);
            }
        }
    }

    #[test]
    fn prunes_on_clustered_data() {
        let (pts, _) = crate::data::vmf_mixture(&crate::data::VmfSpec {
            n: 2000,
            dim: 16,
            clusters: 20,
            kappa: 80.0,
            seed: 4,
        });
        let tree = BallTree::build(pts.clone(), BoundKind::Mult, 16);
        let mut st = QueryStats::default();
        tree.range(&pts[0], 0.9, &mut st);
        assert!(
            st.sim_evals < 2000,
            "no pruning happened: {} evals",
            st.sim_evals
        );
    }
}
