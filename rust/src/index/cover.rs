//! Cover tree (Beygelzimer/Kakade/Langford 2006, simplified per
//! Izbicki/Shelton 2015) on the angular metric, expressed entirely in the
//! similarity domain.
//!
//! Cover-tree invariants are angle comparisons `d_arccos(x, y) <= r_level`;
//! since `arccos` is monotone these are evaluated as `sim(x, y) >=
//! cos(r_level)` against a precomputed per-level table — the only
//! trigonometry in the structure, amortized over the whole tree. Query-time
//! pruning uses the tracked similarity interval of each node's descendants
//! together with Eq. 13, exactly like the other trees.

use crate::bounds::{BoundKind, SimInterval};
use crate::query::{BatchContext, Frontier, QueryContext, SearchRequest, SearchResponse};

use super::{sort_desc, Corpus, RangePlan, SimilarityIndex, TopkPlan};

/// Geometric base of the level radii (2.0 in the original paper; 1.3 gives
/// flatter trees on the sphere where all angles are <= pi).
const BASE: f64 = 1.3;
/// Top level: BASE^MAX_LEVEL >= pi covers the whole sphere.
const MAX_LEVEL: i32 = 5; // 1.3^5 = 3.71 > pi
const MIN_LEVEL: i32 = -60;

#[inline]
fn covdist_cos(level: i32) -> f64 {
    // cos of the covering radius at `level`; clamped to angles in [0, pi].
    let r = BASE.powi(level);
    if r >= std::f64::consts::PI {
        -1.0
    } else {
        r.cos()
    }
}

struct Node {
    id: u32,
    level: i32,
    children: Vec<Node>,
    /// Similarity interval of all *descendants* (not incl. self) to `id`;
    /// `None` for childless nodes.
    cover: Option<SimInterval>,
}

impl Node {
    fn extend_cover(&mut self, s: f64) {
        match &mut self.cover {
            Some(c) => c.extend(s),
            None => self.cover = Some(SimInterval::point(s)),
        }
    }
}

/// Similarity-native cover tree.
pub struct CoverTree<C: Corpus> {
    corpus: C,
    root: Option<Node>,
    bound: BoundKind,
}

impl<C: Corpus> CoverTree<C> {
    pub fn build(corpus: C, bound: BoundKind) -> Self {
        let mut tree = CoverTree { corpus, root: None, bound };
        for id in 0..tree.corpus.len() as u32 {
            tree.insert(id);
        }
        tree
    }

    fn insert(&mut self, x: u32) {
        let Some(mut root) = self.root.take() else {
            self.root = Some(Node { id: x, level: MAX_LEVEL, children: Vec::new(), cover: None });
            return;
        };
        let s_root = self.corpus.sim_ij(root.id, x);
        if s_root < covdist_cos(root.level) {
            // x does not fit under the root's cover: raise the root level
            // until it does (top level covers the sphere, so this ends).
            while s_root < covdist_cos(root.level) && root.level < MAX_LEVEL {
                root.level += 1;
            }
        }
        Self::insert_rec(&self.corpus, &mut root, x, s_root);
        self.root = Some(root);
    }

    /// Insert x under p (which covers it); `s_p` = sim(p, x), already known.
    fn insert_rec(corpus: &C, p: &mut Node, x: u32, s_p: f64) {
        p.extend_cover(s_p);
        // Try to hand off to a child that covers x.
        // (First compute similarities; borrow rules: index the chosen child.)
        let mut chosen: Option<(usize, f64)> = None;
        for (ci, c) in p.children.iter().enumerate() {
            let s_c = corpus.sim_ij(c.id, x);
            if s_c >= covdist_cos(c.level) {
                chosen = Some((ci, s_c));
                break;
            }
        }
        match chosen {
            Some((ci, s_c)) => Self::insert_rec(corpus, &mut p.children[ci], x, s_c),
            None => {
                let level = (p.level - 1).max(MIN_LEVEL);
                p.children.push(Node { id: x, level, children: Vec::new(), cover: None });
            }
        }
    }

    /// Propagate cover extension along an ancestor path — handled inline in
    /// `insert_rec` via `extend_cover`, but ancestors above the insertion
    /// path also need the new member's similarity. The simplified insert
    /// above extends covers only along the exact descent path, which is
    /// precisely the set of ancestors of the new node, so all covers stay
    /// valid by construction.
    // Doc anchor only: exists to carry the invariant note above in rustdoc.
    #[allow(dead_code)]
    fn cover_invariant_doc() {}

    fn range_rec(
        &self,
        node: &Node,
        q: &C::Vector,
        s: f64,
        plan: &RangePlan,
        out: &mut Vec<(u32, f64)>,
        ctx: &mut QueryContext,
    ) {
        if ctx.budget_exhausted() {
            ctx.truncated = true;
            return;
        }
        ctx.stats.nodes_visited += 1;
        ctx.trace_visit(node.id as u64);
        ctx.trace_eval(node.id as u64, 1.0, s);
        if s >= plan.tau && ctx.admits(node.id) {
            out.push((node.id, s));
        }
        let Some(cover) = node.cover else { return };
        let ub = plan.bound.upper_over(s, cover);
        if ub < plan.tau {
            ctx.stats.pruned += 1;
            ctx.trace_prune(node.id as u64, ub);
            return;
        }
        for child in &node.children {
            let sc = self.corpus.sim_q(q, child.id);
            ctx.stats.sim_evals += 1;
            self.range_rec(child, q, sc, plan, out, ctx);
        }
    }

    fn topk_into(
        &self,
        q: &C::Vector,
        plan: &TopkPlan,
        ctx: &mut QueryContext,
        out: &mut Vec<(u32, f64)>,
    ) {
        let mut results = plan.lease_heap(ctx);
        let mut frontier: Frontier<'_, Node> = ctx.lease_frontier();
        if let Some(root) = &self.root {
            let s = self.corpus.sim_q(q, root.id);
            ctx.stats.sim_evals += 1;
            ctx.trace_eval(root.id as u64, 1.0, s);
            if ctx.admits(root.id) {
                results.offer(root.id, s);
            }
            let ub = match root.cover {
                Some(cover) => plan.bound.upper_over(s, cover),
                None => -1.0,
            };
            frontier.push(ub, root, s);
        }
        while let Some((ub, node, _s)) = frontier.pop() {
            if results.len() >= plan.k && ub <= results.floor() {
                break;
            }
            if plan.dead_below_floor(ub) {
                break;
            }
            if ctx.budget_exhausted() {
                ctx.truncated = true;
                break;
            }
            ctx.stats.nodes_visited += 1;
            ctx.trace_visit(node.id as u64);
            for child in &node.children {
                let sc = self.corpus.sim_q(q, child.id);
                ctx.stats.sim_evals += 1;
                ctx.note_eval_slack(plan.bound, child.id as u64, ub, sc);
                if ctx.admits(child.id) {
                    results.offer(child.id, sc);
                }
                let child_ub = match child.cover {
                    Some(cover) => plan.bound.upper_over(sc, cover),
                    None => -1.0,
                };
                if !plan.dead_below_floor(child_ub)
                    && (results.len() < plan.k || child_ub > results.floor())
                {
                    frontier.push(child_ub, child, sc);
                } else {
                    ctx.stats.pruned += 1;
                    ctx.trace_prune(child.id as u64, child_ub);
                }
            }
        }
        out.clear();
        results.drain_into(out);
        ctx.release_heap(results);
        ctx.release_frontier(frontier);
    }

    /// ADR-006 multi-query traversal: one shared best-first frontier with
    /// a live-slot mask in the aux word. Every node id is offered to each
    /// live slot exactly once — at push time, like the single-query path —
    /// so the heaps never see duplicates.
    fn traverse_batch(
        &self,
        queries: &[C::Vector],
        bc: &mut BatchContext,
        ctx: &mut QueryContext,
        resps: &mut [SearchResponse],
    ) {
        let Some(root) = &self.root else { return };
        self.corpus.stage_queries(queries, &mut bc.qb);
        let mut frontier: Frontier<'_, Node> = ctx.lease_frontier();
        let full = bc.full_mask();
        {
            let mut mask = 0u64;
            let mut ub_max = f64::NEG_INFINITY;
            let mut m = full;
            while m != 0 {
                let j = m.trailing_zeros() as usize;
                m &= m - 1;
                let s = self.corpus.sim_q(&queries[j], root.id);
                super::batch_offer(bc, resps, j, root.id, s);
                let ub = match root.cover {
                    Some(cover) => bc.bound.upper_over(s, cover),
                    None => -1.0,
                };
                if bc.slot_alive(j, ub) {
                    mask |= 1 << j;
                    ub_max = ub_max.max(ub);
                } else {
                    bc.stats[j].pruned += 1;
                }
            }
            if mask != 0 {
                frontier.push(ub_max, root, f64::from_bits(mask));
            }
        }
        while let Some((ub, node, aux)) = frontier.pop() {
            if !bc.any_alive(ub) {
                break; // best-first: no remaining node serves any slot
            }
            let mask = bc.refine(aux.to_bits(), ub);
            if mask == 0 {
                continue; // every interested slot retired since the push
            }
            super::note_visit(bc, mask);
            for child in &node.children {
                let mut child_mask = 0u64;
                let mut child_ub = f64::NEG_INFINITY;
                let mut m = mask;
                while m != 0 {
                    let j = m.trailing_zeros() as usize;
                    m &= m - 1;
                    let sc = self.corpus.sim_q(&queries[j], child.id);
                    super::batch_offer(bc, resps, j, child.id, sc);
                    let ub_j = match child.cover {
                        Some(cover) => bc.bound.upper_over(sc, cover),
                        None => -1.0,
                    };
                    if bc.slot_alive(j, ub_j) {
                        child_mask |= 1 << j;
                        child_ub = child_ub.max(ub_j);
                    } else {
                        bc.stats[j].pruned += 1;
                    }
                }
                if child_mask != 0 {
                    frontier.push(child_ub, child, f64::from_bits(child_mask));
                }
            }
        }
        ctx.release_frontier(frontier);
    }
}

impl<C: Corpus> SimilarityIndex<C::Vector> for CoverTree<C> {
    fn len(&self) -> usize {
        self.corpus.len()
    }

    fn search_into(
        &self,
        q: &C::Vector,
        req: &SearchRequest,
        ctx: &mut QueryContext,
        resp: &mut SearchResponse,
    ) {
        super::search_frame(
            req,
            ctx,
            resp,
            self.bound,
            super::ORD_COVER,
            |plan, ctx, out| {
                if let Some(root) = &self.root {
                    let s = self.corpus.sim_q(q, root.id);
                    ctx.stats.sim_evals += 1;
                    self.range_rec(root, q, s, plan, out, ctx);
                }
                sort_desc(out);
            },
            |plan, ctx, out| self.topk_into(q, plan, ctx, out),
        );
    }

    fn search_batch_into(
        &self,
        queries: &[C::Vector],
        reqs: &[SearchRequest],
        ctx: &mut QueryContext,
        resps: &mut Vec<SearchResponse>,
    ) {
        super::run_batch(
            queries,
            reqs,
            ctx,
            resps,
            self.bound,
            super::ORD_COVER,
            &mut |q, req, ctx, resp| self.search_into(q, req, ctx, resp),
            &mut |qs, bc, ctx, chunk| self.traverse_batch(qs, bc, ctx, chunk),
        );
    }

    fn name(&self) -> &'static str {
        "cover-tree"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{uniform_sphere, vmf_mixture, VmfSpec};
    use crate::index::{LinearScan, QueryStats};
    use crate::metrics::SimVector;

    #[test]
    fn matches_linear_scan() {
        let pts = uniform_sphere(400, 8, 71);
        let tree = CoverTree::build(pts.clone(), BoundKind::Mult);
        let lin = LinearScan::build(pts.clone());
        let mut s1 = QueryStats::default();
        let mut s2 = QueryStats::default();
        for qi in [0usize, 137, 399] {
            for tau in [0.85, 0.4] {
                assert_eq!(
                    tree.range(&pts[qi], tau, &mut s1),
                    lin.range(&pts[qi], tau, &mut s2),
                    "tau={tau} qi={qi}"
                );
            }
            let a = tree.knn(&pts[qi], 6, &mut s1);
            let b = lin.knn(&pts[qi], 6, &mut s2);
            for ((_, x), (_, y)) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn covers_contain_all_descendants() {
        fn check(items: &[crate::metrics::DenseVec], node: &Node) {
            let mut desc = Vec::new();
            fn collect(n: &Node, out: &mut Vec<u32>) {
                for c in &n.children {
                    out.push(c.id);
                    collect(c, out);
                }
            }
            collect(node, &mut desc);
            if let Some(cover) = node.cover {
                for d in desc {
                    let s = items[node.id as usize].sim(&items[d as usize]);
                    assert!(s >= cover.lo - 1e-9 && s <= cover.hi + 1e-9);
                }
            } else {
                assert!(node.children.is_empty());
            }
            for c in &node.children {
                check(items, c);
            }
        }
        let pts = uniform_sphere(150, 6, 72);
        let tree = CoverTree::build(pts.clone(), BoundKind::Mult);
        check(&pts, tree.root.as_ref().unwrap());
    }

    #[test]
    fn prunes_on_clustered_data() {
        let (pts, _) =
            vmf_mixture(&VmfSpec { n: 3000, dim: 16, clusters: 30, kappa: 100.0, seed: 10 });
        let tree = CoverTree::build(pts.clone(), BoundKind::Mult);
        let mut st = QueryStats::default();
        tree.range(&pts[7], 0.9, &mut st);
        assert!(st.sim_evals < 3000, "{}", st.sim_evals);
    }

    #[test]
    fn duplicate_points_are_handled() {
        let p = crate::metrics::DenseVec::new(vec![1.0, 0.0, 0.0]);
        let pts = vec![p.clone(); 20];
        let tree = CoverTree::build(pts.clone(), BoundKind::Mult);
        let mut st = QueryStats::default();
        assert_eq!(tree.range(&p, 0.99, &mut st).len(), 20);
        assert_eq!(tree.knn(&p, 5, &mut st).len(), 5);
    }
}
