//! GNAT — Geometric Near-neighbor Access Tree (Brin 1995) in the
//! similarity domain.
//!
//! Each node holds `m` split points; every other item joins the region of
//! its most similar split point. The node stores the full `m x m` table of
//! similarity intervals `range[i][j]` = interval of `sim(split_i, y)` over
//! all `y` in region `j`. A query computes the `m` split similarities and
//! discards region `j` whenever *any* split point `i` certifies
//! `upper_over(sim(q, split_i), range[i][j]) < tau` — the multi-pivot
//! generalization of the VP-tree test.
//!
//! Split-point similarities and leaf buckets are scored through the
//! corpus's batch kernels (blocked, zero-copy when built on a
//! [`crate::storage::CorpusView`]).

use crate::bounds::{BoundKind, SimInterval};
use crate::query::{BatchContext, QueryContext, SearchRequest, SearchResponse};

use super::{sort_desc, Corpus, KnnHeap, RangePlan, SimilarityIndex, TopkPlan};

struct Node {
    splits: Vec<u32>,
    /// `ranges[i * regions + j]`: interval of sim(splits[i], y) for y in
    /// region j (including region j's split point).
    ranges: Vec<SimInterval>,
    children: Vec<Node>,
    /// Leaf payload.
    bucket: Vec<u32>,
}

/// Similarity-native GNAT.
pub struct Gnat<C: Corpus> {
    corpus: C,
    root: Option<Node>,
    bound: BoundKind,
    fanout: usize,
}

impl<C: Corpus> Gnat<C> {
    pub fn build(corpus: C, bound: BoundKind, fanout: usize) -> Self {
        let fanout = fanout.max(2);
        let ids: Vec<u32> = (0..corpus.len() as u32).collect();
        let root = if ids.is_empty() {
            None
        } else {
            Some(Self::build_node(&corpus, ids, fanout))
        };
        Gnat { corpus, root, bound, fanout }
    }

    pub fn fanout(&self) -> usize {
        self.fanout
    }

    fn build_node(corpus: &C, ids: Vec<u32>, fanout: usize) -> Node {
        if ids.len() <= fanout + 1 {
            return Node {
                splits: Vec::new(),
                ranges: Vec::new(),
                children: Vec::new(),
                bucket: ids,
            };
        }

        // Farthest-first split points.
        let mut splits: Vec<u32> = vec![ids[0]];
        let mut max_sim: Vec<f64> = ids.iter().map(|&i| corpus.sim_ij(ids[0], i)).collect();
        while splits.len() < fanout {
            let (pos, _) = max_sim
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            let s = ids[pos];
            if splits.contains(&s) {
                break;
            }
            splits.push(s);
            for (j, &i) in ids.iter().enumerate() {
                max_sim[j] = max_sim[j].max(corpus.sim_ij(s, i));
            }
        }
        if splits.len() < 2 {
            return Node {
                splits: Vec::new(),
                ranges: Vec::new(),
                children: Vec::new(),
                bucket: ids,
            };
        }

        // Assign to most similar split point.
        let m = splits.len();
        let mut regions: Vec<Vec<u32>> = vec![Vec::new(); m];
        for &i in &ids {
            if splits.contains(&i) {
                continue;
            }
            let (g, _) = splits
                .iter()
                .enumerate()
                .map(|(g, &sp)| (g, corpus.sim_ij(sp, i)))
                .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap();
            regions[g].push(i);
        }

        // Interval table over (split, region) incl. the region's own split.
        let mut ranges = vec![SimInterval::point(0.0); m * m];
        for (i, &sp) in splits.iter().enumerate() {
            for (j, region) in regions.iter().enumerate() {
                let mut iv = SimInterval::point(corpus.sim_ij(sp, splits[j]));
                for &y in region {
                    iv.extend(corpus.sim_ij(sp, y));
                }
                ranges[i * m + j] = iv;
            }
        }

        let children: Vec<Node> = regions
            .into_iter()
            .enumerate()
            .map(|(j, mut region)| {
                region.push(splits[j]);
                Self::build_node(corpus, region, fanout)
            })
            .collect();

        Node { splits, ranges, children, bucket: Vec::new() }
    }

    fn range_rec(
        &self,
        node: &Node,
        q: &C::Vector,
        plan: &RangePlan,
        out: &mut Vec<(u32, f64)>,
        ctx: &mut QueryContext,
    ) {
        if ctx.budget_exhausted() {
            ctx.truncated = true;
            return;
        }
        ctx.stats.nodes_visited += 1;
        ctx.trace_visit(node.splits.first().or(node.bucket.first()).map_or(0, |&s| s as u64));
        let n =
            self.corpus.scan_ids_range_ctx(q, &node.bucket, plan.tau, out, ctx.kernel_scratch());
        ctx.stats.sim_evals += n;
        if node.splits.is_empty() {
            return;
        }
        let m = node.splits.len();
        // One pooled buffer per recursion level: each level leases its own
        // and releases it on exit, so the pool's steady state holds at most
        // tree-depth buffers.
        let mut split_sims = ctx.lease_sims();
        self.corpus.sims(q, &node.splits, &mut split_sims);
        ctx.stats.sim_evals += m as u64;
        // NOTE: split points live in their own region's subtree; regions
        // are pruned collectively below, and surviving subtrees report them.
        for (j, child) in node.children.iter().enumerate() {
            let mut kill = None;
            for i in 0..m {
                let ub = plan.bound.upper_over(split_sims[i], node.ranges[i * m + j]);
                if ub < plan.tau {
                    kill = Some(ub);
                    break;
                }
            }
            match kill {
                None => self.range_rec(child, q, plan, out, ctx),
                Some(ub) => {
                    ctx.stats.pruned += 1;
                    ctx.trace_prune(node.splits[j] as u64, ub);
                }
            }
        }
        ctx.release_sims(split_sims);
    }

    fn knn_rec(
        &self,
        node: &Node,
        q: &C::Vector,
        results: &mut KnnHeap,
        plan: &TopkPlan,
        ctx: &mut QueryContext,
    ) {
        if ctx.budget_exhausted() {
            ctx.truncated = true;
            return;
        }
        ctx.stats.nodes_visited += 1;
        ctx.trace_visit(node.splits.first().or(node.bucket.first()).map_or(0, |&s| s as u64));
        let n = self.corpus.scan_ids_topk_ctx(q, &node.bucket, results, ctx.kernel_scratch());
        ctx.stats.sim_evals += n;
        if node.splits.is_empty() {
            return;
        }
        let m = node.splits.len();
        let mut split_sims = ctx.lease_sims();
        self.corpus.sims(q, &node.splits, &mut split_sims);
        ctx.stats.sim_evals += m as u64;
        // Visit regions in order of their best upper bound so the floor
        // rises quickly; skip regions certified below the floor (or below
        // the KnnWithin similarity floor — both bounds prune this one
        // pass). The (ub desc, region asc) comparator is total, so the
        // allocation-free unstable sort is deterministic.
        let mut order = ctx.lease_pairs();
        order.extend((0..node.children.len()).map(|j| {
            let ub = (0..m)
                .map(|i| plan.bound.upper_over(split_sims[i], node.ranges[i * m + j]))
                .fold(f64::INFINITY, f64::min);
            (j as u32, ub)
        }));
        order.sort_unstable_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        for &(j, ub) in order.iter() {
            let sj = j as usize;
            ctx.note_eval_slack(plan.bound, node.splits[sj] as u64, ub, split_sims[sj]);
            if plan.dead_below_floor(ub) || (results.len() >= plan.k && ub <= results.floor()) {
                ctx.stats.pruned += 1;
                ctx.trace_prune(node.splits[sj] as u64, ub);
                continue;
            }
            self.knn_rec(&node.children[sj], q, results, plan, ctx);
        }
        ctx.release_pairs(order);
        ctx.release_sims(split_sims);
    }

    /// Multi-query recursive descent (ADR-006): one walk serves every
    /// live slot. A region is entered while *any* slot's multi-pivot
    /// bound admits it; regions are visited in order of their best bound
    /// over the batch so the heaps tighten early, and each slot's
    /// admission is re-checked against its current floor right before the
    /// recursion.
    fn batch_rec(
        &self,
        node: &Node,
        queries: &[C::Vector],
        mask: u64,
        bc: &mut BatchContext,
        ctx: &mut QueryContext,
        resps: &mut [SearchResponse],
    ) {
        super::note_visit(bc, mask);
        super::batch_scan_ids(&self.corpus, queries, bc, mask, &node.bucket, resps);
        if node.splits.is_empty() {
            return;
        }
        let m = node.splits.len();
        let nslots = bc.len();
        // Slot-major per-slot split similarities (slot j at [j*m, j*m+m)).
        let mut split_sims = ctx.lease_sims();
        split_sims.resize(nslots * m, 0.0);
        let mut mm = mask;
        while mm != 0 {
            let j = mm.trailing_zeros() as usize;
            mm &= mm - 1;
            for (i, &sp) in node.splits.iter().enumerate() {
                split_sims[j * m + i] = self.corpus.sim_q(&queries[j], sp);
            }
            bc.stats[j].sim_evals += m as u64;
        }
        // Child-major per-(region, slot) certified bounds.
        let mut ubs = ctx.lease_sims();
        ubs.resize(node.children.len() * nslots, f64::NEG_INFINITY);
        let mut order = ctx.lease_pairs();
        for c in 0..node.children.len() {
            let mut best = f64::NEG_INFINITY;
            let mut mm = mask;
            while mm != 0 {
                let j = mm.trailing_zeros() as usize;
                mm &= mm - 1;
                let ub = (0..m)
                    .map(|i| {
                        bc.bound.upper_over(split_sims[j * m + i], node.ranges[i * m + c])
                    })
                    .fold(f64::INFINITY, f64::min);
                ubs[c * nslots + j] = ub;
                best = best.max(ub);
            }
            order.push((c as u32, best));
        }
        order.sort_unstable_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        for &(c, _) in order.iter() {
            let c = c as usize;
            let mut child_mask = 0u64;
            let mut mm = mask;
            while mm != 0 {
                let j = mm.trailing_zeros() as usize;
                mm &= mm - 1;
                if bc.slot_alive(j, ubs[c * nslots + j]) {
                    child_mask |= 1 << j;
                } else {
                    bc.stats[j].pruned += 1;
                }
            }
            if child_mask != 0 {
                self.batch_rec(&node.children[c], queries, child_mask, bc, ctx, resps);
            }
        }
        ctx.release_pairs(order);
        ctx.release_sims(ubs);
        ctx.release_sims(split_sims);
    }

    fn traverse_batch(
        &self,
        queries: &[C::Vector],
        bc: &mut BatchContext,
        ctx: &mut QueryContext,
        resps: &mut [SearchResponse],
    ) {
        let Some(root) = &self.root else { return };
        self.corpus.stage_queries(queries, &mut bc.qb);
        self.batch_rec(root, queries, bc.full_mask(), bc, ctx, resps);
    }
}

impl<C: Corpus> SimilarityIndex<C::Vector> for Gnat<C> {
    fn len(&self) -> usize {
        self.corpus.len()
    }

    fn search_into(
        &self,
        q: &C::Vector,
        req: &SearchRequest,
        ctx: &mut QueryContext,
        resp: &mut SearchResponse,
    ) {
        super::search_frame(
            req,
            ctx,
            resp,
            self.bound,
            super::ORD_GNAT,
            |plan, ctx, out| {
                if let Some(root) = &self.root {
                    self.range_rec(root, q, plan, out, ctx);
                }
                sort_desc(out);
            },
            |plan, ctx, out| {
                let mut results = plan.lease_heap(ctx);
                if let Some(root) = &self.root {
                    self.knn_rec(root, q, &mut results, plan, ctx);
                }
                out.clear();
                results.drain_into(out);
                ctx.release_heap(results);
            },
        );
    }

    fn search_batch_into(
        &self,
        queries: &[C::Vector],
        reqs: &[SearchRequest],
        ctx: &mut QueryContext,
        resps: &mut Vec<SearchResponse>,
    ) {
        super::run_batch(
            queries,
            reqs,
            ctx,
            resps,
            self.bound,
            super::ORD_GNAT,
            &mut |q, req, ctx, resp| self.search_into(q, req, ctx, resp),
            &mut |qs, bc, ctx, chunk| self.traverse_batch(qs, bc, ctx, chunk),
        );
    }

    fn name(&self) -> &'static str {
        "gnat"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{uniform_sphere, vmf_mixture, VmfSpec};
    use crate::index::{LinearScan, QueryStats};

    #[test]
    fn matches_linear_scan() {
        let pts = uniform_sphere(400, 8, 61);
        let tree = Gnat::build(pts.clone(), BoundKind::Mult, 6);
        let lin = LinearScan::build(pts.clone());
        let mut s1 = QueryStats::default();
        let mut s2 = QueryStats::default();
        for qi in [0usize, 200, 399] {
            for tau in [0.8, 0.3] {
                assert_eq!(
                    tree.range(&pts[qi], tau, &mut s1),
                    lin.range(&pts[qi], tau, &mut s2),
                    "tau={tau}"
                );
            }
            let a = tree.knn(&pts[qi], 8, &mut s1);
            let b = lin.knn(&pts[qi], 8, &mut s2);
            for ((_, x), (_, y)) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn prunes_on_clustered_data() {
        let (pts, _) =
            vmf_mixture(&VmfSpec { n: 3000, dim: 16, clusters: 30, kappa: 100.0, seed: 9 });
        let tree = Gnat::build(pts.clone(), BoundKind::Mult, 8);
        let mut st = QueryStats::default();
        tree.range(&pts[100], 0.9, &mut st);
        assert!(st.sim_evals < 3000, "{}", st.sim_evals);
        assert!(st.pruned > 0);
    }

    #[test]
    fn all_items_reachable() {
        // Every item must appear in exactly one leaf/region path: a full
        // range query at tau = -1 returns everything exactly once.
        let pts = uniform_sphere(200, 4, 62);
        let tree = Gnat::build(pts.clone(), BoundKind::Mult, 5);
        let mut st = QueryStats::default();
        let hits = tree.range(&pts[0], -1.0, &mut st);
        assert_eq!(hits.len(), 200);
        let mut ids: Vec<u32> = hits.iter().map(|&(i, _)| i).collect();
        ids.sort(); // lint: stable-sort — test-only dedup ordering
        ids.dedup();
        assert_eq!(ids.len(), 200);
    }
}
