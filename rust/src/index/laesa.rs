//! LAESA (Micó/Oncina/Vidal 1994) in the similarity domain.
//!
//! Linear preprocessing: a table of exact similarities from `P` pivots to
//! every corpus item. At query time the `P` query-pivot similarities are
//! computed once; each candidate then gets a certified interval on
//! `sim(q, c)` by intersecting the per-pivot intervals (Eqs. 10/13) — only
//! candidates whose upper bound clears the threshold are scored exactly.
//!
//! This is also the batch-friendly index: the interval table for a whole
//! query batch is exactly the `pivot_filter` PJRT artifact (see
//! `runtime`), so the coordinator can run the filtering phase on the
//! XLA side. Table construction streams each pivot row through the
//! corpus's batch kernel ([`Corpus::sims_of_item`]).

use crate::bounds::{BoundKind, PivotPairs, SimInterval};
use crate::query::{BatchContext, QueryContext, SearchRequest, SearchResponse};
use crate::storage::KernelScratch;

use super::{sort_desc, Corpus, KnnHeap, QueryStats, RangePlan, SimilarityIndex, TopkPlan};

/// Candidates per exact-evaluation chunk on the top-k path: small enough
/// that the rising floor is re-checked often, large enough that the
/// blocked kernels (and the i8 pre-filter, where armed) amortize.
const CAND_CHUNK: usize = 32;

/// Pivot-table index with triangle-inequality candidate filtering.
pub struct Laesa<C: Corpus> {
    corpus: C,
    /// Pivot item ids.
    pivots: Vec<u32>,
    /// The pivot ids again, sorted — allocation-free membership checks on
    /// the query path (a per-query `HashSet` would defeat ADR-004).
    pivots_sorted: Vec<u32>,
    /// `table[p * n + i]` = sim(pivots[p], items[i]).
    table: Vec<f64>,
    /// Pivot-pair partners for the Ptolemaic refinement (ADR-009). Built
    /// from the table itself — no extra similarity evaluations.
    pairs: PivotPairs,
    bound: BoundKind,
}

impl<C: Corpus> Laesa<C> {
    /// Build with `n_pivots` pivots chosen by farthest-first traversal in
    /// angle space (maximize the minimum angle to previous pivots), the
    /// standard "extreme pivots" heuristic.
    pub fn build(corpus: C, bound: BoundKind, n_pivots: usize) -> Self {
        let n = corpus.len();
        let p = n_pivots.min(n).max(if n == 0 { 0 } else { 1 });
        let mut pivots: Vec<u32> = Vec::with_capacity(p);
        let mut table: Vec<f64> = Vec::with_capacity(p * n);
        if n > 0 {
            // min over chosen pivots of |angle| ~ max over pivots of sim;
            // track per-item max similarity to any chosen pivot.
            let mut max_sim = vec![f64::NEG_INFINITY; n];
            let mut next = 0u32; // first pivot: item 0
            let mut row: Vec<f64> = Vec::new();
            for _ in 0..p {
                pivots.push(next);
                corpus.sims_of_item(next, &mut row);
                for (m, &s) in max_sim.iter_mut().zip(&row) {
                    *m = m.max(s);
                }
                table.extend_from_slice(&row);
                // Next pivot: the item least similar to all chosen pivots.
                next = max_sim
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i as u32)
                    .unwrap();
            }
        }
        let mut pivots_sorted = pivots.clone();
        pivots_sorted.sort_unstable();
        // Pivot-pivot similarities are already in the table (rows span the
        // whole corpus, pivots included), so pairing costs no extra evals.
        let pairs = PivotPairs::build(pivots.len(), |a, b| table[a * n + pivots[b] as usize]);
        Laesa { corpus, pivots, pivots_sorted, table, pairs, bound }
    }

    pub fn n_pivots(&self) -> usize {
        self.pivots.len()
    }

    pub fn pivots(&self) -> &[u32] {
        &self.pivots
    }

    /// Exact similarity table row for pivot `p` (length = corpus size).
    pub fn table_row(&self, p: usize) -> &[f64] {
        let n = self.corpus.len();
        &self.table[p * n..(p + 1) * n]
    }

    /// Certified interval on `sim(q, item_i)` from the pivot table, given
    /// the query's pivot similarities.
    #[inline]
    pub fn interval_for(&self, q_piv: &[f64], i: usize) -> SimInterval {
        self.interval_with(self.bound, q_piv, i)
    }

    /// [`Laesa::interval_for`] under an explicit bound (the per-request
    /// override path).
    #[inline]
    fn interval_with(&self, bound: BoundKind, q_piv: &[f64], i: usize) -> SimInterval {
        let n = self.corpus.len();
        let mut iv = SimInterval::full();
        for (p, &sq) in q_piv.iter().enumerate() {
            let sp = self.table[p * n + i];
            iv = iv.intersect(&bound.interval(sq, sp));
            if iv.is_empty() {
                return iv;
            }
        }
        // Ptolemaic kinds: the per-pivot base interval above already equals
        // the Mult/MultLb1 intersection (the two-sim degradation), so the
        // pair refinement can only tighten — never-looser by construction.
        if bound.is_ptolemaic() && !self.pairs.is_empty() {
            let fast = bound == BoundKind::PtolemaicFast;
            iv = self.pairs.refine(iv, fast, q_piv, |p| self.table[p * n + i]);
        }
        iv
    }

    /// Pivot sims into a borrowed buffer (the context query path).
    fn query_pivot_sims_into(&self, q: &C::Vector, ctx: &mut QueryContext, out: &mut Vec<f64>) {
        ctx.stats.sim_evals += self.pivots.len() as u64;
        self.corpus.sims(q, &self.pivots, out);
    }

    /// ADR-006 multi-query traversal: one (query-block × pivot-rows)
    /// kernel sweep fills every slot's pivot similarities, then each slot
    /// runs the standard candidate phases against its own heap/threshold
    /// through the blocked kernels.
    fn traverse_batch(
        &self,
        queries: &[C::Vector],
        bc: &mut BatchContext,
        ctx: &mut QueryContext,
        resps: &mut [SearchResponse],
    ) {
        let n = self.corpus.len();
        let m = self.pivots.len();
        if n == 0 {
            return;
        }
        self.corpus.stage_queries(queries, &mut bc.qb);
        let mask = bc.full_mask();
        super::note_visit(bc, mask);
        let nslots = bc.len();

        // Batched pivot stage. Floors are disabled: pivot similarities
        // feed the interval table, so none may be skipped by a pre-filter.
        let mut q_piv = ctx.lease_sims();
        q_piv.resize(nslots * m, 0.0);
        bc.live.clear();
        for j in 0..nslots {
            bc.live.push(j as u32);
            bc.floors[j] = -2.0;
        }
        {
            let BatchContext { qb, stats, scratches, live, floors, .. } = bc;
            let _ = self.corpus.scan_ids_multi_ctx(
                queries,
                qb,
                &self.pivots,
                live,
                floors,
                scratches,
                &mut |j, pos, s| q_piv[j * m + pos] = s,
            );
            for st in stats[..nslots].iter_mut() {
                st.sim_evals += m as u64;
            }
        }

        let mut ids = ctx.lease_ids();
        let mut cands = ctx.lease_pairs();
        for j in 0..nslots {
            let piv = &q_piv[j * m..(j + 1) * m];
            if bc.slots[j].range {
                // Collect every candidate whose certified interval admits
                // tau, then score the survivors in one blocked scan.
                let tau = bc.slots[j].tau;
                ids.clear();
                for i in 0..n {
                    let iv = self.interval_with(bc.bound, piv, i);
                    if iv.hi < tau || iv.is_empty() {
                        bc.stats[j].pruned += 1;
                    } else {
                        ids.push(i as u32);
                    }
                }
                let BatchContext { stats, scratches, .. } = bc;
                let evals = self.corpus.scan_ids_range_ctx(
                    &queries[j],
                    &ids,
                    tau,
                    &mut resps[j].hits,
                    &mut scratches[j],
                );
                stats[j].sim_evals += evals;
            } else {
                // Identical ordering and pivot seeding to the single-query
                // path, so batch results match it bitwise.
                cands.clear();
                cands.extend(
                    (0..n).map(|i| (i as u32, self.interval_with(bc.bound, piv, i).hi)),
                );
                cands.sort_unstable_by(|a, b| {
                    b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0))
                });
                let plan = TopkPlan {
                    k: bc.heaps[j].k(),
                    within: bc.slots[j].within.then_some(bc.slots[j].tau),
                    bound: bc.bound,
                };
                for (idx, &p) in self.pivots.iter().enumerate() {
                    bc.heaps[j].offer(p, piv[idx]);
                }
                let BatchContext { heaps, stats, scratches, .. } = bc;
                self.topk_candidates(
                    &queries[j],
                    &cands,
                    &plan,
                    &mut heaps[j],
                    &mut stats[j],
                    &mut ids,
                    &mut scratches[j],
                );
            }
        }
        ctx.release_pairs(cands);
        ctx.release_ids(ids);
        ctx.release_sims(q_piv);
    }
}

impl<C: Corpus> Laesa<C> {
    fn range_search(
        &self,
        q: &C::Vector,
        plan: &RangePlan,
        ctx: &mut QueryContext,
        out: &mut Vec<(u32, f64)>,
    ) {
        ctx.stats.nodes_visited += 1;
        ctx.trace_visit(0);
        out.clear();
        let mut q_piv = ctx.lease_sims();
        self.query_pivot_sims_into(q, ctx, &mut q_piv);
        for i in 0..self.corpus.len() {
            if !ctx.admits(i as u32) {
                continue; // denied: no interval, no exact evaluation
            }
            if ctx.budget_exhausted() {
                ctx.truncated = true;
                break;
            }
            let iv = self.interval_with(plan.bound, &q_piv, i);
            if iv.hi < plan.tau || iv.is_empty() {
                ctx.stats.pruned += 1;
                ctx.trace_prune(i as u64, iv.hi);
                continue; // certified non-match
            }
            let s = self.corpus.sim_q(q, i as u32);
            ctx.stats.sim_evals += 1;
            ctx.note_eval_slack(plan.bound, i as u64, iv.hi, s);
            if s >= plan.tau {
                out.push((i as u32, s));
            }
        }
        ctx.release_sims(q_piv);
        sort_desc(out);
    }

    /// Evaluate the `(ub desc, id asc)`-ordered candidate list against the
    /// heap in chunks of [`CAND_CHUNK`], so exact evaluations run through
    /// the corpus's blocked kernel path — on the quantized backend each
    /// chunk is pre-filtered by certified i8 upper bounds before the exact
    /// re-rank (ADR-003). The floor is re-checked at chunk boundaries
    /// rather than per candidate, so relative to a per-item loop at most
    /// `CAND_CHUNK - 1` extra candidates are scored; every one of them is
    /// certified at or below the floor, so the result set is unchanged.
    /// Plain-request path only: no id filter, no evaluation budget.
    // Zero-alloc hot path: candidate state rides as parameters rather than
    // allocating a per-call struct (ADR-004).
    #[allow(clippy::too_many_arguments)]
    fn topk_candidates(
        &self,
        q: &C::Vector,
        cands: &[(u32, f64)],
        plan: &TopkPlan,
        results: &mut KnnHeap,
        stats: &mut QueryStats,
        ids: &mut Vec<u32>,
        scratch: &mut KernelScratch,
    ) {
        let mut pos = 0usize;
        while pos < cands.len() {
            if plan.dead_below_floor(cands[pos].1)
                || (results.len() >= plan.k && cands[pos].1 <= results.floor())
            {
                // Sorted by ub desc: everything remaining is certified out.
                stats.pruned += (cands.len() - pos) as u64;
                break;
            }
            ids.clear();
            while pos < cands.len() && ids.len() < CAND_CHUNK {
                let (id, ub) = cands[pos];
                if plan.dead_below_floor(ub)
                    || (results.len() >= plan.k && ub <= results.floor())
                {
                    break; // the outer check charges the remainder as pruned
                }
                pos += 1;
                if self.pivots_sorted.binary_search(&id).is_err() {
                    ids.push(id); // pivots are already in the heap
                }
            }
            if !ids.is_empty() {
                stats.sim_evals += self.corpus.scan_ids_topk_ctx(q, ids, results, scratch);
            }
        }
    }

    fn topk_search(
        &self,
        q: &C::Vector,
        plan: &TopkPlan,
        kernel_path: bool,
        ctx: &mut QueryContext,
        out: &mut Vec<(u32, f64)>,
    ) {
        ctx.stats.nodes_visited += 1;
        ctx.trace_visit(0);
        let mut q_piv = ctx.lease_sims();
        self.query_pivot_sims_into(q, ctx, &mut q_piv);
        let n = self.corpus.len();

        // AESA-style ordering: score candidates in decreasing upper bound so
        // the floor rises as fast as possible; stop when the floor clears
        // the best remaining upper bound. The (ub desc, id asc) comparator
        // is total, so the allocation-free unstable sort is deterministic.
        let mut cands = ctx.lease_pairs();
        cands.extend((0..n).map(|i| (i as u32, self.interval_with(plan.bound, &q_piv, i).hi)));
        cands.sort_unstable_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));

        let mut results = plan.lease_heap(ctx);
        // Seed with the pivots (already evaluated — free information).
        for (idx, &p) in self.pivots.iter().enumerate() {
            if ctx.admits(p) {
                results.offer(p, q_piv[idx]);
            }
        }
        if kernel_path {
            // Plain request: chunked kernel evaluation (the i8 backend
            // pre-filters each chunk against the current floor).
            let mut ids = ctx.lease_ids();
            let mut st = QueryStats::default();
            let scratch = ctx.kernel_scratch();
            self.topk_candidates(q, &cands, plan, &mut results, &mut st, &mut ids, scratch);
            ctx.stats.merge(&st);
            ctx.release_ids(ids);
        } else {
            for (pos, &(id, ub)) in cands.iter().enumerate() {
                if plan.dead_below_floor(ub)
                    || (results.len() >= plan.k && ub <= results.floor())
                {
                    // Sorted by ub desc: everything remaining is certified out.
                    ctx.stats.pruned += (cands.len() - pos) as u64;
                    ctx.trace_prune(id as u64, ub);
                    break;
                }
                if self.pivots_sorted.binary_search(&id).is_ok() || !ctx.admits(id) {
                    continue;
                }
                if ctx.budget_exhausted() {
                    ctx.truncated = true;
                    break;
                }
                let s = self.corpus.sim_q(q, id);
                ctx.stats.sim_evals += 1;
                ctx.note_eval_slack(plan.bound, id as u64, ub, s);
                results.offer(id, s);
            }
        }
        out.clear();
        results.drain_into(out);
        ctx.release_heap(results);
        ctx.release_pairs(cands);
        ctx.release_sims(q_piv);
    }
}

impl<C: Corpus> SimilarityIndex<C::Vector> for Laesa<C> {
    fn len(&self) -> usize {
        self.corpus.len()
    }

    fn search_into(
        &self,
        q: &C::Vector,
        req: &SearchRequest,
        ctx: &mut QueryContext,
        resp: &mut SearchResponse,
    ) {
        // The chunked kernel path cannot honor per-candidate id filters or
        // evaluation budgets; those requests take the per-item loop.
        let kernel_path = req.filter.is_none() && req.budget.is_none();
        super::search_frame(
            req,
            ctx,
            resp,
            self.bound,
            super::ORD_LAESA,
            |plan, ctx, out| self.range_search(q, plan, ctx, out),
            |plan, ctx, out| self.topk_search(q, plan, kernel_path, ctx, out),
        );
    }

    fn search_batch_into(
        &self,
        queries: &[C::Vector],
        reqs: &[SearchRequest],
        ctx: &mut QueryContext,
        resps: &mut Vec<SearchResponse>,
    ) {
        super::run_batch(
            queries,
            reqs,
            ctx,
            resps,
            self.bound,
            super::ORD_LAESA,
            &mut |q, req, ctx, resp| self.search_into(q, req, ctx, resp),
            &mut |qs, bc, ctx, chunk| self.traverse_batch(qs, bc, ctx, chunk),
        );
    }

    fn name(&self) -> &'static str {
        "laesa"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{uniform_sphere, vmf_mixture, VmfSpec};
    use crate::index::{LinearScan, QueryStats};
    use crate::metrics::SimVector;
    use crate::storage::CorpusStore;

    #[test]
    fn matches_linear_scan() {
        let pts = uniform_sphere(300, 8, 41);
        let idx = Laesa::build(pts.clone(), BoundKind::Mult, 12);
        let lin = LinearScan::build(pts.clone());
        let mut s1 = QueryStats::default();
        let mut s2 = QueryStats::default();
        for qi in [0usize, 50, 299] {
            for tau in [0.8, 0.3] {
                assert_eq!(idx.range(&pts[qi], tau, &mut s1), lin.range(&pts[qi], tau, &mut s2));
            }
            let a = idx.knn(&pts[qi], 10, &mut s1);
            let b = lin.knn(&pts[qi], 10, &mut s2);
            for ((_, x), (_, y)) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn intervals_contain_truth() {
        let pts = uniform_sphere(100, 8, 43);
        let idx = Laesa::build(pts.clone(), BoundKind::Mult, 8);
        let q = &pts[99];
        let mut ctx = QueryContext::new();
        let mut q_piv = Vec::new();
        idx.query_pivot_sims_into(q, &mut ctx, &mut q_piv);
        for i in 0..100 {
            let iv = idx.interval_for(&q_piv, i);
            let s = q.sim(&pts[i]);
            assert!(iv.lo <= s + 1e-9 && s <= iv.hi + 1e-9, "item {i}: {iv:?} vs {s}");
        }
    }

    #[test]
    fn view_built_table_matches_per_item_table() {
        let pts = uniform_sphere(120, 10, 44);
        let store = CorpusStore::from_rows(pts.clone());
        let a = Laesa::build(pts.clone(), BoundKind::Mult, 10);
        let b = Laesa::build(store.view(), BoundKind::Mult, 10);
        assert_eq!(a.pivots(), b.pivots());
        for p in 0..a.n_pivots() {
            assert_eq!(a.table_row(p), b.table_row(p), "pivot row {p}");
        }
    }

    #[test]
    fn prunes_on_clustered_data() {
        let (pts, _) = vmf_mixture(&VmfSpec { n: 3000, dim: 16, clusters: 30, kappa: 100.0, seed: 5 });
        let idx = Laesa::build(pts.clone(), BoundKind::Mult, 32);
        let mut st = QueryStats::default();
        idx.range(&pts[0], 0.9, &mut st);
        assert!(st.sim_evals < 3000, "{} evals", st.sim_evals);
        assert!(st.pruned > 0);
    }

    #[test]
    fn ptolemaic_matches_linear_scan() {
        let (pts, _) =
            vmf_mixture(&VmfSpec { n: 600, dim: 8, clusters: 12, kappa: 60.0, seed: 7 });
        let lin = LinearScan::build(pts.clone());
        let mut s1 = QueryStats::default();
        let mut s2 = QueryStats::default();
        for bound in [BoundKind::Ptolemaic, BoundKind::PtolemaicFast] {
            let idx = Laesa::build(pts.clone(), bound, 8);
            for qi in [0usize, 123, 599] {
                for tau in [0.85, 0.4] {
                    assert_eq!(
                        idx.range(&pts[qi], tau, &mut s1),
                        lin.range(&pts[qi], tau, &mut s2),
                        "{bound:?} range tau={tau}"
                    );
                }
                let a = idx.knn(&pts[qi], 7, &mut s1);
                let b = lin.knn(&pts[qi], 7, &mut s2);
                for ((_, x), (_, y)) in a.iter().zip(&b) {
                    assert!((x - y).abs() < 1e-12, "{bound:?} knn");
                }
            }
        }
    }

    #[test]
    fn ptolemaic_intervals_contain_truth() {
        let pts = uniform_sphere(100, 8, 45);
        for bound in [BoundKind::Ptolemaic, BoundKind::PtolemaicFast] {
            let idx = Laesa::build(pts.clone(), bound, 8);
            let q = &pts[99];
            let mut ctx = QueryContext::new();
            let mut q_piv = Vec::new();
            idx.query_pivot_sims_into(q, &mut ctx, &mut q_piv);
            for i in 0..100 {
                let iv = idx.interval_for(&q_piv, i);
                let s = q.sim(&pts[i]);
                // f32-normalized corpus vectors leave ~1e-6 of chord slack
                // (the f64 derivation itself is pinned in bounds::ptolemy).
                assert!(
                    iv.lo <= s + 1e-6 && s <= iv.hi + 1e-6,
                    "{bound:?} item {i}: {iv:?} vs {s}"
                );
            }
        }
    }

    #[test]
    fn ptolemaic_prunes_at_least_as_much_as_mult() {
        let (pts, _) =
            vmf_mixture(&VmfSpec { n: 1500, dim: 16, clusters: 15, kappa: 80.0, seed: 8 });
        let mult = Laesa::build(pts.clone(), BoundKind::Mult, 16);
        let ptol = Laesa::build(pts.clone(), BoundKind::Ptolemaic, 16);
        let mut sm = QueryStats::default();
        let mut sp = QueryStats::default();
        for qi in 0..8 {
            mult.range(&pts[qi * 150], 0.85, &mut sm);
            ptol.range(&pts[qi * 150], 0.85, &mut sp);
        }
        // The pair refinement intersects the Mult interval, so it can only
        // prune more (never-looser by construction).
        assert!(sp.sim_evals <= sm.sim_evals, "mult={} ptol={}", sm.sim_evals, sp.sim_evals);
        assert!(sp.pruned >= sm.pruned, "mult={} ptol={}", sm.pruned, sp.pruned);
    }

    #[test]
    fn more_pivots_never_hurt_pruning() {
        let (pts, _) = vmf_mixture(&VmfSpec { n: 1000, dim: 8, clusters: 10, kappa: 50.0, seed: 6 });
        let few = Laesa::build(pts.clone(), BoundKind::Mult, 4);
        let many = Laesa::build(pts.clone(), BoundKind::Mult, 32);
        let mut sf = QueryStats::default();
        let mut sm = QueryStats::default();
        for qi in 0..10 {
            few.range(&pts[qi * 100], 0.8, &mut sf);
            many.range(&pts[qi * 100], 0.8, &mut sm);
        }
        // Non-pivot evaluations should shrink with more pivots.
        let f_extra = sf.sim_evals - 10 * 4;
        let m_extra = sm.sim_evals - 10 * 32;
        assert!(m_extra <= f_extra, "few={f_extra} many={m_extra}");
    }
}
