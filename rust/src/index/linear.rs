//! Exhaustive scan — the correctness oracle and pruning-power baseline.

use crate::metrics::SimVector;

use super::{sort_desc, KnnHeap, QueryStats, SimilarityIndex};

/// Brute-force index: every query evaluates every item.
pub struct LinearScan<V: SimVector> {
    items: Vec<V>,
}

impl<V: SimVector> LinearScan<V> {
    pub fn build(items: Vec<V>) -> Self {
        LinearScan { items }
    }

    pub fn items(&self) -> &[V] {
        &self.items
    }
}

impl<V: SimVector> SimilarityIndex<V> for LinearScan<V> {
    fn len(&self) -> usize {
        self.items.len()
    }

    fn range(&self, q: &V, tau: f64, stats: &mut QueryStats) -> Vec<(u32, f64)> {
        stats.nodes_visited += 1;
        let mut out = Vec::new();
        for (i, item) in self.items.iter().enumerate() {
            let s = q.sim(item);
            stats.sim_evals += 1;
            if s >= tau {
                out.push((i as u32, s));
            }
        }
        sort_desc(&mut out);
        out
    }

    fn knn(&self, q: &V, k: usize, stats: &mut QueryStats) -> Vec<(u32, f64)> {
        stats.nodes_visited += 1;
        let mut heap = KnnHeap::new(k);
        for (i, item) in self.items.iter().enumerate() {
            let s = q.sim(item);
            stats.sim_evals += 1;
            heap.offer(i as u32, s);
        }
        heap.into_sorted()
    }

    fn name(&self) -> &'static str {
        "linear"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::uniform_sphere;

    #[test]
    fn range_returns_sorted_matches() {
        let pts = uniform_sphere(100, 8, 1);
        let idx = LinearScan::build(pts.clone());
        let mut stats = QueryStats::default();
        let hits = idx.range(&pts[0], 0.5, &mut stats);
        assert_eq!(stats.sim_evals, 100);
        assert!(hits.windows(2).all(|w| w[0].1 >= w[1].1));
        assert!(hits.iter().all(|&(_, s)| s >= 0.5));
        assert_eq!(hits[0].0, 0);
    }

    #[test]
    fn knn_self_is_first() {
        let pts = uniform_sphere(50, 8, 2);
        let idx = LinearScan::build(pts.clone());
        let mut stats = QueryStats::default();
        let hits = idx.knn(&pts[7], 5, &mut stats);
        assert_eq!(hits.len(), 5);
        assert_eq!(hits[0].0, 7);
        assert!((hits[0].1 - 1.0).abs() < 1e-6);
    }

    #[test]
    fn knn_with_k_larger_than_corpus() {
        let pts = uniform_sphere(3, 4, 3);
        let idx = LinearScan::build(pts.clone());
        let mut stats = QueryStats::default();
        assert_eq!(idx.knn(&pts[0], 10, &mut stats).len(), 3);
    }
}
