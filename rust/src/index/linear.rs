//! Exhaustive scan — the correctness oracle and pruning-power baseline.

use crate::query::{QueryContext, SearchRequest, SearchResponse};

use super::{sort_desc, Corpus, KnnHeap, SimilarityIndex};

/// Rows per chunk on the budgeted scan path: small enough that a budget
/// overshoots by at most one chunk, large enough to amortize the gather.
const BUDGET_CHUNK: u32 = 1024;

/// Brute-force index: every query evaluates every item. Built on a
/// [`crate::storage::CorpusView`] the scan runs through the blocked batch
/// kernels over the contiguous store; built on a `Vec<V>` it takes the
/// per-item path.
pub struct LinearScan<C: Corpus> {
    corpus: C,
}

impl<C: Corpus> LinearScan<C> {
    pub fn build(corpus: C) -> Self {
        LinearScan { corpus }
    }

    /// Budgeted full scan: chunked so the traversal can stop once the
    /// evaluation budget is spent (the unbudgeted path scans in one blocked
    /// kernel call). `heap` set means top-k, else range at `tau`.
    fn scan_budgeted(
        &self,
        q: &C::Vector,
        tau: f64,
        mut heap: Option<&mut KnnHeap>,
        ctx: &mut QueryContext,
        out: &mut Vec<(u32, f64)>,
    ) {
        let n = self.corpus.len() as u32;
        let mut ids = ctx.lease_ids();
        let mut start = 0u32;
        while start < n {
            if ctx.budget_exhausted() {
                ctx.truncated = true;
                break;
            }
            let end = start.saturating_add(BUDGET_CHUNK).min(n);
            ids.clear();
            ids.extend(start..end);
            let evals = match heap.as_deref_mut() {
                Some(heap) => self.corpus.scan_ids_topk_ctx(q, &ids, heap, ctx.kernel_scratch()),
                None => self.corpus.scan_ids_range_ctx(q, &ids, tau, out, ctx.kernel_scratch()),
            };
            ctx.stats.sim_evals += evals;
            start = end;
        }
        ctx.release_ids(ids);
    }
}

impl<C: Corpus> SimilarityIndex<C::Vector> for LinearScan<C> {
    fn len(&self) -> usize {
        self.corpus.len()
    }

    fn search_into(
        &self,
        q: &C::Vector,
        req: &SearchRequest,
        ctx: &mut QueryContext,
        resp: &mut SearchResponse,
    ) {
        // No build-time bound to override: the scan is exhaustive, so the
        // default passed to the frame is inert.
        super::search_frame(
            req,
            ctx,
            resp,
            crate::bounds::BoundKind::Mult,
            super::ORD_LINEAR,
            |plan, ctx, out| {
                ctx.stats.nodes_visited += 1;
                ctx.trace_visit(0);
                if req.budget.is_some() {
                    self.scan_budgeted(q, plan.tau, None, ctx, out);
                } else {
                    let evals =
                        self.corpus.scan_all_range_ctx(q, plan.tau, out, ctx.kernel_scratch());
                    ctx.stats.sim_evals += evals;
                }
                sort_desc(out);
            },
            |plan, ctx, out| {
                ctx.stats.nodes_visited += 1;
                ctx.trace_visit(0);
                let mut heap = plan.lease_heap(ctx);
                if req.budget.is_some() {
                    self.scan_budgeted(q, 0.0, Some(&mut heap), ctx, out);
                } else {
                    let evals = self.corpus.scan_all_topk_ctx(q, &mut heap, ctx.kernel_scratch());
                    ctx.stats.sim_evals += evals;
                }
                heap.drain_into(out);
                ctx.release_heap(heap);
            },
        );
    }

    fn search_batch_into(
        &self,
        queries: &[C::Vector],
        reqs: &[SearchRequest],
        ctx: &mut QueryContext,
        resps: &mut Vec<SearchResponse>,
    ) {
        super::run_batch(
            queries,
            reqs,
            ctx,
            resps,
            crate::bounds::BoundKind::Mult,
            super::ORD_LINEAR,
            &mut |q, req, ctx, resp| self.search_into(q, req, ctx, resp),
            &mut |qs, bc, _ctx, chunk| {
                // One multi-kernel sweep of the whole corpus serves every
                // slot (no tree, so nothing retires mid-scan).
                self.corpus.stage_queries(qs, &mut bc.qb);
                let mask = bc.full_mask();
                super::note_visit(bc, mask);
                super::batch_scan_all(&self.corpus, qs, bc, mask, chunk);
            },
        );
    }

    fn name(&self) -> &'static str {
        "linear"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::uniform_sphere;
    use crate::index::QueryStats;
    use crate::storage::CorpusStore;

    #[test]
    fn range_returns_sorted_matches() {
        let pts = uniform_sphere(100, 8, 1);
        let idx = LinearScan::build(pts.clone());
        let mut stats = QueryStats::default();
        let hits = idx.range(&pts[0], 0.5, &mut stats);
        assert_eq!(stats.sim_evals, 100);
        assert!(hits.windows(2).all(|w| w[0].1 >= w[1].1));
        assert!(hits.iter().all(|&(_, s)| s >= 0.5));
        assert_eq!(hits[0].0, 0);
    }

    #[test]
    fn knn_self_is_first() {
        let pts = uniform_sphere(50, 8, 2);
        let idx = LinearScan::build(pts.clone());
        let mut stats = QueryStats::default();
        let hits = idx.knn(&pts[7], 5, &mut stats);
        assert_eq!(hits.len(), 5);
        assert_eq!(hits[0].0, 7);
        assert!((hits[0].1 - 1.0).abs() < 1e-6);
    }

    #[test]
    fn knn_with_k_larger_than_corpus() {
        let pts = uniform_sphere(3, 4, 3);
        let idx = LinearScan::build(pts.clone());
        let mut stats = QueryStats::default();
        assert_eq!(idx.knn(&pts[0], 10, &mut stats).len(), 3);
    }

    #[test]
    fn view_backed_scan_is_byte_identical_to_per_item() {
        let pts = uniform_sphere(75, 12, 4);
        let store = CorpusStore::from_rows(pts.clone());
        let per_item = LinearScan::build(pts.clone());
        let zero_copy = LinearScan::build(store.view());
        let mut s1 = QueryStats::default();
        let mut s2 = QueryStats::default();
        for qi in [0usize, 33, 74] {
            let q = &pts[qi];
            assert_eq!(
                per_item.range(q, 0.2, &mut s1),
                zero_copy.range(q, 0.2, &mut s2)
            );
            assert_eq!(per_item.knn(q, 9, &mut s1), zero_copy.knn(q, 9, &mut s2));
        }
        assert_eq!(s1.sim_evals, s2.sim_evals);
    }
}
