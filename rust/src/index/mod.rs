//! Similarity-native metric indexes.
//!
//! Each index answers exact range queries (`sim(q, y) >= tau`) and exact
//! kNN (max similarity) using the paper's triangle inequalities for
//! pruning — no conversion to distances anywhere on the query path. Every
//! index is parameterized by a [`BoundKind`] so the benchmark harness can
//! measure how bound tightness translates into pruning power (the paper's
//! motivating application, deferred there to future work).
//!
//! Exactness contract: for any corpus, query, `tau` and `k`, results equal
//! the linear scan's (up to ties in kNN) for **every** bound kind — looser
//! bounds may only cost extra similarity evaluations, never results. The
//! proptest suite in `integration_index_exactness.rs` enforces this.

pub mod balltree;
pub mod cover;
pub mod gnat;
pub mod laesa;
pub mod linear;
pub mod mtree;
pub mod vptree;

pub use balltree::BallTree;
pub use cover::CoverTree;
pub use gnat::Gnat;
pub use laesa::Laesa;
pub use linear::LinearScan;
pub use mtree::MTree;
pub use vptree::VpTree;

use crate::metrics::SimVector;

/// Query-time instrumentation: the paper's pruning-power currency is the
/// number of exact similarity computations avoided.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct QueryStats {
    /// Exact similarity evaluations performed.
    pub sim_evals: u64,
    /// Tree nodes (or pivot tables / regions) visited.
    pub nodes_visited: u64,
    /// Candidates discarded by a bound without an exact evaluation.
    pub pruned: u64,
}

impl QueryStats {
    pub fn merge(&mut self, other: &QueryStats) {
        self.sim_evals += other.sim_evals;
        self.nodes_visited += other.nodes_visited;
        self.pruned += other.pruned;
    }
}

/// An exact cosine-similarity search index.
pub trait SimilarityIndex<V: SimVector>: Send + Sync {
    /// Number of indexed items.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// All `(id, sim)` with `sim(q, item) >= tau`, in descending similarity.
    fn range(&self, q: &V, tau: f64, stats: &mut QueryStats) -> Vec<(u32, f64)>;

    /// The `k` most similar items, in descending similarity. Fewer than `k`
    /// are returned only when the corpus is smaller than `k`.
    fn knn(&self, q: &V, k: usize, stats: &mut QueryStats) -> Vec<(u32, f64)>;

    /// Index name for benchmark tables.
    fn name(&self) -> &'static str;
}

/// Bounded max-similarity result collector for kNN searches.
#[derive(Debug)]
pub struct KnnHeap {
    k: usize,
    /// Min-heap by similarity (worst current member on top), as a sorted
    /// Vec kept small: k is small in practice, so O(k) insert is fine and
    /// avoids float-ordering wrappers.
    entries: Vec<(u32, f64)>,
}

impl KnnHeap {
    pub fn new(k: usize) -> Self {
        KnnHeap { k: k.max(1), entries: Vec::with_capacity(k + 1) }
    }

    /// Current pruning floor: the k-th best similarity, or -1 (no pruning)
    /// while the heap is not full.
    #[inline]
    pub fn floor(&self) -> f64 {
        if self.entries.len() < self.k {
            -1.0
        } else {
            self.entries.last().map(|&(_, s)| s).unwrap_or(-1.0)
        }
    }

    #[inline]
    pub fn offer(&mut self, id: u32, sim: f64) {
        if self.entries.len() >= self.k && sim <= self.floor() {
            return;
        }
        let pos = self
            .entries
            .partition_point(|&(_, s)| s > sim || (s == sim && true));
        self.entries.insert(pos, (id, sim));
        self.entries.truncate(self.k);
    }

    pub fn into_sorted(self) -> Vec<(u32, f64)> {
        self.entries
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Sort a result set in descending similarity with deterministic tie order.
pub(crate) fn sort_desc(results: &mut Vec<(u32, f64)>) {
    results.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
}

/// Max-priority entry for best-first tree searches: orders a node handle by
/// its similarity upper bound.
#[derive(Debug, Clone, Copy)]
pub(crate) struct Prioritized<T> {
    pub ub: f64,
    pub item: T,
}

impl<T> PartialEq for Prioritized<T> {
    fn eq(&self, other: &Self) -> bool {
        self.ub == other.ub
    }
}
impl<T> Eq for Prioritized<T> {}
impl<T> PartialOrd for Prioritized<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Prioritized<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.ub.partial_cmp(&other.ub).unwrap_or(std::cmp::Ordering::Equal)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knn_heap_keeps_best_k() {
        let mut h = KnnHeap::new(3);
        for (id, s) in [(0, 0.1), (1, 0.9), (2, 0.5), (3, 0.7), (4, 0.3)] {
            h.offer(id, s);
        }
        let out = h.into_sorted();
        assert_eq!(out.iter().map(|&(i, _)| i).collect::<Vec<_>>(), vec![1, 3, 2]);
    }

    #[test]
    fn knn_heap_floor_semantics() {
        let mut h = KnnHeap::new(2);
        assert_eq!(h.floor(), -1.0);
        h.offer(0, 0.5);
        assert_eq!(h.floor(), -1.0); // not full yet
        h.offer(1, 0.8);
        assert!((h.floor() - 0.5).abs() < 1e-15);
        h.offer(2, 0.6);
        assert!((h.floor() - 0.6).abs() < 1e-15);
    }

    #[test]
    fn prioritized_orders_by_ub() {
        let mut heap = std::collections::BinaryHeap::new();
        heap.push(Prioritized { ub: 0.2, item: "a" });
        heap.push(Prioritized { ub: 0.9, item: "b" });
        heap.push(Prioritized { ub: 0.5, item: "c" });
        assert_eq!(heap.pop().unwrap().item, "b");
        assert_eq!(heap.pop().unwrap().item, "c");
    }
}
