//! Similarity-native metric indexes.
//!
//! Each index answers exact range queries (`sim(q, y) >= tau`) and exact
//! kNN (max similarity) using the paper's triangle inequalities for
//! pruning — no conversion to distances anywhere on the query path. Every
//! index is parameterized by a [`BoundKind`] so the benchmark harness can
//! measure how bound tightness translates into pruning power (the paper's
//! motivating application, deferred there to future work).
//!
//! Exactness contract: for any corpus, query, `tau` and `k`, results equal
//! the linear scan's (up to ties in kNN) for **every** bound kind — looser
//! bounds may only cost extra similarity evaluations, never results. The
//! proptest suite in `integration_index_exactness.rs` enforces this.

pub mod balltree;
pub mod cover;
pub mod gnat;
pub mod laesa;
pub mod linear;
pub mod mtree;
pub mod vptree;

pub use balltree::BallTree;
pub use cover::CoverTree;
pub use gnat::Gnat;
pub use laesa::Laesa;
pub use linear::LinearScan;
pub use mtree::MTree;
pub use vptree::VpTree;

use crate::bounds::BoundKind;
use crate::metrics::{DenseVec, SimVector};
use crate::query::{
    BatchContext, MAX_BATCH, QueryContext, SearchMode, SearchRequest, SearchResponse,
};
use crate::storage::{CorpusView, KernelScratch, QueryBlock};

/// What an index builds over: a collection of vectors addressed by dense
/// `u32` ids.
///
/// Two implementations exist. `Vec<V>` is the owning per-item path (the
/// only option for `SparseVec` corpora). [`CorpusView`] is the zero-copy
/// path: it aliases the shared [`crate::storage::CorpusStore`] buffer and
/// routes the id-list/full scans through the store's pluggable
/// [`crate::storage::KernelBackend`] (scalar / SIMD / i8-quantized,
/// ADR-003). Every backend returns scan results byte-identical to the
/// per-item path — exact backends bit-for-bit per similarity, the
/// quantized backend exact-after-re-rank — so indexes inherit whichever
/// backend their corpus carries without code changes here.
pub trait Corpus: Send + Sync + 'static {
    type Vector: SimVector;

    /// Number of items.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Vector-space dimension (0 for an empty corpus).
    fn dim(&self) -> usize;

    /// Exact similarity between an external query and item `id`.
    fn sim_q(&self, q: &Self::Vector, id: u32) -> f64;

    /// Exact similarity between two corpus items (build-time pivot math).
    fn sim_ij(&self, a: u32, b: u32) -> f64;

    /// Similarities of `q` to each of `ids`, replacing `out`'s contents in
    /// matching order.
    fn sims(&self, q: &Self::Vector, ids: &[u32], out: &mut Vec<f64>) {
        out.clear();
        out.extend(ids.iter().map(|&id| self.sim_q(q, id)));
    }

    /// Similarities of item `a` to every item, replacing `out`'s contents
    /// (LAESA table rows).
    fn sims_of_item(&self, a: u32, out: &mut Vec<f64>) {
        out.clear();
        out.extend((0..self.len() as u32).map(|b| self.sim_ij(a, b)));
    }

    /// Score `ids` against `q`, pushing every `(id, sim)` with `sim >= tau`.
    /// Returns the number of exact evaluations performed.
    fn scan_ids_range(
        &self,
        q: &Self::Vector,
        ids: &[u32],
        tau: f64,
        out: &mut Vec<(u32, f64)>,
    ) -> u64 {
        for &id in ids {
            let s = self.sim_q(q, id);
            if s >= tau {
                out.push((id, s));
            }
        }
        ids.len() as u64
    }

    /// Score `ids` against `q`, offering each into `heap`. Returns evals.
    fn scan_ids_topk(&self, q: &Self::Vector, ids: &[u32], heap: &mut KnnHeap) -> u64 {
        for &id in ids {
            heap.offer(id, self.sim_q(q, id));
        }
        ids.len() as u64
    }

    /// Score the whole corpus against `q` with a threshold. Returns evals.
    fn scan_all_range(&self, q: &Self::Vector, tau: f64, out: &mut Vec<(u32, f64)>) -> u64 {
        for id in 0..self.len() as u32 {
            let s = self.sim_q(q, id);
            if s >= tau {
                out.push((id, s));
            }
        }
        self.len() as u64
    }

    /// Score the whole corpus against `q` into a heap. Returns evals.
    fn scan_all_topk(&self, q: &Self::Vector, heap: &mut KnnHeap) -> u64 {
        for id in 0..self.len() as u32 {
            heap.offer(id, self.sim_q(q, id));
        }
        self.len() as u64
    }

    // --- scratch-borrowing scan variants (the context hot path) ------------
    //
    // The per-item defaults have nothing to cache, but they do honor the
    // scratch's armed id filter (ADR-005): denied ids are skipped *before*
    // the exact evaluation, mirroring what the kernel backends do on the
    // CorpusView path. The CorpusView impl overrides them to thread the
    // scratch into the kernel backend, so a quantized backend builds its
    // QuantQuery once per query instead of once per leaf bucket (ADR-004).

    /// [`Corpus::scan_ids_range`] with a borrowed per-query kernel scratch.
    fn scan_ids_range_ctx(
        &self,
        q: &Self::Vector,
        ids: &[u32],
        tau: f64,
        out: &mut Vec<(u32, f64)>,
        scratch: &mut KernelScratch,
    ) -> u64 {
        if !scratch.has_filter() {
            return self.scan_ids_range(q, ids, tau, out);
        }
        let mut evals = 0;
        for &id in ids {
            if !scratch.filter_admits(id) {
                continue;
            }
            let s = self.sim_q(q, id);
            evals += 1;
            if s >= tau {
                out.push((id, s));
            }
        }
        evals
    }

    /// [`Corpus::scan_ids_topk`] with a borrowed per-query kernel scratch.
    fn scan_ids_topk_ctx(
        &self,
        q: &Self::Vector,
        ids: &[u32],
        heap: &mut KnnHeap,
        scratch: &mut KernelScratch,
    ) -> u64 {
        if !scratch.has_filter() {
            return self.scan_ids_topk(q, ids, heap);
        }
        let mut evals = 0;
        for &id in ids {
            if scratch.filter_admits(id) {
                heap.offer(id, self.sim_q(q, id));
                evals += 1;
            }
        }
        evals
    }

    /// [`Corpus::scan_all_range`] with a borrowed per-query kernel scratch.
    fn scan_all_range_ctx(
        &self,
        q: &Self::Vector,
        tau: f64,
        out: &mut Vec<(u32, f64)>,
        scratch: &mut KernelScratch,
    ) -> u64 {
        if !scratch.has_filter() {
            return self.scan_all_range(q, tau, out);
        }
        let mut evals = 0;
        for id in 0..self.len() as u32 {
            if !scratch.filter_admits(id) {
                continue;
            }
            let s = self.sim_q(q, id);
            evals += 1;
            if s >= tau {
                out.push((id, s));
            }
        }
        evals
    }

    /// [`Corpus::scan_all_topk`] with a borrowed per-query kernel scratch.
    fn scan_all_topk_ctx(
        &self,
        q: &Self::Vector,
        heap: &mut KnnHeap,
        scratch: &mut KernelScratch,
    ) -> u64 {
        if !scratch.has_filter() {
            return self.scan_all_topk(q, heap);
        }
        let mut evals = 0;
        for id in 0..self.len() as u32 {
            if scratch.filter_admits(id) {
                heap.offer(id, self.sim_q(q, id));
                evals += 1;
            }
        }
        evals
    }

    // --- multi-query scan variants (the batched-traversal hot path) --------
    //
    // One call scores a whole batch's live query slots against one row
    // block (ADR-006). The per-item defaults loop; the CorpusView impl
    // overrides them to dispatch the GEMM-shaped `sim_block_multi` /
    // `scan_multi` kernel entry points, where the quantized backend
    // pre-filters each slot against its certified floor through one cached
    // `QuantQuery` per slot. The batch path serves plain plans only, so no
    // filter handling is needed here.

    /// Pack the batch's query vectors for the multi kernels. The per-item
    /// default leaves the block empty (per-item corpora score through
    /// [`Corpus::sim_q`] in the multi-scan defaults); [`CorpusView`] packs
    /// the dense query slices into one contiguous block.
    fn stage_queries(&self, queries: &[Self::Vector], qb: &mut QueryBlock) {
        let _ = queries;
        qb.reset(0);
    }

    /// Score `ids` against every live query slot: `sink(slot, pos, sim)`
    /// receives positions into `ids` (the caller maps `pos` back through
    /// `ids[pos]`). `floors[slot]` is a certified lower cutoff for that
    /// slot's result set — a backend may skip a `(slot, row)` pair only
    /// when the row provably scores strictly below it. Returns the exact
    /// evaluations delivered (= sink invocations).
    // Wide by design: the multi-query kernel contract threads every
    // per-slot buffer through one call (ADR-006).
    #[allow(clippy::too_many_arguments)]
    fn scan_ids_multi_ctx(
        &self,
        queries: &[Self::Vector],
        qb: &QueryBlock,
        ids: &[u32],
        live: &[u32],
        floors: &[f64],
        scratches: &mut [KernelScratch],
        sink: &mut dyn FnMut(usize, usize, f64),
    ) -> u64 {
        let _ = (qb, floors, scratches);
        for &j in live {
            for (pos, &id) in ids.iter().enumerate() {
                sink(j as usize, pos, self.sim_q(&queries[j as usize], id));
            }
        }
        live.len() as u64 * ids.len() as u64
    }

    /// Score the whole corpus against every live query slot (`pos` is the
    /// item id for a full scan). See [`Corpus::scan_ids_multi_ctx`].
    fn scan_all_multi_ctx(
        &self,
        queries: &[Self::Vector],
        qb: &QueryBlock,
        live: &[u32],
        floors: &[f64],
        scratches: &mut [KernelScratch],
        sink: &mut dyn FnMut(usize, usize, f64),
    ) -> u64 {
        let _ = (qb, floors, scratches);
        for &j in live {
            for id in 0..self.len() as u32 {
                sink(j as usize, id as usize, self.sim_q(&queries[j as usize], id));
            }
        }
        live.len() as u64 * self.len() as u64
    }
}

/// The owning per-item corpus: works for any [`SimVector`], including
/// sparse vectors.
impl<V: SimVector> Corpus for Vec<V> {
    type Vector = V;

    fn len(&self) -> usize {
        self.as_slice().len()
    }

    fn dim(&self) -> usize {
        self.first().map(SimVector::dim).unwrap_or(0)
    }

    #[inline]
    fn sim_q(&self, q: &V, id: u32) -> f64 {
        q.sim(&self[id as usize])
    }

    #[inline]
    fn sim_ij(&self, a: u32, b: u32) -> f64 {
        self[a as usize].sim(&self[b as usize])
    }
}

/// The zero-copy corpus: aliases the shared store and scans through the
/// blocked batch kernels.
impl Corpus for CorpusView {
    type Vector = DenseVec;

    fn len(&self) -> usize {
        CorpusView::len(self)
    }

    fn dim(&self) -> usize {
        CorpusView::dim(self)
    }

    #[inline]
    fn sim_q(&self, q: &DenseVec, id: u32) -> f64 {
        crate::storage::dot_slice(q.as_slice(), self.row(id))
    }

    #[inline]
    fn sim_ij(&self, a: u32, b: u32) -> f64 {
        crate::storage::dot_slice(self.row(a), self.row(b))
    }

    fn sims(&self, q: &DenseVec, ids: &[u32], out: &mut Vec<f64>) {
        self.dot_batch(q.as_slice(), ids, out);
    }

    fn sims_of_item(&self, a: u32, out: &mut Vec<f64>) {
        out.clear();
        out.reserve(CorpusView::len(self));
        self.for_each_sim(self.row(a), |_, s| out.push(s));
    }

    fn scan_ids_range(
        &self,
        q: &DenseVec,
        ids: &[u32],
        tau: f64,
        out: &mut Vec<(u32, f64)>,
    ) -> u64 {
        CorpusView::scan_ids_range(self, q.as_slice(), ids, tau, out)
    }

    fn scan_ids_topk(&self, q: &DenseVec, ids: &[u32], heap: &mut KnnHeap) -> u64 {
        CorpusView::scan_ids_topk(self, q.as_slice(), ids, heap)
    }

    fn scan_all_range(&self, q: &DenseVec, tau: f64, out: &mut Vec<(u32, f64)>) -> u64 {
        CorpusView::scan_range(self, q.as_slice(), tau, out)
    }

    fn scan_all_topk(&self, q: &DenseVec, heap: &mut KnnHeap) -> u64 {
        CorpusView::scan_topk(self, q.as_slice(), heap)
    }

    fn scan_ids_range_ctx(
        &self,
        q: &DenseVec,
        ids: &[u32],
        tau: f64,
        out: &mut Vec<(u32, f64)>,
        scratch: &mut KernelScratch,
    ) -> u64 {
        CorpusView::scan_ids_range_with(self, q.as_slice(), ids, tau, out, scratch)
    }

    fn scan_ids_topk_ctx(
        &self,
        q: &DenseVec,
        ids: &[u32],
        heap: &mut KnnHeap,
        scratch: &mut KernelScratch,
    ) -> u64 {
        CorpusView::scan_ids_topk_with(self, q.as_slice(), ids, heap, scratch)
    }

    fn scan_all_range_ctx(
        &self,
        q: &DenseVec,
        tau: f64,
        out: &mut Vec<(u32, f64)>,
        scratch: &mut KernelScratch,
    ) -> u64 {
        CorpusView::scan_range_with(self, q.as_slice(), tau, out, scratch)
    }

    fn scan_all_topk_ctx(
        &self,
        q: &DenseVec,
        heap: &mut KnnHeap,
        scratch: &mut KernelScratch,
    ) -> u64 {
        CorpusView::scan_topk_with(self, q.as_slice(), heap, scratch)
    }

    fn stage_queries(&self, queries: &[DenseVec], qb: &mut QueryBlock) {
        if self.is_empty() {
            // An empty view has dimension 0; traversals bail before any
            // scan, so leave the block empty instead of tripping the
            // dimension assert (mirrors the single-query path, where
            // `check_query` is never reached on an empty corpus).
            qb.reset(0);
            return;
        }
        qb.reset(CorpusView::dim(self));
        for q in queries {
            qb.push(q.as_slice());
        }
    }

    // Wide by design: mirrors the trait method above (ADR-006).
    #[allow(clippy::too_many_arguments)]
    fn scan_ids_multi_ctx(
        &self,
        _queries: &[DenseVec],
        qb: &QueryBlock,
        ids: &[u32],
        live: &[u32],
        floors: &[f64],
        scratches: &mut [KernelScratch],
        sink: &mut dyn FnMut(usize, usize, f64),
    ) -> u64 {
        CorpusView::scan_ids_multi_with(self, qb, ids, live, floors, scratches, sink)
    }

    fn scan_all_multi_ctx(
        &self,
        _queries: &[DenseVec],
        qb: &QueryBlock,
        live: &[u32],
        floors: &[f64],
        scratches: &mut [KernelScratch],
        sink: &mut dyn FnMut(usize, usize, f64),
    ) -> u64 {
        CorpusView::scan_all_multi_with(self, qb, live, floors, scratches, sink)
    }
}

/// Query-time instrumentation: the paper's pruning-power currency is the
/// number of exact similarity computations avoided.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct QueryStats {
    /// Exact similarity evaluations performed.
    pub sim_evals: u64,
    /// Tree nodes (or pivot tables / regions) visited.
    pub nodes_visited: u64,
    /// Candidates discarded by a bound without an exact evaluation.
    pub pruned: u64,
}

impl QueryStats {
    pub fn merge(&mut self, other: &QueryStats) {
        self.sim_evals += other.sim_evals;
        self.nodes_visited += other.nodes_visited;
        self.pruned += other.pruned;
    }
}

/// An exact cosine-similarity search index.
///
/// The single required entry point is [`SimilarityIndex::search_into`]
/// (ADR-005): it executes one typed [`SearchRequest`] plan — kNN, range,
/// or kNN-within-a-floor, with optional per-request bound/kernel
/// overrides, id filter, and evaluation budget — borrowing a
/// [`QueryContext`] for every piece of traversal scratch, so the
/// steady-state query path allocates nothing (ADR-004). Every classic
/// signature (`knn` / `knn_into` / `range` / `range_into` /
/// `knn_batch` / `range_batch`) is a provided shim that builds the
/// equivalent plain plan, so existing call sites keep compiling and keep
/// returning byte-identical results.
pub trait SimilarityIndex<V: SimVector>: Send + Sync {
    /// Number of indexed items.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Execute one typed search plan, replacing `resp`'s contents: hits in
    /// `(sim desc, id asc)` order, the per-query stats window, and the
    /// budget-truncation flag. Traversal scratch and instrumentation come
    /// from `ctx` (whose per-query stats this call adds to — the caller
    /// owns the query boundary via [`QueryContext::begin_query`]).
    /// Implementations delegate to the crate-internal `search_frame`,
    /// which arms the plan with [`QueryContext::apply_plan`] at entry and
    /// disarms at exit; the request's filter ids are interpreted in this
    /// index's local id space.
    fn search_into(
        &self,
        q: &V,
        req: &SearchRequest,
        ctx: &mut QueryContext,
        resp: &mut SearchResponse,
    );

    /// Execute a batch of typed plans, one response per query, replacing
    /// `resps`' contents (ADR-006). Results are byte-identical to calling
    /// [`SimilarityIndex::search_into`] per query on tie-free corpora (the
    /// usual kNN tie-membership caveat applies, exactly as between any two
    /// sound traversal orders).
    ///
    /// The tree indexes override this: a batch of *plain* plans descends
    /// the tree **once** behind a shared best-first frontier — a node is
    /// pruned only when no live query's bound can admit it, queries retire
    /// from the batch as their heaps tighten, and every leaf visit becomes
    /// one (query-block × row-block) multi-kernel call. Optioned plans
    /// (bound/kernel override, filter, budget) fall back to sequential
    /// per-query execution. This default *is* that fallback, and unlike
    /// [`SimilarityIndex::search_into`] it owns the query boundary: it
    /// calls [`QueryContext::begin_query`] itself (per query here, per
    /// chunk on the shared-frontier path), matching
    /// [`SimilarityIndex::knn_batch`] semantics.
    fn search_batch_into(
        &self,
        queries: &[V],
        reqs: &[SearchRequest],
        ctx: &mut QueryContext,
        resps: &mut Vec<SearchResponse>,
    ) {
        assert_eq!(queries.len(), reqs.len(), "batch queries/plans length mismatch");
        resps.resize_with(queries.len(), SearchResponse::default);
        for ((q, req), resp) in queries.iter().zip(reqs).zip(resps.iter_mut()) {
            ctx.begin_query();
            self.search_into(q, req, ctx, resp);
        }
    }

    /// [`SimilarityIndex::search_into`] with a throwaway context — the
    /// convenience form for one-off plans.
    fn search(&self, q: &V, req: &SearchRequest) -> SearchResponse {
        let mut ctx = QueryContext::new();
        ctx.begin_query();
        let mut resp = SearchResponse::default();
        self.search_into(q, req, &mut ctx, &mut resp);
        resp
    }

    /// All `(id, sim)` with `sim(q, item) >= tau`, in descending
    /// similarity, replacing `out`'s contents. (Compat shim over
    /// [`SimilarityIndex::search_into`] with a plain range plan.)
    fn range_into(&self, q: &V, tau: f64, ctx: &mut QueryContext, out: &mut Vec<(u32, f64)>) {
        let req = SearchRequest::range(tau).build();
        let mut resp = SearchResponse::default();
        std::mem::swap(&mut resp.hits, out);
        self.search_into(q, &req, ctx, &mut resp);
        std::mem::swap(&mut resp.hits, out);
    }

    /// The `k` most similar items, in descending similarity, replacing
    /// `out`'s contents. Fewer than `k` are returned only when the corpus
    /// is smaller than `k`. (Compat shim over
    /// [`SimilarityIndex::search_into`] with a plain kNN plan.)
    fn knn_into(&self, q: &V, k: usize, ctx: &mut QueryContext, out: &mut Vec<(u32, f64)>) {
        let req = SearchRequest::knn(k).build();
        let mut resp = SearchResponse::default();
        std::mem::swap(&mut resp.hits, out);
        self.search_into(q, &req, ctx, &mut resp);
        std::mem::swap(&mut resp.hits, out);
    }

    /// All `(id, sim)` with `sim(q, item) >= tau`, in descending similarity.
    /// (Convenience form: one throwaway context per call; hot paths reuse a
    /// context through [`SimilarityIndex::range_into`] or the batch API.)
    fn range(&self, q: &V, tau: f64, stats: &mut QueryStats) -> Vec<(u32, f64)> {
        let mut ctx = QueryContext::new();
        ctx.begin_query();
        let mut out = Vec::new();
        self.range_into(q, tau, &mut ctx, &mut out);
        stats.merge(&ctx.stats);
        out
    }

    /// The `k` most similar items, in descending similarity. Fewer than `k`
    /// are returned only when the corpus is smaller than `k`. (Convenience
    /// form; see [`SimilarityIndex::range`].)
    fn knn(&self, q: &V, k: usize, stats: &mut QueryStats) -> Vec<(u32, f64)> {
        let mut ctx = QueryContext::new();
        ctx.begin_query();
        let mut out = Vec::new();
        self.knn_into(q, k, &mut ctx, &mut out);
        stats.merge(&ctx.stats);
        out
    }

    /// Run a batch of range queries through one shared context. Results are
    /// byte-identical to calling [`SimilarityIndex::range`] per query, and
    /// each query's [`QueryStats`] ride along.
    fn range_batch(
        &self,
        queries: &[V],
        tau: f64,
        ctx: &mut QueryContext,
    ) -> Vec<(Vec<(u32, f64)>, QueryStats)> {
        queries
            .iter()
            .map(|q| {
                ctx.begin_query();
                let mut out = Vec::new();
                self.range_into(q, tau, ctx, &mut out);
                (out, ctx.stats)
            })
            .collect()
    }

    /// Run a batch of kNN queries through one shared context. Results are
    /// byte-identical to calling [`SimilarityIndex::knn`] per query.
    fn knn_batch(
        &self,
        queries: &[V],
        k: usize,
        ctx: &mut QueryContext,
    ) -> Vec<(Vec<(u32, f64)>, QueryStats)> {
        queries
            .iter()
            .map(|q| {
                ctx.begin_query();
                let mut out = Vec::new();
                self.knn_into(q, k, ctx, &mut out);
                (out, ctx.stats)
            })
            .collect()
    }

    /// Index name for benchmark tables.
    fn name(&self) -> &'static str;
}

/// Bounded max-similarity result collector for kNN searches, with an
/// optional hard similarity floor (the `KnnWithin` mode's `tau`).
#[derive(Debug)]
pub struct KnnHeap {
    k: usize,
    /// Min-heap by similarity (worst current member on top), as a sorted
    /// Vec kept small: k is small in practice, so O(k) insert is fine and
    /// avoids float-ordering wrappers.
    entries: Vec<(u32, f64)>,
    /// Hard admission floor: candidates below it are rejected outright,
    /// and [`KnnHeap::floor`] never reports below it. `-1.0` (the cosine
    /// minimum) for plain kNN — behaviorally identical to no floor, since
    /// every similarity is clamped to `[-1, 1]`.
    min: f64,
}

impl Default for KnnHeap {
    /// An empty k=1 heap that has allocated nothing — the rest state a
    /// [`QueryContext`] holds between leases (`std::mem::take` must not
    /// allocate).
    fn default() -> Self {
        KnnHeap { k: 1, entries: Vec::new(), min: -1.0 }
    }
}

impl KnnHeap {
    pub fn new(k: usize) -> Self {
        KnnHeap { k: k.max(1), entries: Vec::with_capacity(k + 1), min: -1.0 }
    }

    /// Reset for a fresh query retaining `k`, keeping the entry buffer and
    /// clearing any similarity floor. After the first reset at a given
    /// `k`, subsequent same-`k` resets never allocate (offer inserts
    /// before truncating, hence `k + 1`).
    pub fn reset(&mut self, k: usize) {
        self.k = k.max(1);
        self.entries.clear();
        self.entries.reserve(self.k + 1);
        self.min = -1.0;
    }

    /// Arm a hard similarity floor (call right after [`KnnHeap::reset`] /
    /// [`KnnHeap::new`], before the first offer): candidates with
    /// `sim < tau` are rejected, and [`KnnHeap::floor`] reports at least
    /// `tau` — so certified pre-filters prune below it immediately, even
    /// while the heap is not full.
    pub fn set_min(&mut self, tau: f64) {
        debug_assert!(self.entries.is_empty(), "set_min on a non-empty heap");
        self.min = tau;
    }

    /// Append the retained entries (already in `(sim desc, id asc)` order)
    /// to `out` and clear the heap, keeping its buffer — the
    /// allocation-free sibling of [`KnnHeap::into_sorted`].
    pub fn drain_into(&mut self, out: &mut Vec<(u32, f64)>) {
        out.extend(self.entries.drain(..));
    }

    /// The `k` this heap retains (the backend pre-filters need it to
    /// compute a certified pruning floor).
    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// Current pruning floor: the k-th best similarity (or the armed
    /// similarity floor while the heap is not full — `-1.0`, i.e. no
    /// pruning, for a plain kNN heap).
    #[inline]
    pub fn floor(&self) -> f64 {
        if self.entries.len() < self.k {
            self.min
        } else {
            self.entries.last().map(|&(_, s)| s.max(self.min)).unwrap_or(self.min)
        }
    }

    /// Offer a candidate. Ties in similarity are broken by **ascending id**
    /// (matching [`sort_desc`]), so the retained set is the top-k under the
    /// total order `(sim desc, id asc)` regardless of insertion order —
    /// a candidate equal to the current floor still displaces a larger-id
    /// incumbent.
    #[inline]
    pub fn offer(&mut self, id: u32, sim: f64) {
        if sim < self.min {
            return; // below the armed similarity floor (KnnWithin)
        }
        if self.entries.len() >= self.k && sim < self.floor() {
            return;
        }
        let pos = self
            .entries
            .partition_point(|&(eid, s)| s > sim || (s == sim && eid < id));
        self.entries.insert(pos, (id, sim));
        self.entries.truncate(self.k);
    }

    pub fn into_sorted(self) -> Vec<(u32, f64)> {
        self.entries
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Mode-resolved top-k traversal parameters the tree indexes share: the
/// result count, the optional `KnnWithin` similarity floor, and the
/// effective pruning bound (the per-request override, else the build-time
/// bound).
pub(crate) struct TopkPlan {
    pub k: usize,
    /// `Some(tau)` for `KnnWithin`: subtrees whose certified upper bound
    /// is strictly below `tau` are pruned even while the heap is not full,
    /// and the heap rejects candidates below `tau`.
    pub within: Option<f64>,
    pub bound: BoundKind,
}

impl TopkPlan {
    /// Lease the result heap for this plan (floored at `tau` for
    /// `KnnWithin`).
    pub fn lease_heap(&self, ctx: &mut QueryContext) -> KnnHeap {
        let mut heap = ctx.lease_heap(self.k);
        if let Some(tau) = self.within {
            heap.set_min(tau);
        }
        heap
    }

    /// Whether a subtree with certified upper bound `ub` is dead on the
    /// `KnnWithin` floor alone (plain kNN never prunes here).
    #[inline]
    pub fn dead_below_floor(&self, ub: f64) -> bool {
        self.within.is_some_and(|tau| ub < tau)
    }
}

/// Mode-resolved range traversal parameters (threshold + effective bound).
pub(crate) struct RangePlan {
    pub tau: f64,
    pub bound: BoundKind,
}

/// Dense index ordinals into the obs registry's per-index slots, in
/// `obs::INDEX_NAMES` order (`coordinator::IndexKind::ordinal` maps to the
/// same slots) — each index passes its own ordinal to the search frames so
/// `BoundKind::Auto` reads the right slack histograms.
pub(crate) const ORD_LINEAR: usize = 0;
pub(crate) const ORD_VP: usize = 1;
pub(crate) const ORD_BALL: usize = 2;
pub(crate) const ORD_MTREE: usize = 3;
pub(crate) const ORD_COVER: usize = 4;
pub(crate) const ORD_LAESA: usize = 5;
pub(crate) const ORD_GNAT: usize = 6;

/// Resolve the effective pruning bound once per query: `Auto` consults the
/// process-wide obs slack histograms for this index kind (ADR-009), with a
/// fixed Mult fallback while the histograms are cold; concrete kinds pass
/// through. The snapshot-per-query rule keeps a query's trace coherent;
/// results never depend on the choice because every family is exact.
#[inline]
pub(crate) fn resolve_bound(kind: BoundKind, index_ord: usize) -> BoundKind {
    if kind == BoundKind::Auto {
        crate::obs::OBS.select_bound(index_ord).unwrap_or(BoundKind::Mult)
    } else {
        kind
    }
}

/// The shared `search_into` frame (ADR-005): arm the plan on the context,
/// resolve the effective bound (including `Auto`, against `index_ord`'s
/// slack histograms), dispatch the mode to the index's two traversal
/// closures, then publish truncation/stats into the response and disarm.
/// One place — so no index implementation can forget to disarm an armed
/// filter or budget before the context serves the next query.
pub(crate) fn search_frame(
    req: &SearchRequest,
    ctx: &mut QueryContext,
    resp: &mut SearchResponse,
    default_bound: BoundKind,
    index_ord: usize,
    range: impl FnOnce(&RangePlan, &mut QueryContext, &mut Vec<(u32, f64)>),
    topk: impl FnOnce(&TopkPlan, &mut QueryContext, &mut Vec<(u32, f64)>),
) {
    ctx.apply_plan(req);
    let bound = resolve_bound(req.bound.unwrap_or(default_bound), index_ord);
    resp.hits.clear();
    resp.trace.clear();
    match req.mode {
        SearchMode::Range { tau } => range(&RangePlan { tau, bound }, ctx, &mut resp.hits),
        SearchMode::Knn { k } | SearchMode::KnnWithin { k, .. } => {
            topk(&TopkPlan { k, within: req.mode.tau(), bound }, ctx, &mut resp.hits)
        }
    }
    resp.truncated = ctx.truncated;
    resp.stats = ctx.stats;
    if ctx.trace_armed() {
        if ctx.truncated {
            ctx.trace_event(crate::obs::TraceEvent::budget_stop());
        }
        ctx.take_trace(&mut resp.trace);
    }
    ctx.clear_plan();
}

/// The shared `search_batch_into` frame (ADR-006): validate lengths,
/// route optioned plans to sequential per-query execution, and drive the
/// batchable chunks (at most [`MAX_BATCH`] queries each) through the
/// index's shared-frontier traversal — arming the leased [`BatchContext`]
/// before each chunk and publishing per-slot heaps/hits/stats into the
/// responses after. One place, so no index can forget to publish or to
/// release the arena.
///
/// A batch is admitted to the shared-frontier path when every request is
/// plain *except possibly a pruning-bound override they all agree on*: the
/// bound is batch-global traversal state, so a uniform override batches
/// exactly like the default. The agreed bound (else `default_bound`, the
/// index's build-time bound) is resolved once — including `Auto` — and
/// published on [`BatchContext::bound`] for every chunk, matching the
/// per-query frame's snapshot rule. Mixed-bound or otherwise-optioned
/// batches take the sequential fallback.
pub(crate) fn run_batch<V: SimVector>(
    queries: &[V],
    reqs: &[SearchRequest],
    ctx: &mut QueryContext,
    resps: &mut Vec<SearchResponse>,
    default_bound: BoundKind,
    index_ord: usize,
    fallback: &mut dyn FnMut(&V, &SearchRequest, &mut QueryContext, &mut SearchResponse),
    traverse: &mut dyn FnMut(&[V], &mut BatchContext, &mut QueryContext, &mut [SearchResponse]),
) {
    assert_eq!(queries.len(), reqs.len(), "batch queries/plans length mismatch");
    resps.resize_with(queries.len(), SearchResponse::default);
    if queries.is_empty() {
        return;
    }
    let uniform = reqs.iter().all(|r| r.is_plain_except_bound())
        && reqs.iter().all(|r| r.bound == reqs[0].bound);
    if !uniform {
        for ((q, req), resp) in queries.iter().zip(reqs).zip(resps.iter_mut()) {
            ctx.begin_query();
            fallback(q, req, ctx, resp);
        }
        return;
    }
    let bound = resolve_bound(reqs[0].bound.unwrap_or(default_bound), index_ord);
    let mut start = 0;
    while start < queries.len() {
        let end = (start + MAX_BATCH).min(queries.len());
        ctx.begin_query();
        let mut bc = ctx.lease_batch();
        bc.begin(&reqs[start..end]);
        bc.bound = bound;
        let chunk = &mut resps[start..end];
        for resp in chunk.iter_mut() {
            resp.hits.clear();
            resp.trace.clear();
            resp.truncated = false;
        }
        traverse(&queries[start..end], &mut bc, ctx, chunk);
        publish_batch(&mut bc, ctx, chunk);
        ctx.release_batch(bc);
        start = end;
    }
}

/// Publish one traversed chunk: drain each kNN slot's heap (already in
/// `(sim desc, id asc)` order) or sort each range slot's hits, copy the
/// per-slot stats window, and fold it into the context's window.
fn publish_batch(bc: &mut BatchContext, ctx: &mut QueryContext, resps: &mut [SearchResponse]) {
    for (j, resp) in resps.iter_mut().enumerate() {
        if bc.slots[j].range {
            sort_desc(&mut resp.hits);
        } else {
            bc.heaps[j].drain_into(&mut resp.hits);
        }
        resp.stats = bc.stats[j];
        ctx.stats.merge(&bc.stats[j]);
    }
}

/// Attribute one physical node visit to the entry's first live slot, so
/// the per-slot `nodes_visited` windows sum to the physical work done —
/// which is what makes "batched nodes_visited < q independent traversals"
/// measurable from response stats.
#[inline]
pub(crate) fn note_visit(bc: &mut BatchContext, mask: u64) {
    debug_assert!(mask != 0, "visiting a node with no live slots");
    bc.stats[mask.trailing_zeros() as usize].nodes_visited += 1;
}

/// Dispatch one directly-evaluated candidate (a vantage point, routing
/// object, or pivot the traversal scored through [`Corpus::sim_q`]) to
/// slot `j`'s collector, counting the exact evaluation in its window.
#[inline]
pub(crate) fn batch_offer(
    bc: &mut BatchContext,
    resps: &mut [SearchResponse],
    j: usize,
    id: u32,
    sim: f64,
) {
    bc.stats[j].sim_evals += 1;
    if bc.slots[j].range {
        if sim >= bc.slots[j].tau {
            resps[j].hits.push((id, sim));
        }
    } else {
        bc.heaps[j].offer(id, sim);
    }
}

/// One batched leaf/bucket visit (ADR-006): stage the live slots and
/// their certified floors, route the id list through the corpus's multi
/// kernel scan, and dispatch each delivered `(slot, id, sim)` to the
/// slot's collector — heap offer for kNN slots, threshold check + push
/// into the slot's response hits for range slots. Each delivery counts
/// one exact evaluation in that slot's stats window, matching what the
/// single-query scans report per query.
pub(crate) fn batch_scan_ids<C: Corpus>(
    corpus: &C,
    queries: &[C::Vector],
    bc: &mut BatchContext,
    mask: u64,
    ids: &[u32],
    resps: &mut [SearchResponse],
) {
    if mask == 0 || ids.is_empty() {
        return;
    }
    bc.stage_live(mask);
    let BatchContext { qb, heaps, stats, scratches, slots, live, floors, .. } = bc;
    corpus.scan_ids_multi_ctx(queries, qb, ids, live, floors, scratches, &mut |j, pos, sim| {
        stats[j].sim_evals += 1;
        let id = ids[pos];
        if slots[j].range {
            if sim >= slots[j].tau {
                resps[j].hits.push((id, sim));
            }
        } else {
            heaps[j].offer(id, sim);
        }
    });
}

/// [`batch_scan_ids`] over the whole corpus (the linear index's batch
/// path): a full scan's positions are the item ids.
pub(crate) fn batch_scan_all<C: Corpus>(
    corpus: &C,
    queries: &[C::Vector],
    bc: &mut BatchContext,
    mask: u64,
    resps: &mut [SearchResponse],
) {
    if mask == 0 || corpus.is_empty() {
        return;
    }
    bc.stage_live(mask);
    let BatchContext { qb, heaps, stats, scratches, slots, live, floors, .. } = bc;
    corpus.scan_all_multi_ctx(queries, qb, live, floors, scratches, &mut |j, pos, sim| {
        stats[j].sim_evals += 1;
        let id = pos as u32;
        if slots[j].range {
            if sim >= slots[j].tau {
                resps[j].hits.push((id, sim));
            }
        } else {
            heaps[j].offer(id, sim);
        }
    });
}

/// Sort a result set in descending similarity with deterministic tie order.
/// `(sim desc, id asc)` is a *total* order over entries with unique ids, so
/// the unstable sort (no allocation, unlike the stable merge sort) yields
/// exactly the same permutation a stable sort would — this keeps the
/// zero-allocation guarantee of the context query path.
pub(crate) fn sort_desc(results: &mut [(u32, f64)]) {
    results.sort_unstable_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knn_heap_keeps_best_k() {
        let mut h = KnnHeap::new(3);
        for (id, s) in [(0, 0.1), (1, 0.9), (2, 0.5), (3, 0.7), (4, 0.3)] {
            h.offer(id, s);
        }
        let out = h.into_sorted();
        assert_eq!(out.iter().map(|&(i, _)| i).collect::<Vec<_>>(), vec![1, 3, 2]);
    }

    #[test]
    fn knn_heap_floor_semantics() {
        let mut h = KnnHeap::new(2);
        assert_eq!(h.floor(), -1.0);
        h.offer(0, 0.5);
        assert_eq!(h.floor(), -1.0); // not full yet
        h.offer(1, 0.8);
        assert!((h.floor() - 0.5).abs() < 1e-15);
        h.offer(2, 0.6);
        assert!((h.floor() - 0.6).abs() < 1e-15);
    }

    #[test]
    fn knn_heap_ties_break_by_ascending_id_insertion_order_independent() {
        // Regression: the old predicate `(s == sim && true)` kept whichever
        // equal-similarity entry arrived first, making results depend on
        // traversal order. The heap must retain the top-k under
        // (sim desc, id asc) for every insertion order.
        let offers = [(5u32, 0.5f64), (1, 0.5), (3, 0.5), (2, 0.9), (4, 0.5)];
        let want = vec![(2u32, 0.9f64), (1, 0.5), (3, 0.5)];
        // All 120 permutations of the 5 offers.
        let mut perm = [0usize, 1, 2, 3, 4];
        let mut all = Vec::new();
        fn heap_result(offers: &[(u32, f64)], order: &[usize]) -> Vec<(u32, f64)> {
            let mut h = KnnHeap::new(3);
            for &i in order {
                let (id, s) = offers[i];
                h.offer(id, s);
            }
            h.into_sorted()
        }
        fn permute(
            k: usize,
            perm: &mut [usize; 5],
            all: &mut Vec<[usize; 5]>,
        ) {
            if k == perm.len() {
                all.push(*perm);
                return;
            }
            for i in k..perm.len() {
                perm.swap(k, i);
                permute(k + 1, perm, all);
                perm.swap(k, i);
            }
        }
        permute(0, &mut perm, &mut all);
        assert_eq!(all.len(), 120);
        for order in all {
            assert_eq!(heap_result(&offers, &order), want, "order {order:?}");
        }
    }

    #[test]
    fn knn_heap_floor_tie_still_displaces_larger_id() {
        let mut h = KnnHeap::new(2);
        h.offer(7, 0.4);
        h.offer(9, 0.4);
        // Equal to the floor but smaller id: must enter, evicting id 9.
        h.offer(2, 0.4);
        assert_eq!(h.into_sorted(), vec![(2, 0.4), (7, 0.4)]);
    }

    #[test]
    fn knn_heap_reset_and_drain_reuse_the_buffer() {
        let mut h = KnnHeap::new(3);
        for (id, s) in [(0u32, 0.1f64), (1, 0.9), (2, 0.5), (3, 0.7)] {
            h.offer(id, s);
        }
        let mut out = vec![(99u32, 0.0f64)]; // drain_into replaces nothing, appends
        out.clear();
        h.drain_into(&mut out);
        assert_eq!(out.iter().map(|&(i, _)| i).collect::<Vec<_>>(), vec![1, 3, 2]);
        assert!(h.is_empty());
        h.reset(2);
        assert_eq!(h.k(), 2);
        h.offer(7, 0.3);
        h.offer(8, 0.6);
        h.offer(9, 0.9);
        assert_eq!(h.into_sorted(), vec![(9, 0.9), (8, 0.6)]);
    }
}
