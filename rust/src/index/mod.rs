//! Similarity-native metric indexes.
//!
//! Each index answers exact range queries (`sim(q, y) >= tau`) and exact
//! kNN (max similarity) using the paper's triangle inequalities for
//! pruning — no conversion to distances anywhere on the query path. Every
//! index is parameterized by a [`BoundKind`] so the benchmark harness can
//! measure how bound tightness translates into pruning power (the paper's
//! motivating application, deferred there to future work).
//!
//! Exactness contract: for any corpus, query, `tau` and `k`, results equal
//! the linear scan's (up to ties in kNN) for **every** bound kind — looser
//! bounds may only cost extra similarity evaluations, never results. The
//! proptest suite in `integration_index_exactness.rs` enforces this.

pub mod balltree;
pub mod cover;
pub mod gnat;
pub mod laesa;
pub mod linear;
pub mod mtree;
pub mod vptree;

pub use balltree::BallTree;
pub use cover::CoverTree;
pub use gnat::Gnat;
pub use laesa::Laesa;
pub use linear::LinearScan;
pub use mtree::MTree;
pub use vptree::VpTree;

use crate::metrics::{DenseVec, SimVector};
use crate::storage::CorpusView;

/// What an index builds over: a collection of vectors addressed by dense
/// `u32` ids.
///
/// Two implementations exist. `Vec<V>` is the owning per-item path (the
/// only option for `SparseVec` corpora). [`CorpusView`] is the zero-copy
/// path: it aliases the shared [`crate::storage::CorpusStore`] buffer and
/// routes the id-list/full scans through the store's pluggable
/// [`crate::storage::KernelBackend`] (scalar / SIMD / i8-quantized,
/// ADR-003). Every backend returns scan results byte-identical to the
/// per-item path — exact backends bit-for-bit per similarity, the
/// quantized backend exact-after-re-rank — so indexes inherit whichever
/// backend their corpus carries without code changes here.
pub trait Corpus: Send + Sync + 'static {
    type Vector: SimVector;

    /// Number of items.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Vector-space dimension (0 for an empty corpus).
    fn dim(&self) -> usize;

    /// Exact similarity between an external query and item `id`.
    fn sim_q(&self, q: &Self::Vector, id: u32) -> f64;

    /// Exact similarity between two corpus items (build-time pivot math).
    fn sim_ij(&self, a: u32, b: u32) -> f64;

    /// Similarities of `q` to each of `ids`, replacing `out`'s contents in
    /// matching order.
    fn sims(&self, q: &Self::Vector, ids: &[u32], out: &mut Vec<f64>) {
        out.clear();
        out.extend(ids.iter().map(|&id| self.sim_q(q, id)));
    }

    /// Similarities of item `a` to every item, replacing `out`'s contents
    /// (LAESA table rows).
    fn sims_of_item(&self, a: u32, out: &mut Vec<f64>) {
        out.clear();
        out.extend((0..self.len() as u32).map(|b| self.sim_ij(a, b)));
    }

    /// Score `ids` against `q`, pushing every `(id, sim)` with `sim >= tau`.
    /// Returns the number of exact evaluations performed.
    fn scan_ids_range(
        &self,
        q: &Self::Vector,
        ids: &[u32],
        tau: f64,
        out: &mut Vec<(u32, f64)>,
    ) -> u64 {
        for &id in ids {
            let s = self.sim_q(q, id);
            if s >= tau {
                out.push((id, s));
            }
        }
        ids.len() as u64
    }

    /// Score `ids` against `q`, offering each into `heap`. Returns evals.
    fn scan_ids_topk(&self, q: &Self::Vector, ids: &[u32], heap: &mut KnnHeap) -> u64 {
        for &id in ids {
            heap.offer(id, self.sim_q(q, id));
        }
        ids.len() as u64
    }

    /// Score the whole corpus against `q` with a threshold. Returns evals.
    fn scan_all_range(&self, q: &Self::Vector, tau: f64, out: &mut Vec<(u32, f64)>) -> u64 {
        for id in 0..self.len() as u32 {
            let s = self.sim_q(q, id);
            if s >= tau {
                out.push((id, s));
            }
        }
        self.len() as u64
    }

    /// Score the whole corpus against `q` into a heap. Returns evals.
    fn scan_all_topk(&self, q: &Self::Vector, heap: &mut KnnHeap) -> u64 {
        for id in 0..self.len() as u32 {
            heap.offer(id, self.sim_q(q, id));
        }
        self.len() as u64
    }
}

/// The owning per-item corpus: works for any [`SimVector`], including
/// sparse vectors.
impl<V: SimVector> Corpus for Vec<V> {
    type Vector = V;

    fn len(&self) -> usize {
        self.as_slice().len()
    }

    fn dim(&self) -> usize {
        self.first().map(SimVector::dim).unwrap_or(0)
    }

    #[inline]
    fn sim_q(&self, q: &V, id: u32) -> f64 {
        q.sim(&self[id as usize])
    }

    #[inline]
    fn sim_ij(&self, a: u32, b: u32) -> f64 {
        self[a as usize].sim(&self[b as usize])
    }
}

/// The zero-copy corpus: aliases the shared store and scans through the
/// blocked batch kernels.
impl Corpus for CorpusView {
    type Vector = DenseVec;

    fn len(&self) -> usize {
        CorpusView::len(self)
    }

    fn dim(&self) -> usize {
        CorpusView::dim(self)
    }

    #[inline]
    fn sim_q(&self, q: &DenseVec, id: u32) -> f64 {
        crate::storage::dot_slice(q.as_slice(), self.row(id))
    }

    #[inline]
    fn sim_ij(&self, a: u32, b: u32) -> f64 {
        crate::storage::dot_slice(self.row(a), self.row(b))
    }

    fn sims(&self, q: &DenseVec, ids: &[u32], out: &mut Vec<f64>) {
        self.dot_batch(q.as_slice(), ids, out);
    }

    fn sims_of_item(&self, a: u32, out: &mut Vec<f64>) {
        out.clear();
        out.reserve(CorpusView::len(self));
        self.for_each_sim(self.row(a), |_, s| out.push(s));
    }

    fn scan_ids_range(
        &self,
        q: &DenseVec,
        ids: &[u32],
        tau: f64,
        out: &mut Vec<(u32, f64)>,
    ) -> u64 {
        CorpusView::scan_ids_range(self, q.as_slice(), ids, tau, out)
    }

    fn scan_ids_topk(&self, q: &DenseVec, ids: &[u32], heap: &mut KnnHeap) -> u64 {
        CorpusView::scan_ids_topk(self, q.as_slice(), ids, heap)
    }

    fn scan_all_range(&self, q: &DenseVec, tau: f64, out: &mut Vec<(u32, f64)>) -> u64 {
        CorpusView::scan_range(self, q.as_slice(), tau, out)
    }

    fn scan_all_topk(&self, q: &DenseVec, heap: &mut KnnHeap) -> u64 {
        CorpusView::scan_topk(self, q.as_slice(), heap)
    }
}

/// Query-time instrumentation: the paper's pruning-power currency is the
/// number of exact similarity computations avoided.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct QueryStats {
    /// Exact similarity evaluations performed.
    pub sim_evals: u64,
    /// Tree nodes (or pivot tables / regions) visited.
    pub nodes_visited: u64,
    /// Candidates discarded by a bound without an exact evaluation.
    pub pruned: u64,
}

impl QueryStats {
    pub fn merge(&mut self, other: &QueryStats) {
        self.sim_evals += other.sim_evals;
        self.nodes_visited += other.nodes_visited;
        self.pruned += other.pruned;
    }
}

/// An exact cosine-similarity search index.
pub trait SimilarityIndex<V: SimVector>: Send + Sync {
    /// Number of indexed items.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// All `(id, sim)` with `sim(q, item) >= tau`, in descending similarity.
    fn range(&self, q: &V, tau: f64, stats: &mut QueryStats) -> Vec<(u32, f64)>;

    /// The `k` most similar items, in descending similarity. Fewer than `k`
    /// are returned only when the corpus is smaller than `k`.
    fn knn(&self, q: &V, k: usize, stats: &mut QueryStats) -> Vec<(u32, f64)>;

    /// Index name for benchmark tables.
    fn name(&self) -> &'static str;
}

/// Bounded max-similarity result collector for kNN searches.
#[derive(Debug)]
pub struct KnnHeap {
    k: usize,
    /// Min-heap by similarity (worst current member on top), as a sorted
    /// Vec kept small: k is small in practice, so O(k) insert is fine and
    /// avoids float-ordering wrappers.
    entries: Vec<(u32, f64)>,
}

impl KnnHeap {
    pub fn new(k: usize) -> Self {
        KnnHeap { k: k.max(1), entries: Vec::with_capacity(k + 1) }
    }

    /// The `k` this heap retains (the backend pre-filters need it to
    /// compute a certified pruning floor).
    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// Current pruning floor: the k-th best similarity, or -1 (no pruning)
    /// while the heap is not full.
    #[inline]
    pub fn floor(&self) -> f64 {
        if self.entries.len() < self.k {
            -1.0
        } else {
            self.entries.last().map(|&(_, s)| s).unwrap_or(-1.0)
        }
    }

    /// Offer a candidate. Ties in similarity are broken by **ascending id**
    /// (matching [`sort_desc`]), so the retained set is the top-k under the
    /// total order `(sim desc, id asc)` regardless of insertion order —
    /// a candidate equal to the current floor still displaces a larger-id
    /// incumbent.
    #[inline]
    pub fn offer(&mut self, id: u32, sim: f64) {
        if self.entries.len() >= self.k && sim < self.floor() {
            return;
        }
        let pos = self
            .entries
            .partition_point(|&(eid, s)| s > sim || (s == sim && eid < id));
        self.entries.insert(pos, (id, sim));
        self.entries.truncate(self.k);
    }

    pub fn into_sorted(self) -> Vec<(u32, f64)> {
        self.entries
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Sort a result set in descending similarity with deterministic tie order.
pub(crate) fn sort_desc(results: &mut Vec<(u32, f64)>) {
    results.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
}

/// Max-priority entry for best-first tree searches: orders a node handle by
/// its similarity upper bound.
#[derive(Debug, Clone, Copy)]
pub(crate) struct Prioritized<T> {
    pub ub: f64,
    pub item: T,
}

impl<T> PartialEq for Prioritized<T> {
    fn eq(&self, other: &Self) -> bool {
        self.ub == other.ub
    }
}
impl<T> Eq for Prioritized<T> {}
impl<T> PartialOrd for Prioritized<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Prioritized<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.ub.partial_cmp(&other.ub).unwrap_or(std::cmp::Ordering::Equal)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knn_heap_keeps_best_k() {
        let mut h = KnnHeap::new(3);
        for (id, s) in [(0, 0.1), (1, 0.9), (2, 0.5), (3, 0.7), (4, 0.3)] {
            h.offer(id, s);
        }
        let out = h.into_sorted();
        assert_eq!(out.iter().map(|&(i, _)| i).collect::<Vec<_>>(), vec![1, 3, 2]);
    }

    #[test]
    fn knn_heap_floor_semantics() {
        let mut h = KnnHeap::new(2);
        assert_eq!(h.floor(), -1.0);
        h.offer(0, 0.5);
        assert_eq!(h.floor(), -1.0); // not full yet
        h.offer(1, 0.8);
        assert!((h.floor() - 0.5).abs() < 1e-15);
        h.offer(2, 0.6);
        assert!((h.floor() - 0.6).abs() < 1e-15);
    }

    #[test]
    fn knn_heap_ties_break_by_ascending_id_insertion_order_independent() {
        // Regression: the old predicate `(s == sim && true)` kept whichever
        // equal-similarity entry arrived first, making results depend on
        // traversal order. The heap must retain the top-k under
        // (sim desc, id asc) for every insertion order.
        let offers = [(5u32, 0.5f64), (1, 0.5), (3, 0.5), (2, 0.9), (4, 0.5)];
        let want = vec![(2u32, 0.9f64), (1, 0.5), (3, 0.5)];
        // All 120 permutations of the 5 offers.
        let mut perm = [0usize, 1, 2, 3, 4];
        let mut all = Vec::new();
        fn heap_result(offers: &[(u32, f64)], order: &[usize]) -> Vec<(u32, f64)> {
            let mut h = KnnHeap::new(3);
            for &i in order {
                let (id, s) = offers[i];
                h.offer(id, s);
            }
            h.into_sorted()
        }
        fn permute(
            k: usize,
            perm: &mut [usize; 5],
            all: &mut Vec<[usize; 5]>,
        ) {
            if k == perm.len() {
                all.push(*perm);
                return;
            }
            for i in k..perm.len() {
                perm.swap(k, i);
                permute(k + 1, perm, all);
                perm.swap(k, i);
            }
        }
        permute(0, &mut perm, &mut all);
        assert_eq!(all.len(), 120);
        for order in all {
            assert_eq!(heap_result(&offers, &order), want, "order {order:?}");
        }
    }

    #[test]
    fn knn_heap_floor_tie_still_displaces_larger_id() {
        let mut h = KnnHeap::new(2);
        h.offer(7, 0.4);
        h.offer(9, 0.4);
        // Equal to the floor but smaller id: must enter, evicting id 9.
        h.offer(2, 0.4);
        assert_eq!(h.into_sorted(), vec![(2, 0.4), (7, 0.4)]);
    }

    #[test]
    fn prioritized_orders_by_ub() {
        let mut heap = std::collections::BinaryHeap::new();
        heap.push(Prioritized { ub: 0.2, item: "a" });
        heap.push(Prioritized { ub: 0.9, item: "b" });
        heap.push(Prioritized { ub: 0.5, item: "c" });
        assert_eq!(heap.pop().unwrap().item, "b");
        assert_eq!(heap.pop().unwrap().item, "c");
    }
}
