//! M-tree (Ciaccia/Patella/Zezula 1997) in the similarity domain.
//!
//! Capacity-bounded balanced-ish tree of routing entries. Each entry stores
//! its routing object, the similarity "covering interval" of its subtree,
//! and the *exact similarity between the routing object and its parent's
//! routing object*. That last value enables the M-tree's signature saving:
//! before computing `sim(q, route)`, chain the known `sim(q, parent)` with
//! `sim(parent, route)` through Eqs. 10/13 to a certified interval on
//! `sim(q, route)`; if even the most optimistic value cannot clear the
//! threshold once widened by the covering interval, the whole entry is
//! dropped with **zero** similarity evaluations.
//!
//! Per-entry pre-checks make leaf scans data-dependent, so this index keeps
//! per-item scoring (through [`Corpus::sim_q`], zero-copy rows when built
//! on a view) rather than the blocked bucket kernels.

use crate::bounds::{BoundKind, PairRefs, SimInterval};
use crate::query::{BatchContext, Frontier, QueryContext, SearchRequest, SearchResponse};

use super::{sort_desc, Corpus, RangePlan, SimilarityIndex, TopkPlan};

struct Entry {
    /// Routing object (internal) or data item (leaf).
    id: u32,
    /// sim(id, parent routing object); 1.0 at the root (no parent).
    parent_sim: f64,
    /// Covering interval: similarities of all subtree items to `id`.
    /// `None` for leaf entries (the entry is the item itself).
    cover: Option<SimInterval>,
    /// Similarities of all subtree items to the *parent's* routing object —
    /// the second over-box for the Ptolemaic descend refinement (ADR-009).
    /// `None` at the root level and on leaf entries.
    parent_cover: Option<SimInterval>,
    child: Option<Box<NodeBody>>,
}

struct NodeBody {
    entries: Vec<Entry>,
    is_leaf: bool,
}

/// Similarity-native M-tree.
pub struct MTree<C: Corpus> {
    corpus: C,
    root: Option<NodeBody>,
    bound: BoundKind,
    capacity: usize,
}

impl<C: Corpus> MTree<C> {
    /// Bulk-load an M-tree with node capacity `capacity` (>= 4 recommended).
    pub fn build(corpus: C, bound: BoundKind, capacity: usize) -> Self {
        let capacity = capacity.max(2);
        let ids: Vec<u32> = (0..corpus.len() as u32).collect();
        let root = if ids.is_empty() {
            None
        } else {
            Some(Self::bulk_load(&corpus, ids, capacity, None))
        };
        MTree { corpus, root, bound, capacity }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Recursive bulk load: pick `capacity` routing objects (spread by a
    /// farthest-first pass), assign items to the most similar route, recurse.
    fn bulk_load(corpus: &C, ids: Vec<u32>, capacity: usize, parent: Option<u32>) -> NodeBody {
        let parent_sim = |id: u32| -> f64 {
            match parent {
                Some(p) => corpus.sim_ij(p, id),
                None => 1.0,
            }
        };

        if ids.len() <= capacity {
            let entries = ids
                .into_iter()
                .map(|id| Entry {
                    id,
                    parent_sim: parent_sim(id),
                    cover: None,
                    parent_cover: None,
                    child: None,
                })
                .collect();
            return NodeBody { entries, is_leaf: true };
        }

        // Choose routing objects: farthest-first (min-max-similarity).
        let mut routes: Vec<u32> = vec![ids[0]];
        let mut max_sim: Vec<f64> = ids.iter().map(|&i| corpus.sim_ij(ids[0], i)).collect();
        while routes.len() < capacity {
            let (pos, _) = max_sim
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            let r = ids[pos];
            if routes.contains(&r) {
                break;
            }
            routes.push(r);
            for (j, &i) in ids.iter().enumerate() {
                max_sim[j] = max_sim[j].max(corpus.sim_ij(r, i));
            }
        }

        if routes.len() < 2 {
            // Degenerate data (e.g. all-identical points): an oversized leaf
            // is correct and terminates the recursion.
            let entries = ids
                .into_iter()
                .map(|id| Entry {
                    id,
                    parent_sim: parent_sim(id),
                    cover: None,
                    parent_cover: None,
                    child: None,
                })
                .collect();
            return NodeBody { entries, is_leaf: true };
        }

        // Assign every id to its most similar route.
        let mut groups: Vec<Vec<u32>> = vec![Vec::new(); routes.len()];
        for &i in &ids {
            if routes.contains(&i) {
                continue;
            }
            let (g, _) = routes
                .iter()
                .enumerate()
                .map(|(g, &r)| (g, corpus.sim_ij(r, i)))
                .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap();
            groups[g].push(i);
        }

        let entries = routes
            .iter()
            .zip(groups)
            .map(|(&r, mut group)| {
                // The route itself lives inside its subtree.
                group.push(r);
                let mut cover: Option<SimInterval> = None;
                for &i in &group {
                    let s = corpus.sim_ij(r, i);
                    match &mut cover {
                        Some(c) => c.extend(s),
                        None => cover = Some(SimInterval::point(s)),
                    }
                }
                // The parent route's similarity cover over the same subtree:
                // the (parent, route) pivot pair then bounds every member by
                // Ptolemy at query time, for free at descend.
                let parent_cover = parent.map(|p| {
                    let mut pc = SimInterval::point(corpus.sim_ij(p, group[0]));
                    for &i in &group[1..] {
                        pc.extend(corpus.sim_ij(p, i));
                    }
                    pc
                });
                let child = Self::bulk_load(corpus, group, capacity, Some(r));
                Entry {
                    id: r,
                    parent_sim: parent_sim(r),
                    cover,
                    parent_cover,
                    child: Some(Box::new(child)),
                }
            })
            .collect();
        NodeBody { entries, is_leaf: false }
    }

    /// Certified reach of an entry's subtree: upper bound on `sim(q, y)`
    /// over every subtree member `y`, from the parent-chain interval on
    /// `sim(q, route)` alone — no similarity evaluation.
    fn entry_reach(bound: BoundKind, parent_s: f64, entry: &Entry) -> f64 {
        let route_iv = bound.interval(parent_s, entry.parent_sim);
        match entry.cover {
            Some(cover) => {
                if !route_iv.intersect(&cover).is_empty() {
                    1.0
                } else {
                    bound
                        .upper_over(route_iv.lo, cover)
                        .max(bound.upper_over(route_iv.hi, cover))
                }
            }
            None => route_iv.hi,
        }
    }

    /// Ptolemaic refinement of an internal entry's descend bound (ADR-009):
    /// the parent route `u` and the entry's route `v` form a pivot pair with
    /// exact `sim(q,u) = parent_s`, `sim(q,v) = s`, `sim(u,v) = parent_sim`;
    /// the subtree's similarity covers to each are the over-boxes. Returns
    /// 1.0 (vacuous) when no parent cover was recorded (root level).
    #[inline]
    fn ptolemaic_child_ub(
        bound: BoundKind,
        parent_s: f64,
        s: f64,
        entry: &Entry,
        cover: SimInterval,
    ) -> f64 {
        let Some(parent_cover) = entry.parent_cover else { return 1.0 };
        let refs = PairRefs::new(parent_s, s, entry.parent_sim);
        if bound == BoundKind::PtolemaicFast {
            refs.upper_over_fast(parent_cover, cover)
        } else {
            refs.upper_over(parent_cover, cover)
        }
    }

    /// Range search over a node; `parent_s` = sim(q, parent route), or None
    /// at the root (parent_sim fields are then vacuous 1.0 and the cheap
    /// pre-check is skipped).
    fn range_rec(
        &self,
        node: &NodeBody,
        q: &C::Vector,
        parent_s: Option<f64>,
        plan: &RangePlan,
        out: &mut Vec<(u32, f64)>,
        ctx: &mut QueryContext,
    ) {
        if ctx.budget_exhausted() {
            ctx.truncated = true;
            return;
        }
        ctx.stats.nodes_visited += 1;
        ctx.trace_visit(node.entries.first().map_or(0, |e| e.id) as u64);
        for entry in &node.entries {
            // Denied leaf entries are the data items themselves: skip them
            // before any exact evaluation. (Internal routes still need
            // their similarity for pruning, whatever the filter says.)
            if node.is_leaf && !ctx.admits(entry.id) {
                continue;
            }
            // Cheap pre-check (no sim eval): certified interval on
            // sim(q, entry.id) via the parent chain, widened over the
            // covering interval: can anything in the subtree reach tau?
            let reach = parent_s.map(|ps| Self::entry_reach(plan.bound, ps, entry));
            if let Some(r) = reach {
                if r < plan.tau {
                    ctx.stats.pruned += 1;
                    ctx.trace_prune(entry.id as u64, r);
                    continue; // dropped without computing sim(q, route)
                }
            }
            let s = self.corpus.sim_q(q, entry.id);
            ctx.stats.sim_evals += 1;
            match reach {
                Some(r) => ctx.note_eval_slack(plan.bound, entry.id as u64, r, s),
                None => ctx.trace_eval(entry.id as u64, 1.0, s),
            }
            if node.is_leaf {
                if s >= plan.tau {
                    out.push((entry.id, s));
                }
                continue;
            }
            // Internal entry: the route itself is reported by its subtree
            // (routes are members of their own subtrees).
            let Some(cover) = entry.cover else { continue };
            let mut ub = plan.bound.upper_over(s, cover);
            if plan.bound.is_ptolemaic() {
                if let Some(ps) = parent_s {
                    ub = ub.min(Self::ptolemaic_child_ub(plan.bound, ps, s, entry, cover));
                }
            }
            if ub >= plan.tau {
                self.range_rec(entry.child.as_ref().unwrap(), q, Some(s), plan, out, ctx);
            } else {
                ctx.stats.pruned += 1;
                ctx.trace_prune(entry.id as u64, ub);
            }
        }
    }

    fn topk_into(
        &self,
        q: &C::Vector,
        plan: &TopkPlan,
        ctx: &mut QueryContext,
        out: &mut Vec<(u32, f64)>,
    ) {
        let mut results = plan.lease_heap(ctx);
        // Frontier carries (node, sim(q, parent route)); NAN at the root.
        let mut frontier: Frontier<'_, NodeBody> = ctx.lease_frontier();
        if let Some(root) = &self.root {
            frontier.push(1.0, root, f64::NAN);
        }
        while let Some((ub, node, parent_s)) = frontier.pop() {
            if results.len() >= plan.k && ub <= results.floor() {
                break;
            }
            if plan.dead_below_floor(ub) {
                break;
            }
            if ctx.budget_exhausted() {
                ctx.truncated = true;
                break;
            }
            ctx.stats.nodes_visited += 1;
            ctx.trace_visit(node.entries.first().map_or(0, |e| e.id) as u64);
            for entry in &node.entries {
                if node.is_leaf && !ctx.admits(entry.id) {
                    continue; // denied data item: no exact evaluation
                }
                // Cheap pre-check against the current floor (the M-tree's
                // saved similarity computation); with a KnnWithin floor it
                // also fires while the heap is not yet full.
                if !parent_s.is_nan() && (results.len() >= plan.k || plan.within.is_some()) {
                    let reach = Self::entry_reach(plan.bound, parent_s, entry);
                    let dead = if results.len() >= plan.k {
                        reach <= results.floor()
                    } else {
                        plan.dead_below_floor(reach)
                    };
                    if dead {
                        ctx.stats.pruned += 1;
                        ctx.trace_prune(entry.id as u64, reach);
                        continue;
                    }
                }
                let s = self.corpus.sim_q(q, entry.id);
                ctx.stats.sim_evals += 1;
                ctx.note_eval_slack(plan.bound, entry.id as u64, ub, s);
                if node.is_leaf {
                    results.offer(entry.id, s);
                } else {
                    // Routes are members of their own subtrees; the leaf
                    // level reports them (avoids duplicate result entries).
                    if let Some(cover) = entry.cover {
                        let mut child_ub = plan.bound.upper_over(s, cover);
                        if plan.bound.is_ptolemaic() && !parent_s.is_nan() {
                            child_ub = child_ub
                                .min(Self::ptolemaic_child_ub(plan.bound, parent_s, s, entry, cover));
                        }
                        if !plan.dead_below_floor(child_ub)
                            && (results.len() < plan.k || child_ub > results.floor())
                        {
                            frontier.push(child_ub, entry.child.as_ref().unwrap(), s);
                        } else {
                            ctx.stats.pruned += 1;
                            ctx.trace_prune(entry.id as u64, child_ub);
                        }
                    }
                }
            }
        }
        out.clear();
        results.drain_into(out);
        ctx.release_heap(results);
        ctx.release_frontier(frontier);
    }

    /// ADR-006 multi-query descent: entry-order recursion (the parent
    /// route's per-slot similarities stay in scope for the parent-chain
    /// pre-check), with each leaf scored for every live slot in one
    /// multi-query kernel call.
    // Zero-alloc recursion: the batch state rides as parameters instead of
    // a heap-built context struct (ADR-004).
    #[allow(clippy::too_many_arguments)]
    fn batch_rec(
        &self,
        node: &NodeBody,
        queries: &[C::Vector],
        mask: u64,
        parent_sims: Option<&[f64]>,
        bc: &mut BatchContext,
        ctx: &mut QueryContext,
        resps: &mut [SearchResponse],
    ) {
        super::note_visit(bc, mask);
        if node.is_leaf {
            let mut ids = ctx.lease_ids();
            ids.extend(node.entries.iter().map(|e| e.id));
            super::batch_scan_ids(&self.corpus, queries, bc, mask, &ids, resps);
            ctx.release_ids(ids);
            return;
        }
        let nslots = bc.len();
        let mut sims = ctx.lease_sims();
        sims.resize(nslots, 0.0);
        for entry in &node.entries {
            let Some(cover) = entry.cover else { continue };
            let mut child_mask = 0u64;
            let mut m = mask;
            while m != 0 {
                let j = m.trailing_zeros() as usize;
                m &= m - 1;
                // The M-tree's saved evaluation, per slot: the parent
                // chain can certify the subtree dead for this slot before
                // sim(q_j, route) is ever computed.
                if let Some(ps) = parent_sims {
                    let reach = Self::entry_reach(bc.bound, ps[j], entry);
                    if !bc.slot_alive(j, reach) {
                        bc.stats[j].pruned += 1;
                        continue;
                    }
                }
                let s = self.corpus.sim_q(&queries[j], entry.id);
                bc.stats[j].sim_evals += 1;
                sims[j] = s;
                let mut ub = bc.bound.upper_over(s, cover);
                if bc.bound.is_ptolemaic() {
                    if let Some(ps) = parent_sims {
                        ub = ub.min(Self::ptolemaic_child_ub(bc.bound, ps[j], s, entry, cover));
                    }
                }
                if bc.slot_alive(j, ub) {
                    child_mask |= 1 << j;
                } else {
                    bc.stats[j].pruned += 1;
                }
            }
            if child_mask != 0 {
                // Recurse immediately, so `sims` is this entry's route
                // similarities for the whole subtree walk.
                self.batch_rec(
                    entry.child.as_ref().unwrap(),
                    queries,
                    child_mask,
                    Some(&sims),
                    bc,
                    ctx,
                    resps,
                );
            }
        }
        ctx.release_sims(sims);
    }

    fn traverse_batch(
        &self,
        queries: &[C::Vector],
        bc: &mut BatchContext,
        ctx: &mut QueryContext,
        resps: &mut [SearchResponse],
    ) {
        let Some(root) = &self.root else { return };
        self.corpus.stage_queries(queries, &mut bc.qb);
        self.batch_rec(root, queries, bc.full_mask(), None, bc, ctx, resps);
    }
}

impl<C: Corpus> SimilarityIndex<C::Vector> for MTree<C> {
    fn len(&self) -> usize {
        self.corpus.len()
    }

    fn search_into(
        &self,
        q: &C::Vector,
        req: &SearchRequest,
        ctx: &mut QueryContext,
        resp: &mut SearchResponse,
    ) {
        super::search_frame(
            req,
            ctx,
            resp,
            self.bound,
            super::ORD_MTREE,
            |plan, ctx, out| {
                if let Some(root) = &self.root {
                    self.range_rec(root, q, None, plan, out, ctx);
                }
                sort_desc(out);
            },
            |plan, ctx, out| self.topk_into(q, plan, ctx, out),
        );
    }

    fn search_batch_into(
        &self,
        queries: &[C::Vector],
        reqs: &[SearchRequest],
        ctx: &mut QueryContext,
        resps: &mut Vec<SearchResponse>,
    ) {
        super::run_batch(
            queries,
            reqs,
            ctx,
            resps,
            self.bound,
            super::ORD_MTREE,
            &mut |q, req, ctx, resp| self.search_into(q, req, ctx, resp),
            &mut |qs, bc, ctx, chunk| self.traverse_batch(qs, bc, ctx, chunk),
        );
    }

    fn name(&self) -> &'static str {
        "m-tree"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{uniform_sphere, vmf_mixture, VmfSpec};
    use crate::index::{LinearScan, QueryStats};

    #[test]
    fn matches_linear_scan() {
        let pts = uniform_sphere(500, 8, 51);
        let tree = MTree::build(pts.clone(), BoundKind::Mult, 8);
        let lin = LinearScan::build(pts.clone());
        let mut s1 = QueryStats::default();
        let mut s2 = QueryStats::default();
        for qi in [0usize, 123, 499] {
            for tau in [0.85, 0.4, -0.2] {
                assert_eq!(
                    tree.range(&pts[qi], tau, &mut s1),
                    lin.range(&pts[qi], tau, &mut s2),
                    "tau={tau} qi={qi}"
                );
            }
            let a = tree.knn(&pts[qi], 9, &mut s1);
            let b = lin.knn(&pts[qi], 9, &mut s2);
            for ((_, x), (_, y)) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn matches_linear_with_every_bound_kind() {
        let pts = uniform_sphere(150, 6, 52);
        let lin = LinearScan::build(pts.clone());
        for bound in BoundKind::ALL {
            let tree = MTree::build(pts.clone(), bound, 6);
            let mut s1 = QueryStats::default();
            let mut s2 = QueryStats::default();
            for qi in [1usize, 75] {
                assert_eq!(
                    tree.range(&pts[qi], 0.5, &mut s1),
                    lin.range(&pts[qi], 0.5, &mut s2),
                    "bound={bound:?}"
                );
            }
        }
    }

    /// Walk the tree collecting each entry's subtree members, asserting
    /// `parent_cover` really covers sim(parent, member) for every member.
    /// Returns the member ids of `node` (for the caller's own check).
    fn check_parent_covers<C: Corpus>(
        corpus: &C,
        node: &NodeBody,
        parent: Option<u32>,
    ) -> Vec<u32> {
        let mut all = Vec::new();
        for e in &node.entries {
            match &e.child {
                Some(child) => {
                    let members = check_parent_covers(corpus, child, Some(e.id));
                    match (parent, e.parent_cover) {
                        (Some(p), Some(pc)) => {
                            for &m in &members {
                                let s = corpus.sim_ij(p, m);
                                assert!(
                                    pc.lo <= s && s <= pc.hi,
                                    "entry {}: sim({p},{m})={s} outside {pc:?}",
                                    e.id
                                );
                            }
                        }
                        (Some(_), None) => panic!("internal entry {} lacks parent_cover", e.id),
                        (None, Some(_)) => panic!("root-level entry {} has parent_cover", e.id),
                        (None, None) => {}
                    }
                    all.extend(members);
                }
                None => all.push(e.id),
            }
        }
        all
    }

    #[test]
    fn parent_covers_contain_subtree_sims() {
        let (pts, _) =
            vmf_mixture(&VmfSpec { n: 800, dim: 8, clusters: 8, kappa: 60.0, seed: 10 });
        let tree = MTree::build(pts.clone(), BoundKind::Ptolemaic, 6);
        let root = tree.root.as_ref().unwrap();
        let members = check_parent_covers(&tree.corpus, root, None);
        assert_eq!(members.len(), pts.len());
    }

    #[test]
    fn ptolemaic_descend_matches_linear_on_clusters() {
        let (pts, _) =
            vmf_mixture(&VmfSpec { n: 1200, dim: 8, clusters: 12, kappa: 80.0, seed: 9 });
        let lin = LinearScan::build(pts.clone());
        let mut s1 = QueryStats::default();
        let mut s2 = QueryStats::default();
        for bound in [BoundKind::Ptolemaic, BoundKind::PtolemaicFast] {
            let tree = MTree::build(pts.clone(), bound, 8);
            for qi in [0usize, 600, 1199] {
                for tau in [0.9, 0.5] {
                    assert_eq!(
                        tree.range(&pts[qi], tau, &mut s1),
                        lin.range(&pts[qi], tau, &mut s2),
                        "{bound:?} tau={tau} qi={qi}"
                    );
                }
                let a = tree.knn(&pts[qi], 9, &mut s1);
                let b = lin.knn(&pts[qi], 9, &mut s2);
                for ((_, x), (_, y)) in a.iter().zip(&b) {
                    assert!((x - y).abs() < 1e-12, "{bound:?} knn qi={qi}");
                }
            }
        }
    }

    #[test]
    fn parent_chain_saves_evaluations_on_clusters() {
        let (pts, _) = vmf_mixture(&VmfSpec { n: 4000, dim: 16, clusters: 40, kappa: 120.0, seed: 8 });
        let tree = MTree::build(pts.clone(), BoundKind::Mult, 16);
        let mut st = QueryStats::default();
        tree.range(&pts[42], 0.9, &mut st);
        assert!(st.sim_evals < 4000 / 2, "{} evals", st.sim_evals);
        assert!(st.pruned > 0);
    }
}
