//! Vantage-point tree in the similarity domain.
//!
//! Classic VP-tree (Uhlmann 1991 / Yianilos 1993), with every distance
//! replaced by a similarity and every pruning test by the paper's triangle
//! inequality: a child subtree whose members' similarity to the vantage
//! point lies in `[lo, hi]` can only contain matches if
//! `upper_over(sim(q, vp), [lo, hi]) >= tau` (range) or `> floor` (kNN).
//!
//! Built over any [`Corpus`]: a `Vec<V>` (owning, per-item scoring) or a
//! zero-copy [`crate::storage::CorpusView`], in which case leaf buckets are
//! scored through the blocked batch kernels.

use crate::bounds::{BoundKind, SimInterval};
use crate::query::{BatchContext, Frontier, QueryContext, SearchRequest, SearchResponse};

use super::{sort_desc, Corpus, RangePlan, SimilarityIndex, TopkPlan};

struct Node {
    /// Vantage point (item id).
    vp: u32,
    /// Children: `near` holds items with `sim(vp, x) >= mu` (the similar
    /// half), `far` the rest; each with the exact similarity interval of
    /// its members to `vp`.
    near: Option<(SimInterval, Box<Node>)>,
    far: Option<(SimInterval, Box<Node>)>,
    /// Leaf payload: item ids (only for leaves; vp is still queried).
    bucket: Vec<u32>,
}

/// Similarity-native vantage-point tree.
pub struct VpTree<C: Corpus> {
    corpus: C,
    root: Option<Node>,
    bound: BoundKind,
    leaf_size: usize,
}

impl<C: Corpus> VpTree<C> {
    /// Build with the given pruning bound; `leaf_size` trades tree depth for
    /// scan width (8–32 is typical).
    pub fn build(corpus: C, bound: BoundKind, seed: u64) -> Self {
        Self::with_leaf_size(corpus, bound, seed, 16)
    }

    pub fn with_leaf_size(corpus: C, bound: BoundKind, seed: u64, leaf_size: usize) -> Self {
        let mut ids: Vec<u32> = (0..corpus.len() as u32).collect();
        let mut rng = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).max(1);
        let root = if ids.is_empty() {
            None
        } else {
            Some(Self::build_node(&corpus, &mut ids, leaf_size.max(1), &mut rng))
        };
        VpTree { corpus, root, bound, leaf_size: leaf_size.max(1) }
    }

    fn next_rand(rng: &mut u64) -> u64 {
        // xorshift64*: deterministic, dependency-free pivot selection.
        let mut x = *rng;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        *rng = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    fn build_node(corpus: &C, ids: &mut [u32], leaf_size: usize, rng: &mut u64) -> Node {
        // Random vantage point; swap it to the front.
        let pick = (Self::next_rand(rng) % ids.len() as u64) as usize;
        ids.swap(0, pick);
        let vp = ids[0];
        let rest = &mut ids[1..];

        if rest.len() <= leaf_size {
            return Node { vp, near: None, far: None, bucket: rest.to_vec() };
        }

        // Split at the median similarity to the vantage point.
        let mut sims: Vec<(u32, f64)> =
            rest.iter().map(|&id| (id, corpus.sim_ij(vp, id))).collect();
        // lint: stable-sort — build path; similarity ties must keep id
        // order so tree construction is deterministic across runs.
        sims.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let mid = sims.len() / 2;

        let (near_slice, far_slice) = sims.split_at(mid);
        let make = |slice: &[(u32, f64)], rng: &mut u64| -> Option<(SimInterval, Box<Node>)> {
            if slice.is_empty() {
                return None;
            }
            let mut iv = SimInterval::point(slice[0].1);
            for &(_, s) in slice {
                iv.extend(s);
            }
            let mut child_ids: Vec<u32> = slice.iter().map(|&(id, _)| id).collect();
            Some((iv, Box::new(Self::build_node(corpus, &mut child_ids, leaf_size, rng))))
        };
        let near = make(near_slice, rng);
        let far = make(far_slice, rng);
        Node { vp, near, far, bucket: Vec::new() }
    }

    pub fn bound(&self) -> BoundKind {
        self.bound
    }

    pub fn leaf_size(&self) -> usize {
        self.leaf_size
    }

    fn range_node(
        &self,
        node: &Node,
        q: &C::Vector,
        plan: &RangePlan,
        out: &mut Vec<(u32, f64)>,
        ctx: &mut QueryContext,
    ) {
        if ctx.budget_exhausted() {
            ctx.truncated = true;
            return;
        }
        ctx.stats.nodes_visited += 1;
        ctx.trace_visit(node.vp as u64);
        let s = self.corpus.sim_q(q, node.vp);
        ctx.stats.sim_evals += 1;
        ctx.trace_eval(node.vp as u64, 1.0, s);
        if s >= plan.tau && ctx.admits(node.vp) {
            out.push((node.vp, s));
        }
        let n =
            self.corpus.scan_ids_range_ctx(q, &node.bucket, plan.tau, out, ctx.kernel_scratch());
        ctx.stats.sim_evals += n;
        for child in [&node.near, &node.far].into_iter().flatten() {
            let (iv, sub) = child;
            let ub = plan.bound.upper_over(s, *iv);
            if ub >= plan.tau {
                self.range_node(sub, q, plan, out, ctx);
            } else {
                ctx.stats.pruned += 1;
                ctx.trace_prune(sub.vp as u64, ub);
            }
        }
    }

    fn topk_into(
        &self,
        q: &C::Vector,
        plan: &TopkPlan,
        ctx: &mut QueryContext,
        out: &mut Vec<(u32, f64)>,
    ) {
        let mut results = plan.lease_heap(ctx);
        let mut frontier: Frontier<'_, Node> = ctx.lease_frontier();
        if let Some(root) = &self.root {
            frontier.push(1.0, root, 0.0);
        }
        while let Some((ub, node, _)) = frontier.pop() {
            if results.len() >= plan.k && ub <= results.floor() {
                break; // no remaining node can improve the result set
            }
            if plan.dead_below_floor(ub) {
                break; // best-first: everything remaining is below tau too
            }
            if ctx.budget_exhausted() {
                ctx.truncated = true;
                break;
            }
            ctx.stats.nodes_visited += 1;
            ctx.trace_visit(node.vp as u64);
            let s = self.corpus.sim_q(q, node.vp);
            ctx.stats.sim_evals += 1;
            ctx.note_eval_slack(plan.bound, node.vp as u64, ub, s);
            if ctx.admits(node.vp) {
                results.offer(node.vp, s);
            }
            let evals =
                self.corpus.scan_ids_topk_ctx(q, &node.bucket, &mut results, ctx.kernel_scratch());
            ctx.stats.sim_evals += evals;
            for child in [&node.near, &node.far].into_iter().flatten() {
                let (iv, sub) = child;
                let child_ub = plan.bound.upper_over(s, *iv);
                if !plan.dead_below_floor(child_ub)
                    && (results.len() < plan.k || child_ub > results.floor())
                {
                    frontier.push(child_ub, sub.as_ref(), 0.0);
                } else {
                    ctx.stats.pruned += 1;
                    ctx.trace_prune(sub.vp as u64, child_ub);
                }
            }
        }
        out.clear();
        results.drain_into(out);
        ctx.release_heap(results);
        ctx.release_frontier(frontier);
    }

    /// Shared-frontier multi-query descent (ADR-006): the whole batch
    /// walks the tree once behind one best-first frontier whose entries
    /// carry a live-slot bitmask in the auxiliary float. A node is visited
    /// only while at least one slot's bound admits it; slots retire from
    /// an entry between push and pop as their heaps tighten; every bucket
    /// visit is one (query-block × row-block) multi-kernel call.
    fn traverse_batch(
        &self,
        queries: &[C::Vector],
        bc: &mut BatchContext,
        ctx: &mut QueryContext,
        resps: &mut [SearchResponse],
    ) {
        let Some(root) = &self.root else { return };
        self.corpus.stage_queries(queries, &mut bc.qb);
        let mut frontier: Frontier<'_, Node> = ctx.lease_frontier();
        frontier.push(1.0, root, f64::from_bits(bc.full_mask()));
        let mut sims = ctx.lease_sims();
        sims.resize(bc.len(), 0.0);
        while let Some((ub, node, aux)) = frontier.pop() {
            if !bc.any_alive(ub) {
                break; // best-first: no remaining entry can serve any slot
            }
            let mask = bc.refine(aux.to_bits(), ub);
            if mask == 0 {
                continue; // this entry's slots retired; other entries may live
            }
            super::note_visit(bc, mask);
            let mut m = mask;
            while m != 0 {
                let j = m.trailing_zeros() as usize;
                m &= m - 1;
                let s = self.corpus.sim_q(&queries[j], node.vp);
                sims[j] = s;
                super::batch_offer(bc, resps, j, node.vp, s);
            }
            super::batch_scan_ids(&self.corpus, queries, bc, mask, &node.bucket, resps);
            for child in [&node.near, &node.far].into_iter().flatten() {
                let (iv, sub) = child;
                let mut child_mask = 0u64;
                let mut child_ub = f64::NEG_INFINITY;
                let mut m = mask;
                while m != 0 {
                    let j = m.trailing_zeros() as usize;
                    m &= m - 1;
                    let ub_j = bc.bound.upper_over(sims[j], *iv);
                    if bc.slot_alive(j, ub_j) {
                        child_mask |= 1 << j;
                        child_ub = child_ub.max(ub_j);
                    } else {
                        bc.stats[j].pruned += 1;
                    }
                }
                if child_mask != 0 {
                    frontier.push(child_ub, sub.as_ref(), f64::from_bits(child_mask));
                }
            }
        }
        ctx.release_sims(sims);
        ctx.release_frontier(frontier);
    }
}

impl<C: Corpus> SimilarityIndex<C::Vector> for VpTree<C> {
    fn len(&self) -> usize {
        self.corpus.len()
    }

    fn search_into(
        &self,
        q: &C::Vector,
        req: &SearchRequest,
        ctx: &mut QueryContext,
        resp: &mut SearchResponse,
    ) {
        super::search_frame(
            req,
            ctx,
            resp,
            self.bound,
            super::ORD_VP,
            |plan, ctx, out| {
                if let Some(root) = &self.root {
                    self.range_node(root, q, plan, out, ctx);
                }
                sort_desc(out);
            },
            |plan, ctx, out| self.topk_into(q, plan, ctx, out),
        );
    }

    fn search_batch_into(
        &self,
        queries: &[C::Vector],
        reqs: &[SearchRequest],
        ctx: &mut QueryContext,
        resps: &mut Vec<SearchResponse>,
    ) {
        super::run_batch(
            queries,
            reqs,
            ctx,
            resps,
            self.bound,
            super::ORD_VP,
            &mut |q, req, ctx, resp| self.search_into(q, req, ctx, resp),
            &mut |qs, bc, ctx, chunk| self.traverse_batch(qs, bc, ctx, chunk),
        );
    }

    fn name(&self) -> &'static str {
        "vp-tree"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::uniform_sphere;
    use crate::index::{LinearScan, QueryStats};
    use crate::metrics::DenseVec;

    fn check_matches_linear(n: usize, d: usize, seed: u64, bound: BoundKind) {
        let pts = uniform_sphere(n, d, seed);
        let tree = VpTree::build(pts.clone(), bound, seed);
        let lin = LinearScan::build(pts.clone());
        for qi in 0..5.min(n) {
            let q = &pts[qi * (n / 5).max(1) % n];
            let mut s1 = QueryStats::default();
            let mut s2 = QueryStats::default();
            for tau in [0.9, 0.5, 0.0] {
                let a = tree.range(q, tau, &mut s1);
                let b = lin.range(q, tau, &mut s2);
                assert_eq!(a, b, "range tau={tau} bound={:?}", bound);
            }
            let a = tree.knn(q, 10, &mut s1);
            let b = lin.knn(q, 10, &mut s2);
            let av: Vec<f64> = a.iter().map(|&(_, s)| s).collect();
            let bv: Vec<f64> = b.iter().map(|&(_, s)| s).collect();
            for (x, y) in av.iter().zip(&bv) {
                assert!((x - y).abs() < 1e-12, "knn sims differ: {av:?} vs {bv:?}");
            }
        }
    }

    #[test]
    fn matches_linear_scan_low_dim() {
        check_matches_linear(300, 4, 11, BoundKind::Mult);
    }

    #[test]
    fn matches_linear_scan_mid_dim() {
        check_matches_linear(300, 16, 12, BoundKind::Mult);
    }

    #[test]
    fn matches_linear_with_loose_bounds() {
        check_matches_linear(200, 8, 13, BoundKind::Euclidean);
        check_matches_linear(200, 8, 14, BoundKind::MultLb1);
        check_matches_linear(200, 8, 15, BoundKind::EuclLb);
    }

    #[test]
    fn tighter_bound_prunes_at_least_as_well() {
        let pts = uniform_sphere(2000, 8, 21);
        let tight = VpTree::build(pts.clone(), BoundKind::Mult, 1);
        let loose = VpTree::build(pts.clone(), BoundKind::Euclidean, 1);
        let mut st = QueryStats::default();
        let mut sl = QueryStats::default();
        for qi in 0..20 {
            tight.range(&pts[qi * 100], 0.8, &mut st);
            loose.range(&pts[qi * 100], 0.8, &mut sl);
        }
        assert!(
            st.sim_evals <= sl.sim_evals,
            "tight {} > loose {}",
            st.sim_evals,
            sl.sim_evals
        );
    }

    #[test]
    fn empty_and_singleton() {
        let empty: VpTree<Vec<DenseVec>> = VpTree::build(Vec::new(), BoundKind::Mult, 0);
        let mut stats = QueryStats::default();
        let q = DenseVec::new(vec![1.0, 0.0]);
        assert!(empty.range(&q, 0.0, &mut stats).is_empty());
        assert!(empty.knn(&q, 3, &mut stats).is_empty());

        let one = VpTree::build(vec![q.clone()], BoundKind::Mult, 0);
        assert_eq!(one.knn(&q, 3, &mut stats).len(), 1);
    }
}
