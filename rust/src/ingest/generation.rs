//! The units of the generational corpus: the mutable staging
//! [`MemTable`], immutable sealed [`Generation`]s, and the published
//! [`GenerationSet`] snapshot that queries fan out over.
//!
//! Everything in this module is immutable once constructed — mutation in
//! the ingest layer means building a new snapshot (sharing unchanged parts
//! by `Arc`) and publishing it with one pointer swap. That is what keeps
//! the read path lock-free and the exactness argument simple: a query sees
//! exactly one consistent logical corpus, scored by the same kernels the
//! linear-scan oracle uses.

use std::collections::HashSet;
use std::sync::Arc;

use crate::bounds::BoundKind;
use crate::coordinator::IndexKind;
use crate::index::{LinearScan, QueryStats, SimilarityIndex};
use crate::metrics::DenseVec;
use crate::obs::{TraceEvent, TraceKind, OBS};
use crate::query::{QueryContext, SearchMode, SearchRequest, SearchResponse};
use crate::storage::{CorpusStore, KernelBackend};

/// Move one source's trace into the caller's accumulator, lifting
/// item-scoped event ids (visit/prune/eval) into the global id space
/// through `map` (a generation's id table, or the memtable base offset).
/// Scan/budget/filter events carry counts, not ids — they pass through.
fn lift_trace(
    dst: &mut Vec<TraceEvent>,
    src: &mut Vec<TraceEvent>,
    mut map: impl FnMut(u64) -> u64,
) {
    for mut ev in src.drain(..) {
        if matches!(ev.kind, TraceKind::Visit | TraceKind::Prune | TraceKind::Eval) {
            ev.id = map(ev.id);
        }
        dst.push(ev);
    }
}

/// Sort global hits in descending similarity with the crate-wide tie
/// order (similarity desc, id asc) — the same total order the linear
/// scan, the shard merge, and [`KnnHeap`] use. The order is total (ids are
/// unique), so the allocation-free unstable sort is deterministic and
/// identical to a stable sort.
fn sort_hits(hits: &mut [(u64, f64)]) {
    hits.sort_unstable_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
}

/// The staging buffer: freshly inserted (normalized) rows awaiting a
/// seal. Copy-on-write — every insert publishes a fresh `MemTable` whose
/// store holds one more row. The copy is bounded by the seal threshold,
/// so it stays small; in exchange the read path gets a plain immutable
/// [`CorpusStore`] it can scan with the existing blocked kernels.
#[derive(Clone)]
pub struct MemTable {
    /// Global id of staged row 0; staged ids are `base .. base + len`.
    base: u64,
    store: CorpusStore,
}

impl MemTable {
    /// An empty memtable whose next staged row will get global id `base`,
    /// scanning through the given kernel backend (shared with the corpus's
    /// generations so every scan feeds one set of counters).
    pub fn empty(dim: usize, base: u64, kernel: &Arc<dyn KernelBackend>) -> MemTable {
        let store = CorpusStore::from_flat_normalized_with(Vec::new(), dim, kernel.clone());
        MemTable { base, store }
    }

    pub fn len(&self) -> usize {
        self.store.len()
    }

    pub fn is_empty(&self) -> bool {
        self.store.is_empty()
    }

    pub fn base(&self) -> u64 {
        self.base
    }

    pub fn store(&self) -> &CorpusStore {
        &self.store
    }

    /// A new memtable with `row` (already normalized) appended, keeping
    /// the kernel backend. Memtable stores are never sidecar-warmed, so
    /// under a quantized backend the per-insert rebuild stays a plain copy
    /// and memtable scans are exact, whatever the memtable's size.
    pub fn with_row(&self, row: &[f32]) -> MemTable {
        let d = self.store.dim();
        assert_eq!(row.len(), d, "memtable row dimension {} != {d}", row.len());
        let mut flat = Vec::with_capacity(self.store.flat().len() + d);
        flat.extend_from_slice(self.store.flat());
        flat.extend_from_slice(row);
        let kernel = self.store.kernel().clone();
        let store = CorpusStore::from_flat_normalized_with(flat, d, kernel);
        MemTable { base: self.base, store }
    }
}

/// An immutable sealed generation: a contiguous [`CorpusStore`] of
/// surviving rows, the global id of each row, and a similarity index
/// built over the store through the ordinary [`IndexKind`] machinery.
pub struct Generation {
    /// `ids[local] = global id`, strictly ascending (seals and compactions
    /// both emit rows in ascending-id order).
    ids: Vec<u64>,
    store: CorpusStore,
    index: Box<dyn SimilarityIndex<DenseVec>>,
}

impl Generation {
    /// Build a generation over `store` rows carrying the given global ids,
    /// scanning through the corpus's shared kernel backend. Quantized
    /// backends build their i8 sidecar here — on the sealer/compactor
    /// thread, so the first query never pays the O(n*d) quantization pass.
    pub fn build(
        ids: Vec<u64>,
        store: CorpusStore,
        kind: IndexKind,
        bound: BoundKind,
        kernel: &Arc<dyn KernelBackend>,
    ) -> Generation {
        debug_assert_eq!(ids.len(), store.len());
        debug_assert!(ids.windows(2).all(|w| w[0] < w[1]), "generation ids not ascending");
        // Keep the store's backend when it already is the shared instance
        // (re-attaching would discard an existing sidecar).
        let store = if Arc::ptr_eq(store.kernel(), kernel) {
            store
        } else {
            store.with_backend(kernel.clone())
        };
        store.warm_quant_sidecar();
        let index = kind.build(store.view(), bound);
        Generation { ids, store, index }
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    pub fn ids(&self) -> &[u64] {
        &self.ids
    }

    pub fn store(&self) -> &CorpusStore {
        &self.store
    }

    /// Whether this generation physically holds `id` (tombstones are
    /// tracked in the [`GenerationSet`], not here).
    pub fn contains(&self, id: u64) -> bool {
        self.ids.binary_search(&id).is_ok()
    }

    /// Bytes of sealed vector data.
    pub fn bytes(&self) -> u64 {
        (self.store.flat().len() * std::mem::size_of::<f32>()) as u64
    }

    /// Localize a plan for this generation: filter ids translate from
    /// global to row-local (via binary search over the ascending id
    /// column), and the mode is replaced by the tombstone-over-fetching
    /// `mode`. Returns `None` when `req` can run as-is (range mode, no
    /// filter) — the zero-copy fast path. A generation whose id column is
    /// exactly `0..len` (generation 0, or the survivor of a gapless full
    /// compaction) shares the filter by `Arc` instead of copying it.
    fn localize(&self, req: &SearchRequest, mode: SearchMode) -> Option<SearchRequest> {
        let needs_mode_rewrite = !matches!(mode, SearchMode::Range { .. });
        // Strictly ascending ids filling [0, len) are exactly 0..len:
        // global ids ARE local ids (out-of-range filter entries match
        // nothing), so the filter needs no translation.
        let identity_ids = self.ids.first() == Some(&0)
            && self.ids.last() == Some(&(self.ids.len() as u64 - 1));
        if req.filter.is_none() || identity_ids {
            if !needs_mode_rewrite {
                return None;
            }
            return Some(SearchRequest { mode, ..req.clone() });
        }
        Some(req.localized(mode, |id| self.ids.binary_search(&id).ok().map(|l| l as u64)))
    }
}

/// One immutable snapshot of the whole mutable corpus: the memtable, the
/// sealed generations, and the tombstone set. Published atomically by the
/// ingest layer; queries run entirely against one snapshot.
pub struct GenerationSet {
    memtable: MemTable,
    generations: Vec<Arc<Generation>>,
    /// Deleted-but-not-yet-dropped global ids. Every member refers to
    /// exactly one physical row (memtable or sealed); seals and
    /// compactions drop those rows and remove the resolved ids.
    tombstones: Arc<HashSet<u64>>,
}

impl GenerationSet {
    pub(crate) fn new(
        memtable: MemTable,
        generations: Vec<Arc<Generation>>,
        tombstones: Arc<HashSet<u64>>,
    ) -> GenerationSet {
        GenerationSet { memtable, generations, tombstones }
    }

    pub fn memtable(&self) -> &MemTable {
        &self.memtable
    }

    pub fn generations(&self) -> &[Arc<Generation>] {
        &self.generations
    }

    pub fn tombstones(&self) -> &Arc<HashSet<u64>> {
        &self.tombstones
    }

    /// Physical rows across memtable and generations (tombstoned included).
    pub fn physical_rows(&self) -> usize {
        self.memtable.len() + self.generations.iter().map(|g| g.len()).sum::<usize>()
    }

    /// Live (visible) items: physical rows minus unresolved tombstones.
    pub fn live(&self) -> u64 {
        (self.physical_rows() - self.tombstones.len()) as u64
    }

    pub fn sealed_bytes(&self) -> u64 {
        self.generations.iter().map(|g| g.bytes()).sum()
    }

    /// Whether `id` is currently visible to queries.
    pub fn contains_live(&self, id: u64) -> bool {
        if self.tombstones.contains(&id) {
            return false;
        }
        let mt = &self.memtable;
        if id >= mt.base() && id < mt.base() + mt.len() as u64 {
            return true;
        }
        self.generations.iter().any(|g| g.contains(id))
    }

    /// Visit every live row as `(global id, normalized row)`: generations
    /// in publication order (ascending id within each), then the memtable.
    pub fn for_each_live_row(&self, mut f: impl FnMut(u64, &[f32])) {
        for g in &self.generations {
            for (local, &id) in g.ids().iter().enumerate() {
                if !self.tombstones.contains(&id) {
                    f(id, g.store().row(local));
                }
            }
        }
        let mt = &self.memtable;
        for local in 0..mt.len() {
            let id = mt.base() + local as u64;
            if !self.tombstones.contains(&id) {
                f(id, mt.store().row(local));
            }
        }
    }

    /// Exact kNN across all generations plus the memtable, tombstones
    /// filtered, merged under (sim desc, id asc). Returns the hits and the
    /// number of exact similarity evaluations spent. (Convenience form:
    /// one throwaway context; the serving path reuses one through
    /// [`GenerationSet::search_ctx`].)
    pub fn knn(&self, q: &DenseVec, k: usize) -> (Vec<(u64, f64)>, u64) {
        let mut ctx = QueryContext::new();
        ctx.begin_query();
        let mut out = Vec::new();
        let evals = self.knn_ctx(q, k, &mut ctx, &mut out);
        (out, evals)
    }

    /// Plain-kNN shim over [`GenerationSet::search_ctx`].
    pub fn knn_ctx(
        &self,
        q: &DenseVec,
        k: usize,
        ctx: &mut QueryContext,
        out: &mut Vec<(u64, f64)>,
    ) -> u64 {
        self.search_ctx(q, &SearchRequest::knn(k).build(), ctx, out).0
    }

    /// Exact range query (`sim >= tau`) across all generations plus the
    /// memtable, tombstones filtered, sorted under (sim desc, id asc).
    /// (Convenience form; see [`GenerationSet::knn`].)
    pub fn range(&self, q: &DenseVec, tau: f64) -> (Vec<(u64, f64)>, u64) {
        let mut ctx = QueryContext::new();
        ctx.begin_query();
        let mut out = Vec::new();
        let evals = self.range_ctx(q, tau, &mut ctx, &mut out);
        (out, evals)
    }

    /// Plain-range shim over [`GenerationSet::search_ctx`].
    pub fn range_ctx(
        &self,
        q: &DenseVec,
        tau: f64,
        ctx: &mut QueryContext,
        out: &mut Vec<(u64, f64)>,
    ) -> u64 {
        self.search_ctx(q, &SearchRequest::range(tau).build(), ctx, out).0
    }

    /// Execute one typed search plan (ADR-005) across all generations plus
    /// the memtable, through one borrowed [`QueryContext`]: the traversal
    /// scratch *and* the kernels' quantized-query cache are shared across
    /// the whole fan-out (the cache depends only on the query bytes, not
    /// on which store is scanned). The caller owns the query boundary
    /// ([`QueryContext::begin_query`] once per logical query); the
    /// request's filter ids are *global* and are translated per source.
    /// Returns `(exact evaluations spent, budget-truncated)`.
    ///
    /// Exactness (kNN modes): each source is asked for its top
    /// `k + |tombstones|` candidates; at most `|tombstones|` of any
    /// source's candidates can be filtered out afterwards, so each source
    /// still contributes its true top-k survivors and the global merge is
    /// exact (the same argument, and the same f64 tie caveat, as the
    /// per-index contract in `index/mod.rs`). The user filter needs no
    /// over-fetch: it is applied *inside* each source's scan.
    pub fn search_ctx(
        &self,
        q: &DenseVec,
        req: &SearchRequest,
        ctx: &mut QueryContext,
        out: &mut Vec<(u64, f64)>,
    ) -> (u64, bool) {
        let evals_before = ctx.stats.sim_evals;
        let mut truncated = false;
        out.clear();
        // Per-source mode: kNN modes over-fetch for the tombstone filter.
        let (k, fetch_mode) = match req.mode {
            SearchMode::Knn { k } => {
                let k = k.max(1);
                (Some(k), SearchMode::Knn { k: k.saturating_add(self.tombstones.len()) })
            }
            SearchMode::KnnWithin { k, tau } => {
                let k = k.max(1);
                (
                    Some(k),
                    SearchMode::KnnWithin { k: k.saturating_add(self.tombstones.len()), tau },
                )
            }
            SearchMode::Range { tau } => (None, SearchMode::Range { tau }),
        };
        let mut resp = SearchResponse { hits: ctx.lease_pairs(), ..SearchResponse::default() };
        for (gi, g) in self.generations.iter().enumerate() {
            let before = ctx.stats;
            let local = g.localize(req, fetch_mode);
            g.index.search_into(q, local.as_ref().unwrap_or(req), ctx, &mut resp);
            if ctx.obs_enabled() {
                OBS.record_gen(
                    gi,
                    1,
                    ctx.stats.sim_evals - before.sim_evals,
                    ctx.stats.nodes_visited - before.nodes_visited,
                    ctx.stats.pruned - before.pruned,
                );
            }
            truncated |= resp.truncated;
            for &(local_id, s) in resp.hits.iter() {
                let id = g.ids[local_id as usize];
                if !self.tombstones.contains(&id) {
                    out.push((id, s));
                }
            }
        }
        if !self.memtable.is_empty() {
            // The memtable scans as a throwaway LinearScan over its store
            // view (a handful of Arc bumps, no heap allocation): one code
            // path arms the filter/budget/override exactly like every
            // other source — in particular the budget keeps working here
            // even though each generation's `search_into` disarmed the
            // plan at its exit, and a budgeted scan chunks so truncation
            // still overshoots by at most one chunk.
            let base = self.memtable.base();
            let hi = base + self.memtable.len() as u64;
            let local = if req.filter.is_none() || base == 0 {
                // Identity id space (fresh corpus, nothing sealed yet):
                // share the filter by Arc, only the mode changes.
                SearchRequest { mode: fetch_mode, ..req.clone() }
            } else {
                req.localized(fetch_mode, |id| {
                    if (base..hi).contains(&id) {
                        Some(id - base)
                    } else {
                        None
                    }
                })
            };
            let scan = LinearScan::build(self.memtable.store().view());
            let before = ctx.stats;
            scan.search_into(q, &local, ctx, &mut resp);
            if ctx.obs_enabled() {
                OBS.record_gen(
                    self.generations.len(),
                    1,
                    ctx.stats.sim_evals - before.sim_evals,
                    ctx.stats.nodes_visited - before.nodes_visited,
                    ctx.stats.pruned - before.pruned,
                );
            }
            truncated |= resp.truncated;
            for &(local_id, s) in resp.hits.iter() {
                let id = base + local_id as u64;
                if !self.tombstones.contains(&id) {
                    out.push((id, s));
                }
            }
        }
        ctx.release_pairs(resp.hits);
        sort_hits(out);
        if let Some(k) = k {
            out.truncate(k);
        }
        (ctx.stats.sim_evals - evals_before, truncated)
    }

    /// Execute a batch of typed plans across all generations plus the
    /// memtable (ADR-006): every source sees the *whole* batch through one
    /// [`SimilarityIndex::search_batch_into`] call, so a batch of plain
    /// plans descends each generation's tree once behind the shared
    /// frontier. Tombstone handling never disturbs that grouping — the
    /// per-source over-fetch (`k + |tombstones|`, same exactness argument
    /// as [`GenerationSet::search_ctx`]) only rewrites the *mode*, which
    /// [`SearchRequest::is_plain`] ignores, so plain plans stay plain and
    /// the post-hoc global-id filter does the rest. Only user filters
    /// force the per-query fallback, and that decision is per source.
    ///
    /// `outs[j]` receives query `j`'s global hits (tombstones filtered,
    /// `(sim desc, id asc)`); `metas[j]` its merged per-query stats,
    /// truncation flag, and trace (traced plans only — event ids lifted
    /// into the global id space, sources in execution order). The callee
    /// owns the query boundary (it runs through `search_batch_into`),
    /// matching that method and unlike [`GenerationSet::search_ctx`].
    pub fn search_batch_ctx(
        &self,
        queries: &[DenseVec],
        reqs: &[SearchRequest],
        ctx: &mut QueryContext,
        outs: &mut Vec<Vec<(u64, f64)>>,
        metas: &mut Vec<(QueryStats, bool, Vec<TraceEvent>)>,
    ) {
        assert_eq!(queries.len(), reqs.len(), "batch queries/plans length mismatch");
        let n = queries.len();
        outs.resize_with(n, Vec::new);
        for out in outs.iter_mut() {
            out.clear();
        }
        metas.clear();
        metas.resize_with(n, || (QueryStats::default(), false, Vec::new()));
        if n == 0 {
            return;
        }
        // Per-query target k and tombstone-over-fetching source mode.
        let mut ks: Vec<Option<usize>> = Vec::with_capacity(n);
        let mut fetch: Vec<SearchMode> = Vec::with_capacity(n);
        for req in reqs {
            let (k, mode) = match req.mode {
                SearchMode::Knn { k } => {
                    let k = k.max(1);
                    (Some(k), SearchMode::Knn { k: k.saturating_add(self.tombstones.len()) })
                }
                SearchMode::KnnWithin { k, tau } => {
                    let k = k.max(1);
                    (
                        Some(k),
                        SearchMode::KnnWithin { k: k.saturating_add(self.tombstones.len()), tau },
                    )
                }
                SearchMode::Range { tau } => (None, SearchMode::Range { tau }),
            };
            ks.push(k);
            fetch.push(mode);
        }
        let mut local: Vec<SearchRequest> = Vec::with_capacity(n);
        let mut resps: Vec<SearchResponse> = Vec::new();
        for (gi, g) in self.generations.iter().enumerate() {
            local.clear();
            for (req, &mode) in reqs.iter().zip(&fetch) {
                local.push(g.localize(req, mode).unwrap_or_else(|| req.clone()));
            }
            g.index.search_batch_into(queries, &local, ctx, &mut resps);
            let mut work = QueryStats::default();
            for (j, resp) in resps.iter_mut().enumerate() {
                work.merge(&resp.stats);
                metas[j].0.merge(&resp.stats);
                metas[j].1 |= resp.truncated;
                lift_trace(&mut metas[j].2, &mut resp.trace, |id| g.ids[id as usize]);
                for &(local_id, s) in resp.hits.iter() {
                    let id = g.ids[local_id as usize];
                    if !self.tombstones.contains(&id) {
                        outs[j].push((id, s));
                    }
                }
            }
            if ctx.obs_enabled() {
                OBS.record_gen(gi, n as u64, work.sim_evals, work.nodes_visited, work.pruned);
            }
        }
        if !self.memtable.is_empty() {
            let base = self.memtable.base();
            let hi = base + self.memtable.len() as u64;
            local.clear();
            for (req, &mode) in reqs.iter().zip(&fetch) {
                local.push(if req.filter.is_none() || base == 0 {
                    SearchRequest { mode, ..req.clone() }
                } else {
                    req.localized(mode, |id| {
                        if (base..hi).contains(&id) {
                            Some(id - base)
                        } else {
                            None
                        }
                    })
                });
            }
            let scan = LinearScan::build(self.memtable.store().view());
            scan.search_batch_into(queries, &local, ctx, &mut resps);
            let mut work = QueryStats::default();
            for (j, resp) in resps.iter_mut().enumerate() {
                work.merge(&resp.stats);
                metas[j].0.merge(&resp.stats);
                metas[j].1 |= resp.truncated;
                lift_trace(&mut metas[j].2, &mut resp.trace, |id| base + id);
                for &(local_id, s) in resp.hits.iter() {
                    let id = base + local_id as u64;
                    if !self.tombstones.contains(&id) {
                        outs[j].push((id, s));
                    }
                }
            }
            if ctx.obs_enabled() {
                let slot = self.generations.len();
                OBS.record_gen(slot, n as u64, work.sim_evals, work.nodes_visited, work.pruned);
            }
        }
        for (out, k) in outs.iter_mut().zip(&ks) {
            sort_hits(out);
            if let Some(k) = k {
                out.truncate(*k);
            }
        }
    }
}
