//! Generational ingest: lock-free mutable corpora over the
//! [`CorpusStore`] backbone (ADR-002).
//!
//! The static serving stack is build-once; this subsystem makes a served
//! corpus mutable under traffic without ever locking the scan path. The
//! layout is LSM-like:
//!
//! ```text
//! insert ──> MemTable (COW staging, exact linear scan)
//!               │ seal at threshold (background or inline)
//!               v
//!          Generation 0..n  (immutable CorpusStore + SimilarityIndex)
//!               │ compact: merge generations, drop tombstoned rows
//!               v
//!          fewer, larger generations
//! delete ──> tombstone set (filtered at query time, resolved by
//!            the next seal/compaction that rewrites the row)
//! ```
//!
//! Every mutation builds a fresh [`GenerationSet`] snapshot (sharing
//! unchanged generations by `Arc`) and publishes it through a
//! [`SnapshotCell`] — one atomic pointer swap, hazard-pointer
//! reclamation, no reader locks. Queries fan out across all generations
//! plus the memtable, merge under the crate-wide (sim desc, id asc)
//! order, and filter tombstones; results are exactly what a linear scan
//! over the surviving logical corpus would return (bit-identical
//! similarities — every path scores through the same kernels).
//!
//! Writers (insert/delete/seal/compact) serialize behind one writer lock;
//! that lock is never taken on the query path.

pub mod generation;
pub mod swap;

pub use generation::{Generation, GenerationSet, MemTable};
pub use swap::SnapshotCell;

use std::collections::HashSet;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::bounds::BoundKind;
use crate::coordinator::IndexKind;
use crate::metrics::DenseVec;
use crate::query::QueryContext;
use crate::storage::{
    backend_for, default_kernel, normalize_row, CorpusStore, KernelBackend, KernelKind,
};
use crate::sync::{AtomicBool, AtomicU64, Ordering};

/// Configuration of a mutable corpus.
#[derive(Debug, Clone)]
pub struct IngestConfig {
    /// Vector-space dimension (fixed for the corpus lifetime).
    pub dim: usize,
    /// Index built over each sealed generation.
    pub index: IndexKind,
    pub bound: BoundKind,
    /// Kernel backend every generation and memtable scan goes through
    /// (ADR-003); one shared instance per corpus.
    pub kernel: KernelKind,
    /// Seal the memtable into a generation at this many staged rows.
    pub seal_threshold: usize,
    /// Compact when more generations than this are sealed (background
    /// mode; explicit `compact` merges all). Which generations merge is
    /// decided by the size-tiered policy ([`pick_tiered_merge`]).
    pub max_generations: usize,
    /// Size-tiered compaction ratio: generations whose sizes are within
    /// this factor of their tier's smallest member merge together. Larger
    /// ratios merge more aggressively; values below 1 behave as 1.
    pub tier_ratio: f64,
    /// Fully compact when this many tombstones are unresolved. Bounds the
    /// per-delete set copy and the per-query `k + |tombstones|` over-fetch
    /// under delete-heavy traffic (deletes alone never trigger a seal, so
    /// without this cap the set would grow until an explicit `compact`).
    pub max_tombstones: usize,
    /// Run the sealer/compactor on a background thread. With `false`,
    /// sealing and merging happen inline on the inserting thread —
    /// deterministic, which is what the exactness tests want.
    pub background: bool,
    /// Poll interval of the background maintenance thread.
    pub maintenance_interval: Duration,
}

impl IngestConfig {
    /// Defaults for a corpus of the given dimension: VP-tree generations
    /// under the multiplicative bound, sealed every 512 rows, background
    /// maintenance on.
    pub fn new(dim: usize) -> IngestConfig {
        IngestConfig {
            dim,
            index: IndexKind::Vp,
            bound: BoundKind::Mult,
            kernel: default_kernel(),
            seal_threshold: 512,
            max_generations: 6,
            tier_ratio: 4.0,
            max_tombstones: 1024,
            background: true,
            maintenance_interval: Duration::from_millis(2),
        }
    }
}

/// Point-in-time ingest gauges and lifetime counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct IngestStats {
    /// Live (visible) items.
    pub live: u64,
    pub memtable_items: u64,
    pub generations: u64,
    /// Unresolved tombstones (deleted ids whose rows still exist).
    pub tombstones: u64,
    /// Bytes of vector data in sealed generations.
    pub sealed_bytes: u64,
    pub inserts: u64,
    pub deletes: u64,
    pub seals: u64,
    pub compactions: u64,
}

/// State owned by the writer lock. A struct (not a bare counter) so the
/// lock guards the whole read-modify-publish critical section, not just
/// the id allocation.
struct WriterState {
    next_id: u64,
}

struct Inner {
    cfg: IngestConfig,
    /// One backend instance shared by the memtable and every generation,
    /// so the whole corpus feeds one set of kernel counters.
    kernel: Arc<dyn KernelBackend>,
    cell: SnapshotCell<GenerationSet>,
    writer: Mutex<WriterState>,
    inserts: AtomicU64,
    deletes: AtomicU64,
    seals: AtomicU64,
    compactions: AtomicU64,
    stop: AtomicBool,
}

impl Inner {
    fn publish(&self, set: GenerationSet) {
        self.cell.store(Arc::new(set));
    }

    /// Seal the memtable into a new generation, dropping tombstoned rows
    /// and resolving their tombstones. Caller holds the writer lock.
    /// Returns whether anything was published.
    fn seal_locked(&self, st: &mut WriterState) -> bool {
        let cur = self.cell.load();
        let mt = cur.memtable();
        if mt.is_empty() {
            return false;
        }
        let d = self.cfg.dim;
        let mut ids = Vec::with_capacity(mt.len());
        let mut flat = Vec::with_capacity(mt.len() * d);
        for local in 0..mt.len() {
            let id = mt.base() + local as u64;
            if cur.tombstones().contains(&id) {
                continue;
            }
            ids.push(id);
            flat.extend_from_slice(mt.store().row(local));
        }
        let tombstones = if ids.len() == mt.len() {
            cur.tombstones().clone()
        } else {
            // Staged rows tombstoned before the seal are dropped above;
            // resolve their tombstones here.
            let lo = mt.base();
            let hi = mt.base() + mt.len() as u64;
            let mut kept = HashSet::new();
            for &id in cur.tombstones().iter() {
                if id < lo || id >= hi {
                    kept.insert(id);
                }
            }
            Arc::new(kept)
        };
        let mut generations = cur.generations().to_vec();
        if !ids.is_empty() {
            let store = CorpusStore::from_flat_normalized_with(flat, d, self.kernel.clone());
            generations.push(Arc::new(Generation::build(
                ids,
                store,
                self.cfg.index,
                self.cfg.bound,
                &self.kernel,
            )));
        }
        let memtable = MemTable::empty(d, st.next_id, &self.kernel);
        self.publish(GenerationSet::new(memtable, generations, tombstones));
        self.seals.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Merge the picked generations (by position) into one, dropping
    /// tombstoned rows and resolving their tombstones. Rows are copied
    /// byte-for-byte — never re-normalized — so similarities stay
    /// bit-identical across compactions. Caller holds the writer lock.
    fn compact_locked(&self, pick: &[usize]) -> bool {
        let cur = self.cell.load();
        if pick.is_empty() {
            return false;
        }
        let picked: Vec<&Arc<Generation>> = pick.iter().map(|&i| &cur.generations()[i]).collect();
        // Gather surviving rows in ascending global-id order.
        let mut rows: Vec<(u64, usize, u32)> = Vec::new();
        for (pi, g) in picked.iter().enumerate() {
            for (local, &id) in g.ids().iter().enumerate() {
                if !cur.tombstones().contains(&id) {
                    rows.push((id, pi, local as u32));
                }
            }
        }
        rows.sort_unstable_by_key(|r| r.0);
        let d = self.cfg.dim;
        let mut ids = Vec::with_capacity(rows.len());
        let mut flat = Vec::with_capacity(rows.len() * d);
        for (id, pi, local) in rows {
            ids.push(id);
            flat.extend_from_slice(picked[pi].store().row(local as usize));
        }
        let mut kept = HashSet::new();
        for &id in cur.tombstones().iter() {
            if !picked.iter().any(|g| g.contains(id)) {
                kept.insert(id);
            }
        }
        let tombstones = Arc::new(kept);
        let mut generations: Vec<Arc<Generation>> = Vec::new();
        for (i, g) in cur.generations().iter().enumerate() {
            if !pick.contains(&i) {
                generations.push(g.clone());
            }
        }
        if !ids.is_empty() {
            let store = CorpusStore::from_flat_normalized_with(flat, d, self.kernel.clone());
            generations.push(Arc::new(Generation::build(
                ids,
                store,
                self.cfg.index,
                self.cfg.bound,
                &self.kernel,
            )));
        }
        self.publish(GenerationSet::new(cur.memtable().clone(), generations, tombstones));
        self.compactions.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Background compaction step: merge one size tier of generations
    /// (see [`pick_tiered_merge`]), falling back to the two smallest when
    /// the size ladder is too steep for any tier to qualify — generation
    /// count must still shrink.
    fn merge_tiered_locked(&self) -> bool {
        let cur = self.cell.load();
        if cur.generations().len() < 2 {
            return false;
        }
        let sizes: Vec<usize> = cur.generations().iter().map(|g| g.len()).collect();
        drop(cur);
        match pick_tiered_merge(&sizes, self.cfg.tier_ratio, 2) {
            Some(pick) => self.compact_locked(&pick),
            None => {
                let mut order: Vec<usize> = (0..sizes.len()).collect();
                // lint: stable-sort — compaction path; equal-size segments
                // must merge oldest-first (index order) for determinism.
                order.sort_by_key(|&i| sizes[i]);
                self.compact_locked(&order[..2])
            }
        }
    }

    /// Seal, then rewrite every generation (the explicit-`compact` body;
    /// also the tombstone-pressure response). Caller holds the writer lock.
    fn compact_all_locked(&self, st: &mut WriterState) {
        self.seal_locked(st);
        let cur = self.cell.load();
        let all: Vec<usize> = (0..cur.generations().len()).collect();
        drop(cur);
        self.compact_locked(&all);
    }
}

/// A mutable, generational corpus with a lock-free exact query path.
///
/// Dropping the last handle stops and joins the background maintenance
/// thread (if configured).
pub struct IngestCorpus {
    inner: Arc<Inner>,
    maintenance: Mutex<Option<JoinHandle<()>>>,
}

impl IngestCorpus {
    /// An empty mutable corpus.
    pub fn new(cfg: IngestConfig) -> Result<IngestCorpus> {
        Self::with_initial(cfg, None)
    }

    /// A mutable corpus seeded with an existing store as generation 0
    /// (ids `0..initial.len()`), e.g. to take a build-once deployment
    /// live-updatable without re-inserting the corpus row by row.
    pub fn with_initial(cfg: IngestConfig, initial: Option<CorpusStore>) -> Result<IngestCorpus> {
        if cfg.dim == 0 {
            bail!("ingest corpus needs dim >= 1");
        }
        if cfg.seal_threshold == 0 {
            bail!("seal_threshold must be >= 1");
        }
        cfg.kernel.validate_dim(cfg.dim)?;
        let kernel = backend_for(cfg.kernel);
        let mut generations = Vec::new();
        let mut next_id = 0u64;
        if let Some(store) = initial {
            if !store.is_empty() {
                if store.dim() != cfg.dim {
                    bail!("initial store dim {} != configured dim {}", store.dim(), cfg.dim);
                }
                let ids: Vec<u64> = (0..store.len() as u64).collect();
                next_id = store.len() as u64;
                generations.push(Arc::new(Generation::build(
                    ids,
                    store,
                    cfg.index,
                    cfg.bound,
                    &kernel,
                )));
            }
        }
        let set = GenerationSet::new(
            MemTable::empty(cfg.dim, next_id, &kernel),
            generations,
            Arc::new(HashSet::new()),
        );
        let inner = Arc::new(Inner {
            cfg: cfg.clone(),
            kernel,
            cell: SnapshotCell::new(Arc::new(set)),
            writer: Mutex::new(WriterState { next_id }),
            inserts: AtomicU64::new(0),
            deletes: AtomicU64::new(0),
            seals: AtomicU64::new(0),
            compactions: AtomicU64::new(0),
            stop: AtomicBool::new(false),
        });
        let maintenance = if cfg.background {
            let worker = inner.clone();
            Some(
                std::thread::Builder::new()
                    .name("simetra-ingest".into())
                    .spawn(move || maintenance_loop(&worker))
                    .map_err(|e| anyhow::anyhow!("spawn ingest maintenance: {e}"))?,
            )
        } else {
            None
        };
        Ok(IngestCorpus { inner, maintenance: Mutex::new(maintenance) })
    }

    pub fn dim(&self) -> usize {
        self.inner.cfg.dim
    }

    /// The backend every memtable and generation scan goes through (one
    /// shared instance; its counters cover the whole corpus).
    pub fn kernel(&self) -> &Arc<dyn KernelBackend> {
        &self.inner.kernel
    }

    /// Insert a raw vector (L2-normalized on the way in, like every other
    /// ingest path). Returns the assigned global id. Ids are monotone and
    /// never reused, and stay stable across seals and compactions.
    pub fn insert(&self, vector: Vec<f32>) -> Result<u64> {
        if vector.len() != self.inner.cfg.dim {
            bail!(
                "vector dimension {} does not match corpus dimension {}",
                vector.len(),
                self.inner.cfg.dim
            );
        }
        if !vector.iter().all(|v| v.is_finite()) {
            bail!("vector contains a non-finite component");
        }
        let mut row = vector;
        normalize_row(&mut row);
        let mut st = self.inner.writer.lock().unwrap();
        let cur = self.inner.cell.load();
        let id = st.next_id;
        st.next_id += 1;
        debug_assert_eq!(id, cur.memtable().base() + cur.memtable().len() as u64);
        let memtable = cur.memtable().with_row(&row);
        self.inner.publish(GenerationSet::new(
            memtable,
            cur.generations().to_vec(),
            cur.tombstones().clone(),
        ));
        self.inner.inserts.fetch_add(1, Ordering::Relaxed);
        if !self.inner.cfg.background {
            // Synchronous mode: maintain inline, deterministically.
            let snap = self.inner.cell.load();
            if snap.memtable().len() >= self.inner.cfg.seal_threshold {
                self.inner.seal_locked(&mut st);
                let snap = self.inner.cell.load();
                if snap.generations().len() > self.inner.cfg.max_generations {
                    self.inner.merge_tiered_locked();
                }
            }
        }
        Ok(id)
    }

    /// Tombstone a live id. Returns `false` (a no-op) for unknown,
    /// already-deleted, or never-assigned ids.
    pub fn delete(&self, id: u64) -> bool {
        let mut st = self.inner.writer.lock().unwrap();
        let cur = self.inner.cell.load();
        if !cur.contains_live(id) {
            return false;
        }
        let mut set: HashSet<u64> = cur.tombstones().as_ref().clone();
        set.insert(id);
        self.inner.publish(GenerationSet::new(
            cur.memtable().clone(),
            cur.generations().to_vec(),
            Arc::new(set),
        ));
        self.inner.deletes.fetch_add(1, Ordering::Relaxed);
        if !self.inner.cfg.background {
            // Synchronous mode: resolve tombstone pressure inline.
            let snap = self.inner.cell.load();
            if snap.tombstones().len() >= self.inner.cfg.max_tombstones {
                self.inner.compact_all_locked(&mut st);
            }
        }
        true
    }

    /// Seal the memtable into a generation now (no-op when empty).
    pub fn flush(&self) {
        let mut st = self.inner.writer.lock().unwrap();
        self.inner.seal_locked(&mut st);
    }

    /// Full compaction: seal the memtable, then rewrite all generations
    /// into one, dropping every tombstoned row.
    pub fn compact(&self) {
        let mut st = self.inner.writer.lock().unwrap();
        self.inner.compact_all_locked(&mut st);
    }

    /// Exact kNN over the current snapshot (lock-free).
    pub fn knn(&self, q: &DenseVec, k: usize) -> (Vec<(u64, f64)>, u64) {
        self.inner.cell.load().knn(q, k)
    }

    /// Exact range query over the current snapshot (lock-free).
    pub fn range(&self, q: &DenseVec, tau: f64) -> (Vec<(u64, f64)>, u64) {
        self.inner.cell.load().range(q, tau)
    }

    /// Execute one typed search plan (ADR-005) over the current snapshot
    /// through a borrowed [`QueryContext`] (the serving hot path: the
    /// coordinator's batch worker reuses one context across every query of
    /// every batch). Marks the query boundary itself; replaces `out`;
    /// returns `(exact evaluations spent, budget-truncated)`.
    pub fn search_ctx(
        &self,
        q: &DenseVec,
        req: &crate::query::SearchRequest,
        ctx: &mut QueryContext,
        out: &mut Vec<(u64, f64)>,
    ) -> (u64, bool) {
        ctx.begin_query();
        self.inner.cell.load().search_ctx(q, req, ctx, out)
    }

    /// Execute a batch of typed plans over the current snapshot (ADR-006):
    /// the whole batch fans out together, so each generation's index sees
    /// one [`crate::index::SimilarityIndex::search_batch_into`] call and a
    /// batch of plain plans descends each tree once behind the shared
    /// frontier. The snapshot is loaded once — every query in the batch
    /// sees the same consistent corpus. `outs[j]` receives query `j`'s
    /// global hits, `metas[j]` its stats, truncation flag, and trace
    /// (traced plans only); the query boundary is owned by the batch
    /// machinery (no `begin_query` here).
    pub fn search_batch_ctx(
        &self,
        queries: &[DenseVec],
        reqs: &[crate::query::SearchRequest],
        ctx: &mut QueryContext,
        outs: &mut Vec<Vec<(u64, f64)>>,
        metas: &mut Vec<(crate::index::QueryStats, bool, Vec<crate::obs::TraceEvent>)>,
    ) {
        self.inner.cell.load().search_batch_ctx(queries, reqs, ctx, outs, metas)
    }

    /// Exact kNN over the current snapshot through a borrowed
    /// [`QueryContext`] (plain-plan shim over [`IngestCorpus::search_ctx`]).
    pub fn knn_ctx(
        &self,
        q: &DenseVec,
        k: usize,
        ctx: &mut QueryContext,
        out: &mut Vec<(u64, f64)>,
    ) -> u64 {
        ctx.begin_query();
        self.inner.cell.load().knn_ctx(q, k, ctx, out)
    }

    /// Exact range query over the current snapshot through a borrowed
    /// [`QueryContext`]; same contract as [`IngestCorpus::knn_ctx`].
    pub fn range_ctx(
        &self,
        q: &DenseVec,
        tau: f64,
        ctx: &mut QueryContext,
        out: &mut Vec<(u64, f64)>,
    ) -> u64 {
        ctx.begin_query();
        self.inner.cell.load().range_ctx(q, tau, ctx, out)
    }

    /// The current published snapshot (lock-free; holding it pins its
    /// generations and memtable alive, not the corpus).
    pub fn snapshot(&self) -> Arc<GenerationSet> {
        self.inner.cell.load()
    }

    pub fn stats(&self) -> IngestStats {
        let snap = self.inner.cell.load();
        IngestStats {
            live: snap.live(),
            memtable_items: snap.memtable().len() as u64,
            generations: snap.generations().len() as u64,
            tombstones: snap.tombstones().len() as u64,
            sealed_bytes: snap.sealed_bytes(),
            inserts: self.inner.inserts.load(Ordering::Relaxed),
            deletes: self.inner.deletes.load(Ordering::Relaxed),
            seals: self.inner.seals.load(Ordering::Relaxed),
            compactions: self.inner.compactions.load(Ordering::Relaxed),
        }
    }
}

impl Drop for IngestCorpus {
    fn drop(&mut self) {
        self.inner.stop.store(true, Ordering::SeqCst);
        if let Some(handle) = self.maintenance.lock().unwrap().take() {
            let _ = handle.join();
        }
    }
}

/// Size-tiered compaction policy: which generations (by position in
/// `sizes`) should merge. Generations are grouped into tiers by walking
/// them in ascending size; a tier is a maximal run whose members are all
/// within `ratio` of the tier's smallest. The smallest tier with at least
/// `min_run` members merges whole — the classic LSM size-tiered shape,
/// which keeps write amplification O(log n) instead of the two-smallest
/// policy's repeated rewriting of the big survivor.
///
/// Returns `None` when no tier qualifies (e.g. a strictly geometric size
/// ladder steeper than `ratio`).
pub fn pick_tiered_merge(sizes: &[usize], ratio: f64, min_run: usize) -> Option<Vec<usize>> {
    let min_run = min_run.max(2);
    if sizes.len() < min_run {
        return None;
    }
    let ratio = ratio.max(1.0);
    let mut order: Vec<usize> = (0..sizes.len()).collect();
    // lint: stable-sort — compaction planning; equal-size segments must
    // stay in index order so tier runs are deterministic.
    order.sort_by_key(|&i| sizes[i]);
    let mut start = 0usize;
    while start < order.len() {
        let floor = sizes[order[start]].max(1) as f64;
        let mut end = start + 1;
        while end < order.len() && sizes[order[end]] as f64 <= floor * ratio {
            end += 1;
        }
        if end - start >= min_run {
            return Some(order[start..end].to_vec());
        }
        start = end;
    }
    None
}

/// Background sealer/compactor: seal when the memtable crosses the
/// threshold, merge one size tier when too many generations pile up,
/// otherwise sleep. Every action publishes with one atomic swap; queries
/// in flight keep their snapshots.
fn maintenance_loop(inner: &Inner) {
    while !inner.stop.load(Ordering::SeqCst) {
        let snap = inner.cell.load();
        let seal_due = snap.memtable().len() >= inner.cfg.seal_threshold;
        let compact_due = snap.generations().len() > inner.cfg.max_generations;
        let tombstones_due = snap.tombstones().len() >= inner.cfg.max_tombstones;
        drop(snap);
        if seal_due {
            let mut st = inner.writer.lock().unwrap();
            inner.seal_locked(&mut st);
        } else if compact_due {
            let _st = inner.writer.lock().unwrap();
            inner.merge_tiered_locked();
        } else if tombstones_due {
            let mut st = inner.writer.lock().unwrap();
            inner.compact_all_locked(&mut st);
        } else {
            std::thread::sleep(inner.cfg.maintenance_interval);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{uniform_sphere, uniform_sphere_store};
    use std::time::Instant;

    fn sync_cfg(dim: usize) -> IngestConfig {
        IngestConfig {
            seal_threshold: 16,
            max_generations: 2,
            background: false,
            ..IngestConfig::new(dim)
        }
    }

    #[test]
    fn empty_corpus_answers_empty() {
        let corpus = IngestCorpus::new(sync_cfg(4)).unwrap();
        let q = DenseVec::new(vec![1.0, 0.0, 0.0, 0.0]);
        assert!(corpus.knn(&q, 5).0.is_empty());
        assert!(corpus.range(&q, 0.0).0.is_empty());
        assert_eq!(corpus.stats().live, 0);
    }

    #[test]
    fn insert_then_knn_finds_self() {
        let corpus = IngestCorpus::new(sync_cfg(8)).unwrap();
        let rows = uniform_sphere(40, 8, 5);
        let mut ids = Vec::new();
        for r in &rows {
            ids.push(corpus.insert(r.as_slice().to_vec()).unwrap());
        }
        assert_eq!(ids, (0..40u64).collect::<Vec<_>>());
        // 40 inserts at threshold 16 -> at least two seals happened inline.
        let st = corpus.stats();
        assert!(st.seals >= 2, "{st:?}");
        assert_eq!(st.live, 40);
        for (i, r) in rows.iter().enumerate().step_by(7) {
            let (hits, evals) = corpus.knn(r, 3);
            assert_eq!(hits[0].0, i as u64);
            assert!((hits[0].1 - 1.0).abs() < 1e-9);
            assert!(evals > 0);
        }
    }

    #[test]
    fn delete_hides_rows_and_compact_resolves_tombstones() {
        let corpus = IngestCorpus::new(sync_cfg(8)).unwrap();
        let rows = uniform_sphere(30, 8, 6);
        for r in &rows {
            corpus.insert(r.as_slice().to_vec()).unwrap();
        }
        assert!(corpus.delete(3));
        assert!(!corpus.delete(3), "double delete must be a no-op");
        assert!(!corpus.delete(999), "unknown id must be a no-op");
        let (hits, _) = corpus.knn(&rows[3], 1);
        assert_ne!(hits[0].0, 3, "tombstoned id surfaced");
        let st = corpus.stats();
        assert_eq!(st.live, 29);
        assert_eq!(st.tombstones, 1);
        corpus.compact();
        let st = corpus.stats();
        assert_eq!(st.live, 29);
        assert_eq!(st.tombstones, 0, "compaction must resolve tombstones");
        assert_eq!(st.generations, 1);
        assert_eq!(st.memtable_items, 0);
        let (hits, _) = corpus.knn(&rows[3], 30);
        assert_eq!(hits.len(), 29);
        assert!(hits.iter().all(|&(id, _)| id != 3));
    }

    #[test]
    fn tombstone_pressure_triggers_compaction() {
        let cfg = IngestConfig { max_tombstones: 4, ..sync_cfg(8) };
        let corpus = IngestCorpus::new(cfg).unwrap();
        let rows = uniform_sphere(40, 8, 13);
        for r in &rows {
            corpus.insert(r.as_slice().to_vec()).unwrap();
        }
        for id in 0..10u64 {
            assert!(corpus.delete(id));
            // The unresolved set never reaches the cap at rest.
            assert!(corpus.stats().tombstones < 4, "{:?}", corpus.stats());
        }
        assert_eq!(corpus.stats().live, 30);
        let (hits, _) = corpus.knn(&rows[0], 40);
        assert_eq!(hits.len(), 30);
        assert!(hits.iter().all(|&(id, _)| id >= 10));
    }

    #[test]
    fn ids_stay_stable_across_compaction() {
        let corpus = IngestCorpus::new(sync_cfg(8)).unwrap();
        let rows = uniform_sphere(50, 8, 7);
        for r in &rows {
            corpus.insert(r.as_slice().to_vec()).unwrap();
        }
        let (before, _) = corpus.knn(&rows[17], 5);
        corpus.flush();
        corpus.compact();
        let (after, _) = corpus.knn(&rows[17], 5);
        assert_eq!(before, after, "compaction changed visible results");
        assert_eq!(after[0].0, 17);
        // New inserts after compaction continue the id sequence.
        let id = corpus.insert(rows[0].as_slice().to_vec()).unwrap();
        assert_eq!(id, 50);
    }

    #[test]
    fn initial_store_becomes_generation_zero() {
        let store = uniform_sphere_store(25, 6, 9);
        let q = store.vec(9);
        let corpus = IngestCorpus::with_initial(sync_cfg(6), Some(store)).unwrap();
        let st = corpus.stats();
        assert_eq!(st.live, 25);
        assert_eq!(st.generations, 1);
        let (hits, _) = corpus.knn(&q, 1);
        assert_eq!(hits[0].0, 9);
        let id = corpus.insert(q.as_slice().to_vec()).unwrap();
        assert_eq!(id, 25);
    }

    #[test]
    fn rejects_bad_dim_and_non_finite() {
        let corpus = IngestCorpus::new(sync_cfg(4)).unwrap();
        assert!(corpus.insert(vec![1.0; 3]).is_err());
        assert!(corpus.insert(vec![1.0, f32::NAN, 0.0, 0.0]).is_err());
        assert!(corpus.insert(vec![1.0, f32::INFINITY, 0.0, 0.0]).is_err());
        assert!(IngestCorpus::new(IngestConfig::new(0)).is_err());
    }

    #[test]
    fn tiered_merge_picks_the_smallest_qualifying_tier() {
        // Three near-equal small generations and one huge one: the small
        // tier merges; the huge generation is left alone.
        let mut pick = pick_tiered_merge(&[100, 90, 10_000, 110], 4.0, 2).unwrap();
        pick.sort_unstable();
        assert_eq!(pick, vec![0, 1, 3]);
        // A geometric ladder steeper than the ratio: no tier qualifies.
        assert_eq!(pick_tiered_merge(&[1, 10, 100, 1000], 4.0, 2), None);
        // Equal sizes all land in one tier.
        let mut all = pick_tiered_merge(&[64, 64, 64], 2.0, 2).unwrap();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2]);
        // The run is anchored at the tier's smallest member, not chained:
        // 10 and 30 are within ratio 4, 100 is not (100 > 4 * 10 = 40).
        let mut low = pick_tiered_merge(&[100, 10, 30, 120], 4.0, 2).unwrap();
        low.sort_unstable();
        assert_eq!(low, vec![1, 2]);
        // Too few generations, or min_run not reached.
        assert_eq!(pick_tiered_merge(&[512], 4.0, 2), None);
        assert_eq!(pick_tiered_merge(&[8, 9], 4.0, 3), None);
        // Zero-size generations cannot divide by zero.
        assert!(pick_tiered_merge(&[0, 0, 5], 4.0, 2).is_some());
    }

    #[test]
    fn inline_compaction_is_size_tiered() {
        // seal_threshold 16, max_generations 2: after the third seal the
        // three equal-sized generations form one tier and merge together.
        let corpus = IngestCorpus::new(sync_cfg(8)).unwrap();
        let rows = uniform_sphere(64, 8, 23);
        for r in &rows {
            corpus.insert(r.as_slice().to_vec()).unwrap();
        }
        let st = corpus.stats();
        assert!(st.compactions >= 1, "{st:?}");
        assert!(st.generations <= 3, "{st:?}");
        assert_eq!(st.live, 64);
        // Results stay exact across tiered merges.
        let (hits, _) = corpus.knn(&rows[17], 3);
        assert_eq!(hits[0].0, 17);
    }

    #[test]
    fn background_thread_seals_and_merges() {
        let cfg = IngestConfig {
            seal_threshold: 8,
            max_generations: 2,
            maintenance_interval: Duration::from_micros(200),
            ..IngestConfig::new(8)
        };
        let corpus = IngestCorpus::new(cfg).unwrap();
        let rows = uniform_sphere(400, 8, 11);
        // Feed batches of one seal's worth and wait for the background
        // thread to drain them; generations pile up past max_generations
        // and force a merge. (Feeding everything at once could race the
        // sealer into one big generation and never compact.)
        let deadline = Instant::now() + Duration::from_secs(60);
        let mut next = 0usize;
        loop {
            let st = corpus.stats();
            if st.seals >= 1 && st.compactions >= 1 {
                break;
            }
            assert!(Instant::now() < deadline, "maintenance never caught up: {st:?}");
            if st.memtable_items < 8 && next + 8 <= rows.len() {
                for r in &rows[next..next + 8] {
                    corpus.insert(r.as_slice().to_vec()).unwrap();
                }
                next += 8;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        let st = corpus.stats();
        assert_eq!(st.live, next as u64);
        let (hits, _) = corpus.knn(&rows[0], 1);
        assert_eq!(hits[0].0, 0);
        // Drop joins the maintenance thread (would hang the test otherwise).
    }
}
