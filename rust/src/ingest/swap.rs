//! Hand-rolled atomic `Arc` swap: the publication primitive of the ingest
//! subsystem (the offline build has no `arc-swap` or `crossbeam`, and the
//! read path is not allowed to take a lock).
//!
//! A [`SnapshotCell`] holds the current snapshot behind an `AtomicPtr`;
//! [`SnapshotCell::load`] hands out an `Arc` clone of it without ever
//! blocking, and [`SnapshotCell::store`] publishes a replacement with one
//! pointer swap. Reclamation of the retired pointer uses classic hazard
//! pointers: a reader parks the pointer it is about to dereference in one
//! of a fixed set of hazard slots, re-validates that the pointer is still
//! current, and only then clones the `Arc`; a writer retires the old
//! pointer by waiting until no slot holds it. The hazard window covers
//! only the `Arc` clone (a refcount bump), so queries of any duration
//! never delay the sealer/compactor by more than nanoseconds — and the
//! sealer never delays queries at all.
//!
//! All atomics go through the [`crate::sync`] shims, so the protocol is
//! explored exhaustively (to a preemption bound) by the deterministic
//! model checker in [`crate::sync::model`] — see `tests/model_checker.rs`
//! and ADR-010. The [`model::note_alloc`]/[`model::note_free`]/
//! [`model::note_deref`] hooks below are no-ops outside a model run.

use std::marker::PhantomData;
use std::sync::Arc;

use crate::sync::{model, AtomicPtr, AtomicUsize, Ordering};

/// Hazard slots shared by all concurrent readers of one cell. The hazard
/// window is two atomic stores wide, so collisions are rare even with far
/// more reader threads than slots; a reader that finds every slot taken
/// spins with `yield_now` until one frees.
const HAZARD_SLOTS: usize = 64;

/// Slot states: `FREE` (available), `CLAIMED` (taken, no pointer parked);
/// any other value is the parked pointer. Neither sentinel can collide
/// with a real `Box` address.
const FREE: usize = 0;
const CLAIMED: usize = 1;

/// An atomically swappable `Arc<T>` with lock-free reads.
///
/// Writers may call [`store`](SnapshotCell::store) concurrently (each
/// retired pointer is reclaimed exactly once), though the ingest layer
/// serializes them behind its writer lock anyway so publications are
/// totally ordered.
///
/// # Memory ordering
///
/// The protocol's one store→load race — reader parks a hazard then
/// re-checks `current`, writer swaps `current` then scans the hazards —
/// keeps `SeqCst` on all four accesses: each side must observe the other's
/// earlier store, which release/acquire alone cannot guarantee (the
/// classic Dekker store-buffering shape). Everything else is relaxed to
/// the publication edges it actually needs, documented at each site. The
/// model checker validates the protocol logic over all bounded schedules
/// (under sequentially consistent interpretation); the relaxed edges are
/// additionally exercised by Miri's weak-memory emulation and ThreadSanitizer
/// in CI (ADR-010).
pub struct SnapshotCell<T> {
    /// Points at a `Box<Arc<T>>`; the box is the unit of reclamation.
    current: AtomicPtr<Arc<T>>,
    hazards: Box<[AtomicUsize]>,
    /// The cell owns an `Arc<T>` through the raw pointer: inherit its
    /// `Send`/`Sync` requirements instead of the unconditional ones
    /// `AtomicPtr` would grant.
    _owns: PhantomData<Arc<T>>,
}

impl<T> SnapshotCell<T> {
    pub fn new(value: Arc<T>) -> SnapshotCell<T> {
        SnapshotCell::with_slots(value, HAZARD_SLOTS)
    }

    /// A cell with a custom hazard-slot count (`slots >= 1`). Production
    /// code uses [`SnapshotCell::new`]; small slot counts keep the model
    /// checker's schedule space tight and let the slot-exhaustion stress
    /// test force claim contention with a handful of threads.
    pub fn with_slots(value: Arc<T>, slots: usize) -> SnapshotCell<T> {
        assert!(slots >= 1, "a SnapshotCell needs at least one hazard slot");
        let mut hazards = Vec::with_capacity(slots);
        for _ in 0..slots {
            hazards.push(AtomicUsize::new(FREE));
        }
        let p = Box::into_raw(Box::new(value));
        model::note_alloc(p as usize);
        SnapshotCell {
            current: AtomicPtr::new(p),
            hazards: hazards.into_boxed_slice(),
            _owns: PhantomData,
        }
    }

    /// Claim a free hazard slot, spinning if all are momentarily busy.
    fn claim_slot(&self) -> &AtomicUsize {
        loop {
            for slot in self.hazards.iter() {
                // AcqRel claim / Acquire failure: the claim synchronizes
                // with the previous holder's Release of `FREE`, ordering
                // this reader's window after the predecessor's. Slot
                // handoff never races `current`, so SeqCst buys nothing
                // here (checked schedules: ADR-010 §model results).
                if slot
                    .compare_exchange(FREE, CLAIMED, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
                {
                    return slot;
                }
            }
            crate::sync::yield_now();
        }
    }

    /// Lock-free snapshot read: returns an `Arc` clone of the current
    /// value. Never blocks on writers; the only wait is for a hazard slot
    /// when more than `HAZARD_SLOTS` readers are inside their (two-store)
    /// critical windows simultaneously.
    pub fn load(&self) -> Arc<T> {
        let slot = self.claim_slot();
        let arc = loop {
            // Relaxed speculative read: the value is not trusted until the
            // SeqCst re-check below observes it still current.
            let p = self.current.load(Ordering::Relaxed);
            // SeqCst park + SeqCst re-validate: the reader's half of the
            // Dekker pair with the writer's swap + hazard scan. Do not
            // weaken — with release/acquire both sides can miss each
            // other's store and the writer frees a box this reader is
            // about to dereference. (The model checker pins the protocol
            // logic; this ordering pair is the one part it takes on the
            // hardware-memory-model side: ADR-010.)
            slot.store(p as usize, Ordering::SeqCst);
            if self.current.load(Ordering::SeqCst) == p {
                model::note_deref(p as usize);
                // SAFETY: the re-check observed `p` still current *after*
                // the hazard was parked, so in the SeqCst total order the
                // park precedes any retiring swap of `p` — a writer's
                // clearance scan (which runs after its swap) must see the
                // hazard and cannot free the box before the clone below
                // completes.
                break unsafe { (*p).clone() };
            }
        };
        // Release: the clone above must be globally visible before the
        // slot frees, because the writer's clearance scan (Acquire-or-
        // stronger load) takes this store as permission to reclaim.
        slot.store(FREE, Ordering::Release);
        arc
    }

    /// Publish a new snapshot with one pointer swap, then reclaim the old
    /// box once no reader has it parked in a hazard slot. Readers are
    /// never blocked; the writer waits only for hazard windows (an `Arc`
    /// clone), not for queries.
    pub fn store(&self, value: Arc<T>) {
        let fresh = Box::into_raw(Box::new(value));
        model::note_alloc(fresh as usize);
        // SeqCst swap + SeqCst scan: the writer's half of the Dekker pair
        // (see `load`). The swap also release-publishes the fresh box to
        // readers and acquire-orders this writer after the previous
        // publication it retires.
        let old = self.current.swap(fresh, Ordering::SeqCst);
        loop {
            let parked = self.hazards.iter().any(|s| s.load(Ordering::SeqCst) == old as usize);
            if !parked {
                break;
            }
            crate::sync::yield_now();
        }
        model::note_free(old as usize);
        // SAFETY: `old` came out of the swap above (so this call owns its
        // reclamation exclusively), it is no longer reachable through
        // `current`, and no hazard slot protects it anymore.
        drop(unsafe { Box::from_raw(old) });
    }
}

impl<T> Drop for SnapshotCell<T> {
    fn drop(&mut self) {
        let p = *self.current.get_mut();
        model::note_free(p as usize);
        // SAFETY: `&mut self` means no concurrent reader or writer exists;
        // the box is exclusively ours.
        drop(unsafe { Box::from_raw(p) });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sync::AtomicBool;

    #[test]
    fn load_returns_current_value_across_stores() {
        let cell = SnapshotCell::new(Arc::new(1u64));
        assert_eq!(*cell.load(), 1);
        cell.store(Arc::new(2));
        cell.store(Arc::new(3));
        assert_eq!(*cell.load(), 3);
    }

    #[test]
    fn retired_snapshots_stay_alive_while_cloned() {
        let cell = SnapshotCell::new(Arc::new(vec![7u64; 4]));
        let pinned = cell.load();
        cell.store(Arc::new(vec![8u64; 4]));
        // The old snapshot was retired but our clone keeps it alive.
        assert_eq!(pinned[0], 7);
        assert_eq!(cell.load()[0], 8);
    }

    #[test]
    fn hammer_concurrent_loads_during_stores() {
        // Miri executes this faithfully but ~3 orders of magnitude slower;
        // a shrunken run still crosses the publication path thousands of
        // times under its weak-memory exploration.
        #[cfg(miri)]
        const STORES: u64 = 40;
        #[cfg(not(miri))]
        const STORES: u64 = 2000;
        let cell = Arc::new(SnapshotCell::new(Arc::new(vec![0u64; 16])));
        let stop = Arc::new(AtomicBool::new(false));
        let mut readers = Vec::new();
        for _ in 0..4 {
            let cell = cell.clone();
            let stop = stop.clone();
            readers.push(std::thread::spawn(move || {
                let mut last = 0u64;
                let mut loads = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let snap = cell.load();
                    let v = snap[0];
                    assert!(snap.iter().all(|&x| x == v), "torn snapshot");
                    assert!(v >= last, "snapshot went backwards: {v} < {last}");
                    last = v;
                    loads += 1;
                }
                loads
            }));
        }
        for i in 1..=STORES {
            cell.store(Arc::new(vec![i; 16]));
        }
        stop.store(true, Ordering::Relaxed);
        for r in readers {
            assert!(r.join().unwrap() > 0);
        }
        assert_eq!(cell.load()[0], STORES);
    }

    /// Satellite pin (ISSUE 10): with fewer hazard slots than concurrent
    /// readers, `claim_slot`'s `yield_now` spin must hand slots around and
    /// terminate — readers beyond the slot count wait, they don't wedge.
    #[test]
    #[cfg_attr(miri, ignore)] // 66 OS threads: far too slow under Miri
    fn more_readers_than_hazard_slots_terminates() {
        const READERS: usize = 66;
        let cell = Arc::new(SnapshotCell::with_slots(Arc::new(vec![0u64; 8]), 2));
        let stop = Arc::new(AtomicBool::new(false));
        let mut readers = Vec::new();
        for _ in 0..READERS {
            let cell = cell.clone();
            let stop = stop.clone();
            readers.push(std::thread::spawn(move || {
                let mut loads = 0u64;
                // Load-then-check so every reader proves at least one trip
                // through the claim spin, even if it is scheduled late.
                loop {
                    let snap = cell.load();
                    let v = snap[0];
                    assert!(snap.iter().all(|&x| x == v), "torn snapshot");
                    loads += 1;
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                }
                loads
            }));
        }
        for i in 1..=200u64 {
            cell.store(Arc::new(vec![i; 8]));
        }
        stop.store(true, Ordering::Relaxed);
        for r in readers {
            // Every reader made progress through the 2-slot bottleneck.
            assert!(r.join().unwrap() > 0);
        }
        assert_eq!(cell.load()[0], 200);
    }
}
