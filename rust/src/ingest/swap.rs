//! Hand-rolled atomic `Arc` swap: the publication primitive of the ingest
//! subsystem (the offline build has no `arc-swap` or `crossbeam`, and the
//! read path is not allowed to take a lock).
//!
//! A [`SnapshotCell`] holds the current snapshot behind an `AtomicPtr`;
//! [`SnapshotCell::load`] hands out an `Arc` clone of it without ever
//! blocking, and [`SnapshotCell::store`] publishes a replacement with one
//! pointer swap. Reclamation of the retired pointer uses classic hazard
//! pointers: a reader parks the pointer it is about to dereference in one
//! of a fixed set of hazard slots, re-validates that the pointer is still
//! current, and only then clones the `Arc`; a writer retires the old
//! pointer by waiting until no slot holds it. The hazard window covers
//! only the `Arc` clone (a refcount bump), so queries of any duration
//! never delay the sealer/compactor by more than nanoseconds — and the
//! sealer never delays queries at all.

use std::marker::PhantomData;
use std::sync::atomic::{AtomicPtr, AtomicUsize, Ordering};
use std::sync::Arc;

/// Hazard slots shared by all concurrent readers of one cell. The hazard
/// window is two atomic stores wide, so collisions are rare even with far
/// more reader threads than slots; a reader that finds every slot taken
/// spins with `yield_now` until one frees.
const HAZARD_SLOTS: usize = 64;

/// Slot states: `FREE` (available), `CLAIMED` (taken, no pointer parked);
/// any other value is the parked pointer. Neither sentinel can collide
/// with a real `Box` address.
const FREE: usize = 0;
const CLAIMED: usize = 1;

/// An atomically swappable `Arc<T>` with lock-free reads.
///
/// Writers may call [`store`](SnapshotCell::store) concurrently (each
/// retired pointer is reclaimed exactly once), though the ingest layer
/// serializes them behind its writer lock anyway so publications are
/// totally ordered.
pub struct SnapshotCell<T> {
    /// Points at a `Box<Arc<T>>`; the box is the unit of reclamation.
    current: AtomicPtr<Arc<T>>,
    hazards: Box<[AtomicUsize]>,
    /// The cell owns an `Arc<T>` through the raw pointer: inherit its
    /// `Send`/`Sync` requirements instead of the unconditional ones
    /// `AtomicPtr` would grant.
    _owns: PhantomData<Arc<T>>,
}

impl<T> SnapshotCell<T> {
    pub fn new(value: Arc<T>) -> SnapshotCell<T> {
        let mut hazards = Vec::with_capacity(HAZARD_SLOTS);
        for _ in 0..HAZARD_SLOTS {
            hazards.push(AtomicUsize::new(FREE));
        }
        SnapshotCell {
            current: AtomicPtr::new(Box::into_raw(Box::new(value))),
            hazards: hazards.into_boxed_slice(),
            _owns: PhantomData,
        }
    }

    /// Claim a free hazard slot, spinning if all are momentarily busy.
    fn claim_slot(&self) -> &AtomicUsize {
        loop {
            for slot in self.hazards.iter() {
                if slot
                    .compare_exchange(FREE, CLAIMED, Ordering::SeqCst, Ordering::SeqCst)
                    .is_ok()
                {
                    return slot;
                }
            }
            std::thread::yield_now();
        }
    }

    /// Lock-free snapshot read: returns an `Arc` clone of the current
    /// value. Never blocks on writers; the only wait is for a hazard slot
    /// when more than `HAZARD_SLOTS` readers are inside their (two-store)
    /// critical windows simultaneously.
    pub fn load(&self) -> Arc<T> {
        let slot = self.claim_slot();
        let arc = loop {
            let p = self.current.load(Ordering::SeqCst);
            slot.store(p as usize, Ordering::SeqCst);
            if self.current.load(Ordering::SeqCst) == p {
                // Safety: the re-check observed `p` still current *after*
                // the hazard was parked, so in the SeqCst total order the
                // park precedes any retiring swap of `p` — a writer's
                // clearance scan (which runs after its swap) must see the
                // hazard and cannot free the box before the clone below
                // completes.
                break unsafe { (*p).clone() };
            }
        };
        slot.store(FREE, Ordering::SeqCst);
        arc
    }

    /// Publish a new snapshot with one pointer swap, then reclaim the old
    /// box once no reader has it parked in a hazard slot. Readers are
    /// never blocked; the writer waits only for hazard windows (an `Arc`
    /// clone), not for queries.
    pub fn store(&self, value: Arc<T>) {
        let fresh = Box::into_raw(Box::new(value));
        let old = self.current.swap(fresh, Ordering::SeqCst);
        loop {
            let parked = self.hazards.iter().any(|s| s.load(Ordering::SeqCst) == old as usize);
            if !parked {
                break;
            }
            std::thread::yield_now();
        }
        // Safety: `old` came out of the swap above (so this call owns its
        // reclamation exclusively), it is no longer reachable through
        // `current`, and no hazard slot protects it anymore.
        drop(unsafe { Box::from_raw(old) });
    }
}

impl<T> Drop for SnapshotCell<T> {
    fn drop(&mut self) {
        let p = *self.current.get_mut();
        // Safety: `&mut self` means no concurrent reader or writer exists;
        // the box is exclusively ours.
        drop(unsafe { Box::from_raw(p) });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;

    #[test]
    fn load_returns_current_value_across_stores() {
        let cell = SnapshotCell::new(Arc::new(1u64));
        assert_eq!(*cell.load(), 1);
        cell.store(Arc::new(2));
        cell.store(Arc::new(3));
        assert_eq!(*cell.load(), 3);
    }

    #[test]
    fn retired_snapshots_stay_alive_while_cloned() {
        let cell = SnapshotCell::new(Arc::new(vec![7u64; 4]));
        let pinned = cell.load();
        cell.store(Arc::new(vec![8u64; 4]));
        // The old snapshot was retired but our clone keeps it alive.
        assert_eq!(pinned[0], 7);
        assert_eq!(cell.load()[0], 8);
    }

    #[test]
    fn hammer_concurrent_loads_during_stores() {
        let cell = Arc::new(SnapshotCell::new(Arc::new(vec![0u64; 16])));
        let stop = Arc::new(AtomicBool::new(false));
        let mut readers = Vec::new();
        for _ in 0..4 {
            let cell = cell.clone();
            let stop = stop.clone();
            readers.push(std::thread::spawn(move || {
                let mut last = 0u64;
                let mut loads = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let snap = cell.load();
                    let v = snap[0];
                    assert!(snap.iter().all(|&x| x == v), "torn snapshot");
                    assert!(v >= last, "snapshot went backwards: {v} < {last}");
                    last = v;
                    loads += 1;
                }
                loads
            }));
        }
        for i in 1..=2000u64 {
            cell.store(Arc::new(vec![i; 16]));
        }
        stop.store(true, Ordering::Relaxed);
        for r in readers {
            assert!(r.join().unwrap() > 0);
        }
        assert_eq!(cell.load()[0], 2000);
    }
}
