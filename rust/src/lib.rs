//! # simetra — exact cosine-similarity search with a triangle inequality
//!
//! A reproduction and productionization of Erich Schubert, *"A Triangle
//! Inequality for Cosine Similarity"* (SISAP 2021). The paper derives tight,
//! trig-free triangle inequalities in the similarity domain
//! (`bounds`), which this crate uses to lift the classical metric-index
//! family (`index`: VP-tree, ball-tree, M-tree, cover tree, LAESA, GNAT)
//! from distances to cosine similarity — plus a batched scoring `runtime`
//! backed by AOT-compiled JAX/Pallas artifacts over PJRT, wrapped in a
//! `coordinator` serving engine.
//!
//! ## Quick start
//!
//! ```no_run
//! use simetra::bounds::BoundKind;
//! use simetra::data::uniform_sphere;
//! use simetra::index::{SimilarityIndex, VpTree};
//!
//! let corpus = uniform_sphere(10_000, 64, 42);
//! let index = VpTree::build(corpus.clone(), BoundKind::Mult, 7);
//! let mut stats = simetra::index::QueryStats::default();
//! let hits = index.knn(&corpus[0], 10, &mut stats);
//! assert_eq!(hits[0].0, 0); // a point's own nearest neighbor is itself
//! println!("similarity computations: {}", stats.sim_evals);
//! ```

pub mod bounds;
pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod figures;
pub mod index;
pub mod metrics;
pub mod runtime;
pub mod sparse;
pub mod util;
