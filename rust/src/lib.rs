//! # simetra — exact cosine-similarity search with a triangle inequality
//!
//! A reproduction and productionization of Erich Schubert, *"A Triangle
//! Inequality for Cosine Similarity"* (SISAP 2021). The paper derives tight,
//! trig-free triangle inequalities in the similarity domain
//! (`bounds`), which this crate uses to lift the classical metric-index
//! family (`index`: VP-tree, ball-tree, M-tree, cover tree, LAESA, GNAT)
//! from distances to cosine similarity — plus a batched scoring `runtime`
//! backed by AOT-compiled JAX/Pallas artifacts over PJRT, wrapped in a
//! `coordinator` serving engine.
//!
//! All dense-vector layers sit on one shared `storage::CorpusStore`: a
//! single contiguous row-major buffer of the normalized corpus, sliced into
//! zero-copy `CorpusView` handles by indexes, shards, and the PJRT input
//! path, and scanned through pluggable kernel backends (scalar / SIMD /
//! i8-quantized; ADR-003).
//!
//! ## Quick start
//!
//! Every layer answers one typed plan, a [`query::SearchRequest`]
//! (ADR-005): the query mode — kNN, range, or kNN restricted to a
//! similarity floor — plus per-request options (bound/kernel override,
//! allow/deny id filter, similarity-evaluation budget):
//!
//! ```no_run
//! use simetra::bounds::BoundKind;
//! use simetra::data::uniform_sphere_store;
//! use simetra::index::{SimilarityIndex, VpTree};
//! use simetra::query::SearchRequest;
//!
//! // One contiguous allocation for the whole corpus...
//! let store = uniform_sphere_store(10_000, 64, 42);
//! // ...and the index builds over a zero-copy view of it.
//! let index = VpTree::build(store.view(), BoundKind::Mult, 7);
//!
//! // Top-10 restricted to sim >= 0.7, both bounds pruning one traversal.
//! let req = SearchRequest::knn(10).within(0.7).build();
//! let resp = index.search(&store.vec(0), &req);
//! assert_eq!(resp.hits[0].0, 0); // a point's own nearest neighbor is itself
//! assert!(resp.hits.iter().all(|&(_, s)| s >= 0.7));
//! println!("similarity computations: {}", resp.stats.sim_evals);
//!
//! // Filters are applied before exact evaluation inside the kernels, and
//! // budgets degrade to certified partial results (flagged `truncated`).
//! let req = SearchRequest::knn(10)
//!     .deny(vec![17, 23])
//!     .budget(50_000)
//!     .build();
//! let resp = index.search(&store.vec(0), &req);
//! assert!(resp.hits.iter().all(|&(id, _)| id != 17 && id != 23));
//! if resp.truncated {
//!     println!("budget hit: results are exact over the evaluated subset");
//! }
//! ```
//!
//! The classic signatures (`knn`, `range`, `knn_into`, `range_into`,
//! `knn_batch`, `range_batch`) still exist on every index as provided
//! shims over [`index::SimilarityIndex::search_into`] — byte-identical to
//! plain plans.
//!
//! The pruning bound itself is pluggable ([`bounds::BoundKind`], ADR-009):
//! the paper's Eq. 10/13 `Mult` interval is the default; `Ptolemaic` (and
//! its sqrt-free `PtolemaicFast` relaxation) adds pivot-*pair* refinement
//! by Ptolemy's inequality where an index holds two references per
//! candidate — LAESA's pivot table, the M-tree's parent/route pair — and
//! `Auto` picks per index from observed bound slack (ADR-007), falling
//! back to `Mult` until warm. Every kind returns exactly the linear-scan
//! result; only the amount of pruning changes:
//!
//! ```no_run
//! use simetra::bounds::BoundKind;
//! use simetra::data::uniform_sphere_store;
//! use simetra::index::{Laesa, SimilarityIndex};
//! use simetra::query::SearchRequest;
//!
//! let store = uniform_sphere_store(10_000, 64, 42);
//! let index = Laesa::build(store.view(), BoundKind::Mult, 32);
//! // Per-request override: identical hits, tighter candidate filtering.
//! let req = SearchRequest::knn(10).bound(BoundKind::Ptolemaic).build();
//! let resp = index.search(&store.vec(0), &req);
//! assert_eq!(resp.hits[0].0, 0);
//! println!("pruned with pair bounds: {}", resp.stats.pruned);
//! ```
//!
//! Scans default to the scalar backend;
//! [`storage::CorpusStore::with_kernel`] swaps in the SIMD backend
//! (bit-identical results, AVX-accelerated) or the i8-quantized pre-filter
//! (byte-identical results after exact re-rank) — indexes built over the
//! store's views inherit it untouched, and a `SearchRequest` can override
//! the backend per query:
//!
//! ```no_run
//! use simetra::bounds::BoundKind;
//! use simetra::data::uniform_sphere_store;
//! use simetra::index::{SimilarityIndex, VpTree};
//! use simetra::query::SearchRequest;
//! use simetra::storage::KernelKind;
//!
//! let store = uniform_sphere_store(10_000, 64, 42).with_kernel(KernelKind::Simd);
//! let index = VpTree::build(store.view(), BoundKind::Mult, 7);
//! let req = SearchRequest::knn(10).kernel(KernelKind::Scalar).build();
//! let resp = index.search(&store.vec(0), &req);
//! assert_eq!(resp.hits[0].0, 0); // same bytes whatever the backend
//! ```
//!
//! The steady-state query path allocates nothing: a reusable
//! [`query::QueryContext`] owns every traversal buffer (result heap,
//! frontier, candidate pools, the i8 backend's per-query quantized-query
//! cache, the armed filter), and `knn_batch` / `range_batch` run whole
//! query batches through one context with results byte-identical to
//! one-at-a-time calls (ADR-004):
//!
//! ```no_run
//! use simetra::bounds::BoundKind;
//! use simetra::data::{uniform_sphere, uniform_sphere_store};
//! use simetra::index::{SimilarityIndex, VpTree};
//! use simetra::query::QueryContext;
//!
//! let store = uniform_sphere_store(10_000, 64, 42);
//! let index = VpTree::build(store.view(), BoundKind::Mult, 7);
//! let queries = uniform_sphere(32, 64, 43);
//! let mut ctx = QueryContext::new(); // one per worker thread, reused forever
//! for (hits, stats) in index.knn_batch(&queries, 10, &mut ctx) {
//!     assert!(hits.len() == 10 && stats.sim_evals > 0);
//! }
//! println!("quantized-query builds: {}", ctx.quant_builds());
//! ```
//!
//! Batches of *plain* plans go further (ADR-006):
//! [`index::SimilarityIndex::search_batch_into`] descends the tree
//! **once** for the whole batch behind a shared frontier — a node is
//! pruned only when
//! no live query's bound admits it, queries retire as their heaps
//! tighten, and every leaf visit scores a (query-block × row-block)
//! kernel call. Results stay byte-identical to per-query execution;
//! optioned plans fall back per query automatically:
//!
//! ```no_run
//! use simetra::bounds::BoundKind;
//! use simetra::data::{uniform_sphere, uniform_sphere_store};
//! use simetra::index::{SimilarityIndex, VpTree};
//! use simetra::query::{QueryContext, SearchRequest};
//!
//! let store = uniform_sphere_store(10_000, 64, 42);
//! let index = VpTree::build(store.view(), BoundKind::Mult, 7);
//! let queries = uniform_sphere(32, 64, 43);
//! let reqs: Vec<_> = queries.iter().map(|_| SearchRequest::knn(10).build()).collect();
//! let mut ctx = QueryContext::new();
//! let mut resps = Vec::new();
//! index.search_batch_into(&queries, &reqs, &mut ctx, &mut resps);
//! let nodes: u64 = resps.iter().map(|r| r.stats.nodes_visited).sum();
//! println!("one shared descent: {nodes} physical node visits for 32 queries");
//! ```
//!
//! Any request can ask for an EXPLAIN trace (ADR-007): `trace()` records
//! a bounded event log of the traversal — node visits, prune decisions
//! with their certified bounds, exact evaluations, kernel scan blocks,
//! budget/filter gates — into pre-sized context scratch. Traced results
//! are byte-identical to untraced ones, and with tracing off the hooks
//! cost one predicted branch (the zero-alloc contract holds):
//!
//! ```no_run
//! use simetra::bounds::BoundKind;
//! use simetra::data::uniform_sphere_store;
//! use simetra::index::{SimilarityIndex, VpTree};
//! use simetra::obs::TraceKind;
//! use simetra::query::SearchRequest;
//!
//! let store = uniform_sphere_store(10_000, 64, 42);
//! let index = VpTree::build(store.view(), BoundKind::Mult, 7);
//! let req = SearchRequest::knn(10).trace().build();
//! let resp = index.search(&store.vec(0), &req);
//! let pruned = resp.trace.iter().filter(|e| e.kind == TraceKind::Prune).count();
//! println!("{} events, {pruned} prune decisions", resp.trace.len());
//! ```
//!
//! Indexes also build from an owning `Vec<V>` for any `SimVector` (the
//! per-item path sparse corpora use):
//!
//! ```no_run
//! use simetra::bounds::BoundKind;
//! use simetra::data::{zipf_corpus, ZipfSpec};
//! use simetra::index::Laesa;
//!
//! let docs = zipf_corpus(&ZipfSpec::default());
//! let index = Laesa::build(docs, BoundKind::Mult, 32);
//! ```
//!
//! Corpora that change under traffic go through the generational `ingest`
//! subsystem (ADR-002): inserts stage in a memtable, seal into immutable
//! indexed generations, deletes tombstone, and a background compactor
//! folds generations together — queries stay exact and never take a lock:
//!
//! ```no_run
//! use simetra::coordinator::{Coordinator, CoordinatorConfig};
//! use simetra::ingest::IngestConfig;
//!
//! let coord =
//!     Coordinator::new_mutable(CoordinatorConfig::default(), IngestConfig::new(4)).unwrap();
//! let id = coord.insert(vec![0.1, 0.2, 0.3, 0.4]).unwrap();
//! let (hits, _) = coord.knn(vec![0.1, 0.2, 0.3, 0.4], 1).unwrap();
//! assert_eq!(hits[0].id, id);
//! coord.delete(id).unwrap();
//! ```
//!
//! Serving is one call (ADR-008): a fixed worker pool multiplexes
//! pipelined newline-delimited JSON connections over a streaming wire
//! path — request lines pull-parse straight off the socket buffer into
//! per-connection scratch, responses serialize tree-free into a reused
//! output buffer, and the steady-state wire path allocates nothing per
//! request:
//!
//! ```no_run
//! use simetra::coordinator::server::{serve, Client};
//! use simetra::coordinator::{Coordinator, CoordinatorConfig};
//! use simetra::data::uniform_sphere;
//!
//! let corpus = uniform_sphere(10_000, 64, 42);
//! let coord = Coordinator::new(corpus, CoordinatorConfig::default()).unwrap();
//! let mut server = serve(coord, "127.0.0.1:0").unwrap();
//!
//! let mut client = Client::connect(server.addr()).unwrap();
//! let hits = client.knn(vec![0.5; 64], 10).unwrap();
//! assert_eq!(hits.len(), 10);
//! server.stop(); // joins the accept thread and every pool worker
//! ```
//!
//! ## Unsafe-code policy (ADR-010)
//!
//! `unsafe` is confined to two places — the AVX kernels in `storage` and
//! the pointer-reclamation sites of the hazard-pointer snapshot cell /
//! zero-alloc frontier — and every `unsafe` block or function carries a
//! `// SAFETY:` comment justifying it, with `unsafe_op_in_unsafe_fn`
//! denied crate-wide so no operation is implicitly trusted. Concurrency
//! primitives never touch `std::sync::atomic` directly: they go through
//! the [`sync`] shim layer, which doubles as the instrumentation plane for
//! the deterministic model checker in [`sync::model`]. All of this is
//! machine-enforced by `simetra-lint` ([`lint`], run in CI and by unit
//! test), Miri, and ThreadSanitizer — not by convention.

#![deny(unsafe_op_in_unsafe_fn)]

pub mod bounds;
pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod figures;
pub mod index;
pub mod ingest;
pub mod lint;
pub mod metrics;
pub mod obs;
pub mod query;
pub mod runtime;
pub mod sparse;
pub mod storage;
pub mod sync;
pub mod util;

pub use error::SimetraError;
