//! Repo-invariant lint pass (ADR-010).
//!
//! A small source-level checker for invariants that `rustc`/`clippy`
//! cannot express because they are *policy*, not language rules:
//!
//! 1. **Documented `unsafe`** — every `unsafe` block or fn must carry a
//!    `// SAFETY:` comment (or a `# Safety` doc section) in the comment
//!    block immediately preceding it.
//! 2. **No stable sorts on query-path modules** (ADR-004) — `.sort()` /
//!    `.sort_by*()` in `index/`, `query/`, `storage/`, `bounds/`,
//!    `ingest/`, `sparse/` need an explicit `lint: stable-sort` waiver
//!    comment explaining why a stable sort is intended.
//! 3. **No FMA in kernel code** (ADR-003) — `mul_add` contracts the
//!    mul/add rounding steps and breaks the bit-exactness contract
//!    between scalar and SIMD paths; a `lint: fma` waiver is required
//!    anywhere it appears.
//! 4. **Atomics only through the shim** — `std::sync::atomic` /
//!    `core::sync::atomic` may be named only under `sync/`, so the
//!    model checker (see [`crate::sync::model`]) sees every atomic op.
//! 5. **Justified lint suppressions** — `#[allow(..)]` / `#![allow(..)]`
//!    must carry a comment (same line or immediately above) saying why.
//!
//! The checker is deliberately lexical: it splits each line into code
//! and comment, blanks string-literal contents, and matches fixed
//! needles. That keeps it dependency-free and fast enough to run as a
//! unit test ([`check_tree`] over `src/` is asserted empty in this
//! crate's test suite and in the CI `lint` job via the `simetra-lint`
//! binary).

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// One finding: a rule violated at a file/line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    /// Path relative to the scanned root.
    pub file: PathBuf,
    /// 1-based line number.
    pub line: usize,
    /// Short rule identifier (e.g. `unsafe-needs-safety-comment`).
    pub rule: &'static str,
    /// Human-readable description of the violation.
    pub message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file.display(), self.line, self.rule, self.message)
    }
}

/// Module directories that count as the query path for rule 2
/// (ADR-004). `util/`, `coordinator/`, `obs/` and the binaries are
/// build/serve plumbing where stable sorts are fine.
const QUERY_PATH_DIRS: &[&str] = &["bounds/", "index/", "ingest/", "query/", "sparse/", "storage/"];

/// Stable-sort method calls rejected by rule 2. `sort_unstable*` is the
/// sanctioned spelling on these paths.
const STABLE_SORTS: &[&str] = &[".sort(", ".sort_by(", ".sort_by_key(", ".sort_by_cached_key("];

/// Walk every `.rs` file under `src_root` and collect violations.
///
/// Files are visited in sorted order so output is deterministic.
pub fn check_tree(src_root: &Path) -> io::Result<Vec<Violation>> {
    let mut files = Vec::new();
    collect_rs_files(src_root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for f in &files {
        let rel = f.strip_prefix(src_root).unwrap_or(f).to_string_lossy().replace('\\', "/");
        let source = fs::read_to_string(f)?;
        out.extend(check_source(&rel, &source));
    }
    Ok(out)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Check one file's source. `rel_path` is the path relative to `src/`
/// with `/` separators (e.g. `storage/kernels.rs`); it decides which
/// directory-scoped rules apply.
pub fn check_source(rel_path: &str, source: &str) -> Vec<Violation> {
    let lines: Vec<SplitLine> = source.lines().map(split_line).collect();
    let mut out = Vec::new();
    let on_query_path = QUERY_PATH_DIRS.iter().any(|d| rel_path.starts_with(d));
    let in_sync = rel_path.starts_with("sync/") || rel_path == "sync.rs";

    for (idx, l) in lines.iter().enumerate() {
        let line_no = idx + 1;

        // Rule 1: documented unsafe.
        if contains_word(&l.code, "unsafe")
            && !l.comment.contains("SAFETY:")
            && !block_above_has(&lines, idx, &["SAFETY:", "# Safety"])
        {
            out.push(Violation {
                file: PathBuf::from(rel_path),
                line: line_no,
                rule: "unsafe-needs-safety-comment",
                message: "`unsafe` without a `// SAFETY:` comment (or `# Safety` doc) \
                          immediately above"
                    .into(),
            });
        }

        // Rule 2: no stable sorts on the query path (ADR-004).
        if on_query_path
            && STABLE_SORTS.iter().any(|s| l.code.contains(s))
            && !l.comment.contains("lint: stable-sort")
            && !block_above_has(&lines, idx, &["lint: stable-sort"])
        {
            out.push(Violation {
                file: PathBuf::from(rel_path),
                line: line_no,
                rule: "stable-sort-on-query-path",
                message: "stable sort on a query-path module (ADR-004); use \
                          `sort_unstable*` or add a `lint: stable-sort` waiver comment"
                    .into(),
            });
        }

        // Rule 3: no FMA contraction (ADR-003).
        if contains_word(&l.code, "mul_add")
            && !l.comment.contains("lint: fma")
            && !block_above_has(&lines, idx, &["lint: fma"])
        {
            out.push(Violation {
                file: PathBuf::from(rel_path),
                line: line_no,
                rule: "fma-breaks-bit-exactness",
                message: "`mul_add` fuses the mul/add rounding steps (ADR-003); compute \
                          them separately or add a `lint: fma` waiver comment"
                    .into(),
            });
        }

        // Rule 4: atomics only through the sync shim.
        if !in_sync
            && (l.code.contains("std::sync::atomic") || l.code.contains("core::sync::atomic"))
        {
            out.push(Violation {
                file: PathBuf::from(rel_path),
                line: line_no,
                rule: "raw-atomics-outside-sync",
                message: "direct `std::sync::atomic` use outside `sync/`; import the \
                          shim types from `crate::sync` so the model checker sees the op"
                    .into(),
            });
        }

        // Rule 5: justified lint suppressions.
        if (l.code.contains("#[allow(") || l.code.contains("#![allow("))
            && l.comment.trim().is_empty()
            && !plain_comment_above(&lines, idx)
        {
            out.push(Violation {
                file: PathBuf::from(rel_path),
                line: line_no,
                rule: "allow-needs-justification",
                message: "`#[allow(..)]` without a justification comment on the same \
                          line or immediately above"
                    .into(),
            });
        }
    }
    out
}

/// One source line split into its code part (string-literal contents
/// blanked) and its trailing `//` comment text (empty when none).
struct SplitLine {
    raw: String,
    code: String,
    comment: String,
}

/// Lexically split a line. Tracks double-quoted strings (with `\`
/// escapes) and char/byte literals so a `//` or needle inside a
/// literal never counts as code; lifetimes (`'a`) are left alone.
/// Strings and literals reset at end of line — multi-line string
/// bodies are rare enough here that per-line state is a fair trade.
fn split_line(raw: &str) -> SplitLine {
    let b = raw.as_bytes();
    let mut code: Vec<u8> = Vec::with_capacity(b.len());
    let mut comment = String::new();
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b'"' => {
                // String literal: keep the quotes, blank the contents.
                code.push(b'"');
                i += 1;
                while i < b.len() {
                    match b[i] {
                        b'\\' => i += 2,
                        b'"' => {
                            code.push(b'"');
                            i += 1;
                            break;
                        }
                        _ => i += 1,
                    }
                }
            }
            b'\'' => {
                // Char/byte literal vs lifetime: a literal closes with a
                // quote within a short window, a lifetime never does.
                let mut j = i + 1;
                let mut close = None;
                while j < b.len() && j <= i + 12 {
                    match b[j] {
                        b'\\' => j += 2,
                        b'\'' => {
                            close = Some(j);
                            break;
                        }
                        _ => j += 1,
                    }
                }
                match close {
                    Some(end) => {
                        code.extend_from_slice(b"' '");
                        i = end + 1;
                    }
                    None => {
                        code.push(b'\'');
                        i += 1;
                    }
                }
            }
            b'/' if i + 1 < b.len() && b[i + 1] == b'/' => {
                comment = String::from_utf8_lossy(&b[i..]).into_owned();
                break;
            }
            c => {
                code.push(c);
                i += 1;
            }
        }
    }
    SplitLine {
        raw: raw.to_string(),
        code: String::from_utf8_lossy(&code).into_owned(),
        comment,
    }
}

/// Word-boundary search: `needle` in `hay` with no identifier char on
/// either side (so `unsafe_op_in_unsafe_fn` does not match `unsafe`).
fn contains_word(hay: &str, needle: &str) -> bool {
    let hb = hay.as_bytes();
    let mut from = 0;
    while let Some(pos) = hay[from..].find(needle) {
        let start = from + pos;
        let end = start + needle.len();
        let pre_ok = start == 0 || !is_ident(hb[start - 1]);
        let post_ok = end >= hb.len() || !is_ident(hb[end]);
        if pre_ok && post_ok {
            return true;
        }
        from = start + 1;
    }
    false
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Walk the contiguous block of comment/attribute lines directly above
/// line `idx` and report whether any comment contains one of `needles`.
fn block_above_has(lines: &[SplitLine], idx: usize, needles: &[&str]) -> bool {
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let t = lines[j].raw.trim_start();
        if t.starts_with("//") {
            if needles.iter().any(|n| t.contains(n)) {
                return true;
            }
        } else if t.starts_with("#[") || t.starts_with("#!") {
            continue;
        } else {
            break;
        }
    }
    false
}

/// Like [`block_above_has`] but just requires a plain (non-doc) `//`
/// comment to exist in the block — used for `#[allow]` justification,
/// where any explanation counts.
fn plain_comment_above(lines: &[SplitLine], idx: usize) -> bool {
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let t = lines[j].raw.trim_start();
        if t.starts_with("///") || t.starts_with("//!") {
            continue;
        }
        if t.starts_with("//") {
            return true;
        }
        if t.starts_with("#[") || t.starts_with("#!") {
            continue;
        }
        break;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(rel: &str, src: &str) -> Vec<&'static str> {
        check_source(rel, src).into_iter().map(|v| v.rule).collect()
    }

    #[test]
    fn undocumented_unsafe_is_flagged_and_safety_comment_clears_it() {
        let bad = "fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
        assert_eq!(rules("query/x.rs", bad), vec!["unsafe-needs-safety-comment"]);

        let good =
            "fn f(p: *const u8) -> u8 {\n    // SAFETY: caller owns p.\n    unsafe { *p }\n}\n";
        assert!(rules("query/x.rs", good).is_empty());

        let doc =
            "/// # Safety\n/// Caller owns p.\nunsafe fn f(p: *const u8) -> u8 {\n    *p\n}\n";
        assert!(rules("query/x.rs", doc).is_empty());
    }

    #[test]
    fn unsafe_inside_comments_strings_and_idents_is_ignored() {
        let src = "//! unsafe is discussed here\nconst X: &str = \"unsafe\";\nfn unsafe_op_in_unsafe_fn_lookalike() {}\n";
        assert!(rules("query/x.rs", src).is_empty());
    }

    #[test]
    fn stable_sort_scoping_and_waiver() {
        let sort = "fn f(v: &mut Vec<u32>) {\n    v.sort_by_key(|x| *x);\n}\n";
        assert_eq!(rules("index/x.rs", sort), vec!["stable-sort-on-query-path"]);
        // Out of scope: util and binaries may sort stably.
        assert!(rules("util/x.rs", sort).is_empty());

        let waived =
            "fn f(v: &mut Vec<u32>) {\n    // lint: stable-sort — build path.\n    v.sort_by_key(|x| *x);\n}\n";
        assert!(rules("index/x.rs", waived).is_empty());

        let unstable = "fn f(v: &mut Vec<u32>) {\n    v.sort_unstable();\n}\n";
        assert!(rules("index/x.rs", unstable).is_empty());
    }

    #[test]
    fn mul_add_is_flagged_everywhere() {
        let src = "fn f(a: f64, b: f64, c: f64) -> f64 {\n    a.mul_add(b, c)\n}\n";
        assert_eq!(rules("util/x.rs", src), vec!["fma-breaks-bit-exactness"]);
    }

    #[test]
    fn raw_atomics_allowed_only_under_sync() {
        let src = "use std::sync::atomic::AtomicU64;\n";
        assert_eq!(rules("obs/mod.rs", src), vec!["raw-atomics-outside-sync"]);
        assert!(rules("sync/model.rs", src).is_empty());
    }

    #[test]
    fn allow_needs_a_comment() {
        let bare = "#[allow(dead_code)]\nfn f() {}\n";
        assert_eq!(rules("query/x.rs", bare), vec!["allow-needs-justification"]);

        let same_line = "#[allow(dead_code)] // kept for doc anchoring\nfn f() {}\n";
        assert!(rules("query/x.rs", same_line).is_empty());

        let above = "// kept for doc anchoring\n#[allow(dead_code)]\nfn f() {}\n";
        assert!(rules("query/x.rs", above).is_empty());

        // Doc comments alone do not justify a suppression.
        let doc_only = "/// Does things.\n#[allow(dead_code)]\nfn f() {}\n";
        assert_eq!(rules("query/x.rs", doc_only), vec!["allow-needs-justification"]);
    }

    #[test]
    fn char_literals_do_not_derail_the_scanner() {
        // The quote char literal must not open a string that would
        // swallow the rest of the line (a real stable sort follows).
        let src =
            "fn f(v: &mut Vec<char>) {\n    let _q = '\"'; v.sort_by_key(|c| *c as u32);\n}\n";
        assert_eq!(rules("index/x.rs", src), vec!["stable-sort-on-query-path"]);
    }

    #[test]
    fn the_crate_source_tree_is_lint_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let violations = check_tree(&root).expect("walk src");
        assert!(
            violations.is_empty(),
            "lint violations:\n{}",
            violations.iter().map(|v| format!("  {v}\n")).collect::<String>()
        );
    }
}
