//! simetra CLI: serve a corpus, run one-shot searches, regenerate the
//! paper's figures, and self-check the PJRT runtime against native scoring.
//!
//! Argument parsing is hand-rolled (`clap` is unavailable in this offline
//! build); flags are `--key value` pairs after a subcommand.

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use simetra::bounds::BoundKind;
use simetra::coordinator::{
    server, BatchConfig, Coordinator, CoordinatorConfig, ExecMode, IndexKind,
};
use simetra::data::{uniform_sphere, vmf_mixture_store, VmfSpec};
use simetra::figures;
use simetra::index::SimilarityIndex;
use simetra::ingest::IngestConfig;
use simetra::metrics::SimVector;
use simetra::query::SearchRequest;
use simetra::runtime::Engine;
use simetra::storage::KernelKind;

const USAGE: &str = "\
simetra — exact cosine-similarity search with a triangle inequality
          (Schubert, SISAP 2021)

USAGE: simetra <command> [--flag value ...]

COMMANDS:
  serve      Serve a synthetic corpus over TCP (JSON lines protocol)
             --addr 127.0.0.1:7878  --n 100000  --dim 128  --clusters 64
             --kappa 40  --shards 4  --index vp  --bound mult
             --kernel scalar|simd|i8  (scan backend, ADR-003; default:
                           SIMETRA_KERNEL env var, else scalar)
             --mode index|engine|hybrid  --artifacts artifacts
             --max-batch 32  --max-wait-us 2000
             --workers 0  (connection worker-pool size for the pipelined
                           wire path, ADR-008; 0 = auto from available
                           cores, clamped to 2..=8)
             --mutable 1  (generational ingest: insert/delete/flush/compact
                           ops enabled; requires --mode index)
             Wire ops: knn/range (legacy) plus the versioned 'search' op
             carrying mode knn|range|knn_within, bound/kernel overrides,
             allow/deny filters and a sim-eval budget (ADR-005)
  search     One-shot search on a synthetic corpus (sanity/demo); the flag
             surface mirrors the typed SearchRequest plan (ADR-005)
             --n 10000  --dim 64  --k 10  --index vp  --bound mult
             --kernel scalar|simd|i8
             --within 0.7        (top-k restricted to sim >= tau)
             --budget 50000      (sim-eval budget; partial results are
                                  flagged truncated)
             --allow 1,2,3 | --deny 4,5  (sorted id filter, applied
                                  before exact evaluation in the kernels)
             --bound-override mult  (per-request pruning bound; --bound
                                  stays the build-time bound)
  stats      Fetch serving statistics from a running server
             --addr 127.0.0.1:7878
             --prometheus 1  (emit the full Prometheus text exposition —
                           bound-slack histograms keyed by index and
                           bound, per-stage spans, per-shard/generation
                           work, slow-query ring — via the 'metrics' op)
  figures    Regenerate the paper's figures as CSV + summary
             --out figures_out  --steps 401
  selfcheck  Verify the PJRT runtime against native rust scoring
             --artifacts artifacts

INDEXES: linear vp ball m-tree cover laesa gnat
BOUNDS:  euclidean eucl-lb arccos arccos-fast mult mult-lb1 mult-lb2
         ptolemaic ptolemaic-fast (pivot-pair bounds, ADR-009)
         auto (per-index pick from observed bound slack; mult until warm)
KERNELS: scalar simd i8
";

/// Tiny `--key value` flag parser.
struct Flags(HashMap<String, String>);

impl Flags {
    fn parse(args: &[String]) -> Result<Flags> {
        let mut map = HashMap::new();
        let mut it = args.iter();
        while let Some(arg) = it.next() {
            let key = arg
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got '{arg}'"))?;
            let value = it.next().with_context(|| format!("--{key} needs a value"))?;
            map.insert(key.replace('-', "_"), value.clone());
        }
        Ok(Flags(map))
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.0.get(key).map(|s| s.as_str())
    }

    fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
            None => Ok(default),
        }
    }

    fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be a number")),
            None => Ok(default),
        }
    }
}

fn parse_kernel(flags: &Flags) -> Result<Option<KernelKind>> {
    match flags.get("kernel") {
        Some(v) => Ok(Some(
            KernelKind::parse(v).with_context(|| format!("unknown --kernel '{v}'"))?,
        )),
        None => Ok(None),
    }
}

/// The backend the command will run: `--kernel` if given, else the
/// `SIMETRA_KERNEL` env default. Validated against the corpus dimension
/// up front — a clean error beats the assert backstop inside store
/// construction.
fn effective_kernel(kernel: Option<KernelKind>, dim: usize) -> Result<KernelKind> {
    let effective = kernel.unwrap_or_else(simetra::storage::default_kernel);
    effective.validate_dim(dim)?;
    Ok(effective)
}

pub fn parse_bound(s: &str) -> Result<BoundKind> {
    BoundKind::parse(s).ok_or_else(|| anyhow::anyhow!("unknown bound '{s}'"))
}

/// Parse a comma-separated id list flag (`--allow 1,2,3`).
fn parse_id_list(value: &str) -> Result<Vec<u64>> {
    value
        .split(',')
        .filter(|s| !s.trim().is_empty())
        .map(|s| s.trim().parse::<u64>().with_context(|| format!("bad id '{s}'")))
        .collect()
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(command) = args.first() else {
        eprint!("{USAGE}");
        std::process::exit(2);
    };
    let flags = Flags::parse(&args[1..])?;
    match command.as_str() {
        "serve" => cmd_serve(&flags),
        "search" => cmd_search(&flags),
        "stats" => cmd_stats(&flags),
        "figures" => cmd_figures(&flags),
        "selfcheck" => cmd_selfcheck(&flags),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n");
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    }
}

fn cmd_serve(flags: &Flags) -> Result<()> {
    let addr = flags.str_or("addr", "127.0.0.1:7878");
    let n = flags.usize_or("n", 100_000)?;
    let dim = flags.usize_or("dim", 128)?;
    let clusters = flags.usize_or("clusters", 64)?;
    let kappa = flags.f64_or("kappa", 40.0)?;
    let shards = flags.usize_or("shards", 4)?;
    let index = IndexKind::parse(&flags.str_or("index", "vp"))
        .context("unknown --index")?;
    let bound = parse_bound(&flags.str_or("bound", "mult"))?;
    let mode = ExecMode::parse(&flags.str_or("mode", "index")).context("unknown --mode")?;
    let kernel = parse_kernel(flags)?;
    let effective_k = effective_kernel(kernel, dim)?;
    let artifacts = flags.get("artifacts").map(PathBuf::from);
    let max_batch = flags.usize_or("max_batch", 32)?;
    let max_wait_us = flags.usize_or("max_wait_us", 2000)? as u64;
    let workers = flags.usize_or("workers", 0)?;

    let mutable = flags.get("mutable").is_some_and(|v| v != "0" && v != "false");

    eprintln!("generating corpus: n={n} dim={dim} clusters={clusters} kappa={kappa}");
    // Store-native generation: one contiguous allocation that every shard,
    // index, and PJRT tile aliases.
    let (store, _) = vmf_mixture_store(&VmfSpec { n, dim, clusters, kappa, seed: 42 });
    eprintln!(
        "building {index:?} shards={shards} bound={} mode={mode:?} kernel={} mutable={mutable}",
        bound.name(),
        effective_k.name()
    );
    let config = CoordinatorConfig {
        n_shards: shards,
        index,
        bound,
        mode,
        batch: BatchConfig {
            max_batch,
            max_wait: std::time::Duration::from_micros(max_wait_us),
            queue_depth: 4096,
        },
        artifact_dir: artifacts,
        hybrid_pivots: 32,
        kernel,
    };
    let coord = if mutable {
        // The generated corpus seeds generation 0; inserts grow from
        // there. Index and bound carry over from the coordinator config.
        Coordinator::new_mutable_with(Some(store), config, IngestConfig::new(dim))?
    } else {
        Coordinator::new(store, config)?
    };
    let server_handle = server::serve_with(coord, &addr, server::ServeConfig { workers })?;
    eprintln!("serving on {} — press Ctrl-C to stop", server_handle.addr());
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_search(flags: &Flags) -> Result<()> {
    let n = flags.usize_or("n", 10_000)?;
    let dim = flags.usize_or("dim", 64)?;
    let k = flags.usize_or("k", 10)?;
    let kind =
        IndexKind::parse(&flags.str_or("index", "vp")).context("unknown --index")?;
    let bound = parse_bound(&flags.str_or("bound", "mult"))?;
    let kernel = effective_kernel(parse_kernel(flags)?, dim)?;
    let (store, _) = vmf_mixture_store(&VmfSpec { n, dim, clusters: 32, kappa: 50.0, seed: 42 });
    // Apply the effective kind unconditionally: with_kernel is also the
    // warm point that builds the i8 sidecar, including when the backend
    // came from the SIMETRA_KERNEL env default.
    let store = store.with_kernel(kernel);
    let build0 = std::time::Instant::now();
    let idx = kind.build(store.view(), bound);
    let build_t = build0.elapsed();

    // Assemble the typed plan from the flag surface (ADR-005).
    let mut builder = SearchRequest::knn(k);
    if let Some(tau) = flags.get("within") {
        builder = builder.within(tau.parse().context("--within must be a number")?);
    }
    if let Some(b) = flags.get("bound_override") {
        builder = builder.bound(parse_bound(b)?);
    }
    if let Some(budget) = flags.get("budget") {
        builder = builder.budget(budget.parse().context("--budget must be an integer")?);
    }
    if let Some(ids) = flags.get("allow") {
        builder = builder.allow(parse_id_list(ids)?);
    }
    if let Some(ids) = flags.get("deny") {
        if flags.get("allow").is_some() {
            bail!("--allow and --deny are mutually exclusive");
        }
        builder = builder.deny(parse_id_list(ids)?);
    }
    let req = builder.build();

    let q = store.vec(0);
    let t0 = std::time::Instant::now();
    let resp = idx.search(&q, &req);
    let dt = t0.elapsed();
    println!(
        "index={} bound={} kernel={} n={n} dim={dim} (built in {build_t:?})",
        idx.name(),
        bound.name(),
        store.kernel_kind().name()
    );
    println!(
        "query took {dt:?}; {} sim evals ({:.1}% of corpus), {} pruned{}",
        resp.stats.sim_evals,
        100.0 * resp.stats.sim_evals as f64 / n as f64,
        resp.stats.pruned,
        if resp.truncated { " [truncated: sim-eval budget hit]" } else { "" }
    );
    for (rank, (id, s)) in resp.hits.iter().enumerate() {
        println!("  #{rank}: id={id} sim={s:.6}");
    }
    Ok(())
}

fn cmd_stats(flags: &Flags) -> Result<()> {
    let addr = flags.str_or("addr", "127.0.0.1:7878");
    let prometheus = flags.get("prometheus").is_some_and(|v| v != "0" && v != "false");
    let mut client = server::Client::connect(
        addr.parse().with_context(|| format!("bad --addr '{addr}'"))?,
    )?;
    if prometheus {
        // One snapshot path with the JSON 'stats' op — the server renders
        // the same counters plus the observability registry's families.
        print!("{}", client.metrics()?);
        return Ok(());
    }
    let s = client.stats()?;
    println!("kernel={} corpus_size={} shards={}", s.kernel, s.corpus_size, s.shards);
    println!(
        "queries={} batches={} errors={} ctx_reuses={}",
        s.queries, s.batches, s.errors, s.ctx_reuses
    );
    println!(
        "sim_evals={} pruned={} nodes_visited={} pruned_fraction={:.4}",
        s.sim_evals, s.pruned, s.nodes_visited, s.pruned_fraction
    );
    println!(
        "latency_us p50={} p99={} max={} sum={}",
        s.latency_us_p50, s.latency_us_p99, s.latency_us_max, s.latency_us_sum
    );
    println!(
        "ingest: generations={} memtable_items={} tombstones={} inserts={} deletes={}",
        s.generations, s.memtable_items, s.tombstones, s.inserts, s.deletes
    );
    Ok(())
}

fn cmd_figures(flags: &Flags) -> Result<()> {
    let out = PathBuf::from(flags.str_or("out", "figures_out"));
    let steps = flags.usize_or("steps", figures::GRID)?;
    figures::write_all(&out, steps)?;
    println!("figures written to {}", out.display());
    print!("{}", std::fs::read_to_string(out.join("summary.txt"))?);
    Ok(())
}

fn cmd_selfcheck(flags: &Flags) -> Result<()> {
    let dir = PathBuf::from(flags.str_or("artifacts", "artifacts"));
    let engine = Engine::load(&dir)?;
    println!("platform: {}", engine.platform());
    println!("artifacts: {}", engine.manifest().artifacts.len());

    let corpus = uniform_sphere(1000, 128, 7);
    let queries = uniform_sphere(8, 128, 8);
    let mut qflat = Vec::new();
    for q in &queries {
        qflat.extend_from_slice(q.as_slice());
    }
    let mut cflat = Vec::new();
    for c in &corpus {
        cflat.extend_from_slice(c.as_slice());
    }
    let out = engine.score_topk(&qflat, 8, &cflat, 1000, 128, 5)?;
    let mut max_err = 0.0f64;
    for (qi, q) in queries.iter().enumerate() {
        let native: Vec<f64> = corpus.iter().map(|c| q.sim(c)).collect();
        let mut order: Vec<usize> = (0..1000).collect();
        order.sort_by(|&a, &b| native[b].partial_cmp(&native[a]).unwrap());
        for j in 0..5 {
            let got = out.values[qi * out.k + j] as f64;
            let want = native[order[j]];
            max_err = max_err.max((got - want).abs());
        }
    }
    println!("score_topk max |err| vs native: {max_err:.2e}");
    anyhow::ensure!(max_err < 1e-4, "runtime numerics diverge");

    // pivot_filter: verify certified intervals contain the truth.
    let pivots = uniform_sphere(16, 128, 9);
    let mut sim_qp = Vec::new();
    for q in &queries {
        for p in &pivots {
            sim_qp.push(q.sim(p) as f32);
        }
    }
    let mut sim_pc = Vec::new();
    for p in &pivots {
        for c in corpus.iter().take(1000) {
            sim_pc.push(p.sim(c) as f32);
        }
    }
    let bounds = engine.pivot_filter(&sim_qp, 8, &sim_pc, 16, 1000)?;
    let mut violations = 0;
    for (qi, q) in queries.iter().enumerate() {
        for (ci, c) in corpus.iter().enumerate() {
            let truth = q.sim(c);
            let lb = bounds.lb[qi * 1000 + ci] as f64;
            let ub = bounds.ub[qi * 1000 + ci] as f64;
            if truth < lb - 1e-4 || truth > ub + 1e-4 {
                violations += 1;
            }
        }
    }
    println!("pivot_filter interval violations: {violations}/8000");
    anyhow::ensure!(violations == 0, "pivot bounds do not contain the truth");
    println!("selfcheck OK");
    Ok(())
}
