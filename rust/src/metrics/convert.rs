//! Distances derived from cosine similarity (paper §2, Eqs. 4–6) and the
//! similarity/distance conversions used when comparing against classical
//! metric indexing.

/// Eq. 4: the common "cosine distance" `1 - sim`. **Not a metric** — it
/// violates the triangle inequality (see tests), which is the paper's
/// motivation.
#[inline]
pub fn d_cosine(sim: f64) -> f64 {
    1.0 - sim
}

/// Eq. 5: `sqrt(2 - 2 sim)` — the Euclidean distance of the normalized
/// vectors; a metric.
#[inline]
pub fn d_sqrt_cosine(sim: f64) -> f64 {
    (2.0 - 2.0 * sim).max(0.0).sqrt()
}

/// Eq. 6: `arccos(sim)` — the angle / arc length; a metric on the sphere.
#[inline]
pub fn d_arccos(sim: f64) -> f64 {
    sim.clamp(-1.0, 1.0).acos()
}

/// Inverse of Eq. 5 (distance back to similarity).
#[inline]
pub fn sim_from_sqrt_cosine(d: f64) -> f64 {
    1.0 - 0.5 * d * d
}

/// Inverse of Eq. 6.
#[inline]
pub fn sim_from_arccos(d: f64) -> f64 {
    d.cos()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sphere::uniform_sphere;
    use crate::metrics::SimVector;

    #[test]
    fn conversions_round_trip() {
        for i in 0..=100 {
            let s = -1.0 + 2.0 * i as f64 / 100.0;
            assert!((sim_from_sqrt_cosine(d_sqrt_cosine(s)) - s).abs() < 1e-12);
            assert!((sim_from_arccos(d_arccos(s)) - s).abs() < 1e-9);
        }
    }

    #[test]
    fn cosine_distance_violates_triangle_inequality() {
        // The paper's univariate counterexample style: three coplanar unit
        // vectors at angles 0, 60 and 120 degrees.
        let sim = |a: f64, b: f64| (a - b).cos();
        let (x, z, y) = (0.0f64, 1.0471975512, 2.0943951024); // 0, 60, 120 deg
        let dxy = d_cosine(sim(x, y));
        let dxz = d_cosine(sim(x, z));
        let dzy = d_cosine(sim(z, y));
        assert!(dxy > dxz + dzy + 1e-9, "expected violation: {dxy} vs {}", dxz + dzy);
    }

    #[test]
    fn sqrt_cosine_and_arccos_are_metric_on_samples() {
        let pts = uniform_sphere(60, 8, 42);
        for i in 0..pts.len() {
            for j in 0..pts.len() {
                for k in 0..pts.len() {
                    let sxy = pts[i].sim(&pts[j]);
                    let sxz = pts[i].sim(&pts[k]);
                    let szy = pts[k].sim(&pts[j]);
                    assert!(
                        d_sqrt_cosine(sxy) <= d_sqrt_cosine(sxz) + d_sqrt_cosine(szy) + 1e-9
                    );
                    assert!(d_arccos(sxy) <= d_arccos(sxz) + d_arccos(szy) + 1e-9);
                }
            }
        }
    }
}
