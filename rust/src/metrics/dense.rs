//! Dense vectors, L2-normalized at construction.

/// A dense vector stored normalized (f32 payload, f64 accumulation).
///
/// Normalizing once at ingest makes every similarity a plain dot product —
/// and makes the stored corpus directly usable as rows of the PJRT scoring
/// artifact's input buffer.
#[derive(Debug, Clone, PartialEq)]
pub struct DenseVec {
    data: Vec<f32>,
}

impl DenseVec {
    /// Build from raw values; the vector is L2-normalized (zero vectors are
    /// kept as all-zeros, so their similarity to anything is 0).
    pub fn new(raw: Vec<f32>) -> Self {
        let mut data = raw;
        let norm: f64 = data.iter().map(|&v| v as f64 * v as f64).sum::<f64>().sqrt();
        if norm > 0.0 {
            let inv = (1.0 / norm) as f32;
            for v in &mut data {
                *v *= inv;
            }
        }
        DenseVec { data }
    }

    /// Wrap values that are already unit-norm (or intentionally raw);
    /// used by generators that sample directly on the sphere.
    pub fn from_normalized(data: Vec<f32>) -> Self {
        DenseVec { data }
    }

    /// Reload this vector from raw values in place, L2-normalizing like
    /// [`DenseVec::new`] (zero vectors stay all-zeros). Reuses the
    /// existing payload buffer when its capacity suffices, so the
    /// streaming wire path can turn scratch slices into query vectors
    /// without a steady-state allocation (ADR-008).
    pub fn refill(&mut self, raw: &[f32]) {
        self.data.clear();
        self.data.extend_from_slice(raw);
        let norm: f64 = self.data.iter().map(|&v| v as f64 * v as f64).sum::<f64>().sqrt();
        if norm > 0.0 {
            let inv = (1.0 / norm) as f32;
            for v in &mut self.data {
                *v *= inv;
            }
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Dot product via the canonical scalar kernel
    /// ([`crate::storage::dot_slice`]: 4-way unrolled f64 accumulation,
    /// clamped to `[-1, 1]`). The batched hot paths go through the
    /// `storage` blocked kernels or the PJRT artifact, all of which produce
    /// bit-identical results to this per pair.
    ///
    /// # Panics
    /// Panics on dimension mismatch (no silent truncation, even in release
    /// builds).
    #[inline]
    pub fn dot(&self, other: &Self) -> f64 {
        crate::storage::dot_slice(&self.data, &other.data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalizes_on_construction() {
        let v = DenseVec::new(vec![3.0, 4.0]);
        let norm: f32 = v.as_slice().iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-6);
    }

    #[test]
    fn refill_matches_new_and_reuses_the_buffer() {
        let mut v = DenseVec::new(vec![0.0; 8]);
        let cap = v.data.capacity();
        v.refill(&[3.0, 4.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        assert_eq!(v, DenseVec::new(vec![3.0, 4.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]));
        assert_eq!(v.data.capacity(), cap, "refill reallocated the payload");
        // Zero vectors stay all-zeros, like `new`.
        v.refill(&[0.0; 8]);
        assert_eq!(v, DenseVec::new(vec![0.0; 8]));
    }

    #[test]
    fn dot_matches_naive_for_odd_lengths() {
        for n in [1usize, 2, 3, 5, 7, 13, 100, 101] {
            let a: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
            let b: Vec<f32> = (0..n).map(|i| (i as f32 * 0.71).cos()).collect();
            let da = DenseVec::new(a.clone());
            let db = DenseVec::new(b.clone());
            let naive: f64 = da
                .as_slice()
                .iter()
                .zip(db.as_slice())
                .map(|(&x, &y)| x as f64 * y as f64)
                .sum();
            assert!((da.dot(&db) - naive.clamp(-1.0, 1.0)).abs() < 1e-9, "n={n}");
        }
    }

    #[test]
    #[should_panic(expected = "dimension mismatch")]
    fn dot_rejects_dimension_mismatch() {
        let a = DenseVec::new(vec![1.0, 0.0, 0.0]);
        let b = DenseVec::new(vec![1.0, 0.0]);
        a.dot(&b);
    }

    #[test]
    fn dot_clamps_to_cosine_range() {
        let v = DenseVec::new(vec![1.0; 64]);
        assert!(v.dot(&v) <= 1.0);
        let w = DenseVec::new(vec![-1.0; 64]);
        assert!(v.dot(&w) >= -1.0);
    }
}
