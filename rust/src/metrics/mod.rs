//! Cosine similarity over dense and sparse vectors, and the derived
//! distances of paper §2 (Eqs. 4–6).

pub mod convert;
pub mod dense;

pub use convert::{d_arccos, d_cosine, d_sqrt_cosine};
pub use dense::DenseVec;

use crate::sparse::SparseVec;

/// A vector that can report its cosine similarity to another of its type.
///
/// Implementations pre-normalize at construction so `sim` is a plain dot
/// product — the paper's "best practice" of working with L2-normalized data.
pub trait SimVector: Clone + Send + Sync + 'static {
    /// Cosine similarity in `[-1, 1]` (0 against the zero vector).
    fn sim(&self, other: &Self) -> f64;

    /// Dimensionality (vector-space dimension, not #non-zeros).
    fn dim(&self) -> usize;
}

impl SimVector for DenseVec {
    #[inline]
    fn sim(&self, other: &Self) -> f64 {
        self.dot(other)
    }

    fn dim(&self) -> usize {
        self.len()
    }
}

impl SimVector for SparseVec {
    #[inline]
    fn sim(&self, other: &Self) -> f64 {
        self.dot(other)
    }

    fn dim(&self) -> usize {
        self.dim()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_and_sparse_agree() {
        let a = vec![0.0f32, 1.0, 0.0, 2.0, 0.0, 3.0];
        let b = vec![1.0f32, 1.0, 0.0, 0.0, 0.0, 4.0];
        let da = DenseVec::new(a.clone());
        let db = DenseVec::new(b.clone());
        let sa = SparseVec::from_dense(&a);
        let sb = SparseVec::from_dense(&b);
        assert!((da.sim(&db) - sa.sim(&sb)).abs() < 1e-6);
    }

    #[test]
    fn sim_is_scale_invariant() {
        let a = DenseVec::new(vec![1.0, 2.0, 3.0]);
        let b = DenseVec::new(vec![3.0, 2.0, 1.0]);
        let a4 = DenseVec::new(vec![4.0, 8.0, 12.0]);
        assert!((a.sim(&b) - a4.sim(&b)).abs() < 1e-6);
    }

    #[test]
    fn self_similarity_is_one_zero_vector_is_zero() {
        let a = DenseVec::new(vec![0.3, -0.4, 0.5]);
        assert!((a.sim(&a) - 1.0).abs() < 1e-6);
        let z = DenseVec::new(vec![0.0, 0.0, 0.0]);
        assert_eq!(z.sim(&a), 0.0);
        assert_eq!(z.sim(&z), 0.0);
    }
}
