//! Query observability (ADR-007): traced search (EXPLAIN), bound-slack
//! histograms, per-stage span timings, per-shard / per-generation work
//! breakdowns, and a slow-query ring — exposed as Prometheus text.
//!
//! Everything here is **zero-overhead when off** and allocation-free on
//! the query path:
//!
//! * Trace recording is gated on a per-request `armed` flag; when a plan
//!   does not ask for a trace every hook is a single predicted branch.
//!   The event buffer is fixed-capacity ([`TRACE_CAP`]) and lives in the
//!   per-context kernel scratch, so a traced query writes into pre-sized
//!   storage (the one-time `arm` reservation is the only allocation a
//!   traced request ever makes inside the engine).
//! * Bound-slack samples land in a plain per-context array
//!   ([`SlackWindow`]) and are drained into the global lock-free
//!   [`ObsRegistry`] by the owning worker between batches — traversals
//!   never touch an atomic.
//! * Span timings, per-shard counters, and the slow-query floor check are
//!   single relaxed atomic ops; the slow-query ring itself is a
//!   fixed-capacity array behind a mutex that is only locked when a query
//!   is slower than the current top-N floor.
//!
//! The registry is a process-wide static ([`OBS`]) because observability
//! is a property of the serving process, not of one coordinator value —
//! the `metrics` wire op and `simetra stats --prometheus` both render the
//! same snapshot via [`ObsRegistry::render_into`].

use std::fmt::Write as _;
use std::sync::Mutex;
use std::time::Duration;

use crate::sync::{AtomicU64, Ordering};

use crate::bounds::BoundKind;

/// Maximum events captured per traced request; beyond this the trace is
/// marked truncated and further events are dropped (never reallocated).
pub const TRACE_CAP: usize = 4096;

/// Linear slack-histogram buckets of width [`SLACK_WIDTH`] over `[0, 2)`;
/// slack of a sound upper bound on cosine similarity always fits.
pub const SLACK_BUCKETS: usize = 16;

/// Width of one slack bucket.
pub const SLACK_WIDTH: f64 = 0.125;

/// Number of `BoundKind` variants (slack histograms key on the ordinal).
/// `Auto` has a row for layout parity but never accumulates: slack is
/// always recorded under the *resolved* kind, so its row renders empty.
pub const BOUND_KINDS: usize = 10;

/// Samples a (index, bound) slack histogram needs before the `Auto`
/// selector trusts its mean — below this the cell is "cold".
pub const AUTO_MIN_SAMPLES: u64 = 1024;

/// Mean-slack margin (in similarity units) the `Auto` selector requires:
/// the exact Ptolemaic family must *beat* Mult by this much to amortize
/// its extra per-candidate arithmetic; the sqrt-free variant merely has to
/// stay within it.
pub const AUTO_MARGIN: f64 = 0.01;

/// Number of index kinds (must track `coordinator::IndexKind`).
pub const INDEX_KINDS: usize = 7;

/// Label names for index ordinals, in `coordinator::IndexKind` order
/// (pinned by a test over `IndexKind::ordinal`/`name`).
pub const INDEX_NAMES: [&str; INDEX_KINDS] =
    ["linear", "vp", "ball", "m-tree", "cover", "laesa", "gnat"];

/// Slots for per-shard work breakdowns (shards beyond this share the last
/// slot; real deployments shard far below it).
pub const SHARD_SLOTS: usize = 64;

/// Slots for per-generation work breakdowns (keyed by the generation's
/// position in the published set, clamped).
pub const GEN_SLOTS: usize = 64;

/// Capacity of the slow-query ring (top-N by latency).
pub const SLOW_CAP: usize = 16;

/// Log2-nanosecond buckets for stage spans: bucket `i` holds durations of
/// `[2^(i-1), 2^i)` ns (bucket 0 is `0 ns`), the same edge scheme as the
/// coordinator latency histogram but in nanoseconds.
pub const SPAN_BUCKETS: usize = 40;

// ---------------------------------------------------------------------------
// Trace events
// ---------------------------------------------------------------------------

/// What a single trace event describes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceKind {
    /// A tree node was visited; `id` is the node's representative item.
    Visit,
    /// A subtree/region/candidate was pruned; `bound` is the certified
    /// upper bound that ruled it out, `id` its representative item.
    Prune,
    /// An exact similarity was computed; `bound` is the certified upper
    /// bound the traversal held for `id` (1.0 when it had none), `sim`
    /// the exact value — `bound - sim` is the observed slack.
    Eval,
    /// A blocked kernel scan ran; `id` is the number of rows scanned and
    /// `bound` the number of exact evaluations it performed.
    Scan,
    /// The `sim_evals` budget ran out and the traversal stopped.
    BudgetStop,
    /// An id-filter was armed for this request; `id` is the filter size.
    FilterGate,
}

impl TraceKind {
    /// Stable lowercase wire token.
    pub fn token(self) -> &'static str {
        match self {
            TraceKind::Visit => "visit",
            TraceKind::Prune => "prune",
            TraceKind::Eval => "eval",
            TraceKind::Scan => "scan",
            TraceKind::BudgetStop => "budget_stop",
            TraceKind::FilterGate => "filter_gate",
        }
    }

    /// Inverse of [`TraceKind::token`].
    pub fn parse(s: &str) -> Option<TraceKind> {
        Some(match s {
            "visit" => TraceKind::Visit,
            "prune" => TraceKind::Prune,
            "eval" => TraceKind::Eval,
            "scan" => TraceKind::Scan,
            "budget_stop" => TraceKind::BudgetStop,
            "filter_gate" => TraceKind::FilterGate,
            _ => return None,
        })
    }
}

/// One bounded-log entry of a traced traversal. All fields are finite —
/// events with no bound/sim carry `0.0` (or `1.0` for the trivial upper
/// bound) so the wire round-trip stays exact under `PartialEq`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    pub kind: TraceKind,
    pub id: u64,
    pub bound: f64,
    pub sim: f64,
}

impl TraceEvent {
    pub fn visit(id: u64) -> TraceEvent {
        TraceEvent { kind: TraceKind::Visit, id, bound: 0.0, sim: 0.0 }
    }

    pub fn prune(id: u64, bound: f64) -> TraceEvent {
        TraceEvent { kind: TraceKind::Prune, id, bound, sim: 0.0 }
    }

    pub fn eval(id: u64, bound: f64, sim: f64) -> TraceEvent {
        TraceEvent { kind: TraceKind::Eval, id, bound, sim }
    }

    pub fn scan(rows: u64, evals: u64) -> TraceEvent {
        TraceEvent { kind: TraceKind::Scan, id: rows, bound: evals as f64, sim: 0.0 }
    }

    pub fn budget_stop() -> TraceEvent {
        TraceEvent { kind: TraceKind::BudgetStop, id: 0, bound: 0.0, sim: 0.0 }
    }

    pub fn filter_gate(filter_len: u64) -> TraceEvent {
        TraceEvent { kind: TraceKind::FilterGate, id: filter_len, bound: 0.0, sim: 0.0 }
    }
}

/// Fixed-capacity per-request event log. Disarmed it is a single branch
/// per hook; armed it appends into storage reserved once per context.
#[derive(Debug, Default)]
pub struct TraceBuf {
    armed: bool,
    truncated: bool,
    events: Vec<TraceEvent>,
}

impl TraceBuf {
    /// Start recording for one request. The first arm on a context
    /// reserves [`TRACE_CAP`] slots; later arms reuse the storage.
    pub fn arm(&mut self) {
        self.armed = true;
        self.truncated = false;
        self.events.clear();
        if self.events.capacity() < TRACE_CAP {
            self.events.reserve_exact(TRACE_CAP - self.events.capacity());
        }
    }

    pub fn disarm(&mut self) {
        self.armed = false;
    }

    #[inline]
    pub fn armed(&self) -> bool {
        self.armed
    }

    /// True when events were dropped at [`TRACE_CAP`].
    pub fn truncated(&self) -> bool {
        self.truncated
    }

    #[inline]
    pub fn push(&mut self, ev: TraceEvent) {
        if !self.armed {
            return;
        }
        if self.events.len() < TRACE_CAP {
            self.events.push(ev);
        } else {
            self.truncated = true;
        }
    }

    /// Move the recorded events into `out` (replacing its contents) and
    /// clear the log; the buffer stays armed until [`TraceBuf::disarm`].
    pub fn take_into(&mut self, out: &mut Vec<TraceEvent>) {
        out.clear();
        out.extend_from_slice(&self.events);
        self.events.clear();
    }
}

// ---------------------------------------------------------------------------
// Per-context slack window
// ---------------------------------------------------------------------------

#[inline]
fn slack_bucket(slack: f64) -> usize {
    ((slack.max(0.0) / SLACK_WIDTH) as usize).min(SLACK_BUCKETS - 1)
}

/// Per-`QueryContext` bound-slack accumulator: traversals record into a
/// plain array (no atomics on the query path); the owning worker drains
/// it into the global [`ObsRegistry`] keyed by its index kind.
#[derive(Debug)]
pub struct SlackWindow {
    counts: [[u32; SLACK_BUCKETS]; BOUND_KINDS],
    sum_micros: [u64; BOUND_KINDS],
    any: bool,
}

impl Default for SlackWindow {
    fn default() -> Self {
        SlackWindow {
            counts: [[0; SLACK_BUCKETS]; BOUND_KINDS],
            sum_micros: [0; BOUND_KINDS],
            any: false,
        }
    }
}

impl SlackWindow {
    #[inline]
    pub fn record(&mut self, bound: BoundKind, slack: f64) {
        let bi = bound as usize;
        self.counts[bi][slack_bucket(slack)] += 1;
        self.sum_micros[bi] += (slack.max(0.0) * 1e6) as u64;
        self.any = true;
    }

    /// Flush every sample into `reg` under `index` (an
    /// `IndexKind::ordinal`) and reset the window.
    pub fn drain_into(&mut self, reg: &ObsRegistry, index: usize) {
        if !self.any {
            return;
        }
        let ii = index.min(INDEX_KINDS - 1);
        for (bi, row) in self.counts.iter_mut().enumerate() {
            for (bu, c) in row.iter_mut().enumerate() {
                if *c > 0 {
                    reg.slack[ii][bi].buckets[bu].fetch_add(*c as u64, Ordering::Relaxed);
                    *c = 0;
                }
            }
            if self.sum_micros[bi] > 0 {
                let micros = self.sum_micros[bi];
                reg.slack[ii][bi].sum_micros.fetch_add(micros, Ordering::Relaxed);
                self.sum_micros[bi] = 0;
            }
        }
        self.any = false;
    }
}

// ---------------------------------------------------------------------------
// Stages
// ---------------------------------------------------------------------------

/// Pipeline stages with span-timing histograms.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    Parse,
    Plan,
    ShardFanout,
    Traversal,
    KernelScan,
    Merge,
    Serialize,
}

/// Number of [`Stage`] variants.
pub const STAGES: usize = 7;

impl Stage {
    pub const ALL: [Stage; STAGES] = [
        Stage::Parse,
        Stage::Plan,
        Stage::ShardFanout,
        Stage::Traversal,
        Stage::KernelScan,
        Stage::Merge,
        Stage::Serialize,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Stage::Parse => "parse",
            Stage::Plan => "plan",
            Stage::ShardFanout => "shard_fanout",
            Stage::Traversal => "traversal",
            Stage::KernelScan => "kernel_scan",
            Stage::Merge => "merge",
            Stage::Serialize => "serialize",
        }
    }
}

// ---------------------------------------------------------------------------
// Slow-query ring
// ---------------------------------------------------------------------------

/// Summary of one completed request, kept when it ranks in the top
/// [`SLOW_CAP`] by latency.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SlowEntry {
    pub latency_us: u64,
    /// `"knn"`, `"range"`, or `"knn_within"`.
    pub mode: &'static str,
    pub k: u64,
    /// Similarity floor; meaningful only when `has_tau`.
    pub tau: f64,
    pub has_tau: bool,
    /// Bound-override token, or `"default"`.
    pub bound: &'static str,
    pub hits: u64,
    pub sim_evals: u64,
    pub nodes_visited: u64,
    pub pruned: u64,
    pub truncated: bool,
}

/// Fixed-capacity top-N-by-latency ring: offers replace the current
/// minimum once full, so the ring always holds the N slowest seen.
#[derive(Debug)]
pub struct SlowRing {
    entries: [Option<SlowEntry>; SLOW_CAP],
}

impl SlowRing {
    pub const fn new() -> SlowRing {
        SlowRing { entries: [None; SLOW_CAP] }
    }

    /// Insert if a slot is free or `e` beats the slowest ring minimum;
    /// returns whether the entry was kept.
    pub fn offer(&mut self, e: SlowEntry) -> bool {
        let mut free = None;
        let mut min_i = 0usize;
        let mut min_v = u64::MAX;
        for (i, slot) in self.entries.iter().enumerate() {
            match slot {
                None => {
                    free = Some(i);
                    break;
                }
                Some(s) if s.latency_us < min_v => {
                    min_v = s.latency_us;
                    min_i = i;
                }
                Some(_) => {}
            }
        }
        if let Some(i) = free {
            self.entries[i] = Some(e);
            return true;
        }
        if e.latency_us > min_v {
            self.entries[min_i] = Some(e);
            return true;
        }
        false
    }

    /// Minimum latency a new entry must beat; `0` until the ring fills.
    pub fn floor(&self) -> u64 {
        let mut min_v = u64::MAX;
        for slot in &self.entries {
            match slot {
                None => return 0,
                Some(s) => min_v = min_v.min(s.latency_us),
            }
        }
        min_v
    }

    pub fn len(&self) -> usize {
        self.entries.iter().filter(|s| s.is_some()).count()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.iter().all(|s| s.is_none())
    }

    /// Entries sorted by latency, slowest first (allocates; exposition
    /// and test path only).
    pub fn sorted(&self) -> Vec<SlowEntry> {
        let mut v: Vec<SlowEntry> = self.entries.iter().flatten().copied().collect();
        v.sort_unstable_by(|a, b| b.latency_us.cmp(&a.latency_us));
        v
    }
}

impl Default for SlowRing {
    fn default() -> Self {
        SlowRing::new()
    }
}

// ---------------------------------------------------------------------------
// Global registry
// ---------------------------------------------------------------------------

// The `_ZERO` consts below are deliberate const-seeded templates: each use
// site *copies* the interior-mutable value into a fresh static cell (array
// repetition in `ObsRegistry::new`), which is exactly the pattern the lint
// exists to flag when done by accident.
#[allow(clippy::declare_interior_mutable_const)]
const ATOMIC_ZERO: AtomicU64 = AtomicU64::new(0);

/// One (index kind, bound kind) slack histogram cell.
struct SlackHist {
    buckets: [AtomicU64; SLACK_BUCKETS],
    sum_micros: AtomicU64,
}

// Const template, copied per array slot (see ATOMIC_ZERO above).
#[allow(clippy::declare_interior_mutable_const)]
const SLACK_HIST_ZERO: SlackHist =
    SlackHist { buckets: [ATOMIC_ZERO; SLACK_BUCKETS], sum_micros: ATOMIC_ZERO };

// Const template, copied per array slot (see ATOMIC_ZERO above).
#[allow(clippy::declare_interior_mutable_const)]
const SLACK_ROW_ZERO: [SlackHist; BOUND_KINDS] = [SLACK_HIST_ZERO; BOUND_KINDS];

/// One stage-span histogram (log2-ns buckets + sum).
struct SpanHist {
    buckets: [AtomicU64; SPAN_BUCKETS],
    sum_ns: AtomicU64,
}

// Const template, copied per array slot (see ATOMIC_ZERO above).
#[allow(clippy::declare_interior_mutable_const)]
const SPAN_HIST_ZERO: SpanHist =
    SpanHist { buckets: [ATOMIC_ZERO; SPAN_BUCKETS], sum_ns: ATOMIC_ZERO };

/// Per-shard / per-generation work counters.
struct WorkCell {
    queries: AtomicU64,
    sim_evals: AtomicU64,
    nodes_visited: AtomicU64,
    pruned: AtomicU64,
}

// Const template, copied per array slot (see ATOMIC_ZERO above).
#[allow(clippy::declare_interior_mutable_const)]
const WORK_CELL_ZERO: WorkCell = WorkCell {
    queries: ATOMIC_ZERO,
    sim_evals: ATOMIC_ZERO,
    nodes_visited: ATOMIC_ZERO,
    pruned: ATOMIC_ZERO,
};

#[inline]
fn span_bucket(ns: u64) -> usize {
    ((64 - ns.leading_zeros()) as usize).min(SPAN_BUCKETS - 1)
}

/// Process-wide lock-free observability registry.
pub struct ObsRegistry {
    slack: [[SlackHist; BOUND_KINDS]; INDEX_KINDS],
    stages: [SpanHist; STAGES],
    shards: [WorkCell; SHARD_SLOTS],
    gens: [WorkCell; GEN_SLOTS],
    slow: Mutex<SlowRing>,
    slow_floor: AtomicU64,
}

/// The process-wide registry every layer records into.
pub static OBS: ObsRegistry = ObsRegistry::new();

impl ObsRegistry {
    pub const fn new() -> ObsRegistry {
        ObsRegistry {
            slack: [SLACK_ROW_ZERO; INDEX_KINDS],
            stages: [SPAN_HIST_ZERO; STAGES],
            shards: [WORK_CELL_ZERO; SHARD_SLOTS],
            gens: [WORK_CELL_ZERO; GEN_SLOTS],
            slow: Mutex::new(SlowRing::new()),
            slow_floor: AtomicU64::new(0),
        }
    }

    /// Record one span for `stage`.
    #[inline]
    pub fn record_stage(&self, stage: Stage, took: Duration) {
        let ns = took.as_nanos() as u64;
        let h = &self.stages[stage as usize];
        h.buckets[span_bucket(ns)].fetch_add(1, Ordering::Relaxed);
        h.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Fold one batch's work into the per-shard breakdown.
    pub fn record_shard(&self, shard: usize, queries: u64, evals: u64, nodes: u64, pruned: u64) {
        let c = &self.shards[shard.min(SHARD_SLOTS - 1)];
        c.queries.fetch_add(queries, Ordering::Relaxed);
        c.sim_evals.fetch_add(evals, Ordering::Relaxed);
        c.nodes_visited.fetch_add(nodes, Ordering::Relaxed);
        c.pruned.fetch_add(pruned, Ordering::Relaxed);
    }

    /// Fold one generation visit's work into the per-generation
    /// breakdown (`pos` is the generation's position in the set).
    pub fn record_gen(&self, pos: usize, queries: u64, evals: u64, nodes: u64, pruned: u64) {
        let c = &self.gens[pos.min(GEN_SLOTS - 1)];
        c.queries.fetch_add(queries, Ordering::Relaxed);
        c.sim_evals.fetch_add(evals, Ordering::Relaxed);
        c.nodes_visited.fetch_add(nodes, Ordering::Relaxed);
        c.pruned.fetch_add(pruned, Ordering::Relaxed);
    }

    /// Offer a completed query to the slow-query ring. The common case
    /// (faster than the current top-N floor) is one relaxed load.
    pub fn note_query(&self, e: SlowEntry) {
        let floor = self.slow_floor.load(Ordering::Relaxed);
        if floor > 0 && e.latency_us <= floor {
            return;
        }
        let mut ring = match self.slow.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        ring.offer(e);
        self.slow_floor.store(ring.floor(), Ordering::Relaxed);
    }

    /// Total slack samples recorded under `(index, bound)`.
    pub fn slack_count(&self, index: usize, bound: BoundKind) -> u64 {
        let h = &self.slack[index.min(INDEX_KINDS - 1)][bound as usize];
        h.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Mean observed slack (`ub - sim` per admitted candidate) and sample
    /// count for `(index, bound)`; `None` when no samples were recorded.
    pub fn mean_slack(&self, index: usize, bound: BoundKind) -> Option<(f64, u64)> {
        let h = &self.slack[index.min(INDEX_KINDS - 1)][bound as usize];
        let n: u64 = h.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum();
        if n == 0 {
            return None;
        }
        Some((h.sum_micros.load(Ordering::Relaxed) as f64 / 1e6 / n as f64, n))
    }

    /// Resolve [`BoundKind::Auto`] for one index kind from the live slack
    /// histograms (ADR-009).
    ///
    /// Measured mean slack is the tightness signal: lower slack means the
    /// upper bounds hug the true similarities and prune more. The policy,
    /// over cells with at least [`AUTO_MIN_SAMPLES`] samples:
    ///
    /// 1. `Ptolemaic` if its mean slack beats Mult's by [`AUTO_MARGIN`]
    ///    (the measured tightness win pays for the extra pair arithmetic);
    /// 2. else `PtolemaicFast` if its mean slack is within the margin of
    ///    Mult's (equal tightness at lower per-candidate cost);
    /// 3. else `Mult` once its own histogram is warm;
    /// 4. `None` while Mult's histogram is cold — the caller falls back to
    ///    a fixed default so behavior is deterministic from process start.
    ///
    /// Candidate families only warm up once traffic has actually run them
    /// (e.g. canary requests with an explicit override); until then the
    /// selector stays on the warm baseline. Exactness does not depend on
    /// the choice — every family is valid — so a selection flip mid-stream
    /// can never change results, only cost; the search frame still
    /// snapshots one selection per query so per-query traces are coherent.
    pub fn select_bound(&self, index: usize) -> Option<BoundKind> {
        let warm = |b: BoundKind| {
            self.mean_slack(index, b).filter(|&(_, n)| n >= AUTO_MIN_SAMPLES).map(|(m, _)| m)
        };
        let mult = warm(BoundKind::Mult)?;
        if let Some(p) = warm(BoundKind::Ptolemaic) {
            if p + AUTO_MARGIN <= mult {
                return Some(BoundKind::Ptolemaic);
            }
        }
        if let Some(f) = warm(BoundKind::PtolemaicFast) {
            if f <= mult + AUTO_MARGIN {
                return Some(BoundKind::PtolemaicFast);
            }
        }
        Some(BoundKind::Mult)
    }

    /// Total spans recorded for `stage`.
    pub fn stage_count(&self, stage: Stage) -> u64 {
        let h = &self.stages[stage as usize];
        h.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Slowest-first snapshot of the slow-query ring.
    pub fn slow_queries(&self) -> Vec<SlowEntry> {
        match self.slow.lock() {
            Ok(g) => g.sorted(),
            Err(poisoned) => poisoned.into_inner().sorted(),
        }
    }

    /// Render every family in Prometheus text format into `buf`.
    ///
    /// Histogram `le` edges follow the recording buckets exactly: slack
    /// buckets are linear with width [`SLACK_WIDTH`] (the top edge `2`
    /// doubles as `+Inf` — slack of a sound bound never exceeds it); span
    /// buckets are log2 nanoseconds with inclusive edges `2^i - 1`.
    pub fn render_into(&self, buf: &mut String) {
        buf.push_str("# HELP simetra_bound_slack Bound slack ub-sim of evaluated candidates.\n");
        buf.push_str("# TYPE simetra_bound_slack histogram\n");
        for (ii, iname) in INDEX_NAMES.iter().enumerate() {
            for bk in BoundKind::ALL {
                let h = &self.slack[ii][bk as usize];
                let total: u64 = h.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum();
                if total == 0 {
                    continue;
                }
                let l = format!("index=\"{}\",bound=\"{}\"", iname, bk.token());
                let mut cum = 0u64;
                for (bu, cell) in h.buckets.iter().enumerate() {
                    cum += cell.load(Ordering::Relaxed);
                    let le = (bu + 1) as f64 * SLACK_WIDTH;
                    let _ = writeln!(buf, "simetra_bound_slack_bucket{{{l},le=\"{le}\"}} {cum}");
                }
                let _ = writeln!(buf, "simetra_bound_slack_bucket{{{l},le=\"+Inf\"}} {total}");
                let sum = h.sum_micros.load(Ordering::Relaxed) as f64 / 1e6;
                let _ = writeln!(buf, "simetra_bound_slack_sum{{{l}}} {sum}");
                let _ = writeln!(buf, "simetra_bound_slack_count{{{l}}} {total}");
            }
        }

        buf.push_str("# HELP simetra_stage_duration_ns Per-stage span timings.\n");
        buf.push_str("# TYPE simetra_stage_duration_ns histogram\n");
        for stage in Stage::ALL {
            let h = &self.stages[stage as usize];
            let l = format!("stage=\"{}\"", stage.name());
            let mut cum = 0u64;
            for (bu, cell) in h.buckets.iter().enumerate() {
                let c = cell.load(Ordering::Relaxed);
                cum += c;
                // Sparse: skip interior zero buckets to keep the page
                // small (cumulative counts stay exact).
                if c == 0 && bu != 0 && bu != SPAN_BUCKETS - 1 {
                    continue;
                }
                let le = (1u64 << bu) - 1;
                let _ = writeln!(buf, "simetra_stage_duration_ns_bucket{{{l},le=\"{le}\"}} {cum}");
            }
            let _ = writeln!(buf, "simetra_stage_duration_ns_bucket{{{l},le=\"+Inf\"}} {cum}");
            let sum = h.sum_ns.load(Ordering::Relaxed);
            let _ = writeln!(buf, "simetra_stage_duration_ns_sum{{{l}}} {sum}");
            let _ = writeln!(buf, "simetra_stage_duration_ns_count{{{l}}} {cum}");
        }

        render_work(buf, "shard", &self.shards);
        render_work(buf, "generation", &self.gens);

        buf.push_str("# HELP simetra_slow_query_latency_us Slowest queries, top-N by latency.\n");
        buf.push_str("# TYPE simetra_slow_query_latency_us gauge\n");
        buf.push_str("# HELP simetra_slow_query_sim_evals Exact evals of the slowest queries.\n");
        buf.push_str("# TYPE simetra_slow_query_sim_evals gauge\n");
        for (rank, e) in self.slow_queries().iter().enumerate() {
            let (m, k, b) = (e.mode, e.k, e.bound);
            let l = format!("rank=\"{rank}\",mode=\"{m}\",k=\"{k}\",bound=\"{b}\"");
            let _ = writeln!(buf, "simetra_slow_query_latency_us{{{l}}} {}", e.latency_us);
            let _ = writeln!(buf, "simetra_slow_query_sim_evals{{{l}}} {}", e.sim_evals);
        }
    }
}

fn render_work(buf: &mut String, what: &str, cells: &[WorkCell]) {
    let _ = writeln!(buf, "# HELP simetra_{what}_work Per-{what} query work counters.");
    let _ = writeln!(buf, "# TYPE simetra_{what}_work counter");
    for (i, c) in cells.iter().enumerate() {
        let q = c.queries.load(Ordering::Relaxed);
        if q == 0 {
            continue;
        }
        let pairs = [
            ("queries", q),
            ("sim_evals", c.sim_evals.load(Ordering::Relaxed)),
            ("nodes_visited", c.nodes_visited.load(Ordering::Relaxed)),
            ("pruned", c.pruned.load(Ordering::Relaxed)),
        ];
        for (name, v) in pairs {
            let l = format!("{what}=\"{i}\",counter=\"{name}\"");
            let _ = writeln!(buf, "simetra_{what}_work{{{l}}} {v}");
        }
    }
}

impl Default for ObsRegistry {
    fn default() -> Self {
        ObsRegistry::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(latency_us: u64) -> SlowEntry {
        SlowEntry {
            latency_us,
            mode: "knn",
            k: 10,
            tau: 0.0,
            has_tau: false,
            bound: "default",
            hits: 10,
            sim_evals: 100,
            nodes_visited: 20,
            pruned: 5,
            truncated: false,
        }
    }

    #[test]
    fn trace_buf_caps_at_capacity_and_marks_truncation() {
        let mut t = TraceBuf::default();
        t.push(TraceEvent::visit(1));
        let mut out = vec![TraceEvent::visit(9)];
        t.take_into(&mut out);
        assert!(out.is_empty(), "disarmed pushes record nothing");
        t.arm();
        for i in 0..(TRACE_CAP as u64 + 10) {
            t.push(TraceEvent::visit(i));
        }
        assert!(t.truncated());
        t.take_into(&mut out);
        assert_eq!(out.len(), TRACE_CAP);
        assert_eq!(out[0], TraceEvent::visit(0));
        t.disarm();
        assert!(!t.armed());
    }

    #[test]
    fn slow_ring_fills_then_evicts_minimum() {
        let mut r = SlowRing::new();
        assert_eq!(r.floor(), 0);
        assert!(r.is_empty());
        for i in 0..SLOW_CAP as u64 {
            assert!(r.offer(entry(100 + i)));
        }
        assert_eq!(r.len(), SLOW_CAP);
        assert_eq!(r.floor(), 100);
        // Slower than the floor: evicts the minimum.
        assert!(r.offer(entry(500)));
        assert_eq!(r.floor(), 101);
        // Not slower than the (new) floor: rejected.
        assert!(!r.offer(entry(101)));
        assert!(!r.offer(entry(50)));
        let sorted = r.sorted();
        assert_eq!(sorted.len(), SLOW_CAP);
        assert_eq!(sorted[0].latency_us, 500);
        assert!(sorted.windows(2).all(|w| w[0].latency_us >= w[1].latency_us));
    }

    #[test]
    fn registry_note_query_respects_floor() {
        let reg = ObsRegistry::new();
        for i in 0..SLOW_CAP as u64 {
            reg.note_query(entry(1000 + i));
        }
        reg.note_query(entry(1)); // below floor: dropped without locking
        let snap = reg.slow_queries();
        assert_eq!(snap.len(), SLOW_CAP);
        assert!(snap.iter().all(|e| e.latency_us >= 1000));
        reg.note_query(entry(9999));
        assert_eq!(reg.slow_queries()[0].latency_us, 9999);
    }

    #[test]
    fn slack_window_drains_into_registry() {
        let reg = ObsRegistry::new();
        let mut w = SlackWindow::default();
        w.record(BoundKind::Mult, 0.0);
        w.record(BoundKind::Mult, 0.13);
        w.record(BoundKind::Mult, 5.0); // clamped into the last bucket
        w.record(BoundKind::Arccos, 0.5);
        w.drain_into(&reg, 1); // "vp"
        assert_eq!(reg.slack_count(1, BoundKind::Mult), 3);
        assert_eq!(reg.slack_count(1, BoundKind::Arccos), 1);
        assert_eq!(reg.slack_count(0, BoundKind::Mult), 0);
        // Drained windows are empty: a second drain adds nothing.
        w.drain_into(&reg, 1);
        assert_eq!(reg.slack_count(1, BoundKind::Mult), 3);
    }

    #[test]
    fn render_emits_parseable_prometheus_text() {
        let reg = ObsRegistry::new();
        let mut w = SlackWindow::default();
        w.record(BoundKind::Mult, 0.3);
        w.drain_into(&reg, 1);
        reg.record_stage(Stage::Parse, Duration::from_micros(3));
        reg.record_shard(0, 4, 400, 40, 10);
        reg.record_gen(2, 1, 50, 5, 1);
        reg.note_query(entry(42));
        let mut buf = String::new();
        reg.render_into(&mut buf);
        for needle in [
            "# TYPE simetra_bound_slack histogram",
            "simetra_bound_slack_bucket{index=\"vp\",bound=\"mult\",le=\"+Inf\"} 1",
            "simetra_bound_slack_count{index=\"vp\",bound=\"mult\"} 1",
            "simetra_stage_duration_ns_bucket{stage=\"parse\",le=\"+Inf\"} 1",
            "simetra_shard_work{shard=\"0\",counter=\"sim_evals\"} 400",
            "simetra_generation_work{generation=\"2\",counter=\"queries\"} 1",
            "simetra_slow_query_latency_us{rank=\"0\",mode=\"knn\"",
        ] {
            assert!(buf.contains(needle), "missing {needle:?} in:\n{buf}");
        }
        // Every non-comment line is `name{labels} value`.
        for line in buf.lines() {
            if line.starts_with('#') || line.is_empty() {
                continue;
            }
            let (name_labels, value) = line.rsplit_once(' ').expect("sample line");
            assert!(value.parse::<f64>().is_ok(), "bad value in {line:?}");
            assert!(name_labels.starts_with("simetra_"), "bad family in {line:?}");
        }
    }

    fn warm(reg: &ObsRegistry, index: usize, bound: BoundKind, slack: f64) {
        let mut w = SlackWindow::default();
        for _ in 0..AUTO_MIN_SAMPLES {
            w.record(bound, slack);
        }
        w.drain_into(reg, index);
    }

    #[test]
    fn auto_selector_policy() {
        let reg = ObsRegistry::new();
        // Cold registry: no selection, caller uses the fixed fallback.
        assert_eq!(reg.select_bound(5), None);
        // Warm baseline only: stay on Mult.
        warm(&reg, 5, BoundKind::Mult, 0.5);
        assert_eq!(reg.select_bound(5), Some(BoundKind::Mult));
        // A candidate family below AUTO_MIN_SAMPLES stays invisible.
        let mut w = SlackWindow::default();
        w.record(BoundKind::Ptolemaic, 0.0);
        w.drain_into(&reg, 5);
        assert_eq!(reg.select_bound(5), Some(BoundKind::Mult));
        // Warm and measurably tighter: the exact family wins.
        warm(&reg, 5, BoundKind::Ptolemaic, 0.2);
        assert_eq!(reg.select_bound(5), Some(BoundKind::Ptolemaic));
        // Selections are per index kind — other rows stay cold.
        assert_eq!(reg.select_bound(1), None);
    }

    #[test]
    fn auto_selector_prefers_fast_at_equal_tightness() {
        let reg = ObsRegistry::new();
        warm(&reg, 1, BoundKind::Mult, 0.5);
        // Exact Ptolemaic within the margin (not a win), fast within the
        // margin too: the cheaper family takes it.
        warm(&reg, 1, BoundKind::Ptolemaic, 0.495);
        warm(&reg, 1, BoundKind::PtolemaicFast, 0.505);
        assert_eq!(reg.select_bound(1), Some(BoundKind::PtolemaicFast));
        // A clearly looser fast family falls back to Mult.
        let reg2 = ObsRegistry::new();
        warm(&reg2, 1, BoundKind::Mult, 0.5);
        warm(&reg2, 1, BoundKind::PtolemaicFast, 0.9);
        assert_eq!(reg2.select_bound(1), Some(BoundKind::Mult));
    }

    #[test]
    fn mean_slack_reports_average() {
        let reg = ObsRegistry::new();
        let mut w = SlackWindow::default();
        w.record(BoundKind::Mult, 0.25);
        w.record(BoundKind::Mult, 0.75);
        w.drain_into(&reg, 0);
        let (mean, n) = reg.mean_slack(0, BoundKind::Mult).unwrap();
        assert_eq!(n, 2);
        assert!((mean - 0.5).abs() < 1e-4);
        assert_eq!(reg.mean_slack(0, BoundKind::Arccos), None);
    }

    #[test]
    fn trace_kind_tokens_round_trip() {
        for k in [
            TraceKind::Visit,
            TraceKind::Prune,
            TraceKind::Eval,
            TraceKind::Scan,
            TraceKind::BudgetStop,
            TraceKind::FilterGate,
        ] {
            assert_eq!(TraceKind::parse(k.token()), Some(k));
        }
        assert_eq!(TraceKind::parse("nope"), None);
    }
}
