//! The unified query-execution layer: a reusable per-worker scratch arena
//! (ADR-004).
//!
//! With the paper's bounds making each node visit cheap, the steady-state
//! cost of a query is increasingly the *bookkeeping around* the traversal:
//! every index used to allocate a fresh [`KnnHeap`], a fresh `BinaryHeap`
//! frontier, and fresh candidate/similarity buffers per call, and the i8
//! kernel re-quantized the query once per leaf bucket. A [`QueryContext`]
//! owns all of that scratch once per worker thread and lends it out query
//! after query:
//!
//! ```text
//! worker thread ── owns ──> QueryContext
//!                             ├─ KnnHeap            (lease_heap/release_heap)
//!                             ├─ frontier buffer    (lease_frontier/release_frontier)
//!                             ├─ Vec<f64> pool      (lease_sims/release_sims)
//!                             ├─ Vec<(u32,f64)> pool(lease_pairs/release_pairs)
//!                             ├─ KernelScratch      (cached QuantQuery + bound buffers)
//!                             └─ QueryStats         (per-query window + lifetime totals)
//! ```
//!
//! Exactness: a leased buffer is always cleared/reset before use, and the
//! cached quantized query is rebuilt from the same bytes it would be built
//! from inline, so results through a reused context are byte-identical to
//! the fresh-allocation path (enforced by `tests/integration_query.rs`).
//!
//! Ownership contract: callers that drive *multiple* index executions per
//! logical query (the generation fan-out, shard batches) call
//! [`QueryContext::begin_query`] exactly once per logical query; the
//! per-index entry points (`knn_into` / `range_into`) never call it, so one
//! query can share the quantized-query cache across the memtable and every
//! generation. `SimilarityIndex::knn_batch` / `range_batch` and the
//! compatibility wrappers call it for you.

pub mod plan;

pub use plan::{IdFilter, SearchMode, SearchRequest, SearchRequestBuilder, SearchResponse};

use std::collections::BinaryHeap;
use std::marker::PhantomData;

use crate::bounds::BoundKind;
use crate::index::{KnnHeap, QueryStats};
use crate::obs::{SlackWindow, TraceEvent, OBS};
use crate::storage::{FilterMode, KernelScratch, QueryBlock};

/// The maximum number of queries one shared-frontier traversal carries:
/// a batch entry's live-query set travels as a `u64` bitmask stored in
/// the frontier's auxiliary float (ADR-006), so one chunk holds at most
/// 64 slots. Larger request batches are served in chunks of this size.
pub const MAX_BATCH: usize = 64;

/// A type-erased frontier entry: the upper bound (the heap priority), a
/// node pointer, and one auxiliary float (the already-computed center/parent
/// similarity some trees carry alongside the node).
#[derive(Debug, Clone, Copy)]
struct FrontierEntry {
    ub: f64,
    ptr: usize,
    aux: f64,
}

impl PartialEq for FrontierEntry {
    fn eq(&self, other: &Self) -> bool {
        self.ub == other.ub
    }
}
impl Eq for FrontierEntry {}
impl PartialOrd for FrontierEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for FrontierEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Same comparison as `index::Prioritized`: by upper bound, ties
        // Equal — so a reused frontier pops in exactly the order the old
        // per-query BinaryHeap<Prioritized<_>> did.
        self.ub.partial_cmp(&other.ub).unwrap_or(std::cmp::Ordering::Equal)
    }
}

/// A best-first frontier over borrowed tree nodes whose backing buffer
/// comes from (and returns to) a [`QueryContext`].
///
/// The entries store `&'t T` type-erased as a pointer so one buffer can
/// serve every index's node type. Soundness: pointers enter only through
/// [`Frontier::push`], which demands a `&'t T`; the buffer is cleared when
/// leased, so no entry from a previous query (with a different `T` or a
/// dead lifetime) can ever be popped.
pub struct Frontier<'t, T> {
    heap: BinaryHeap<FrontierEntry>,
    _nodes: PhantomData<&'t T>,
}

impl<'t, T> Frontier<'t, T> {
    fn from_buf(mut buf: Vec<FrontierEntry>) -> Frontier<'t, T> {
        buf.clear();
        Frontier { heap: BinaryHeap::from(buf), _nodes: PhantomData }
    }

    fn into_buf(self) -> Vec<FrontierEntry> {
        self.heap.into_vec()
    }

    /// Push a node with its priority (`ub`) and auxiliary float.
    #[inline]
    pub fn push(&mut self, ub: f64, node: &'t T, aux: f64) {
        self.heap.push(FrontierEntry { ub, ptr: node as *const T as usize, aux });
    }

    /// Pop the highest-upper-bound node.
    #[inline]
    pub fn pop(&mut self) -> Option<(f64, &'t T, f64)> {
        self.heap.pop().map(|e| {
            // SAFETY: `e.ptr` was produced by `push` from a `&'t T` (the
            // buffer was cleared on lease, so no stale entries exist), and
            // `'t` is still live because `self` is parameterized by it.
            let node = unsafe { &*(e.ptr as *const T) };
            (e.ub, node, e.aux)
        })
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

/// Per-slot mode parameters of one batch entry, resolved from its
/// [`SearchRequest`] at [`BatchContext::begin`].
#[derive(Debug, Clone, Copy)]
pub(crate) struct BatchSlot {
    /// Range mode: hits are collected directly instead of through a heap.
    pub range: bool,
    /// The similarity threshold (`Range` / `KnnWithin` tau; `-1.0`, the
    /// cosine minimum, when the mode has none).
    pub tau: f64,
    /// `KnnWithin`: `tau` also prunes the kNN traversal outright.
    pub within: bool,
}

impl Default for BatchSlot {
    fn default() -> Self {
        BatchSlot { range: false, tau: -1.0, within: false }
    }
}

/// The multi-query traversal arena (ADR-006): per-slot result heaps,
/// stats windows, and kernel scratches, plus the packed [`QueryBlock`]
/// the GEMM-shaped multi kernels consume and the live-list/floor staging
/// buffers every shared-frontier leaf visit reuses. Leased from a
/// [`QueryContext`] ([`QueryContext::lease_batch`]) so the steady-state
/// batch path allocates nothing once the arena has grown to the largest
/// batch size it has served (ADR-004).
///
/// One batch carries at most [`MAX_BATCH`] slots; the index-level batch
/// entry points chunk larger request lists.
pub struct BatchContext {
    /// The packed query block fed to the multi kernels (CorpusView path;
    /// per-item corpora leave it empty).
    pub(crate) qb: QueryBlock,
    /// The batch-effective pruning bound: the uniform per-request override
    /// when the batch carries one, else the index's build-time bound, with
    /// `Auto` already resolved — set by the batch frame (`run_batch`)
    /// after [`BatchContext::begin`], read by every `traverse_batch`.
    pub(crate) bound: BoundKind,
    /// Per-slot kNN collectors (slot-indexed; idle for range slots).
    pub(crate) heaps: Vec<KnnHeap>,
    /// Per-slot instrumentation windows.
    pub(crate) stats: Vec<QueryStats>,
    /// Per-slot kernel scratches: one cached `QuantQuery` per slot per
    /// batch, amortized across every row block the traversal scans.
    pub(crate) scratches: Vec<KernelScratch>,
    /// Per-slot mode parameters.
    pub(crate) slots: Vec<BatchSlot>,
    /// Compacted live-slot list staged for the current kernel scan.
    pub(crate) live: Vec<u32>,
    /// Slot-indexed certified floors staged for the current kernel scan.
    pub(crate) floors: Vec<f64>,
    /// Active batch size (slots beyond it are idle capacity). Crate
    /// visibility only so the index-level batch frame can destructure the
    /// arena into disjoint field borrows; everyone else reads
    /// [`BatchContext::len`].
    pub(crate) len: usize,
}

impl Default for BatchContext {
    fn default() -> Self {
        BatchContext {
            qb: QueryBlock::default(),
            bound: BoundKind::Mult,
            heaps: Vec::new(),
            stats: Vec::new(),
            scratches: Vec::new(),
            slots: Vec::new(),
            live: Vec::new(),
            floors: Vec::new(),
            len: 0,
        }
    }
}

impl BatchContext {
    /// Active batch size.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Arm the arena for one batch of *plain* plans: per-slot heaps reset
    /// to their modes (with the `KnnWithin` floor pre-armed), stats and
    /// floors zeroed, quantized-query caches invalidated.
    ///
    /// # Panics
    /// Panics when the batch exceeds [`MAX_BATCH`] — callers chunk first.
    pub fn begin(&mut self, reqs: &[SearchRequest]) {
        let q = reqs.len();
        assert!(q <= MAX_BATCH, "batch of {q} exceeds MAX_BATCH={MAX_BATCH}");
        if self.slots.len() < q {
            self.heaps.resize_with(q, KnnHeap::default);
            self.stats.resize(q, QueryStats::default());
            self.scratches.resize_with(q, KernelScratch::new);
            self.slots.resize_with(q, BatchSlot::default);
            self.floors.resize(q, -1.0);
        }
        self.len = q;
        for (j, req) in reqs.iter().enumerate() {
            self.stats[j] = QueryStats::default();
            self.scratches[j].invalidate();
            self.slots[j] = match req.mode {
                SearchMode::Range { tau } => BatchSlot { range: true, tau, within: false },
                SearchMode::Knn { k } => {
                    self.heaps[j].reset(k);
                    BatchSlot { range: false, tau: -1.0, within: false }
                }
                SearchMode::KnnWithin { k, tau } => {
                    self.heaps[j].reset(k);
                    self.heaps[j].set_min(tau);
                    BatchSlot { range: false, tau, within: true }
                }
            };
        }
    }

    /// The all-live bitmask for this batch (the root frontier entry's
    /// auxiliary payload).
    #[inline]
    pub fn full_mask(&self) -> u64 {
        if self.len == MAX_BATCH {
            u64::MAX
        } else {
            (1u64 << self.len) - 1
        }
    }

    /// Whether slot `j` can still admit a node with certified upper bound
    /// `ub` — the batch form of the single-query prune predicates. A range
    /// slot is live iff `ub >= tau` (a node below the threshold cannot
    /// hold a hit). A kNN slot is dead once `ub` is strictly below its
    /// `KnnWithin` floor, or once its heap is full and `ub` cannot beat
    /// the current k-th similarity.
    #[inline]
    pub fn slot_alive(&self, j: usize, ub: f64) -> bool {
        let slot = self.slots[j];
        if slot.range {
            return ub >= slot.tau;
        }
        if slot.within && ub < slot.tau {
            return false;
        }
        let heap = &self.heaps[j];
        heap.len() < heap.k() || ub > heap.floor()
    }

    /// Drop every slot of `mask` that is dead at `ub` (queries retire from
    /// an entry as their heaps tighten between push and pop).
    #[inline]
    pub fn refine(&self, mask: u64, ub: f64) -> u64 {
        let mut out = mask;
        let mut m = mask;
        while m != 0 {
            let j = m.trailing_zeros();
            m &= m - 1;
            if !self.slot_alive(j as usize, ub) {
                out &= !(1u64 << j);
            }
        }
        out
    }

    /// Whether *any* slot of the batch could still admit a node with
    /// upper bound `ub` — the global termination check: when this is
    /// false at the popped (maximum remaining) bound of a best-first
    /// frontier, every remaining entry is dead for every query.
    #[inline]
    pub fn any_alive(&self, ub: f64) -> bool {
        (0..self.len).any(|j| self.slot_alive(j, ub))
    }

    /// Stage the compacted live-slot list and the slot-indexed certified
    /// floors for one kernel scan (`scan_ids_multi_with` /
    /// `scan_all_multi_with`): `floors[j]` is a value slot `j`'s result
    /// set provably cannot admit below — its heap floor, or its range
    /// threshold — captured at scan entry exactly like the single-query
    /// quantized pre-filter captures it.
    pub fn stage_live(&mut self, mask: u64) {
        self.live.clear();
        let mut m = mask;
        while m != 0 {
            let j = m.trailing_zeros() as usize;
            m &= m - 1;
            self.live.push(j as u32);
            self.floors[j] = if self.slots[j].range {
                self.slots[j].tau
            } else {
                self.heaps[j].floor()
            };
        }
    }
}

/// Reusable per-worker query scratch: every buffer a traversal needs, plus
/// per-query instrumentation and the kernel-level quantized-query cache.
///
/// Not `Sync`/shared: each worker thread owns one and lends pieces to the
/// traversal at hand. All leases hand back *owned* values (`std::mem::take`
/// under the hood), so a traversal can hold the result heap, the frontier,
/// and pooled buffers simultaneously without fighting the borrow checker,
/// and recursive traversals can lease one buffer per recursion level.
#[derive(Default)]
pub struct QueryContext {
    /// Reusable kNN collector (leased via [`QueryContext::lease_heap`]).
    heap: KnnHeap,
    /// Reusable frontier storage (leased via [`QueryContext::lease_frontier`]).
    frontier: Vec<FrontierEntry>,
    /// Pool of similarity buffers (pivot sims, split sims).
    sims_pool: Vec<Vec<f64>>,
    /// Pool of `(id, value)` buffers (candidate lists, visit orders,
    /// per-generation hit staging).
    pairs_pool: Vec<Vec<(u32, f64)>>,
    /// Pool of raw id buffers (budgeted chunk scans).
    ids_pool: Vec<Vec<u32>>,
    /// Per-query exact-evaluation budget (ADR-005), armed by
    /// [`QueryContext::apply_plan`]; measured against the current window's
    /// `stats.sim_evals`.
    budget: Option<u64>,
    /// Set by a traversal that stopped early on budget exhaustion; copied
    /// into [`SearchResponse::truncated`] by `search_into`.
    pub truncated: bool,
    /// Kernel-level scratch: cached [`crate::storage::KernelScratch`]
    /// quantized query + certified-bound buffers.
    scratch: KernelScratch,
    /// Instrumentation for the query in flight (since the last
    /// [`QueryContext::begin_query`]).
    pub stats: QueryStats,
    /// Stats of all *finished* queries (folded in at `begin_query`).
    totals: QueryStats,
    /// Queries started on this context.
    queries: u64,
    /// The multi-query traversal arena (ADR-006), leased via
    /// [`QueryContext::lease_batch`].
    batch: BatchContext,
    /// Per-context bound-slack window (ADR-007), drained into the global
    /// registry by the owning worker via [`QueryContext::drain_slack`].
    slack: SlackWindow,
    /// Whether aggregate observability (slack windows, kernel-scan spans)
    /// is recorded on this context; trace events are armed per request.
    obs_enabled: bool,
}

impl QueryContext {
    pub fn new() -> QueryContext {
        QueryContext::default()
    }

    /// Mark a logical query boundary: fold the previous query's stats into
    /// the lifetime totals, reset the per-query window, and invalidate the
    /// cached quantized query. Returns `true` when this context has served
    /// a query before (the context-reuse signal the serving metrics count).
    ///
    /// Call exactly once per logical query, *before* the first index
    /// execution — even when that query then fans out over many indexes
    /// (generations, or several scans of one shard batch): the quantized
    /// query is valid across all of them.
    pub fn begin_query(&mut self) -> bool {
        let reused = self.queries > 0;
        self.totals.merge(&self.stats);
        self.stats = QueryStats::default();
        self.scratch.invalidate();
        self.clear_plan();
        self.truncated = false;
        self.queries += 1;
        reused
    }

    /// Arm the per-request plan (ADR-005): evaluation budget, kernel
    /// override, and the id filter (copied into the kernel scratch's
    /// reused buffer — ids are interpreted in the *caller's local* id
    /// space, which is why layers translate via
    /// [`SearchRequest::localized`] before delegating). Every
    /// `search_into` implementation calls this at entry and
    /// [`QueryContext::clear_plan`] at exit, so legacy `knn_into` /
    /// `range_into` calls interleaved on the same context are unaffected.
    pub fn apply_plan(&mut self, req: &SearchRequest) {
        self.budget = req.budget;
        self.truncated = false;
        self.scratch.set_kernel_override(req.kernel);
        if req.trace {
            self.scratch.trace.arm();
        } else {
            self.scratch.trace.disarm();
        }
        match &req.filter {
            IdFilter::None => self.scratch.clear_filter(),
            IdFilter::Allow(ids) => {
                self.scratch.trace.push(TraceEvent::filter_gate(ids.len() as u64));
                self.scratch.set_filter(FilterMode::Allow, local_ids(ids))
            }
            IdFilter::Deny(ids) => {
                self.scratch.trace.push(TraceEvent::filter_gate(ids.len() as u64));
                self.scratch.set_filter(FilterMode::Deny, local_ids(ids))
            }
        }
    }

    /// Disarm the plan armed by [`QueryContext::apply_plan`] (buffers are
    /// kept; `truncated` is left for the caller to read).
    pub fn clear_plan(&mut self) {
        self.budget = None;
        self.scratch.set_kernel_override(None);
        self.scratch.clear_filter();
        self.scratch.trace.disarm();
    }

    /// Turn aggregate observability (ADR-007) on or off for this context:
    /// bound-slack windows and kernel-scan span timings. Workers that own
    /// a context enable it once; per-request EXPLAIN tracing is armed
    /// independently by [`QueryContext::apply_plan`].
    pub fn set_obs_enabled(&mut self, on: bool) {
        self.obs_enabled = on;
        self.scratch.obs_enabled = on;
    }

    /// Whether aggregate observability is on for this context.
    #[inline]
    pub fn obs_enabled(&self) -> bool {
        self.obs_enabled
    }

    /// Whether the in-flight request asked for an EXPLAIN trace.
    #[inline]
    pub fn trace_armed(&self) -> bool {
        self.scratch.trace.armed()
    }

    /// Whether the armed trace dropped events at `TRACE_CAP`.
    #[inline]
    pub fn trace_truncated(&self) -> bool {
        self.scratch.trace.truncated()
    }

    /// Record a node visit into the armed trace (one branch when off).
    #[inline]
    pub fn trace_visit(&mut self, id: u64) {
        self.scratch.trace.push(TraceEvent::visit(id));
    }

    /// Record a prune decision with its certified upper bound.
    #[inline]
    pub fn trace_prune(&mut self, id: u64, bound: f64) {
        self.scratch.trace.push(TraceEvent::prune(id, bound));
    }

    /// Record a generic trace event (budget stops, scan summaries the
    /// traversal itself issues).
    #[inline]
    pub fn trace_event(&mut self, ev: TraceEvent) {
        self.scratch.trace.push(ev);
    }

    /// Record an exact evaluation without a slack sample — for sites
    /// where the traversal holds no per-candidate certified bound
    /// (`bound` is `1.0`, the trivial one, at such sites).
    #[inline]
    pub fn trace_eval(&mut self, id: u64, bound: f64, sim: f64) {
        self.scratch.trace.push(TraceEvent::eval(id, bound, sim));
    }

    /// Record an exact evaluation whose admitting upper bound was `ub`:
    /// an `Eval` trace event when armed, and a bound-slack sample
    /// (`ub - sim`, keyed by `bound`) when aggregate observability is on.
    #[inline]
    pub fn note_eval_slack(&mut self, bound: BoundKind, id: u64, ub: f64, sim: f64) {
        if self.obs_enabled {
            self.slack.record(bound, ub - sim);
        }
        self.scratch.trace.push(TraceEvent::eval(id, ub, sim));
    }

    /// Move the recorded trace events into `out` (replacing its contents).
    #[inline]
    pub fn take_trace(&mut self, out: &mut Vec<TraceEvent>) {
        self.scratch.trace.take_into(out);
    }

    /// Drain the per-context slack window into the global registry under
    /// index-kind ordinal `index` (no-op when the window is empty).
    pub fn drain_slack(&mut self, index: usize) {
        self.slack.drain_into(&OBS, index);
    }

    /// Whether the armed evaluation budget is spent (always `false`
    /// without a budget). Traversals check this at node granularity and
    /// set [`QueryContext::truncated`] when they stop early.
    #[inline]
    pub fn budget_exhausted(&self) -> bool {
        self.budget.is_some_and(|b| self.stats.sim_evals >= b)
    }

    /// Whether the armed id filter admits local id `id` (always `true`
    /// without a filter). Kernel scans apply the same filter *before*
    /// exact evaluation; this entry point is for the per-node offers
    /// (vantage points, routing objects) tree traversals make directly.
    #[inline]
    pub fn admits(&self, id: u32) -> bool {
        self.scratch.filter_admits(id)
    }

    /// Queries started on this context (reuses = `queries() - 1`).
    pub fn queries(&self) -> u64 {
        self.queries
    }

    /// Reuse events since a [`QueryContext::queries`] snapshot `q0`: every
    /// query begun on this context after its very first counts as a reuse.
    /// The one formula for every worker that reports the context-reuse
    /// gauge per batch (snapshot before, report after).
    pub fn reuses_since(&self, q0: u64) -> u64 {
        self.queries.saturating_sub(1) - q0.saturating_sub(1)
    }

    /// Lifetime stats: every finished query plus the one in flight.
    pub fn totals(&self) -> QueryStats {
        let mut t = self.totals;
        t.merge(&self.stats);
        t
    }

    /// Lifetime number of quantized-query builds (one per query that
    /// touched a quantized scan, when the context is reused correctly).
    pub fn quant_builds(&self) -> u64 {
        self.scratch.quant_builds()
    }

    /// The kernel-level scratch, for threading into the `*_with` scan entry
    /// points of [`crate::storage::CorpusView`].
    #[inline]
    pub fn kernel_scratch(&mut self) -> &mut KernelScratch {
        &mut self.scratch
    }

    /// Lease the result heap, reset to retain `k`. Pair with
    /// [`QueryContext::release_heap`].
    #[inline]
    pub fn lease_heap(&mut self, k: usize) -> KnnHeap {
        let mut heap = std::mem::take(&mut self.heap);
        heap.reset(k);
        heap
    }

    #[inline]
    pub fn release_heap(&mut self, heap: KnnHeap) {
        self.heap = heap;
    }

    /// Lease the (cleared) frontier for a best-first traversal over nodes
    /// of type `T`. Pair with [`QueryContext::release_frontier`].
    #[inline]
    pub fn lease_frontier<'t, T>(&mut self) -> Frontier<'t, T> {
        Frontier::from_buf(std::mem::take(&mut self.frontier))
    }

    #[inline]
    pub fn release_frontier<T>(&mut self, frontier: Frontier<'_, T>) {
        self.frontier = frontier.into_buf();
    }

    /// Lease a cleared `Vec<f64>` from the pool (allocates only until the
    /// pool has grown to the traversal's maximum recursion depth). Pair
    /// with [`QueryContext::release_sims`].
    #[inline]
    pub fn lease_sims(&mut self) -> Vec<f64> {
        let mut v = self.sims_pool.pop().unwrap_or_default();
        v.clear();
        v
    }

    #[inline]
    pub fn release_sims(&mut self, v: Vec<f64>) {
        self.sims_pool.push(v);
    }

    /// Lease a cleared `Vec<(u32, f64)>` from the pool. Pair with
    /// [`QueryContext::release_pairs`].
    #[inline]
    pub fn lease_pairs(&mut self) -> Vec<(u32, f64)> {
        let mut v = self.pairs_pool.pop().unwrap_or_default();
        v.clear();
        v
    }

    #[inline]
    pub fn release_pairs(&mut self, v: Vec<(u32, f64)>) {
        self.pairs_pool.push(v);
    }

    /// Lease the multi-query traversal arena (ADR-006). The arena comes
    /// back in whatever state the last batch left it — callers arm it
    /// with [`BatchContext::begin`]. Pair with
    /// [`QueryContext::release_batch`].
    #[inline]
    pub fn lease_batch(&mut self) -> BatchContext {
        std::mem::take(&mut self.batch)
    }

    #[inline]
    pub fn release_batch(&mut self, batch: BatchContext) {
        self.batch = batch;
    }

    /// Lease a cleared `Vec<u32>` from the pool (budgeted chunk scans).
    /// Pair with [`QueryContext::release_ids`].
    #[inline]
    pub fn lease_ids(&mut self) -> Vec<u32> {
        let mut v = self.ids_pool.pop().unwrap_or_default();
        v.clear();
        v
    }

    #[inline]
    pub fn release_ids(&mut self, v: Vec<u32>) {
        self.ids_pool.push(v);
    }
}

/// Filter ids that fit the index-local `u32` id space (larger ids cannot
/// name any local row: an allow entry excludes nothing extra by dropping,
/// a deny entry constrains nothing).
fn local_ids(ids: &[u64]) -> impl Iterator<Item = u32> + '_ {
    ids.iter().filter(|&&id| id <= u32::MAX as u64).map(|&id| id as u32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn begin_query_rolls_stats_and_counts_reuse() {
        let mut ctx = QueryContext::new();
        assert!(!ctx.begin_query(), "first query is not a reuse");
        ctx.stats.sim_evals = 10;
        ctx.stats.pruned = 3;
        assert!(ctx.begin_query());
        assert_eq!(ctx.stats, QueryStats::default());
        assert_eq!(ctx.totals().sim_evals, 10);
        ctx.stats.sim_evals = 5;
        assert_eq!(ctx.totals().sim_evals, 15, "totals include the in-flight query");
        assert_eq!(ctx.queries(), 2);
        // The reuse gauge: the context's very first query is not a reuse.
        assert_eq!(ctx.reuses_since(0), 1);
        assert_eq!(ctx.reuses_since(1), 1);
        assert_eq!(ctx.reuses_since(2), 0);
        ctx.begin_query();
        assert_eq!(ctx.reuses_since(2), 1);
        assert_eq!(QueryContext::new().reuses_since(0), 0, "idle context reports none");
    }

    #[test]
    fn heap_lease_resets_and_keeps_capacity() {
        let mut ctx = QueryContext::new();
        let mut h = ctx.lease_heap(3);
        for (id, s) in [(5u32, 0.9f64), (1, 0.8), (2, 0.7), (9, 0.6)] {
            h.offer(id, s);
        }
        assert_eq!(h.len(), 3);
        ctx.release_heap(h);
        let h = ctx.lease_heap(2);
        assert!(h.is_empty(), "leased heap must start empty");
        assert_eq!(h.k(), 2);
        ctx.release_heap(h);
    }

    #[test]
    fn frontier_pops_best_first_and_reuses_buffer() {
        let nodes = [10u64, 20, 30];
        let mut ctx = QueryContext::new();
        let mut f: Frontier<'_, u64> = ctx.lease_frontier();
        f.push(0.2, &nodes[0], 1.0);
        f.push(0.9, &nodes[1], 2.0);
        f.push(0.5, &nodes[2], 3.0);
        let (ub, node, aux) = f.pop().unwrap();
        assert_eq!((ub, *node, aux), (0.9, 20, 2.0));
        assert_eq!(*f.pop().unwrap().1, 30);
        ctx.release_frontier(f);
        // A fresh lease over a *different* node type starts empty: the
        // leftover entry for nodes[0] must be unreachable.
        let f2: Frontier<'_, String> = ctx.lease_frontier();
        assert!(f2.is_empty());
        ctx.release_frontier(f2);
    }

    #[test]
    fn pools_recycle_buffers() {
        let mut ctx = QueryContext::new();
        let mut a = ctx.lease_sims();
        a.extend([1.0, 2.0]);
        let cap = a.capacity();
        let b = ctx.lease_sims(); // nested lease: a second, distinct buffer
        assert!(b.is_empty());
        ctx.release_sims(b);
        ctx.release_sims(a);
        let c = ctx.lease_sims();
        assert!(c.is_empty() && c.capacity() >= cap, "recycled buffer keeps capacity");
        ctx.release_sims(c);
        let p = ctx.lease_pairs();
        assert!(p.is_empty());
        ctx.release_pairs(p);
    }
}
