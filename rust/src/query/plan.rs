//! The typed search plan (ADR-005): one declarative request that flows
//! unchanged from the wire protocol through the coordinator, shards,
//! ingest generations, and into every index traversal and kernel scan.
//!
//! The paper's contribution is a *family* of certified bounds; a family is
//! only usable if the query — not seven method signatures — carries the
//! per-query choices. A [`SearchRequest`] names the query mode
//! ([`SearchMode`]: kNN, range, or kNN-within-a-floor) plus the options
//! the theory supports per query: a pruning-bound override, a kernel
//! backend override, a sorted allow/deny [`IdFilter`] applied *before*
//! exact evaluation inside kernel scans, and a similarity-evaluation
//! budget that degrades to a certified partial result (flagged in
//! [`SearchResponse::truncated`]).

use std::sync::Arc;

use crate::bounds::BoundKind;
use crate::index::QueryStats;
use crate::storage::KernelKind;

/// The query mode of a [`SearchRequest`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SearchMode {
    /// The `k` most similar items.
    Knn { k: usize },
    /// Every item with `sim >= tau`.
    Range { tau: f64 },
    /// The `k` most similar items *among those with `sim >= tau`*: both
    /// bounds prune one traversal (the kNN floor and the range threshold),
    /// and the result equals a post-filtered [`SearchMode::Knn`] exactly
    /// (see ADR-005 for the argument).
    KnnWithin { k: usize, tau: f64 },
}

impl SearchMode {
    /// The `k` of a kNN-flavored mode.
    pub fn k(&self) -> Option<usize> {
        match *self {
            SearchMode::Knn { k } | SearchMode::KnnWithin { k, .. } => Some(k),
            SearchMode::Range { .. } => None,
        }
    }

    /// The similarity threshold of a range-flavored mode.
    pub fn tau(&self) -> Option<f64> {
        match *self {
            SearchMode::Range { tau } | SearchMode::KnnWithin { tau, .. } => Some(tau),
            SearchMode::Knn { .. } => None,
        }
    }
}

/// A sorted id allow/deny list. Ids are in the id space of the layer the
/// request is handed to: global (`u64`) at the coordinator/wire level,
/// index-local at the index level; layers with a non-identity id mapping
/// translate via [`IdFilter::localize`] before delegating. Shared behind
/// an `Arc` so fanning a request out across shards never copies the list.
#[derive(Debug, Clone, Default, PartialEq)]
pub enum IdFilter {
    /// Every id is admitted.
    #[default]
    None,
    /// Only the listed ids are admitted. Must be sorted ascending.
    Allow(Arc<Vec<u64>>),
    /// The listed ids are excluded. Must be sorted ascending.
    Deny(Arc<Vec<u64>>),
}

impl IdFilter {
    pub fn is_none(&self) -> bool {
        matches!(self, IdFilter::None)
    }

    /// The sorted id list, if any.
    pub fn ids(&self) -> Option<&[u64]> {
        match self {
            IdFilter::None => None,
            IdFilter::Allow(ids) | IdFilter::Deny(ids) => Some(ids),
        }
    }

    /// Whether the id list is sorted ascending (vacuously true for `None`).
    /// The builder and the wire parser always produce sorted lists; the
    /// coordinator validates hand-built requests with this.
    pub fn is_sorted(&self) -> bool {
        self.ids().is_none_or(|ids| ids.windows(2).all(|w| w[0] <= w[1]))
    }

    /// Translate the filter into another id space: each id maps through
    /// `map` (`None` drops it — an allow/deny entry for an id a partition
    /// does not hold constrains nothing there). The output is re-sorted
    /// only when `map` was non-monotone; the serving layers' maps
    /// (subtract-a-base, binary-search over an ascending id column) keep
    /// order, so they pay one linear is-sorted check instead of a sort.
    pub fn localize(&self, mut map: impl FnMut(u64) -> Option<u64>) -> IdFilter {
        let translate = |ids: &Arc<Vec<u64>>, map: &mut dyn FnMut(u64) -> Option<u64>| {
            let mut out: Vec<u64> = ids.iter().filter_map(|&id| map(id)).collect();
            if !out.windows(2).all(|w| w[0] <= w[1]) {
                out.sort_unstable();
            }
            Arc::new(out)
        };
        match self {
            IdFilter::None => IdFilter::None,
            IdFilter::Allow(ids) => IdFilter::Allow(translate(ids, &mut map)),
            IdFilter::Deny(ids) => IdFilter::Deny(translate(ids, &mut map)),
        }
    }
}

/// A typed, declarative search plan — the one argument every layer's
/// `search` entry point takes (ADR-005). Build with [`SearchRequest::knn`]
/// / [`SearchRequest::range`] / [`SearchRequest::knn_within`]:
///
/// ```
/// use simetra::query::SearchRequest;
/// let req = SearchRequest::knn(10).within(0.7).budget(50_000).build();
/// assert_eq!(req.mode.k(), Some(10));
/// assert_eq!(req.mode.tau(), Some(0.7));
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct SearchRequest {
    pub mode: SearchMode,
    /// Per-request pruning-bound override; `None` keeps the bound the
    /// index was built with. Sound for every [`BoundKind`]: tree shapes
    /// store exact similarity intervals, so any certified bound prunes
    /// them correctly (looser bounds cost evaluations, never results).
    pub bound: Option<BoundKind>,
    /// Per-request kernel backend override, resolved against the serving
    /// store's available backends (exact kinds are always available; the
    /// i8 pre-filter only where a sidecar is live — otherwise the
    /// coordinator rejects with `KernelUnavailable`).
    pub kernel: Option<KernelKind>,
    /// Sorted allow/deny id list, applied before exact evaluation inside
    /// kernel scans: filtered-out rows never cost a similarity evaluation.
    pub filter: IdFilter,
    /// Budget of exact similarity evaluations. When a traversal exhausts
    /// it, the search stops early and returns a certified partial result
    /// (exact over the evaluated subset) with
    /// [`SearchResponse::truncated`] set. Applied per partition (shard /
    /// generation-set window).
    pub budget: Option<u64>,
    /// Record an EXPLAIN trace (ADR-007): a bounded event log of the
    /// traversal (visits, prune decisions with their certified bounds,
    /// exact evaluations, kernel scans, budget/filter gates) returned in
    /// [`SearchResponse::trace`]. Results are byte-identical to the
    /// untraced plan; traced requests take the per-query path (never the
    /// shared-frontier batch descent).
    pub trace: bool,
}

impl SearchRequest {
    /// A plain kNN plan (returns a builder).
    pub fn knn(k: usize) -> SearchRequestBuilder {
        SearchRequestBuilder::new(SearchMode::Knn { k })
    }

    /// A plain range plan (returns a builder).
    pub fn range(tau: f64) -> SearchRequestBuilder {
        SearchRequestBuilder::new(SearchMode::Range { tau })
    }

    /// A kNN plan restricted to `sim >= tau` (returns a builder).
    pub fn knn_within(k: usize, tau: f64) -> SearchRequestBuilder {
        SearchRequestBuilder::new(SearchMode::KnnWithin { k, tau })
    }

    /// Whether the request carries no per-request options — the shape the
    /// coordinator's uniform-batch fast paths accept.
    pub fn is_plain(&self) -> bool {
        self.bound.is_none() && self.is_plain_except_bound()
    }

    /// Like [`SearchRequest::is_plain`] but tolerating a pruning-bound
    /// override: the effective bound is batch-global state in the
    /// shared-frontier traversal, so a batch whose requests all agree on
    /// the override batches exactly like a plain one (ADR-006 follow-on).
    /// Kernel overrides, filters, budgets, and traces remain per-query.
    pub fn is_plain_except_bound(&self) -> bool {
        self.kernel.is_none() && self.budget.is_none() && self.filter.is_none() && !self.trace
    }

    /// The same plan with `mode` and a translated filter — how layers with
    /// a non-identity id mapping (shards, generations) delegate downward.
    pub fn localized(
        &self,
        mode: SearchMode,
        map: impl FnMut(u64) -> Option<u64>,
    ) -> SearchRequest {
        SearchRequest {
            mode,
            bound: self.bound,
            kernel: self.kernel,
            filter: self.filter.localize(map),
            budget: self.budget,
            trace: self.trace,
        }
    }
}

/// Builder for [`SearchRequest`] (all options default to off).
#[derive(Debug, Clone)]
pub struct SearchRequestBuilder {
    req: SearchRequest,
}

impl SearchRequestBuilder {
    fn new(mode: SearchMode) -> SearchRequestBuilder {
        SearchRequestBuilder {
            req: SearchRequest {
                mode,
                bound: None,
                kernel: None,
                filter: IdFilter::None,
                budget: None,
                trace: false,
            },
        }
    }

    /// Restrict the result set to `sim >= tau` ([`SearchMode::Knn`]
    /// becomes [`SearchMode::KnnWithin`]; on range modes this replaces the
    /// threshold).
    pub fn within(mut self, tau: f64) -> Self {
        self.req.mode = match self.req.mode {
            SearchMode::Knn { k } | SearchMode::KnnWithin { k, .. } => {
                SearchMode::KnnWithin { k, tau }
            }
            SearchMode::Range { .. } => SearchMode::Range { tau },
        };
        self
    }

    /// Override the pruning bound for this request.
    pub fn bound(mut self, bound: BoundKind) -> Self {
        self.req.bound = Some(bound);
        self
    }

    /// Override the kernel backend for this request.
    pub fn kernel(mut self, kernel: KernelKind) -> Self {
        self.req.kernel = Some(kernel);
        self
    }

    /// Admit only these ids (sorted and deduplicated here).
    pub fn allow(mut self, mut ids: Vec<u64>) -> Self {
        ids.sort_unstable();
        ids.dedup();
        self.req.filter = IdFilter::Allow(Arc::new(ids));
        self
    }

    /// Exclude these ids (sorted and deduplicated here).
    pub fn deny(mut self, mut ids: Vec<u64>) -> Self {
        ids.sort_unstable();
        ids.dedup();
        self.req.filter = IdFilter::Deny(Arc::new(ids));
        self
    }

    /// Cap the exact similarity evaluations spent on this request.
    pub fn budget(mut self, sim_evals: u64) -> Self {
        self.req.budget = Some(sim_evals);
        self
    }

    /// Record an EXPLAIN trace of the traversal (ADR-007).
    pub fn trace(mut self) -> Self {
        self.req.trace = true;
        self
    }

    pub fn build(self) -> SearchRequest {
        self.req
    }
}

/// The result of one index-level search: hits in `(sim desc, id asc)`
/// order, the per-query instrumentation window, and whether an evaluation
/// budget truncated the traversal (hits are then exact over the evaluated
/// subset). Reusable: every `search_into` replaces the contents.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SearchResponse {
    pub hits: Vec<(u32, f64)>,
    pub stats: QueryStats,
    pub truncated: bool,
    /// The EXPLAIN event log when the request set [`SearchRequest::trace`]
    /// (empty otherwise; capped at [`crate::obs::TRACE_CAP`] events).
    pub trace: Vec<crate::obs::TraceEvent>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_covers_every_option() {
        let req = SearchRequest::knn(10)
            .within(0.7)
            .bound(BoundKind::Euclidean)
            .kernel(KernelKind::Simd)
            .allow(vec![9, 3, 3, 7])
            .budget(1000)
            .trace()
            .build();
        assert_eq!(req.mode, SearchMode::KnnWithin { k: 10, tau: 0.7 });
        assert_eq!(req.bound, Some(BoundKind::Euclidean));
        assert_eq!(req.kernel, Some(KernelKind::Simd));
        assert_eq!(req.filter.ids(), Some(&[3u64, 7, 9][..]));
        assert!(req.filter.is_sorted());
        assert_eq!(req.budget, Some(1000));
        assert!(req.trace);
        assert!(!req.is_plain());
        assert!(SearchRequest::range(0.5).build().is_plain());
        // A trace request alone de-plains the plan: traced searches must
        // take the per-query path, never the shared-frontier batch.
        assert!(!SearchRequest::knn(3).trace().build().is_plain());
    }

    #[test]
    fn mode_accessors() {
        assert_eq!(SearchMode::Knn { k: 3 }.k(), Some(3));
        assert_eq!(SearchMode::Knn { k: 3 }.tau(), None);
        assert_eq!(SearchMode::Range { tau: 0.2 }.tau(), Some(0.2));
        assert_eq!(SearchMode::KnnWithin { k: 2, tau: 0.5 }.k(), Some(2));
    }

    #[test]
    fn localize_translates_and_drops() {
        let f = SearchRequest::knn(1).deny(vec![5, 10, 15]).build().filter;
        let local = f.localize(|id| if id >= 10 { Some(id - 10) } else { None });
        assert_eq!(local.ids(), Some(&[0u64, 5][..]));
        assert!(matches!(local, IdFilter::Deny(_)));
        assert!(IdFilter::None.localize(Some).is_none());
    }
}
