//! The real PJRT engine: compiles AOT HLO-text artifacts on the CPU client
//! and executes them. Only compiled with the `pjrt` feature, which requires
//! the `xla` bindings (see rust/Cargo.toml).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use super::{Manifest, PivotBounds, TopKResult};

/// Synchronous PJRT engine owning the compiled executables.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    dir: PathBuf,
}

impl Engine {
    /// Load the manifest and compile every artifact on the CPU client.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e}"))?;
        let mut exes = HashMap::new();
        for art in &manifest.artifacts {
            let path = dir.join(&art.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe =
                client.compile(&comp).map_err(|e| anyhow!("compile {}: {e}", art.name))?;
            exes.insert(art.name.clone(), exe);
        }
        Ok(Engine { client, manifest, exes, dir: dir.to_path_buf() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
        xla::Literal::vec1(data)
            .reshape(dims)
            .map_err(|e| anyhow!("reshape {dims:?}: {e}"))
    }

    /// Batched top-k: `queries` is row-major `(q, d)`, `corpus` row-major
    /// `(n, d)` (rows need not be normalized — the artifact normalizes).
    /// Pads to the selected variant and strips padding from the result.
    pub fn score_topk(
        &self,
        queries: &[f32],
        q: usize,
        corpus: &[f32],
        n: usize,
        d: usize,
        k: usize,
    ) -> Result<TopKResult> {
        anyhow::ensure!(queries.len() == q * d, "queries shape mismatch");
        anyhow::ensure!(corpus.len() == n * d, "corpus shape mismatch");
        let art = self
            .manifest
            .pick_score_topk(q, n, d, k)
            .ok_or_else(|| anyhow!("no score_topk artifact fits q={q} n={n} d={d} k={k}"))?;
        let (aq, an, ad, ak) = (
            art.param("q") as usize,
            art.param("n") as usize,
            art.param("d") as usize,
            art.param("k") as usize,
        );
        let mut qbuf = vec![0.0f32; aq * ad];
        for r in 0..q {
            qbuf[r * ad..r * ad + d].copy_from_slice(&queries[r * d..(r + 1) * d]);
        }
        let mut cbuf = vec![0.0f32; an * ad];
        for r in 0..n {
            cbuf[r * ad..r * ad + d].copy_from_slice(&corpus[r * d..(r + 1) * d]);
        }
        let lq = Self::literal_f32(&qbuf, &[aq as i64, ad as i64])?;
        let lc = Self::literal_f32(&cbuf, &[an as i64, ad as i64])?;
        let ln = xla::Literal::scalar(n as i32);
        let exe = &self.exes[&art.name];
        let out = exe
            .execute::<xla::Literal>(&[lq, lc, ln])
            .map_err(|e| anyhow!("execute {}: {e}", art.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e}"))?;
        let (values_l, indices_l) = out.to_tuple2().map_err(|e| anyhow!("tuple: {e}"))?;
        let all_values: Vec<f32> = values_l.to_vec().map_err(|e| anyhow!("values: {e}"))?;
        let all_indices: Vec<i32> = indices_l.to_vec().map_err(|e| anyhow!("indices: {e}"))?;
        // Strip query padding and clip k.
        let kk = k.min(ak).min(n);
        let mut values = Vec::with_capacity(q * kk);
        let mut indices = Vec::with_capacity(q * kk);
        for r in 0..q {
            values.extend_from_slice(&all_values[r * ak..r * ak + kk]);
            indices.extend_from_slice(&all_indices[r * ak..r * ak + kk]);
        }
        Ok(TopKResult { values, indices, k: kk })
    }

    /// Batched LAESA pivot filtering: `sim_qp` row-major `(q, p)`, `sim_pc`
    /// row-major `(p, n)`. Returns certified bounds on `sim(q_i, c_j)`.
    pub fn pivot_filter(
        &self,
        sim_qp: &[f32],
        q: usize,
        sim_pc: &[f32],
        p: usize,
        n: usize,
    ) -> Result<PivotBounds> {
        anyhow::ensure!(sim_qp.len() == q * p, "sim_qp shape mismatch");
        anyhow::ensure!(sim_pc.len() == p * n, "sim_pc shape mismatch");
        let art = self
            .manifest
            .pick_pivot_filter(q, p, n)
            .ok_or_else(|| anyhow!("no pivot_filter artifact fits q={q} p={p} n={n}"))?;
        let (aq, ap, an) =
            (art.param("q") as usize, art.param("p") as usize, art.param("n") as usize);
        // Padding pivots must certify nothing: a pivot row of s=0 yields the
        // vacuous interval [-1, 1] per Eq. 10/13 (radical = 1), so zero-fill
        // is safe. Padded corpus columns produce garbage bounds for j >= n,
        // which the caller never reads.
        let mut qp = vec![0.0f32; aq * ap];
        for r in 0..q {
            qp[r * ap..r * ap + p].copy_from_slice(&sim_qp[r * p..(r + 1) * p]);
        }
        let mut pc = vec![0.0f32; ap * an];
        for r in 0..p {
            pc[r * an..r * an + n].copy_from_slice(&sim_pc[r * n..(r + 1) * n]);
        }
        let lqp = Self::literal_f32(&qp, &[aq as i64, ap as i64])?;
        let lpc = Self::literal_f32(&pc, &[ap as i64, an as i64])?;
        let exe = &self.exes[&art.name];
        let out = exe
            .execute::<xla::Literal>(&[lqp, lpc])
            .map_err(|e| anyhow!("execute {}: {e}", art.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e}"))?;
        let (lb_l, ub_l) = out.to_tuple2().map_err(|e| anyhow!("tuple: {e}"))?;
        let lb_all: Vec<f32> = lb_l.to_vec().map_err(|e| anyhow!("lb: {e}"))?;
        let ub_all: Vec<f32> = ub_l.to_vec().map_err(|e| anyhow!("ub: {e}"))?;
        let mut lb = Vec::with_capacity(q * n);
        let mut ub = Vec::with_capacity(q * n);
        for r in 0..q {
            lb.extend_from_slice(&lb_all[r * an..r * an + n]);
            ub.extend_from_slice(&ub_all[r * an..r * an + n]);
        }
        Ok(PivotBounds { lb, ub, n })
    }
}
