//! The artifact manifest written by `python/compile/aot.py`.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::Json;

/// Tensor description (shape + dtype) of one artifact input/output.
#[derive(Debug, Clone)]
pub struct TensorMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorMeta {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(TensorMeta {
            name: v.req("name")?.as_str()?.to_string(),
            shape: v
                .req("shape")?
                .as_arr()?
                .iter()
                .map(|s| s.as_usize())
                .collect::<Result<_>>()?,
            dtype: v.req("dtype")?.as_str()?.to_string(),
        })
    }
}

/// One AOT-compiled entry point at a fixed (padded) shape.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub entry: String,
    pub file: String,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
    pub params: HashMap<String, i64>,
}

impl ArtifactMeta {
    pub fn param(&self, key: &str) -> i64 {
        *self.params.get(key).unwrap_or(&0)
    }

    fn from_json(v: &Json) -> Result<Self> {
        let tensors = |key: &str| -> Result<Vec<TensorMeta>> {
            v.req(key)?.as_arr()?.iter().map(TensorMeta::from_json).collect()
        };
        let mut params = HashMap::new();
        if let Json::Obj(fields) = v.req("params")? {
            for (k, val) in fields {
                params.insert(k.clone(), val.as_i64()?);
            }
        }
        Ok(ArtifactMeta {
            name: v.req("name")?.as_str()?.to_string(),
            entry: v.req("entry")?.as_str()?.to_string(),
            file: v.req("file")?.as_str()?.to_string(),
            inputs: tensors("inputs")?,
            outputs: tensors("outputs")?,
            params,
        })
    }
}

/// `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u32,
    pub pad_score: f64,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let v = Json::parse(text).context("parsing manifest JSON")?;
        let version = v.req("version")?.as_usize()? as u32;
        anyhow::ensure!(version == 1, "unsupported manifest version {version}");
        let artifacts = v
            .req("artifacts")?
            .as_arr()?
            .iter()
            .map(ArtifactMeta::from_json)
            .collect::<Result<_>>()?;
        Ok(Manifest { version, pad_score: v.req("pad_score")?.as_f64()?, artifacts })
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    /// All artifacts for an entry point.
    pub fn variants(&self, entry: &str) -> Vec<&ArtifactMeta> {
        self.artifacts.iter().filter(|a| a.entry == entry).collect()
    }

    /// Smallest `score_topk` variant that fits a (q, n, d, k) request.
    pub fn pick_score_topk(&self, q: usize, n: usize, d: usize, k: usize) -> Option<&ArtifactMeta> {
        self.variants("score_topk")
            .into_iter()
            .filter(|a| {
                // d may be zero-padded up to the artifact's d (zero features
                // change neither dots nor norms).
                a.param("q") as usize >= q
                    && a.param("n") as usize >= n
                    && a.param("d") as usize >= d
                    && a.param("k") as usize >= k
            })
            .min_by_key(|a| (a.param("q"), a.param("n"), a.param("d"), a.param("k")))
    }

    /// Smallest `pivot_filter` variant fitting (q, p, n).
    pub fn pick_pivot_filter(&self, q: usize, p: usize, n: usize) -> Option<&ArtifactMeta> {
        self.variants("pivot_filter")
            .into_iter()
            .filter(|a| {
                a.param("q") as usize >= q
                    && a.param("p") as usize >= p
                    && a.param("n") as usize >= n
            })
            .min_by_key(|a| (a.param("q"), a.param("p"), a.param("n")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Manifest {
        Manifest::parse(
            r#"{
              "version": 1, "pad_score": -2.0,
              "artifacts": [
                {"name": "a", "entry": "score_topk", "file": "a.hlo.txt",
                 "inputs": [{"name": "queries", "shape": [8, 128], "dtype": "f32"}],
                 "outputs": [],
                 "params": {"q": 8, "n": 1024, "d": 128, "k": 16}},
                {"name": "b", "entry": "score_topk", "file": "b.hlo.txt",
                 "inputs": [], "outputs": [],
                 "params": {"q": 32, "n": 4096, "d": 128, "k": 16}},
                {"name": "p", "entry": "pivot_filter", "file": "p.hlo.txt",
                 "inputs": [], "outputs": [],
                 "params": {"q": 8, "p": 16, "n": 1024}}
              ]
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_tensor_meta() {
        let m = sample();
        assert_eq!(m.pad_score, -2.0);
        assert_eq!(m.artifacts[0].inputs[0].shape, vec![8, 128]);
        assert_eq!(m.artifacts[0].inputs[0].dtype, "f32");
    }

    #[test]
    fn picks_smallest_fitting_variant() {
        let m = sample();
        assert_eq!(m.pick_score_topk(4, 500, 128, 10).unwrap().name, "a");
        assert_eq!(m.pick_score_topk(16, 500, 128, 10).unwrap().name, "b");
        assert_eq!(m.pick_score_topk(4, 2000, 128, 10).unwrap().name, "b");
        assert!(m.pick_score_topk(64, 500, 128, 10).is_none());
        // Smaller d fits via zero-padding; larger d does not.
        assert_eq!(m.pick_score_topk(4, 500, 64, 10).unwrap().name, "a");
        assert!(m.pick_score_topk(4, 500, 256, 10).is_none());
    }

    #[test]
    fn pivot_variant_selection() {
        let m = sample();
        assert_eq!(m.pick_pivot_filter(8, 16, 1000).unwrap().name, "p");
        assert!(m.pick_pivot_filter(9, 16, 1000).is_none());
    }

    #[test]
    fn rejects_bad_version() {
        assert!(Manifest::parse(r#"{"version": 2, "pad_score": 0, "artifacts": []}"#).is_err());
    }
}
