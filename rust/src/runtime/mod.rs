//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! The `xla` crate's PJRT handles hold raw pointers and are not `Send`;
//! [`Engine`] therefore owns them on the thread that created it, and
//! [`EngineHandle`] wraps an `Engine` on a dedicated executor thread behind
//! a channel so the multi-threaded coordinator can call it from anywhere —
//! also serializing device access, which is what a single-device client
//! wants regardless.

pub mod manifest;

pub use manifest::{ArtifactMeta, Manifest, TensorMeta};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

/// Result of a batched `score_topk` execution (padded rows removed).
#[derive(Debug, Clone)]
pub struct TopKResult {
    /// `values[qi * k + j]` = j-th best similarity for query `qi`.
    pub values: Vec<f32>,
    /// Matching corpus indices.
    pub indices: Vec<i32>,
    pub k: usize,
}

/// Result of a `pivot_filter` execution.
#[derive(Debug, Clone)]
pub struct PivotBounds {
    /// Row-major `(q, n)` lower bounds.
    pub lb: Vec<f32>,
    /// Row-major `(q, n)` upper bounds.
    pub ub: Vec<f32>,
    pub n: usize,
}

/// Synchronous PJRT engine owning the compiled executables.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    dir: PathBuf,
}

impl Engine {
    /// Load the manifest and compile every artifact on the CPU client.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e}"))?;
        let mut exes = HashMap::new();
        for art in &manifest.artifacts {
            let path = dir.join(&art.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe =
                client.compile(&comp).map_err(|e| anyhow!("compile {}: {e}", art.name))?;
            exes.insert(art.name.clone(), exe);
        }
        Ok(Engine { client, manifest, exes, dir: dir.to_path_buf() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
        xla::Literal::vec1(data)
            .reshape(dims)
            .map_err(|e| anyhow!("reshape {dims:?}: {e}"))
    }

    /// Batched top-k: `queries` is row-major `(q, d)`, `corpus` row-major
    /// `(n, d)` (rows need not be normalized — the artifact normalizes).
    /// Pads to the selected variant and strips padding from the result.
    pub fn score_topk(
        &self,
        queries: &[f32],
        q: usize,
        corpus: &[f32],
        n: usize,
        d: usize,
        k: usize,
    ) -> Result<TopKResult> {
        anyhow::ensure!(queries.len() == q * d, "queries shape mismatch");
        anyhow::ensure!(corpus.len() == n * d, "corpus shape mismatch");
        let art = self
            .manifest
            .pick_score_topk(q, n, d, k)
            .ok_or_else(|| anyhow!("no score_topk artifact fits q={q} n={n} d={d} k={k}"))?;
        let (aq, an, ad, ak) = (
            art.param("q") as usize,
            art.param("n") as usize,
            art.param("d") as usize,
            art.param("k") as usize,
        );
        let mut qbuf = vec![0.0f32; aq * ad];
        for r in 0..q {
            qbuf[r * ad..r * ad + d].copy_from_slice(&queries[r * d..(r + 1) * d]);
        }
        let mut cbuf = vec![0.0f32; an * ad];
        for r in 0..n {
            cbuf[r * ad..r * ad + d].copy_from_slice(&corpus[r * d..(r + 1) * d]);
        }
        let lq = Self::literal_f32(&qbuf, &[aq as i64, ad as i64])?;
        let lc = Self::literal_f32(&cbuf, &[an as i64, ad as i64])?;
        let ln = xla::Literal::scalar(n as i32);
        let exe = &self.exes[&art.name];
        let out = exe
            .execute::<xla::Literal>(&[lq, lc, ln])
            .map_err(|e| anyhow!("execute {}: {e}", art.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e}"))?;
        let (values_l, indices_l) = out.to_tuple2().map_err(|e| anyhow!("tuple: {e}"))?;
        let all_values: Vec<f32> = values_l.to_vec().map_err(|e| anyhow!("values: {e}"))?;
        let all_indices: Vec<i32> = indices_l.to_vec().map_err(|e| anyhow!("indices: {e}"))?;
        // Strip query padding and clip k.
        let kk = k.min(ak).min(n);
        let mut values = Vec::with_capacity(q * kk);
        let mut indices = Vec::with_capacity(q * kk);
        for r in 0..q {
            values.extend_from_slice(&all_values[r * ak..r * ak + kk]);
            indices.extend_from_slice(&all_indices[r * ak..r * ak + kk]);
        }
        Ok(TopKResult { values, indices, k: kk })
    }

    /// Batched LAESA pivot filtering: `sim_qp` row-major `(q, p)`, `sim_pc`
    /// row-major `(p, n)`. Returns certified bounds on `sim(q_i, c_j)`.
    pub fn pivot_filter(
        &self,
        sim_qp: &[f32],
        q: usize,
        sim_pc: &[f32],
        p: usize,
        n: usize,
    ) -> Result<PivotBounds> {
        anyhow::ensure!(sim_qp.len() == q * p, "sim_qp shape mismatch");
        anyhow::ensure!(sim_pc.len() == p * n, "sim_pc shape mismatch");
        let art = self
            .manifest
            .pick_pivot_filter(q, p, n)
            .ok_or_else(|| anyhow!("no pivot_filter artifact fits q={q} p={p} n={n}"))?;
        let (aq, ap, an) =
            (art.param("q") as usize, art.param("p") as usize, art.param("n") as usize);
        // Padding pivots must certify nothing: a pivot row of s=0 yields the
        // vacuous interval [-1, 1] per Eq. 10/13 (radical = 1), so zero-fill
        // is safe. Padded corpus columns produce garbage bounds for j >= n,
        // which the caller never reads.
        let mut qp = vec![0.0f32; aq * ap];
        for r in 0..q {
            qp[r * ap..r * ap + p].copy_from_slice(&sim_qp[r * p..(r + 1) * p]);
        }
        let mut pc = vec![0.0f32; ap * an];
        for r in 0..p {
            pc[r * an..r * an + n].copy_from_slice(&sim_pc[r * n..(r + 1) * n]);
        }
        let lqp = Self::literal_f32(&qp, &[aq as i64, ap as i64])?;
        let lpc = Self::literal_f32(&pc, &[ap as i64, an as i64])?;
        let exe = &self.exes[&art.name];
        let out = exe
            .execute::<xla::Literal>(&[lqp, lpc])
            .map_err(|e| anyhow!("execute {}: {e}", art.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e}"))?;
        let (lb_l, ub_l) = out.to_tuple2().map_err(|e| anyhow!("tuple: {e}"))?;
        let lb_all: Vec<f32> = lb_l.to_vec().map_err(|e| anyhow!("lb: {e}"))?;
        let ub_all: Vec<f32> = ub_l.to_vec().map_err(|e| anyhow!("ub: {e}"))?;
        let mut lb = Vec::with_capacity(q * n);
        let mut ub = Vec::with_capacity(q * n);
        for r in 0..q {
            lb.extend_from_slice(&lb_all[r * an..r * an + n]);
            ub.extend_from_slice(&ub_all[r * an..r * an + n]);
        }
        Ok(PivotBounds { lb, ub, n })
    }
}

/// A request processed by the engine thread.
enum EngineRequest {
    ScoreTopK {
        queries: Vec<f32>,
        q: usize,
        corpus: Vec<f32>,
        n: usize,
        d: usize,
        k: usize,
        reply: mpsc::SyncSender<Result<TopKResult>>,
    },
    PivotFilter {
        sim_qp: Vec<f32>,
        q: usize,
        sim_pc: Vec<f32>,
        p: usize,
        n: usize,
        reply: mpsc::SyncSender<Result<PivotBounds>>,
    },
}

/// Shareable handle to an [`Engine`] on its own executor thread. Calls are
/// blocking; concurrent callers are serialized by the channel.
pub struct EngineHandle {
    tx: Mutex<mpsc::Sender<EngineRequest>>,
}

impl EngineHandle {
    /// Spawn the executor thread and load the engine there.
    pub fn spawn(dir: &Path) -> Result<Self> {
        let dir = dir.to_path_buf();
        let (tx, rx) = mpsc::channel::<EngineRequest>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        std::thread::Builder::new()
            .name("simetra-engine".into())
            .spawn(move || {
                let engine = match Engine::load(&dir) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                for req in rx {
                    match req {
                        EngineRequest::ScoreTopK { queries, q, corpus, n, d, k, reply } => {
                            let _ = reply.send(engine.score_topk(&queries, q, &corpus, n, d, k));
                        }
                        EngineRequest::PivotFilter { sim_qp, q, sim_pc, p, n, reply } => {
                            let _ = reply.send(engine.pivot_filter(&sim_qp, q, &sim_pc, p, n));
                        }
                    }
                }
            })
            .context("spawning engine thread")?;
        ready_rx.recv().context("engine thread died during load")??;
        Ok(EngineHandle { tx: Mutex::new(tx) })
    }

    fn send(&self, req: EngineRequest) -> Result<()> {
        self.tx
            .lock()
            .map_err(|_| anyhow!("engine handle poisoned"))?
            .send(req)
            .map_err(|_| anyhow!("engine thread gone"))
    }

    /// Batched top-k (see [`Engine::score_topk`]); blocks until done.
    pub fn score_topk(
        &self,
        queries: Vec<f32>,
        q: usize,
        corpus: Vec<f32>,
        n: usize,
        d: usize,
        k: usize,
    ) -> Result<TopKResult> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.send(EngineRequest::ScoreTopK { queries, q, corpus, n, d, k, reply })?;
        rx.recv().map_err(|_| anyhow!("engine dropped request"))?
    }

    /// Pivot filtering (see [`Engine::pivot_filter`]); blocks until done.
    pub fn pivot_filter(
        &self,
        sim_qp: Vec<f32>,
        q: usize,
        sim_pc: Vec<f32>,
        p: usize,
        n: usize,
    ) -> Result<PivotBounds> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.send(EngineRequest::PivotFilter { sim_qp, q, sim_pc, p, n, reply })?;
        rx.recv().map_err(|_| anyhow!("engine dropped request"))?
    }
}
