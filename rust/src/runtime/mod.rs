//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! The `xla` crate's PJRT handles hold raw pointers and are not `Send`;
//! [`Engine`] therefore owns them on the thread that created it, and
//! [`EngineHandle`] wraps an `Engine` on a dedicated executor thread behind
//! a channel so the multi-threaded coordinator can call it from anywhere —
//! also serializing device access, which is what a single-device client
//! wants regardless.
//!
//! Corpus inputs cross the channel as [`CorpusView`] handles: the executor
//! thread reads the shared [`crate::storage::CorpusStore`] buffer directly
//! (an `Arc` bump per tile, no re-packing) and only the `xla` literal
//! construction copies bytes, at the FFI boundary where it is unavoidable.
//!
//! The real engine needs the `xla` bindings and is gated behind the `pjrt`
//! feature; without it a stub with the same API reports the missing feature
//! from [`Engine::load`].

pub mod manifest;

#[cfg(feature = "pjrt")]
mod engine;
#[cfg(not(feature = "pjrt"))]
#[path = "stub.rs"]
mod engine;

pub use engine::Engine;
pub use manifest::{ArtifactMeta, Manifest, TensorMeta};

use std::path::Path;
use std::sync::{mpsc, Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use crate::storage::CorpusView;

/// Result of a batched `score_topk` execution (padded rows removed).
#[derive(Debug, Clone)]
pub struct TopKResult {
    /// `values[qi * k + j]` = j-th best similarity for query `qi`.
    pub values: Vec<f32>,
    /// Matching corpus indices.
    pub indices: Vec<i32>,
    pub k: usize,
}

/// Result of a `pivot_filter` execution.
#[derive(Debug, Clone)]
pub struct PivotBounds {
    /// Row-major `(q, n)` lower bounds.
    pub lb: Vec<f32>,
    /// Row-major `(q, n)` upper bounds.
    pub ub: Vec<f32>,
    pub n: usize,
}

/// A request processed by the engine thread.
enum EngineRequest {
    ScoreTopK {
        /// Row-major `(q, d)` queries, shared — one flattening per batch,
        /// reused across corpus tiles.
        queries: Arc<Vec<f32>>,
        q: usize,
        /// Zero-copy window onto the corpus store.
        corpus: CorpusView,
        k: usize,
        reply: mpsc::SyncSender<Result<TopKResult>>,
    },
    PivotFilter {
        sim_qp: Vec<f32>,
        q: usize,
        sim_pc: Vec<f32>,
        p: usize,
        n: usize,
        reply: mpsc::SyncSender<Result<PivotBounds>>,
    },
}

/// Shareable handle to an [`Engine`] on its own executor thread. Calls are
/// blocking; concurrent callers are serialized by the channel.
pub struct EngineHandle {
    tx: Mutex<mpsc::Sender<EngineRequest>>,
}

impl EngineHandle {
    /// Spawn the executor thread and load the engine there.
    pub fn spawn(dir: &Path) -> Result<Self> {
        let dir = dir.to_path_buf();
        let (tx, rx) = mpsc::channel::<EngineRequest>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        std::thread::Builder::new()
            .name("simetra-engine".into())
            .spawn(move || {
                let engine = match Engine::load(&dir) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                for req in rx {
                    match req {
                        EngineRequest::ScoreTopK { queries, q, corpus, k, reply } => {
                            let n = corpus.len();
                            let d = corpus.dim();
                            let flat = corpus.contiguous_or_gather();
                            let _ = reply.send(engine.score_topk(&queries, q, flat, n, d, k));
                        }
                        EngineRequest::PivotFilter { sim_qp, q, sim_pc, p, n, reply } => {
                            let _ = reply.send(engine.pivot_filter(&sim_qp, q, &sim_pc, p, n));
                        }
                    }
                }
            })
            .context("spawning engine thread")?;
        ready_rx.recv().context("engine thread died during load")??;
        Ok(EngineHandle { tx: Mutex::new(tx) })
    }

    fn send(&self, req: EngineRequest) -> Result<()> {
        self.tx
            .lock()
            .map_err(|_| anyhow!("engine handle poisoned"))?
            .send(req)
            .map_err(|_| anyhow!("engine thread gone"))
    }

    /// Batched top-k over a corpus view (see [`Engine::score_topk`]);
    /// blocks until done. `n` and `d` come from the view.
    pub fn score_topk(
        &self,
        queries: Arc<Vec<f32>>,
        q: usize,
        corpus: CorpusView,
        k: usize,
    ) -> Result<TopKResult> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.send(EngineRequest::ScoreTopK { queries, q, corpus, k, reply })?;
        rx.recv().map_err(|_| anyhow!("engine dropped request"))?
    }

    /// Pivot filtering (see [`Engine::pivot_filter`]); blocks until done.
    pub fn pivot_filter(
        &self,
        sim_qp: Vec<f32>,
        q: usize,
        sim_pc: Vec<f32>,
        p: usize,
        n: usize,
    ) -> Result<PivotBounds> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.send(EngineRequest::PivotFilter { sim_qp, q, sim_pc, p, n, reply })?;
        rx.recv().map_err(|_| anyhow!("engine dropped request"))?
    }
}
