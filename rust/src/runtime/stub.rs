//! Stub engine for builds without the `pjrt` feature.
//!
//! The offline build has no `xla` bindings, so [`Engine::load`] always
//! fails with a clear message and the struct itself is uninhabited — the
//! coordinator's Index mode, every index, and all native benches work
//! unchanged, while Engine/Hybrid modes report the missing feature at
//! startup instead of failing mysteriously later.

use std::path::Path;

use anyhow::{bail, Result};

use super::{Manifest, PivotBounds, TopKResult};

/// Uninhabited placeholder with the same API as the real PJRT engine.
pub struct Engine {
    never: std::convert::Infallible,
}

impl Engine {
    /// Always fails: enabling the real engine is a two-step change —
    /// add the `xla` dependency to rust/Cargo.toml (it is not bundled in
    /// the offline build, so the `pjrt` feature alone will not compile),
    /// then rebuild with `--features pjrt`.
    pub fn load(_dir: &Path) -> Result<Self> {
        bail!(
            "simetra was built without the `pjrt` feature: PJRT artifacts cannot \
             be compiled or executed. To enable, first add the `xla` dependency \
             to rust/Cargo.toml (see the [features] comment there — the feature \
             alone will not compile without it), then rebuild with --features pjrt"
        )
    }

    pub fn manifest(&self) -> &Manifest {
        match self.never {}
    }

    pub fn artifact_dir(&self) -> &Path {
        match self.never {}
    }

    pub fn platform(&self) -> String {
        match self.never {}
    }

    pub fn score_topk(
        &self,
        _queries: &[f32],
        _q: usize,
        _corpus: &[f32],
        _n: usize,
        _d: usize,
        _k: usize,
    ) -> Result<TopKResult> {
        match self.never {}
    }

    pub fn pivot_filter(
        &self,
        _sim_qp: &[f32],
        _q: usize,
        _sim_pc: &[f32],
        _p: usize,
        _n: usize,
    ) -> Result<PivotBounds> {
        match self.never {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_reports_missing_feature() {
        let err = Engine::load(Path::new("/nonexistent")).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
