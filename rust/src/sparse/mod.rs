//! Sparse vectors as sorted `(index, value)` pairs with a merge-join dot
//! product — the representation paper §2 singles out as the reason cosine
//! similarity is cheap on text data.

/// A sparse vector: strictly increasing `idx`, parallel `val`, normalized to
/// unit L2 norm at construction (zero vectors stay zero).
#[derive(Debug, Clone, PartialEq)]
pub struct SparseVec {
    idx: Vec<u32>,
    val: Vec<f32>,
    dim: usize,
}

impl SparseVec {
    /// Build from (index, value) pairs; sorts, merges duplicate indexes
    /// (summing), drops explicit zeros, and L2-normalizes.
    pub fn new(mut pairs: Vec<(u32, f32)>, dim: usize) -> Self {
        // lint: stable-sort — construction path, not a query path; order
        // ties (duplicate indexes) must keep insertion order for the merge.
        pairs.sort_by_key(|&(i, _)| i);
        let mut idx = Vec::with_capacity(pairs.len());
        let mut val: Vec<f32> = Vec::with_capacity(pairs.len());
        for (i, v) in pairs {
            debug_assert!((i as usize) < dim, "index {i} out of dim {dim}");
            if let Some(&last) = idx.last() {
                if last == i {
                    *val.last_mut().unwrap() += v;
                    continue;
                }
            }
            idx.push(i);
            val.push(v);
        }
        // Drop zeros created by cancellation, then normalize.
        let mut k = 0;
        for j in 0..idx.len() {
            if val[j] != 0.0 {
                idx[k] = idx[j];
                val[k] = val[j];
                k += 1;
            }
        }
        idx.truncate(k);
        val.truncate(k);
        let norm: f64 = val.iter().map(|&v| v as f64 * v as f64).sum::<f64>().sqrt();
        if norm > 0.0 {
            let inv = (1.0 / norm) as f32;
            for v in &mut val {
                *v *= inv;
            }
        }
        SparseVec { idx, val, dim }
    }

    /// Build from a dense slice (test/interop convenience).
    pub fn from_dense(dense: &[f32]) -> Self {
        let pairs = dense
            .iter()
            .enumerate()
            .filter(|(_, &v)| v != 0.0)
            .map(|(i, &v)| (i as u32, v))
            .collect();
        Self::new(pairs, dense.len())
    }

    /// Materialize to a dense (normalized) vector of length `dim` — the
    /// bridge to the PJRT batched-scoring path.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim];
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            out[i as usize] = v;
        }
        out
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }

    #[inline]
    pub fn iter(&self) -> impl Iterator<Item = (u32, f32)> + '_ {
        self.idx.iter().copied().zip(self.val.iter().copied())
    }

    /// Merge-join dot product: O(nnz_a + nnz_b), touching only indexes
    /// present in both vectors.
    pub fn dot(&self, other: &Self) -> f64 {
        let (mut i, mut j) = (0usize, 0usize);
        let mut sum = 0.0f64;
        let (ai, av) = (&self.idx, &self.val);
        let (bi, bv) = (&other.idx, &other.val);
        while i < ai.len() && j < bi.len() {
            match ai[i].cmp(&bi[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    sum += av[i] as f64 * bv[j] as f64;
                    i += 1;
                    j += 1;
                }
            }
        }
        sum.clamp(-1.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_dot_matches_dense_dot() {
        let a = vec![0.0f32, 2.0, 0.0, 0.0, 3.0, 0.0, 1.0];
        let b = vec![1.0f32, 4.0, 0.0, 2.0, 5.0, 0.0, 0.0];
        let sa = SparseVec::from_dense(&a);
        let sb = SparseVec::from_dense(&b);
        let na: f64 = a.iter().map(|&v| (v * v) as f64).sum::<f64>().sqrt();
        let nb: f64 = b.iter().map(|&v| (v * v) as f64).sum::<f64>().sqrt();
        let want: f64 =
            a.iter().zip(&b).map(|(&x, &y)| (x as f64) * (y as f64)).sum::<f64>() / (na * nb);
        assert!((sa.dot(&sb) - want).abs() < 1e-6);
    }

    #[test]
    fn duplicate_indexes_are_merged() {
        let v = SparseVec::new(vec![(3, 1.0), (3, 2.0), (1, 1.0)], 8);
        assert_eq!(v.nnz(), 2);
        let w = SparseVec::new(vec![(1, 1.0), (3, 3.0)], 8);
        assert!((v.dot(&w) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn cancellation_drops_entries() {
        let v = SparseVec::new(vec![(2, 1.5), (2, -1.5), (5, 1.0)], 8);
        assert_eq!(v.nnz(), 1);
    }

    #[test]
    fn to_dense_round_trips() {
        let v = SparseVec::new(vec![(0, 1.0), (6, -2.0)], 7);
        let d = v.to_dense();
        let back = SparseVec::from_dense(&d);
        assert_eq!(v, back);
    }

    #[test]
    fn disjoint_supports_have_zero_similarity() {
        let a = SparseVec::new(vec![(0, 1.0), (2, 1.0)], 6);
        let b = SparseVec::new(vec![(1, 1.0), (3, 1.0)], 6);
        assert_eq!(a.dot(&b), 0.0);
    }

    #[test]
    fn zero_vector_is_safe() {
        let z = SparseVec::new(vec![], 4);
        let a = SparseVec::new(vec![(1, 2.0)], 4);
        assert_eq!(z.dot(&a), 0.0);
        assert_eq!(z.nnz(), 0);
    }
}
